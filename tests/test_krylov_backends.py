"""Tree vs flat Krylov backend equivalence.

The flat backend ravels iterates once per solve and runs the recurrences
through the fused Pallas kernels (interpret mode on CPU); the tree backend
is the original sharding-preserving pytree path. Same math, same
KrylovResult — differences are reduction-order fp noise only.

The hf_step equivalence runs at init_damping=5.0: on a *barely damped*
indefinite Hessian, Bi-CG-STAB chaotically amplifies reduction-order noise
(same effect test_distributed.py documents for the 8-device schedule), so
backend equivalence — like distributed equivalence — is only meaningful in
the well-conditioned regime.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, hf_init, hf_step
from repro.core.krylov import FlatVectorBackend, get_backend
from repro.core.solvers import bicgstab, cg, pcg
from repro.core.tree_math import tree_norm, tree_random_like, tree_sub
from repro.data import classification_dataset
from repro.models import build_mlp


def _vec(x):
    """Two-leaf pytree (vector + matrix leaf) to exercise ravel/unravel."""
    x = np.asarray(x, np.float32)
    return {"a": jnp.asarray(x[:5]), "b": jnp.asarray(x[5:]).reshape(3, 3)}


def _unvec(t):
    return np.concatenate([np.asarray(t["a"]).ravel(), np.asarray(t["b"]).ravel()])


def _mat_op(M):
    def op(v):
        f = jnp.concatenate([v["a"].ravel(), v["b"].ravel()])
        out = M @ f
        return {"a": out[:5], "b": out[5:].reshape(3, 3)}
    return op


def _flat_be(template):
    return get_backend("flat", template=template, interpret=True)


class TestFlatBackendRepresentation:
    def test_lift_lower_roundtrip(self):
        t = _vec(np.arange(14))
        be = _flat_be(t)
        flat = be.lift(t)
        assert flat.shape == (14,) and flat.dtype == jnp.float32
        back = be.lower(flat)
        for k in t:
            np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(t[k]))

    def test_wrap_op_matches_tree_op(self):
        rng = np.random.RandomState(0)
        M = jnp.asarray(rng.randn(14, 14).astype(np.float32))
        t = _vec(rng.randn(14))
        be = _flat_be(t)
        out = be.wrap_op(_mat_op(M))(be.lift(t))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(M @ jnp.asarray(_unvec(t))), rtol=1e-6)

    def test_fused_ops_match_tree_ops(self):
        rng = np.random.RandomState(1)
        tree_be = get_backend("tree")
        y, u, v = (_vec(rng.randn(14)) for _ in range(3))
        be = _flat_be(y)
        yf, uf, vf = be.lift(y), be.lift(u), be.lift(v)
        np.testing.assert_allclose(
            np.asarray(be.fused_update(yf, uf, vf, 0.3, -1.7)),
            _unvec(tree_be.fused_update(y, u, v, 0.3, -1.7)), rtol=1e-6, atol=1e-6)
        rf, d1f, d2f = be.update_residual(yf, uf, 0.6, r0s=vf)
        rt, d1t, d2t = tree_be.update_residual(y, u, 0.6, r0s=v)
        np.testing.assert_allclose(np.asarray(rf), _unvec(rt), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(float(d1f), float(d1t), rtol=1e-5)
        np.testing.assert_allclose(float(d2f), float(d2t), rtol=1e-5)
        np.testing.assert_allclose(
            [float(x) for x in be.dot2(uf, vf)],
            [float(x) for x in tree_be.dot2(u, v)], rtol=1e-5)


class TestSolverEquivalence:
    """Each solver, both backends, same KrylovResult (to fp noise)."""

    def _spd(self):
        rng = np.random.RandomState(2)
        Q = rng.randn(14, 14).astype(np.float32)
        M = jnp.asarray(Q @ Q.T + 14 * np.eye(14, dtype=np.float32))
        return M, _vec(rng.randn(14)), _vec(np.zeros(14))

    def test_cg(self):
        M, b, x0 = self._spd()
        rt = cg(_mat_op(M), b, x0, lam=0.0, max_iters=40, tol=1e-8)
        rf = cg(_mat_op(M), b, x0, lam=0.0, max_iters=40, tol=1e-8,
                backend=_flat_be(b))
        assert int(rt.iters) == int(rf.iters)
        np.testing.assert_allclose(_unvec(rt.x), _unvec(rf.x), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(_unvec(rt.r), _unvec(rf.r), atol=1e-4)

    def test_pcg(self):
        M, b, x0 = self._spd()
        m_inv = {"a": 1.0 / jnp.diag(M)[:5], "b": (1.0 / jnp.diag(M)[5:]).reshape(3, 3)}
        rt = pcg(_mat_op(M), b, x0, lam=0.0, M_inv=m_inv, max_iters=40, tol=1e-8)
        rf = pcg(_mat_op(M), b, x0, lam=0.0, M_inv=m_inv, max_iters=40, tol=1e-8,
                 backend=_flat_be(b))
        assert int(rt.iters) == int(rf.iters)
        np.testing.assert_allclose(_unvec(rt.x), _unvec(rf.x), rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("precondition", [False, True])
    def test_bicgstab(self, precondition):
        M, b, x0 = self._spd()
        m_inv = None
        if precondition:
            m_inv = {"a": 1.0 / jnp.diag(M)[:5], "b": (1.0 / jnp.diag(M)[5:]).reshape(3, 3)}
        rt = bicgstab(_mat_op(M), b, x0, lam=0.0, max_iters=40, tol=1e-8, M_inv=m_inv)
        rf = bicgstab(_mat_op(M), b, x0, lam=0.0, max_iters=40, tol=1e-8, M_inv=m_inv,
                      backend=_flat_be(b))
        assert int(rt.iters) == int(rf.iters)
        np.testing.assert_allclose(_unvec(rt.x), _unvec(rf.x), rtol=1e-4, atol=1e-5)
        # near-tied φ values along the trajectory make the *argmin* iterate
        # noise-sensitive; the invariant is that both backends' best iterates
        # reach the same quadratic-model value φ(x) = ½xᵀMx − bᵀx.
        def phi(x):
            return 0.5 * float(x @ np.asarray(M) @ x) - float(_unvec(b) @ x)
        np.testing.assert_allclose(phi(_unvec(rt.x_best)), phi(_unvec(rf.x_best)),
                                   rtol=1e-4, atol=1e-6)

    def test_nc_capture_matches_on_indefinite(self):
        d = np.array([4.0, -2.0, 1.0, -0.5] + [1.0] * 10, np.float32)
        M = jnp.asarray(np.diag(d))
        rng = np.random.RandomState(3)
        b, x0 = _vec(rng.randn(14)), _vec(np.zeros(14))
        rt = bicgstab(_mat_op(M), b, x0, lam=0.0, max_iters=3, tol=1e-8)
        rf = bicgstab(_mat_op(M), b, x0, lam=0.0, max_iters=3, tol=1e-8,
                      backend=_flat_be(b))
        assert bool(rt.nc_found) and bool(rf.nc_found)
        np.testing.assert_allclose(float(rt.nc_curv), float(rf.nc_curv),
                                   rtol=1e-4, atol=1e-5)


class TestHFStepEquivalence:
    """The tentpole acceptance test: one hf_step on a small MLP, flat fused
    backend (interpret) vs pytree backend — same delta, same metrics to 1e-5,
    for all four solver variants."""

    SOLVERS = ["gn_cg", "hessian_cg", "hybrid_cg", "bicgstab"]

    def _setup(self):
        model = build_mlp((8, 16, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 64, 8, 4)
        params = model.init(jax.random.PRNGKey(1))
        return model, data, params

    @pytest.mark.parametrize("solver", SOLVERS)
    def test_step_matches_across_backends(self, solver):
        model, data, params = self._setup()
        out = {}
        for backend in ("tree", "flat"):
            cfg = HFConfig(solver=solver, max_cg_iters=8, init_damping=5.0,
                           krylov_backend=backend)
            state = hf_init(params, cfg)
            step = jax.jit(lambda p, s, cfg=cfg: hf_step(
                model.loss_fn, p, s, data, data, cfg,
                model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
            p2, _, metrics = step(params, state)
            out[backend] = (p2, metrics)
        pt, mt = out["tree"]
        pf, mf = out["flat"]
        # identical delta: params stepped to the same point
        for a, b in zip(jax.tree_util.tree_leaves(pt), jax.tree_util.tree_leaves(pf)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)
        assert int(mt["cg_iters"]) == int(mf["cg_iters"])
        for k in mt:
            np.testing.assert_allclose(float(mt[k]), float(mf[k]),
                                       rtol=1e-5, atol=1e-5, err_msg=k)

    def test_flat_backend_trains(self):
        """A few full steps with the fused backend actually reduce the loss."""
        model, data, params = self._setup()
        cfg = HFConfig(solver="bicgstab", max_cg_iters=6, krylov_backend="flat")
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s: hf_step(model.loss_fn, p, s, data, data, cfg))
        losses = []
        for _ in range(6):
            params, state, m = step(params, state)
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.7 * losses[0]


class TestConfigValidation:
    def test_bad_backend_name_raises(self):
        with pytest.raises(ValueError, match="krylov_backend"):
            HFConfig(krylov_backend="ravel")

    def test_get_backend_flat_requires_template(self):
        with pytest.raises(ValueError, match="template"):
            get_backend("flat")

    def test_get_backend_unknown_name(self):
        with pytest.raises(ValueError, match="backend"):
            get_backend("dense")


def _nan_op(M):
    """Operator whose products are NaN-poisoned (models a blown-up HVP)."""
    inner = _mat_op(M)

    def op(v):
        return jax.tree_util.tree_map(lambda x: x * jnp.nan, inner(v))

    return op


class TestNonFiniteProductBreakdown:
    """ISSUE 9 satellite: NaN curvature products surface as breakdown in
    the standard recurrences too — for BOTH vector backends — and never
    as convergence (NaN < tol is False; the guards must not rely on it)."""

    def _sys(self):
        rng = np.random.RandomState(3)
        A = rng.randn(14, 14).astype(np.float32)
        M = jnp.asarray(A @ A.T + 14 * np.eye(14, dtype=np.float32))
        return M, _vec(rng.randn(14)), _vec(np.zeros(14))

    @pytest.mark.parametrize("be", [None, "flat"])
    def test_cg_nan_op(self, be):
        M, b, x0 = self._sys()
        backend = _flat_be(b) if be == "flat" else None
        r = cg(_nan_op(M), b, x0, lam=0.0, max_iters=20, tol=1e-8,
               backend=backend)
        assert bool(r.breakdown)
        assert not bool(r.residual < 1e-8)
        assert int(r.iters) <= 2  # froze immediately, no zombie iterations
        assert np.isfinite(_unvec(r.x)).all()

    @pytest.mark.parametrize("be", [None, "flat"])
    def test_bicgstab_nan_op(self, be):
        M, b, x0 = self._sys()
        backend = _flat_be(b) if be == "flat" else None
        r = bicgstab(_nan_op(M), b, x0, lam=0.0, max_iters=20, tol=1e-8,
                     backend=backend)
        assert bool(r.breakdown)
        assert not bool(r.residual < 1e-8)
        assert np.isfinite(_unvec(r.x)).all()

    def test_clean_solves_unaffected_by_guard(self):
        # the finiteness guard must not flag healthy systems
        M, b, x0 = self._sys()
        for solver in (cg, bicgstab):
            r = solver(_mat_op(M), b, x0, lam=0.0, max_iters=60, tol=1e-8)
            assert not bool(r.breakdown)
            assert float(r.residual) < 1e-4
