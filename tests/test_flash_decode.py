"""Split-K flash-decode kernels vs jnp oracles and the `decode_attend`
model path (interpret mode): GQA x sliding-window x ragged per-sequence t
x non-block/page-aligned lengths, paged gather with unmapped pages, and the
(o, m, l) stats contract the sharded decode merge relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import flash_decode as fd
from repro.kernels import ops, ref
from repro.models import attention as att


def _qkv_dec(key, B, W, H, KV, hd, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, W, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, W, KV, hd), jnp.float32).astype(dtype)
    return q, k, v


FD_CASES = [
    # (B, W, H, KV, hd, blk_k, n_splits, window, ragged, dtype)
    (1, 128, 2, 2, 32, 64, 2, None, False, jnp.float32),
    (2, 256, 4, 2, 32, 64, 4, None, False, jnp.float32),   # GQA
    (2, 300, 4, 1, 32, 64, 4, 90, False, jnp.float32),     # window + unaligned W
    (3, 200, 4, 2, 32, 64, 8, None, True, jnp.float32),    # ragged per-seq t
    (2, 192, 8, 2, 64, 64, 3, 64, True, jnp.bfloat16),     # everything, bf16
    (1, 40, 2, 2, 16, 128, 4, None, False, jnp.float32),   # W < blk_k
]


@pytest.mark.parametrize("B,W,H,KV,hd,blk_k,n_splits,window,ragged,dtype",
                         FD_CASES)
def test_flash_decode_matches_ref(B, W, H, KV, hd, blk_k, n_splits, window,
                                  ragged, dtype):
    q, k, v = _qkv_dec(jax.random.PRNGKey(0), B, W, H, KV, hd, dtype)
    # rolling-slot layout: absolute position p in slot p % W, all written
    pos = jnp.arange(W, dtype=jnp.int32)
    if ragged:
        t = jnp.array([(7 * b + 11) % W for b in range(B)], jnp.int32)
    else:
        t = jnp.int32(W - 1)
    bias = fd.decode_bias(pos, t, window=window)
    out = ops.flash_decode(q, k, v, bias, blk_k=blk_k, n_splits=n_splits,
                           interpret=True)
    expected = ref.flash_decode_ref(q, k, v, bias)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_stats_contract():
    """return_stats (o, m, l) must merge across an arbitrary KV split with
    combine_splits to the unsplit result — the sequence-sharded decode
    schedule is exactly this merge."""
    B, W, H, KV, hd = 2, 256, 4, 2, 32
    q, k, v = _qkv_dec(jax.random.PRNGKey(1), B, W, H, KV, hd)
    bias = fd.decode_bias(jnp.arange(W, dtype=jnp.int32), jnp.int32(W - 1))
    o_full = ops.flash_decode(q, k, v, bias, blk_k=64, interpret=True)
    # split the window into two "shards", merge their (o, m, l)
    half = W // 2
    parts = [
        ops.flash_decode(q, k[:, s], v[:, s], bias[:, s], blk_k=64,
                         interpret=True, return_stats=True)
        for s in (slice(0, half), slice(half, W))
    ]
    G = H // KV
    o = jnp.stack([p[0].reshape(B, KV, G, hd) for p in parts], axis=2)
    m = jnp.stack([p[1].reshape(B, KV, G) for p in parts], axis=2)
    l = jnp.stack([p[2].reshape(B, KV, G) for p in parts], axis=2)
    merged, _, _ = fd.combine_splits(o, m, l)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(o_full),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_fully_masked_rows():
    B, W, H, KV, hd = 2, 128, 2, 2, 16
    q, k, v = _qkv_dec(jax.random.PRNGKey(2), B, W, H, KV, hd)
    bias = jnp.full((B, W), fd.NEG_INF, jnp.float32).at[0].set(0.0)
    o, m, l = ops.flash_decode(q, k, v, bias, blk_k=64, interpret=True,
                               return_stats=True)
    assert np.all(np.asarray(o[1]) == 0.0)
    assert np.all(np.asarray(m[1]) <= fd.NEG_INF / 2)
    assert np.all(np.asarray(l[1]) == 0.0)
    np.testing.assert_allclose(np.asarray(o[0]),
                               np.asarray(ref.flash_decode_ref(q, k, v, bias)[0]),
                               rtol=2e-5, atol=2e-5)


PAGED_CASES = [
    # (B, P, ps, maxp, H, KV, hd, window, seq_lens)
    (2, 8, 64, 3, 4, 2, 32, None, (130, 57)),       # non-page-aligned lengths
    (3, 12, 64, 5, 2, 1, 32, 100, (320, 17, 64)),   # window frees early pages
    (2, 6, 128, 2, 4, 4, 16, None, (256, 1)),       # MHA, full + single token
]


@pytest.mark.parametrize("B,P,ps,maxp,H,KV,hd,window,seq_lens", PAGED_CASES)
def test_flash_decode_paged_matches_ref(B, P, ps, maxp, H, KV, hd, window,
                                        seq_lens):
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, H, hd))
    k_pool = jax.random.normal(k2, (P, ps, KV, hd))
    v_pool = jax.random.normal(k3, (P, ps, KV, hd))
    seq_len = jnp.array(seq_lens, jnp.int32)
    # interleave sequences' pages across the pool; unmapped -> -1
    tbl = np.full((B, maxp), -1, np.int32)
    nxt = 0
    for b in range(B):
        for j in range(-(-int(seq_lens[b]) // ps)):
            tbl[b, j] = nxt % P
            nxt += 1
    # a window that has rolled past a whole page frees it
    if window is not None:
        for b in range(B):
            first_live = max(0, int(seq_lens[b]) - window)
            for j in range(maxp):
                if (j + 1) * ps <= first_live:
                    tbl[b, j] = -1
    page_table = jnp.asarray(tbl)
    bias = fd.paged_bias(page_table, seq_len, ps, window=window)
    out = ops.flash_decode_paged(q, k_pool, v_pool, page_table, bias,
                                 interpret=True)
    expected = ref.flash_decode_paged_ref(q, k_pool, v_pool, page_table, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------- model-path parity ----
def _tiny_cfg(**kw):
    return ModelConfig(arch_id="t", family="dense", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64, **kw)


@pytest.mark.parametrize("window", [None, 12])
def test_decode_attend_flash_parity(window):
    """cfg.use_flash_attention decode == the dense `_sdpa` decode_attend
    oracle, token by token, through a rolling window."""
    cfg = _tiny_cfg(sliding_window=window)
    cfgf = cfg.replace(use_flash_attention=True)
    p = att.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B = 2
    c_ref = att.init_kv_cache(cfg, B, 32, jnp.float32)
    c_fl = c_ref
    for t in range(20):
        xt = jax.random.normal(jax.random.PRNGKey(t), (B, 1, cfg.d_model))
        y_ref, c_ref = att.decode_attend(p, xt, t, c_ref, cfg)
        y_fl, c_fl = att.decode_attend(p, xt, t, c_fl, cfgf)
        np.testing.assert_allclose(np.asarray(y_fl), np.asarray(y_ref),
                                   rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(c_fl.k), np.asarray(c_ref.k))


def test_decode_attend_ragged_matches_per_sequence():
    """Ragged per-slot decode == each sequence decoded alone with the scalar
    path, at staggered absolute positions (continuous-batching semantics)."""
    cfg = _tiny_cfg(sliding_window=10, use_flash_attention=True)
    p = att.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, steps = 3, 8
    offsets = jnp.array([0, 2, 5])
    cr = att.init_kv_cache(cfg, B, 32, jnp.float32, ragged=True)
    ys = []
    for step in range(steps):
        xt = jax.random.normal(jax.random.PRNGKey(step), (B, 1, cfg.d_model))
        y, cr = att.decode_attend_ragged(p, xt, offsets + step, cr, cfg)
        ys.append(y)
    for b in range(B):
        c1 = att.init_kv_cache(cfg, 1, 32, jnp.float32)
        for step in range(steps):
            t = int(offsets[b]) + step
            xt = jax.random.normal(jax.random.PRNGKey(step),
                                   (B, 1, cfg.d_model))[b:b + 1]
            y1, c1 = att.decode_attend(p, xt, t, c1, cfg)
            np.testing.assert_allclose(np.asarray(ys[step][b]),
                                       np.asarray(y1[0]),
                                       rtol=2e-5, atol=2e-5)


def test_decode_attend_ragged_inactive_slots():
    cfg = _tiny_cfg(use_flash_attention=True)
    p = att.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B = 3
    c0 = att.init_kv_cache(cfg, B, 16, jnp.float32, ragged=True)
    active = jnp.array([True, False, True])
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model))
    y, c1 = att.decode_attend_ragged(p, x, jnp.zeros((B,), jnp.int32), c0,
                                     cfg, active=active)
    assert np.all(np.asarray(c1.k[1]) == np.asarray(c0.k[1]))
    assert int(np.asarray(c1.pos[1]).max()) == -1      # still empty
    assert np.all(np.asarray(y[1]) == 0.0)             # masked attend
    assert np.any(np.asarray(c1.pos[0]) == 0)


def test_decode_cross_attend_flash_parity():
    cfg = _tiny_cfg()
    cfgf = cfg.replace(use_flash_attention=True)
    p = att.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, F, KV, hd = 2, 17, cfg.n_kv_heads, cfg.resolved_head_dim
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model))
    kv = (jax.random.normal(jax.random.PRNGKey(2), (B, F, KV, hd)),
          jax.random.normal(jax.random.PRNGKey(3), (B, F, KV, hd)))
    y0 = att.decode_cross_attend(p, x, kv, cfg)
    y1 = att.decode_cross_attend(p, x, kv, cfgf)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_flash_decode_long_window_grid():
    """Larger sweep: 1k-slot windows, every split config, both mask shapes."""
    B, W, H, KV, hd = 2, 1024, 8, 2, 64
    q, k, v = _qkv_dec(jax.random.PRNGKey(7), B, W, H, KV, hd)
    pos = jnp.arange(W, dtype=jnp.int32)
    for window in (None, 300):
        for n_splits in (1, 4, 8):
            t = jnp.array([W - 1, W // 3], jnp.int32)
            bias = fd.decode_bias(pos, t, window=window)
            out = ops.flash_decode(q, k, v, bias, blk_k=128,
                                   n_splits=n_splits, interpret=True)
            expected = ref.flash_decode_ref(q, k, v, bias)
            np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                                       rtol=2e-5, atol=2e-5)
