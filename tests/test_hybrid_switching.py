"""Hybrid-CG operator switching (paper §5): after an exact-Hessian iteration
that encounters negative curvature, the NEXT iteration uses the Gauss-Newton
operator, then switches back."""
import jax
import jax.numpy as jnp

from repro.core import HFConfig, hf_init, hf_step


def loss_fn(params, batch):
    x, y = params["x"], params["y"]
    return 0.5 * x**2 + 0.25 * y**4 - 0.5 * y**2 + 0.0 * jnp.sum(batch)


def model_out_fn(params, batch):
    return jnp.stack([params["x"], params["y"] ** 2 / 2.0])


def out_loss_fn(z, batch):
    return 0.5 * z[0] ** 2 + z[1] ** 2 - z[1] + 0.0 * jnp.sum(batch)


BATCH = jnp.zeros((1,))


def test_hybrid_gn_flag_flips_and_resets():
    cfg = HFConfig(solver="hybrid_cg", max_cg_iters=10, init_damping=1e-3)
    params = {"x": jnp.asarray(0.9), "y": jnp.asarray(0.0)}
    state = hf_init(params, cfg)
    step = jax.jit(lambda p, s: hf_step(
        loss_fn, p, s, BATCH, BATCH, cfg,
        model_out_fn=model_out_fn, out_loss_fn=out_loss_fn))
    flags = []
    ncs = []
    for _ in range(8):
        params, state, m = step(params, state)
        flags.append(bool(state.use_gn))
        ncs.append(bool(m["nc_found"]))
    # near the saddle, exact-Hessian iterations find NC -> next uses GN
    assert any(flags), "GN fallback never triggered"
    for i in range(len(flags) - 1):
        if flags[i]:  # a GN iteration NEVER schedules another GN iteration
            assert not flags[i + 1]
        if ncs[i] and not flags[i]:  # exact-H iteration w/ NC schedules GN
            assert flags[i + 1]


def test_metrics_report_gn_usage():
    cfg = HFConfig(solver="hybrid_cg", max_cg_iters=5, init_damping=1e-3)
    params = {"x": jnp.asarray(0.9), "y": jnp.asarray(0.0)}
    state = hf_init(params, cfg)
    _, state, m = hf_step(loss_fn, params, state, BATCH, BATCH, cfg,
                          model_out_fn=model_out_fn, out_loss_fn=out_loss_fn)
    assert "used_gn" in m and not bool(m["used_gn"])  # first step is exact-H
