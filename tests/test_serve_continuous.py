"""Continuous batching and model-level paged decode must reproduce the
batch-at-once dense path token-for-token: ``serve_continuous`` (slot
scheduler + paged cache + staggered arrivals + slot reuse) against
``serve``, and ``decode_step_paged``/``decode_step_ragged`` against
``decode_step`` on the same prompts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import lm_batch
from repro.models import build_model


def _tiny_model(**kw):
    cfg = get_smoke_config("qwen2-1.5b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, **kw)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _batch_at_once(model, params, prompt, S, gen, max_len):
    """(B, gen) greedy tokens via the dense prefill + scalar-t decode."""
    logits, cache = model.prefill(params, prompt, max_len)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    toks = [tok]
    for i in range(gen - 1):
        logits, cache = model.decode_step(
            params, tok, jnp.asarray(S + i, jnp.int32), cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(tok)
    return np.asarray(jnp.concatenate(toks, axis=1))


def _prompt(cfg, B, S, seed=1):
    batch = lm_batch(jax.random.PRNGKey(seed), cfg, B, S + 1)
    p = dict(batch)
    p["tokens"] = batch["tokens"][:, :S]
    return p


def test_continuous_matches_batch_at_once():
    """3 requests on 2 slots with staggered arrivals: every request's
    tokens equal its batch-at-once row (slot reuse included)."""
    from repro.launch.serve import serve, serve_continuous

    S, gen, n_req = 8, 5, 3
    ref, _ = serve("qwen2-1.5b", smoke=True, batch_size=n_req, prompt_len=S,
                   gen_len=gen, log_fn=lambda *a: None)
    got, stats = serve_continuous(
        "qwen2-1.5b", smoke=True, batch_size=2, n_requests=n_req,
        prompt_len=S, gen_len=gen, arrival_steps=[0, 0, 2],
        log_fn=lambda *a: None)
    np.testing.assert_array_equal(got, ref)
    assert stats["steps"] >= gen


@pytest.mark.parametrize("window", [None, 6])
def test_paged_decode_token_parity(window):
    """Per-slot paged admission + decode == dense batch-at-once, with and
    without a sliding window (page freeing during decode)."""
    cfg, model, params = _tiny_model(sliding_window=window)
    B, S, gen = 3, 10, 6
    max_len = S + gen
    prompt = _prompt(cfg, B, S)
    ref = _batch_at_once(model, params, prompt, S, gen, max_len)

    ps = 4
    n_pages = 1 + B * (-(-max_len // ps) + 1)
    cache = model.init_cache_paged(B, max_len, n_pages, ps)
    tok = jnp.zeros((B, 1), jnp.int32)
    for b in range(B):
        pb = {"tokens": prompt["tokens"][b:b + 1]}
        lg, cache = model.prefill_paged(params, pb, cache, jnp.asarray(b))
        tok = tok.at[b, 0].set(jnp.argmax(lg[0, -1]).astype(jnp.int32))
    toks = [tok]
    active = jnp.ones((B,), bool)
    for _ in range(gen - 1):
        lg, cache = model.decode_step_paged(params, tok, cache, active)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        toks.append(tok)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(toks, 1)), ref)


def test_ragged_decode_matches_scalar_t():
    """decode_step_ragged at uniform per-slot t == scalar-t decode_step."""
    from repro.models.attention import KVCache

    cfg, model, params = _tiny_model()
    B, S, gen = 3, 10, 5
    max_len = S + gen
    prompt = _prompt(cfg, B, S)
    ref = _batch_at_once(model, params, prompt, S, gen, max_len)

    _, dcache = model.prefill(params, prompt, max_len)
    kv = dcache["b0_attn"]
    rcache = {"b0_attn": KVCache(kv.k, kv.v, jnp.broadcast_to(
        kv.pos[:, None], (kv.pos.shape[0], B, kv.pos.shape[1])))}
    tok = jnp.asarray(ref[:, :1])
    toks = [tok]
    for i in range(gen - 1):
        t = jnp.full((B,), S + i, jnp.int32)
        lg, rcache = model.decode_step_ragged(params, tok, t, rcache,
                                              jnp.ones((B,), bool))
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        toks.append(tok)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(toks, 1)), ref)


def test_serving_paths_gated_off_unsupported_families():
    cfg = get_smoke_config("zamba2-7b")
    model = build_model(cfg)
    assert model.decode_step_paged is None
    assert model.decode_step_ragged is None
