"""Unit tests for the Krylov solvers, HVP operators, damping and line search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HFConfig, armijo, bicgstab, cg, fd_hvp, hf_init, hf_step,
    lm_update, make_damped, make_gnvp, make_hvp, sign_correct,
)
from repro.core.tree_math import tree_dot, tree_norm, tree_scale, tree_sub

jax.config.update("jax_enable_x64", False)


def _mat_op(M):
    return lambda v: {"x": M @ v["x"]}


def _vec(x):
    return {"x": jnp.asarray(x, jnp.float32)}


class TestCG:
    def test_solves_spd_system(self):
        rng = np.random.RandomState(0)
        Q = rng.randn(8, 8).astype(np.float32)
        M = Q @ Q.T + 8 * np.eye(8, dtype=np.float32)
        b = _vec(rng.randn(8))
        res = cg(_mat_op(jnp.asarray(M)), b, _vec(np.zeros(8)), lam=0.0, max_iters=50, tol=1e-6)
        np.testing.assert_allclose(np.asarray(res.x["x"]), np.linalg.solve(M, b["x"]), rtol=1e-3, atol=1e-4)
        assert not bool(res.nc_found)

    def test_detects_negative_curvature(self):
        M = jnp.diag(jnp.array([2.0, -1.0, 3.0], jnp.float32))
        b = _vec([1.0, 1.0, 1.0])
        res = cg(_mat_op(M), b, _vec(np.zeros(3)), lam=0.0, max_iters=20, tol=1e-8)
        assert bool(res.nc_found)
        d = np.asarray(res.nc_dir["x"])
        assert d @ np.diag([2.0, -1.0, 3.0]) @ d < 0

    def test_warm_start_converges_faster(self):
        rng = np.random.RandomState(1)
        Q = rng.randn(16, 16).astype(np.float32)
        M = jnp.asarray(Q @ Q.T + 16 * np.eye(16, dtype=np.float32))
        b = _vec(rng.randn(16))
        x_star = {"x": jnp.linalg.solve(M, b["x"])}
        cold = cg(_mat_op(M), b, _vec(np.zeros(16)), lam=0.0, max_iters=3, tol=1e-10)
        warm = cg(_mat_op(M), b, tree_scale(0.95, x_star), lam=0.0, max_iters=3, tol=1e-10)
        assert tree_norm(tree_sub(warm.x, x_star)) < tree_norm(tree_sub(cold.x, x_star))


class TestBiCGSTAB:
    def test_solves_spd_system(self):
        rng = np.random.RandomState(2)
        Q = rng.randn(8, 8).astype(np.float32)
        M = Q @ Q.T + 8 * np.eye(8, dtype=np.float32)
        b = _vec(rng.randn(8))
        res = bicgstab(_mat_op(jnp.asarray(M)), b, _vec(np.zeros(8)), lam=0.0, max_iters=60, tol=1e-6)
        np.testing.assert_allclose(np.asarray(res.x["x"]), np.linalg.solve(M, b["x"]), rtol=1e-3, atol=1e-4)

    def test_solves_indefinite_system(self):
        # This is the point of Alg. 3: CG cannot do this, Bi-CG-STAB can.
        M = jnp.diag(jnp.array([4.0, -2.0, 1.0, -0.5], jnp.float32))
        rng = np.random.RandomState(3)
        b = _vec(rng.randn(4))
        res = bicgstab(_mat_op(M), b, _vec(np.zeros(4)), lam=0.0, max_iters=60, tol=1e-6)
        x_star = np.asarray(b["x"]) / np.array([4.0, -2.0, 1.0, -0.5])
        np.testing.assert_allclose(np.asarray(res.x["x"]), x_star, rtol=1e-3, atol=1e-4)
        assert bool(res.nc_found)
        assert float(res.nc_curv) < 0

    def test_nonsymmetric_system(self):
        rng = np.random.RandomState(4)
        M = rng.randn(6, 6).astype(np.float32) + 6 * np.eye(6, dtype=np.float32)
        b = _vec(rng.randn(6))
        res = bicgstab(_mat_op(jnp.asarray(M)), b, _vec(np.zeros(6)), lam=0.0, max_iters=100, tol=1e-6)
        np.testing.assert_allclose(np.asarray(res.x["x"]), np.linalg.solve(M, b["x"]), rtol=1e-2, atol=1e-3)


class TestHVP:
    def _loss(self, params, batch):
        x, y = batch
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        z = h @ params["w2"]
        return jnp.mean((z - y) ** 2) + 1e-3 * tree_dot(params, params)

    def _setup(self):
        rng = np.random.RandomState(5)
        params = {
            "w1": jnp.asarray(rng.randn(4, 8) * 0.3, jnp.float32),
            "b1": jnp.zeros(8, jnp.float32),
            "w2": jnp.asarray(rng.randn(8, 2) * 0.3, jnp.float32),
        }
        batch = (jnp.asarray(rng.randn(16, 4), jnp.float32), jnp.asarray(rng.randn(16, 2), jnp.float32))
        v = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.1, params)
        return params, batch, v

    def test_exact_hvp_matches_finite_difference(self):
        params, batch, v = self._setup()
        hv = make_hvp(self._loss, params, batch)(v)
        fd = fd_hvp(self._loss, params, batch, v, eps=1e-3)
        for a, b in zip(jax.tree_util.tree_leaves(hv), jax.tree_util.tree_leaves(fd)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-2, atol=5e-3)

    def test_hvp_is_symmetric(self):
        params, batch, _ = self._setup()
        hvp = make_hvp(self._loss, params, batch)
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        u = jax.tree_util.tree_map(lambda p: jax.random.normal(k1, p.shape), params)
        w = jax.tree_util.tree_map(lambda p: jax.random.normal(k2, p.shape), params)
        np.testing.assert_allclose(float(tree_dot(u, hvp(w))), float(tree_dot(w, hvp(u))), rtol=1e-3)

    def test_gnvp_is_psd(self):
        params, batch, _ = self._setup()

        def out_fn(p, b):
            x, _ = b
            return jnp.tanh(x @ p["w1"] + p["b1"]) @ p["w2"]

        def out_loss(z, b):
            return jnp.mean((z - b[1]) ** 2)

        gn = make_gnvp(out_fn, out_loss, params, batch)
        for seed in range(5):
            v = jax.tree_util.tree_map(
                lambda p: jax.random.normal(jax.random.PRNGKey(seed), p.shape), params
            )
            assert float(tree_dot(v, gn(v))) >= -1e-6

    def test_gnvp_equals_hvp_for_linear_model(self):
        # With a linear model, GN == exact Hessian for squared loss.
        rng = np.random.RandomState(6)
        params = {"w": jnp.asarray(rng.randn(4, 3) * 0.3, jnp.float32)}
        batch = (jnp.asarray(rng.randn(8, 4), jnp.float32), jnp.asarray(rng.randn(8, 3), jnp.float32))

        def out_fn(p, b):
            return b[0] @ p["w"]

        def out_loss(z, b):
            return jnp.mean((z - b[1]) ** 2)

        def loss(p, b):
            return out_loss(out_fn(p, b), b)

        v = {"w": jnp.ones((4, 3), jnp.float32)}
        hv = make_hvp(loss, params, batch)(v)
        gv = make_gnvp(out_fn, out_loss, params, batch)(v)
        np.testing.assert_allclose(np.asarray(hv["w"]), np.asarray(gv["w"]), rtol=1e-4, atol=1e-5)


class TestLineSearchDamping:
    def test_armijo_full_step_on_quadratic(self):
        loss = lambda p: 0.5 * tree_dot(p, p)
        params = _vec([2.0, -3.0])
        g = params
        delta = tree_scale(-1.0, g)  # Newton step
        res = armijo(loss, params, loss(params), delta, tree_dot(g, delta))
        assert float(res.alpha) == 1.0 and bool(res.success)

    def test_armijo_backtracks_on_overshoot(self):
        loss = lambda p: 0.5 * tree_dot(p, p)
        params = _vec([1.0])
        delta = _vec([-10.0])  # way too far
        res = armijo(loss, params, loss(params), delta, tree_dot(params, delta))
        assert float(res.alpha) < 1.0 and bool(res.success)

    def test_lm_update_directions(self):
        lam = jnp.asarray(1.0)
        # good model fit -> decrease lambda
        lam_good, rho = lm_update(lam, 1.0, 0.0, -1.0)
        assert float(lam_good) < 1.0 and float(rho) == pytest.approx(1.0)
        # poor fit -> increase
        lam_bad, _ = lm_update(lam, 1.0, 0.99, -1.0)
        assert float(lam_bad) > 1.0
        # ascent -> increase hard
        lam_up, _ = lm_update(lam, 1.0, 1.5, -1.0)
        assert float(lam_up) > float(lam_bad)

    def test_sign_correct(self):
        g = _vec([1.0, 0.0])
        d = _vec([1.0, 1.0])  # ascent direction
        d2, _ = sign_correct(g, d)
        assert float(tree_dot(g, d2)) <= 0
