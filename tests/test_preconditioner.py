"""Jacobi-preconditioned Krylov solvers + Hutchinson diagonal estimation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, hf_init, hf_step
from repro.core.solvers import bicgstab, cg, hutchinson_diag, pcg
from repro.core.tree_math import tree_norm, tree_sub
from repro.data import classification_dataset
from repro.models import build_mlp


def _vec(x):
    return {"x": jnp.asarray(x, jnp.float32)}


def _mat_op(M):
    return lambda v: {"x": M @ v["x"]}


def test_pcg_beats_cg_on_ill_conditioned_diagonal():
    d = np.logspace(0, 4, 32).astype(np.float32)    # condition number 1e4
    M = jnp.diag(jnp.asarray(d))
    rng = np.random.RandomState(0)
    b = _vec(rng.randn(32))
    x_star = {"x": b["x"] / d}
    m_inv = {"x": 1.0 / jnp.asarray(d)}             # exact Jacobi
    plain = cg(_mat_op(M), b, _vec(np.zeros(32)), lam=0.0, max_iters=6, tol=1e-12)
    pre = pcg(_mat_op(M), b, _vec(np.zeros(32)), lam=0.0, M_inv=m_inv,
              max_iters=6, tol=1e-12)
    err_plain = float(tree_norm(tree_sub(plain.x, x_star)))
    err_pre = float(tree_norm(tree_sub(pre.x, x_star)))
    assert err_pre < err_plain * 1e-2   # exact Jacobi solves diagonal in 1 it


def test_pcg_identity_preconditioner_equals_cg():
    """With M⁻¹ = I, pcg IS cg — identical iterates at every budget (the
    engine body is shared; the identity multiply is exact in fp)."""
    rng = np.random.RandomState(5)
    Q = rng.randn(12, 12).astype(np.float32)
    M = jnp.asarray(Q @ Q.T + 12 * np.eye(12, dtype=np.float32))
    b = _vec(rng.randn(12))
    ident = {"x": jnp.ones(12, jnp.float32)}
    for iters in (1, 3, 7, 20):
        plain = cg(_mat_op(M), b, _vec(np.zeros(12)), lam=0.0,
                   max_iters=iters, tol=1e-10)
        pre = pcg(_mat_op(M), b, _vec(np.zeros(12)), lam=0.0, M_inv=ident,
                  max_iters=iters, tol=1e-10)
        assert int(plain.iters) == int(pre.iters)
        np.testing.assert_array_equal(np.asarray(plain.x["x"]), np.asarray(pre.x["x"]))
        np.testing.assert_array_equal(np.asarray(plain.r["x"]), np.asarray(pre.r["x"]))


def test_bicgstab_identity_preconditioner_is_plain_bicgstab():
    """M_inv=None and M⁻¹=I take the same recurrence — bit-equal iterates."""
    rng = np.random.RandomState(6)
    M = jnp.diag(jnp.asarray(np.linspace(0.5, 8.0, 12), jnp.float32))
    b = _vec(rng.randn(12))
    ident = {"x": jnp.ones(12, jnp.float32)}
    plain = bicgstab(_mat_op(M), b, _vec(np.zeros(12)), lam=0.0,
                     max_iters=9, tol=1e-10)
    pre = bicgstab(_mat_op(M), b, _vec(np.zeros(12)), lam=0.0,
                   max_iters=9, tol=1e-10, M_inv=ident)
    assert int(plain.iters) == int(pre.iters)
    np.testing.assert_array_equal(np.asarray(plain.x["x"]), np.asarray(pre.x["x"]))


def test_preconditioned_bicgstab_beats_plain_on_ill_conditioned():
    """Exact Jacobi on a diagonal system: right-preconditioned Bi-CG-STAB
    solves in one iteration where the plain solver is nowhere close."""
    d = np.logspace(0, 4, 32).astype(np.float32)
    M = jnp.diag(jnp.asarray(d))
    rng = np.random.RandomState(7)
    b = _vec(rng.randn(32))
    x_star = {"x": b["x"] / d}
    m_inv = {"x": 1.0 / jnp.asarray(d)}
    plain = bicgstab(_mat_op(M), b, _vec(np.zeros(32)), lam=0.0,
                     max_iters=4, tol=1e-12)
    pre = bicgstab(_mat_op(M), b, _vec(np.zeros(32)), lam=0.0,
                   max_iters=4, tol=1e-12, M_inv=m_inv)
    err_plain = float(tree_norm(tree_sub(plain.x, x_star)))
    err_pre = float(tree_norm(tree_sub(pre.x, x_star)))
    assert err_pre < err_plain * 1e-2


def test_hutchinson_diag_estimates_diagonal():
    d = jnp.asarray(np.linspace(1.0, 10.0, 64), jnp.float32)
    op = _mat_op(jnp.diag(d))
    est = hutchinson_diag(op, _vec(np.zeros(64)), step=jnp.asarray(3), samples=1)
    # for a diagonal matrix one Rademacher sample is EXACT: v ⊙ Dv = D v² = D
    np.testing.assert_allclose(np.asarray(est["x"]), np.asarray(d), rtol=1e-5)


@pytest.mark.parametrize("solver", ["hessian_cg", "bicgstab"])
def test_hf_with_preconditioning_trains(solver):
    """precondition=True must actually engage for every solver — for
    bicgstab it was silently ignored before the unified engine (the branch
    order in hf_step dispatched to the unpreconditioned path)."""
    model = build_mlp((16, 32, 4))
    data = classification_dataset(jax.random.PRNGKey(0), 256, 16, 4)
    cfg = HFConfig(solver=solver, max_cg_iters=6, precondition=True)
    params = model.init(jax.random.PRNGKey(1))
    state = hf_init(params, cfg)
    step = jax.jit(lambda p, s: hf_step(model.loss_fn, p, s, data, data, cfg))
    losses = []
    for _ in range(8):
        params, state, m = step(params, state)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.6 * losses[0]


def test_bicgstab_precondition_is_not_a_noop():
    """hf_step(precondition=True, solver=bicgstab) must produce a different
    (preconditioned) step than precondition=False on an ill-conditioned
    problem — guards against the silent-ignore regression."""
    model = build_mlp((16, 32, 4))
    data = classification_dataset(jax.random.PRNGKey(0), 256, 16, 4)
    params = model.init(jax.random.PRNGKey(1))
    deltas = {}
    for pre in (False, True):
        cfg = HFConfig(solver="bicgstab", max_cg_iters=6, precondition=pre,
                       krylov_jitter=0.0)
        state = hf_init(params, cfg)
        p2, _, _ = jax.jit(lambda p, s, cfg=cfg: hf_step(
            model.loss_fn, p, s, data, data, cfg))(params, state)
        deltas[pre] = p2
    diff = float(tree_norm(tree_sub(deltas[True], deltas[False])))
    assert diff > 1e-6, "preconditioning silently ignored for bicgstab"
