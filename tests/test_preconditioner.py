"""Jacobi-preconditioned CG + Hutchinson diagonal estimation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HFConfig, hf_init, hf_step
from repro.core.solvers import cg, hutchinson_diag, pcg
from repro.core.tree_math import tree_norm, tree_sub
from repro.data import classification_dataset
from repro.models import build_mlp


def _vec(x):
    return {"x": jnp.asarray(x, jnp.float32)}


def _mat_op(M):
    return lambda v: {"x": M @ v["x"]}


def test_pcg_beats_cg_on_ill_conditioned_diagonal():
    d = np.logspace(0, 4, 32).astype(np.float32)    # condition number 1e4
    M = jnp.diag(jnp.asarray(d))
    rng = np.random.RandomState(0)
    b = _vec(rng.randn(32))
    x_star = {"x": b["x"] / d}
    m_inv = {"x": 1.0 / jnp.asarray(d)}             # exact Jacobi
    plain = cg(_mat_op(M), b, _vec(np.zeros(32)), lam=0.0, max_iters=6, tol=1e-12)
    pre = pcg(_mat_op(M), b, _vec(np.zeros(32)), lam=0.0, M_inv=m_inv,
              max_iters=6, tol=1e-12)
    err_plain = float(tree_norm(tree_sub(plain.x, x_star)))
    err_pre = float(tree_norm(tree_sub(pre.x, x_star)))
    assert err_pre < err_plain * 1e-2   # exact Jacobi solves diagonal in 1 it


def test_hutchinson_diag_estimates_diagonal():
    d = jnp.asarray(np.linspace(1.0, 10.0, 64), jnp.float32)
    op = _mat_op(jnp.diag(d))
    est = hutchinson_diag(op, _vec(np.zeros(64)), step=jnp.asarray(3), samples=1)
    # for a diagonal matrix one Rademacher sample is EXACT: v ⊙ Dv = D v² = D
    np.testing.assert_allclose(np.asarray(est["x"]), np.asarray(d), rtol=1e-5)


def test_hf_with_preconditioning_trains():
    model = build_mlp((16, 32, 4))
    data = classification_dataset(jax.random.PRNGKey(0), 256, 16, 4)
    cfg = HFConfig(solver="hessian_cg", max_cg_iters=6, precondition=True)
    params = model.init(jax.random.PRNGKey(1))
    state = hf_init(params, cfg)
    step = jax.jit(lambda p, s: hf_step(model.loss_fn, p, s, data, data, cfg))
    losses = []
    for _ in range(8):
        params, state, m = step(params, state)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.6 * losses[0]
