"""Roofline analysis unit tests: HLO collective parser + term arithmetic."""
import numpy as np

from repro.roofline import (
    HW,
    collective_bytes_from_hlo,
    cost_summary,
    model_flops,
    roofline_terms,
)
from repro.configs import INPUT_SHAPES, get_config
from repro.roofline.analysis import active_param_count

HLO = """
HloModule jit_step
  %x1 = bf16[128,256]{1,0} all-reduce(bf16[128,256]{1,0} %a), replica_groups=...
  %x2 = f32[64]{0} all-gather(f32[4]{0} %b), dimensions={0}
  %x3 = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce-start(%c, %d)
  %x4 = f32[8,8]{1,0} all-reduce-done(%x3)
  %x5 = bf16[2,4]{1,0} collective-permute(bf16[2,4]{1,0} %e)
  %x6 = f32[16]{0} reduce-scatter(f32[64]{0} %f), dimensions={0}
  %nope = f32[10]{0} add(f32[10]{0} %g, f32[10]{0} %h)
"""


def test_collective_parser():
    c = collective_bytes_from_hlo(HLO)
    assert c["all-reduce"] == 128 * 256 * 2 + 2 * 8 * 8 * 4  # x1 + x3 tuple
    assert c["all-gather"] == 64 * 4
    assert c["collective-permute"] == 2 * 4 * 2
    assert c["reduce-scatter"] == 16 * 4
    assert c["counts"]["all-reduce"] == 2          # start counted, done not
    assert c["total"] == sum(c[k] for k in
                             ("all-reduce", "all-gather", "reduce-scatter",
                              "all-to-all", "collective-permute"))
    assert len(c["top_ops"]) >= 4


def test_roofline_terms_bottleneck():
    t = roofline_terms(197e12, 0.0, 0.0, 256)   # exactly 1s of compute
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert t["bottleneck"] == "compute_s"
    t = roofline_terms(0.0, 819e9, 50e9 * 2, 256)
    assert t["bottleneck"] == "collective_s"


def test_cost_summary_handles_list_and_dict():
    assert cost_summary([{"flops": 5.0, "bytes accessed": 7.0}])["flops"] == 5.0
    assert cost_summary({"flops": 5.0})["bytes_accessed"] == 0.0
    assert cost_summary(None) == {}


def test_moe_active_params_less_than_total():
    cfg = get_config("mixtral-8x22b")
    assert active_param_count(cfg) < 0.4 * cfg.param_count()   # 2 of 8 experts
    dense = get_config("qwen2-1.5b")
    assert active_param_count(dense) == dense.param_count()


def test_model_flops_shapes():
    cfg = get_config("qwen2-1.5b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr > de * 1000   # train touches ~8k x more tokens at 3x flops
