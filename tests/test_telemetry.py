"""Telemetry subsystem (repro.obs): sink, zero-cost-off, phase/collective
events, Krylov introspection, trace merging, report CLI, and the headline
measurement — the overlapped schedule's grad-reduce span visibly
overlapping the curvature primal build, while the blocking schedule's does
not.

Fast tests run single-process (XLA:CPU runs debug callbacks synchronously
in the compute thread, so the executor's schedule is visible without a
real interconnect). The 2-process CLI test is slow-marked like the other
multiproc spawns.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, hf_init
from repro.core.collectives import count_executed, jaxpr_collective_counts
from repro.core.distributed import data_parallel_hf_step
from repro.core.hf import METRICS_SCHEMA
from repro.core.solvers import cg
from repro.data import classification_dataset
from repro.models import build_mlp
from repro.obs import report, telemetry, trace

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------- sink --
def test_sink_roundtrip(tmp_path):
    d = str(tmp_path)
    with telemetry.Telemetry(d, process_index=3, meta={"kind": "t"}) as s:
        with s.span("outer", step=1):
            s.instant("hello", x=2)
        s.counter("depth", 4)
        s.collective_begin("g", "g")
        s.collective_begin("g", "g")   # FIFO: two in flight, same key
        s.collective_end("g", "g")
        s.collective_end("g", "g")
        s.solve_event(0, iters=3, residual=0.5)
    evs = trace.load_events(d)
    assert all(e["pid"] == 3 for e in evs)
    kinds = [e["ev"] for e in evs]
    assert kinds[0] == "meta" and evs[0]["kind"] == "t"
    colls = [e for e in evs if e["ev"] == "coll"]
    assert len(colls) == 2
    assert all(c["t1"] >= c["t0"] for c in colls)
    # FIFO pairing: first end takes the first begin
    assert colls[0]["t0"] <= colls[1]["t0"]
    span = next(e for e in evs if e["ev"] == "span")
    assert span["t1"] >= span["t0"] and span["step"] == 1


# ---------------------------------------------- instrumented step fixture --
@pytest.fixture(scope="module")
def setup():
    model = build_mlp((16, 32, 4))
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(jax.random.PRNGKey(0), 16, 16, 4)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return model, params, data, mesh


@pytest.fixture(scope="module")
def instrumented_run(setup, tmp_path_factory):
    """One jitted s-step data-parallel HF step with sink + executed-count
    instrumentation armed; shared by the event-content tests below."""
    model, params, data, mesh = setup
    cfg = HFConfig(solver="hessian_cg", max_cg_iters=6, cg_tol=0.0,
                   sstep_s=2)
    d = str(tmp_path_factory.mktemp("telemetry"))
    sink = telemetry.Telemetry(d)
    with telemetry.install(sink), count_executed() as counts:
        step = data_parallel_hf_step(model.loss_fn, mesh, cfg)
        p, s, m = jax.jit(step)(params, hf_init(params, cfg), data)
        jax.block_until_ready(p)
    sink.close()
    executed = counts.per_device(len(jax.local_devices()))
    return d, trace.load_events(d), executed, jax.device_get(m)


# ------------------------------------------------------- zero-cost off --
def test_zero_cost_when_disabled(setup, tmp_path):
    """No sink installed → the jaxpr carries no callbacks and the static
    collective fingerprint is byte-identical to the audited one; installed
    → callbacks appear WITHOUT changing the collective schedule."""
    model, params, data, mesh = setup
    cfg = HFConfig(solver="hessian_cg", max_cg_iters=8, cg_tol=0.0)

    step_off = data_parallel_hf_step(model.loss_fn, mesh, cfg)
    jx_off = jax.make_jaxpr(step_off)(params, hf_init(params, cfg), data)
    assert "callback" not in str(jx_off)
    c_off = jaxpr_collective_counts(jx_off.jaxpr)
    # hessian_cg_s1 fingerprint from tests/test_collective_audit.py COMBOS
    assert (c_off["top"]["psum2"], c_off["while_body"]["psum2"]) == (5, 3)

    with telemetry.Telemetry(str(tmp_path)) as sink:
        with telemetry.install(sink):
            step_on = data_parallel_hf_step(model.loss_fn, mesh, cfg)
            jx_on = jax.make_jaxpr(step_on)(params, hf_init(params, cfg),
                                            data)
    assert "callback" in str(jx_on)
    c_on = jaxpr_collective_counts(jx_on.jaxpr)
    assert (c_on["top"]["psum2"], c_on["while_body"]["psum2"]) == (5, 3)


# ------------------------------------------------------ event content --
def test_collective_events_match_executed_counts(instrumented_run):
    """Per tag, the telemetry begin/end span pairs count exactly the
    collectives the independent executed-count callback tallies."""
    _, events, executed, _ = instrumented_run
    colls = trace.collective_spans(events)
    by_tag = {}
    for c in colls:
        by_tag[c["tag"]] = by_tag.get(c["tag"], 0) + 1
        assert c["t1"] >= c["t0"]
    assert by_tag == {t: int(n) for t, n in executed.items() if n}


def test_phase_markers_present_and_ordered(instrumented_run):
    _, events, _, _ = instrumented_run
    spans = trace.phase_spans(events)
    names = [s["name"] for s in spans if s["step"] == 0]
    # shared-primal path: no separate grad_build phase
    assert names == ["curvature_primal", "krylov_solve", "line_search",
                     "update_damping"]
    ts = [s["t1"] for s in spans if s["step"] == 0]
    assert ts == sorted(ts)
    assert all(s["t1"] >= s["t0"] for s in spans)


def test_solve_event_matches_metrics(instrumented_run):
    _, events, _, m = instrumented_run
    (sol,) = [e for e in events if e["ev"] == "solve"]
    assert sol["step"] == 0
    assert sol["iters"] == int(m["cg_iters"])
    assert sol["syncs"] == int(m["krylov_syncs"])
    assert sol["residual"] == pytest.approx(float(m["cg_residual"]),
                                            rel=1e-5)
    hist = sol["residual_history"]
    assert len(hist) == sol["iters"]           # NaN tail filtered
    assert all(np.isfinite(hist))
    assert hist[-1] == pytest.approx(float(m["cg_residual"]), rel=1e-5)


def test_metrics_contract(instrumented_run):
    """Every hf_step metric: enumerated in METRICS_SCHEMA, scalar, finite."""
    _, _, _, m = instrumented_run
    assert set(m) == set(METRICS_SCHEMA)
    for k, v in m.items():
        arr = np.asarray(v)
        assert arr.shape == (), (k, arr.shape)
        assert np.isfinite(arr.astype(np.float64)), (k, v)


# ------------------------------------------- solver residual history --
def test_residual_history_solver_level():
    """cg's residual_history: ‖r‖ per executed iteration, NaN beyond."""
    n = 12
    diag = jnp.linspace(1.0, 4.0, n)
    A = lambda v: diag * v  # noqa: E731
    b = jnp.ones((n,))
    res = cg(A, b, jnp.zeros((n,)), lam=0.0, max_iters=20, tol=1e-6)
    it = int(res.iters)
    hist = np.asarray(res.residual_history)
    assert hist.shape == (20,)
    assert np.all(np.isfinite(hist[:it]))
    assert np.all(np.isnan(hist[it:]))
    assert hist[it - 1] == pytest.approx(float(res.residual), rel=1e-5)
    # monotone-ish convergence on an SPD diagonal: last < first
    assert hist[it - 1] < hist[0]


# ------------------------------------------------- trace.json merging --
def _synthetic_events():
    return [
        {"ev": "meta", "pid": 0, "process": 0, "ts": 100.0},
        {"ev": "phase", "pid": 0, "name": "step_begin", "step": 0,
         "ts": 100.0},
        {"ev": "phase", "pid": 0, "name": "grad_build", "step": 0,
         "ts": 100.1},
        {"ev": "phase", "pid": 0, "name": "curvature_primal", "step": 0,
         "ts": 100.4},
        {"ev": "coll", "pid": 0, "tag": "grad_hvp", "label": "grad_reduce",
         "t0": 100.15, "t1": 100.35},
        {"ev": "coll", "pid": 1, "tag": "grad_hvp", "label": "grad_reduce",
         "t0": 100.0, "t1": 100.05},
        {"ev": "phase", "pid": 1, "name": "step_begin", "step": 0,
         "ts": 99.9},
        {"ev": "phase", "pid": 1, "name": "grad_reduce", "step": 0,
         "ts": 100.05},
        {"ev": "phase", "pid": 1, "name": "curvature_primal", "step": 0,
         "ts": 100.3},
        {"ev": "counter", "pid": 0, "name": "loss", "value": 2.0,
         "ts": 100.4},
        {"ev": "span", "pid": 0, "name": "host_step", "t0": 100.0,
         "t1": 100.5, "step": 0},
    ]


def test_overlap_math_on_synthetic_events():
    evs = _synthetic_events()
    assert trace.overlap_seconds(dict(t0=0.0, t1=2.0),
                                 dict(t0=1.0, t1=3.0)) == 1.0
    assert trace.overlap_seconds(dict(t0=0.0, t1=1.0),
                                 dict(t0=2.0, t1=3.0)) == 0.0
    rows = trace.grad_reduce_overlap(evs)
    by_pid = {r["pid"]: r for r in rows}
    # pid 0: coll [.15,.35] vs curvature_primal [.1,.4] → 0.2s overlap
    assert by_pid[0]["overlap_s"] == pytest.approx(0.2, abs=1e-9)
    # pid 1 (blocking): coll closed at the phase's left edge → zero
    assert by_pid[1]["overlap_s"] == pytest.approx(0.0, abs=1e-9)


def test_build_trace_structure(tmp_path):
    d = str(tmp_path)
    with open(os.path.join(d, "events-p0.jsonl"), "w") as f:
        for e in _synthetic_events():
            if e.get("pid") == 0:
                f.write(json.dumps({k: v for k, v in e.items()
                                    if k != "pid"}) + "\n")
    out = trace.merge_dir(d)
    assert os.path.basename(out) == "trace.json"
    doc = json.load(open(out))
    evs = doc["traceEvents"]
    phases = [e for e in evs if e.get("ph") == "X"]
    assert phases, evs
    assert all(e["ts"] >= 0 and e["dur"] >= 1 for e in phases)
    assert {e["ph"] for e in evs} >= {"X", "M", "C"}
    names = {e["name"] for e in phases}
    assert {"grad_build", "curvature_primal", "grad_reduce",
            "host_step"} <= names


# -------------------------------------------------------- report CLI --
def test_report_renders_real_run(instrumented_run, capsys):
    d, _, _, _ = instrumented_run
    summary = report.render(d)
    out = capsys.readouterr().out
    assert summary["n_phases"] > 0
    assert summary["n_collectives"] > 0
    assert summary["n_solves"] == 1
    for section in ("phase breakdown", "collective timeline",
                    "solve convergence"):
        assert section in out, out
    assert report.main([d, "--check"]) == 0


def test_report_check_fails_on_empty(tmp_path, capsys):
    d = str(tmp_path)
    with telemetry.Telemetry(d):
        pass                                   # meta only, no phases
    assert report.main([d, "--check"]) == 1


# ---------------------------------- the schedule measurement (headline) --
def _overlap_run(overlap: bool, out_dir: str):
    """One non-shared-primal HF step (hvp_frac<1 ⇒ the gradient reduce is a
    standalone collective) big enough that the curvature primal build is
    long against callback granularity. Returns the loaded events."""
    model = build_mlp((64, 256, 256, 10))
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(jax.random.PRNGKey(0), 256, 64, 10)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    cfg = HFConfig(solver="hessian_cg", max_cg_iters=4, cg_tol=0.0,
                   overlap=overlap)
    sink = telemetry.Telemetry(out_dir)
    with telemetry.install(sink):
        step = data_parallel_hf_step(model.loss_fn, mesh, cfg,
                                     hvp_frac=0.5)
        p, s, m = jax.jit(step)(params, hf_init(params, cfg), data)
        jax.block_until_ready(p)
    sink.close()
    return trace.load_events(out_dir)


def _primal_and_reduce(events):
    (primal,) = [s for s in trace.phase_spans(events)
                 if s["name"] == "curvature_primal"]
    (red,) = [c for c in trace.collective_spans(events)
              if c["label"] == "grad_reduce"]
    return primal, red


def test_hidden_reduce_schedule_single_process(tmp_path):
    """Single-process edition of the schedule measurement (a 1-device psum
    is ~free, so the honest single-process observable is the *ordering*,
    not the duration): blocking mode pins the grad-reduce before the
    curvature primal build — its span closes before the build starts and
    an explicit grad_reduce phase appears; overlap mode removes that
    ordering — the reduce executes at/after the build's start and the
    grad_reduce phase is gone. The duration-overlap assertion (reduce span
    bracketing the primal at ~full width) lives in the 2-process test
    below, where gloo gives the collective real latency."""
    evs_ov = _overlap_run(True, str(tmp_path / "ov"))
    evs_bl = _overlap_run(False, str(tmp_path / "bl"))

    p_bl, r_bl = _primal_and_reduce(evs_bl)
    assert any(s["name"] == "grad_reduce" for s in trace.phase_spans(evs_bl))
    assert r_bl["t1"] <= p_bl["t0"], (r_bl, p_bl)
    rows_bl = trace.grad_reduce_overlap(evs_bl)
    assert rows_bl and all(r["overlap_s"] == 0 for r in rows_bl), rows_bl

    p_ov, r_ov = _primal_and_reduce(evs_ov)
    assert not any(s["name"] == "grad_reduce"
                   for s in trace.phase_spans(evs_ov))
    assert r_ov["t0"] >= p_ov["t0"], (r_ov, p_ov)


@pytest.mark.slow  # 2× (2-process spawn + jit train loop): ~2 min
def test_two_process_trace_shows_overlap(tmp_path):
    """`train --num-processes 2 --telemetry-dir D`: the primary merges one
    trace.json whose per-process grad-reduce spans overlap the curvature
    primal under --overlap and do not without it."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)

    def run(overlap: bool, d: str):
        cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
               "qwen1.5-0.5b", "--smoke", "--num-processes", "2",
               "--steps", "2", "--batch-size", "8", "--seq-len", "16",
               "--max-cg-iters", "4", "--sstep", "2",
               "--telemetry-dir", d]
        if overlap:
            cmd.append("--overlap")
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=ROOT, timeout=600)
        assert r.returncode == 0, r.stdout + r.stderr
        assert os.path.exists(os.path.join(d, "trace.json"))
        evs = trace.load_events(d)
        assert {e["pid"] for e in evs} == {0, 1}
        return trace.grad_reduce_overlap(evs)

    rows_ov = run(True, str(tmp_path / "ov"))
    rows_bl = run(False, str(tmp_path / "bl"))
    for pid in (0, 1):
        ov = [r for r in rows_ov if r["pid"] == pid]
        bl = [r for r in rows_bl if r["pid"] == pid]
        assert ov and bl, (rows_ov, rows_bl)
        # steady-state steps (step 0 includes warm caches); require the
        # hidden reduce to overlap the primal on every step for overlap
        # mode and on none for blocking mode
        assert all(r["overlap_s"] > 0 for r in ov), rows_ov
        assert all(r["overlap_s"] == 0 for r in bl), rows_bl
