"""Multi-process harness tests (launch/multiproc.py).

The fast tests cover the launcher mechanics in-process. The slow tests
spawn REAL coordinated processes (jax.distributed + gloo CPU collectives,
one device each) and assert the tentpole claims end to end:

  * a 2-process ``data_parallel_hf_step`` run produces the same losses and
    the same executed collective counts as the 1-process run of the
    identical program (the schedule is process-count invariant),
  * the executed blocking-sync count matches the §3 comm-model formula,
  * the ``train.py --num-processes`` CLI re-entry path (parent re-spawns
    its own argv, children initialize from env) completes a smoke run.

benchmarks/fig5_scaling.py --executed runs the same harness over the full
{cg, bicgstab} × {s=1, s>1 newton} × overlap grid as the CI bench check;
these tests keep the harness itself under the weekly slow grid.
"""
import os
import subprocess
import sys

import pytest

from repro.launch import multiproc

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_not_active_outside_spawn(monkeypatch):
    monkeypatch.delenv(multiproc.ENV_NUM, raising=False)
    assert not multiproc.active()
    # initialize_from_env must be a no-op here (calling jax.distributed
    # without a coordinator would hang).
    multiproc.initialize_from_env()


def test_free_port_is_bindable():
    import socket

    port = multiproc._free_port()
    assert 0 < port < 65536
    with socket.socket() as s:
        s.bind(("127.0.0.1", port))


def test_spawn_sets_env_and_pins_one_device():
    """Children see the coordination env vars and exactly one XLA device."""
    code = ("import os; assert os.environ['" + multiproc.ENV_NUM + "']=='2'; "
            "assert '--xla_force_host_platform_device_count=1' in "
            "os.environ['XLA_FLAGS']")
    multiproc.spawn(2, "timeit", ["-n", "1", "-r", "1", "-s", code, "pass"])


def test_spawn_raises_on_child_failure():
    with pytest.raises(RuntimeError, match="exit codes"):
        multiproc.spawn(2, "timeit", ["-s", "raise SystemExit(3)", "pass"])


def test_shard_and_replicate_placement():
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    batch = {"x": np.arange(8.0, dtype=np.float32).reshape(4, 2)}
    sharded = multiproc.shard_batch(batch, mesh)
    assert sharded["x"].sharding.spec == P("data")
    np.testing.assert_array_equal(np.asarray(sharded["x"]), batch["x"])
    rep = multiproc.replicate({"w": np.ones((3,), np.float32)}, mesh)
    assert rep["w"].sharding.spec == P()


@pytest.mark.slow  # 4 process spawns with full HF jit each: ~1 min
def test_two_process_parity_and_executed_syncs():
    """The tentpole: same combo, 1 vs 2 real processes — loss parity,
    identical executed collectives, blocking syncs == comm model."""
    from benchmarks.comm_model import hf_sstep_syncs_per_iteration
    from benchmarks.fig5_scaling import _spawn_combo

    for combo, s, overlap in (("cg_s2", 2, False),
                              ("cg_s2_overlap", 2, True)):
        r1 = _spawn_combo(combo, 1, steps=1)
        r2 = _spawn_combo(combo, 2, steps=1)
        assert r1["n_processes"] == 1 and r2["n_processes"] == 2
        assert abs(r1["final_loss"] - r2["final_loss"]) <= 1e-4 * max(
            1.0, abs(r1["final_loss"])), (combo, r1, r2)
        assert r1["executed"] == r2["executed"], (combo, r1, r2)
        for st in r2["steps"]:
            assert int(st["blocking_syncs"]) == hf_sstep_syncs_per_iteration(
                int(st["cg_iters"]), int(st["ls_evals"]), s,
                overlap=overlap), (combo, st)


@pytest.mark.slow  # spawn + 2-step training loop under jit: ~1 min
def test_train_cli_num_processes_smoke():
    """`train --num-processes 2` re-spawns itself and completes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen1.5-0.5b",
         "--smoke", "--num-processes", "2", "--steps", "2",
         "--batch-size", "8", "--seq-len", "16", "--max-cg-iters", "4",
         "--sstep", "2", "--overlap"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600,
    )
    assert r.returncode == 0, r.stdout + r.stderr
