"""Newton/Chebyshev s-step basis layer tests (ISSUE 5).

Covers the four layers the basis subsystem adds:
  * free Ritz estimation (``core.krylov.ritz_from_segment``): extracted
    estimates vs ``numpy.linalg.eigvalsh`` on small SPD and indefinite
    operators, from both monomial and Chebyshev (traced-coefficient)
    chains;
  * deterministic Leja ordering (``core.krylov.leja_order``);
  * the adaptive solvers themselves: monomial breaks at the doubled depth
    (CG s=8 / Bi-CG-STAB s=4) where Newton/Chebyshev run guard-quiet, on
    both vector backends;
  * the fallback chain adaptive → monomial → standard under degenerate
    spectra / unusable bases, and the config threading
    (HFConfig.sstep_basis → hf_step metrics → HFOptConfig).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, hf_init, hf_step
from repro.core.krylov import get_backend, leja_order, ritz_from_segment
from repro.core.solvers import cg
from repro.core.sstep import (
    BASES,
    BasisSpec,
    _segment_T,
    _segment_shift,
    resolve_basis,
    sstep_bicgstab,
    sstep_cg,
)
from repro.data import classification_dataset
from repro.models import build_mlp


def _vec(x):
    """Two-leaf pytree (vector + matrix leaf) to exercise ravel/unravel."""
    x = np.asarray(x, np.float32)
    return {"a": jnp.asarray(x[:5]), "b": jnp.asarray(x[5:]).reshape(-1, 1)}


def _unvec(t):
    return np.concatenate([np.asarray(t["a"]).ravel(), np.asarray(t["b"]).ravel()])


def _mat_op(M):
    def op(v):
        f = jnp.concatenate([v["a"].ravel(), v["b"].ravel()])
        out = M @ f
        return {"a": out[:5], "b": out[5:].reshape(-1, 1)}
    return op


def _clustered_spd(n=30, seed=2):
    """Damped-curvature-like spectrum: a cluster near 1 plus a spread tail
    (κ = 100) — deep monomial chains break here, adaptive bases do not."""
    rng = np.random.RandomState(seed)
    U, _ = np.linalg.qr(rng.randn(n, n))
    d = np.concatenate([1.0 + 0.1 * np.arange(20),
                        np.linspace(5, 100, n - 20)]).astype(np.float32)
    M = (U * d) @ U.T
    return (jnp.asarray(M.astype(np.float32)), d,
            _vec(rng.randn(n)), _vec(np.zeros(n)))


def _rel_res(M, x, b):
    return (np.linalg.norm(np.asarray(M) @ _unvec(x) - _unvec(b))
            / np.linalg.norm(_unvec(b)))


class TestRitzEstimation:
    """ritz_from_segment vs numpy.linalg.eigvalsh — the estimates are free
    (Gram + recurrence block only, no extra operator products)."""

    def _eig_setup(self, ev, seed=7):
        n = len(ev)
        rng = np.random.RandomState(seed)
        U, _ = np.linalg.qr(rng.randn(n, n))
        A = (U * np.asarray(ev)) @ U.T
        return A, rng.randn(n)

    @pytest.mark.parametrize("ev", [
        [1.0, 2.0, 3.0, 4.0, 5.0],          # SPD
        [-2.0, -0.5, 1.0, 3.0, 6.0],        # indefinite
    ])
    def test_chebyshev_chain_full_dim_matches_eigvalsh(self, ev):
        """A full-dimension chain in a conditioned (Chebyshev) basis makes
        the Ritz values the exact spectrum; the extraction consumes the
        traced recurrence block (_segment_T)."""
        A, v0 = self._eig_setup(ev)
        n = len(ev)
        lo, hi = min(ev), max(ev)
        c, h = 0.5 * (lo + hi), 0.6 * (hi - lo)
        alpha = np.full(n, c, np.float32)
        beta = np.r_[0.0, np.full(n - 1, h / 2)].astype(np.float32)
        gamma = np.r_[h, np.full(n - 1, h / 2)].astype(np.float32)
        ch = [v0]
        for j in range(n):
            w = A @ ch[-1]
            vp = ch[-2] if j > 0 else ch[-1]
            ch.append((w - alpha[j] * ch[-1] - beta[j] * vp) / gamma[j])
        V = np.stack(ch).astype(np.float32)
        Tp = _segment_T(
            (jnp.asarray(alpha), jnp.asarray(beta), jnp.asarray(gamma)),
            n + 1)
        ritz, ok = ritz_from_segment(jnp.asarray(V @ V.T), Tp)
        assert bool(ok)
        truth = np.linalg.eigvalsh(A)
        np.testing.assert_allclose(np.asarray(ritz), truth, rtol=0.02,
                                   atol=0.02 * np.abs(truth).max())

    def test_monomial_chain_extremes(self):
        """A short monomial chain's extreme Ritz values approximate the
        spectral edges (the quantities the Newton shifts / Chebyshev
        interval actually need); interior values are conditioning-limited
        in f32 and not asserted."""
        ev = np.array([-2.0, -0.5, 1.0, 3.0, 6.0])
        A, v0 = self._eig_setup(ev)
        n = len(ev)
        chain = [v0]
        for _ in range(n):
            chain.append(A @ chain[-1])
        V = np.stack(chain).astype(np.float32)
        ritz, ok = ritz_from_segment(jnp.asarray(V @ V.T),
                                     _segment_shift(n + 1))
        assert bool(ok)
        r = np.asarray(ritz)
        assert abs(r.max() - ev.max()) < 0.05 * abs(ev.max())
        assert abs(r.min() - ev.min()) < 0.15 * (ev.max() - ev.min())

    def test_nonfinite_gram_flagged(self):
        G = jnp.full((4, 4), jnp.inf, jnp.float32)
        _, ok = ritz_from_segment(G, _segment_shift(4))
        assert not bool(ok)


class TestLejaOrder:
    def test_known_sequence(self):
        out = np.asarray(leja_order(jnp.asarray([1.0, 10.0, 5.0])))
        # magnitude-damped criterion |θ|·Π|θ − chosen|: 10 first, then 5
        # (5·|5−10| = 25 beats 1·|1−10| = 9) — the dominant-end sweep that
        # conditions f32 Newton chains (see core.krylov.leja_order)
        np.testing.assert_array_equal(out, [10.0, 5.0, 1.0])

    def test_deterministic_across_calls(self):
        vals = jnp.asarray(np.random.RandomState(0).randn(12).astype(np.float32))
        a = np.asarray(leja_order(vals))
        b = np.asarray(leja_order(vals))
        np.testing.assert_array_equal(a, b)

    def test_permutation_invariant_for_distinct_values(self):
        rng = np.random.RandomState(3)
        vals = np.unique(rng.randn(10).astype(np.float32))
        a = np.asarray(leja_order(jnp.asarray(vals)))
        b = np.asarray(leja_order(jnp.asarray(vals[::-1].copy())))
        np.testing.assert_array_equal(a, b)

    def test_jit_stable(self):
        vals = jnp.asarray([3.0, -7.0, 1.5, 0.2], jnp.float32)
        a = np.asarray(leja_order(vals))
        b = np.asarray(jax.jit(leja_order)(vals))
        np.testing.assert_array_equal(a, b)


class TestAdaptiveDoublesDepth:
    """The tentpole claim: monomial breaks at CG s=8 / Bi-CG-STAB s=4,
    Newton/Chebyshev run those depths guard-quiet."""

    @pytest.mark.parametrize("basis", ["newton", "chebyshev"])
    def test_cg_s8(self, basis):
        M, _, b, x0 = _clustered_spd()
        rm = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=8, max_iters=24,
                      tol=1e-5, basis="monomial", fallback=False)
        assert bool(rm.breakdown)          # monomial cannot even start s=8
        assert int(rm.iters) == 0
        ra = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=8, max_iters=24,
                      tol=1e-5, basis=basis, fallback=False)
        assert not bool(ra.breakdown)
        assert not bool(ra.basis_degraded)
        assert _rel_res(M, ra.x, b) < 0.1
        # communication-avoiding invariant: bootstraps + full-depth cycles,
        # far below one sync per iteration
        assert int(ra.syncs) <= 2 + (int(ra.iters) - 8 + 7) // 8 + 1

    @pytest.mark.parametrize("basis", ["newton", "chebyshev"])
    def test_bicgstab_s4_guard_quiet(self, basis):
        M, _, b, x0 = _clustered_spd()
        rm = sstep_bicgstab(_mat_op(M), b, x0, lam=0.0, s=4, max_iters=24,
                            tol=1e-5, basis="monomial", fallback=False)
        assert bool(rm.basis_breakdown)    # monomial guard kills s=4
        ra = sstep_bicgstab(_mat_op(M), b, x0, lam=0.0, s=4, max_iters=24,
                            tol=1e-5, basis=basis, fallback=False)
        # any breakdown must be the recurrence's own ρ/ω collapse (which
        # the standard solver exhibits too), never the Gram guard
        assert not bool(ra.basis_breakdown)
        assert not bool(ra.basis_degraded)
        assert int(ra.iters) >= 4
        assert _rel_res(M, ra.x, b) < 0.5

    def test_cg_s8_flat_backend_matches_tree(self):
        M, _, b, x0 = _clustered_spd()
        kw = dict(lam=0.0, s=8, max_iters=24, tol=1e-5, basis="newton",
                  fallback=False)
        rt = sstep_cg(_mat_op(M), b, x0, **kw)
        rf = sstep_cg(_mat_op(M), b, x0, **kw,
                      backend=get_backend("flat", template=b, interpret=True))
        # reduction-order noise can move convergence across a cycle edge
        assert abs(int(rt.iters) - int(rf.iters)) <= 8
        assert abs(int(rt.syncs) - int(rf.syncs)) <= 1
        assert not bool(rf.breakdown)
        assert _rel_res(M, rf.x, b) < 0.1


class TestFallbackChain:
    """Adaptive → monomial → standard: correctness never depends on a
    basis surviving."""

    def test_unusable_adaptive_basis_degrades_to_monomial(self):
        """First link: garbage adaptive coefficients overflow the chain,
        the guard fires, the solve degrades (sticky) to prefix-guarded
        monomial cycles and still finishes — basis_degraded records it."""
        class GarbageBasis(BasisSpec):
            def coeffs(self, ritz, ok, depth):
                f32 = jnp.float32
                return (jnp.full((depth,), 1e30, f32),
                        jnp.zeros((depth,), f32),
                        jnp.full((depth,), 1e-30, f32))

        M, _, b, x0 = _clustered_spd()
        r = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=8, max_iters=24,
                     tol=1e-5, basis=GarbageBasis("chebyshev"),
                     fallback=True)
        assert bool(r.basis_degraded)
        assert _rel_res(M, r.x, b) < 0.1

    def test_fully_degenerate_spectrum_reaches_standard(self):
        """Last link: on A = c·I every Krylov chain is rank-1, the
        (monomial) bootstrap cannot start, and the standard-solver
        fallback finishes the solve exactly."""
        n = 30
        rng = np.random.RandomState(4)
        M = jnp.asarray(3.0 * np.eye(n, dtype=np.float32))
        b, x0 = _vec(rng.randn(n)), _vec(np.zeros(n))
        r = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=8, max_iters=24,
                     tol=1e-8, basis="chebyshev", fallback=True)
        assert bool(r.breakdown)
        assert bool(r.basis_breakdown)
        np.testing.assert_allclose(_unvec(r.x), _unvec(b) / 3.0,
                                   rtol=1e-5, atol=1e-6)

    def test_few_point_spectrum_converges_in_bootstraps(self):
        """A 3-eigenvalue spectrum collapses the Krylov space to dim 3:
        the prefix-guarded bootstrap cycles converge the solve exactly —
        no breakdown, no degrade, no fallback."""
        n = 30
        rng = np.random.RandomState(2)
        U, _ = np.linalg.qr(rng.randn(n, n))
        d = np.array([1.0] * 10 + [2.0] * 10 + [5.0] * 10, np.float32)
        M = jnp.asarray(((U * d) @ U.T).astype(np.float32))
        b, x0 = _vec(rng.randn(n)), _vec(np.zeros(n))
        xt = (np.asarray((U / d) @ U.T) @ _unvec(b)).astype(np.float32)
        r = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=8, max_iters=24,
                     tol=1e-6, basis="chebyshev", fallback=True)
        assert not bool(r.breakdown)
        assert not bool(r.basis_degraded)
        np.testing.assert_allclose(_unvec(r.x), xt, rtol=1e-3, atol=1e-5)

    def test_converged_warm_start_is_not_a_breakdown(self):
        """An x0 that already solves the system (a perfect warm start)
        terminates cleanly: the bootstrap cycles traced after termination
        grow degenerate chains from the stale residual, and their guard
        verdicts must be masked — not reported as breakdown/fallback."""
        M, d, b, x0 = _clustered_spd()
        xt = np.linalg.solve(np.asarray(M, np.float64),
                             _unvec(b)).astype(np.float32)
        r = sstep_cg(_mat_op(M), b, _vec(xt), lam=0.0, s=8, max_iters=24,
                     tol=1e-4, basis="newton", fallback=False)
        assert not bool(r.breakdown)
        assert not bool(r.basis_breakdown)
        assert int(r.iters) == 0
        rb = sstep_bicgstab(_mat_op(M), b, _vec(xt), lam=0.0, s=4,
                            max_iters=24, tol=1e-4, basis="newton",
                            fallback=False)
        assert not bool(rb.breakdown)
        assert int(rb.iters) == 0

    def test_monomial_path_reports_no_degrade(self):
        M, _, b, x0 = _clustered_spd()
        r = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=2, max_iters=16,
                     tol=1e-5, basis="monomial")
        assert not bool(r.basis_degraded)


class TestConfigThreading:
    def _setup(self):
        model = build_mlp((8, 16, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 64, 8, 4)
        params = model.init(jax.random.PRNGKey(1))
        return model, data, params

    def test_bad_basis_raises(self):
        with pytest.raises(ValueError, match="sstep_basis"):
            HFConfig(sstep_basis="legendre")
        with pytest.raises(ValueError, match="basis"):
            resolve_basis("legendre")
        assert resolve_basis(None).kind == "monomial"
        assert resolve_basis(BasisSpec("newton")).kind == "newton"
        assert BASES == ("monomial", "newton", "chebyshev")

    @pytest.mark.parametrize("basis", ["newton", "chebyshev"])
    def test_hf_step_trains_with_adaptive_basis(self, basis):
        model, data, params = self._setup()
        cfg = HFConfig(solver="gn_cg", max_cg_iters=16, init_damping=5.0,
                       sstep_s=8, sstep_basis=basis)
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s: hf_step(
            model.loss_fn, p, s, data, data, cfg,
            model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
        losses = []
        for _ in range(5):
            params, state, m = step(params, state)
            losses.append(float(m["loss"]))
        assert "sstep_basis_degraded" in m and "sstep_basis_fallback" in m
        assert losses[-1] < 0.7 * losses[0]

    def test_optimizer_threading(self):
        from repro.configs.base import HFOptConfig
        from repro.optim import make_optimizer
        model, data, params = self._setup()
        opt = make_optimizer(
            HFOptConfig(name="bicgstab", max_cg_iters=8, sstep_s=4,
                        sstep_basis="newton"),
            model.loss_fn, model_out_fn=model.logits_fn,
            out_loss_fn=model.out_loss_fn,
        )
        state = opt.init(params)
        _, _, m = jax.jit(opt.step)(params, state, data)
        assert "sstep_basis_fallback" in m
