"""Pallas kernels vs pure-jnp oracles (interpret mode), sweeping shapes and
dtypes, plus property-style sweeps on the CG fusions.

The CG-fusion sweeps run over a fixed (n, coefficient, seed) grid covering
the edge shapes (n=1, block-1, block, block+1, multi-block) so the suite
collects and passes without ``hypothesis``; when hypothesis is installed the
same oracle checks additionally run fuzzed (see the *_fuzz tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False


def _qkv(key, B, S, H, KV, hd, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (B, S, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (B, S, KV, hd), jnp.float32).astype(dtype)
    return q, k, v


FA_CASES = [
    # (B, S, H, KV, hd, blk, causal, window, dtype)
    (1, 128, 1, 1, 64, 64, True, None, jnp.float32),
    (2, 256, 4, 2, 64, 128, True, None, jnp.float32),
    (1, 256, 4, 4, 32, 64, False, None, jnp.float32),
    (1, 256, 2, 1, 64, 64, True, 64, jnp.float32),     # sliding window
    (2, 128, 8, 2, 128, 64, True, None, jnp.bfloat16), # GQA bf16
    (1, 512, 2, 2, 64, 128, True, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,KV,hd,blk,causal,window,dtype", FA_CASES)
def test_flash_attention_matches_ref(B, S, H, KV, hd, blk, causal, window, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, KV, hd, dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              blk_q=blk, blk_k=blk, interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_uneven_blocks():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 384, 2, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, blk_q=128, blk_k=128, interpret=True)
    expected = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=2e-5, atol=2e-5)


# ------------------------------------------- flash backward / JVP kernels --
FA_AD_CASES = [
    # (B, S, H, KV, hd, blk, causal, window, valid_len)
    (1, 128, 1, 1, 64, 64, True, None, None),
    (2, 128, 4, 2, 32, 64, True, None, None),      # GQA
    (1, 256, 4, 4, 32, 128, False, None, None),    # non-causal (encoder)
    (1, 256, 2, 1, 64, 64, True, 64, None),        # sliding window + GQA
    (1, 256, 2, 2, 32, 128, False, None, 130),     # padded tail, non-causal
    (1, 256, 2, 1, 32, 128, True, None, 130),      # padded tail, causal GQA
]


def _fa_ad_inputs(B, S, H, KV, hd):
    ks = jax.random.split(jax.random.PRNGKey(7), 7)
    q, k, v = _qkv(ks[0], B, S, H, KV, hd, jnp.float32)
    do = jax.random.normal(ks[3], (B, S, H, hd), jnp.float32)
    qt = jax.random.normal(ks[4], (B, S, H, hd), jnp.float32)
    kt = jax.random.normal(ks[5], (B, S, KV, hd), jnp.float32)
    vt = jax.random.normal(ks[6], (B, S, KV, hd), jnp.float32)
    return q, k, v, do, qt, kt, vt


@pytest.mark.parametrize("B,S,H,KV,hd,blk,causal,window,valid_len", FA_AD_CASES)
def test_flash_fwd_lse_matches_ref(B, S, H, KV, hd, blk, causal, window, valid_len):
    q, k, v, *_ = _fa_ad_inputs(B, S, H, KV, hd)
    kw = dict(causal=causal, window=window, valid_len=valid_len)
    o, lse = ops.flash_attention_fwd(q, k, v, blk_q=blk, blk_k=blk,
                                     interpret=True, **kw)
    o_r, lse_r = ref.flash_attention_fwd_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,KV,hd,blk,causal,window,valid_len", FA_AD_CASES)
def test_flash_bwd_matches_ref_and_ad(B, S, H, KV, hd, blk, causal, window, valid_len):
    """dQ / dK+dV Pallas passes vs the explicit-formula reference, and the
    reference vs jax AD of the dense forward (oracle of the oracle)."""
    q, k, v, do, *_ = _fa_ad_inputs(B, S, H, KV, hd)
    kw = dict(causal=causal, window=window, valid_len=valid_len)
    o, lse = ref.flash_attention_fwd_ref(q, k, v, **kw)
    dq, dk, dv = ops.flash_attention_bwd(q, k, v, o, lse, do, blk_q=blk,
                                         blk_k=blk, interpret=True, **kw)
    dq_r, dk_r, dv_r = ref.flash_attention_bwd_ref(q, k, v, o, lse, do, **kw)
    _, vjp = jax.vjp(lambda *a: ref.flash_attention_ref(*a, **kw), q, k, v)
    dq_a, dk_a, dv_a = vjp(do)
    for got, want, oracle in ((dq, dq_r, dq_a), (dk, dk_r, dk_a), (dv, dv_r, dv_a)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(want), np.asarray(oracle),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,S,H,KV,hd,blk,causal,window,valid_len", FA_AD_CASES)
def test_flash_jvp_matches_ref_and_ad(B, S, H, KV, hd, blk, causal, window, valid_len):
    q, k, v, _, qt, kt, vt = _fa_ad_inputs(B, S, H, KV, hd)
    kw = dict(causal=causal, window=window, valid_len=valid_len)
    o, lse = ref.flash_attention_fwd_ref(q, k, v, **kw)
    ot, lset = ops.flash_attention_jvp(q, k, v, o, lse, qt, kt, vt, blk_q=blk,
                                       blk_k=blk, interpret=True, **kw)
    ot_r, lset_r = ref.flash_attention_jvp_ref(q, k, v, o, lse, qt, kt, vt, **kw)
    _, ot_a = jax.jvp(lambda *a: ref.flash_attention_ref(*a, **kw),
                      (q, k, v), (qt, kt, vt))
    np.testing.assert_allclose(np.asarray(ot), np.asarray(ot_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lset), np.asarray(lset_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(ot_r), np.asarray(ot_a), rtol=2e-4, atol=2e-4)


# Fixed property grid: edge shapes around the VMEM block boundary plus
# coefficient signs/magnitudes. Deterministic — no hypothesis required.
NS = [1, 127, 65_535, 65_536, 65_537, 200_000]
COEFFS = [(0.5, 0.25), (-2.7, 3.0), (0.0, -1.0)]


def _check_x_update(n, alpha, gamma, seed):
    key = jax.random.PRNGKey(seed)
    x, p, s = (jax.random.normal(k, (n,), jnp.float32)
               for k in jax.random.split(key, 3))
    out = ops.bicgstab_x_update(x, p, s, alpha, gamma, interpret=True)
    expected = ref.bicgstab_x_update_ref(x, p, s, alpha, gamma)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5)


def _check_residual_dots(n, gamma, seed):
    key = jax.random.PRNGKey(seed)
    s, As, r0s = (jax.random.normal(k, (n,), jnp.float32)
                  for k in jax.random.split(key, 3))
    r, d1, d2 = ops.bicgstab_residual_dots(s, As, r0s, gamma, interpret=True)
    er, e1, e2 = ref.bicgstab_residual_dots_ref(s, As, r0s, gamma)
    np.testing.assert_allclose(np.asarray(r), np.asarray(er), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(d1), float(e1), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(float(d2), float(e2), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("alpha,gamma", COEFFS)
def test_x_update_property(n, alpha, gamma):
    _check_x_update(n, alpha, gamma, seed=n)


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("gamma", [0.3, -1.9])
def test_residual_dots_property(n, gamma):
    _check_residual_dots(n, gamma, seed=n + 1)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=200_000),
        alpha=st.floats(min_value=-3, max_value=3, allow_nan=False),
        gamma=st.floats(min_value=-3, max_value=3, allow_nan=False),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_x_update_fuzz(n, alpha, gamma, seed):
        _check_x_update(n, alpha, gamma, seed)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=200_000),
        gamma=st.floats(min_value=-3, max_value=3, allow_nan=False),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_residual_dots_fuzz(n, gamma, seed):
        _check_residual_dots(n, gamma, seed)


@pytest.mark.parametrize("n", [1, 127, 16384, 16385, 70_000])
@pytest.mark.parametrize("su,sv", [(1, 1), (3, 5), (8, 8), (9, 17)])
def test_gram_block_matches_matmul(n, su, sv):
    """The s-step Gram kernel: per-column-block partials of U @ Vᵀ across
    edge shapes (sub-block, block, block+1, multi-block columns; row counts
    off the sublane tile)."""
    key = jax.random.PRNGKey(n + su)
    U = jax.random.normal(key, (su, n), jnp.float32)
    V = jax.random.normal(jax.random.fold_in(key, 1), (sv, n), jnp.float32)
    G = ops.gram_block(U, V, interpret=True)
    assert G.shape == (su, sv)
    ref_G = np.asarray(U) @ np.asarray(V).T
    scale = max(float(np.abs(ref_G).max()), 1.0)
    np.testing.assert_allclose(np.asarray(G), ref_G, rtol=1e-4,
                               atol=1e-5 * scale * n ** 0.5)


@pytest.mark.parametrize("n", [1, 127, 4096, 65536, 65537, 300_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dot2_shapes_dtypes(n, dtype):
    key = jax.random.PRNGKey(n)
    u = jax.random.normal(key, (n,), jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32).astype(dtype)
    d1, d2 = ops.dot2(u, v, interpret=True)
    e1, e2 = ref.dot2_ref(u, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(float(d1), float(e1), rtol=tol, atol=tol * n ** 0.5)
    np.testing.assert_allclose(float(d2), float(e2), rtol=tol, atol=tol * n ** 0.5)
