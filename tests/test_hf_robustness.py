"""Divergence sentinel (ISSUE 9 tentpole part 4): a poisoned batch must
not reach the parameters. Non-finite accepted loss/step → reject the
update (params bitwise unchanged, warm start dropped), boost λ through
the LM machinery, and report via metrics["step_rejected"]."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, hf_init, hf_step
from repro.data import classification_dataset
from repro.launch.faults import FaultPlan, parse_faults
from repro.models import build_mlp

MODEL = build_mlp((8, 16, 4))
DATA = classification_dataset(jax.random.PRNGKey(0), 32, 8, 4)


def _step_fn(cfg):
    return jax.jit(lambda p, s, b: hf_step(
        MODEL.loss_fn, p, s, b, b, cfg,
        model_out_fn=MODEL.logits_fn, out_loss_fn=MODEL.out_loss_fn))


def _poison(batch):
    plan = FaultPlan(parse_faults("nan_batch@step=0"), 0)
    return plan.poison_batch(0, batch)


def _leaves_equal(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)))


class TestRejectNonfinite:
    def test_nan_batch_rejected_params_rolled_back(self):
        cfg = HFConfig(solver="gn_cg", max_cg_iters=4)  # defaults: on
        step = _step_fn(cfg)
        params = MODEL.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        p2, s2, m = step(params, state, _poison(DATA))
        assert float(m["step_rejected"]) == 1.0
        assert _leaves_equal(params, p2)  # bitwise rollback
        # warm start dropped: the poisoned direction must not be recycled
        assert all(np.all(np.asarray(l) == 0)
                   for l in jax.tree_util.tree_leaves(s2.prev_delta))
        # λ boosted by damping_inc² (reject_boost=0 default)
        assert float(s2.lam) == pytest.approx(
            float(state.lam) * cfg.damping_inc ** 2)
        assert float(m["rho"]) == 0.0

    def test_recovers_after_poisoned_step(self):
        cfg = HFConfig(solver="gn_cg", max_cg_iters=4)
        step = _step_fn(cfg)
        params = MODEL.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        params, state, m = step(params, state, _poison(DATA))
        assert float(m["step_rejected"]) == 1.0
        losses = []
        for _ in range(3):
            params, state, m = step(params, state, DATA)
            assert float(m["step_rejected"]) == 0.0
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0]  # training resumed

    def test_reject_boost_honored(self):
        cfg = HFConfig(solver="gn_cg", max_cg_iters=4, reject_boost=10.0)
        step = _step_fn(cfg)
        params = MODEL.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        _, s2, _ = step(params, state, _poison(DATA))
        assert float(s2.lam) == pytest.approx(float(state.lam) * 10.0)

    def test_clean_steps_not_rejected_and_parity_with_sentinel_off(self):
        cfg_on = HFConfig(solver="gn_cg", max_cg_iters=4)
        cfg_off = HFConfig(solver="gn_cg", max_cg_iters=4,
                           reject_nonfinite=False)
        params = MODEL.init(jax.random.PRNGKey(1))
        p_on, s_on = params, hf_init(params, cfg_on)
        p_off, s_off = params, hf_init(params, cfg_off)
        step_on, step_off = _step_fn(cfg_on), _step_fn(cfg_off)
        for _ in range(3):
            p_on, s_on, m = step_on(p_on, s_on, DATA)
            p_off, s_off, _ = step_off(p_off, s_off, DATA)
            assert float(m["step_rejected"]) == 0.0
        assert _leaves_equal(p_on, p_off)  # sentinel is a no-op when clean

    def test_sentinel_off_lets_nan_through(self):
        # Documents WHY the sentinel exists: without it the NaN batch
        # poisons the parameters (0 * NaN = NaN even at alpha = 0).
        cfg = HFConfig(solver="gn_cg", max_cg_iters=4,
                       reject_nonfinite=False)
        step = _step_fn(cfg)
        params = MODEL.init(jax.random.PRNGKey(1))
        p2, _, m = step(params, hf_init(params, cfg), _poison(DATA))
        assert "step_rejected" in m  # schema stable either way
        leaves = np.concatenate(
            [np.asarray(l).ravel() for l in jax.tree_util.tree_leaves(p2)])
        assert not np.isfinite(leaves).all()


class TestStrictDescent:
    def test_accepts_normal_descending_steps(self):
        cfg = HFConfig(solver="gn_cg", max_cg_iters=8, strict_descent=True,
                       descent_guard=1e-3)
        step = _step_fn(cfg)
        params = MODEL.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        for _ in range(3):
            params, state, m = step(params, state, DATA)
            assert float(m["step_rejected"]) == 0.0

    def test_rejects_loss_increase(self):
        # Force an ascent acceptance: descent_guard=-10 demands the new
        # loss beat f0 by 10·max(1,|f0|) — impossible for a real step, so
        # strict_descent must reject and keep params.
        cfg = HFConfig(solver="gn_cg", max_cg_iters=4, strict_descent=True,
                       descent_guard=-10.0)
        step = _step_fn(cfg)
        params = MODEL.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        p2, s2, m = step(params, state, DATA)
        assert float(m["step_rejected"]) == 1.0
        assert _leaves_equal(params, p2)
        assert float(s2.lam) > float(state.lam)
