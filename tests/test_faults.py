"""Fault injection + supervision machinery (ISSUE 9 tentpole): spec
parsing, plan gating (process / restart-attempt), the collective
watchdog, and the spawn_supervised restart loop with real child
processes (the ``timeit`` trick from test_multiproc.py: a stdlib module
whose -s setup statement runs arbitrary code under the spawn env)."""
import os
import time

import pytest

from repro.core import collectives
from repro.launch import multiproc
from repro.launch.faults import (ENV_FAULTS, Fault, FaultPlan, corrupt_file,
                                 parse_faults)


class TestSpecParsing:
    def test_single(self):
        (f,) = parse_faults("kill@step=3,proc=1")
        assert f == Fault(kind="kill", step=3, proc=1)

    def test_multi_and_defaults(self):
        fs = parse_faults(
            "nan_batch@step=2; delay@step=1,secs=0.5,attempt=1 ;")
        assert fs[0] == Fault(kind="nan_batch", step=2, proc=None)
        assert fs[1] == Fault(kind="delay", step=1, secs=0.5, attempt=1)

    def test_spec_roundtrip(self):
        for s in ("kill@step=3,proc=1", "hang@step=0",
                  "delay@step=2,secs=0.25,attempt=2"):
            (f,) = parse_faults(s)
            assert parse_faults(f.spec()) == [f]

    def test_empty(self):
        assert parse_faults("") == []

    @pytest.mark.parametrize("bad", [
        "explode@step=1",        # unknown kind
        "kill@proc=1",           # missing step
        "kill@step=1,when=now",  # unknown field
        "kill",                  # missing @
    ])
    def test_bad_specs_raise(self, bad):
        with pytest.raises(ValueError):
            parse_faults(bad)


class TestFaultPlan:
    def _kill_calls(self, monkeypatch):
        calls = []
        monkeypatch.setattr(os, "_exit", lambda code: calls.append(code))
        return calls

    def test_proc_filter(self):
        faults = parse_faults("kill@step=1,proc=1;nan_batch@step=2")
        p0 = FaultPlan(faults, process_index=0)
        p1 = FaultPlan(faults, process_index=1)
        assert [f.kind for f in p0.faults] == ["nan_batch"]  # proc=None: all
        assert [f.kind for f in p1.faults] == ["kill", "nan_batch"]

    def test_attempt_gating(self):
        faults = parse_faults("kill@step=1,proc=0")
        assert FaultPlan(faults, 0, attempt=0).active()
        assert not FaultPlan(faults, 0, attempt=1).active()

    def test_from_env_reads_restart_attempt(self, monkeypatch):
        monkeypatch.setenv(ENV_FAULTS, "kill@step=1,proc=0")
        monkeypatch.setenv(multiproc.ENV_RESTART, "1")
        assert not FaultPlan.from_env(0).active()
        monkeypatch.setenv(multiproc.ENV_RESTART, "0")
        assert FaultPlan.from_env(0).active()

    def test_kill_fires_once_at_step(self, monkeypatch):
        calls = self._kill_calls(monkeypatch)
        plan = FaultPlan(parse_faults("kill@step=2,proc=0"), 0)
        plan.on_step_begin(0)
        plan.on_step_begin(1)
        assert calls == []
        plan.on_step_begin(2)
        assert calls == [1]
        plan.on_step_begin(2)  # fired-once: no re-fire
        assert calls == [1]

    def test_delay_sleeps(self):
        plan = FaultPlan(parse_faults("delay@step=0,secs=0.1"), 0)
        t0 = time.time()
        plan.on_step_begin(0)
        assert time.time() - t0 >= 0.1

    def test_poison_batch_floats_only(self):
        import jax.numpy as jnp
        import numpy as np
        plan = FaultPlan(parse_faults("nan_batch@step=1"), 0)
        batch = {"tokens": jnp.arange(4), "vision": jnp.ones((2, 3))}
        out = plan.poison_batch(0, batch)
        assert out is batch  # wrong step: untouched
        out = plan.poison_batch(1, batch)
        assert np.isnan(np.asarray(out["vision"])).all()
        np.testing.assert_array_equal(np.asarray(out["tokens"]),
                                      np.arange(4))

    def test_telemetry_emission(self):
        events = []

        class Sink:
            def emit(self, ev):
                events.append(ev)

        plan = FaultPlan(parse_faults("delay@step=0,secs=0.01"), 3,
                         telemetry=Sink())
        plan.on_step_begin(0)
        assert events and events[0]["ev"] == "fault"
        assert events[0]["kind"] == "delay"
        assert events[0]["injected"] is True
        assert events[0]["proc"] == 3

    def test_corrupt_checkpoint_hits_newest(self, tmp_path):
        from repro.checkpoint import (latest_valid_step, save_checkpoint,
                                      valid_steps)
        save_checkpoint(str(tmp_path), 1, {"w": [1.0, 2.0]})
        save_checkpoint(str(tmp_path), 2, {"w": [3.0, 4.0]})
        plan = FaultPlan(parse_faults("corrupt_ckpt@step=2"), 0)
        path = plan.corrupt_checkpoint(2, str(tmp_path))
        assert path and path.endswith("ckpt_00000002.npz")
        assert valid_steps(str(tmp_path)) == [1]
        assert latest_valid_step(str(tmp_path)) == 1


class TestCorruptFile:
    def test_changes_bytes_not_size(self, tmp_path):
        p = tmp_path / "blob.bin"
        p.write_bytes(bytes(range(256)) * 16)
        before = p.read_bytes()
        corrupt_file(str(p))
        after = p.read_bytes()
        assert len(after) == len(before) and after != before


class TestWatchdog:
    def test_fires_on_stuck_collective(self):
        fired = []
        wd = collectives.Watchdog(0.1, on_timeout=lambda t, w: fired.append(t),
                                  poll_s=0.02).start()
        wd.arm("grad_hvp")
        time.sleep(0.4)
        assert wd.fired and fired == ["grad_hvp"]
        wd.stop()

    def test_no_fire_when_disarmed(self):
        fired = []
        wd = collectives.Watchdog(0.1, on_timeout=lambda t, w: fired.append(t),
                                  poll_s=0.02).start()
        wd.arm("grad_hvp")
        wd.disarm("grad_hvp")
        time.sleep(0.3)
        assert not wd.fired and fired == []
        wd.stop()

    def test_fifo_pairing_per_tag(self):
        fired = []
        wd = collectives.Watchdog(0.15, on_timeout=lambda t, w: fired.append(t),
                                  poll_s=0.02).start()
        # two outstanding same-tag collectives; one completes — the other
        # (older) is re-covered by FIFO pop, so nothing should fire only
        # if BOTH complete
        wd.arm("loss")
        wd.arm("loss")
        wd.disarm("loss")
        wd.disarm("loss")
        time.sleep(0.3)
        assert not wd.fired
        wd.stop()

    def test_exit_code_constant_matches_launcher(self):
        assert collectives.EXIT_WATCHDOG == multiproc.EXIT_WATCHDOG

    def test_install_bakes_callbacks_into_preduce(self):
        """Trace a shard_map'd preduce under collective_watchdog: the
        compiled program arms/disarms per execution (balanced — nothing
        left outstanding), and tracing outside the context bakes nothing."""
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        events = []

        class Probe(collectives.Watchdog):
            def arm(self, tag):
                events.append(("arm", tag))
                super().arm(tag)

            def disarm(self, tag):
                events.append(("disarm", tag))
                super().disarm(tag)

        wd = Probe(30.0, on_timeout=lambda t, w: None, poll_s=1.0)
        collectives._watchdog = wd
        try:
            def f(x):
                return collectives.preduce(x, "data", tag="loss")
            sm = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P())
            out = jax.jit(sm)(jnp.arange(float(len(jax.devices()))))
            jax.block_until_ready(out)
        finally:
            collectives._watchdog = None
        arms = [e for e in events if e[0] == "arm"]
        disarms = [e for e in events if e[0] == "disarm"]
        assert arms and len(arms) == len(disarms)
        with wd._lock:
            assert all(not q for q in wd._outstanding.values())


_CHILD_SNIPPET = (
    "import os\n"
    "attempt = int(os.environ.get('REPRO_MULTIPROC_RESTART', '0'))\n"
)


class TestSpawnSupervised:
    """Real child processes via the stdlib ``timeit`` module (its -s setup
    statement runs arbitrary code under the spawn environment)."""

    def _spawn(self, code, **kw):
        return multiproc.spawn_supervised(
            2, "timeit", ["-n", "1", "-r", "1", "-s", code, "pass"],
            backoff_s=0.05, poll_s=0.05, log=lambda m: None, **kw)

    def test_clean_run_uses_zero_restarts(self, tmp_path):
        restarts = self._spawn("pass", max_restarts=2,
                               heartbeat_dir=str(tmp_path))
        assert restarts == 0

    def test_restart_after_worker_death(self, tmp_path):
        # worker 1 hard-exits on attempt 0 only; attempt 1 succeeds
        code = (_CHILD_SNIPPET +
                "wid = os.environ['REPRO_MULTIPROC_ID']\n"
                "if attempt == 0 and wid == '1': os._exit(9)\n")
        restarts = self._spawn(code, max_restarts=2,
                               heartbeat_dir=str(tmp_path))
        assert restarts == 1

    def test_budget_exhaustion_raises(self, tmp_path):
        code = _CHILD_SNIPPET + "os._exit(3)\n"
        with pytest.raises(RuntimeError, match="exhausted"):
            self._spawn(code, max_restarts=1, heartbeat_dir=str(tmp_path))

    def test_hang_detected_by_heartbeat_staleness(self, tmp_path):
        # attempt 0: both workers sleep forever without heartbeating —
        # only the liveness monitor can catch this (no exit code ever).
        code = (_CHILD_SNIPPET +
                "import time\n"
                "if attempt == 0: time.sleep(600)\n")
        t0 = time.time()
        restarts = self._spawn(code, max_restarts=1, hang_timeout_s=1.5,
                               heartbeat_dir=str(tmp_path))
        assert restarts == 1
        assert time.time() - t0 < 60  # detected by staleness, not timeout

    def test_heartbeat_resets_staleness(self, tmp_path):
        # attempt 0 worker 0 beats while working slowly; no restart needed
        code = (
            _CHILD_SNIPPET +
            "import time\n"
            "hbd = os.environ.get('REPRO_MULTIPROC_HEARTBEAT')\n"
            "wid = os.environ['REPRO_MULTIPROC_ID']\n"
            "for i in range(6):\n"
            "    open(os.path.join(hbd, 'hb-p' + wid), 'w').write(str(i))\n"
            "    time.sleep(0.4)\n"
        )
        restarts = self._spawn(code, max_restarts=1, hang_timeout_s=1.5,
                               heartbeat_dir=str(tmp_path))
        assert restarts == 0

    def test_heartbeat_writer_roundtrip(self, tmp_path, monkeypatch):
        monkeypatch.setenv(multiproc.ENV_HEARTBEAT_DIR, str(tmp_path))
        monkeypatch.setenv(multiproc.ENV_ID, "1")
        multiproc.heartbeat(5)
        hb = tmp_path / "hb-p1"
        assert hb.exists() and hb.read_text().startswith("5 ")

    def test_heartbeat_noop_outside_supervision(self, monkeypatch):
        monkeypatch.delenv(multiproc.ENV_HEARTBEAT_DIR, raising=False)
        multiproc.heartbeat(1)  # must not raise
