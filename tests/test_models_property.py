"""Property tests on model substrate invariants:

  * exact HVP == finite differences through every block family (scan, SSD,
    MoE routing, recurrence) — the property the whole HF optimizer rests on,
  * HVP symmetry <u, Hv> == <v, Hu>,
  * SSD chunked == step-by-step recurrence,
  * causal/sliding-window attention causality (future tokens cannot leak),
  * MoE router invariants (gates normalized, capacity respected).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import fd_hvp, make_hvp
from repro.core.tree_math import tree_dot, tree_random_like
from repro.data import lm_batch
from repro.models import build_model
from repro.models.ssm import ssd_chunked, ssd_step


FAMILIES = ["qwen2-1.5b", "granite-moe-1b-a400m", "zamba2-7b", "xlstm-1.3b",
            "whisper-small", "phi-3-vision-4.2b"]


@pytest.mark.slow  # jit of jvp-of-grad per family: ~10-20s each
@pytest.mark.parametrize("arch", FAMILIES)
def test_hvp_symmetry(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 2, 16)
    hvp = make_hvp(model.loss_fn, params, batch)
    u = tree_random_like(jax.random.PRNGKey(2), params)
    w = tree_random_like(jax.random.PRNGKey(3), params)
    uhw = float(tree_dot(u, hvp(w)))
    whu = float(tree_dot(w, hvp(u)))
    np.testing.assert_allclose(uhw, whu, rtol=2e-3, atol=1e-4)


@pytest.mark.slow  # 2 extra grad jits per arch for the fd oracle
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "xlstm-1.3b"])
def test_hvp_matches_finite_difference(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 2, 16)
    v = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.01, params)
    hv = make_hvp(model.loss_fn, params, batch)(v)
    fd = fd_hvp(model.loss_fn, params, batch, v, eps=1e-3)
    hv_flat = jnp.concatenate([x.ravel() for x in jax.tree_util.tree_leaves(hv)])
    fd_flat = jnp.concatenate([x.ravel() for x in jax.tree_util.tree_leaves(fd)])
    # compare in the aggregate (fd noise per-coordinate is large)
    cos = jnp.vdot(hv_flat, fd_flat) / (
        jnp.linalg.norm(hv_flat) * jnp.linalg.norm(fd_flat) + 1e-12
    )
    assert float(cos) > 0.99


# Fixed-seed grid (formerly a hypothesis @given sweep — degraded so the
# suite collects without the dependency): chunk==L, chunk|L, multi-head,
# narrow/wide state, distinct seeds.
@pytest.mark.parametrize("L,chunk,H,N,P,seed", [
    (8, 4, 1, 4, 4, 0),
    (8, 8, 2, 16, 8, 1),
    (32, 8, 3, 4, 8, 2),
    (32, 16, 1, 16, 4, 3),
    (64, 16, 4, 16, 8, 4),
    (64, 4, 2, 4, 4, 5),
])
def test_ssd_chunked_equals_recurrence(L, chunk, H, N, P, seed):
    if L % chunk:
        chunk = L
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    B = 2
    u = jax.random.normal(ks[0], (B, L, H, P))
    log_a = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bv = jax.random.normal(ks[2], (B, L, N))
    Cv = jax.random.normal(ks[3], (B, L, N))
    y_chunk, h_chunk = ssd_chunked(u, log_a, Bv, Cv, chunk)

    state = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(L):
        y_t, state = ssd_step(u[:, t], log_a[:, t], Bv[:, t], Cv[:, t], state)
        ys.append(y_t)
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(state), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mixtral-8x22b", "zamba2-7b", "xlstm-1.3b"])
def test_causality(arch, monkeypatch):
    """Perturbing a future token must not change past logits.

    Capacity-based MoE routing is *legitimately nonlocal within a routing
    group* (tokens compete for expert capacity slots — a changed future
    token can evict an earlier one in its group). For MoE archs we shrink
    the routing group and assert causality across group boundaries, which
    is the property the grouped router actually guarantees."""
    cfg = get_smoke_config(arch)
    safe = 23  # positions guaranteed unaffected by perturbing token 23
    if cfg.n_experts:
        from repro.models import moe as moe_mod
        monkeypatch.setattr(moe_mod, "MOE_GROUP_LEN", 8)
        safe = 16  # groups [0,8) and [8,16) don't contain the perturbed token
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 1, 24)
    logits1 = model.logits_fn(params, batch)
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"].at[:, -1].set((batch["tokens"][:, -1] + 7) % cfg.vocab_size)
    logits2 = model.logits_fn(params, b2)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :safe]), np.asarray(logits2[:, :safe]), rtol=1e-5, atol=1e-5
    )


def test_sliding_window_limits_range():
    """With window W and L layers, tokens >= L*W positions back cannot
    influence a query (the receptive field grows with depth — one window per
    layer). Dense arch: MoE capacity routing is legitimately nonlocal."""
    cfg = get_smoke_config("qwen2-1.5b").replace(sliding_window=32)  # 2L x 32 = 64 < 99
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 100
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 1, S)
    logits1 = model.logits_fn(params, batch)
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"].at[:, 0].set((batch["tokens"][:, 0] + 3) % cfg.vocab_size)
    logits2 = model.logits_fn(params, b2)
    # token 0 is outside the 2-layer receptive field of the last query
    np.testing.assert_allclose(
        np.asarray(logits1[:, -1]), np.asarray(logits2[:, -1]), rtol=1e-4, atol=1e-4
    )
    # but inside the receptive field of query 10
    assert not np.allclose(np.asarray(logits1[:, 10]), np.asarray(logits2[:, 10]), atol=1e-5)


class TestMoE:
    def test_gates_normalized_and_capacity(self):
        from repro.models.moe import apply_moe, capacity, group_len_for, moe_init
        cfg = get_smoke_config("granite-moe-1b-a400m")
        p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        y, aux = apply_moe(p, x, cfg)
        assert y.shape == x.shape
        assert float(aux) >= 1.0 - 1e-3  # Switch aux lower bound at balance
        # capacity formula
        gl = group_len_for(32)
        assert capacity(cfg, gl) == max(int(cfg.capacity_factor * cfg.top_k * gl / cfg.n_experts), 1)

    def test_moe_differentiable_twice(self):
        from repro.models.moe import apply_moe, moe_init
        cfg = get_smoke_config("granite-moe-1b-a400m")
        p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))

        def f(pp):
            y, aux = apply_moe(pp, x, cfg)
            return jnp.sum(y ** 2) + aux

        g = jax.grad(f)(p)
        hv = jax.jvp(jax.grad(f), (p,), (jax.tree_util.tree_map(jnp.ones_like, p),))[1]
        assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree_util.tree_leaves(hv))
