"""Curvature engine (core.curvature): linearize-once + chunked accumulation.

The engine must be *invisible* numerically: every mode is the same operator
G, only the execution schedule differs. Reference chain:

    fd_hvp oracle  ≡  naive (rebuild-per-call)  ≡  linearize  ≡  chunked

for the exact Hessian and (minus the fd oracle) the Gauss-Newton product,
and one full ``hf_step`` must agree across curvature modes × both Krylov
vector backends.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, fd_hvp, hf_init, hf_step
from repro.core.curvature import (
    MODES,
    chunked_scalar_fn,
    make_gnvp_op,
    make_hvp_op,
    split_chunks,
)
from repro.core.solvers import hutchinson_diag
from repro.core.tree_math import tree_random_like
from repro.data import classification_dataset
from repro.models import build_mlp

B = 12
CHUNK_SIZES = [1, B // 2, B, 5, B + 1]  # {1, B/2, B, non-divisor, >B}


def _setup():
    model = build_mlp((8, 16, 4))
    batch = classification_dataset(jax.random.PRNGKey(0), B, 8, 4)
    params = model.init(jax.random.PRNGKey(1))
    v = tree_random_like(jax.random.PRNGKey(2), params)
    return model, batch, params, v


def _assert_trees_close(a, b, rtol=1e-5, atol=1e-6, err=""):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=rtol, atol=atol, err_msg=err
        )


class TestHVPModes:
    def test_linearize_matches_naive_and_fd(self):
        model, batch, params, v = _setup()
        naive = make_hvp_op(model.loss_fn, params, batch, mode="naive")(v)
        lin = make_hvp_op(model.loss_fn, params, batch, mode="linearize")(v)
        _assert_trees_close(naive, lin)
        fd = fd_hvp(model.loss_fn, params, batch, v)
        _assert_trees_close(lin, fd, rtol=5e-2, atol=5e-3, err="vs fd oracle")

    @pytest.mark.parametrize("chunk", CHUNK_SIZES)
    def test_chunked_matches_unchunked(self, chunk):
        model, batch, params, v = _setup()
        ref = make_hvp_op(model.loss_fn, params, batch, mode="linearize")(v)
        ch = make_hvp_op(
            model.loss_fn, params, batch, mode="chunked", chunk_size=chunk
        )(v)
        _assert_trees_close(ref, ch, err=f"chunk={chunk}")

    @pytest.mark.parametrize("remat", [True, False])
    def test_remat_does_not_change_values(self, remat):
        model, batch, params, v = _setup()
        ref = make_hvp_op(model.loss_fn, params, batch, mode="naive")(v)
        ch = make_hvp_op(
            model.loss_fn, params, batch, mode="chunked", chunk_size=5,
            remat=remat,
        )(v)
        _assert_trees_close(ref, ch)

    def test_unknown_mode_raises(self):
        model, batch, params, _ = _setup()
        with pytest.raises(ValueError, match="curvature mode"):
            make_hvp_op(model.loss_fn, params, batch, mode="cached")


class TestGNVPModes:
    def test_linearize_matches_naive(self):
        model, batch, params, v = _setup()
        kw = dict(model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn)
        naive = make_gnvp_op(
            kw["model_out_fn"], kw["out_loss_fn"], params, batch, mode="naive"
        )(v)
        lin = make_gnvp_op(
            kw["model_out_fn"], kw["out_loss_fn"], params, batch, mode="linearize"
        )(v)
        _assert_trees_close(naive, lin)

    @pytest.mark.parametrize("chunk", CHUNK_SIZES)
    def test_chunked_matches_unchunked(self, chunk):
        model, batch, params, v = _setup()
        ref = make_gnvp_op(
            model.logits_fn, model.out_loss_fn, params, batch, mode="naive"
        )(v)
        ch = make_gnvp_op(
            model.logits_fn, model.out_loss_fn, params, batch,
            mode="chunked", chunk_size=chunk,
        )(v)
        _assert_trees_close(ref, ch, err=f"chunk={chunk}")


class TestChunkedLoss:
    """The scan-over-microbatches loss is exact, not approximate."""

    @pytest.mark.parametrize("chunk", CHUNK_SIZES)
    def test_scalar_value_exact(self, chunk):
        model, batch, params, _ = _setup()
        full = float(model.loss_fn(params, batch))
        chunked = float(chunked_scalar_fn(model.loss_fn, batch, chunk)(params))
        np.testing.assert_allclose(chunked, full, rtol=1e-6)

    def test_split_chunks_shapes(self):
        _, batch, _, _ = _setup()
        main, rem, n_chunks, n_rem = split_chunks(batch, 5)
        assert n_chunks == 2 and n_rem == 2
        assert main["x"].shape == (2, 5, 8)
        assert rem["x"].shape == (2, 8)
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(main["x"]).reshape(10, 8),
                            np.asarray(rem["x"])]),
            np.asarray(batch["x"]),
        )

    def test_mismatched_leading_dims_raise(self):
        bad = {"x": jnp.zeros((4, 2)), "y": jnp.zeros((5,), jnp.int32)}
        with pytest.raises(ValueError, match="leading dim"):
            split_chunks(bad, 2)


class TestGradReduceOnce:
    """Alg. 2's schedule: ONE reduce per accumulated product — grad_reduce
    must be applied exactly once regardless of how many chunks are swept.
    The probe reduce adds a constant: if it were applied per chunk the
    result would be offset by n_chunks, not 1."""

    def _probe(self, t):
        return jax.tree_util.tree_map(lambda x: x + 1.0, t)

    @pytest.mark.parametrize("mode,chunk", [
        ("naive", 0), ("linearize", 0), ("chunked", 5), ("chunked", 1),
    ])
    def test_hvp_reduce_applied_once(self, mode, chunk):
        model, batch, params, v = _setup()
        plain = make_hvp_op(model.loss_fn, params, batch,
                            mode=mode, chunk_size=chunk)(v)
        reduced = make_hvp_op(model.loss_fn, params, batch, mode=mode,
                              chunk_size=chunk, grad_reduce=self._probe)(v)
        expect = jax.tree_util.tree_map(lambda x: x + 1.0, plain)
        _assert_trees_close(reduced, expect, err=f"{mode}/chunk={chunk}")

    @pytest.mark.parametrize("mode,chunk", [("linearize", 0), ("chunked", 5)])
    def test_gnvp_reduce_applied_once(self, mode, chunk):
        model, batch, params, v = _setup()
        plain = make_gnvp_op(model.logits_fn, model.out_loss_fn, params, batch,
                             mode=mode, chunk_size=chunk)(v)
        reduced = make_gnvp_op(model.logits_fn, model.out_loss_fn, params,
                               batch, mode=mode, chunk_size=chunk,
                               grad_reduce=self._probe)(v)
        expect = jax.tree_util.tree_map(lambda x: x + 1.0, plain)
        _assert_trees_close(reduced, expect, err=f"{mode}/chunk={chunk}")


class TestSharedPrimal:
    """One jax.linearize(value_and_grad) pass == value_and_grad + a separate
    linearize-once HVP build (ROADMAP item: shared primal between gradient
    and curvature when hvp_batch == batch)."""

    def test_matches_separate_builds(self):
        from repro.core.curvature import shared_primal_hvp
        model, batch, params, v = _setup()
        f0, g, hvp = shared_primal_hvp(model.loss_fn, params, batch)
        f0_ref, g_ref = jax.value_and_grad(model.loss_fn)(params, batch)
        hvp_ref = make_hvp_op(model.loss_fn, params, batch, mode="linearize")
        np.testing.assert_allclose(float(f0), float(f0_ref), rtol=1e-6)
        _assert_trees_close(g, g_ref)
        _assert_trees_close(hvp(v), hvp_ref(v))

    def test_grad_reduce_applied(self):
        from repro.core.curvature import shared_primal_hvp
        model, batch, params, v = _setup()
        probe = lambda t: jax.tree_util.tree_map(lambda x: x + 1.0, t)
        _, g0, hvp0 = shared_primal_hvp(model.loss_fn, params, batch)
        _, g1, hvp1 = shared_primal_hvp(model.loss_fn, params, batch,
                                        grad_reduce=probe)
        _assert_trees_close(g1, probe(g0))
        _assert_trees_close(hvp1(v), probe(hvp0(v)))

    def test_hf_step_shared_vs_separate_paths(self):
        """hf_step takes the shared-primal path when hvp_batch IS batch and
        the separate-build path when it is merely equal — both must produce
        the same step."""
        model = build_mlp((8, 16, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 64, 8, 4)
        data_copy = jax.tree_util.tree_map(lambda x: x.copy(), data)
        params = model.init(jax.random.PRNGKey(1))
        cfg = HFConfig(solver="bicgstab", max_cg_iters=8, init_damping=5.0)
        state = hf_init(params, cfg)
        shared = jax.jit(lambda p, s: hf_step(
            model.loss_fn, p, s, data, data, cfg))(params, state)
        separate = jax.jit(lambda p, s: hf_step(
            model.loss_fn, p, s, data, data_copy, cfg))(params, state)
        _assert_trees_close(shared[0], separate[0], rtol=1e-5, atol=1e-5)
        for k in shared[2]:
            np.testing.assert_allclose(
                float(shared[2][k]), float(separate[2][k]),
                rtol=1e-5, atol=1e-5, err_msg=k)


class TestHFStepAcrossModes:
    """One hf_step must be numerically identical (to fp noise) for every
    curvature mode on both Krylov vector backends. init_damping=5.0 keeps
    the Bi-CG-STAB recurrence in the well-conditioned regime where
    reduction-order noise stays at fp level (same policy as
    test_krylov_backends)."""

    def _setup(self):
        model = build_mlp((8, 16, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 64, 8, 4)
        params = model.init(jax.random.PRNGKey(1))
        return model, data, params

    def _step(self, model, data, params, cfg):
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s, cfg=cfg: hf_step(
            model.loss_fn, p, s, data, data, cfg,
            model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
        p2, _, metrics = step(params, state)
        return p2, metrics

    @pytest.mark.parametrize("backend", ["tree", "flat"])
    @pytest.mark.parametrize("mode", list(MODES))
    def test_step_matches_reference(self, mode, backend):
        model, data, params = self._setup()
        ref_cfg = HFConfig(solver="bicgstab", max_cg_iters=8, init_damping=5.0,
                           curvature_mode="naive", krylov_backend="tree")
        cfg = HFConfig(solver="bicgstab", max_cg_iters=8, init_damping=5.0,
                       curvature_mode=mode, curvature_chunk_size=24,
                       krylov_backend=backend)
        p_ref, m_ref = self._step(model, data, params, ref_cfg)
        p2, m2 = self._step(model, data, params, cfg)
        _assert_trees_close(p_ref, p2, rtol=1e-5, atol=1e-5,
                            err=f"{mode}/{backend}")
        assert int(m_ref["cg_iters"]) == int(m2["cg_iters"])
        for k in m_ref:
            np.testing.assert_allclose(float(m_ref[k]), float(m2[k]),
                                       rtol=1e-5, atol=1e-5, err_msg=k)

    def test_hybrid_solver_chunked_trains(self):
        """The hybrid lax.cond switch runs on the cached linear maps; a few
        chunked-mode steps must still reduce the loss."""
        model, data, params = self._setup()
        cfg = HFConfig(solver="hybrid_cg", max_cg_iters=6,
                       curvature_mode="chunked", curvature_chunk_size=16)
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s: hf_step(
            model.loss_fn, p, s, data, data, cfg,
            model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
        losses = []
        for _ in range(6):
            params, state, m = step(params, state)
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.8 * losses[0]


class TestHutchinsonReuse:
    """`hutchinson_diag` applies the operator as-is: handing it the step's
    prebuilt linearized operator gives the naive-mode estimate exactly (no
    re-linearization, same numbers)."""

    def test_probe_matches_across_modes(self):
        model, batch, params, _ = _setup()
        step = jnp.asarray(3)
        like = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        diags = {
            mode: hutchinson_diag(
                make_hvp_op(model.loss_fn, params, batch,
                            mode=mode, chunk_size=5),
                like, step, samples=2)
            for mode in MODES
        }
        _assert_trees_close(diags["naive"], diags["linearize"])
        _assert_trees_close(diags["naive"], diags["chunked"])


class TestConfigPlumbing:
    def test_bad_mode_raises_in_hfconfig(self):
        with pytest.raises(ValueError, match="curvature_mode"):
            HFConfig(curvature_mode="lazy")

    def test_optimizer_threads_curvature_config(self):
        from repro.configs.base import HFOptConfig
        from repro.optim import make_optimizer

        model = build_mlp((8, 16, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 16, 8, 4)
        params = model.init(jax.random.PRNGKey(1))
        opt = make_optimizer(
            HFOptConfig(name="bicgstab", max_cg_iters=4,
                        curvature_mode="chunked", curvature_chunk_size=4),
            model.loss_fn, model_out_fn=model.logits_fn,
            out_loss_fn=model.out_loss_fn,
        )
        state = opt.init(params)
        p2, _, metrics = jax.jit(opt.step)(params, state, data)
        assert np.isfinite(float(metrics["loss"]))
        assert any(
            not np.allclose(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(p2))
        )
