"""Paper Figure 2: f(x,y) = 0.5x² + 0.25y⁴ − 0.5y².

Saddle at (0,0); minima at (0,±1). From any (x,0) start, gradient methods and
Newton-CG converge to the saddle (no gradient component along y); the paper's
Bi-CG-STAB HF escapes via the negative-curvature direction (0,±1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, hf_init, hf_step


def loss_fn(params, batch):
    x, y = params["x"], params["y"]
    return 0.5 * x**2 + 0.25 * y**4 - 0.5 * y**2 + 0.0 * jnp.sum(batch)


def model_out_fn(params, batch):
    # "network output" for the GN split: z = (x, y²/2) with loss l(z) below —
    # GN of this split is PSD and has NO information along y at y=0.
    return jnp.stack([params["x"], params["y"] ** 2 / 2.0])


def out_loss_fn(z, batch):
    return 0.5 * z[0] ** 2 + z[1] ** 2 - z[1] + 0.0 * jnp.sum(batch)


BATCH = jnp.zeros((1,))
START = {"x": jnp.asarray(0.9, jnp.float32), "y": jnp.asarray(0.0, jnp.float32)}


def run(solver, steps=40, damping=1e-3, jitter=1e-3):
    cfg = HFConfig(solver=solver, max_cg_iters=10, init_damping=damping,
                   krylov_jitter=jitter)
    params, state = START, hf_init(START, cfg)
    step = jax.jit(
        lambda p, s: hf_step(
            loss_fn, p, s, BATCH, BATCH, cfg,
            model_out_fn=model_out_fn, out_loss_fn=out_loss_fn,
        ),
        static_argnames=(),
    )
    metrics = None
    for _ in range(steps):
        params, state, metrics = step(params, state)
    return params, metrics


def test_sgd_converges_to_saddle():
    params = dict(START)
    for _ in range(200):
        g = jax.grad(loss_fn)(params, BATCH)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.1 * gg, params, g)
    # stuck exactly at the saddle: y never moves
    assert abs(float(params["x"])) < 1e-3
    assert abs(float(params["y"])) < 1e-8
    assert float(loss_fn(params, BATCH)) == pytest.approx(0.0, abs=1e-5)


def test_gn_cg_converges_to_saddle():
    # Deterministic GN-CG (no Krylov jitter): the Gauss-Newton operator is
    # blind along y at y=0 (zero curvature, zero gradient) — converges to the
    # saddle exactly as the paper claims for Martens' HF / SFN / Newton.
    # (With jitter enabled GN can drift off the axis through its curvature
    # null-space, but that is damping-amplified noise, not curvature use.)
    params, _ = run("gn_cg", jitter=0.0)
    assert abs(float(params["y"])) < 1e-6  # no escape: GN blind along y at y=0
    assert float(loss_fn(params, BATCH)) > -0.2


def test_bicgstab_escapes_saddle():
    params, metrics = run("bicgstab")
    f = float(loss_fn(params, BATCH))
    assert f == pytest.approx(-0.25, abs=1e-2)   # reached a local minimum
    assert abs(abs(float(params["y"])) - 1.0) < 0.05


def test_hybrid_escapes_saddle():
    params, _ = run("hybrid_cg")
    assert float(loss_fn(params, BATCH)) == pytest.approx(-0.25, abs=1e-2)


def test_hessian_cg_escapes_saddle():
    # exact-Hessian CG also sees the NC direction (captured, not discarded)
    params, _ = run("hessian_cg")
    assert float(loss_fn(params, BATCH)) == pytest.approx(-0.25, abs=1e-2)


def test_bicgstab_reports_negative_curvature():
    _, metrics = run("bicgstab", steps=1)
    assert bool(metrics["nc_found"])
    assert float(metrics["nc_curv"]) < 0
