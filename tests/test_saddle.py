"""Paper Figure 2: f(x,y) = 0.5x² + 0.25y⁴ − 0.5y².

Saddle at (0,0); minima at (0,±1). From any (x,0) start, gradient methods and
Newton-CG converge to the saddle (no gradient component along y); the paper's
Bi-CG-STAB HF escapes via the negative-curvature direction (0,±1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, hf_init, hf_step


def loss_fn(params, batch):
    x, y = params["x"], params["y"]
    return 0.5 * x**2 + 0.25 * y**4 - 0.5 * y**2 + 0.0 * jnp.sum(batch)


def model_out_fn(params, batch):
    # "network output" for the GN split: z = (x, y²/2) with loss l(z) below —
    # GN of this split is PSD and has NO information along y at y=0.
    return jnp.stack([params["x"], params["y"] ** 2 / 2.0])


def out_loss_fn(z, batch):
    return 0.5 * z[0] ** 2 + z[1] ** 2 - z[1] + 0.0 * jnp.sum(batch)


BATCH = jnp.zeros((1,))
START = {"x": jnp.asarray(0.9, jnp.float32), "y": jnp.asarray(0.0, jnp.float32)}


def run(solver, steps=40, damping=1e-3, jitter=1e-3, nc_mode="truncate"):
    cfg = HFConfig(solver=solver, max_cg_iters=10, init_damping=damping,
                   krylov_jitter=jitter, nc_mode=nc_mode)
    params, state = START, hf_init(START, cfg)
    step = jax.jit(
        lambda p, s: hf_step(
            loss_fn, p, s, BATCH, BATCH, cfg,
            model_out_fn=model_out_fn, out_loss_fn=out_loss_fn,
        ),
        static_argnames=(),
    )
    metrics = None
    for _ in range(steps):
        params, state, metrics = step(params, state)
    return params, metrics


def test_sgd_converges_to_saddle():
    params = dict(START)
    for _ in range(200):
        g = jax.grad(loss_fn)(params, BATCH)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.1 * gg, params, g)
    # stuck exactly at the saddle: y never moves
    assert abs(float(params["x"])) < 1e-3
    assert abs(float(params["y"])) < 1e-8
    assert float(loss_fn(params, BATCH)) == pytest.approx(0.0, abs=1e-5)


def test_gn_cg_converges_to_saddle():
    # Deterministic GN-CG (no Krylov jitter): the Gauss-Newton operator is
    # blind along y at y=0 (zero curvature, zero gradient) — converges to the
    # saddle exactly as the paper claims for Martens' HF / SFN / Newton.
    # (With jitter enabled GN can drift off the axis through its curvature
    # null-space, but that is damping-amplified noise, not curvature use.)
    params, _ = run("gn_cg", jitter=0.0)
    assert abs(float(params["y"])) < 1e-6  # no escape: GN blind along y at y=0
    assert float(loss_fn(params, BATCH)) > -0.2


def test_bicgstab_escapes_saddle():
    params, metrics = run("bicgstab")
    f = float(loss_fn(params, BATCH))
    assert f == pytest.approx(-0.25, abs=1e-2)   # reached a local minimum
    assert abs(abs(float(params["y"])) - 1.0) < 0.05


def test_hybrid_escapes_saddle():
    params, _ = run("hybrid_cg")
    assert float(loss_fn(params, BATCH)) == pytest.approx(-0.25, abs=1e-2)


def test_hessian_cg_escapes_saddle():
    # exact-Hessian CG also sees the NC direction (captured, not discarded)
    params, _ = run("hessian_cg")
    assert float(loss_fn(params, BATCH)) == pytest.approx(-0.25, abs=1e-2)


def test_bicgstab_reports_negative_curvature():
    _, metrics = run("bicgstab", steps=1)
    assert bool(metrics["nc_found"])
    assert float(metrics["nc_curv"]) < 0
    # nc_lambda (the escape scale): a λ_min(G) estimate at least as
    # negative as the probe's Rayleigh quotient; here λ_min = −1 exactly.
    assert float(metrics["nc_lambda"]) <= float(metrics["nc_curv"])
    assert float(metrics["nc_lambda"]) == pytest.approx(-1.0, abs=0.05)


def _steps_to_exit(nc_mode, thresh=0.5, steps=40):
    """Outer steps until |y| > thresh (out of the saddle's basin boundary).

    Runs the full trajectory either way; returns (exit_step, final_params).
    """
    cfg = HFConfig(solver="bicgstab", max_cg_iters=10, init_damping=1e-3,
                   krylov_jitter=1e-3, nc_mode=nc_mode)
    params, state = START, hf_init(START, cfg)
    step = jax.jit(lambda p, s: hf_step(loss_fn, p, s, BATCH, BATCH, cfg))
    exit_step = steps + 1
    for i in range(steps):
        params, state, _ = step(params, state)
        if exit_step > steps and abs(float(params["y"])) > thresh:
            exit_step = i + 1
    return exit_step, params


def test_escape_exits_saddle_in_fewer_steps():
    # A/B on the Fig. 2 landscape: the saddle-free escape step moves |λ_min|
    # = 1 along the NC direction at once, while truncate's norm-matched NC
    # step crawls at max(sol_norm, nc_min_step) per outer step as the
    # solution component decays. Strict inequality, and both reach a minimum.
    n_esc, p_esc = _steps_to_exit("escape")
    n_trunc, p_trunc = _steps_to_exit("truncate")
    assert n_esc < n_trunc
    assert float(loss_fn(p_esc, BATCH)) == pytest.approx(-0.25, abs=1e-2)


def test_escape_poisoned_lambda_rejected_by_sentinel(monkeypatch):
    # Regression: nc_mode="escape" + a non-finite λ estimate must flow INTO
    # the PR 9 divergence sentinel (step_rejected, params kept bitwise) —
    # the escape comparison resolves NaN/inf model values TOWARD taking the
    # NC step precisely so poisoned curvature cannot be silently accepted
    # through a False NaN comparison.
    import repro.core.hf as hf_mod

    real_bicgstab = hf_mod.bicgstab

    def poisoned(*args, **kwargs):
        res = real_bicgstab(*args, **kwargs)
        return res._replace(
            nc_found=jnp.ones((), bool),
            nc_curv=jnp.asarray(-1.0, jnp.float32),
            nc_lambda=jnp.asarray(-jnp.inf, jnp.float32),
        )

    monkeypatch.setattr(hf_mod, "bicgstab", poisoned)
    cfg = HFConfig(solver="bicgstab", max_cg_iters=10, init_damping=1e-3,
                   krylov_jitter=1e-3, nc_mode="escape")
    assert cfg.reject_nonfinite
    state = hf_init(START, cfg)
    new_params, new_state, metrics = hf_step(
        loss_fn, START, state, BATCH, BATCH, cfg)
    assert bool(metrics["step_rejected"])
    for k in ("x", "y"):
        np.testing.assert_array_equal(np.asarray(new_params[k]),
                                      np.asarray(START[k]))
    # warm start dropped, λ boosted through the LM machinery
    assert float(jnp.abs(new_state.prev_delta["y"])) == 0.0
    assert float(new_state.lam) > float(state.lam)


def test_nc_mode_validated():
    with pytest.raises(ValueError, match="nc_mode"):
        HFConfig(nc_mode="bogus")
