"""s-step (communication-avoiding) Krylov subsystem tests.

Covers the three layers the subsystem adds (ISSUE 3):
  * block backend ops (gram / block_combine / lift_block — tree vs flat via
    the Pallas ``dots_block`` kernel in interpret mode),
  * multi-tangent block curvature products (block-HVP == s independent HVPs
    for every curvature mode),
  * the s-step solvers themselves: equivalence with the standard
    recurrences on SPD and indefinite systems for s ∈ {1, 2, 4}, the
    Gram-factorization breakdown guard + standard-solver fallback, and
    hf_step parity across s-step × both vector backends.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, hf_init, hf_step
from repro.core.blocks import (
    block_op_from_single,
    make_block_gnvp_op,
    make_block_hvp_op,
    stack_tangents,
    unstack_tangents,
)
from repro.core.curvature import make_gnvp_op, make_hvp_op
from repro.core.krylov import get_backend
from repro.core.solvers import bicgstab, cg
from repro.core.sstep import sstep_bicgstab, sstep_cg
from repro.core.tree_math import tree_pseudo_noise
from repro.data import classification_dataset
from repro.models import build_mlp


def _vec(x):
    """Two-leaf pytree (vector + matrix leaf) to exercise ravel/unravel."""
    x = np.asarray(x, np.float32)
    return {"a": jnp.asarray(x[:5]), "b": jnp.asarray(x[5:]).reshape(3, 3)}


def _unvec(t):
    return np.concatenate([np.asarray(t["a"]).ravel(), np.asarray(t["b"]).ravel()])


def _mat_op(M):
    def op(v):
        f = jnp.concatenate([v["a"].ravel(), v["b"].ravel()])
        out = M @ f
        return {"a": out[:5], "b": out[5:].reshape(3, 3)}
    return op


def _flat_be(template):
    return get_backend("flat", template=template, interpret=True)


def _spd():
    rng = np.random.RandomState(2)
    Q = rng.randn(14, 14).astype(np.float32)
    M = jnp.asarray(Q @ Q.T + 14 * np.eye(14, dtype=np.float32))
    return M, _vec(rng.randn(14)), _vec(np.zeros(14))


class TestBlockBackendOps:
    """The BlockVectorBackend protocol extension, tree vs flat."""

    def _vecs(self, n=3):
        rng = np.random.RandomState(0)
        return [_vec(rng.randn(14)) for _ in range(n)]

    def test_gram_matches_pairwise_dots(self):
        vecs = self._vecs(3)
        tb = get_backend("tree")
        fb = _flat_be(vecs[0])
        Bt = tb.block_stack(vecs)
        Bf = fb.block_stack([fb.lift(v) for v in vecs])
        Gt = np.asarray(tb.gram(Bt, Bt))
        Gf = np.asarray(fb.gram(Bf, Bf))
        ref = np.array([[float(_unvec(u) @ _unvec(v)) for v in vecs]
                        for u in vecs])
        np.testing.assert_allclose(Gt, ref, rtol=1e-5)
        np.testing.assert_allclose(Gf, ref, rtol=1e-5)

    def test_gram_rectangular(self):
        vecs = self._vecs(5)
        tb = get_backend("tree")
        fb = _flat_be(vecs[0])
        U, V = vecs[:2], vecs[2:]
        Gt = np.asarray(tb.gram(tb.block_stack(U), tb.block_stack(V)))
        Gf = np.asarray(fb.gram(fb.block_stack([fb.lift(u) for u in U]),
                                fb.block_stack([fb.lift(v) for v in V])))
        assert Gt.shape == (2, 3)
        np.testing.assert_allclose(Gt, Gf, rtol=1e-5, atol=1e-6)

    def test_block_combine_matches_manual(self):
        vecs = self._vecs(3)
        rng = np.random.RandomState(1)
        C = rng.randn(2, 3).astype(np.float32)
        tb = get_backend("tree")
        fb = _flat_be(vecs[0])
        out_t = tb.block_combine(jnp.asarray(C), tb.block_stack(vecs))
        out_f = fb.block_combine(
            jnp.asarray(C), fb.block_stack([fb.lift(v) for v in vecs]))
        ref = C @ np.stack([_unvec(v) for v in vecs])
        for i in range(2):
            np.testing.assert_allclose(
                _unvec(tb.block_col(out_t, i)), ref[i], rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(
                np.asarray(fb.block_col(out_f, i)), ref[i], rtol=1e-5, atol=1e-6)

    def test_lift_lower_block_roundtrip(self):
        vecs = self._vecs(4)
        tb = get_backend("tree")
        fb = _flat_be(vecs[0])
        stacked = tb.block_stack(vecs)
        M = fb.lift_block(stacked)
        assert M.shape == (4, 14)
        back = fb.lower_block(M)
        for a, b in zip(jax.tree_util.tree_leaves(stacked),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_wrap_block_op(self):
        M, b, _ = _spd()
        vecs = self._vecs(2)
        tb = get_backend("tree")
        fb = _flat_be(b)
        blk_op = block_op_from_single(_mat_op(M))
        out_t = tb.wrap_block_op(blk_op)(tb.block_stack(vecs))
        out_f = fb.wrap_block_op(blk_op)(
            fb.block_stack([fb.lift(v) for v in vecs]))
        for i in range(2):
            ref = np.asarray(M) @ _unvec(vecs[i])
            np.testing.assert_allclose(_unvec(tb.block_col(out_t, i)), ref,
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(np.asarray(out_f[i]), ref,
                                       rtol=1e-5, atol=1e-5)


class TestBlockCurvature:
    """Block-HVP/GNVP == s independent single products, every mode."""

    def _setup(self):
        model = build_mlp((8, 12, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 32, 8, 4)
        params = model.init(jax.random.PRNGKey(1))
        tangents = [tree_pseudo_noise(params, i) for i in range(3)]
        return model, data, params, tangents

    @pytest.mark.parametrize("mode,chunk", [
        ("linearize", 0), ("chunked", 8),
        pytest.param("naive", 0, marks=pytest.mark.slow),
        pytest.param("chunked", 10, marks=pytest.mark.slow),
    ])
    def test_block_hvp_matches_singles(self, mode, chunk):
        model, data, params, tangents = self._setup()
        single = make_hvp_op(model.loss_fn, params, data,
                             mode=mode, chunk_size=chunk)
        blk = make_block_hvp_op(model.loss_fn, params, data,
                                mode=mode, chunk_size=chunk)
        out = blk(stack_tangents(tangents))
        for got, v in zip(unstack_tangents(out), tangents):
            ref = single(v)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(ref)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("mode,chunk", [("linearize", 0), ("chunked", 8)])
    def test_block_gnvp_matches_singles(self, mode, chunk):
        model, data, params, tangents = self._setup()
        single = make_gnvp_op(model.logits_fn, model.out_loss_fn, params, data,
                              mode=mode, chunk_size=chunk)
        blk = make_block_gnvp_op(model.logits_fn, model.out_loss_fn, params,
                                 data, mode=mode, chunk_size=chunk)
        out = blk(stack_tangents(tangents))
        for got, v in zip(unstack_tangents(out), tangents):
            ref = single(v)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(ref)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-5)

    def test_block_op_from_single_shares_linearization(self):
        model, data, params, tangents = self._setup()
        single = make_hvp_op(model.loss_fn, params, data, mode="linearize")
        blk = block_op_from_single(single)
        out = blk(stack_tangents(tangents))
        for got, v in zip(unstack_tangents(out), tangents):
            ref = single(v)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(ref)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-6, atol=1e-6)


class TestSStepCG:
    """s-step CG == standard CG (same math, one Gram reduce per cycle)."""

    @pytest.mark.parametrize("s", [1, 2, 4])
    def test_matches_standard_on_spd(self, s):
        M, b, x0 = _spd()
        rt = cg(_mat_op(M), b, x0, lam=0.0, max_iters=40, tol=1e-8)
        rs = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=s, max_iters=40, tol=1e-8)
        assert not bool(rs.breakdown)
        np.testing.assert_allclose(_unvec(rs.x), _unvec(rt.x),
                                   rtol=1e-4, atol=1e-4)
        # cycles, not iterations: the communication-avoiding invariant
        assert int(rs.syncs) <= math.ceil(int(rs.iters) / s) + 1

    @pytest.mark.parametrize("s", [2, 4])
    def test_flat_backend_matches_tree(self, s):
        M, b, x0 = _spd()
        rt = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=s, max_iters=40, tol=1e-8)
        rf = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=s, max_iters=40, tol=1e-8,
                      backend=_flat_be(b))
        # reduction-order noise can move convergence across a cycle edge:
        # the invariant is the same solution within at most one extra cycle
        assert abs(int(rt.iters) - int(rf.iters)) <= s
        assert abs(int(rt.syncs) - int(rf.syncs)) <= 1
        np.testing.assert_allclose(_unvec(rt.x), _unvec(rf.x),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("s", [2, 4])
    def test_block_operator_path_matches(self, s):
        M, b, x0 = _spd()
        A = _mat_op(M)
        r1 = sstep_cg(A, b, x0, lam=0.0, s=s, max_iters=40, tol=1e-8)
        r2 = sstep_cg(A, b, x0, lam=0.0, s=s, max_iters=40, tol=1e-8,
                      A_block=block_op_from_single(A))
        np.testing.assert_allclose(_unvec(r1.x), _unvec(r2.x),
                                   rtol=1e-5, atol=1e-6)

    def test_nc_capture_on_indefinite(self):
        d = np.array([4.0, -2.0, 1.0, -0.5] + [1.0] * 10, np.float32)
        M = jnp.asarray(np.diag(d))
        rng = np.random.RandomState(3)
        b, x0 = _vec(rng.randn(14)), _vec(np.zeros(14))
        rs = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=2, max_iters=8, tol=1e-8,
                      fallback=False)
        # CG truncates at negative curvature and reports the direction
        assert bool(rs.nc_found)
        dvec = _unvec(rs.nc_dir)
        curv = float(dvec @ np.diag(d) @ dvec)
        np.testing.assert_allclose(curv, float(rs.nc_curv), rtol=1e-3, atol=1e-4)
        assert curv < 0


class TestSStepBiCGStab:
    """s-step Bi-CG-STAB == standard, SPD + indefinite, s ∈ {1, 2, 4}."""

    @pytest.mark.parametrize("s", [1, 2])
    def test_matches_standard_on_spd(self, s):
        M, b, x0 = _spd()
        xt = np.linalg.solve(np.asarray(M), _unvec(b))
        rs = sstep_bicgstab(_mat_op(M), b, x0, lam=0.0, s=s, max_iters=40,
                            tol=1e-8)
        assert not bool(rs.breakdown)
        np.testing.assert_allclose(_unvec(rs.x), xt, rtol=1e-4, atol=1e-4)
        assert int(rs.syncs) <= math.ceil(int(rs.iters) / s) + 1

    def test_s4_converges_with_fallback_guarantee(self):
        # depth-8 monomial chains exceed f32: the guard may hand the solve
        # to the standard solver — either way the system must be solved.
        M, b, x0 = _spd()
        xt = np.linalg.solve(np.asarray(M), _unvec(b))
        rs = sstep_bicgstab(_mat_op(M), b, x0, lam=0.0, s=4, max_iters=40,
                            tol=1e-8)
        np.testing.assert_allclose(_unvec(rs.x), xt, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("s", [1, 2, 4])
    def test_indefinite_system(self, s):
        d = np.array([4.0, -2.0, 1.0, -0.5] + [2.0] * 10, np.float32)
        M = jnp.asarray(np.diag(d))
        rng = np.random.RandomState(3)
        b, x0 = _vec(rng.randn(14)), _vec(np.zeros(14))
        xt = _unvec(b) / d
        rs = sstep_bicgstab(_mat_op(M), b, x0, lam=0.0, s=s, max_iters=60,
                            tol=1e-8)
        np.testing.assert_allclose(_unvec(rs.x), xt, rtol=1e-3, atol=1e-4)
        assert bool(rs.nc_found)

    @pytest.mark.parametrize("s", [1, 2])
    def test_flat_backend_matches_tree(self, s):
        M, b, x0 = _spd()
        rt = sstep_bicgstab(_mat_op(M), b, x0, lam=0.0, s=s, max_iters=40,
                            tol=1e-8)
        rf = sstep_bicgstab(_mat_op(M), b, x0, lam=0.0, s=s, max_iters=40,
                            tol=1e-8, backend=_flat_be(b))
        # same-cycle-or-adjacent convergence (reduction-order fp noise),
        # same solution — the invariant that matters
        assert abs(int(rt.iters) - int(rf.iters)) <= s
        np.testing.assert_allclose(_unvec(rt.x), _unvec(rf.x),
                                   rtol=1e-4, atol=1e-4)


class TestGramBreakdownFallback:
    """The conditioning guard fires on a degenerate monomial basis and the
    standard-solver fallback preserves correctness."""

    def _ill(self):
        dvals = np.logspace(0, 8, 14).astype(np.float32)
        rng = np.random.RandomState(2)
        return (jnp.asarray(np.diag(dvals)), dvals,
                _vec(rng.randn(14)), _vec(np.zeros(14)))

    @pytest.mark.parametrize("solver", [sstep_cg, sstep_bicgstab])
    def test_guard_triggers_without_fallback(self, solver):
        M, dvals, b, x0 = self._ill()
        rs = solver(_mat_op(M), b, x0, lam=0.0, s=8, max_iters=60, tol=1e-8,
                    fallback=False)
        assert bool(rs.breakdown)
        assert np.isfinite(_unvec(rs.x)).all()
        # frozen: the broken cycle must not have moved the iterate
        assert float(rs.residual) > 1.0

    def test_fallback_recovers_cg(self):
        M, dvals, b, x0 = self._ill()
        rs = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=8, max_iters=60, tol=1e-8,
                      fallback=True)
        rt = cg(_mat_op(M), b, x0, lam=0.0, max_iters=60, tol=1e-8)
        assert bool(rs.breakdown)
        # fallback == the standard solve (from the frozen x0 iterate)
        np.testing.assert_allclose(_unvec(rs.x), _unvec(rt.x),
                                   rtol=1e-5, atol=1e-6)

    def test_well_conditioned_does_not_fall_back(self):
        M, b, x0 = _spd()
        rs = sstep_cg(_mat_op(M), b, x0, lam=0.0, s=2, max_iters=40, tol=1e-8)
        assert not bool(rs.breakdown)


class TestHFStepSStep:
    """hf_step parity across s-step × both vector backends + training."""

    def _setup(self):
        model = build_mlp((8, 16, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 64, 8, 4)
        params = model.init(jax.random.PRNGKey(1))
        return model, data, params

    def _step_out(self, model, data, params, cfg):
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s, cfg=cfg: hf_step(
            model.loss_fn, p, s, data, data, cfg,
            model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
        return step(params, state)

    @pytest.mark.parametrize("solver,s", [("bicgstab", 2), ("gn_cg", 2)])
    def test_backend_parity(self, solver, s):
        model, data, params = self._setup()
        out = {}
        for backend in ("tree", "flat"):
            cfg = HFConfig(solver=solver, max_cg_iters=8, init_damping=5.0,
                           krylov_backend=backend, sstep_s=s)
            out[backend] = self._step_out(model, data, params, cfg)
        pt, _, mt = out["tree"]
        pf, _, mf = out["flat"]
        for a, b in zip(jax.tree_util.tree_leaves(pt),
                        jax.tree_util.tree_leaves(pf)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)
        assert int(mt["krylov_syncs"]) == int(mf["krylov_syncs"])
        assert int(mt["cg_iters"]) == int(mf["cg_iters"])

    @pytest.mark.slow
    @pytest.mark.parametrize("solver", ["bicgstab", "gn_cg", "hessian_cg",
                                        "hybrid_cg"])
    @pytest.mark.parametrize("s", [2, 4])
    @pytest.mark.parametrize("backend", ["tree", "flat"])
    def test_full_grid_runs_and_descends(self, solver, s, backend):
        model, data, params = self._setup()
        cfg = HFConfig(solver=solver, max_cg_iters=8, init_damping=5.0,
                       krylov_backend=backend, sstep_s=s)
        _, _, m = self._step_out(model, data, params, cfg)
        assert float(m["loss_new"]) < float(m["loss"])
        assert int(m["krylov_syncs"]) <= int(m["cg_iters"]) + 1

    def test_sstep_syncs_below_standard(self):
        model, data, params = self._setup()
        base = HFConfig(solver="bicgstab", max_cg_iters=8, init_damping=5.0)
        _, _, m_std = self._step_out(model, data, params, base)
        cfg = HFConfig(solver="bicgstab", max_cg_iters=8, init_damping=5.0,
                       sstep_s=4)
        _, _, m_ss = self._step_out(model, data, params, cfg)
        if not bool(m_ss["sstep_fallback"]):
            assert int(m_ss["krylov_syncs"]) < int(m_std["krylov_syncs"])
            assert int(m_ss["krylov_syncs"]) <= math.ceil(
                int(m_ss["cg_iters"]) / 4) + 1

    def test_sstep_trains(self):
        model, data, params = self._setup()
        cfg = HFConfig(solver="bicgstab", max_cg_iters=6, sstep_s=2)
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s: hf_step(
            model.loss_fn, p, s, data, data, cfg))
        losses = []
        for _ in range(6):
            params, state, m = step(params, state)
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.7 * losses[0]

    def test_forced_cg_recurrence_on_bicgstab_solver(self):
        model, data, params = self._setup()
        cfg = HFConfig(solver="bicgstab", max_cg_iters=8, init_damping=5.0,
                       sstep_s=2, sstep_solver="cg")
        _, _, m = self._step_out(model, data, params, cfg)
        assert float(m["loss_new"]) < float(m["loss"])


class TestConfigValidation:
    def test_bad_sstep_solver_raises(self):
        with pytest.raises(ValueError, match="sstep_solver"):
            HFConfig(sstep_solver="gmres")

    def test_precondition_with_sstep_raises(self):
        with pytest.raises(ValueError, match="precondition"):
            HFConfig(sstep_s=2, precondition=True)

    def test_optimizer_threading(self):
        from repro.configs.base import HFOptConfig
        from repro.optim import make_optimizer
        model = build_mlp((8, 12, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 32, 8, 4)
        params = model.init(jax.random.PRNGKey(1))
        opt = make_optimizer(
            HFOptConfig(name="bicgstab", max_cg_iters=4, sstep_s=2),
            model.loss_fn, model_out_fn=model.logits_fn,
            out_loss_fn=model.out_loss_fn,
        )
        state = opt.init(params)
        p2, _, m = jax.jit(opt.step)(params, state, data)
        assert "krylov_syncs" in m
        assert int(m["krylov_syncs"]) <= int(m["cg_iters"]) + 1


def _nan_op(M):
    """Curvature operator whose products are poisoned (NaN HVP/GNVP)."""
    inner = _mat_op(M)

    def op(v):
        return jax.tree_util.tree_map(lambda x: x * jnp.nan, inner(v))

    return op


class TestNonFiniteCurvatureBreakdown:
    """ISSUE 9 satellite: a NaN curvature product must surface as
    breakdown (basis degradation at worst), NEVER as convergence — IEEE
    comparisons with NaN are all False, so an unguarded ``res < tol``
    would silently freeze while an unguarded Gram solve would propagate
    NaN into the iterate."""

    @pytest.mark.parametrize("solver", [sstep_cg, sstep_bicgstab])
    @pytest.mark.parametrize("fallback", [False, True])
    def test_sstep_nan_op_breaks_down(self, solver, fallback):
        M, b, x0 = _spd()
        r = solver(_nan_op(M), b, x0, lam=0.0, s=2, max_iters=20, tol=1e-8,
                   fallback=fallback)
        assert bool(r.breakdown)
        # never reported as converged: residual is NaN or large, not < tol
        assert not bool(r.residual < 1e-8)
        # the iterate is frozen at the last finite point, not poisoned
        assert np.isfinite(_unvec(r.x)).all()

    @pytest.mark.parametrize("basis", ["newton", "chebyshev"])
    def test_nonmonomial_basis_nan_op_breaks_down(self, basis):
        M, b, x0 = _spd()
        r = sstep_cg(_nan_op(M), b, x0, lam=0.0, s=4, max_iters=20,
                     tol=1e-8, basis=basis, fallback=False)
        # the Gram guard catches the poisoned cycle (breakdown) whether or
        # not the basis monitor separately flags degradation
        assert bool(r.breakdown) or bool(r.basis_degraded)
        assert not bool(r.residual < 1e-8)
        assert np.isfinite(_unvec(r.x)).all()

    def test_nan_after_first_cycle_keeps_progress(self):
        # Poison only from the second operator application onward: the
        # first cycle's progress must survive the later breakdown.
        M, b, x0 = _spd()
        inner = _mat_op(M)
        calls = {"n": 0}

        def op(v):
            calls["n"] += 1  # trace-time count; poisons all but 1st trace
            bad = calls["n"] > 1
            return jax.tree_util.tree_map(
                lambda x: x * (jnp.nan if bad else 1.0), inner(v))

        r = sstep_cg(op, b, x0, lam=0.0, s=1, max_iters=20, tol=1e-10,
                     fallback=False)
        assert np.isfinite(_unvec(r.x)).all()
