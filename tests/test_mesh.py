"""launch/mesh.py regression tests — global-vs-local device discipline.

Under ``jax.distributed`` every process must build the SAME mesh over the
GLOBAL device list; a mesh built from ``jax.local_devices()`` silently
degenerates to per-process data parallelism with no cross-process
collectives. These tests pin the two guarantees launch/mesh.py makes:
``make_data_mesh`` spans all global devices, and ``make_production_mesh``
refuses (rather than mis-shapes) when the global device count does not
match the production topology.
"""
import jax
import pytest

from repro.launch.mesh import (batch_axes_if_divisible, data_axes,
                               make_data_mesh, make_production_mesh)


def test_data_mesh_spans_all_global_devices():
    mesh = make_data_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == len(jax.devices())
    assert set(mesh.devices.flat) == set(jax.devices())


def test_data_mesh_custom_axis_name():
    mesh = make_data_mesh(axis="dp")
    assert mesh.axis_names == ("dp",)
    assert data_axes(mesh) == ()  # "dp" is not a recognized data axis name


def test_production_mesh_rejects_wrong_global_device_count():
    # The test process sees 1 CPU device; the production shapes need
    # 256/512. The old behavior built a mesh from whatever was available —
    # exactly the local-devices degeneration the docstring warns about.
    for multi_pod in (False, True):
        with pytest.raises(ValueError, match="global devices"):
            make_production_mesh(multi_pod=multi_pod)


def test_data_mesh_batch_axes():
    mesh = make_data_mesh()
    axes = batch_axes_if_divisible(mesh, 8)
    assert axes == ("data",)
