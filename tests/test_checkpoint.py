"""Durable checkpointing (ISSUE 9 tentpole): atomic checksummed writes,
corruption detection, newest-valid fallback, manifest validation, and
step-deterministic resume of the full HF optimizer state."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptError,
    CheckpointMismatchError,
    all_steps,
    config_fingerprint,
    latest_step,
    latest_valid_step,
    restore_checkpoint,
    restore_latest_valid,
    save_checkpoint,
    valid_steps,
    verify_checkpoint,
)
from repro.core import HFConfig, hf_init, hf_step
from repro.data import classification_dataset
from repro.launch.faults import corrupt_file
from repro.models import build_mlp


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "w": jnp.asarray(rng.randn(4, 3).astype(np.float32)),
        "b": {"x": jnp.asarray(rng.randn(3).astype(np.float32))},
    }


def _like(t):
    return jax.tree_util.tree_map(jnp.zeros_like, t)


class TestRoundtrip:
    def test_bitwise_roundtrip_params_and_opt_state(self, tmp_path):
        params, opt = _tree(0), _tree(1)
        save_checkpoint(str(tmp_path), 7, params, opt, extra={"note": "t"})
        p2, o2, meta = restore_checkpoint(str(tmp_path), 7, _like(params),
                                          _like(opt))
        assert meta["step"] == 7 and meta["note"] == "t"
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(opt),
                        jax.tree_util.tree_leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_no_tmp_files_left_behind(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        leftovers = glob.glob(os.path.join(str(tmp_path), "*.tmp"))
        assert leftovers == []

    def test_verify_clean_checkpoint(self, tmp_path):
        path = save_checkpoint(str(tmp_path), 3, _tree(), fingerprint="abcd",
                               processes=2)
        manifest = verify_checkpoint(path)
        assert manifest["step"] == 3
        assert manifest["fingerprint"] == "abcd"
        assert manifest["processes"] == 2
        assert manifest["checksums"]  # one CRC per array


class TestCorruptionDetection:
    def test_bitflip_detected_by_checksum(self, tmp_path):
        path = save_checkpoint(str(tmp_path), 1, _tree())
        corrupt_file(path)
        with pytest.raises(CheckpointCorruptError):
            verify_checkpoint(path)

    def test_truncated_file_detected(self, tmp_path):
        path = save_checkpoint(str(tmp_path), 1, _tree())
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
        with pytest.raises(CheckpointCorruptError):
            verify_checkpoint(path)

    def test_missing_manifest_detected(self, tmp_path):
        # pre-durability (format v1) file: raw npz with no __manifest__
        path = os.path.join(str(tmp_path), "ckpt_00000001.npz")
        np.savez(path, **{"params/w": np.zeros(3, np.float32),
                          "__meta__": json.dumps({"step": 1})})
        with pytest.raises(CheckpointCorruptError, match="manifest"):
            verify_checkpoint(path)

    def test_valid_steps_skips_corrupt(self, tmp_path):
        for s in (1, 2, 3):
            save_checkpoint(str(tmp_path), s, _tree(s))
        corrupt_file(os.path.join(str(tmp_path), "ckpt_00000003.npz"))
        assert all_steps(str(tmp_path)) == [1, 2, 3]
        assert latest_step(str(tmp_path)) == 3
        assert valid_steps(str(tmp_path)) == [1, 2]
        assert latest_valid_step(str(tmp_path)) == 2


class TestNewestValidFallback:
    def test_restore_latest_valid_skips_corrupt_newest(self, tmp_path):
        params = _tree(0)
        for s in (1, 2, 3):
            save_checkpoint(str(tmp_path), s, _tree(s))
        corrupt_file(os.path.join(str(tmp_path), "ckpt_00000003.npz"))
        out = restore_latest_valid(str(tmp_path), _like(params))
        assert out is not None
        p2, opt, meta, step = out
        assert step == 2 and meta["step"] == 2 and opt is None
        for a, b in zip(jax.tree_util.tree_leaves(_tree(2)),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restore_latest_valid_empty_dir(self, tmp_path):
        assert restore_latest_valid(str(tmp_path), _like(_tree())) is None

    def test_all_corrupt_returns_none(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        corrupt_file(os.path.join(str(tmp_path), "ckpt_00000001.npz"))
        assert restore_latest_valid(str(tmp_path), _like(_tree())) is None


class TestManifestValidation:
    """Satellite 1: restore validates the manifest instead of trusting
    latest_step blindly."""

    def test_fingerprint_mismatch_raises(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree(), fingerprint="aaaa")
        with pytest.raises(CheckpointMismatchError, match="fingerprint"):
            restore_checkpoint(str(tmp_path), 1, _like(_tree()),
                               expect_fingerprint="bbbb")

    def test_process_count_mismatch_raises(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree(), processes=2)
        with pytest.raises(CheckpointMismatchError, match="process"):
            restore_checkpoint(str(tmp_path), 1, _like(_tree()),
                               expect_processes=4)

    def test_matching_manifest_restores(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree(), fingerprint="aaaa",
                        processes=2)
        restore_checkpoint(str(tmp_path), 1, _like(_tree()),
                           expect_fingerprint="aaaa", expect_processes=2)

    def test_latest_valid_does_not_skip_mismatch(self, tmp_path):
        # A corrupt file is skipped; a MISMATCHED valid file is an
        # operator error and must raise, not silently fall back.
        save_checkpoint(str(tmp_path), 1, _tree(), fingerprint="aaaa")
        save_checkpoint(str(tmp_path), 2, _tree(), fingerprint="aaaa")
        with pytest.raises(CheckpointMismatchError):
            restore_latest_valid(str(tmp_path), _like(_tree()),
                                 expect_fingerprint="bbbb")

    def test_structure_mismatch_raises(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        # extra leaf the saved tree never had
        with pytest.raises(CheckpointMismatchError, match="structure|leaf"):
            restore_checkpoint(str(tmp_path), 1,
                               {"w": jnp.zeros((4, 3)), "b": {"x": jnp.zeros(3)},
                                "extra": jnp.zeros(2)})
        # shape mismatch on an existing leaf
        with pytest.raises(CheckpointMismatchError, match="shape"):
            restore_checkpoint(str(tmp_path), 1,
                               {"w": jnp.zeros((2, 2), jnp.float32),
                                "b": {"x": jnp.zeros(3, jnp.float32)}})


class TestConfigFingerprint:
    def test_stable_across_dict_order(self):
        a = config_fingerprint({"x": 1, "y": [1, 2], "z": {"k": True}})
        b = config_fingerprint({"z": {"k": True}, "y": [1, 2], "x": 1})
        assert a == b and len(a) == 16

    def test_dataclass_fields_covered(self):
        a = config_fingerprint(HFConfig(solver="gn_cg"))
        b = config_fingerprint(HFConfig(solver="bicgstab"))
        c = config_fingerprint(HFConfig(solver="gn_cg"))
        assert a != b and a == c


class TestResumeDeterminism:
    """Full HF state checkpointing makes resume step-deterministic: run
    4 steps straight vs 2 + checkpoint + restore + 2 — bitwise-identical
    params (λ, warm-start δ, step counter all restored)."""

    def test_resume_matches_uninterrupted(self, tmp_path):
        model = build_mlp((8, 16, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 32, 8, 4)
        params0 = model.init(jax.random.PRNGKey(1))
        cfg = HFConfig(solver="gn_cg", max_cg_iters=4)
        step = jax.jit(lambda p, s: hf_step(
            model.loss_fn, p, s, data, data, cfg,
            model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))

        p, s = params0, hf_init(params0, cfg)
        for _ in range(4):
            p, s, _ = step(p, s)

        q, t = params0, hf_init(params0, cfg)
        for _ in range(2):
            q, t, _ = step(q, t)
        save_checkpoint(str(tmp_path), 2, q, t, fingerprint="f",
                        processes=1)
        q2, t2, _ = restore_checkpoint(str(tmp_path), 2, _like(q), _like(t),
                                       expect_fingerprint="f",
                                       expect_processes=1)
        for _ in range(2):
            q2, t2, _ = step(q2, t2)

        for a, b in zip(jax.tree_util.tree_leaves(p),
                        jax.tree_util.tree_leaves(q2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
