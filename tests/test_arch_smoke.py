"""Per-architecture smoke tests: reduced variant of each assigned family
(2 layers, d_model<=512, <=4 experts) — one forward + one HF train step on
CPU, asserting output shapes and no NaNs; plus prefill/decode consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import HFConfig, hf_init, hf_step
from repro.data import lm_batch
from repro.models import build_model

# Full-architecture sweep (forward + HF step per family) is several minutes
# of jit compiles — out of the tier-1 budget. Core hf_step coverage stays in
# tier-1 via test_system / test_krylov_backends / test_preconditioner.
pytestmark = pytest.mark.slow

B, S = 2, 32


def _setup(arch_id):
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, B, S)
    return cfg, model, params, batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finite(arch_id):
    cfg, model, params, batch = _setup(arch_id)
    logits = model.logits_fn(params, batch)
    assert logits.shape == batch["targets"].shape + (cfg.padded_vocab,)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # a random model should sit near uniform CE
    assert float(loss) < jnp.log(cfg.padded_vocab) * 2


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_one_hf_train_step(arch_id):
    cfg, model, params, batch = _setup(arch_id)
    hf_cfg = HFConfig(solver="bicgstab", max_cg_iters=3, max_backtracks=4)
    state = hf_init(params, hf_cfg)
    new_params, new_state, metrics = jax.jit(
        lambda p, s, b: hf_step(model.loss_fn, p, s, b, b, hf_cfg)
    )(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["loss_new"]))
    assert float(metrics["loss_new"]) <= float(metrics["loss"]) + 1e-5
    for a, b_ in zip(jax.tree_util.tree_leaves(new_params), jax.tree_util.tree_leaves(params)):
        assert a.shape == b_.shape
        assert bool(jnp.all(jnp.isfinite(a)))


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS if a != "whisper-small"])
def test_prefill_decode_consistency(arch_id):
    """decode_step after prefill(S-1 tokens) must reproduce the full-seq
    logits at the last position (numerics: fp32 small models, tol 2e-2).

    MoE archs are checked with a no-drop capacity factor (E/k): capacity
    dropping is a *train-time* semantic — decode groups are single tokens and
    never drop, so equivalence only holds in the no-drop regime."""
    cfg, model, params, batch = _setup(arch_id)
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts) / cfg.top_k + 1.0)
        from repro.models import build_model as _bm
        model = _bm(cfg)
    full = model.logits_fn(params, batch)                  # (B, S_text, V)
    s_text = batch["tokens"].shape[1]
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, : s_text - 1]
    _, cache = model.prefill(params, pre_batch, max_len=S + 8)
    t = jnp.asarray(s_text - 1 + (cfg.n_vision_tokens if cfg.family == "vlm" else 0))
    logits, _ = model.decode_step(params, batch["tokens"][:, -1:], t, cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full[:, -1]), rtol=0.05, atol=2e-2
    )


def test_whisper_prefill_decode_consistency():
    cfg, model, params, batch = _setup("whisper-small")
    full = model.logits_fn(params, batch)
    s = batch["tokens"].shape[1]
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, : s - 1]
    _, cache = model.prefill(params, pre_batch, max_len=S + 8)
    logits, _ = model.decode_step(params, batch["tokens"][:, -1:], jnp.asarray(s - 1), cache)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full[:, -1]), rtol=0.05, atol=2e-2
    )


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_count_analytic_close(arch_id):
    """Analytic param_count stays within 10% of the real tree (sanity for
    roofline MODEL_FLOPS)."""
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    real = sum(x.size for x in jax.tree_util.tree_leaves(params))
    est = cfg.param_count()
    assert abs(est - real) / real < 0.15, (est, real)
