"""Chunked cross-entropy == full-logit cross-entropy (values, grads, HVPs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import make_hvp
from repro.core.tree_math import tree_dot, tree_random_like
from repro.data import lm_batch
from repro.models import build_model

pytestmark = pytest.mark.slow  # grad+HVP through full LM stacks: ~10s/case


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "granite-moe-1b-a400m"])
@pytest.mark.parametrize("chunk", [64, 256])
def test_chunked_ce_matches_full(arch, chunk):
    cfg = get_smoke_config(arch)
    model_full = build_model(cfg)
    model_chunk = build_model(cfg.replace(ce_chunk=chunk))
    params = model_full.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 2, 16)

    l_full = float(model_full.loss_fn(params, batch))
    l_chunk = float(model_chunk.loss_fn(params, batch))
    np.testing.assert_allclose(l_chunk, l_full, rtol=1e-5)

    g_full = jax.grad(model_full.loss_fn)(params, batch)
    g_chunk = jax.grad(model_chunk.loss_fn)(params, batch)
    for a, b in zip(jax.tree_util.tree_leaves(g_full), jax.tree_util.tree_leaves(g_chunk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-5)

    v = tree_random_like(jax.random.PRNGKey(2), params)
    hv_full = make_hvp(model_full.loss_fn, params, batch)(v)
    hv_chunk = make_hvp(model_chunk.loss_fn, params, batch)(v)
    num = float(tree_dot(hv_full, hv_chunk))
    den = float(tree_dot(hv_full, hv_full)) ** 0.5 * float(tree_dot(hv_chunk, hv_chunk)) ** 0.5
    assert num / max(den, 1e-12) > 0.9999
