"""Paper §3 communication-model sanity checks."""
import math

from benchmarks.comm_model import (
    dp_floats_per_epoch,
    dp_syncs_per_epoch,
    hf_syncs_per_iteration,
    model_size,
    mp_syncs_per_epoch,
    sgd_syncs_per_epoch,
    speedup_model,
)


def test_sgd_syncs_dominate_hf():
    """Paper's core systems claim: per epoch, data-parallel SGD needs
    n/(N·b)·2 reduces while HF needs ~1 + K + E."""
    n, b, N = 60000, 64, 16
    sgd = sgd_syncs_per_epoch(n, b, N)
    hf = hf_syncs_per_iteration(cg_iters=10, ls_evals=3)
    assert sgd / hf > 50  # order(s) of magnitude


def test_model_parallel_syncs_exceed_data_parallel():
    n, b, layers = 60000, 64, 4
    assert mp_syncs_per_epoch(n, b, layers) > dp_syncs_per_epoch(n, b)


def test_larger_batch_fewer_syncs():
    assert dp_syncs_per_epoch(60000, 1024) < dp_syncs_per_epoch(60000, 64)


def test_model_size_mnist():
    assert model_size((784, 400, 10)) == 784 * 400 + 400 + 400 * 10 + 10


def test_speedup_monotone_for_compute_bound():
    sp = [speedup_model(N, compute_s_per_node_unit=10.0, bytes_per_sync=4e6,
                        syncs=14) for N in (1, 2, 4, 8, 16)]
    assert all(b > a for a, b in zip(sp, sp[1:]))


def test_speedup_saturates_for_comm_bound():
    """Tiny compute + many syncs (small batch): speedup flattens, the paper's
    'small batch is the primary bottleneck for scaling'."""
    sp32 = speedup_model(32, compute_s_per_node_unit=0.01, bytes_per_sync=4e6,
                         syncs=1000)
    assert sp32 < 2.0
