"""Paper §3 communication-model sanity checks."""
import math

from benchmarks.comm_model import (
    dp_floats_per_epoch,
    dp_syncs_per_epoch,
    hf_floats_per_iteration,
    hf_sstep_floats_per_iteration,
    hf_sstep_syncs_per_iteration,
    hf_syncs_per_iteration,
    model_size,
    mp_syncs_per_epoch,
    sgd_syncs_per_epoch,
    speedup_model,
    sstep_basis_len,
    sstep_bootstrap,
)


def test_sgd_syncs_dominate_hf():
    """Paper's core systems claim: per epoch, data-parallel SGD needs
    n/(N·b)·2 reduces while HF needs ~1 + K + E."""
    n, b, N = 60000, 64, 16
    sgd = sgd_syncs_per_epoch(n, b, N)
    hf = hf_syncs_per_iteration(cg_iters=10, ls_evals=3)
    assert sgd / hf > 50  # order(s) of magnitude


def test_model_parallel_syncs_exceed_data_parallel():
    n, b, layers = 60000, 64, 4
    assert mp_syncs_per_epoch(n, b, layers) > dp_syncs_per_epoch(n, b)


def test_larger_batch_fewer_syncs():
    assert dp_syncs_per_epoch(60000, 1024) < dp_syncs_per_epoch(60000, 64)


def test_model_size_mnist():
    assert model_size((784, 400, 10)) == 784 * 400 + 400 + 400 * 10 + 10


def test_speedup_monotone_for_compute_bound():
    sp = [speedup_model(N, compute_s_per_node_unit=10.0, bytes_per_sync=4e6,
                        syncs=14) for N in (1, 2, 4, 8, 16)]
    assert all(b > a for a, b in zip(sp, sp[1:]))


def test_speedup_saturates_for_comm_bound():
    """Tiny compute + many syncs (small batch): speedup flattens, the paper's
    'small batch is the primary bottleneck for scaling'."""
    sp32 = speedup_model(32, compute_s_per_node_unit=0.01, bytes_per_sync=4e6,
                         syncs=1000)
    assert sp32 < 2.0


class TestSStepModel:
    """s-step (communication-avoiding) HF formulas — core/sstep.py's
    1 + ceil(K/s) + E sync schedule."""

    def test_syncs_drop_from_K_to_ceil_K_over_s(self):
        K, E = 10, 3
        assert hf_syncs_per_iteration(K, E) == 1 + K + E
        assert hf_sstep_syncs_per_iteration(K, E, 1) == 1 + K + E
        assert hf_sstep_syncs_per_iteration(K, E, 2) == 1 + 5 + E
        assert hf_sstep_syncs_per_iteration(K, E, 4) == 1 + math.ceil(10 / 4) + E
        assert hf_sstep_syncs_per_iteration(K, E, 16) == 1 + 1 + E

    def test_syncs_monotone_nonincreasing_in_s(self):
        vals = [hf_sstep_syncs_per_iteration(16, 2, s) for s in (1, 2, 4, 8, 16)]
        assert all(b <= a for a, b in zip(vals, vals[1:]))

    def test_sstep_floats_trade_bytes_for_syncs(self):
        """Each cycle grows both power chains: 2s−1 model-sized products per
        s iterations (vs s standard) plus a small Gram — asymptotically ~2×
        the bytes, for s× fewer blocking syncs."""
        dims, K, E = (784, 400, 150, 10), 16, 2
        std = hf_floats_per_iteration(dims, K, E)
        m = model_size(dims)
        for s in (2, 4):
            ss = hf_sstep_floats_per_iteration(dims, K, E, s)
            cycles = math.ceil(K / s)
            assert ss > std            # more bytes ...
            assert ss < 2.0 * std      # ... bounded by the ~2x chain factor
            # exact product count: 1 gradient + (2s-1) per cycle
            expected_products = (1 + cycles * (2 * s - 1)) * m
            assert abs(ss - expected_products) < 0.01 * std  # + Gram only

    def test_sstep_floats_s1_reduces_to_standard_plus_gram(self):
        dims, K, E = (784, 400, 150, 10), 16, 2
        std = hf_floats_per_iteration(dims, K, E)
        ss = hf_sstep_floats_per_iteration(dims, K, E, 1)
        gram = K * sstep_basis_len(1, "cg") ** 2  # one 3x3 Gram per cycle
        assert ss == std + gram

    def test_basis_len(self):
        # CG: [p..A^s p, r..A^{s-1}r] ⇒ 2s+1; Bi-CG-STAB: depth-2s chains
        assert sstep_basis_len(4, "cg") == 9
        assert sstep_basis_len(4, "bicgstab") == 17
        assert sstep_basis_len(1, "cg") == 3

    def test_sstep_executed_counts_match_model(self):
        """The formula's ceil(K/s) bound holds for the EXECUTED sync counts
        of an actual s-step solve (KrylovResult.syncs)."""
        import jax.numpy as jnp
        import numpy as np
        from repro.core.sstep import sstep_cg

        rng = np.random.RandomState(0)
        Q = rng.randn(20, 20).astype(np.float32)
        M = jnp.asarray(Q @ Q.T + 20 * np.eye(20, dtype=np.float32))
        b = {"v": jnp.asarray(rng.randn(20).astype(np.float32))}
        x0 = {"v": jnp.zeros(20, jnp.float32)}
        op = lambda t: {"v": M @ t["v"]}
        for s in (2, 4):
            res = sstep_cg(op, b, x0, lam=0.0, s=s, max_iters=16, tol=1e-10)
            assert not bool(res.breakdown)
            K_exec = int(res.iters)
            assert int(res.syncs) <= math.ceil(16 / s)
            assert int(res.syncs) == math.ceil(K_exec / s)


class TestSStepBasisModel:
    """Newton/Chebyshev-basis schedule: bootstrap cycles + doubled s
    (core/sstep.py, §Perf pair G)."""

    def test_monomial_default_unchanged(self):
        assert hf_sstep_syncs_per_iteration(16, 2, 4) == 1 + 4 + 2
        assert (hf_sstep_syncs_per_iteration(16, 2, 4, basis="monomial")
                == hf_sstep_syncs_per_iteration(16, 2, 4))
        assert sstep_bootstrap(8, "cg", "monomial") == (0, 0)

    def test_bootstrap_shape(self):
        # CG: f32-safe depth 4 ⇒ ceil(s/4) cycles covering ≥ s iterations
        assert sstep_bootstrap(8, "cg", "newton") == (2, 8)
        assert sstep_bootstrap(4, "cg", "chebyshev") == (1, 4)
        # Bi-CG-STAB: 2-deep budget + one margin cycle
        assert sstep_bootstrap(4, "bicgstab", "newton") == (3, 6)

    def test_adaptive_beats_monomial_best_at_doubled_s(self):
        """The headline schedule: CG s=8 newton under the monomial-best
        usable depth (s=4), Bi-CG-STAB s=4 under monomial s=2 — despite
        paying for the bootstrap Grams."""
        K, E = 16, 2
        cg8 = hf_sstep_syncs_per_iteration(K, E, 8, solver="cg",
                                           basis="newton")
        assert cg8 == 1 + 2 + math.ceil((16 - 8) / 8) + E == 6
        assert cg8 < hf_sstep_syncs_per_iteration(K, E, 4)      # mono s=4
        bi4 = hf_sstep_syncs_per_iteration(K, E, 4, solver="bicgstab",
                                           basis="chebyshev")
        assert bi4 == 1 + 3 + math.ceil((16 - 6) / 4) + E == 9
        assert bi4 < hf_sstep_syncs_per_iteration(K, E, 2)      # mono s=2

    def test_adaptive_floats_bounded(self):
        """Bootstrap chains are shallower, so the adaptive bases cost at
        most the ~2× monomial chain factor in model-sized traffic."""
        dims, K, E = (784, 400, 150, 10), 32, 2
        std = hf_floats_per_iteration(dims, K, E)
        nb = hf_sstep_floats_per_iteration(dims, K, E, 8, solver="cg",
                                           basis="newton")
        assert std < nb < 2.1 * std

    def test_executed_adaptive_counts_within_bound(self):
        """Executed sync counts of a real Newton-basis solve respect the
        basis-aware bound (bootstraps + full-depth cycles)."""
        import jax.numpy as jnp
        import numpy as np
        from repro.core.sstep import sstep_cg

        rng = np.random.RandomState(2)
        U, _ = np.linalg.qr(rng.randn(30, 30))
        d = np.concatenate([1.0 + 0.1 * np.arange(20),
                            np.linspace(5, 100, 10)]).astype(np.float32)
        M = jnp.asarray(((U * d) @ U.T).astype(np.float32))
        b = {"v": jnp.asarray(rng.randn(30).astype(np.float32))}
        x0 = {"v": jnp.zeros(30, jnp.float32)}
        op = lambda t: {"v": M @ t["v"]}
        K = 24
        res = sstep_cg(op, b, x0, lam=0.0, s=8, max_iters=K, tol=1e-6,
                       basis="newton")
        assert not bool(res.breakdown)
        bound = hf_sstep_syncs_per_iteration(K, 0, 8, solver="cg",
                                             basis="newton") - 1
        assert int(res.syncs) <= bound


class TestOverlapModel:
    """Overlapped-schedule formulas (``overlap=True`` — HFConfig.overlap):
    double-buffered cycles, hidden grad-reduce, paired line search. The
    executed counterparts are asserted by tests/test_overlap.py and
    benchmarks/fig5_scaling.py --executed."""

    def test_blocking_syncs_formula(self):
        K, E = 16, 3
        # s=4 overlap: cycles at stride 8, no gradient term, paired search.
        assert hf_sstep_syncs_per_iteration(K, E, 4, overlap=True) == \
            math.ceil(K / 8) + math.ceil(E / 2) == 4
        assert hf_sstep_syncs_per_iteration(K, E, 4) == 1 + 4 + E

    def test_overlap_strictly_fewer_blocking_syncs(self):
        # Bi-CG-STAB/newton stops at s=4: its bootstrap cycle count grows
        # with the doubled effective stride (ceil(2s/s_boot)+1), so at
        # extreme s the overlap schedule is bootstrap-dominated and the
        # saving inverts — overlap is a small-to-moderate-s tool there.
        K, E = 16, 2
        for solver, basis, s_range in (("cg", "monomial", (2, 4, 8)),
                                       ("cg", "newton", (2, 4, 8)),
                                       ("bicgstab", "newton", (2, 4))):
            for s in s_range:
                ov = hf_sstep_syncs_per_iteration(K, E, s, solver=solver,
                                                  basis=basis, overlap=True)
                base = hf_sstep_syncs_per_iteration(K, E, s, solver=solver,
                                                    basis=basis)
                assert ov < base, (s, solver, basis, ov, base)

    def test_s1_keeps_standard_krylov_term(self):
        # s-step only engages for s > 1 (core/hf.py): at s=1 the standard
        # solver's K per-iteration round-trips remain; overlap saves only
        # the gradient (hidden) and line-search (paired) terms.
        K, E = 10, 3
        assert hf_sstep_syncs_per_iteration(K, E, 1, overlap=True) == \
            K + math.ceil(E / 2)

    def test_bootstrap_runs_at_doubled_stride(self):
        # Double-buffered cycles bootstrap at the EFFECTIVE stride 2s.
        K, E, s = 32, 2, 4
        n_boot, covered = sstep_bootstrap(2 * s, "cg", "newton")
        expect = n_boot + math.ceil((K - covered) / (2 * s)) + 1
        assert hf_sstep_syncs_per_iteration(K, E, s, basis="newton",
                                            overlap=True) == expect

    def test_overlap_floats_hidden_not_removed(self):
        """Overlap hides reduces behind compute; the bytes still flow. The
        paired search can only ADD (one speculative eval on odd E); the
        model-sized chain traffic stays within the ~2x envelope."""
        dims, K, s = (784, 400, 150, 10), 16, 4
        for E in (2, 3):
            ov = hf_sstep_floats_per_iteration(dims, K, E, s, overlap=True)
            base = hf_sstep_floats_per_iteration(dims, K, E, s)
            std = hf_floats_per_iteration(dims, K, E)
            assert ov >= std
            assert ov < 2.1 * std
            # ... and never fewer total floats than the non-overlapped
            # schedule minus rounding (hidden ≠ removed).
            assert ov >= base - 1

    def test_overlap_floats_paired_ls_rounds_up(self):
        dims, K, s = (784, 400, 150, 10), 16, 1
        # s=1: identical chains either way; only the line-search scalars
        # differ — 2*ceil(E/2) paired vs E serial.
        for E in (1, 2, 3, 4):
            ov = hf_sstep_floats_per_iteration(dims, K, E, s, overlap=True)
            base = hf_sstep_floats_per_iteration(dims, K, E, s)
            assert ov - base == 2 * math.ceil(E / 2) - E
