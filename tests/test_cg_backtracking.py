"""Free CG-backtracking: the solver returns the best-model iterate."""
import jax.numpy as jnp
import numpy as np

from repro.core import bicgstab, cg
from repro.core.tree_math import tree_dot


def _vec(x):
    return {"x": jnp.asarray(x, jnp.float32)}


def _mat_op(M):
    return lambda v: {"x": M @ v["x"]}


def _phi(M, b, x):
    return 0.5 * float(x["x"] @ (M @ x["x"])) - float(b["x"] @ x["x"])


def test_bicgstab_returns_best_model_iterate_indefinite():
    """On an indefinite system Bi-CG-STAB's φ trajectory is non-monotone;
    the returned iterate must have φ ≤ φ of every truncation point we can
    reach by capping iterations."""
    rng = np.random.RandomState(7)
    d = np.concatenate([np.linspace(0.5, 4.0, 12), [-1.0, -0.3]]).astype(np.float32)
    M = jnp.diag(jnp.asarray(d))
    b = _vec(rng.randn(14))
    phis = []
    phis_final = []
    for iters in range(1, 12):
        res = bicgstab(_mat_op(M), b, _vec(np.zeros(14)), lam=0.0,
                       max_iters=iters, tol=1e-12)
        phis.append(_phi(M, b, res.x_best))
        phis_final.append(_phi(M, b, res.x))
    # best-so-far property: φ of x_best is non-increasing in budget
    assert all(b2 <= a2 + 1e-4 for a2, b2 in zip(phis, phis[1:])), phis
    # and dominates the final iterate at every budget
    assert all(pb <= pf + 1e-4 for pb, pf in zip(phis, phis_final))


def test_residual_consistent_with_returned_iterate():
    rng = np.random.RandomState(0)
    Q = rng.randn(10, 10).astype(np.float32)
    M = jnp.asarray(Q @ Q.T + 10 * np.eye(10, dtype=np.float32))
    b = _vec(rng.randn(10))
    res = bicgstab(_mat_op(M), b, _vec(np.zeros(10)), lam=0.0, max_iters=40, tol=1e-8)
    r_check = np.asarray(b["x"]) - np.asarray(M @ res.x["x"])
    np.testing.assert_allclose(np.asarray(res.r["x"]), r_check, rtol=1e-3, atol=1e-4)


def test_cg_best_equals_last_on_spd():
    """CG minimizes φ over the growing Krylov space: best == last."""
    rng = np.random.RandomState(1)
    Q = rng.randn(8, 8).astype(np.float32)
    M = jnp.asarray(Q @ Q.T + 8 * np.eye(8, dtype=np.float32))
    b = _vec(rng.randn(8))
    res = cg(_mat_op(M), b, _vec(np.zeros(8)), lam=0.0, max_iters=50, tol=1e-8)
    np.testing.assert_allclose(
        np.asarray(res.x["x"]), np.linalg.solve(np.asarray(M), b["x"]),
        rtol=1e-3, atol=1e-4)
