"""Distributed-equivalence tests.

Run in a SUBPROCESS with 8 fake host devices (XLA_FLAGS must be set before
jax initializes, and the main test process must keep its 1-device view).
Checks:
  * shard_map data-parallel HF step == single-process hf_step (bitwise-ish)
  * the HLO of the shard_map step contains exactly the paper's collective
    schedule (all-reduces for grad + HVPs + line-search, nothing else)
  * sharding rules produce valid, divisible PartitionSpecs for every arch
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import HFConfig, hf_init, hf_step
    from repro.core.distributed import data_parallel_hf_step
    from repro.data import classification_dataset
    from repro.models import build_mlp

    model = build_mlp((16, 32, 4))
    data = classification_dataset(jax.random.PRNGKey(0), 256, 16, 4)
    params = model.init(jax.random.PRNGKey(1))
    mesh = jax.make_mesh((8,), ("data",))

    # --- stable solver (GN-CG, SPD system): tight equivalence --------------
    cfg = HFConfig(solver="gn_cg", max_cg_iters=5, krylov_jitter=0.0)
    state = hf_init(params, cfg)
    ref_p, _, ref_m = jax.jit(
        lambda p, s: hf_step(model.loss_fn, p, s, data, data, cfg,
                             model_out_fn=model.logits_fn,
                             out_loss_fn=model.out_loss_fn)
    )(params, state)
    step = data_parallel_hf_step(model.loss_fn, mesh, cfg, data_axes=("data",),
                                 model_out_fn=model.logits_fn,
                                 out_loss_fn=model.out_loss_fn)
    dp_p, _, dp_m = jax.jit(step)(params, state, data)
    for a, b in zip(jax.tree_util.tree_leaves(ref_p), jax.tree_util.tree_leaves(dp_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(float(ref_m["loss"]), float(dp_m["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(ref_m["grad_norm"]), float(dp_m["grad_norm"]), rtol=1e-4)

    # --- bicgstab: grad/loss exact; the indefinite Krylov recurrence
    # chaotically amplifies reduction-order fp noise, so directions are only
    # statistically equivalent — assert the operator-level quantities.
    cfg = HFConfig(solver="bicgstab", max_cg_iters=5, krylov_jitter=0.0)
    state = hf_init(params, cfg)
    _, _, ref_m = jax.jit(
        lambda p, s: hf_step(model.loss_fn, p, s, data, data, cfg)
    )(params, state)
    step = data_parallel_hf_step(model.loss_fn, mesh, cfg, data_axes=("data",))
    jstep = jax.jit(step)
    dp_p, _, dp_m = jstep(params, state, data)
    np.testing.assert_allclose(float(ref_m["loss"]), float(dp_m["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(ref_m["grad_norm"]), float(dp_m["grad_norm"]), rtol=1e-4)
    assert float(dp_m["loss_new"]) <= float(dp_m["loss"])  # still a descent step

    # collective schedule: only all-reduces (psum/pmean), no all-gathers of
    # model state — the paper's pure data-parallel pattern.
    hlo = jstep.lower(params, state, data).compile().as_text()
    n_ar = hlo.count(" all-reduce(") + hlo.count(" all-reduce-start(")
    assert n_ar >= 1, "expected all-reduces in the schedule"
    assert " all-to-all(" not in hlo
    print("OK", n_ar)
""")


@pytest.mark.slow  # subprocess with 8 fake devices + full HF jit: ~17s
def test_shard_map_hf_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


SHARDING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    from repro.configs import ARCH_IDS, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import param_specs
    from repro.models import build_model

    mesh = make_production_mesh(multi_pod=True)
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        p = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = param_specs(p, cfg, mesh, fsdp=True)
        flat_p = jax.tree_util.tree_leaves_with_path(p)
        flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        n_sharded = 0
        for (path, leaf), spec in zip(flat_p, flat_s):
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert leaf.shape[dim] % size == 0, (arch, path, leaf.shape, spec)
                n_sharded += 1
        assert n_sharded > 0, arch
        print("OK", arch, n_sharded)
""")


def test_sharding_rules_divisible_all_archs():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SHARDING_SCRIPT], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count("OK") == 10
