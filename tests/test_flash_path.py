"""The flash-attention model path (cfg.use_flash_attention) must match the
jnp `_sdpa` path (kernel in interpret mode on CPU) — forward, gradient,
JVP, and through the whole HF step.

Fast tier: one GQA-causal config end-to-end (prefill, grad, jvp, curvature
products) plus the S=130 pad-and-mask regression. The full grid — sliding
window, non-causal encoder, every curvature_mode x Krylov backend, gn_cg —
is ``slow``-marked (CI keeps it collectable; run with ``-m slow``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import HFConfig, hf_init, hf_step
from repro.core.curvature import make_gnvp_op, make_hvp_op
from repro.data import lm_batch
from repro.models import build_model


def _tiny(arch="qwen2-1.5b", **kw):
    cfg = get_smoke_config(arch)
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=64)
    return cfg.replace(
        n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2 if not cfg.is_encoder_decoder else 4,
        d_ff=128, vocab_size=256, **kw)


def _pair(cfg):
    """(jnp model, flash model) sharing params."""
    mj = build_model(cfg)
    mf = build_model(cfg.replace(use_flash_attention=True))
    return mj, mf, mj.init(jax.random.PRNGKey(0))


def _assert_trees_close(a, b, rtol, atol):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ------------------------------------------------------------- prefill ----
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mixtral-8x22b"])
def test_flash_prefill_matches_jnp(arch):
    cfg = get_smoke_config(arch)
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=64)
    model_jnp = build_model(cfg)
    model_fa = build_model(cfg.replace(use_flash_attention=True))
    params = model_jnp.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 2, 256)  # block-aligned S
    logits_jnp, cache_jnp = model_jnp.prefill(params, batch, max_len=256)
    logits_fa, cache_fa = model_fa.prefill(params, batch, max_len=256)
    np.testing.assert_allclose(
        np.asarray(logits_fa), np.asarray(logits_jnp), rtol=2e-3, atol=2e-3
    )
    for a, b in zip(jax.tree_util.tree_leaves(cache_jnp), jax.tree_util.tree_leaves(cache_fa)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_flash_prefill_s130_pad_and_mask():
    """Non-block-aligned S no longer falls back to `_sdpa`: the kernel pads
    to the 128 tile, masks the tail, and slices — regression for the old
    silent ``S % 128 == 0`` gate."""
    cfg = _tiny()
    model_jnp, model_fa, params = _pair(cfg)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 2, 130)
    logits_jnp, cache_jnp = model_jnp.prefill(params, batch, max_len=130)
    logits_fa, cache_fa = model_fa.prefill(params, batch, max_len=130)
    np.testing.assert_allclose(
        np.asarray(logits_fa), np.asarray(logits_jnp), rtol=2e-3, atol=2e-3
    )
    for a, b in zip(jax.tree_util.tree_leaves(cache_jnp), jax.tree_util.tree_leaves(cache_fa)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- grad/jvp parity --
def _grad_parity(cfg, B=2, S=64):
    model_jnp, model_fa, params = _pair(cfg)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, B, S)
    f_j, g_j = jax.value_and_grad(model_jnp.loss_fn)(params, batch)
    f_f, g_f = jax.value_and_grad(model_fa.loss_fn)(params, batch)
    np.testing.assert_allclose(float(f_f), float(f_j), rtol=1e-5, atol=1e-5)
    _assert_trees_close(g_f, g_j, rtol=1e-3, atol=1e-4)


def _jvp_parity(cfg, B=2, S=64):
    model_jnp, model_fa, params = _pair(cfg)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, B, S)
    tan = jax.tree_util.tree_map(
        lambda p: jnp.cos(jnp.arange(p.size, dtype=jnp.float32)
                          ).reshape(p.shape).astype(p.dtype), params)
    _, tj = jax.jvp(lambda p: model_jnp.loss_fn(p, batch), (params,), (tan,))
    _, tf = jax.jvp(lambda p: model_fa.loss_fn(p, batch), (params,), (tan,))
    np.testing.assert_allclose(float(tf), float(tj), rtol=1e-4, atol=1e-4)


def test_flash_grad_parity_gqa_causal():
    _grad_parity(_tiny())


def test_flash_jvp_parity_gqa_causal():
    _jvp_parity(_tiny())


def test_flash_grad_parity_s130():
    _grad_parity(_tiny(), S=130)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["mixtral-8x22b", "whisper-small"])
def test_flash_grad_parity_grid(arch):
    # mixtral: sliding window (64) + MoE; whisper: non-causal encoder +
    # causal decoder + (jnp-path) cross attention
    cfg = _tiny(arch) if arch != "whisper-small" else get_smoke_config(arch)
    _grad_parity(cfg)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["mixtral-8x22b", "whisper-small"])
def test_flash_jvp_parity_grid(arch):
    cfg = _tiny(arch) if arch != "whisper-small" else get_smoke_config(arch)
    _jvp_parity(cfg)


# ------------------------------------------------- curvature products -----
def _models_and_batch(S=32):
    cfg = _tiny()
    model_jnp, model_fa, params = _pair(cfg)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 2, S)
    tan = jax.tree_util.tree_map(
        lambda p: jnp.sin(jnp.arange(p.size, dtype=jnp.float32)
                          ).reshape(p.shape).astype(jnp.float32), params)
    return model_jnp, model_fa, params, batch, tan


@pytest.mark.parametrize("mode", ["naive", "linearize", "chunked"])
def test_flash_hvp_product_matches_jnp(mode, S=32):
    """The exact-Hessian product through the flash path (jax.linearize /
    jvp-of-grad through the attention kernels' second-order rule) matches
    the jnp path to 1e-4 — the quantity every Krylov iteration consumes."""
    model_jnp, model_fa, params, batch, tan = _models_and_batch(S)
    kw = dict(mode=mode, chunk_size=1 if mode == "chunked" else 0)
    hj = make_hvp_op(model_jnp.loss_fn, params, batch, **kw)(tan)
    hf = make_hvp_op(model_fa.loss_fn, params, batch, **kw)(tan)
    _assert_trees_close(hf, hj, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("mode", ["naive", "linearize"])
def test_flash_gnvp_product_matches_jnp(mode):
    """The Gauss-Newton product (J·v via the Pallas JVP pass, Jᵀ·u via the
    Pallas backward kernels under jax.linear_transpose) matches jnp."""
    model_jnp, model_fa, params, batch, tan = _models_and_batch()
    gj = make_gnvp_op(model_jnp.logits_fn, model_jnp.out_loss_fn, params,
                      batch, mode=mode)(tan)
    gf = make_gnvp_op(model_fa.logits_fn, model_fa.out_loss_fn, params,
                      batch, mode=mode)(tan)
    _assert_trees_close(gf, gj, rtol=1e-3, atol=1e-4)


# ------------------------------------------------------- hf_step parity ---
def _hf_step_pair(solver, mode, backend, S=32, iters=4):
    cfg = _tiny()
    model_jnp, model_fa, params = _pair(cfg)
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 2, S)
    # Well-damped regime: with the paper's default damping at a saddle-heavy
    # random init, the indefinite Bi-CG-STAB solve amplifies 1e-7 operator
    # noise into discrete branch flips (NC selection, φ-best iterate) — the
    # repo's own tree-vs-flat backends differ by more than flash-vs-jnp
    # there. λ=100 makes A strongly PD so the whole-step comparison measures
    # the attention path, not branch chaos (measured: 3e-8 parity across
    # all modes × backends; per-product parity is pinned separately above
    # at realistic conditioning).
    hcfg = HFConfig(solver=solver, max_cg_iters=iters, init_damping=100.0,
                    krylov_backend=backend, curvature_mode=mode,
                    curvature_chunk_size=1 if mode == "chunked" else 0)
    out = {}
    for name, m in (("jnp", model_jnp), ("flash", model_fa)):
        state = hf_init(params, hcfg)
        step = jax.jit(lambda p, s, b, m=m: hf_step(
            m.loss_fn, p, s, b, b, hcfg,
            model_out_fn=m.logits_fn, out_loss_fn=m.out_loss_fn))
        newp, _, metrics = step(params, state, batch)
        out[name] = (newp, metrics)
    (pj, mj), (pf, mf) = out["jnp"], out["flash"]
    np.testing.assert_allclose(float(mf["loss"]), float(mj["loss"]),
                               rtol=1e-5, atol=1e-5)
    _assert_trees_close(pf, pj, rtol=1e-3, atol=1e-4)


def test_hf_step_flash_matches_jnp_fast():
    """Acceptance fast lane: default mode x default backend."""
    _hf_step_pair("bicgstab", "linearize", "tree")


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["naive", "linearize", "chunked"])
@pytest.mark.parametrize("backend", ["tree", "flat"])
def test_hf_step_flash_matches_jnp_grid(mode, backend):
    """Acceptance grid: all three curvature_modes x both Krylov backends."""
    _hf_step_pair("bicgstab", mode, backend)


@pytest.mark.slow
def test_hf_step_flash_matches_jnp_gn():
    _hf_step_pair("gn_cg", "linearize", "tree")


@pytest.mark.slow
@pytest.mark.parametrize("solver,mode", [
    ("gn_cg", "linearize"), ("gn_cg", "naive"), ("gn_cg", "chunked"),
    ("hybrid_cg", "linearize"), ("bicgstab", "linearize"),
])
def test_hf_step_flash_sstep_runs(solver, mode):
    """s-step + flash attention must run for every solver family and
    curvature mode: the block products vmap the curvature map, so hf_step
    builds the GN operator under second_order_tangents() when sstep_s > 1
    (linear_call has no batching rule — kernels/flash_ad.py), and
    make_gnvp_op re-enters that context around the lazy per-call traces of
    its naive/chunked modes; exact-Hessian operators are ctx-built by the
    engine already. Regression: these used to die with an opaque 'Batching
    rule for linear_call not implemented' deep in the solver."""
    cfg = _tiny().replace(n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
                          d_ff=64, vocab_size=128, use_flash_attention=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 2, 16)
    hcfg = HFConfig(solver=solver, max_cg_iters=4, sstep_s=2,
                    curvature_mode=mode,
                    curvature_chunk_size=1 if mode == "chunked" else 0)
    state = hf_init(params, hcfg)
    _, _, metrics = jax.jit(lambda p, s, b: hf_step(
        m.loss_fn, p, s, b, b, hcfg,
        model_out_fn=m.logits_fn, out_loss_fn=m.out_loss_fn))(
        params, state, batch)
    assert np.isfinite(float(metrics["loss"]))