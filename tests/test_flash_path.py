"""The flash-attention model path (cfg.use_flash_attention) must match the
jnp prefill path (kernel in interpret mode on CPU)."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import lm_batch
from repro.models import build_model


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mixtral-8x22b"])
def test_flash_prefill_matches_jnp(arch):
    cfg = get_smoke_config(arch)
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=64)
    model_jnp = build_model(cfg)
    model_fa = build_model(cfg.replace(use_flash_attention=True))
    params = model_jnp.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, 2, 256)  # block-aligned S
    logits_jnp, cache_jnp = model_jnp.prefill(params, batch, max_len=256)
    logits_fa, cache_fa = model_fa.prefill(params, batch, max_len=256)
    np.testing.assert_allclose(
        np.asarray(logits_fa), np.asarray(logits_jnp), rtol=2e-3, atol=2e-3
    )
    for a, b in zip(jax.tree_util.tree_leaves(cache_jnp), jax.tree_util.tree_leaves(cache_fa)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
