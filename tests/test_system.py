"""End-to-end behaviour tests: training convergence, checkpoint round-trip,
serving loop, and the optimizer API surface."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import HFOptConfig, get_smoke_config
from repro.configs.paper_mlp import MNIST_FIG3
from repro.core import HFConfig, hf_init, hf_step
from repro.data import classification_dataset, lm_batch
from repro.models import build_mlp, build_model
from repro.optim import make_optimizer


class TestMLPTraining:
    def test_bicgstab_reaches_low_error(self):
        model = build_mlp((32, 64, 4))
        data = classification_dataset(jax.random.PRNGKey(0), 512, 32, 4)
        cfg = HFConfig(solver="bicgstab", max_cg_iters=10)
        params = model.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s: hf_step(
            model.loss_fn, p, s, data, data, cfg,
            model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
        losses = []
        for _ in range(15):
            params, state, m = step(params, state)
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.3 * losses[0]
        assert float(model.accuracy(params, data)) > 0.9

    def test_monotone_under_line_search(self):
        """Armijo guarantees f never increases across accepted steps."""
        model = build_mlp((16, 32, 3))
        data = classification_dataset(jax.random.PRNGKey(2), 256, 16, 3)
        cfg = HFConfig(solver="bicgstab", max_cg_iters=8)
        params = model.init(jax.random.PRNGKey(3))
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s: hf_step(model.loss_fn, p, s, data, data, cfg))
        prev = float(model.loss_fn(params, data))
        for _ in range(10):
            params, state, m = step(params, state)
            cur = float(model.loss_fn(params, data))
            assert cur <= prev + 1e-5
            prev = cur

    @pytest.mark.slow  # comparative convergence sweep (HF vs SGD budgets)
    def test_hf_beats_sgd_at_equal_communications(self):
        """The paper's core *systems* claim (Fig. 3 right): per unit of
        communication, distributed HF makes far more progress than
        data-parallel mini-batch SGD. HF: 1 grad + K HVP + E line-search
        reduces per outer iteration; SGD: 2 reduces per mini-batch step.
        noise=3.5 keeps the task hard enough that SGD cannot finish within
        the communication budget (an easy task lets b=64 SGD converge in
        one epoch, which tests nothing)."""
        model = build_mlp((32, 64, 8))
        data = classification_dataset(jax.random.PRNGKey(0), 1024, 32, 8, noise=3.5)
        hvp_batch = {k: v[:256] for k, v in data.items()}
        cfg = HFConfig(solver="bicgstab", max_cg_iters=5, max_backtracks=4)
        params = model.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s: hf_step(model.loss_fn, p, s, data, hvp_batch, cfg))
        hf_comms = 0
        for _ in range(6):
            params, state, m = step(params, state)
            hf_comms += 1 + int(m["cg_iters"]) + int(m["ls_evals"])
        hf_loss = float(model.loss_fn(params, data))

        from repro.data.synthetic import minibatches
        from repro.optim.first_order import sgd
        opt = sgd(0.1)
        p2 = model.init(jax.random.PRNGKey(1))
        st = opt.init(p2)
        stepf = jax.jit(lambda p, s, b: opt.step(model.loss_fn, p, s, b))
        sgd_steps = hf_comms // 2          # 2 reduces per SGD step
        done = 0
        for ep in range(100):
            for b in minibatches(data, 64, seed=ep):
                if done >= sgd_steps:
                    break
                p2, st, _ = stepf(p2, st, b)
                done += 1
            if done >= sgd_steps:
                break
        sgd_loss = float(model.loss_fn(p2, data))
        assert hf_loss < sgd_loss, (hf_loss, sgd_loss, hf_comms)


class TestOptimizerApi:
    @pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "bicgstab", "gn_cg"])
    def test_make_optimizer_runs(self, name):
        model = build_mlp((8, 16, 3))
        data = classification_dataset(jax.random.PRNGKey(0), 64, 8, 3)
        opt = make_optimizer(
            HFOptConfig(name=name, lr=0.1, max_cg_iters=3),
            model.loss_fn, model_out_fn=model.logits_fn,
            out_loss_fn=model.out_loss_fn,
        )
        params = model.init(jax.random.PRNGKey(1))
        state = opt.init(params)
        params, state, metrics = jax.jit(opt.step)(params, state, data)
        assert bool(jnp.isfinite(metrics["loss"]))


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = get_smoke_config("qwen2-1.5b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        hf_cfg = HFConfig()
        state = hf_init(params, hf_cfg)
        d = str(tmp_path / "ckpt")
        save_checkpoint(d, 7, params, state, extra={"note": "t"})
        assert latest_step(d) == 7
        p2, s2, meta = restore_checkpoint(d, 7, params, state)
        assert meta["step"] == 7 and meta["note"] == "t"
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restore_into_optimizer_state(self, tmp_path):
        model = build_mlp((8, 4))
        params = model.init(jax.random.PRNGKey(0))
        state = hf_init(params, HFConfig())
        state = state._replace(lam=jnp.asarray(3.5))
        d = str(tmp_path / "c")
        save_checkpoint(d, 1, params, state)
        _, s2, _ = restore_checkpoint(d, 1, params, state)
        assert float(s2.lam) == 3.5


class TestServing:
    def test_greedy_decode_deterministic(self):
        from repro.launch.serve import serve
        g1, s1 = serve("qwen2-1.5b", smoke=True, batch_size=2, prompt_len=8,
                       gen_len=4, log_fn=lambda *a: None)
        g2, s2 = serve("qwen2-1.5b", smoke=True, batch_size=2, prompt_len=8,
                       gen_len=4, log_fn=lambda *a: None)
        assert s1["n_tok"] == 8 and s1["prefill_s"] > 0 and s1["decode_s"] > 0
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))

    @pytest.mark.slow  # full launch.train driver: model build + several steps
    def test_train_driver(self):
        from repro.launch.train import train
        _, _, hist = train("qwen1.5-0.5b", smoke=True, solver="bicgstab",
                           steps=2, batch_size=4, seq_len=32,
                           log_fn=lambda *a: None)
        assert len(hist) == 2
        assert all(np.isfinite(h["loss"]) for h in hist)
