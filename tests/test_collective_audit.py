"""Collective-schedule audit: the jaxpr, the executed program, and
``KrylovResult.syncs`` must tell the same story.

Every all-reduce in ``core.distributed.data_parallel_hf_step`` goes through
``core.collectives.preduce`` (a tagged pmean), which makes the schedule
auditable at two levels:

  * STATIC — ``jaxpr_collective_counts`` walks the traced step and counts
    psum-family equations, split into unconditionally-executed ("top") vs
    inside-a-while-body ("while_body") regions. Pure data parallelism means
    the ONLY collectives are all-reduces (psum/psum2 — pmean lowers to
    psum2): no all-gathers or all-to-alls of model state, for every
    solver × s-step × curvature combo.
  * EXECUTED — ``count_executed`` tallies each preduce tag once per actual
    execution (while_loop trips included), which must reconcile with the
    per-step metrics: ``loss`` reduces = 1 (f0) + one per line-search eval,
    ``grad_hvp`` reduces = gradient + initial-residual probe + the basis /
    per-iteration operator products, and ``metrics["krylov_syncs"]``
    (= ``KrylovResult.syncs``) + the line-search terms must equal both
    ``metrics["blocking_syncs"]`` and the §3 comm-model formula
    (``hf_sstep_syncs_per_iteration``) at the EXECUTED iteration counts.

The single-device mesh is deliberate: shard_map binds the same collective
primitives regardless of axis size, so the schedule audited here is the one
the 2-process harness executes (tests/test_multiproc.py runs the real
thing; benchmarks/fig5_scaling.py --executed cross-checks at N=2).
"""
import jax
import pytest

from repro.core import HFConfig, hf_init
from repro.core.collectives import count_executed, jaxpr_collective_counts
from repro.core.distributed import data_parallel_hf_step
from repro.data import classification_dataset
from repro.models import build_mlp

from benchmarks.comm_model import (hf_sstep_syncs_per_iteration,
                                   sstep_bootstrap)

K = 8  # with cg_tol=0 the CG-family solves run to truncation/max_iters

# solver × s-step × curvature grid. `static`: the audited (top, while_body)
# psum2 equation counts — a deterministic fingerprint of the schedule; if a
# change here is INTENTIONAL (a reduce added/removed/moved), update the
# table and EXPERIMENTS.md §Perf pair I together.
COMBOS = {
    "hessian_cg_s1": dict(solver="hessian_cg", s=1, basis="monomial",
                          overlap=False, curv="linearize", static=(5, 3)),
    "hessian_cg_s2": dict(solver="hessian_cg", s=2, basis="monomial",
                          overlap=False, curv="linearize", static=(7, 7)),
    "hessian_cg_s2_overlap": dict(solver="hessian_cg", s=2, basis="monomial",
                                  overlap=True, curv="linearize",
                                  static=(7, 12)),
    "hessian_cg_s2_chunked": dict(solver="hessian_cg", s=2, basis="monomial",
                                  overlap=False, curv="chunked",
                                  static=(7, 4)),
    "gn_cg_s1": dict(solver="gn_cg", s=1, basis="monomial",
                     overlap=False, curv="linearize", static=(6, 2)),
    "gn_cg_s4_newton": dict(solver="gn_cg", s=4, basis="newton",
                            overlap=False, curv="linearize", static=(11, 6)),
    "bicgstab_s1": dict(solver="bicgstab", s=1, basis="monomial",
                        overlap=False, curv="linearize", static=(5, 5)),
    "bicgstab_s2_newton": dict(solver="bicgstab", s=2, basis="newton",
                               overlap=False, curv="linearize",
                               static=(23, 13)),
}


@pytest.fixture(scope="module")
def setup():
    model = build_mlp((16, 32, 4))
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(jax.random.PRNGKey(0), 16, 16, 4)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    return model, params, data, mesh


def _make_step(model, mesh, spec):
    cfg = HFConfig(solver=spec["solver"], max_cg_iters=K, cg_tol=0.0,
                   sstep_s=spec["s"], sstep_basis=spec["basis"],
                   overlap=spec["overlap"], curvature_mode=spec["curv"])
    kw = (dict(model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn)
          if spec["solver"] == "gn_cg" else {})
    return cfg, data_parallel_hf_step(model.loss_fn, mesh, cfg, **kw)


@pytest.mark.parametrize("name", list(COMBOS))
def test_static_schedule_is_all_reduce_only(name, setup):
    model, params, data, mesh = setup
    spec = COMBOS[name]
    cfg, step = _make_step(model, mesh, spec)
    jaxpr = jax.make_jaxpr(step)(params, hf_init(params, cfg), data)
    counts = jaxpr_collective_counts(jaxpr.jaxpr)
    # Pure data parallelism: all-reduces only (pmean → psum2), never an
    # all-gather/all-to-all of model state — in ANY region.
    prims = set(counts["top"]) | set(counts["while_body"])
    assert prims <= {"psum", "psum2"}, (name, counts)
    assert sum(counts["top"].values()) > 0, name
    assert (counts["top"]["psum2"], counts["while_body"]["psum2"]) == \
        spec["static"], (name, counts)


def test_static_overlap_adds_only_loop_body_reduces(setup):
    """Overlap reorders/hides reduces and adds the speculative deep-half +
    paired line-search ones — all inside the solve/search loops; the
    unconditional top-level schedule is untouched."""
    base = COMBOS["hessian_cg_s2"]["static"]
    ov = COMBOS["hessian_cg_s2_overlap"]["static"]
    assert ov[0] == base[0]
    assert ov[1] > base[1]


@pytest.mark.parametrize("name", list(COMBOS))
def test_executed_counts_match_krylov_syncs_and_comm_model(name, setup):
    model, params, data, mesh = setup
    spec = COMBOS[name]
    cfg, step = _make_step(model, mesh, spec)
    with count_executed() as counts:
        p, s, m = jax.jit(step)(params, hf_init(params, cfg), data)
        jax.block_until_ready(p)
    executed = counts.per_device(len(jax.local_devices()))
    cg_iters, ls_evals = int(m["cg_iters"]), int(m["ls_evals"])
    krylov, blocking = int(m["krylov_syncs"]), int(m["blocking_syncs"])
    assert int(m["sstep_fallback"]) == 0, (name, executed, m)

    # Loss reduces: one f0 + one per line-search eval. Chunked curvature
    # adds one (its primal accumulation probes the pmean'd loss once).
    expect_loss = 1 + ls_evals + (1 if spec["curv"] == "chunked" else 0)
    assert executed["loss"] == expect_loss, (name, executed, ls_evals)
    # gn_cg's Gauss-Newton build probes the pmean'd output loss once.
    assert executed.get("out_loss", 0) == \
        (1 if spec["solver"] == "gn_cg" else 0), (name, executed)

    # Model-sized reduces: gradient + initial-residual probe (A x0) + the
    # operator products — per iteration for the standard solvers, per basis
    # chain level for s-step (cycles recovered from KrylovResult.syncs).
    family = "bicgstab" if spec["solver"] == "bicgstab" else "cg"
    if spec["s"] == 1:
        products = (2 if family == "bicgstab" else 1) * cg_iters
    else:
        s_eff = 2 * spec["s"] if spec["overlap"] else spec["s"]
        n_boot, covered = sstep_bootstrap(s_eff, family, spec["basis"])
        s_boot = covered // n_boot if n_boot else 0
        d = 2 * s_eff if family == "bicgstab" else s_eff
        d_boot = 2 * s_boot if family == "bicgstab" else s_boot
        cycles = krylov - n_boot  # one Gram reduction per executed cycle
        products = cycles * (2 * d - 1) + n_boot * max(2 * d_boot - 1, 0)
    assert executed["grad_hvp"] == 2 + products, (name, executed, m)

    # KrylovResult.syncs ↔ blocking_syncs ↔ §3 comm model, all at the
    # EXECUTED iteration/eval counts.
    if spec["overlap"]:
        assert blocking == krylov + (ls_evals + 1) // 2, (name, m)
    else:
        assert blocking == 1 + krylov + ls_evals, (name, m)
    assert blocking == hf_sstep_syncs_per_iteration(
        cg_iters, ls_evals, spec["s"], solver=family,
        basis=spec["basis"], overlap=spec["overlap"]), (name, m)
