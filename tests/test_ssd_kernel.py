"""SSD intra-chunk Pallas kernel vs the pure-jnp ssd_chunked oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ssd_chunked_pallas
from repro.models.ssm import ssd_chunked

CASES = [
    # (B, L, H, N, P, chunk)
    (1, 64, 1, 16, 16, 16),
    (2, 128, 4, 32, 64, 32),
    (1, 256, 2, 64, 64, 128),
    (2, 64, 3, 16, 32, 64),     # single chunk
]


@pytest.mark.parametrize("B,L,H,N,P,chunk", CASES)
@pytest.mark.parametrize("with_h0", [False, True])
def test_ssd_pallas_matches_jnp(B, L, H, N, P, chunk, with_h0):
    ks = jax.random.split(jax.random.PRNGKey(L + H), 5)
    u = jax.random.normal(ks[0], (B, L, H, P))
    log_a = -jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bv = jax.random.normal(ks[2], (B, L, N)) * 0.5
    Cv = jax.random.normal(ks[3], (B, L, N)) * 0.5
    h0 = jax.random.normal(ks[4], (B, H, N, P)) * 0.3 if with_h0 else None
    y_ref, h_ref = ssd_chunked(u, log_a, Bv, Cv, chunk, h0=h0)
    y_k, h_k = ssd_chunked_pallas(u, log_a, Bv, Cv, chunk, h0=h0, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref), rtol=2e-4, atol=2e-4)
