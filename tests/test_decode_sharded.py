"""Sequence-sharded flash-decode == unsharded decode_attend (8 fake devices,
subprocess so the main suite keeps its 1-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess with 8 fake devices: ~6s

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models.attention import KVCache, attn_init, decode_attend, init_kv_cache
    from repro.models.decode_sharded import sharded_decode_attend

    base = get_smoke_config("granite-3-8b")      # GQA kv=2 < 8 shards
    mesh = jax.make_mesh((8,), ("model",))
    dtype = jnp.float32
    for window in (None, 24):                    # rolling + sliding-window bias
      cfg = base.replace(sliding_window=window)
      p = attn_init(jax.random.PRNGKey(0), cfg, dtype)
      B, W = 2, 64
      cache = init_kv_cache(cfg, B, W, dtype)
      # pre-fill with K/V for positions 0..39 at their rolling slots p % Wc
      # (the windowed cache is only Wc = window slots wide)
      ks = jax.random.split(jax.random.PRNGKey(1), 3)
      npos = 40
      Wc = cache.k.shape[1]
      fill = min(npos, Wc)
      ppos = jnp.arange(npos - fill, npos)
      slots = ppos % Wc
      cache = KVCache(
          k=cache.k.at[:, slots].set(jax.random.normal(ks[0], (B, fill, cfg.n_kv_heads, cfg.resolved_head_dim))),
          v=cache.v.at[:, slots].set(jax.random.normal(ks[1], (B, fill, cfg.n_kv_heads, cfg.resolved_head_dim))),
          pos=cache.pos.at[slots].set(ppos),
      )
      x = jax.random.normal(ks[2], (B, 1, cfg.d_model), dtype)
      t = jnp.asarray(npos, jnp.int32)

      y_ref, c_ref = decode_attend(p, x, t, cache, cfg)

      sharded_cache = KVCache(
          jax.device_put(cache.k, NamedSharding(mesh, P(None, "model"))),
          jax.device_put(cache.v, NamedSharding(mesh, P(None, "model"))),
          jax.device_put(cache.pos, NamedSharding(mesh, P("model"))),
      )
      y_sh, c_sh = jax.jit(
          lambda p, x, c: sharded_decode_attend(p, x, t, c, cfg, mesh)
      )(p, x, sharded_cache)

      np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
      np.testing.assert_allclose(np.asarray(c_sh.k), np.asarray(c_ref.k), rtol=1e-5, atol=1e-6)
      np.testing.assert_allclose(np.asarray(c_sh.pos), np.asarray(c_ref.pos))
      print("OK", window)
    print("OK")
""")


def test_sharded_decode_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + r.stderr[-3000:]
    assert "OK" in r.stdout
