"""Overlapped-collective schedule unit tests (HFConfig.overlap).

Three layers, matching the implementation split:
  * core/sstep.py — double-buffered super-cycles: two s-iteration cycles
    per Gram reduction (``KrylovResult.syncs`` halves), same iterates,
  * core/line_search.py — paired Armijo: two speculative trials per
    blocking round-trip, same accepted step,
  * core/hf.py — the assembled step: ``metrics["blocking_syncs"]`` drops
    to ``krylov_syncs + ceil(E/2)`` (hidden grad-reduce + paired search)
    while the accepted update stays numerically equivalent.

The executed multi-process counterpart lives in tests/test_multiproc.py
and benchmarks/fig5_scaling.py --executed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HFConfig, hf_init, hf_step
from repro.core.line_search import armijo
from repro.core.sstep import sstep_bicgstab, sstep_cg
from repro.data import classification_dataset
from repro.models import build_mlp


def _vec(x):
    x = np.asarray(x, np.float32)
    return {"a": jnp.asarray(x[:5]), "b": jnp.asarray(x[5:]).reshape(3, 3)}


def _unvec(t):
    return np.concatenate([np.asarray(t["a"]).ravel(),
                           np.asarray(t["b"]).ravel()])


def _mat_op(M):
    def op(v):
        f = jnp.concatenate([v["a"].ravel(), v["b"].ravel()])
        out = M @ f
        return {"a": out[:5], "b": out[5:].reshape(3, 3)}
    return op


def _spd():
    rng = np.random.RandomState(2)
    Q = rng.randn(14, 14).astype(np.float32)
    M = jnp.asarray(Q @ Q.T + 14 * np.eye(14, dtype=np.float32))
    return M, _vec(rng.randn(14)), _vec(np.zeros(14))


class TestSolverOverlap:
    """Double-buffered cycles: half the Gram syncs, the same iterates."""

    @pytest.mark.parametrize("s,syncs,syncs_ov", [(1, 8, 4), (2, 4, 2)])
    def test_sstep_cg_halves_syncs_same_solution(self, s, syncs, syncs_ov):
        M, b, x0 = _spd()
        kw = dict(lam=1.0, s=s, max_iters=8, tol=0.0)
        base = sstep_cg(_mat_op(M), b, x0, **kw)
        ov = sstep_cg(_mat_op(M), b, x0, overlap=True, **kw)
        assert int(base.syncs) == syncs
        assert int(ov.syncs) == syncs_ov
        assert int(ov.iters) == int(base.iters) == 8
        assert not bool(ov.breakdown)
        np.testing.assert_allclose(_unvec(ov.x), _unvec(base.x),
                                   rtol=1e-3, atol=5e-5)

    def test_sstep_bicgstab_overlap(self):
        # s=1: the s_run=2 chains stay inside Bi-CG-STAB's monomial f32
        # depth budget (2s products per iteration). At s=2, overlap would
        # need depth-8 chains — the prefix guard degrades the speculative
        # half rather than running an unstable basis (checked below).
        M, b, x0 = _spd()
        kw = dict(lam=1.0, s=1, max_iters=8, tol=0.0)
        base = sstep_bicgstab(_mat_op(M), b, x0, **kw)
        ov = sstep_bicgstab(_mat_op(M), b, x0, overlap=True, **kw)
        assert int(base.syncs) == 8 and int(ov.syncs) == 4
        np.testing.assert_allclose(_unvec(ov.x), _unvec(base.x),
                                   rtol=1e-3, atol=5e-5)

    def test_sstep_bicgstab_overlap_guard_never_worse(self):
        # Past the depth budget the guard may cancel the speculative deep
        # half (syncs don't halve) but must never degrade the solution.
        M, b, x0 = _spd()
        kw = dict(lam=1.0, s=2, max_iters=8, tol=0.0)
        base = sstep_bicgstab(_mat_op(M), b, x0, **kw)
        ov = sstep_bicgstab(_mat_op(M), b, x0, overlap=True, **kw)
        assert int(ov.syncs) <= int(base.syncs)
        np.testing.assert_allclose(_unvec(ov.x), _unvec(base.x),
                                   rtol=1e-3, atol=5e-5)


class TestPairedArmijo:
    """paired=True: same accepted step, ⌈E/2⌉ blocking round-trips."""

    def _problem(self, scale):
        # Quadratic bowl; delta chosen so acceptance needs backtracking
        # when scale > 1 (alpha0=1 overshoots).
        target = jnp.arange(1.0, 6.0)
        params = jnp.zeros(5)

        def loss_fn(p):
            return 0.5 * jnp.sum((p - target) ** 2)

        g = jax.grad(loss_fn)(params)
        delta = -scale * g
        return loss_fn, params, loss_fn(params), delta, jnp.vdot(g, delta)

    @pytest.mark.parametrize("scale", [1.0, 3.0, 9.0])
    def test_same_accepted_step(self, scale):
        loss_fn, params, f0, delta, gd = self._problem(scale)
        base = armijo(loss_fn, params, f0, delta, gd)
        pair = armijo(loss_fn, params, f0, delta, gd, paired=True)
        assert bool(base.success) and bool(pair.success)
        # The paired search walks the SAME backtracking sequence alpha0,
        # beta*alpha0, ... two-at-a-time: identical accepted alpha.
        np.testing.assert_allclose(float(pair.alpha), float(base.alpha))
        np.testing.assert_allclose(float(pair.f_new), float(base.f_new),
                                   rtol=1e-6)
        # n_evals counts trials (pairs issue two per round-trip): the
        # blocking round-trips are ceil(n/2) <= the serial count.
        assert (int(pair.n_evals) + 1) // 2 <= int(base.n_evals)

    def test_failure_is_zero_step_both(self):
        loss_fn, params, f0, delta, _ = self._problem(1.0)
        # An ascent direction with a descent-slope claim: never accepted.
        uphill = jax.tree_util.tree_map(lambda d: -d, delta)
        for paired in (False, True):
            r = armijo(loss_fn, params, f0, uphill, jnp.asarray(-1.0),
                       max_backtracks=4, paired=paired)
            assert not bool(r.success)
            assert float(r.alpha) == 0.0
            assert float(r.f_new) == float(f0)


class TestHFStepOverlap:
    """The assembled step: blocking_syncs bookkeeping + loss parity."""

    @pytest.fixture(scope="class")
    def problem(self):
        model = build_mlp((16, 32, 4))
        params = model.init(jax.random.PRNGKey(1))
        data = classification_dataset(jax.random.PRNGKey(0), 32, 16, 4)
        return model, params, data

    def _run(self, problem, **cfg_kw):
        model, params, data = problem
        cfg = HFConfig(solver="hessian_cg", max_cg_iters=8, cg_tol=0.0,
                       **cfg_kw)
        _, _, m = jax.jit(
            lambda p, s: hf_step(model.loss_fn, p, s, data, data, cfg)
        )(params, hf_init(params, cfg))
        return {k: float(v) for k, v in m.items()}

    def test_blocking_syncs_metric(self, problem):
        base = self._run(problem, sstep_s=2)
        ov = self._run(problem, sstep_s=2, overlap=True)
        assert base["blocking_syncs"] == \
            1 + base["krylov_syncs"] + base["ls_evals"]
        assert ov["blocking_syncs"] == \
            ov["krylov_syncs"] + (ov["ls_evals"] + 1) // 2
        assert ov["blocking_syncs"] < base["blocking_syncs"]
        # Same outer problem: overlap changes the schedule, not the math.
        np.testing.assert_allclose(ov["loss"], base["loss"], rtol=1e-5)
        np.testing.assert_allclose(ov["loss_new"], base["loss_new"],
                                   rtol=5e-3)

    def test_overlap_at_s1_keeps_standard_solver(self, problem):
        # s-step only engages for sstep_s > 1; at s=1 overlap still hides
        # the grad reduce and pairs the search, but the Krylov term stays
        # the standard solver's per-iteration round-trips.
        base = self._run(problem)
        ov = self._run(problem, overlap=True)
        assert ov["krylov_syncs"] == base["krylov_syncs"] == base["cg_iters"]
        assert ov["blocking_syncs"] == \
            ov["krylov_syncs"] + (ov["ls_evals"] + 1) // 2
