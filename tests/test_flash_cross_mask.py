"""Flash kernels on the paths PR 4 left to `_sdpa`: explicit masks (as an
additive logit bias operand) and cross-attention (mismatched q/kv lengths
via independent pad-and-mask on both axes).

Kernel level: every raw pass (fwd/bwd/jvp) and every AD route through
``flash_mha`` (grad, linearize, second-order forward-over-reverse) against
the jnp oracles in kernels/ref.py, with and without bias, at aligned and
non-aligned Sq != Sk. Model level: ``attend_full`` with cfg.use_flash_attention
must match the `_sdpa` path bit-for-tolerance on cross_kv and head-broadcast
mask inputs — `_sdpa` is the parity oracle only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.kernels.flash_ad import second_order_tangents
from repro.kernels.ref import NEG_INF
from repro.models import attention as A


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32) * 0.5


def _qkv(seed, B, Sq, Sk, H, KV, hd):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (_rand(ks[0], B, Sq, H, hd), _rand(ks[1], B, Sk, KV, hd),
            _rand(ks[2], B, Sk, KV, hd))


def _bias(seed, bb, Sq, Sk, keep=0.75):
    """Random (bb, Sq, Sk) 0/NEG_INF bias with a guaranteed-valid column."""
    m = jax.random.bernoulli(jax.random.PRNGKey(seed), keep, (bb, Sq, Sk))
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32).at[:, :, 0].set(0.0)


# --------------------------------------------------- raw kernels + bias ----
@pytest.mark.parametrize("bias_batch", [1, 2])
def test_raw_passes_with_bias_match_ref(bias_batch):
    B, Sq, Sk, H, KV, hd = 2, 128, 128, 4, 2, 32
    q, k, v = _qkv(0, B, Sq, Sk, H, KV, hd)
    bias = _bias(7, bias_batch, Sq, Sk)
    kw = dict(causal=False, window=None, bias=bias)

    o, lse = ops.flash_attention_fwd(q, k, v, interpret=True, **kw)
    o_r, lse_r = ref.flash_attention_fwd_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r),
                               rtol=2e-5, atol=2e-5)

    do = _rand(jax.random.PRNGKey(3), B, Sq, H, hd)
    grads = ops.flash_attention_bwd(q, k, v, o_r, lse_r, do,
                                    interpret=True, **kw)
    grads_r = ref.flash_attention_bwd_ref(q, k, v, o_r, lse_r, do, **kw)
    for g, g_r in zip(grads, grads_r):
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_r),
                                   rtol=2e-4, atol=2e-4)

    qt, kt, vt = _qkv(11, B, Sq, Sk, H, KV, hd)
    ot, lt = ops.flash_attention_jvp(q, k, v, o_r, lse_r, qt, kt, vt,
                                     interpret=True, **kw)
    ot_r, lt_r = ref.flash_attention_jvp_ref(q, k, v, o_r, lse_r, qt, kt, vt,
                                             **kw)
    np.testing.assert_allclose(np.asarray(ot), np.asarray(ot_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lt), np.asarray(lt_r),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------- cross lengths through AD ----
@pytest.mark.parametrize("Sq,Sk", [(17, 43), (128, 64)])
def test_cross_length_fwd_and_grad(Sq, Sk):
    B, H, KV, hd = 2, 4, 2, 16
    q, k, v = _qkv(1, B, Sq, Sk, H, KV, hd)

    o = ops.flash_attention(q, k, v, causal=False, window=None, interpret=True)
    o_r = ref.flash_attention_ref(q, k, v, causal=False, window=None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)

    def loss(fn):
        return lambda q, k, v: jnp.sum(jnp.sin(fn(q, k, v)))

    g = jax.grad(loss(lambda q, k, v: ops.flash_attention(
        q, k, v, causal=False, window=None, interpret=True)),
        argnums=(0, 1, 2))(q, k, v)
    g_r = jax.grad(loss(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=False, window=None)), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("Sq,Sk", [(128, 128), (10, 23)])
def test_bias_grad_through_flash_mha(Sq, Sk):
    B, H, KV, hd = 2, 4, 2, 16
    q, k, v = _qkv(2, B, Sq, Sk, H, KV, hd)
    bias = _bias(3, B, Sq, Sk)

    def fl(q, k, v):
        return ops.flash_attention(q, k, v, causal=False, window=None,
                                   bias=bias, interpret=True)

    def rf(q, k, v):
        return ref.flash_attention_ref(q, k, v, causal=False, window=None,
                                       bias=bias)

    np.testing.assert_allclose(np.asarray(fl(q, k, v)),
                               np.asarray(rf(q, k, v)),
                               rtol=2e-5, atol=2e-5)
    gf = jax.grad(lambda q, k, v: jnp.sum(jnp.sin(fl(q, k, v))),
                  argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda q, k, v: jnp.sum(jnp.sin(rf(q, k, v))),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_bias_linearize_and_second_order():
    B, Sq, Sk, H, KV, hd = 2, 64, 96, 4, 2, 16
    q, k, v = _qkv(4, B, Sq, Sk, H, KV, hd)
    qt, kt, vt = _qkv(5, B, Sq, Sk, H, KV, hd)
    bias = _bias(5, 1, Sq, Sk, keep=0.8)

    def fl(q, k, v):
        return ops.flash_attention(q, k, v, causal=False, window=None,
                                   bias=bias, interpret=True)

    def rf(q, k, v):
        return ref.flash_attention_ref(q, k, v, causal=False, window=None,
                                       bias=bias)

    _, jf = jax.linearize(fl, q, k, v)
    _, jr = jax.linearize(rf, q, k, v)
    np.testing.assert_allclose(np.asarray(jf(qt, kt, vt)),
                               np.asarray(jr(qt, kt, vt)),
                               rtol=2e-4, atol=2e-4)

    def gq(fn):
        return lambda qq: jax.grad(
            lambda q_: jnp.sum(jnp.sin(fn(q_, k, v))))(qq)

    with second_order_tangents():
        hf = jax.jvp(gq(fl), (q,), (qt,))
    hr = jax.jvp(gq(rf), (q,), (qt,))
    for a, b in zip(hf, hr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ----------------------------------------------- attend_full routing ----
def _attn_setup(seed=0):
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=32)
    hd = cfg.resolved_head_dim
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    p = {"wq": {"w": _rand(ks[0], 64, 64)},
         "wo": {"w": _rand(ks[1], 64, 64)},
         "wk": {"w": _rand(ks[2], 64, cfg.n_kv_heads * hd)},
         "wv": {"w": _rand(ks[3], 64, cfg.n_kv_heads * hd)}}
    B, S, T = 2, 13, 29
    x = _rand(ks[4], B, S, 64)
    kv = (_rand(ks[5], B, T, cfg.n_kv_heads, hd),
          _rand(ks[5], B, T, cfg.n_kv_heads, hd))
    return cfg, p, x, jnp.arange(S)[None], kv


def test_attend_full_cross_kv_flash_matches_sdpa():
    cfg, p, x, pos, kv = _attn_setup()
    cfgf = cfg.replace(use_flash_attention=True)
    y0 = A.attend_full(p, x, pos, cfg, cross_kv=kv)
    y1 = A.attend_full(p, x, pos, cfgf, cross_kv=kv)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("cross", [False, True])
def test_attend_full_explicit_mask_flash_matches_sdpa(cross):
    cfg, p, x, pos, kv = _attn_setup()
    cfgf = cfg.replace(use_flash_attention=True)
    B, S = x.shape[:2]
    T = kv[0].shape[1] if cross else S
    mask = jax.random.bernoulli(jax.random.PRNGKey(9), 0.7, (B, 1, S, T))
    mask = mask.at[:, :, :, 0].set(True)
    kw = dict(mask=mask, cross_kv=kv if cross else None)
    y0 = A.attend_full(p, x, pos, cfg, **kw)
    y1 = A.attend_full(p, x, pos, cfgf, **kw)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)


def test_attend_full_mask_route_grad_matches_sdpa():
    cfg, p, x, pos, _ = _attn_setup()
    cfgf = cfg.replace(use_flash_attention=True)
    B, S = x.shape[:2]
    mask = jax.random.bernoulli(jax.random.PRNGKey(9), 0.7, (B, 1, S, S))
    mask = mask.at[:, :, :, 0].set(True)
    g0 = jax.grad(lambda x: jnp.sum(jnp.sin(
        A.attend_full(p, x, pos, cfg, mask=mask))))(x)
    g1 = jax.grad(lambda x: jnp.sum(jnp.sin(
        A.attend_full(p, x, pos, cfgf, mask=mask))))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g0),
                               rtol=2e-4, atol=2e-4)


def test_attend_full_per_kv_head_mask_keeps_sdpa():
    """mask.shape[1] > 1 has no bias encoding — must still run (on _sdpa)."""
    cfg, p, x, pos, _ = _attn_setup()
    cfgf = cfg.replace(use_flash_attention=True)
    B, S = x.shape[:2]
    mask = jax.random.bernoulli(
        jax.random.PRNGKey(13), 0.7, (B, cfg.n_kv_heads, S, S))
    mask = mask.at[:, :, :, 0].set(True)
    y0 = A.attend_full(p, x, pos, cfg, mask=mask)
    y1 = A.attend_full(p, x, pos, cfgf, mask=mask)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)
