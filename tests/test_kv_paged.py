"""Paged KV cache vs the dense rolling cache: attend parity per token,
page-pool accounting invariants across alloc/free/release, slot reuse, and
windowed page freeing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import attention as att
from repro.models import kv_paged as kvp
from repro.models.layers import apply_rope, dense


def _cfg(**kw):
    base = dict(arch_id="t", family="dense", n_layers=1, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                use_flash_attention=True)
    base.update(kw)
    return ModelConfig(**base)


def _proj_kv(p, cfg, x, positions):
    k = att._split_heads(dense(p["wk"], x), cfg.n_kv_heads,
                         cfg.resolved_head_dim)
    v = att._split_heads(dense(p["wv"], x), cfg.n_kv_heads,
                         cfg.resolved_head_dim)
    k = apply_rope(k, positions, rope_fraction=cfg.rope_fraction,
                   theta=cfg.rope_theta)
    return k, v


def _dense_cache_from_prefill(cfg, max_len, kpre, vpre, L):
    c = att.init_kv_cache(cfg, 1, max_len, jnp.float32)
    W = c.window
    keep = min(L, W)
    pos = jnp.arange(L - keep, L)
    slots = pos % W
    return att.KVCache(k=c.k.at[:, slots].set(kpre[:, L - keep:L]),
                      v=c.v.at[:, slots].set(vpre[:, L - keep:L]),
                      pos=c.pos.at[slots].set(pos))


@pytest.mark.parametrize("window", [None, 12])
def test_paged_decode_matches_dense(window):
    """Ragged prefill + 25 decode steps: every slot's paged attend equals
    the scalar dense-cache decode, and the pool invariants hold at every
    step (windowed: pages that roll out are freed)."""
    cfg = _cfg(sliding_window=window)
    p = att.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, ps, P, max_len = 3, 8, 32, 64
    lens = jnp.array([20, 5, 1], jnp.int32)
    cache = kvp.init_paged_cache(cfg, 1, B, max_len, P, jnp.float32,
                                 page_size=ps)
    cache = kvp.alloc_prefill(cache, lens, jnp.ones((B,), bool),
                              window=window)
    kvp.check_invariants(cache)
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, 20, cfg.d_model))
    kpre, vpre = _proj_kv(p, cfg, xs, jnp.arange(20))
    kp, vp = kvp.write_prefill_kv(cache.k_pool[0], cache.v_pool[0],
                                  cache.page_table, kpre, vpre, lens)
    cache = cache._replace(k_pool=cache.k_pool.at[0].set(kp),
                           v_pool=cache.v_pool.at[0].set(vp))
    dense_caches = [
        _dense_cache_from_prefill(cfg, max_len, kpre[b:b + 1], vpre[b:b + 1],
                                  int(lens[b]))
        for b in range(B)
    ]
    active = jnp.ones((B,), bool)
    for step in range(25):
        cache = kvp.alloc_decode_page(cache, active)
        xt = jax.random.normal(jax.random.PRNGKey(100 + step),
                               (B, 1, cfg.d_model))
        y, (kp, vp) = kvp.paged_decode_attend(
            p, xt, (cache.k_pool[0], cache.v_pool[0]), cache.page_table,
            cache.seq_len, cfg, active=active)
        cache = cache._replace(k_pool=cache.k_pool.at[0].set(kp),
                               v_pool=cache.v_pool.at[0].set(vp))
        cache = kvp.advance_and_free(cache, active, window)
        kvp.check_invariants(cache)
        for b in range(B):
            t = int(lens[b]) + step
            yd, dense_caches[b] = att.decode_attend(p, xt[b:b + 1], t,
                                                    dense_caches[b], cfg)
            np.testing.assert_allclose(np.asarray(y[b]), np.asarray(yd[0]),
                                       rtol=2e-5, atol=2e-5)
    if window is not None:
        # steady state HBM: ~window tokens per slot, not max_len
        used = P - 1 - int(cache.n_free)
        assert used <= B * (window // ps + 2), used


def test_windowed_prefill_maps_only_live_pages():
    cfg = _cfg(sliding_window=12)
    cache = kvp.init_paged_cache(cfg, 1, 2, 64, 32, jnp.float32, page_size=8)
    lens = jnp.array([40, 6], jnp.int32)
    cache = kvp.alloc_prefill(cache, lens, jnp.ones((2,), bool), window=12)
    kvp.check_invariants(cache)
    tbl = np.asarray(cache.page_table)
    # live range of slot 0 is [28, 40) -> pages 3 and 4 only
    assert (tbl[0, :3] == -1).all() and (tbl[0, 3:5] >= 0).all()
    assert kvp.pages_needed(40, 8, 12) == 2
    assert (tbl[1, 0] >= 0) and (tbl[1, 1:] == -1).all()


def test_release_and_reuse_slot():
    cfg = _cfg()
    B, P = 3, 16
    cache = kvp.init_paged_cache(cfg, 1, B, 64, P, jnp.float32, page_size=8)
    cache = kvp.alloc_prefill(cache, jnp.array([17, 9, 30]),
                              jnp.ones((B,), bool))
    kvp.check_invariants(cache)
    n0 = int(cache.n_free)
    cache = kvp.release_slots(cache, jnp.array([False, True, False]))
    kvp.check_invariants(cache)
    assert int(cache.n_free) == n0 + 2               # ceil(9/8) pages back
    assert int(cache.seq_len[1]) == 0
    assert (np.asarray(cache.page_table[1]) == -1).all()
    # admit a new request into the freed slot
    cache = kvp.alloc_prefill(cache, jnp.array([0, 23, 0]),
                              jnp.array([False, True, False]))
    kvp.check_invariants(cache)
    assert int(cache.seq_len[1]) == 23
    assert (np.asarray(cache.page_table[1, :3]) >= 0).all()
    # other slots untouched
    assert int(cache.seq_len[0]) == 17 and int(cache.seq_len[2]) == 30


def test_pool_exhaustion_accounting():
    """Popping exactly the free count leaves n_free == 0 and every page
    mapped once."""
    cfg = _cfg()
    P, ps = 9, 8                                      # 8 allocatable pages
    cache = kvp.init_paged_cache(cfg, 1, 2, 64, P, jnp.float32, page_size=ps)
    cache = kvp.alloc_prefill(cache, jnp.array([32, 32]),
                              jnp.ones((2,), bool))
    kvp.check_invariants(cache)
    assert int(cache.n_free) == 0
    assert (np.asarray(cache.page_table[:, :4]) >= 0).all()
    assert (np.asarray(cache.page_table[:, 4:]) == -1).all()
