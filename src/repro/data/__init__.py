from .synthetic import (
    classification_dataset,
    lm_batch,
    batch_spec,
    decode_inputs,
    iterate_batches,
)

__all__ = [
    "classification_dataset", "lm_batch", "batch_spec", "decode_inputs",
    "iterate_batches",
]
