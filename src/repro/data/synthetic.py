"""Deterministic synthetic data: LM token streams per model family and
MNIST/TIMIT-like classification sets for the paper's own experiments.

Everything is generated from PRNG keys — no downloads, reproducible, and the
class structure is learnable (Gaussian class prototypes + noise) so optimizer
comparisons (Fig. 3/4) show real convergence differences.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- LM batches --
def lm_batch(key, cfg, batch_size: int, seq_len: int):
    """Synthetic next-token batch for any assigned architecture.

    Tokens follow a noisy periodic process so there is learnable structure.
    For vlm/audio families the stubbed modality embeddings are included.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    text_len = seq_len - (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    base = jax.random.randint(k1, (batch_size, 1), 0, cfg.vocab_size)
    drift = jnp.cumsum(jax.random.randint(k2, (batch_size, text_len), 0, 7) - 3, axis=1)
    stream = jnp.mod(base + drift, cfg.vocab_size).astype(jnp.int32)
    tokens = stream[:, :-1]
    targets = stream[:, 1:]
    # pad to text_len (keep shapes uniform): repeat last column
    tokens = jnp.concatenate([tokens, tokens[:, -1:]], axis=1)
    targets = jnp.concatenate([targets, targets[:, -1:]], axis=1)
    batch = {
        "tokens": tokens,
        "targets": targets,
        "loss_mask": jnp.ones((batch_size, text_len), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["vision_embed"] = jax.random.normal(
            k3, (batch_size, cfg.n_vision_tokens, cfg.vision_dim), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["audio_embed"] = jax.random.normal(
            k3, (batch_size, cfg.n_audio_frames, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    return batch


def batch_spec(cfg, batch_size: int, seq_len: int, kind: str = "train"):
    """ShapeDtypeStruct stand-ins mirroring ``lm_batch`` (dry-run inputs)."""
    text_len = seq_len - (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    sds = jax.ShapeDtypeStruct
    spec = {
        "tokens": sds((batch_size, text_len), jnp.int32),
        "targets": sds((batch_size, text_len), jnp.int32),
        "loss_mask": sds((batch_size, text_len), jnp.float32),
    }
    if cfg.family == "vlm":
        spec["vision_embed"] = sds(
            (batch_size, cfg.n_vision_tokens, cfg.vision_dim), jnp.dtype(cfg.dtype)
        )
    if cfg.family == "audio":
        spec["audio_embed"] = sds(
            (batch_size, cfg.n_audio_frames, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return spec


def decode_inputs(key, cfg, batch_size: int):
    """One decode-step token batch."""
    return jax.random.randint(key, (batch_size, 1), 0, cfg.vocab_size).astype(jnp.int32)


def iterate_batches(key, cfg, batch_size, seq_len, steps):
    for i in range(steps):
        yield lm_batch(jax.random.fold_in(key, i), cfg, batch_size, seq_len)


# ------------------------------------------- classification (paper repro) --
def classification_dataset(key, n: int, d: int, n_classes: int, noise: float = 1.0):
    """Gaussian class prototypes + isotropic noise: learnable, MNIST-like
    dimensions, deterministic. Returns {"x": (n,d), "y": (n,)}."""
    kp, kx, ky = jax.random.split(key, 3)
    protos = jax.random.normal(kp, (n_classes, d)) * 2.0
    y = jax.random.randint(ky, (n,), 0, n_classes)
    x = protos[y] + jax.random.normal(kx, (n, d)) * noise
    return {"x": x.astype(jnp.float32), "y": y.astype(jnp.int32)}


def minibatches(data, batch_size: int, *, seed: int = 0, epochs: int = 1):
    """Shuffled mini-batch iterator over a classification dataset."""
    n = data["x"].shape[0]
    rng = np.random.RandomState(seed)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = perm[i : i + batch_size]
            yield {"x": data["x"][idx], "y": data["y"][idx]}
