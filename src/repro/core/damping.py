"""Levenberg–Marquardt damping adaptation (Martens 2010, paper Alg. 2 line 8).

ρ = actual reduction / predicted reduction of the quadratic model
    m(δ) = gᵀδ + ½ δᵀ(G+λI)δ.

ρ < 1/4  → trust the model less:  λ ← λ·inc
ρ > 3/4  → trust the model more:  λ ← λ/dec
"""
from __future__ import annotations

import jax.numpy as jnp

LM_LOW = 0.25
LM_HIGH = 0.75


def lm_update(lam, f_old, f_new, pred_red, *, inc=1.5, dec=1.5, lam_min=1e-8, lam_max=1e8):
    """Return (new λ, ρ). pred_red = m(δ) − m(0) (should be ≤ 0)."""
    actual = f_new - f_old
    rho = actual / jnp.minimum(pred_red, -1e-20)  # both negative if progress
    lam_new = jnp.where(rho < LM_LOW, lam * inc, jnp.where(rho > LM_HIGH, lam / dec, lam))
    # If the step was not even a descent step (rho<0 w/ pred_red<0), damp hard.
    lam_new = jnp.where(actual > 0.0, lam * inc * inc, lam_new)
    return jnp.clip(lam_new, lam_min, lam_max), rho
