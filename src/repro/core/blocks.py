"""Multi-tangent block curvature products: s tangents through one cached map.

The linearize-once engine (core/curvature.py) already makes each curvature
product a cheap cached-linear-map application — but a map application still
streams the cached linearization residuals (activations, batch intermediates)
from HBM once **per tangent**. The s-step/block-Krylov subsystem
(core/sstep.py) wants products of *several* tangents against the same
operator; applying them one at a time re-reads the residuals s times.

This module lifts the engine's single-tangent operators to **block
operators**: a stacked ``(s, ...)`` pytree of tangents (leading stack axis on
every leaf — the tree Krylov backend's native block form, and what
``FlatVectorBackend.lower_block`` produces from an ``(s, n)`` matrix) goes
through ``jax.vmap`` **over the cached linear map**, so the residuals are
read once and amortized over all s products. This works uniformly across the
engine's modes:

* ``linearize`` — vmap of the cached ``jax.linearize`` map: one residual
  sweep feeds s tangent passes (the XLA program batches the tangent matmuls;
  on TPU the weight/residual reads are shared across the s rows).
* ``chunked``   — vmap *through the ``lax.scan`` over microbatches*: the scan
  structure is preserved (still one chunk resident at a time, flat memory in
  the curvature batch) and each chunk's residuals are read once for all s
  tangents instead of once per tangent.
* ``naive``     — vmap of the per-call jvp (baseline for the perf pair;
  re-runs the primal, but still once per *block* rather than once per
  tangent).

**Reduce schedule:** ``grad_reduce`` is applied once per accumulated *block*
(one collective carrying s stacked model-sized products) — Alg. 2's
one-reduce-per-product schedule generalizes to one reduce per block product,
which is exactly the communication shape the s-step solvers batch on
(s products, one sync; see benchmarks/comm_model.py's s-step formulas).

``block_op_from_single`` is the hot-path entry: ``hf_step`` builds its
single-tangent operator once (one primal pass) and derives the block form
from the SAME cached linearization — no second primal. The standalone
``make_block_*_op`` builders mirror the curvature-engine constructors for
direct use (benchmarks, tests). ``pair_apply`` is the s-step solvers'
consumer view: the p/r polynomial chains (monomial or the shifted-Newton/
Chebyshev three-term recurrences — core/sstep.py) advance in lock-step, so
each basis level is ONE width-2 block product through the cached map; the
Gram of the finished chains then feeds the free Ritz extraction
(``core.krylov.ritz_from_segment``) that parameterizes the next cycle's
basis — no probe columns or extra products, the recurrence coefficients
already express A on the chain.

Measured: ``benchmarks/sstep_bench.py`` (block-HVP amortization rows,
EXPERIMENTS.md §Perf pair E).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from .curvature import _maybe_reduce, make_gnvp_op, make_hvp_op

Op = Callable[[Any], Any]


def stack_tangents(tangents: Sequence[Any]):
    """Stack s tangent pytrees into one block (leading s axis per leaf)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *tangents)


def unstack_tangents(block):
    """Inverse of ``stack_tangents``: block → list of s tangent pytrees."""
    leaves = jax.tree_util.tree_leaves(block)
    s = leaves[0].shape[0]
    return [jax.tree_util.tree_map(lambda x, j=j: x[j], block) for j in range(s)]


def pair_apply(be, A_, Ab_):
    """Advance two Krylov power chains one level: (A w, A u) as ONE width-2
    block curvature product when a block operator is available (the cached
    linearization residuals are read once for the pair), two singles
    otherwise. ``be`` is the Krylov vector backend, ``A_``/``Ab_`` the
    backend-wrapped single/block operators (``Ab_`` may be None)."""
    if Ab_ is None:
        return lambda w, u: (A_(w), A_(u))

    def pair(w, u):
        out = Ab_(be.block_stack([w, u]))
        return be.block_col(out, 0), be.block_col(out, 1)

    return pair


def block_op_from_single(op: Op) -> Op:
    """Lift a single-tangent operator to a block operator over the SAME
    cached linearization.

    ``op`` is an operator as the curvature engine returns it (its closure
    holds the cached linear map — and, in distributed use, the
    ``grad_reduce`` collective). ``jax.vmap`` maps it over the leading stack
    axis: one residual sweep for all s tangents, and a vmapped
    ``grad_reduce`` lowers to ONE collective carrying the stacked block
    (batching rule of ``lax.pmean``), preserving the one-reduce-per-block
    schedule.
    """
    return jax.vmap(op)


def make_block_hvp_op(
    loss_fn,
    params,
    batch,
    *,
    mode: str = "linearize",
    chunk_size: int = 0,
    remat: bool = True,
    grad_reduce: Optional[Callable[[Any], Any]] = None,
) -> Op:
    """Block Hessian operator: stacked tangents V ↦ stacked products H·V.

    Same mode semantics as ``make_hvp_op``; the primal forward+backward runs
    once at build (linearized modes) and every block application replays the
    cached map under ``jax.vmap``. ``grad_reduce`` is applied once to the
    stacked block output.
    """
    single = make_hvp_op(
        loss_fn, params, batch, mode=mode, chunk_size=chunk_size,
        remat=remat, grad_reduce=None,
    )
    blk = jax.vmap(single)

    def block_hvp(tangents):
        return _maybe_reduce(blk(tangents), grad_reduce)

    return block_hvp


def make_block_gnvp_op(
    model_out_fn,
    out_loss_fn,
    params,
    batch,
    *,
    mode: str = "linearize",
    chunk_size: int = 0,
    remat: bool = True,
    grad_reduce: Optional[Callable[[Any], Any]] = None,
) -> Op:
    """Block Gauss-Newton operator: stacked V ↦ stacked Jᵀ(∇²_z ℓ)J·V.

    The J·v / Jᵀ·u maps and the output-space Hessian are built once (one
    primal forward, as in ``make_gnvp_op``) and vmapped over the stack: the
    network residuals feed all s tangent forward/transpose passes in one
    sweep.
    """
    single = make_gnvp_op(
        model_out_fn, out_loss_fn, params, batch, mode=mode,
        chunk_size=chunk_size, remat=remat, grad_reduce=None,
    )
    blk = jax.vmap(single)

    def block_gnvp(tangents):
        return _maybe_reduce(blk(tangents), grad_reduce)

    return block_gnvp
