"""Core: the paper's distributed Hessian-free optimizer."""
from .hf import HFConfig, HFState, hf_init, hf_step, SOLVERS, SSTEP_SOLVERS
from .blocks import (
    block_op_from_single,
    make_block_gnvp_op,
    make_block_hvp_op,
    stack_tangents,
    unstack_tangents,
)
from .curvature import (
    MODES as CURVATURE_MODES,
    chunked_scalar_fn,
    make_gnvp_op,
    make_hvp_op,
    shared_primal_hvp,
    split_chunks,
)
from .hvp import fd_hvp, make_damped, make_gnvp, make_hvp
from .krylov import BACKENDS, FlatVectorBackend, TreeVectorBackend, get_backend
from .line_search import armijo
from .damping import lm_update
from .solvers import KrylovResult, bicgstab, cg, pcg, sign_correct
from .sstep import sstep_bicgstab, sstep_cg
from . import tree_math

__all__ = [
    "HFConfig", "HFState", "hf_init", "hf_step", "SOLVERS", "SSTEP_SOLVERS",
    "block_op_from_single", "make_block_gnvp_op", "make_block_hvp_op",
    "stack_tangents", "unstack_tangents",
    "CURVATURE_MODES", "chunked_scalar_fn", "make_gnvp_op", "make_hvp_op",
    "shared_primal_hvp", "split_chunks",
    "fd_hvp", "make_damped", "make_gnvp", "make_hvp",
    "BACKENDS", "FlatVectorBackend", "TreeVectorBackend", "get_backend",
    "armijo", "lm_update",
    "KrylovResult", "bicgstab", "cg", "pcg", "sign_correct",
    "sstep_bicgstab", "sstep_cg",
    "tree_math",
]
