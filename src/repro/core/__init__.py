"""Core: the paper's distributed Hessian-free optimizer."""
from .hf import HFConfig, HFState, hf_init, hf_step, SOLVERS
from .curvature import (
    MODES as CURVATURE_MODES,
    chunked_scalar_fn,
    make_gnvp_op,
    make_hvp_op,
    split_chunks,
)
from .hvp import fd_hvp, make_damped, make_gnvp, make_hvp
from .krylov import BACKENDS, FlatVectorBackend, TreeVectorBackend, get_backend
from .line_search import armijo
from .damping import lm_update
from .solvers import KrylovResult, bicgstab, cg, pcg, sign_correct
from . import tree_math

__all__ = [
    "HFConfig", "HFState", "hf_init", "hf_step", "SOLVERS",
    "CURVATURE_MODES", "chunked_scalar_fn", "make_gnvp_op", "make_hvp_op",
    "split_chunks",
    "fd_hvp", "make_damped", "make_gnvp", "make_hvp",
    "BACKENDS", "FlatVectorBackend", "TreeVectorBackend", "get_backend",
    "armijo", "lm_update",
    "KrylovResult", "bicgstab", "cg", "pcg", "sign_correct",
    "tree_math",
]
