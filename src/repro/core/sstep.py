"""Communication-avoiding (s-step) Krylov solvers over the block backend.

The paper's Fig. 5 scaling story is gated by one synchronization per Krylov
iteration: the recurrence computes a dot product, waits for the scalar, and
only then can take the next step (α and β gate everything downstream). With
the curvature product reduced to a cheap cached linear map (PR 2), that
blocking scalar round-trip is the dominant per-iteration cost at scale — it
is pure latency, and it cannot be overlapped because the recurrence is a
strict chain through it.

The s-step (communication-avoiding) reformulation (Chronopoulos & Gear;
Hoemmen; Carson) breaks the chain: each **cycle** first grows the Krylov
space s steps ahead with a *monomial basis* — matvecs only, no interleaved
scalars — then computes EVERY dot product the next s iterations will need as
one Gram matrix of the basis (``be.gram``: one reduction), and finally runs
the s iterations as scalar recurrences **in basis coordinates** (O(s²)
flops, zero communication). Blocking synchronizations per s iterations: one,
instead of s. The basis matvec *products* still move the same bytes, but
they form a dependency chain with no scalar gates — under the paper's
data-parallel schedule their reduces pipeline back-to-back instead of
alternating with scalar round-trips. ``benchmarks/comm_model.py`` carries
the resulting sync model (``1 + ceil(K/s) + E`` vs ``1 + K + E``) and
``benchmarks/sstep_bench.py`` measures the executed counts
(``KrylovResult.syncs``).

The costs, stated honestly (EXPERIMENTS.md §Perf pair E):

* **Extra operator applications.** The basis needs power chains of both the
  direction p and the residual r (they span different spaces after the first
  iteration), so a cycle performs 2s−1 (CG) / 4s−1 (Bi-CG-STAB) products for
  s iterations — asymptotically ~2× the standard recurrence's s / 2s. The
  chains advance in lock-step, so the products pair into width-2 **block
  curvature products** (``A_block`` — core/blocks.py): the cached
  linearization residuals are read once per level instead of once per
  chain, clawing back much of the overhead. s-step wins exactly when the
  latency saved by s× fewer blocking syncs exceeds the extra product
  bandwidth — the paper's small-batch / many-nodes regime, where Fig. 5
  shows synchronization is what breaks scaling.
* **Basis conditioning.** The monomial basis degenerates like the power
  method (κ(V) grows with κ(A)^s); in f32 this is THE failure mode. Every
  cycle factorizes the (normalized) Gram of each power segment — Cholesky,
  the cheapest PD certificate — and declares **breakdown** when a pivot
  collapses (or the Gram is non-finite). With ``fallback=True`` (the
  ``hf_step`` default) a breakdown hands the iterate to the standard
  solver mid-stream: correctness never depends on the basis surviving.
* **Memory.** A cycle keeps 2s+1 / 4s+1 model-sized basis vectors live
  (vs O(1) iterate vectors for the standard recurrences).

Both solvers return the same ``KrylovResult`` as ``core/solvers.py``, with
the same free byproducts: negative-curvature capture (the probe's dᵀAd and
dᵀd are Gram quadratic forms — literally free here) and, for Bi-CG-STAB,
φ-best tracking (⟨b,x⟩ and ⟨x,r⟩ come from three extra columns appended to
the same Gram reduction).

Backend story: everything runs on the ``BlockVectorBackend`` extension
(core/krylov.py) — "tree" keeps the basis as a stacked pytree
(sharding-preserving Gram via per-leaf contractions + one small all-reduce),
"flat" stacks rows into an (s, n) matrix and computes the Gram with the
fused Pallas ``dots_block`` kernel (one pass over the stacked data).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from .krylov import EPS as _EPS, NCState, best_init, BestState, guard_div, nc_init
from .solvers import KrylovResult, bicgstab, cg, _resolve
from .tree_math import tree_where

Op = Callable[[Any], Any]

# Breakdown threshold on the *normalized* Gram's Cholesky pivots: a pivot of
# p means the newest basis vector is only p away (in relative norm) from the
# span of the previous ones, so coordinate round-off is amplified by ~1/p.
# 1e-4 keeps f32 cycles that still converge cleanly (measured: depth-4/5
# chains on moderately conditioned systems sit at 2e-4..4e-3 and recover the
# standard solution to 1e-7) while catching the genuinely degenerate bases
# (deep chains / ill-conditioned operators collapse to <1e-7 or NaN).
GUARD_PIVOT = 1e-4


def _shift(segments) -> jax.Array:
    """Change-of-basis matrix T for a concatenation of monomial power chains:
    A·(V c) = V·(T c). Within each segment T maps e_j ↦ e_{j+1}; the last
    column of each segment is zero (the recurrences never reach it — that is
    precisely the s-iterations-per-cycle budget)."""
    m = sum(segments)
    T = np.zeros((m, m), np.float32)
    start = 0
    for seg in segments:
        for j in range(seg - 1):
            T[start + j + 1, start + j] = 1.0
        start += seg
    return jnp.asarray(T)


def _onehot(m: int, j: int) -> jax.Array:
    return jnp.zeros((m,), jnp.float32).at[j].set(1.0)


def _gram_ok(G, segments, guard_pivot: float) -> jax.Array:
    """Basis-conditioning guard on the Gram factorization.

    Normalizes G to a correlation matrix (so near-converged tiny residual
    chains are not flagged for scale alone) and Cholesky-factorizes each
    power segment separately — across-segment rank deficiency is legitimate
    (first cycle has p ≡ r, so the two chains coincide exactly) while
    within-segment pivot collapse is the monomial-degeneracy signal.
    """
    d = jnp.sqrt(jnp.clip(jnp.diagonal(G), 0.0))
    dn = 1.0 / jnp.maximum(d, _EPS)
    Gn = G * jnp.outer(dn, dn)
    ok = jnp.all(jnp.isfinite(G))
    start = 0
    for seg in segments:
        L = jnp.linalg.cholesky(Gn[start:start + seg, start:start + seg])
        piv = jnp.diagonal(L)
        ok = jnp.logical_and(
            ok,
            jnp.logical_and(jnp.all(jnp.isfinite(L)), jnp.min(piv) > guard_pivot),
        )
        start += seg
    return ok


def _pair_apply(be, A_, Ab_):
    """Advance both power chains one level: (A w, A u) as ONE width-2 block
    curvature product when a block operator is available (the cached
    linearization residuals are read once for the pair — core/blocks.py),
    two singles otherwise."""
    if Ab_ is None:
        return lambda w, u: (A_(w), A_(u))

    def pair(w, u):
        out = Ab_(be.block_stack([w, u]))
        return be.block_col(out, 0), be.block_col(out, 1)

    return pair


def _merge_fallback(res: KrylovResult, run_standard) -> KrylovResult:
    """On basis breakdown, hand the iterate to the standard solver (traced
    into the other ``lax.cond`` branch — it only executes on breakdown) and
    merge the byproducts: the most-negative NC direction wins, iteration and
    sync counts accumulate, and ``breakdown=True`` records that the fallback
    ran."""
    def fb(r):
        std = run_standard(r.x)
        std_better = std.nc_curv < r.nc_curv
        return KrylovResult(
            std.x, std.r, std.x_best, std.r_best,
            tree_where(std_better, std.nc_dir, r.nc_dir),
            jnp.logical_or(std.nc_found, r.nc_found),
            jnp.minimum(std.nc_curv, r.nc_curv),
            r.iters + std.iters, std.residual,
            syncs=r.syncs + std.syncs, breakdown=jnp.ones((), bool),
        )

    return jax.lax.cond(res.breakdown, fb, lambda r: r, res)


def sstep_cg(A: Op, b, x0, *, lam, s: int, max_iters: int, tol: float = 5e-3,
             backend=None, A_block: Optional[Op] = None,
             fallback: bool = True,
             guard_pivot: float = GUARD_PIVOT) -> KrylovResult:
    """s-step CG with Martens truncation and free negative-curvature capture.

    Mathematically iteration-for-iteration identical to ``solvers.cg`` (in
    exact arithmetic): each cycle builds the monomial basis
    [p, Ap, …, Aˢp, r, Ar, …, A^{s−1}r], reduces its Gram ONCE, and runs s
    CG steps in coordinates. ``A_block`` (optional) applies the operator to
    a stacked pair per chain level. ``fallback`` re-enters ``solvers.cg``
    from the current iterate if the Gram factorization flags the basis.
    """
    be = _resolve(backend)
    A_ = be.wrap_op(A)
    Ab_ = None if A_block is None else be.wrap_block_op(A_block)
    pair = _pair_apply(be, A_, Ab_)
    b_ = be.lift(b)
    x0_ = be.lift(x0)
    b_norm = be.norm(b_)
    r0 = be.sub(b_, A_(x0_))
    rr0 = be.dot(r0, r0)
    m = 2 * s + 1
    T = _shift((s + 1, s))
    e_p, e_r = _onehot(m, 0), _onehot(m, s + 1)

    def cond(carry):
        (_, _, _, _, k, done, _, _, _) = carry
        return jnp.logical_and(k < max_iters, jnp.logical_not(done))

    def body(carry):
        x, r, p, rr, k, done, brk0, nc, syncs = carry
        # ---- grow the space s steps ahead: matvecs only, no scalar gates --
        pch, rch = [p], [r]
        for _ in range(s - 1):
            w, u = pair(pch[-1], rch[-1])
            pch.append(w)
            rch.append(u)
        pch.append(A_(pch[-1]))                      # Aˢp (p-chain is longer)
        V = be.block_stack(pch + rch)
        # ---- the cycle's ONE reduction: every dot for s iterations --------
        G = be.gram(V, V)
        G = 0.5 * (G + G.T)
        syncs = syncs + 1
        brk = jnp.logical_not(_gram_ok(G, (s + 1, s), guard_pivot))

        # ---- s CG iterations as O(s²) coordinate recurrences --------------
        p_c, r_c = e_p, e_r
        x_c = jnp.zeros((m,), jnp.float32)
        rr_c = G[s + 1, s + 1]
        stop = brk
        it = jnp.zeros((), jnp.int32)
        cyc_found = jnp.zeros((), bool)
        cyc_curv = nc.curv
        cyc_imp = jnp.zeros((), bool)
        nc_c = jnp.zeros((m,), jnp.float32)
        for j in range(s):
            active = jnp.logical_and(jnp.logical_not(stop), k + j < max_iters)
            Tp = T @ p_c
            pAp = p_c @ (G @ Tp)
            p_sq = p_c @ (G @ p_c)
            # NC probe — the (dᵀAd, dᵀd) pair is two Gram quadratic forms
            raw = (pAp - lam * p_sq) / jnp.maximum(p_sq, _EPS)
            is_nc = jnp.logical_and(active, raw < 0.0)
            better = jnp.logical_and(is_nc, raw < cyc_curv)
            nc_c = jnp.where(
                better, p_c / jnp.sqrt(jnp.maximum(p_sq, _EPS)), nc_c
            )
            cyc_curv = jnp.where(better, raw, cyc_curv)
            cyc_imp = jnp.logical_or(cyc_imp, better)
            cyc_found = jnp.logical_or(cyc_found, is_nc)
            # Martens truncation — same freeze semantics as solvers._cg_engine
            trunc = pAp <= _EPS
            step_ok = jnp.logical_and(active, jnp.logical_not(trunc))
            alpha = rr_c / jnp.maximum(pAp, _EPS)
            x_c = jnp.where(step_ok, x_c + alpha * p_c, x_c)
            r_new = r_c - alpha * Tp
            rr_new = r_new @ (G @ r_new)
            beta = rr_new / jnp.maximum(rr_c, _EPS)
            p_new = r_new + beta * p_c
            r_c = jnp.where(step_ok, r_new, r_c)
            p_c = jnp.where(step_ok, p_new, p_c)
            rr_c = jnp.where(step_ok, rr_new, rr_c)
            it = it + active.astype(jnp.int32)
            conv = jnp.sqrt(jnp.maximum(rr_c, 0.0)) < tol * b_norm
            stop = jnp.logical_or(
                stop,
                jnp.logical_or(jnp.logical_and(active, trunc),
                               jnp.logical_and(step_ok, conv)),
            )

        # ---- materialize the cycle: one combined pass over the basis ------
        # On basis breakdown the coords are still the one-hot inits, but the
        # overflowed basis may hold inf (0·inf = NaN in the combine) — keep
        # the carried vectors instead.
        out = be.block_combine(jnp.stack([x_c, r_c, p_c, nc_c]), V)
        x = be.where(brk, x, be.axpy(1.0, be.block_col(out, 0), x))
        r = be.where(brk, r, be.block_col(out, 1))
        p = be.where(brk, p, be.block_col(out, 2))
        nc = NCState(
            jnp.logical_or(nc.found, cyc_found),
            be.where(cyc_imp, be.block_col(out, 3), nc.dir),
            jnp.where(cyc_imp, cyc_curv, nc.curv),
        )
        return (x, r, p, rr_c, k + it, stop, jnp.logical_or(brk0, brk),
                nc, syncs)

    init = (
        x0_, r0, r0, rr0, jnp.zeros((), jnp.int32),
        jnp.sqrt(rr0) < tol * b_norm, jnp.zeros((), bool),
        nc_init(be, b_), jnp.zeros((), jnp.int32),
    )
    x, r, _, rr, k, _, brk, nc, syncs = jax.lax.while_loop(cond, body, init)
    x, r, nc_dir = be.lower(x), be.lower(r), be.lower(nc.dir)
    res = KrylovResult(x, r, x, r, nc_dir, nc.found, nc.curv, k,
                       jnp.sqrt(jnp.maximum(rr, 0.0)),
                       syncs=syncs, breakdown=brk)
    if not fallback:
        return res
    return _merge_fallback(
        res,
        lambda xs: cg(A, b, xs, lam=lam, max_iters=max_iters, tol=tol,
                      backend=backend),
    )


def sstep_bicgstab(A: Op, b, x0, *, lam, s: int, max_iters: int,
                   tol: float = 5e-3, backend=None,
                   A_block: Optional[Op] = None,
                   fallback: bool = True,
                   guard_pivot: float = GUARD_PIVOT) -> KrylovResult:
    """s-step Bi-CG-STAB (CA-BICGSTAB, Carson) with NC capture and φ-best.

    Each cycle builds [p, Ap, …, A²ˢp, r, Ar, …, A^{2s−1}r] (an iteration
    applies A twice, so the chains run 2s deep for s iterations), appends
    three probe columns [r0*, b, x] to the Gram's right operand — ⟨·,r0*⟩
    drives ρ/α, ⟨·,b⟩ and ⟨·,x⟩ make the φ-best tracker free — and reduces
    everything in ONE ``be.gram`` call. Breakdown covers both the
    Gram-factorization guard and ``solvers.bicgstab``'s ρ/ω collapse (which
    freezes the iterate, like the standard solver, and is reported in
    ``KrylovResult.breakdown``); with ``fallback`` either kind re-enters
    the standard solver from the current iterate — for ρ/ω collapse that
    restart draws a fresh shadow residual r0*, the classic recovery.
    """
    be = _resolve(backend)
    A_ = be.wrap_op(A)
    Ab_ = None if A_block is None else be.wrap_block_op(A_block)
    pair = _pair_apply(be, A_, Ab_)
    b_ = be.lift(b)
    x0_ = be.lift(x0)
    b_norm = be.norm(b_)
    r0 = be.sub(b_, A_(x0_))
    r0_star = r0
    rn0 = be.norm(r0)
    bx0 = be.dot(b_, x0_)
    m = 4 * s + 1
    T = _shift((2 * s + 1, 2 * s))
    e_p, e_r = _onehot(m, 0), _onehot(m, 2 * s + 1)

    def cond(carry):
        (_, _, _, _, _, k, done, _, _, _, _) = carry
        return jnp.logical_and(k < max_iters, jnp.logical_not(done))

    def body(carry):
        x, r, p, bx, rr, k, done, brk0, nc, best, syncs = carry
        # ---- power chains, 2s deep (two A-applications per iteration) -----
        pch, rch = [p], [r]
        for _ in range(2 * s - 1):
            w, u = pair(pch[-1], rch[-1])
            pch.append(w)
            rch.append(u)
        pch.append(A_(pch[-1]))                     # A²ˢp
        cols = pch + rch
        V = be.block_stack(cols)
        W = be.block_stack(cols + [r0_star, b_, x])
        # ---- ONE reduction: basis Gram + the r0*/b/x probe columns --------
        Ge = be.gram(V, W)
        G = 0.5 * (Ge[:, :m] + Ge[:, :m].T)
        g_r0, g_b, g_x0 = Ge[:, m], Ge[:, m + 1], Ge[:, m + 2]
        syncs = syncs + 1
        brk_basis = jnp.logical_not(_gram_ok(G, (2 * s + 1, 2 * s), guard_pivot))

        # ---- s Bi-CG-STAB iterations in coordinates -----------------------
        p_c, r_c = e_p, e_r
        x_c = jnp.zeros((m,), jnp.float32)
        rho = g_r0[2 * s + 1]
        rr_c = G[2 * s + 1, 2 * s + 1]
        stop = brk_basis
        it = jnp.zeros((), jnp.int32)
        brk_rec = jnp.zeros((), bool)
        cyc_found = jnp.zeros((), bool)
        cyc_curv = nc.curv
        cyc_imp = jnp.zeros((), bool)
        nc_c = jnp.zeros((m,), jnp.float32)
        best_xc = jnp.zeros((m,), jnp.float32)
        best_rc = jnp.zeros((m,), jnp.float32)
        best_phi = best.phi
        best_imp = jnp.zeros((), bool)

        def probe(active, cand_c, quad, sq, state):
            nc_c, cyc_curv, cyc_imp, cyc_found = state
            raw = (quad - lam * sq) / jnp.maximum(sq, _EPS)
            is_nc = jnp.logical_and(active, raw < 0.0)
            better = jnp.logical_and(is_nc, raw < cyc_curv)
            nc_c = jnp.where(
                better, cand_c / jnp.sqrt(jnp.maximum(sq, _EPS)), nc_c
            )
            return (nc_c, jnp.where(better, raw, cyc_curv),
                    jnp.logical_or(cyc_imp, better),
                    jnp.logical_or(cyc_found, is_nc))

        for j in range(s):
            active = jnp.logical_and(jnp.logical_not(stop), k + j < max_iters)
            v_c = T @ p_c                                    # A p̂_j
            Gv = G @ v_c
            pAp = p_c @ Gv
            p_sq = p_c @ (G @ p_c)
            nc_state = probe(active, p_c, pAp, p_sq,
                             (nc_c, cyc_curv, cyc_imp, cyc_found))
            alpha, bka = guard_div(rho, v_c @ g_r0)
            s_c = r_c - alpha * v_c                          # ŝ_j
            t_c = T @ s_c                                    # A ŝ_j
            Gt = G @ t_c
            ts = s_c @ Gt
            ss = s_c @ (G @ s_c)
            nc_c, cyc_curv, cyc_imp, cyc_found = probe(
                active, s_c, ts, ss, nc_state)
            tt = t_c @ Gt
            bkg = tt < _EPS
            gamma = ts / jnp.where(bkg, 1.0, tt)
            x_new = x_c + alpha * p_c + gamma * s_c
            r_new = s_c - gamma * t_c
            rho_new = r_new @ g_r0
            rr_new = r_new @ (G @ r_new)
            beta = (rho_new / jnp.where(jnp.abs(rho) < _EPS, 1.0, rho)) * (
                alpha / jnp.where(jnp.abs(gamma) < _EPS, 1.0, gamma)
            )
            p_new = r_new + beta * (p_c - gamma * v_c)
            bk = jnp.logical_or(bka, bkg)
            step_ok = jnp.logical_and(active, jnp.logical_not(bk))
            x_c = jnp.where(step_ok, x_new, x_c)
            r_c = jnp.where(step_ok, r_new, r_c)
            p_c = jnp.where(step_ok, p_new, p_c)
            rho = jnp.where(step_ok, rho_new, rho)
            rr_c = jnp.where(step_ok, rr_new, rr_c)
            # φ-best: ⟨b,x⟩ and ⟨x,r⟩ from the probe columns — no extra dots
            phi = -0.5 * (bx + g_b @ x_c) - 0.5 * (
                g_x0 @ r_c + x_c @ (G @ r_c)
            )
            improved = jnp.logical_and(step_ok, phi < best_phi)
            best_xc = jnp.where(improved, x_c, best_xc)
            best_rc = jnp.where(improved, r_c, best_rc)
            best_phi = jnp.where(improved, phi, best_phi)
            best_imp = jnp.logical_or(best_imp, improved)
            it = it + active.astype(jnp.int32)
            brk_rec = jnp.logical_or(brk_rec, jnp.logical_and(active, bk))
            conv = jnp.sqrt(jnp.maximum(rr_c, 0.0)) < tol * b_norm
            stop = jnp.logical_or(
                stop,
                jnp.logical_or(jnp.logical_and(active, bk),
                               jnp.logical_and(step_ok, conv)),
            )

        # ---- materialize the cycle ----------------------------------------
        # On basis breakdown the coords are still the one-hot inits, but the
        # overflowed basis may hold inf (0·inf = NaN in the combine) — keep
        # the carried vectors/scalars instead.
        out = be.block_combine(
            jnp.stack([x_c, r_c, p_c, nc_c, best_xc, best_rc]), V
        )
        x_new_v = be.where(
            brk_basis, x, be.axpy(1.0, be.block_col(out, 0), x))
        xb_v = be.axpy(1.0, be.block_col(out, 4), x)  # x_start + V·best_xc
        best = BestState(
            be.where(best_imp, xb_v, best.x),
            be.where(best_imp, be.block_col(out, 5), best.r),
            best_phi,
        )
        nc = NCState(
            jnp.logical_or(nc.found, cyc_found),
            be.where(cyc_imp, be.block_col(out, 3), nc.dir),
            jnp.where(cyc_imp, cyc_curv, nc.curv),
        )
        # Recurrence (ρ/ω) collapse is a breakdown too: reporting it keeps
        # parity with solvers.bicgstab's breakdown flag, and routing it
        # through the fallback restarts the standard solver with a FRESH
        # r0* from the frozen iterate — the classic Bi-CG-STAB restart
        # remedy, which typically recovers where the stale shadow residual
        # cannot.
        return (x_new_v,
                be.where(brk_basis, r, be.block_col(out, 1)),
                be.where(brk_basis, p, be.block_col(out, 2)),
                jnp.where(brk_basis, bx, bx + g_b @ x_c), rr_c, k + it, stop,
                jnp.logical_or(brk0, jnp.logical_or(brk_basis, brk_rec)),
                nc, best, syncs)

    init = (
        x0_, r0, r0, bx0, rn0 * rn0, jnp.zeros((), jnp.int32),
        rn0 < tol * b_norm, jnp.zeros((), bool), nc_init(be, b_),
        best_init(be, b_, x0_, r0), jnp.zeros((), jnp.int32),
    )
    x, r, _, _, _, k, _, brk, nc, best, syncs = jax.lax.while_loop(
        cond, body, init)
    res = KrylovResult(
        be.lower(x), be.lower(r), be.lower(best.x), be.lower(best.r),
        be.lower(nc.dir), nc.found, nc.curv, k, be.norm(r),
        syncs=syncs, breakdown=brk,
    )
    if not fallback:
        return res
    return _merge_fallback(
        res,
        lambda xs: bicgstab(A, b, xs, lam=lam, max_iters=max_iters, tol=tol,
                            backend=backend),
    )
