"""Distributed Hessian-free optimizer — paper Algorithm 2 as one jitted step.

Variants (``HFConfig.solver``):
  * ``"gn_cg"``      — Martens' HF: Gauss-Newton operator + CG (PSD; baseline).
  * ``"hessian_cg"`` — exact stochastic Hessian + truncated CG (paper shows
                       this is unstable — reproduced as a baseline).
  * ``"hybrid_cg"``  — exact Hessian CG; after an iteration that encountered
                       negative curvature, the *next* iteration uses the
                       Gauss-Newton operator, then switches back (paper §5).
  * ``"bicgstab"``   — the paper's contribution: Bi-CG-STAB on the indefinite
                       exact Hessian; negative-curvature directions are
                       captured and used as saddle-escape steps.

The step is pure and jittable; under pjit with the batch sharded over
("pod","data") every gradient / HVP / line-search loss evaluation contains
exactly one logical all-reduce — the paper's MPI schedule (one reduce for g,
one per Krylov iteration, one per line-search trial).

The inner Krylov solve runs on a swappable vector backend
(``HFConfig.krylov_backend``): "tree" (pytree iterates, sharding-preserving)
or "flat" (ravelled f32 iterates through the fused Pallas kernels — see
core.krylov). Both yield the same KrylovResult; solver math is identical.

The curvature operator itself comes from the curvature engine
(``HFConfig.curvature_mode`` — core.curvature): the default "linearize" mode
runs the primal forward/backward once per outer step and feeds the Krylov
loop the cached linear map; "chunked" adds flat-memory accumulation over
``curvature_chunk_size``-example microbatches for the paper's Fig. 4
large-curvature-batch regime. When the curvature mini-batch is the full
batch, a single ``jax.linearize(jax.value_and_grad(loss))`` pass yields f0,
g AND the cached Hessian map together (shared primal — one fewer
forward+backward per outer step).

``HFConfig.sstep_s > 1`` swaps the Krylov solve for its s-step
(communication-avoiding) form (core.sstep): per cycle of s iterations the
solver grows a polynomial basis (``HFConfig.sstep_basis``: monomial power
chains, or Ritz-parameterized shifted-Newton/Chebyshev chains that double
the usable depth) with width-2 *block* curvature products (core.blocks —
same cached linearization, residuals read once per pair) and collapses all
of the cycle's dot products into ONE Gram reduction — ``1 + ceil(K/s) + E``
blocking reduces per outer step instead of ``1 + K + E``
(benchmarks/comm_model.py), with a Gram-factorization guard whose fallback
chain (adaptive basis → monomial → standard solver) never lets correctness
depend on a basis surviving.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import damping as damping_mod
from .blocks import block_op_from_single
from .curvature import (
    MODES as CURVATURE_MODES,
    make_damped,
    make_gnvp_op,
    make_hvp_op,
    shared_primal_hvp,
)
from ..kernels.flash_ad import second_order_tangents
from ..obs import telemetry as _telemetry
from .krylov import BACKENDS, get_backend
from .line_search import armijo
from .solvers import bicgstab, cg, hutchinson_diag, pcg, sign_correct
from .sstep import BASES as SSTEP_BASES, sstep_bicgstab, sstep_cg
from .tree_math import (
    tree_axpy,
    tree_axpy_cast,
    tree_dot,
    tree_norm,
    tree_pseudo_noise,
    tree_scale,
    tree_where,
    tree_zeros_like,
)

SOLVERS = ("gn_cg", "hessian_cg", "hybrid_cg", "bicgstab")
SSTEP_SOLVERS = ("auto", "cg", "bicgstab")
NC_MODES = ("truncate", "escape")

# The complete per-step metrics contract of ``hf_step``: every key it
# returns, each a finite scalar (asserted by tests/test_telemetry.py's
# metrics-contract test; hf_step itself checks the key set at trace time).
# The train loop adds host-side fields on top — "step", "wall_s" and (step
# 0 only) "compile_s" — which are NOT part of this in-jit contract.
METRICS_SCHEMA = (
    "loss", "loss_new", "grad_norm", "lambda", "rho", "alpha", "ls_evals",
    "cg_iters", "cg_residual", "krylov_syncs", "blocking_syncs",
    "sstep_fallback", "sstep_basis_fallback", "sstep_basis_degraded",
    "nc_found", "nc_used", "nc_curv", "nc_lambda", "step_norm", "used_gn",
    "step_rejected",
)


@dataclasses.dataclass(frozen=True)
class HFConfig:
    solver: str = "bicgstab"
    max_cg_iters: int = 16
    cg_tol: float = 5e-3
    init_damping: float = 1.0
    damping_inc: float = 1.5
    damping_dec: float = 1.5
    cg_decay: float = 0.95        # η: Krylov warm-start θ_0 = η δ_{k-1}
    ls_c: float = 1e-2            # Armijo sufficient-decrease constant
    ls_beta: float = 0.5
    max_backtracks: int = 12
    # Relative jitter on the Krylov warm start. Enriches the Krylov space with
    # directions orthogonal to g so negative curvature invisible to the exact
    # deterministic recurrence (g ⟂ eigenvector, e.g. the Fig. 2 saddle) is
    # still discoverable — the same role mini-batch Hessian noise plays in the
    # paper's stochastic setting, made deterministic and controllable.
    krylov_jitter: float = 1e-3
    # Minimum norm for a negative-curvature step: along NC directions the
    # quadratic model is unbounded below so it prescribes no scale; we take at
    # least this much and let the Armijo search (Alg. 2 line 9) globalize it.
    nc_min_step: float = 0.1
    # What to do when the NC probe fires (the paper's differentiator over
    # Martens-style HF is exploiting indefinite curvature):
    #   * "truncate" — the historical passive policy: the NC direction
    #     competes with the solver iterate under the damped quadratic model
    #     at the solution's norm scale (floored at nc_min_step).
    #   * "escape"   — saddle-free offense (Arjovsky, arXiv:1506.00059):
    #     an explicit escape step along the NC direction scaled by
    #     |λ_min(G)|, the solver's eigenvalue estimate threaded through
    #     KrylovResult.nc_lambda (Rayleigh quotient from the standard
    #     recurrences, refined by per-cycle Ritz values from the s-step
    #     Grams — free, no extra reductions). The candidate is judged by
    #     the RAW (undamped) model, which is unbounded below along true NC,
    #     so a fired probe nearly always takes the escape step; the Armijo
    #     search globalizes it and the divergence sentinel
    #     (reject_nonfinite) guards the new step family — a non-finite λ
    #     estimate yields a non-finite step that is REJECTED, never
    #     silently masked.
    nc_mode: str = "truncate"
    # Jacobi preconditioning: M = (|diag(Ĝ)| + λ)^α estimated by one
    # Hutchinson probe per step. CG-family solvers use PCG; Bi-CG-STAB uses
    # its right-preconditioned form. The paper omits it ("not much helpful,
    # more computation and storage") — off by default, available for the
    # ill-conditioned regimes where it does pay.
    precondition: bool = False
    precond_alpha: float = 0.75
    # Krylov vector backend (core.krylov): "tree" keeps iterates as pytrees
    # (sharding-preserving; right when params are sharded under pjit);
    # "flat" ravels them once per solve and runs the recurrences through the
    # fused Pallas kernels (right for per-chip-replicated Krylov state, the
    # paper's pure data-parallel setting; interpret-mode off-TPU).
    krylov_backend: str = "tree"
    # Curvature engine (core.curvature): "linearize" runs the primal
    # forward/backward once per outer step and each Krylov iteration applies
    # only the cached linear map; "chunked" additionally accumulates G·v over
    # lax.scan microbatches of `curvature_chunk_size` examples (flat memory
    # in the curvature batch — paper Fig. 4's 10× larger hvp batches);
    # "naive" is the historical rebuild-per-call closure (baselines,
    # EXPERIMENTS.md §Perf pair D).
    curvature_mode: str = "linearize"
    curvature_chunk_size: int = 0     # examples per microbatch (chunked mode;
                                      # <=0 or >=batch ⇒ one whole-batch chunk)
    curvature_remat: bool = True      # jax.checkpoint the chunk body (chunked
                                      # HVP; chunked GN is flat-memory as-is)
    # s-step (communication-avoiding) Krylov solve (core.sstep): sstep_s > 1
    # replaces the standard recurrence with the s-step form — per cycle of s
    # iterations the solver grows a polynomial basis (matvecs only, paired
    # into width-2 block curvature products through the SAME cached
    # linearization) and issues ONE Gram reduction in place of s
    # per-iteration dot syncs (1 + ceil(K/s) + E reduces per outer step vs
    # 1 + K + E — see benchmarks/comm_model.py). A Gram-factorization guard
    # falls back to the standard solver when the basis conditioning
    # degrades, so correctness never depends on the basis surviving.
    # sstep_solver picks the s-step recurrence: "auto" derives it from
    # `solver` (bicgstab ⇒ s-step Bi-CG-STAB, the CG family ⇒ s-step CG);
    # "cg"/"bicgstab" force one. Incompatible with `precondition` (the
    # s-step recurrences are unpreconditioned; rejected at config time).
    sstep_s: int = 1
    sstep_solver: str = "auto"
    # Basis polynomial for the s-step chains (core.sstep.BASES):
    # "monomial" is the classic power chain — simple, but its f32 depth
    # budget caps usable s at ~4 (CG) / 2 (Bi-CG-STAB); "newton"
    # (Leja-ordered shifted-Newton) and "chebyshev" (Ritz-interval
    # Chebyshev) are conditioned bases parameterized by Ritz estimates the
    # cycle Gram already contains for free (bootstrapped from one f32-safe
    # monomial cycle, refreshed every cycle inside the jitted loop) — they
    # roughly double usable s (CG s=8, Bi-CG-STAB s=4: EXPERIMENTS.md
    # §Perf pair G), with a fallback chain Newton/Chebyshev → monomial →
    # standard solver on guard failure.
    sstep_basis: str = "monomial"
    # Overlapped collective schedule (the executed Fig. 5 harness's
    # double-buffered mode — benchmarks/fig5_scaling.py --executed):
    #   * s-step cycles are double-buffered (core.sstep overlap=True): two
    #     cycles share one Gram reduction, its all-reduce hidden behind the
    #     second cycle's chain growth; the speculative deep half runs under
    #     the depth-resolved prefix guard, so it never converges worse than
    #     the non-overlapped schedule at the same s.
    #   * the gradient all-reduce is issued concurrently with the curvature
    #     engine's primal build (no data dependence) instead of gating it —
    #     its latency hides behind a model-sized forward.
    #   * the Armijo search evaluates candidate PAIRS per trip
    #     (core.line_search paired=True): same accepted α, ⌈E/2⌉ blocking
    #     scalar round-trips instead of E.
    # metrics["blocking_syncs"] reports the executed blocking count either
    # way; benchmarks/comm_model.py carries the overlap=True formula.
    overlap: bool = False
    # Divergence sentinel (robustness — see tests/test_hf_robustness.py and
    # benchmarks/chaos_check.py). The repo deliberately runs INDEFINITE
    # stochastic Hessians through Bi-CG-STAB, so a poisoned curvature batch
    # (NaN/Inf activations, corrupted shard) can hand the line search a
    # non-finite direction; without a guard the `0 * NaN = NaN` update
    # poisons the parameters forever. With ``reject_nonfinite`` (default
    # on) an outer step whose accepted loss or step norm is non-finite is
    # REJECTED: params and warm start are kept, λ is boosted through the
    # existing Levenberg-Marquardt machinery (``reject_boost``; 0 ⇒
    # damping_inc²), and metrics["step_rejected"] / a telemetry fault
    # event record it. ``strict_descent`` additionally rejects any step
    # whose new loss exceeds f0 + descent_guard·max(1, |f0|) — off by
    # default (the Armijo search already enforces sufficient decrease;
    # strict mode is for chaos/fault-injection runs where the loss itself
    # may be computed from poisoned data).
    reject_nonfinite: bool = True
    strict_descent: bool = False
    descent_guard: float = 0.0
    reject_boost: float = 0.0

    def __post_init__(self):
        if self.solver not in SOLVERS:
            raise ValueError(f"solver must be one of {SOLVERS}, got {self.solver!r}")
        if self.krylov_backend not in BACKENDS:
            raise ValueError(
                f"krylov_backend must be one of {BACKENDS}, got {self.krylov_backend!r}"
            )
        if self.curvature_mode not in CURVATURE_MODES:
            raise ValueError(
                f"curvature_mode must be one of {CURVATURE_MODES}, "
                f"got {self.curvature_mode!r}"
            )
        if self.sstep_solver not in SSTEP_SOLVERS:
            raise ValueError(
                f"sstep_solver must be one of {SSTEP_SOLVERS}, "
                f"got {self.sstep_solver!r}"
            )
        if self.sstep_basis not in SSTEP_BASES:
            raise ValueError(
                f"sstep_basis must be one of {SSTEP_BASES}, "
                f"got {self.sstep_basis!r}"
            )
        if self.nc_mode not in NC_MODES:
            raise ValueError(
                f"nc_mode must be one of {NC_MODES}, got {self.nc_mode!r}"
            )
        if self.sstep_s > 1 and self.precondition:
            raise ValueError(
                "sstep_s > 1 is incompatible with precondition=True: the "
                "s-step recurrences are unpreconditioned (use the standard "
                "solvers for Jacobi preconditioning)"
            )


class HFState(NamedTuple):
    lam: jax.Array          # λ damping
    prev_delta: Any         # δ_{k-1} for Krylov warm start
    use_gn: jax.Array       # hybrid flag: this iteration uses GN operator
    step: jax.Array


def hf_init(params, config: HFConfig) -> HFState:
    return HFState(
        lam=jnp.asarray(config.init_damping, jnp.float32),
        # Krylov warm-start lives in f32 even for bf16 params (recurrence
        # numerics); the HVP operator casts at its boundary.
        prev_delta=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        ),
        use_gn=jnp.zeros((), bool),
        step=jnp.zeros((), jnp.int32),
    )


def hf_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    params,
    state: HFState,
    batch,
    hvp_batch,
    config: HFConfig,
    model_out_fn: Optional[Callable[[Any, Any], jax.Array]] = None,
    out_loss_fn: Optional[Callable[[jax.Array, Any], jax.Array]] = None,
    grad_reduce: Optional[Callable[[Any], Any]] = None,
):
    """One outer HF iteration. Returns (params, state, metrics).

    ``batch``     — the full (global) batch: gradient + line search.
    ``hvp_batch`` — the mini-batch for stochastic curvature (may be a slice of
                    ``batch``; larger ⇒ better Hessian approximation, the
                    paper's Fig. 4 batch-size scaling).
    ``model_out_fn``/``out_loss_fn`` — network/loss split, required for the
    Gauss-Newton operator (``gn_cg`` and ``hybrid_cg``).
    ``grad_reduce`` — completion collective for AD results under explicit
    data parallelism (shard_map): applied to the gradient and to every
    curvature-operator output. Reverse-mode through a pmean'd loss yields
    each worker's full *local* contribution (the reduction the paper's
    "reduce to root" performs is not inserted by the transpose); the
    distributed wrapper passes ``lax.pmean`` here — Alg. 2's one reduce for
    g and one per Krylov iteration, made explicit. Under pjit/GSPMD leave it
    None (the partitioner inserts the collectives from sharding
    propagation).
    """
    needs_gn = config.solver in ("gn_cg", "hybrid_cg")
    if needs_gn and (model_out_fn is None or out_loss_fn is None):
        raise ValueError(f"solver {config.solver} requires model_out_fn/out_loss_fn")

    # ---- Alg.2 lines 3-5: gradient + stochastic curvature operator ---------
    # Curvature operators are built once per outer step by the curvature
    # engine: in "linearize"/"chunked" modes the primal forward+backward runs
    # HERE (hoisted out of the Krylov loop — and, for the hybrid solver, out
    # of the lax.cond branches, which XLA never hoists itself) and every
    # operator application below executes only the cached linear map.
    # grad_reduce is applied inside the engine, once per accumulated product.
    curv_kw = dict(
        mode=config.curvature_mode, chunk_size=config.curvature_chunk_size,
        remat=config.curvature_remat, grad_reduce=grad_reduce,
    )
    # Shared primal: when the curvature mini-batch IS the gradient batch and
    # the solver wants the exact Hessian, one jax.linearize(value_and_grad)
    # yields f0, g AND the cached Hessian map from a single forward+backward
    # (core.curvature.shared_primal_hvp) — one fewer primal pass per outer
    # step than value_and_grad + a separate engine build.
    shared = (
        config.curvature_mode == "linearize"
        and hvp_batch is batch
        and config.solver != "gn_cg"
    )
    # Telemetry (repro.obs): phase end-markers + the grad-reduce collective
    # label. Every hook is a trace-time no-op unless a sink is installed —
    # the disabled jaxpr is identical to the un-instrumented program
    # (tests/test_telemetry.py). step_scope hands state.step to markers
    # emitted from the curvature engine / s-step solvers.
    _telemetry.marker("step_begin", batch, step=state.step)
    with _telemetry.step_scope(state.step):
        if shared:
            with _telemetry.collective_label("grad_reduce"):
                f0, g, exact = shared_primal_hvp(
                    loss_fn, params, batch, grad_reduce=grad_reduce
                )
        else:
            # ---- Alg.2 lines 3-4: full gradient (all-reduce under pjit) ----
            f0, g = jax.value_and_grad(loss_fn)(params, batch)
            _telemetry.marker("grad_build", f0, g, step=state.step)
            if grad_reduce is not None and not config.overlap:
                with _telemetry.collective_label("grad_reduce"):
                    g = grad_reduce(g)
                # Blocking schedule: close the reduce-wait explicitly so the
                # reconstructed curvature-primal span starts AFTER the psum
                # (the collective must show zero overlap with the build).
                _telemetry.marker("grad_reduce", g, step=state.step)
            # Only build the operators the solver will apply: in the
            # linearized modes construction itself runs a primal pass
            # (eagerly, outside jit).
            if config.solver != "gn_cg":
                exact = make_hvp_op(loss_fn, params, hvp_batch, **curv_kw)
        if needs_gn:
            if config.sstep_s > 1:
                # The s-step solve lifts its operator to stacked
                # multi-tangent blocks via jax.vmap (core/blocks.py). The
                # flash-attention first-order GN tangent (linear_call) has no
                # batching rule, so build the GN operator under the AD-closed
                # second-order rules — plain jnp, vmappable, same math; a
                # no-op for models that don't use flash attention
                # (kernels/flash_ad.py).
                with second_order_tangents():
                    gn = make_gnvp_op(model_out_fn, out_loss_fn, params,
                                      hvp_batch, **curv_kw)
            else:
                gn = make_gnvp_op(model_out_fn, out_loss_fn, params,
                                  hvp_batch, **curv_kw)
        if not shared and grad_reduce is not None and config.overlap:
            # Hidden grad-reduce (overlapped schedule): the model-sized
            # gradient all-reduce has no data dependence on the curvature
            # engine's primal build, so issuing it AFTER the operator
            # construction above lets the scheduler run the collective
            # concurrently with that forward — its first consumer is the
            # Krylov right-hand side, by which point the reduce has
            # completed. Counted as 0 blocking round-trips in
            # metrics["blocking_syncs"]. (The telemetry span of this very
            # collective — begin at input-ready, end at completion — is how
            # the overlap is MEASURED: obs/trace.py grad_reduce_overlap.)
            with _telemetry.collective_label("grad_reduce"):
                g = grad_reduce(g)
    if config.solver == "gn_cg":
        G = gn
    elif config.solver in ("hessian_cg", "bicgstab"):
        G = exact
    else:  # hybrid: runtime switch between the two cached linear maps
        def G(v, _state_use_gn=state.use_gn):
            return jax.lax.cond(_state_use_gn, gn, exact, v)

    lam = state.lam
    A = make_damped(G, lam)
    b = jax.tree_util.tree_map(lambda x: -x.astype(jnp.float32), g)
    x0 = tree_scale(config.cg_decay, state.prev_delta)
    if config.krylov_jitter > 0.0:
        # Sharding-preserving pseudo-noise (NOT jax.random — see
        # tree_math.tree_pseudo_noise): seeded by the gradient values, the
        # element position and the step counter.
        jit_tree = tree_pseudo_noise(g, state.step)
        scale = config.krylov_jitter * jnp.maximum(tree_norm(g), 1e-8) / jnp.maximum(
            tree_norm(jit_tree), 1e-20
        )
        x0 = tree_axpy(scale, jit_tree, x0)

    # ---- Alg.2 line 6: Krylov solve ----------------------------------------
    # Vector backend: "tree" keeps the solve on sharding-preserving pytrees;
    # "flat" ravels once and runs the recurrences via the fused Pallas kernels.
    krylov_be = get_backend(config.krylov_backend, template=b)
    m_inv = None
    if config.precondition:
        # The probe reuses the prebuilt operator G — under the linearized
        # modes each Hutchinson sample is one cached-linear-map application,
        # not a fresh re-linearization (EXPERIMENTS.md §Perf pair D).
        diag = hutchinson_diag(G, b, state.step)
        m_inv = jax.tree_util.tree_map(
            lambda d: 1.0 / (jnp.abs(d) + lam) ** config.precond_alpha, diag
        )
    with _telemetry.step_scope(state.step):
        if config.sstep_s > 1:
            # s-step (communication-avoiding) solve: ONE Gram reduction per
            # cycle of sstep_s iterations, basis power chains paired into
            # width-2 block curvature products derived from the SAME cached
            # linearization as A (core.blocks.block_op_from_single — jax.vmap
            # over the operator, no second primal pass). Falls back to the
            # standard solver on basis-conditioning breakdown.
            kind = config.sstep_solver
            if kind == "auto":
                kind = "bicgstab" if config.solver == "bicgstab" else "cg"
            sstep_fn = sstep_bicgstab if kind == "bicgstab" else sstep_cg
            res = sstep_fn(
                A, b, x0, lam=lam, s=config.sstep_s,
                max_iters=config.max_cg_iters, tol=config.cg_tol,
                backend=krylov_be, A_block=block_op_from_single(A),
                basis=config.sstep_basis, overlap=config.overlap,
            )
        elif config.solver == "bicgstab":
            res = bicgstab(A, b, x0, lam=lam, max_iters=config.max_cg_iters,
                           tol=config.cg_tol, M_inv=m_inv, backend=krylov_be)
        elif m_inv is not None:
            res = pcg(A, b, x0, lam=lam, M_inv=m_inv,
                      max_iters=config.max_cg_iters, tol=config.cg_tol,
                      backend=krylov_be)
        else:
            res = cg(A, b, x0, lam=lam, max_iters=config.max_cg_iters,
                     tol=config.cg_tol, backend=krylov_be)
    _telemetry.marker("krylov_solve", res.residual, res.x, step=state.step)
    _telemetry.solve_event(
        state.step, iters=res.iters, residual=res.residual, syncs=res.syncs,
        residual_history=res.residual_history, nc_found=res.nc_found,
        breakdown=res.breakdown,
    )

    # ---- Alg.2 line 7: best descent direction among {solution, NC dir} -----
    # Quadratic-model values come FREE from solver byproducts — no extra
    # operator applications (each would cost a full HVP = 2 passes over the
    # network; see EXPERIMENTS.md §Perf pair C):
    #   A·x = b − r  (residual identity)  ⇒ m(s·x) = s·gᵀx + ½ xᵀ(b−r)
    #   nc_dir has unit norm and measured raw curvature c = dᵀGd
    #                                      ⇒ m(nc) = gᵀnc + ½ (c+λ)·‖nc‖²
    # free CG-backtracking: the direction candidate is the best-model iterate
    gx = tree_dot(g, res.x_best)
    sign = jnp.where(jnp.sign(gx) == 0, 1.0, -jnp.sign(gx))
    sol = tree_scale(sign, res.x_best)
    sol_norm = tree_norm(sol)
    xAx = tree_dot(res.x_best, jax.tree_util.tree_map(jnp.subtract, b, res.r_best))
    m_sol = sign * gx + 0.5 * xAx
    # λ_min(G) estimate for this solve: the solver's threaded nc_lambda
    # (Ritz-refined on the s-step paths) floored by the probe's Rayleigh
    # quotient, gated on the probe actually firing.
    nc_lam = jnp.where(
        res.nc_found, jnp.minimum(res.nc_lambda, res.nc_curv), 0.0)
    if config.nc_mode == "escape":
        # Saddle-free escape (Arjovsky, arXiv:1506.00059): step along the
        # (unit-norm) NC direction at the |λ_min| scale — the magnitude the
        # saddle-free Newton rescaling |H|⁻¹g prescribes along an
        # eigendirection — instead of borrowing the solution's norm. The
        # candidate is judged by the RAW (undamped) model, honest about
        # being unbounded below along true negative curvature, so a fired
        # probe nearly always escapes; Armijo globalizes the scale.
        nc_scale = jnp.abs(nc_lam)
        nc_raw = tree_scale(nc_scale, res.nc_dir)
        nc, _ = sign_correct(g, nc_raw)
        g_nc = tree_dot(g, nc)
        m_nc = jnp.where(
            res.nc_found,
            g_nc + 0.5 * res.nc_curv * nc_scale**2,
            jnp.inf,
        )
        # NaN-safe toward TAKING the step: a poisoned λ estimate (inf/NaN
        # scale) must reach the divergence sentinel below as a non-finite
        # step and be rejected there — `m_nc < m_sol` would silently mask
        # it (NaN compares False) and accept the solver iterate instead.
        take_nc = jnp.logical_and(
            res.nc_found, jnp.logical_not(m_sol <= m_nc))
    else:
        # Scale the (unit-norm) NC direction to the solution's magnitude so
        # the quadratic-model comparison and the line search see comparable
        # steps; the quadratic model itself is unbounded below along NC
        # directions so it prescribes no scale — floor at nc_min_step and
        # let Armijo globalize.
        nc_scale = jnp.maximum(sol_norm, config.nc_min_step)
        nc_raw = tree_scale(nc_scale, res.nc_dir)
        nc, _ = sign_correct(g, nc_raw)
        g_nc = tree_dot(g, nc)
        m_nc = jnp.where(
            res.nc_found,
            g_nc + 0.5 * (res.nc_curv + lam) * nc_scale**2,
            jnp.inf,
        )
        take_nc = m_nc < m_sol
    delta = tree_where(take_nc, nc, sol)
    m_lin = jnp.where(take_nc, g_nc, sign * gx)       # gᵀδ
    m_quad = jnp.where(take_nc, m_nc - g_nc, 0.5 * xAx)  # ½ δᵀAδ

    # Degenerate solve (zero direction) → steepest descent fallback (paper:
    # "if negative curvature at the very first CG iteration, use −g").
    d_norm = tree_norm(delta)
    degenerate = d_norm < 1e-12
    delta = tree_where(degenerate, b, delta)
    gg = tree_dot(g, g)
    m_lin = jnp.where(degenerate, -gg, m_lin)
    m_quad = jnp.where(degenerate, 0.0, m_quad)

    # ---- Alg.2 line 9: Armijo line search -----------------------------------
    g_dot_delta = tree_dot(g, delta)
    ls = armijo(
        lambda p: loss_fn(p, batch), params, f0, delta, g_dot_delta,
        c=config.ls_c, beta=config.ls_beta, max_backtracks=config.max_backtracks,
        paired=config.overlap,
    )
    _telemetry.marker("line_search", ls.alpha, ls.f_new, step=state.step)

    # ---- Alg.2 lines 8,10: LM damping + parameter update --------------------
    # predicted reduction of the STEP TAKEN: m(αδ) = α·gᵀδ + α²·½δᵀAδ
    pred_red = ls.alpha * m_lin + ls.alpha**2 * m_quad
    pred_red = jnp.minimum(pred_red, -1e-20)
    lam_new, rho = damping_mod.lm_update(
        lam, f0, ls.f_new, pred_red,
        inc=config.damping_inc, dec=config.damping_dec,
    )
    new_params = tree_axpy_cast(ls.alpha, delta, params)
    delta_taken = tree_scale(ls.alpha, delta)

    # ---- divergence sentinel: reject poisoned / ascent steps ---------------
    # A non-finite accepted loss or step (poisoned curvature batch, solver
    # blow-up) must not reach the parameters: even the alpha=0 "zero step"
    # is `0 * NaN = NaN` leaf-wise when delta itself is non-finite. Reject:
    # keep params, drop the warm start (it would re-inject the poisoned
    # direction next step), boost λ through the LM machinery, and report it
    # (metrics["step_rejected"] + a `repro.obs` fault event). strict_descent
    # additionally rejects real loss increases beyond the guard.
    rejected = jnp.zeros((), bool)
    if config.reject_nonfinite or config.strict_descent:
        accept = jnp.ones((), bool)
        if config.reject_nonfinite:
            finite_ok = jnp.logical_and(
                jnp.isfinite(ls.f_new), jnp.isfinite(tree_norm(delta_taken)))
            accept = jnp.logical_and(accept, finite_ok)
        if config.strict_descent:
            guard = config.descent_guard * jnp.maximum(1.0, jnp.abs(f0))
            accept = jnp.logical_and(accept, ls.f_new <= f0 + guard)
        rejected = jnp.logical_not(accept)
        boost = (config.reject_boost if config.reject_boost > 0
                 else config.damping_inc ** 2)
        lam_new = jnp.where(accept, lam_new,
                            jnp.clip(lam * boost, 1e-8, 1e8))
        rho = jnp.where(accept, rho, 0.0)
        new_params = tree_where(accept, new_params, params)
        delta_taken = tree_where(
            accept, delta_taken, tree_zeros_like(state.prev_delta))
    _telemetry.reject_event(state.step, rejected, lam_new, ls.f_new)

    if config.solver == "hybrid_cg":
        # NC encountered this (exact-Hessian) iteration → GN next iteration;
        # after a GN iteration always return to the exact Hessian.
        use_gn_next = jnp.logical_and(jnp.logical_not(state.use_gn), res.nc_found)
    else:
        use_gn_next = jnp.zeros((), bool)

    new_state = HFState(
        lam=lam_new, prev_delta=delta_taken, use_gn=use_gn_next, step=state.step + 1
    )
    _telemetry.marker("update_damping", lam_new, rho, new_params, step=state.step)
    metrics = {
        "loss": f0,
        "loss_new": ls.f_new,
        "grad_norm": tree_norm(g),
        "lambda": lam_new,
        "rho": rho,
        "alpha": ls.alpha,
        "ls_evals": ls.n_evals,
        "cg_iters": res.iters,
        "cg_residual": res.residual,
        # Blocking scalar-producing reductions the Krylov solve issued: one
        # per iteration for the standard recurrences, one Gram reduction per
        # s-iteration cycle for the s-step solvers (+ fallback iterations
        # when the basis guard fired — sstep_fallback). The quantity the
        # comm model's `1 + ceil(K/s) + E` counts (benchmarks/comm_model.py,
        # measured by benchmarks/sstep_bench.py).
        "krylov_syncs": res.syncs,
        # Executed BLOCKING synchronizations this outer step — round-trips
        # where the schedule stalls on a collective's result before the next
        # one can issue: the gradient reduce (hidden behind the curvature
        # primal build under the overlapped schedule ⇒ 0), one per Krylov
        # sync (iterations / Gram cycles — double-buffered cycles already
        # halve res.syncs), and one per line-search trip (candidate PAIRS
        # under overlap ⇒ ⌈E/2⌉). The executed counterpart of
        # comm_model.hf_sstep_syncs_per_iteration(..., overlap=).
        "blocking_syncs": (
            res.syncs + (ls.n_evals + 1) // 2 if config.overlap
            else 1 + res.syncs + ls.n_evals
        ),
        "sstep_fallback": jnp.logical_and(config.sstep_s > 1, res.breakdown),
        # The subset of sstep_fallback caused by the GRAM GUARD (the basis
        # degenerating) — Bi-CG-STAB ρ/ω recurrence collapse, which the
        # standard solver exhibits identically, is excluded. The §Perf
        # pair G acceptance counts THIS rate.
        "sstep_basis_fallback": jnp.logical_and(
            config.sstep_s > 1, res.basis_breakdown),
        # An adaptive (Newton/Chebyshev) s-step basis failed its Gram guard
        # and the solve degraded to the monomial basis mid-stream — the
        # first link of the basis fallback chain (always False for the
        # standard solvers and the monomial basis).
        "sstep_basis_degraded": jnp.logical_and(
            config.sstep_s > 1, res.basis_degraded),
        "nc_found": res.nc_found,
        "nc_used": take_nc,
        "nc_curv": res.nc_curv,
        # λ_min(G) estimate behind the escape scale (0 when the probe did
        # not fire): Rayleigh quotient from the standard recurrences,
        # Ritz-refined per cycle on the s-step paths.
        "nc_lambda": nc_lam,
        "step_norm": tree_norm(delta_taken),
        "used_gn": state.use_gn,
        # Divergence sentinel (reject_nonfinite / strict_descent): the step
        # was rejected — params unchanged, warm start dropped, λ boosted
        # (also emitted as a `repro.obs` fault event, visible in the
        # Perfetto trace's events lane).
        "step_rejected": rejected,
    }
    # Trace-time contract: the metrics dict and the published schema move in
    # lockstep (tests/test_telemetry.py::test_metrics_contract).
    assert set(metrics) == set(METRICS_SCHEMA), sorted(
        set(metrics) ^ set(METRICS_SCHEMA))
    return new_params, new_state, metrics
