"""Krylov solvers for the damped curvature system  (G + λI) d = -g.

* ``cg``        — naive conjugate gradients with Martens-style truncation:
                  terminates as soon as a negative-curvature direction is
                  generated (pᵀAp ≤ 0) and *reports* that direction instead of
                  discarding it (the paper's critique of Newton-CG is that the
                  information is thrown away).
* ``bicgstab``  — stabilized bi-conjugate gradients (paper Algorithm 3); works
                  on the *indefinite* exact stochastic Hessian. Both the search
                  directions p_j and the intermediate s_j come with their
                  operator products (Ap_j, As_j) already computed, so negative
                  curvature of the *undamped* operator is detected for free:
                  dᵀG d = dᵀA d − λ‖d‖². The most negative normalized-curvature
                  direction seen is returned alongside the solution.

Both solvers implement **free CG-backtracking**: the returned iterate is the
one minimizing the quadratic φ(x) = ½xᵀAx − bᵀx over the trajectory, with
φ evaluated from the residual identity A·x = b − r (two scalar tree-dots per
iteration, no operator applications, no loss evaluations). Martens (2010)
backtracks over saved CG iterates with true-loss evaluations; the paper
omits it as too expensive — this form is free. For CG on an SPD system φ is
monotone so best == last; for Bi-CG-STAB (non-monotone) it matters.

Everything is a ``lax.while_loop`` over pytree carries — one jittable program,
one all-reduce per operator application under pjit (the paper's per-CG-
iteration MPI reduce).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .tree_math import (
    tree_axpy,
    tree_axpby,
    tree_dot,
    tree_norm,
    tree_scale,
    tree_where,
    tree_zeros_like,
)

Op = Callable[[Any], Any]

_EPS = 1e-20


class KrylovResult(NamedTuple):
    x: Any                 # final iterate (approximate solution of (G+λI)x = b)
    r: Any                 # its residual VECTOR b - A x (gives A·x for free:
                           # A x = b - r — used for the quadratic-model value
                           # without an extra operator application)
    x_best: Any            # best-model iterate: argmin over the trajectory of
                           # φ(x) = ½xᵀAx − bᵀx (free CG-backtracking; for an
                           # indefinite system this is a *direction* candidate,
                           # not a solution — the solve target is a saddle of φ)
    r_best: Any            # residual of x_best
    nc_dir: Any            # negative-curvature direction of G (zeros if none)
    nc_found: jax.Array    # bool scalar
    nc_curv: jax.Array     # dᵀGd / ‖d‖²  for the reported nc_dir (0 if none)
    iters: jax.Array       # Krylov iterations executed
    residual: jax.Array    # final ‖b - A x‖


def cg(A: Op, b, x0, *, lam, max_iters: int, tol: float = 5e-3) -> KrylovResult:
    """Conjugate gradients with negative-curvature capture.

    ``A`` is the damped operator v ↦ G v + λ v; ``lam`` is λ (used to convert
    damped curvature back to raw curvature for the NC test, matching the
    paper's dᵀHd < 0 criterion on the *stochastic Hessian*).
    """
    b_norm = tree_norm(b)
    r0 = jax.tree_util.tree_map(jnp.subtract, b, A(x0))

    def cond(carry):
        (_, _, _, rs, k, done, _, _, _) = carry
        return jnp.logical_and(k < max_iters, jnp.logical_not(done))

    def body(carry):
        x, r, p, rs, k, done, nc_found, nc_dir, nc_curv = carry
        Ap = A(p)
        pAp = tree_dot(p, Ap)
        p_sq = tree_dot(p, p)
        raw_curv = (pAp - lam * p_sq) / jnp.maximum(p_sq, _EPS)
        # Negative curvature of the *damped* operator breaks CG itself; of the
        # raw operator it is a saddle-escape direction. Capture the rawest one.
        is_nc = raw_curv < 0.0
        better = jnp.logical_and(is_nc, raw_curv < nc_curv)
        nc_dir = tree_where(better, tree_scale(1.0 / jnp.sqrt(jnp.maximum(p_sq, _EPS)), p), nc_dir)
        nc_curv = jnp.where(better, raw_curv, nc_curv)
        nc_found = jnp.logical_or(nc_found, is_nc)
        # Martens truncation: stop when the damped system goes indefinite.
        trunc = pAp <= _EPS
        alpha = rs / jnp.maximum(pAp, _EPS)
        x_new = tree_axpy(alpha, p, x)
        r_new = tree_axpy(-alpha, Ap, r)
        rs_new = tree_dot(r_new, r_new)
        beta = rs_new / jnp.maximum(rs, _EPS)
        p_new = tree_axpy(beta, p, r_new)
        x = tree_where(trunc, x, x_new)
        r = tree_where(trunc, r, r_new)
        p = tree_where(trunc, p, p_new)
        rs_out = jnp.where(trunc, rs, rs_new)
        done_new = jnp.logical_or(trunc, jnp.sqrt(rs_new) < tol * b_norm)
        return (x, r, p, rs_out, k + 1, done_new, nc_found, nc_dir, nc_curv)

    rs0 = tree_dot(r0, r0)
    init = (
        x0, r0, r0, rs0, jnp.zeros((), jnp.int32), rs0 < (tol * b_norm) ** 2,
        jnp.zeros((), bool), tree_zeros_like(b), jnp.zeros(()),
    )
    x, r, _, rs, k, _, nc_found, nc_dir, nc_curv = jax.lax.while_loop(cond, body, init)
    # CG on the (damped, PSD-unless-truncated) system is φ-monotone: best=last
    return KrylovResult(x, r, x, r, nc_dir, nc_found, nc_curv, k, jnp.sqrt(rs))


def bicgstab(A: Op, b, x0, *, lam, max_iters: int, tol: float = 5e-3) -> KrylovResult:
    """Bi-CG-STAB (paper Algorithm 3) with free negative-curvature capture.

    Solves the possibly-indefinite damped system. r0* is chosen as r0
    (standard). Breakdown ((r, r0*) ≈ 0 or (As, As) ≈ 0) freezes the iterate
    and terminates — the caller falls back to the best candidate so far.
    """
    b_norm = tree_norm(b)
    r0 = jax.tree_util.tree_map(jnp.subtract, b, A(x0))
    r0_star = r0

    def phi_of(x, r):
        """Quadratic model ½xᵀAx − bᵀx via A·x = b − r (no operator call)."""
        return -0.5 * tree_dot(b, x) - 0.5 * tree_dot(x, r)

    def probe_nc(d, Ad, nc_found, nc_dir, nc_curv):
        d_sq = tree_dot(d, d)
        raw = (tree_dot(d, Ad) - lam * d_sq) / jnp.maximum(d_sq, _EPS)
        is_nc = raw < 0.0
        better = jnp.logical_and(is_nc, raw < nc_curv)
        nc_dir = tree_where(better, tree_scale(1.0 / jnp.sqrt(jnp.maximum(d_sq, _EPS)), d), nc_dir)
        nc_curv = jnp.where(better, raw, nc_curv)
        return jnp.logical_or(nc_found, is_nc), nc_dir, nc_curv

    def cond(carry):
        (_, _, _, _, k, done, _, _, _, _, _, _) = carry
        return jnp.logical_and(k < max_iters, jnp.logical_not(done))

    def body(carry):
        (x, r, p, rho, k, done, nc_found, nc_dir, nc_curv,
         x_best, r_best, phi_best) = carry
        Ap = A(p)
        nc_found, nc_dir, nc_curv = probe_nc(p, Ap, nc_found, nc_dir, nc_curv)
        denom_a = tree_dot(Ap, r0_star)
        breakdown_a = jnp.abs(denom_a) < _EPS
        alpha = rho / jnp.where(breakdown_a, 1.0, denom_a)
        s = tree_axpy(-alpha, Ap, r)                      # s_j = r_j − α A p_j
        As = A(s)
        nc_found, nc_dir, nc_curv = probe_nc(s, As, nc_found, nc_dir, nc_curv)
        denom_g = tree_dot(As, As)
        breakdown_g = denom_g < _EPS
        gamma = tree_dot(s, As) / jnp.where(breakdown_g, 1.0, denom_g)
        x_new = tree_axpy(gamma, s, tree_axpy(alpha, p, x))
        r_new = tree_axpy(-gamma, As, s)                  # r_{j+1} = s − γ A s
        rho_new = tree_dot(r_new, r0_star)
        beta = (rho_new / jnp.where(jnp.abs(rho) < _EPS, 1.0, rho)) * (
            alpha / jnp.where(jnp.abs(gamma) < _EPS, 1.0, gamma)
        )
        p_new = tree_axpy(beta, tree_axpy(-gamma, Ap, p), r_new)
        breakdown = jnp.logical_or(breakdown_a, breakdown_g)
        x = tree_where(breakdown, x, x_new)
        r = tree_where(breakdown, r, r_new)
        p = tree_where(breakdown, p, p_new)
        rho_out = jnp.where(breakdown, rho, rho_new)
        # free CG-backtracking: track the best-model iterate
        phi = phi_of(x, r)
        improved = jnp.logical_and(phi < phi_best, jnp.logical_not(breakdown))
        x_best = tree_where(improved, x, x_best)
        r_best = tree_where(improved, r, r_best)
        phi_best = jnp.where(improved, phi, phi_best)
        res = tree_norm(r)
        done_new = jnp.logical_or(breakdown, res < tol * b_norm)
        return (x, r, p, rho_out, k + 1, done_new, nc_found, nc_dir, nc_curv,
                x_best, r_best, phi_best)

    rho0 = tree_dot(r0, r0_star)
    init = (
        x0, r0, r0, rho0, jnp.zeros((), jnp.int32),
        tree_norm(r0) < tol * b_norm,
        jnp.zeros((), bool), tree_zeros_like(b), jnp.zeros(()),
        x0, r0, phi_of(x0, r0),
    )
    (x, r, _, _, k, _, nc_found, nc_dir, nc_curv,
     x_best, r_best, _) = jax.lax.while_loop(cond, body, init)
    return KrylovResult(x, r, x_best, r_best, nc_dir, nc_found, nc_curv, k, tree_norm(r))


def pcg(A: Op, b, x0, *, lam, M_inv, max_iters: int, tol: float = 5e-3) -> KrylovResult:
    """Jacobi-preconditioned CG (Chapelle & Erhan 2011; Martens 2010 §4.7).

    ``M_inv``: pytree of elementwise inverse-preconditioner values
    (e.g. 1/(diag(Ĥ)+λ)^α). Negative-curvature capture identical to ``cg``.
    """
    mul = lambda m, v: jax.tree_util.tree_map(lambda mm, vv: mm * vv, m, v)
    b_norm = tree_norm(b)
    r0 = jax.tree_util.tree_map(jnp.subtract, b, A(x0))
    z0 = mul(M_inv, r0)

    def cond(carry):
        (_, _, _, _, rz, k, done, _, _, _) = carry
        return jnp.logical_and(k < max_iters, jnp.logical_not(done))

    def body(carry):
        x, r, z, p, rz, k, done, nc_found, nc_dir, nc_curv = carry
        Ap = A(p)
        pAp = tree_dot(p, Ap)
        p_sq = tree_dot(p, p)
        raw_curv = (pAp - lam * p_sq) / jnp.maximum(p_sq, _EPS)
        is_nc = raw_curv < 0.0
        better = jnp.logical_and(is_nc, raw_curv < nc_curv)
        nc_dir = tree_where(better, tree_scale(1.0 / jnp.sqrt(jnp.maximum(p_sq, _EPS)), p), nc_dir)
        nc_curv = jnp.where(better, raw_curv, nc_curv)
        nc_found = jnp.logical_or(nc_found, is_nc)
        trunc = pAp <= _EPS
        alpha = rz / jnp.maximum(pAp, _EPS)
        x_new = tree_axpy(alpha, p, x)
        r_new = tree_axpy(-alpha, Ap, r)
        z_new = mul(M_inv, r_new)
        rz_new = tree_dot(r_new, z_new)
        beta = rz_new / jnp.maximum(rz, _EPS)
        p_new = tree_axpy(beta, p, z_new)
        x = tree_where(trunc, x, x_new)
        r = tree_where(trunc, r, r_new)
        z = tree_where(trunc, z, z_new)
        p = tree_where(trunc, p, p_new)
        rz_out = jnp.where(trunc, rz, rz_new)
        done_new = jnp.logical_or(trunc, tree_norm(r_new) < tol * b_norm)
        return (x, r, z, p, rz_out, k + 1, done_new, nc_found, nc_dir, nc_curv)

    rz0 = tree_dot(r0, z0)
    init = (
        x0, r0, z0, z0, rz0, jnp.zeros((), jnp.int32),
        tree_norm(r0) < tol * b_norm,
        jnp.zeros((), bool), tree_zeros_like(b), jnp.zeros(()),
    )
    x, r, _, _, _, k, _, nc_found, nc_dir, nc_curv = jax.lax.while_loop(cond, body, init)
    return KrylovResult(x, r, x, r, nc_dir, nc_found, nc_curv, k, tree_norm(r))


def hutchinson_diag(op: Op, like, step, *, samples: int = 1):
    """Hutchinson diagonal estimate diag(A) ≈ E[v ⊙ Av] with Rademacher v
    (built from the sharding-preserving pseudo-noise — no RNG replication)."""
    from .tree_math import tree_pseudo_noise

    acc = tree_zeros_like(like)
    for s in range(samples):
        v = jax.tree_util.tree_map(
            lambda n: jnp.sign(n) + (n == 0), tree_pseudo_noise(like, step * samples + s)
        )
        Av = op(v)
        acc = jax.tree_util.tree_map(
            lambda a, vv, av: a + vv * av.astype(jnp.float32), acc, v, Av
        )
    return jax.tree_util.tree_map(lambda a: a / samples, acc)


def sign_correct(g, d):
    """d̃ = −sign(gᵀd)·d  — force a (non-ascent) direction (paper §4.2)."""
    gd = tree_dot(g, d)
    s = -jnp.sign(gd)
    s = jnp.where(s == 0, 1.0, s)
    return tree_scale(s, d), jnp.abs(gd)
