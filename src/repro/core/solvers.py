"""Krylov solvers for the damped curvature system  (G + λI) d = -g.

One engine, three entry points, two vector backends.

* ``cg``        — conjugate gradients with Martens-style truncation:
                  terminates as soon as a negative-curvature direction is
                  generated (pᵀAp ≤ 0) and *reports* that direction instead of
                  discarding it (the paper's critique of Newton-CG is that the
                  information is thrown away).
* ``pcg``       — the same recurrence with a Jacobi preconditioner folded in
                  (``cg`` is literally ``pcg`` with the identity — one body).
* ``bicgstab``  — stabilized bi-conjugate gradients (paper Algorithm 3); works
                  on the *indefinite* exact stochastic Hessian, optionally
                  right-preconditioned (pass ``M_inv``; the van der Vorst
                  M⁻¹-in-the-recurrence form, which reduces exactly to plain
                  Bi-CG-STAB for M = I). Both the search directions p̂_j and
                  the intermediates ŝ_j come with their operator products
                  already computed, so negative curvature of the *undamped*
                  operator is detected for free: dᵀG d = dᵀA d − λ‖d‖².

All three are thin recurrence definitions over a ``krylov`` vector backend:

* ``backend=None`` / ``"tree"`` — pytree iterates, sharding-preserving leaf
  ops (the original representation; right under pjit with sharded params);
* ``krylov.get_backend("flat", template=b)`` — iterates ravelled once per
  solve into a flat f32 buffer, recurrences executed by the fused Pallas
  kernels (``kernels/cg_fused.py`` via ``kernels/ops.py``), interpret-mode
  off-TPU. Wins when the Krylov state is per-chip replicated (pure data
  parallelism) and the inner loop is HBM-bandwidth-bound: the fusions remove
  whole HBM passes over model-sized vectors.

The shared machinery — negative-curvature probe, free CG-backtracking
(φ-best tracking via the residual identity A·x = b − r), breakdown guards —
lives in ``krylov.py`` and exists exactly once. Every solver returns the
same ``KrylovResult`` (pytree-typed, regardless of backend).

Everything is a ``lax.while_loop`` over backend carries — one jittable
program, one all-reduce per operator application under pjit (the paper's
per-CG-iteration MPI reduce).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .krylov import (
    EPS as _EPS,
    BestState,
    NCState,
    best_init,
    best_update,
    get_backend,
    guard_div,
    nc_init,
    nc_probe,
    phi_value,
)
from .tree_math import tree_dot, tree_pseudo_noise, tree_scale, tree_zeros_like

Op = Callable[[Any], Any]


class KrylovResult(NamedTuple):
    x: Any                 # final iterate (approximate solution of (G+λI)x = b)
    r: Any                 # its residual VECTOR b - A x (gives A·x for free:
                           # A x = b - r — used for the quadratic-model value
                           # without an extra operator application)
    x_best: Any            # best-model iterate: argmin over the trajectory of
                           # φ(x) = ½xᵀAx − bᵀx (free CG-backtracking; for an
                           # indefinite system this is a *direction* candidate,
                           # not a solution — the solve target is a saddle of φ)
    r_best: Any            # residual of x_best
    nc_dir: Any            # negative-curvature direction of G (zeros if none)
    nc_found: jax.Array    # bool scalar
    nc_curv: jax.Array     # dᵀGd / ‖d‖²  for the reported nc_dir (0 if none)
    iters: jax.Array       # Krylov iterations executed
    residual: jax.Array    # final ‖b - A x‖
    syncs: jax.Array       # blocking scalar-producing reductions the solve
                           # issued: one per iteration for the standard
                           # recurrences (each iteration's dots gate the next
                           # scalar step), one GRAM reduction per s-iteration
                           # cycle for the s-step solvers (core/sstep.py) —
                           # the quantity benchmarks/comm_model.py's sync
                           # formulas count
    breakdown: jax.Array   # bool: recurrence/basis breakdown occurred
                           # (Bi-CG-STAB ρ/ω collapse; s-step Gram-
                           # factorization guard — for the s-step solvers
                           # with fallback=True this also means the standard
                           # fallback solve ran)
    basis_degraded: Any = False
                           # bool: an s-step Newton/Chebyshev basis failed
                           # its Gram guard and the solve degraded to the
                           # monomial basis mid-stream (the first link of
                           # the adaptive → monomial → standard fallback
                           # chain, core/sstep.py). Always False for the
                           # standard recurrences and the monomial basis.
    basis_breakdown: Any = False
                           # bool: the breakdown (if any) was caused by the
                           # s-step GRAM GUARD — i.e. the basis itself —
                           # as opposed to Bi-CG-STAB's intrinsic ρ/ω
                           # recurrence collapse, which the standard solver
                           # exhibits identically and which the s-step form
                           # merely reports through the same fallback path.
                           # Always False for the standard recurrences.
    residual_history: Any = None
                           # (max_iters,) f32: ‖r‖ after each executed
                           # iteration, NaN beyond ``iters`` (and at a
                           # Bi-CG-STAB breakdown slot, where the frozen
                           # iterate has no new residual). Written from the
                           # existing loop carries — no extra reductions —
                           # and surfaced per outer step as a telemetry
                           # solve event (repro.obs). For the s-step
                           # fallback path the standard solve's curve is
                           # appended after the partial s-step one.
    nc_lambda: Any = 0.0
                           # f32 scalar: the solver's estimate of the RAW
                           # operator's most-negative eigenvalue λ_min(G),
                           # available for free from data the solve already
                           # produced. Standard recurrences report the best
                           # (most negative) Rayleigh quotient the NC probe
                           # saw — identical to nc_curv; the s-step solvers
                           # refine it with the minimum Ritz value extracted
                           # from each cycle's Gram (core.krylov.
                           # ritz_from_segment, shifted by −λ back to the
                           # raw operator), which lower-bounds the Rayleigh
                           # quotient. 0 when no negative estimate exists.
                           # This is the |λ|-scale of the saddle-free
                           # escape step (HFConfig.nc_mode="escape").


def _resolve(backend):
    return get_backend("tree") if backend is None else backend


def _cg_engine(A: Op, b, x0, *, lam, M_inv, max_iters: int, tol: float,
               backend) -> KrylovResult:
    """(P)CG body shared by ``cg`` and ``pcg``: M_inv=None ⇒ identity."""
    be = _resolve(backend)
    A_ = be.wrap_op(A)
    b_ = be.lift(b)
    m = None if M_inv is None else be.lift(M_inv)
    prec = (lambda r: be.mul(m, r)) if m is not None else (lambda r: r)

    b_norm = be.norm(b_)
    x0_ = be.lift(x0)
    r0 = be.sub(b_, A_(x0_))
    z0 = prec(r0)
    rz0, rr0 = be.dot2(z0, r0)  # (<z0,r0>, <r0,r0>); equal for identity M

    def cond(carry):
        (_, _, _, _, _, k, done, _, _, _) = carry
        return jnp.logical_and(k < max_iters, jnp.logical_not(done))

    def body(carry):
        x, r, p, rz, rr, k, done, nc, broke, hist = carry
        Ap = A_(p)
        pAp, p_sq = be.dot2(Ap, p)
        nc = nc_probe(be, p, pAp, p_sq, lam, nc)
        # Martens truncation: stop when the damped system goes indefinite
        # (negative curvature of the damped operator breaks CG itself; of
        # the raw operator it is a saddle-escape direction — nc_probe above
        # captures the rawest one).
        trunc = pAp <= _EPS
        alpha = rz / jnp.maximum(pAp, _EPS)
        x_new = be.axpy(alpha, p, x)
        r_new, _, rr_new = be.update_residual(r, Ap, alpha)  # r − α·Ap, ‖r‖²
        z_new = prec(r_new)
        rz_new = rr_new if m is None else be.dot(r_new, z_new)
        beta = rz_new / jnp.maximum(rz, _EPS)
        p_new = be.axpy(beta, p, z_new)
        # Non-finite operator products (NaN/Inf HVP, e.g. an overflowing or
        # poisoned curvature batch) break the recurrence *silently*: every
        # comparison against NaN is False, so neither the truncation test
        # nor the tolerance test would ever fire and the poisoned iterate
        # would come back looking like a normal max_iters solve. Detect,
        # freeze the last finite iterate, and report ``breakdown``.
        bad = jnp.logical_not(jnp.logical_and(jnp.isfinite(pAp),
                                              jnp.isfinite(rr_new)))
        freeze = jnp.logical_or(trunc, bad)
        x = be.where(freeze, x, x_new)
        r = be.where(freeze, r, r_new)
        p = be.where(freeze, p, p_new)
        rz_out = jnp.where(freeze, rz, rz_new)
        rr_out = jnp.where(freeze, rr, rr_new)
        # Residual curve from the carried scalar — no extra reductions
        # (rr_out is the frozen pre-step value on a truncation iteration).
        hist = hist.at[k].set(jnp.where(bad, jnp.nan, jnp.sqrt(rr_out)))
        done_new = jnp.logical_or(freeze, jnp.sqrt(rr_new) < tol * b_norm)
        return (x, r, p, rz_out, rr_out, k + 1, done_new, nc,
                jnp.logical_or(broke, bad), hist)

    init = (
        x0_, r0, z0, rz0, rr0, jnp.zeros((), jnp.int32),
        jnp.sqrt(rr0) < tol * b_norm, nc_init(be, b_),
        jnp.zeros((), bool),
        jnp.full((max_iters,), jnp.nan, jnp.float32),
    )
    x, r, _, _, rr, k, _, nc, broke, hist = jax.lax.while_loop(cond, body, init)
    # (P)CG on the (damped, PSD-unless-truncated) system is φ-monotone:
    # best == last. One blocking reduction per iteration (the dots that
    # produce α/β gate the next step): syncs == iters.
    x, r, nc_dir = be.lower(x), be.lower(r), be.lower(nc.dir)
    return KrylovResult(x, r, x, r, nc_dir, nc.found, nc.curv, k, jnp.sqrt(rr),
                        syncs=k, breakdown=broke,
                        residual_history=hist, nc_lambda=nc.curv)


def cg(A: Op, b, x0, *, lam, max_iters: int, tol: float = 5e-3,
       backend=None) -> KrylovResult:
    """Conjugate gradients with negative-curvature capture.

    ``A`` is the damped operator v ↦ G v + λ v; ``lam`` is λ (used to convert
    damped curvature back to raw curvature for the NC test, matching the
    paper's dᵀHd < 0 criterion on the *stochastic Hessian*).
    """
    return _cg_engine(A, b, x0, lam=lam, M_inv=None, max_iters=max_iters,
                      tol=tol, backend=backend)


def pcg(A: Op, b, x0, *, lam, M_inv, max_iters: int, tol: float = 5e-3,
        backend=None) -> KrylovResult:
    """Jacobi-preconditioned CG (Chapelle & Erhan 2011; Martens 2010 §4.7).

    ``M_inv``: pytree of elementwise inverse-preconditioner values
    (e.g. 1/(diag(Ĥ)+λ)^α). Negative-curvature capture identical to ``cg``.
    """
    return _cg_engine(A, b, x0, lam=lam, M_inv=M_inv, max_iters=max_iters,
                      tol=tol, backend=backend)


def bicgstab(A: Op, b, x0, *, lam, max_iters: int, tol: float = 5e-3,
             M_inv=None, backend=None) -> KrylovResult:
    """Bi-CG-STAB (paper Algorithm 3) with free negative-curvature capture.

    Solves the possibly-indefinite damped system. r0* is chosen as r0
    (standard). Breakdown ((r, r0*) ≈ 0 or (t, t) ≈ 0) freezes the iterate
    and terminates — the caller falls back to the best candidate so far.

    ``M_inv`` (optional) enables the right-preconditioned variant: the
    recurrence runs on p̂ = M⁻¹p, ŝ = M⁻¹s (van der Vorst), which for
    M = I is *exactly* plain Bi-CG-STAB — no fourth solver needed. The NC
    probe acts on (p̂, Ap̂)/(ŝ, Aŝ): the directions that actually build x.
    """
    be = _resolve(backend)
    A_ = be.wrap_op(A)
    b_ = be.lift(b)
    m = None if M_inv is None else be.lift(M_inv)
    prec = (lambda r: be.mul(m, r)) if m is not None else (lambda r: r)

    b_norm = be.norm(b_)
    x0_ = be.lift(x0)
    r0 = be.sub(b_, A_(x0_))
    r0_star = r0

    def cond(carry):
        (_, _, _, _, k, done, _, _, _, _) = carry
        return jnp.logical_and(k < max_iters, jnp.logical_not(done))

    def body(carry):
        x, r, p, rho, k, done, nc, best, broke, hist = carry
        phat = prec(p)
        v = A_(phat)                                     # A p̂_j
        v_phat, phat_sq = be.dot2(v, phat)
        nc = nc_probe(be, phat, v_phat, phat_sq, lam, nc)
        denom_a = be.dot(v, r0_star)
        alpha, breakdown_a = guard_div(rho, denom_a)
        s = be.axpy(-alpha, v, r)                        # s_j = r_j − α A p̂_j
        shat = prec(s)
        t = A_(shat)                                     # A ŝ_j
        t_shat, shat_sq = be.dot2(t, shat)
        nc = nc_probe(be, shat, t_shat, shat_sq, lam, nc)
        st_dot, tt = be.dot2(s, t)                       # (<s,t>, <t,t>)
        breakdown_g = tt < _EPS
        gamma = st_dot / jnp.where(breakdown_g, 1.0, tt)
        x_new = be.fused_update(x, phat, shat, alpha, gamma)   # x + αp̂ + γŝ
        # r_{j+1} = s − γ t, fused with the dots it feeds: ⟨r,r0*⟩, ⟨r,r⟩
        r_new, rho_new, rr_new = be.update_residual(s, t, gamma, r0s=r0_star)
        beta = (rho_new / jnp.where(jnp.abs(rho) < _EPS, 1.0, rho)) * (
            alpha / jnp.where(jnp.abs(gamma) < _EPS, 1.0, gamma)
        )
        p_new = be.fused_update(r_new, p, v, beta, -beta * gamma)
        # Non-finite recurrence scalars (NaN HVP → NaN ρ/‖r‖²) evade the
        # ρ/ω collapse guards — guard_div tests |den| < eps, and |NaN| < eps
        # is False — so without this check a poisoned operator would freeze
        # nothing and the NaN iterate would be returned un-flagged. Fold
        # non-finiteness into breakdown: freeze + terminate + report.
        bad = jnp.logical_not(jnp.logical_and(jnp.isfinite(rho_new),
                                              jnp.isfinite(rr_new)))
        breakdown = jnp.logical_or(jnp.logical_or(breakdown_a, breakdown_g),
                                   bad)
        x = be.where(breakdown, x, x_new)
        r = be.where(breakdown, r, r_new)
        p = be.where(breakdown, p, p_new)
        rho_out = jnp.where(breakdown, rho, rho_new)
        # free CG-backtracking: track the best-model iterate
        phi = phi_value(be, b_, x, r)
        best = best_update(be, x, r, phi, jnp.logical_not(breakdown), best)
        # On a breakdown iteration the iterate is frozen and rr_new is
        # meaningless — leave that slot NaN.
        hist = hist.at[k].set(jnp.where(
            breakdown, jnp.nan, jnp.sqrt(jnp.maximum(rr_new, 0.0))))
        done_new = jnp.logical_or(breakdown, jnp.sqrt(rr_new) < tol * b_norm)
        return (x, r, p, rho_out, k + 1, done_new, nc, best,
                jnp.logical_or(broke, breakdown), hist)

    init = (
        x0_, r0, r0, be.dot(r0, r0_star), jnp.zeros((), jnp.int32),
        be.norm(r0) < tol * b_norm, nc_init(be, b_), best_init(be, b_, x0_, r0),
        jnp.zeros((), bool),
        jnp.full((max_iters,), jnp.nan, jnp.float32),
    )
    (x, r, _, _, k, _, nc, best, broke,
     hist) = jax.lax.while_loop(cond, body, init)
    return KrylovResult(
        be.lower(x), be.lower(r), be.lower(best.x), be.lower(best.r),
        be.lower(nc.dir), nc.found, nc.curv, k, be.norm(r),
        syncs=k, breakdown=broke, residual_history=hist, nc_lambda=nc.curv,
    )


def hutchinson_diag(op: Op, like, step, *, samples: int = 1):
    """Hutchinson diagonal estimate diag(A) ≈ E[v ⊙ Av] with Rademacher v
    (built from the sharding-preserving pseudo-noise — no RNG replication).

    ``op`` is applied as-is, once per sample: pass a *prebuilt* operator —
    under the curvature engine's linearized modes each probe is then one
    cached-linear-map application, so ``precondition=True`` shares the outer
    step's single linearization instead of paying a fresh one (the operator
    is exactly the ``G`` the Krylov solve will use)."""
    acc = tree_zeros_like(like)
    for s in range(samples):
        v = jax.tree_util.tree_map(
            lambda n: jnp.sign(n) + (n == 0), tree_pseudo_noise(like, step * samples + s)
        )
        Av = op(v)
        acc = jax.tree_util.tree_map(
            lambda a, vv, av: a + vv * av.astype(jnp.float32), acc, v, Av
        )
    return jax.tree_util.tree_map(lambda a: a / samples, acc)


def sign_correct(g, d):
    """d̃ = −sign(gᵀd)·d  — force a (non-ascent) direction (paper §4.2)."""
    gd = tree_dot(g, d)
    s = -jnp.sign(gd)
    s = jnp.where(s == 0, 1.0, s)
    return tree_scale(s, d), jnp.abs(gd)
