"""Pytree vector algebra used by the Krylov solvers.

This is the execution layer of the *tree* Krylov vector backend
(``core.krylov.TreeVectorBackend``): iterates (r, p, s, x, ...) stay pytrees
with the same structure as the model parameters. Keeping them as pytrees
(instead of ravelling into one flat vector) preserves per-tensor shardings
under pjit — every dot product lowers to a per-shard reduction + one small
all-reduce, and every axpy is embarrassingly parallel. This is the
TPU-native analogue of the paper's "reduce to root" MPI calls. (The *flat*
backend makes the opposite trade: ravel once, fused Pallas recurrences —
see core/krylov.py for when each wins.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_dot(a, b) -> jax.Array:
    """<a, b> in fp32 regardless of leaf dtype (Krylov recurrences are fragile).

    Deliberately ``sum(x*y)`` and NOT ``vdot``: vdot reshapes to 1-D, and a
    flatten of a multi-axis-sharded tensor is unrepresentable in GSPMD, so it
    all-gathers the operand first — on mixtral-8x22b that turned every Krylov
    dot into a 168 GiB all-gather (EXPERIMENTS.md §Perf pair A). The
    elementwise form reduces locally per shard + one scalar all-reduce, which
    is the paper's per-CG-iteration MPI allreduce.
    """
    leaves = [
        jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    ]
    return jnp.sum(jnp.stack(leaves))


def tree_norm(a) -> jax.Array:
    return jnp.sqrt(tree_dot(a, a))


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(alpha, a):
    return jax.tree_util.tree_map(lambda x: alpha * x, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y."""
    return jax.tree_util.tree_map(lambda u, v: alpha * u + v, x, y)


def tree_axpy_cast(alpha, x, y):
    """(alpha * x + y) cast back to y's leaf dtypes — parameter updates from
    f32 Krylov directions onto (possibly bf16) params."""
    return jax.tree_util.tree_map(
        lambda u, v: (alpha * u.astype(jnp.float32) + v.astype(jnp.float32)).astype(v.dtype),
        x, y,
    )


def tree_axpby(alpha, x, beta, y):
    """alpha * x + beta * y."""
    return jax.tree_util.tree_map(lambda u, v: alpha * u + beta * v, x, y)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_where(cond, a, b):
    """Select whole trees on a scalar predicate."""
    return jax.tree_util.tree_map(lambda x, y: jnp.where(cond, x, y), a, b)


def tree_cast(a, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), a)


def tree_size(a) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(a))


def tree_random_like(key, a, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(a)
    keys = jax.random.split(key, len(leaves))
    new = [jax.random.normal(k, x.shape, dtype) for k, x in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, new)


def tree_pseudo_noise(tree, step):
    """Deterministic elementwise pseudo-noise in [-1, 1] with the same pytree
    structure: sin of a position/value/step hash.

    Unlike ``jax.random.normal`` (whose output is born replicated and — when
    added to a sharded Krylov vector — makes GSPMD all-gather the entire
    model-sized tree; observed as 168 GiB all-gathers on mixtral-8x22b,
    EXPERIMENTS.md §Perf pair A), every op here is elementwise or an iota, so
    the noise inherits the consumer's sharding with zero communication.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = []
    sf = jnp.asarray(step, jnp.float32)
    for i, x in enumerate(leaves):
        pos = jnp.zeros(x.shape, jnp.float32)
        for d in range(x.ndim):
            pos = pos + jax.lax.broadcasted_iota(jnp.float32, x.shape, d) * (
                0.7391 + 0.2113 * d
            )
        n = jnp.sin(
            x.astype(jnp.float32) * 1234.567
            + pos * (1.0 + 0.13 * i)
            + sf * 0.61803
            + 0.5 * (i + 1)
        )
        out.append(n)
    return jax.tree_util.tree_unflatten(treedef, out)
