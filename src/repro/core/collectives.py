"""Collective accounting: validate reported sync counts against reality.

``KrylovResult.syncs`` (and ``metrics["blocking_syncs"]``) are *claims* —
integers the solvers compute about their own communication schedule. This
module provides two independent ways to check the claims against what the
compiled program actually does, used by tests/test_collective_audit.py and
``benchmarks/fig5_scaling.py --executed``:

1. **Static jaxpr audit** — :func:`jaxpr_collective_counts` walks a traced
   jaxpr and counts collective primitives (``psum`` — what ``lax.pmean``
   lowers to — plus friends), split into top-level occurrences vs
   occurrences inside ``while_loop`` bodies. For the HF step the invariant
   is: executed collectives = top-level count + Σ (body count × trips),
   where the trip counts are exactly what ``KrylovResult.syncs`` /
   ``n_evals`` report. This catches collectives that silently appear or
   vanish at trace time (e.g. an extra GSPMD-inserted reduce).

2. **Executed-collective counter** — :func:`count_executed` + the
   :func:`preduce` wrapper. ``core.distributed`` routes every explicit
   reduction through ``preduce(tree, axes, tag)``; inside a
   ``count_executed()`` region each traced ``preduce`` site also embeds a
   ``jax.debug.callback`` that fires once per *execution* (per local
   device), including executions inside ``while_loop`` trips — so the
   counter observes the runtime collective count that the static audit can
   only bound. Tracing must happen inside the region (callbacks are baked
   in at trace time): jit a fresh step function under the context manager.

Why an own-layer wrapper instead of monkeypatching ``jax.lax.psum``:
``lax.pmean`` calls ``psum`` through jax-internal bindings that a module
level monkeypatch does not intercept, and primitive ``bind`` hooks see
retraces/transforms, not executions. Tagging at the call site is the only
layer where "one logical reduction" is well-defined.
"""
from __future__ import annotations

import collections
import contextlib
from typing import Any, Iterator, Sequence

import jax
import jax.numpy as jnp

from ..obs import telemetry as _telemetry

# Primitive names that move data across mesh axes (psum covers pmean).
COLLECTIVE_PRIMS = frozenset({
    "psum", "psum2", "pmin", "pmax", "ppermute", "all_gather",
    "all_to_all", "reduce_scatter",
})


class CollectiveCounts:
    """Mutable tally of executed tagged collectives (host-side)."""

    def __init__(self) -> None:
        self.counts: collections.Counter = collections.Counter()

    def add(self, tag: str) -> None:
        self.counts[tag] += 1

    def total(self) -> int:
        return sum(self.counts.values())

    def per_device(self, n_local_devices: int) -> dict:
        """Callbacks fire once per local device shard; normalize them out."""
        out = {}
        for tag, n in self.counts.items():
            assert n % n_local_devices == 0, (tag, n, n_local_devices)
            out[tag] = n // n_local_devices
        return out


_active: CollectiveCounts | None = None


@contextlib.contextmanager
def count_executed() -> Iterator[CollectiveCounts]:
    """Instrument ``preduce`` sites traced within this region.

    The counter observes executions of the instrumented program — keep
    using the jitted function after the region closes and it will keep
    counting into the same object (the callback closes over it).
    """
    global _active
    prev, _active = _active, CollectiveCounts()
    try:
        yield _active
    finally:
        _active = prev


def preduce(tree: Any, axes: Sequence[str] | str, tag: str = "reduce"):
    """``lax.pmean`` over a pytree, tagged for executed-count auditing.

    One ``preduce`` call = one logical collective (jax binds a single
    multi-operand psum for the whole pytree). When tracing happens inside
    :func:`count_executed`, a debug callback rides along and fires once
    per execution per local device — inside ``while_loop`` bodies too,
    which is the whole point: loop-borne collectives are counted at their
    true multiplicity, not once.
    """
    if _active is not None:
        counter = _active
        leaf = jax.tree_util.tree_leaves(tree)[0]
        # The zero-valued scalar operand keeps the callback data-dependent
        # on the reduced value, so it cannot be hoisted out of a loop body.
        jax.debug.callback(
            lambda _: counter.add(tag),
            jnp.zeros((), jnp.float32) * jnp.sum(leaf).astype(jnp.float32),
        )
    sink = _telemetry.active()
    if sink is None:
        return jax.lax.pmean(tree, axes)
    # Telemetry span per executed reduction: the begin callback depends
    # only on the reduce INPUT (XLA:CPU runs it at input-ready — the
    # earliest the collective could issue), the end callback on the reduce
    # OUTPUT (completion). Under HFConfig.overlap the hidden grad-reduce
    # span therefore visibly brackets the curvature primal build; the
    # blocking schedule closes it first. Count tag is unchanged — the
    # label (e.g. "grad_reduce" from telemetry.collective_label) only
    # distinguishes events, so PR 7 executed-count audits stay valid.
    label = _telemetry.current_collective_label() or tag
    leaf_in = jax.tree_util.tree_leaves(tree)[0]
    jax.debug.callback(
        lambda _, _s=sink, _t=tag, _l=label: _s.collective_begin(_t, _l),
        jnp.zeros((), jnp.float32) * jnp.sum(leaf_in).astype(jnp.float32),
    )
    out = jax.lax.pmean(tree, axes)
    leaf_out = jax.tree_util.tree_leaves(out)[0]
    jax.debug.callback(
        lambda _, _s=sink, _t=tag, _l=label: _s.collective_end(_t, _l),
        jnp.zeros((), jnp.float32) * jnp.sum(leaf_out).astype(jnp.float32),
    )
    return out


def _sub_jaxprs(eqn) -> Iterator:
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v


def jaxpr_collective_counts(jaxpr) -> dict:
    """Count collective primitive equations in a (closed) jaxpr.

    Returns ``{"top": Counter, "while_body": Counter}`` mapping primitive
    name → static occurrence count. "top" is everything executed exactly
    once per step (including inside cond branches, scans with known length
    1, pjit bodies); "while_body" is everything inside a ``while`` body or
    cond jaxpr, which executes once per trip — multiply by the trip count
    (= the solver's reported syncs) to predict executed collectives.
    """
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    out = {"top": collections.Counter(), "while_body": collections.Counter()}

    def walk(jx, in_while: bool) -> None:
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name in COLLECTIVE_PRIMS:
                out["while_body" if in_while else "top"][name] += 1
            child_in_while = in_while or name == "while"
            for sub in _sub_jaxprs(eqn):
                walk(sub, child_in_while)

    walk(jaxpr, False)
    return out


def total_static_collectives(jaxpr) -> dict:
    """Convenience: summed psum-family counts per region."""
    c = jaxpr_collective_counts(jaxpr)
    return {k: sum(v.values()) for k, v in c.items()}
