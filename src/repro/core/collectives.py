"""Collective accounting: validate reported sync counts against reality.

``KrylovResult.syncs`` (and ``metrics["blocking_syncs"]``) are *claims* —
integers the solvers compute about their own communication schedule. This
module provides two independent ways to check the claims against what the
compiled program actually does, used by tests/test_collective_audit.py and
``benchmarks/fig5_scaling.py --executed``:

1. **Static jaxpr audit** — :func:`jaxpr_collective_counts` walks a traced
   jaxpr and counts collective primitives (``psum`` — what ``lax.pmean``
   lowers to — plus friends), split into top-level occurrences vs
   occurrences inside ``while_loop`` bodies. For the HF step the invariant
   is: executed collectives = top-level count + Σ (body count × trips),
   where the trip counts are exactly what ``KrylovResult.syncs`` /
   ``n_evals`` report. This catches collectives that silently appear or
   vanish at trace time (e.g. an extra GSPMD-inserted reduce).

2. **Executed-collective counter** — :func:`count_executed` + the
   :func:`preduce` wrapper. ``core.distributed`` routes every explicit
   reduction through ``preduce(tree, axes, tag)``; inside a
   ``count_executed()`` region each traced ``preduce`` site also embeds a
   ``jax.debug.callback`` that fires once per *execution* (per local
   device), including executions inside ``while_loop`` trips — so the
   counter observes the runtime collective count that the static audit can
   only bound. Tracing must happen inside the region (callbacks are baked
   in at trace time): jit a fresh step function under the context manager.

Why an own-layer wrapper instead of monkeypatching ``jax.lax.psum``:
``lax.pmean`` calls ``psum`` through jax-internal bindings that a module
level monkeypatch does not intercept, and primitive ``bind`` hooks see
retraces/transforms, not executions. Tagging at the call site is the only
layer where "one logical reduction" is well-defined.
"""
from __future__ import annotations

import collections
import contextlib
import os
import sys
import threading
import time
from typing import Any, Callable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp

from ..obs import telemetry as _telemetry

# Process exit code used when the watchdog kills a worker stuck in a
# collective. Chosen distinct from Python's 0/1/2 and from signal codes
# (128+N) so the supervisor (launch/multiproc.py, which re-exports this)
# can tell "watchdog fired" apart from an ordinary crash in its logs.
EXIT_WATCHDOG = 87

# Primitive names that move data across mesh axes (psum covers pmean).
COLLECTIVE_PRIMS = frozenset({
    "psum", "psum2", "pmin", "pmax", "ppermute", "all_gather",
    "all_to_all", "reduce_scatter",
})


class CollectiveCounts:
    """Mutable tally of executed tagged collectives (host-side)."""

    def __init__(self) -> None:
        self.counts: collections.Counter = collections.Counter()

    def add(self, tag: str) -> None:
        self.counts[tag] += 1

    def total(self) -> int:
        return sum(self.counts.values())

    def per_device(self, n_local_devices: int) -> dict:
        """Callbacks fire once per local device shard; normalize them out."""
        out = {}
        for tag, n in self.counts.items():
            assert n % n_local_devices == 0, (tag, n, n_local_devices)
            out[tag] = n // n_local_devices
        return out


_active: CollectiveCounts | None = None


@contextlib.contextmanager
def count_executed() -> Iterator[CollectiveCounts]:
    """Instrument ``preduce`` sites traced within this region.

    The counter observes executions of the instrumented program — keep
    using the jitted function after the region closes and it will keep
    counting into the same object (the callback closes over it).
    """
    global _active
    prev, _active = _active, CollectiveCounts()
    try:
        yield _active
    finally:
        _active = prev


class Watchdog:
    """Turn an indefinitely-blocking collective into a detectable death.

    A gloo all-reduce whose peer died blocks *forever* inside a C++ call:
    no Python exception can be raised there and a signal handler will not
    run until the call returns (which it never does). The only reliable
    escape is a side thread that notices the collective has been
    outstanding too long and hard-exits the process — the supervisor
    (``launch.multiproc.spawn_supervised``) then sees ``EXIT_WATCHDOG``
    and restarts the job from the last valid checkpoint.

    Arm/disarm callbacks are baked into :func:`preduce` sites traced while
    :func:`collective_watchdog` is installed: arm fires at reduce-input-
    ready (the earliest the collective can issue), disarm at reduce-output
    (completion) — the same data-dependence trick as the telemetry spans,
    so the armed window brackets exactly the blocking region. Per-tag FIFO
    pairing mirrors ``Telemetry._pending``.

    ``on_timeout`` (tests) replaces the default hard-exit with a callable
    ``(tag, waited_s) -> None``.
    """

    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[str, float], None]] = None,
                 poll_s: Optional[float] = None):
        self.timeout_s = float(timeout_s)
        self.on_timeout = on_timeout
        self._poll_s = poll_s if poll_s is not None else max(
            0.05, self.timeout_s / 4.0)
        self._lock = threading.Lock()
        self._outstanding: dict = {}   # tag -> deque of arm timestamps
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.fired = False
        self.fired_tag: Optional[str] = None

    def arm(self, tag: str) -> None:
        with self._lock:
            self._outstanding.setdefault(
                tag, collections.deque()).append(time.time())

    def disarm(self, tag: str) -> None:
        with self._lock:
            q = self._outstanding.get(tag)
            if q:
                q.popleft()

    def _oldest_overdue(self, now: float):
        with self._lock:
            for tag, q in self._outstanding.items():
                if q and now - q[0] > self.timeout_s:
                    return tag, now - q[0]
        return None

    def start(self) -> "Watchdog":
        self._thread = threading.Thread(
            target=self._run, daemon=True, name="collective-watchdog")
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self._poll_s):
            hit = self._oldest_overdue(time.time())
            if hit is None:
                continue
            tag, waited = hit
            self.fired, self.fired_tag = True, tag
            if self.on_timeout is not None:
                self.on_timeout(tag, waited)
                return
            sys.stderr.write(
                f"[watchdog] collective {tag!r} blocked {waited:.1f}s "
                f"(> {self.timeout_s:.1f}s); peer presumed dead — "
                f"exiting {EXIT_WATCHDOG}\n")
            sys.stderr.flush()
            # os._exit, not sys.exit: the main thread is wedged in gloo
            # C++ and will never unwind a SystemExit.
            os._exit(EXIT_WATCHDOG)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)


_watchdog: Optional[Watchdog] = None


@contextlib.contextmanager
def collective_watchdog(timeout_s: float,
                        on_timeout: Optional[Callable] = None,
                        poll_s: Optional[float] = None):
    """Trace-time install: ``preduce`` sites traced inside this context
    bake in watchdog arm/disarm callbacks (same lifetime rule as
    ``count_executed`` — the compiled program keeps feeding the returned
    :class:`Watchdog` after the context exits). The monitor thread starts
    immediately; call ``.stop()`` to retire it (tests), or leave it for
    the life of the process (training)."""
    global _watchdog
    wd = Watchdog(timeout_s, on_timeout, poll_s).start()
    prev, _watchdog = _watchdog, wd
    try:
        yield wd
    finally:
        _watchdog = prev


def preduce(tree: Any, axes: Sequence[str] | str, tag: str = "reduce"):
    """``lax.pmean`` over a pytree, tagged for executed-count auditing.

    One ``preduce`` call = one logical collective (jax binds a single
    multi-operand psum for the whole pytree). When tracing happens inside
    :func:`count_executed`, a debug callback rides along and fires once
    per execution per local device — inside ``while_loop`` bodies too,
    which is the whole point: loop-borne collectives are counted at their
    true multiplicity, not once.
    """
    if _active is not None:
        counter = _active
        leaf = jax.tree_util.tree_leaves(tree)[0]
        # The zero-valued scalar operand keeps the callback data-dependent
        # on the reduced value, so it cannot be hoisted out of a loop body.
        jax.debug.callback(
            lambda _: counter.add(tag),
            jnp.zeros((), jnp.float32) * jnp.sum(leaf).astype(jnp.float32),
        )
    sink = _telemetry.active()
    wd = _watchdog
    if sink is None and wd is None:
        return jax.lax.pmean(tree, axes)
    # Telemetry span / watchdog window per executed reduction: the begin
    # callback depends only on the reduce INPUT (XLA:CPU runs it at
    # input-ready — the earliest the collective could issue), the end
    # callback on the reduce OUTPUT (completion). Under HFConfig.overlap
    # the hidden grad-reduce span therefore visibly brackets the curvature
    # primal build; the blocking schedule closes it first. The watchdog
    # arms over exactly the same window, so a peer death mid-reduce leaves
    # it armed past its timeout. Count tag is unchanged — the label (e.g.
    # "grad_reduce" from telemetry.collective_label) only distinguishes
    # events, so PR 7 executed-count audits stay valid.
    label = _telemetry.current_collective_label() or tag

    def _begin(_, _s=sink, _w=wd, _t=tag, _l=label):
        if _w is not None:
            _w.arm(_t)
        if _s is not None:
            _s.collective_begin(_t, _l)

    def _end(_, _s=sink, _w=wd, _t=tag, _l=label):
        if _w is not None:
            _w.disarm(_t)
        if _s is not None:
            _s.collective_end(_t, _l)

    leaf_in = jax.tree_util.tree_leaves(tree)[0]
    jax.debug.callback(
        _begin, jnp.zeros((), jnp.float32) * jnp.sum(leaf_in).astype(jnp.float32))
    out = jax.lax.pmean(tree, axes)
    leaf_out = jax.tree_util.tree_leaves(out)[0]
    jax.debug.callback(
        _end, jnp.zeros((), jnp.float32) * jnp.sum(leaf_out).astype(jnp.float32))
    return out


def _sub_jaxprs(eqn) -> Iterator:
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v


def jaxpr_collective_counts(jaxpr) -> dict:
    """Count collective primitive equations in a (closed) jaxpr.

    Returns ``{"top": Counter, "while_body": Counter}`` mapping primitive
    name → static occurrence count. "top" is everything executed exactly
    once per step (including inside cond branches, scans with known length
    1, pjit bodies); "while_body" is everything inside a ``while`` body or
    cond jaxpr, which executes once per trip — multiply by the trip count
    (= the solver's reported syncs) to predict executed collectives.
    """
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    out = {"top": collections.Counter(), "while_body": collections.Counter()}

    def walk(jx, in_while: bool) -> None:
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name in COLLECTIVE_PRIMS:
                out["while_body" if in_while else "top"][name] += 1
            child_in_while = in_while or name == "while"
            for sub in _sub_jaxprs(eqn):
                walk(sub, child_in_while)

    walk(jaxpr, False)
    return out


def total_static_collectives(jaxpr) -> dict:
    """Convenience: summed psum-family counts per region."""
    c = jaxpr_collective_counts(jaxpr)
    return {k: sum(v.values()) for k, v in c.items()}
