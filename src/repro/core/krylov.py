"""Krylov vector backends + the solver components shared by every solver.

The Krylov recurrences in ``solvers.py`` are written against an abstract
*vector backend* instead of concrete pytree ops. A backend decides how the
Krylov iterates (x, r, p, s, ...) are **represented** and how the
bandwidth-bound recurrences (axpy chains, dot products) **execute**:

* ``TreeVectorBackend`` ("tree") — iterates stay pytrees with the parameter
  structure; every op maps over leaves (``tree_math``). Per-tensor shardings
  survive under pjit/GSPMD: each dot is a per-shard reduction + one scalar
  all-reduce (the paper's per-CG-iteration MPI reduce). This is the right
  backend when params are sharded across devices.

* ``FlatVectorBackend`` ("flat") — iterates are ravelled ONCE per solve into
  a single flat f32 buffer and the recurrences run through the fused Pallas
  kernels (``kernels.ops.bicgstab_x_update`` / ``bicgstab_residual_dots`` /
  ``dot2``), which fuse the axpy chains with the dots they feed and so remove
  whole HBM passes over the model-sized vectors. The operator A still sees
  pytrees (``wrap_op`` unflattens at the boundary). Off-TPU the kernels fall
  back to Pallas interpret mode. This is the right backend when the Krylov
  state is replicated per-chip (pure data parallelism, the paper's setting)
  and the inner loop is HBM-bandwidth-bound.

Shared solver components (used by ``cg``/``pcg``/``bicgstab`` so the logic
exists exactly once):

* ``nc_probe`` / ``nc_init``      — negative-curvature capture of the *raw*
  (undamped) operator from direction/operator-product pairs the recurrence
  already has (dᵀGd = dᵀAd − λ‖d‖², no extra operator applications),
* ``phi_value`` / ``best_update`` — free CG-backtracking: φ(x) = ½xᵀAx − bᵀx
  evaluated via the residual identity A·x = b − r, tracking the best-model
  iterate over the trajectory,
* ``guard_div``                   — breakdown-guarded division (Bi-CG-STAB
  ρ/ω breakdowns, CG indefiniteness truncation).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import tree_math as tm

EPS = 1e-20

Op = Callable[[Any], Any]


# ---------------------------------------------------------------------------
# Vector backends
# ---------------------------------------------------------------------------


class TreeVectorBackend:
    """Sharding-preserving pytree backend (the repo's original representation).

    ``lift``/``lower`` are identities; every op is a leaf-map. Dots reduce
    per shard + one scalar all-reduce under pjit (see tree_math.tree_dot).
    """

    name = "tree"

    # -- representation -----------------------------------------------------
    def lift(self, tree):
        return tree

    def lower(self, vec):
        return vec

    def wrap_op(self, A: Op) -> Op:
        return A

    # -- linear algebra -----------------------------------------------------
    def dot(self, u, v):
        return tm.tree_dot(u, v)

    def dot2(self, u, v):
        """(<u,v>, <v,v>)."""
        return tm.tree_dot(u, v), tm.tree_dot(v, v)

    def norm(self, v):
        return tm.tree_norm(v)

    def sub(self, a, b):
        return tm.tree_sub(a, b)

    def axpy(self, alpha, x, y):
        return tm.tree_axpy(alpha, x, y)

    def scale(self, alpha, x):
        return tm.tree_scale(alpha, x)

    def mul(self, m, v):
        return jax.tree_util.tree_map(lambda mm, vv: mm * vv, m, v)

    def where(self, cond, a, b):
        return tm.tree_where(cond, a, b)

    def zeros_like(self, v):
        return tm.tree_zeros_like(v)

    # -- fused recurrence ops (unfused here: one leaf-map per op) -----------
    def fused_update(self, y, u, v, a, g):
        """y + a*u + g*v  (the Bi-CG-STAB x/p updates)."""
        return tm.tree_axpy(g, v, tm.tree_axpy(a, u, y))

    def update_residual(self, s, As, gamma, r0s=None):
        """r = s − γ·As; returns (r, <r,r0s> or None, <r,r>)."""
        r = tm.tree_axpy(-gamma, As, s)
        d1 = None if r0s is None else tm.tree_dot(r, r0s)
        return r, d1, tm.tree_dot(r, r)


class FlatVectorBackend:
    """Flat-buffer backend over the fused Pallas kernels.

    Built from a *template* pytree (structure/shapes of the Krylov space —
    in HF that is the rhs b). ``lift`` ravels a pytree into one flat f32
    vector; ``lower`` restores the pytree (f32 leaves, matching what the
    tree backend produces for Krylov iterates). The recurrences then run on
    flat buffers via the fused kernels; ``interpret=None`` resolves to
    interpret mode off-TPU (kernels.ops handles the resolution).
    """

    name = "flat"

    def __init__(self, template, interpret: Optional[bool] = None):
        from ..kernels import ops as _kops

        self._kops = _kops
        self._interpret = interpret
        leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._shapes = [l.shape for l in leaves]
        self._sizes = [int(l.size) for l in leaves]
        self._offsets = []
        off = 0
        for s in self._sizes:
            off += s
            self._offsets.append(off)

    # -- representation -----------------------------------------------------
    def lift(self, tree):
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate(
            [l.astype(jnp.float32).reshape(-1) for l in leaves]
        )

    def lower(self, vec):
        parts = jnp.split(vec, self._offsets[:-1]) if len(self._sizes) > 1 else [vec]
        leaves = [p.reshape(s) for p, s in zip(parts, self._shapes)]
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def wrap_op(self, A: Op) -> Op:
        return lambda v: self.lift(A(self.lower(v)))

    # -- linear algebra -----------------------------------------------------
    def dot(self, u, v):
        return self._kops.dot2(u, v, interpret=self._interpret)[0]

    def dot2(self, u, v):
        return self._kops.dot2(u, v, interpret=self._interpret)

    def norm(self, v):
        return jnp.sqrt(self._kops.dot2(v, v, interpret=self._interpret)[1])

    def sub(self, a, b):
        return a - b

    def axpy(self, alpha, x, y):
        return alpha * x + y

    def scale(self, alpha, x):
        return alpha * x

    def mul(self, m, v):
        return m * v

    def where(self, cond, a, b):
        return jnp.where(cond, a, b)

    def zeros_like(self, v):
        return jnp.zeros_like(v)

    # -- fused recurrence ops ------------------------------------------------
    def fused_update(self, y, u, v, a, g):
        return self._kops.bicgstab_x_update(y, u, v, a, g, interpret=self._interpret)

    def update_residual(self, s, As, gamma, r0s=None):
        r, d1, d2 = self._kops.bicgstab_residual_dots(
            s, As, s if r0s is None else r0s, gamma, interpret=self._interpret
        )
        return r, (None if r0s is None else d1), d2


BACKENDS = ("tree", "flat")

_TREE_BACKEND = TreeVectorBackend()


def get_backend(name: str, template=None, interpret: Optional[bool] = None):
    """Resolve a backend by name. ``template`` (a pytree spanning the Krylov
    space, e.g. the rhs b) is required for "flat"."""
    if name == "tree":
        return _TREE_BACKEND
    if name == "flat":
        if template is None:
            raise ValueError("flat backend requires a template pytree")
        return FlatVectorBackend(template, interpret=interpret)
    raise ValueError(f"krylov backend must be one of {BACKENDS}, got {name!r}")


# ---------------------------------------------------------------------------
# Shared solver components
# ---------------------------------------------------------------------------


class NCState(NamedTuple):
    """Most-negative normalized raw-curvature direction seen so far."""
    found: jax.Array   # bool scalar
    dir: Any           # backend vector, unit norm (zeros if none)
    curv: jax.Array    # dᵀGd / ‖d‖² for `dir` (0 if none)


def nc_init(be, b) -> NCState:
    return NCState(jnp.zeros((), bool), be.zeros_like(b), jnp.zeros(()))


def nc_probe(be, d, dAd, d_sq, lam, st: NCState) -> NCState:
    """Update the NC state from a (direction, dᵀAd, dᵀd) triple the
    recurrence already computed. A is the damped operator: the raw curvature
    is (dᵀAd − λ‖d‖²)/‖d‖² — negative raw curvature is a saddle-escape
    direction (the paper's dᵀHd < 0 criterion on the stochastic Hessian)."""
    raw = (dAd - lam * d_sq) / jnp.maximum(d_sq, EPS)
    is_nc = raw < 0.0
    better = jnp.logical_and(is_nc, raw < st.curv)
    ndir = be.where(
        better, be.scale(1.0 / jnp.sqrt(jnp.maximum(d_sq, EPS)), d), st.dir
    )
    ncurv = jnp.where(better, raw, st.curv)
    return NCState(jnp.logical_or(st.found, is_nc), ndir, ncurv)


class BestState(NamedTuple):
    """Free CG-backtracking: argmin over the trajectory of φ(x)=½xᵀAx−bᵀx."""
    x: Any
    r: Any
    phi: jax.Array


def phi_value(be, b, x, r):
    """Quadratic model φ(x) = ½xᵀAx − bᵀx via A·x = b − r (no operator
    application, two scalar dots)."""
    return -0.5 * be.dot(b, x) - 0.5 * be.dot(x, r)


def best_init(be, b, x0, r0) -> BestState:
    return BestState(x0, r0, phi_value(be, b, x0, r0))


def best_update(be, x, r, phi, valid, st: BestState) -> BestState:
    improved = jnp.logical_and(phi < st.phi, valid)
    return BestState(
        be.where(improved, x, st.x),
        be.where(improved, r, st.r),
        jnp.where(improved, phi, st.phi),
    )


def guard_div(num, den, eps: float = EPS):
    """num/den with breakdown detection: returns (quotient, |den|<eps)."""
    bad = jnp.abs(den) < eps
    return num / jnp.where(bad, 1.0, den), bad
