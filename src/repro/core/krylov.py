"""Krylov vector backends + the solver components shared by every solver.

The Krylov recurrences in ``solvers.py`` are written against an abstract
*vector backend* instead of concrete pytree ops. A backend decides how the
Krylov iterates (x, r, p, s, ...) are **represented** and how the
bandwidth-bound recurrences (axpy chains, dot products) **execute**:

* ``TreeVectorBackend`` ("tree") — iterates stay pytrees with the parameter
  structure; every op maps over leaves (``tree_math``). Per-tensor shardings
  survive under pjit/GSPMD: each dot is a per-shard reduction + one scalar
  all-reduce (the paper's per-CG-iteration MPI reduce). This is the right
  backend when params are sharded across devices.

* ``FlatVectorBackend`` ("flat") — iterates are ravelled ONCE per solve into
  a single flat f32 buffer and the recurrences run through the fused Pallas
  kernels (``kernels.ops.bicgstab_x_update`` / ``bicgstab_residual_dots`` /
  ``dot2``), which fuse the axpy chains with the dots they feed and so remove
  whole HBM passes over the model-sized vectors. The operator A still sees
  pytrees (``wrap_op`` unflattens at the boundary). Off-TPU the kernels fall
  back to Pallas interpret mode. This is the right backend when the Krylov
  state is replicated per-chip (pure data parallelism, the paper's setting)
  and the inner loop is HBM-bandwidth-bound.

**Block extension** (the ``BlockVectorBackend`` protocol): both backends also
speak *blocks* — ordered stacks of s Krylov vectors. A block is the backend's
native multi-vector representation (tree: pytree with a leading ``s`` axis on
every leaf; flat: an ``(s, n)`` f32 matrix) and supports

* ``block_stack`` / ``block_col``  — build a block from vectors / read one out,
* ``lift_block`` / ``lower_block`` — convert to/from the stacked-pytree form
  the block curvature products (core/blocks.py) consume,
* ``wrap_block_op``                — adapt a stacked-pytree block operator to
  backend blocks (the multi-tangent curvature product boundary),
* ``gram``                         — the (s_u × s_v) Gram matrix ⟨u_i, v_j⟩ in
  ONE pass / one reduction (tree: per-leaf ``dot_general`` contractions, one
  scalar-matrix all-reduce under pjit; flat: the fused Pallas ``dots_block``
  kernel). This is the s-step solvers' single communication point per s
  Krylov iterations (core/sstep.py),
* ``block_combine``                — C @ block: materialize linear
  combinations of the block columns (one pass for any number of outputs).

Shared solver components (used by ``cg``/``pcg``/``bicgstab`` so the logic
exists exactly once):

* ``nc_probe`` / ``nc_init``      — negative-curvature capture of the *raw*
  (undamped) operator from direction/operator-product pairs the recurrence
  already has (dᵀGd = dᵀAd − λ‖d‖², no extra operator applications),
* ``phi_value`` / ``best_update`` — free CG-backtracking: φ(x) = ½xᵀAx − bᵀx
  evaluated via the residual identity A·x = b − r, tracking the best-model
  iterate over the trajectory,
* ``guard_div``                   — breakdown-guarded division (Bi-CG-STAB
  ρ/ω breakdowns, CG indefiniteness truncation),
* ``ritz_from_segment`` / ``leja_order`` — free spectral estimates: Ritz
  values of A on a Krylov chain extracted from a Gram matrix the s-step
  solvers already reduced (no extra operator applications or reductions),
  and the deterministic Leja ordering that turns them into stable
  shifted-Newton basis parameters (core/sstep.py).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import tree_math as tm

EPS = 1e-20

Op = Callable[[Any], Any]


# ---------------------------------------------------------------------------
# Vector backends
# ---------------------------------------------------------------------------


class TreeVectorBackend:
    """Sharding-preserving pytree backend (the repo's original representation).

    ``lift``/``lower`` are identities; every op is a leaf-map. Dots reduce
    per shard + one scalar all-reduce under pjit (see tree_math.tree_dot).
    """

    name = "tree"

    # -- representation -----------------------------------------------------
    def lift(self, tree):
        return tree

    def lower(self, vec):
        return vec

    def wrap_op(self, A: Op) -> Op:
        return A

    # -- linear algebra -----------------------------------------------------
    def dot(self, u, v):
        return tm.tree_dot(u, v)

    def dot2(self, u, v):
        """(<u,v>, <v,v>)."""
        return tm.tree_dot(u, v), tm.tree_dot(v, v)

    def norm(self, v):
        return tm.tree_norm(v)

    def sub(self, a, b):
        return tm.tree_sub(a, b)

    def axpy(self, alpha, x, y):
        return tm.tree_axpy(alpha, x, y)

    def scale(self, alpha, x):
        return tm.tree_scale(alpha, x)

    def mul(self, m, v):
        return jax.tree_util.tree_map(lambda mm, vv: mm * vv, m, v)

    def where(self, cond, a, b):
        return tm.tree_where(cond, a, b)

    def zeros_like(self, v):
        return tm.tree_zeros_like(v)

    # -- fused recurrence ops (unfused here: one leaf-map per op) -----------
    def fused_update(self, y, u, v, a, g):
        """y + a*u + g*v  (the Bi-CG-STAB x/p updates)."""
        return tm.tree_axpy(g, v, tm.tree_axpy(a, u, y))

    def update_residual(self, s, As, gamma, r0s=None):
        """r = s − γ·As; returns (r, <r,r0s> or None, <r,r>)."""
        r = tm.tree_axpy(-gamma, As, s)
        d1 = None if r0s is None else tm.tree_dot(r, r0s)
        return r, d1, tm.tree_dot(r, r)

    # -- block (multi-vector) ops: the BlockVectorBackend extension ---------
    # A tree block is a pytree whose leaves carry a leading stack axis —
    # identical to what the block curvature products (core/blocks.py)
    # produce, so lift_block/lower_block are identities here.
    def block_stack(self, vecs):
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *vecs)

    def block_col(self, block, j):
        return jax.tree_util.tree_map(lambda x: x[j], block)

    def lift_block(self, stacked):
        return stacked

    def lower_block(self, block):
        return block

    def wrap_block_op(self, A_blk: Op) -> Op:
        return A_blk

    def gram(self, U, V):
        """(s_u, s_v) matrix of ⟨u_i, v_j⟩ in f32 — one reduction.

        Per-leaf ``dot_general`` contracting every non-stack axis (NOT a
        reshape-to-2D matmul: a flatten of a multi-axis-sharded leaf is
        unrepresentable in GSPMD — same hazard tree_dot documents, §Perf
        pair A). Under pjit this is a per-shard contraction + one small
        (s_u × s_v) all-reduce: the s-step cycle's single sync.
        """
        parts = [
            jax.lax.dot_general(
                x.astype(jnp.float32), y.astype(jnp.float32),
                ((tuple(range(1, x.ndim)), tuple(range(1, y.ndim))), ((), ())),
            )
            for x, y in zip(jax.tree_util.tree_leaves(U), jax.tree_util.tree_leaves(V))
        ]
        return jnp.sum(jnp.stack(parts), axis=0)

    def block_combine(self, coeffs, U):
        """coeffs @ block: (s,) coeffs → one vector, (m, s) → an m-block."""
        return jax.tree_util.tree_map(
            lambda x: jnp.tensordot(coeffs, x.astype(jnp.float32), axes=1), U
        )


class FlatVectorBackend:
    """Flat-buffer backend over the fused Pallas kernels.

    Built from a *template* pytree (structure/shapes of the Krylov space —
    in HF that is the rhs b). ``lift`` ravels a pytree into one flat f32
    vector; ``lower`` restores the pytree (f32 leaves, matching what the
    tree backend produces for Krylov iterates). The recurrences then run on
    flat buffers via the fused kernels; ``interpret=None`` resolves to
    interpret mode off-TPU (kernels.ops handles the resolution).
    """

    name = "flat"

    def __init__(self, template, interpret: Optional[bool] = None):
        from ..kernels import ops as _kops

        self._kops = _kops
        self._interpret = interpret
        leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._shapes = [l.shape for l in leaves]
        self._sizes = [int(l.size) for l in leaves]
        self._offsets = []
        off = 0
        for s in self._sizes:
            off += s
            self._offsets.append(off)

    # -- representation -----------------------------------------------------
    def lift(self, tree):
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate(
            [l.astype(jnp.float32).reshape(-1) for l in leaves]
        )

    def lower(self, vec):
        parts = jnp.split(vec, self._offsets[:-1]) if len(self._sizes) > 1 else [vec]
        leaves = [p.reshape(s) for p, s in zip(parts, self._shapes)]
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def wrap_op(self, A: Op) -> Op:
        return lambda v: self.lift(A(self.lower(v)))

    # -- linear algebra -----------------------------------------------------
    def dot(self, u, v):
        return self._kops.dot2(u, v, interpret=self._interpret)[0]

    def dot2(self, u, v):
        return self._kops.dot2(u, v, interpret=self._interpret)

    def norm(self, v):
        return jnp.sqrt(self._kops.dot2(v, v, interpret=self._interpret)[1])

    def sub(self, a, b):
        return a - b

    def axpy(self, alpha, x, y):
        return alpha * x + y

    def scale(self, alpha, x):
        return alpha * x

    def mul(self, m, v):
        return m * v

    def where(self, cond, a, b):
        return jnp.where(cond, a, b)

    def zeros_like(self, v):
        return jnp.zeros_like(v)

    # -- fused recurrence ops ------------------------------------------------
    def fused_update(self, y, u, v, a, g):
        return self._kops.bicgstab_x_update(y, u, v, a, g, interpret=self._interpret)

    def update_residual(self, s, As, gamma, r0s=None):
        r, d1, d2 = self._kops.bicgstab_residual_dots(
            s, As, s if r0s is None else r0s, gamma, interpret=self._interpret
        )
        return r, (None if r0s is None else d1), d2

    # -- block (multi-vector) ops: the BlockVectorBackend extension ---------
    # A flat block is an (s, n) f32 matrix — one row per Krylov vector.
    def block_stack(self, vecs):
        return jnp.stack(vecs)

    def block_col(self, block, j):
        return block[j]

    def lift_block(self, stacked):
        """Stacked pytree (leading s axis on every leaf) → (s, n) matrix."""
        leaves = jax.tree_util.tree_leaves(stacked)
        s = leaves[0].shape[0]
        return jnp.concatenate(
            [l.astype(jnp.float32).reshape(s, -1) for l in leaves], axis=1
        )

    def lower_block(self, block):
        """(s, n) matrix → stacked pytree (leading s axis on every leaf)."""
        s = block.shape[0]
        parts = (
            jnp.split(block, self._offsets[:-1], axis=1)
            if len(self._sizes) > 1 else [block]
        )
        leaves = [p.reshape((s,) + sh) for p, sh in zip(parts, self._shapes)]
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def wrap_block_op(self, A_blk: Op) -> Op:
        return lambda M: self.lift_block(A_blk(self.lower_block(M)))

    def gram(self, U, V):
        """(s_u, s_v) Gram via the fused Pallas ``dots_block`` kernel: every
        ⟨u_i, v_j⟩ from ONE pass over the stacked data (the s-step cycle's
        single reduction)."""
        return self._kops.gram_block(U, V, interpret=self._interpret)

    def block_combine(self, coeffs, U):
        return coeffs @ U


BACKENDS = ("tree", "flat")

_TREE_BACKEND = TreeVectorBackend()


def get_backend(name: str, template=None, interpret: Optional[bool] = None):
    """Resolve a backend by name. ``template`` (a pytree spanning the Krylov
    space, e.g. the rhs b) is required for "flat"."""
    if name == "tree":
        return _TREE_BACKEND
    if name == "flat":
        if template is None:
            raise ValueError("flat backend requires a template pytree")
        return FlatVectorBackend(template, interpret=interpret)
    raise ValueError(f"krylov backend must be one of {BACKENDS}, got {name!r}")


# ---------------------------------------------------------------------------
# Shared solver components
# ---------------------------------------------------------------------------


class NCState(NamedTuple):
    """Most-negative normalized raw-curvature direction seen so far."""
    found: jax.Array   # bool scalar
    dir: Any           # backend vector, unit norm (zeros if none)
    curv: jax.Array    # dᵀGd / ‖d‖² for `dir` (0 if none)


def nc_init(be, b) -> NCState:
    return NCState(jnp.zeros((), bool), be.zeros_like(b), jnp.zeros(()))


def nc_probe(be, d, dAd, d_sq, lam, st: NCState) -> NCState:
    """Update the NC state from a (direction, dᵀAd, dᵀd) triple the
    recurrence already computed. A is the damped operator: the raw curvature
    is (dᵀAd − λ‖d‖²)/‖d‖² — negative raw curvature is a saddle-escape
    direction (the paper's dᵀHd < 0 criterion on the stochastic Hessian)."""
    raw = (dAd - lam * d_sq) / jnp.maximum(d_sq, EPS)
    is_nc = raw < 0.0
    better = jnp.logical_and(is_nc, raw < st.curv)
    ndir = be.where(
        better, be.scale(1.0 / jnp.sqrt(jnp.maximum(d_sq, EPS)), d), st.dir
    )
    ncurv = jnp.where(better, raw, st.curv)
    return NCState(jnp.logical_or(st.found, is_nc), ndir, ncurv)


class BestState(NamedTuple):
    """Free CG-backtracking: argmin over the trajectory of φ(x)=½xᵀAx−bᵀx."""
    x: Any
    r: Any
    phi: jax.Array


def phi_value(be, b, x, r):
    """Quadratic model φ(x) = ½xᵀAx − bᵀx via A·x = b − r (no operator
    application, two scalar dots)."""
    return -0.5 * be.dot(b, x) - 0.5 * be.dot(x, r)


def best_init(be, b, x0, r0) -> BestState:
    return BestState(x0, r0, phi_value(be, b, x0, r0))


def best_update(be, x, r, phi, valid, st: BestState) -> BestState:
    improved = jnp.logical_and(phi < st.phi, valid)
    return BestState(
        be.where(improved, x, st.x),
        be.where(improved, r, st.r),
        jnp.where(improved, phi, st.phi),
    )


def guard_div(num, den, eps: float = EPS):
    """num/den with breakdown detection: returns (quotient, |den|<eps)."""
    bad = jnp.abs(den) < eps
    return num / jnp.where(bad, 1.0, den), bad


def ritz_from_segment(Gp, Tp, *, jitter: float = 1e-6):
    """Ritz values of A on the leading d = L−1 vectors of a Krylov chain —
    for FREE, from data an s-step cycle already has.

    ``Gp`` is the (L, L) Gram of one polynomial power chain
    [v_0, …, v_{L−1}] (a segment of the s-step basis — the cycle's single
    reduction already contains it) and ``Tp`` the (L, d) recurrence block
    whose column j holds the coordinates of A·v_j in the chain (exact for
    j < d = L−1: the three-term basis recurrence IS that expansion, so no
    probe columns or extra operator products are needed). Then

        ⟨v_i, A v_j⟩ = (Gp @ Tp)[i, j]        (i < L, j < d)

    and the Ritz values solve the d×d generalized symmetric eigenproblem
    K y = θ M y with K = sym((Gp Tp)[:d, :d]), M = Gp[:d, :d]. Both are
    normalized to correlation scale, reduced by Cholesky (M = CCᵀ ⇒
    eigvalsh(C⁻¹ K C⁻ᵀ)) and solved with ``jnp.linalg.eigvalsh`` — a few
    d×d host-side-free ops, jit/TPU-friendly (no ``eig`` of a
    nonsymmetric matrix; A is the symmetric damped curvature operator).

    Returns ``(ritz, ok)``: θ ascending, and a validity flag (finite
    inputs, finite Cholesky, finite eigenvalues). Callers treat ok=False
    as "no estimate" and keep/fall back to the monomial basis.
    """
    L = Gp.shape[0]
    d = L - 1
    ok = jnp.logical_and(jnp.all(jnp.isfinite(Gp)),
                         jnp.all(jnp.isfinite(Tp)))
    Gp = jnp.where(jnp.isfinite(Gp), Gp, 0.0)
    K = (Gp @ jnp.where(ok, Tp, 0.0))[:d, :d]
    M = Gp[:d, :d]
    dg = jnp.sqrt(jnp.clip(jnp.diagonal(M), 0.0))
    dn = 1.0 / jnp.maximum(dg, EPS)
    scale = jnp.outer(dn, dn)
    Kn = 0.5 * (K + K.T) * scale
    Mn = M * scale
    C = jnp.linalg.cholesky(Mn + jitter * jnp.eye(d, dtype=Mn.dtype))
    ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(C)))
    Cs = jnp.where(ok, C, jnp.eye(d, dtype=Mn.dtype))
    Y = jax.scipy.linalg.solve_triangular(Cs, Kn, lower=True)
    S = jax.scipy.linalg.solve_triangular(Cs, Y.T, lower=True)
    S = 0.5 * (S + S.T)
    ritz = jnp.linalg.eigvalsh(jnp.where(jnp.isfinite(S), S, 0.0))
    return ritz, jnp.logical_and(ok, jnp.all(jnp.isfinite(ritz)))


def leja_order(vals):
    """Deterministic magnitude-damped Leja ordering of real shift values.

    θ_k maximizes |θ| · Π_{j<k} |θ − θ_j| over the remainder (so
    θ_0 = argmax |θ|). The |θ| weight is a deliberate departure from the
    textbook unweighted product: it keeps the early shifts sweeping DOWN
    from the dominant end of the spectrum instead of alternating between
    the extremes, which measurably conditions f32 Newton chains grown
    from spectrally top-heavy Krylov vectors better — the dominant
    eigencomponents are damped first, before the products can amplify
    them (A/B-measured on the §Perf pair G bench: the unweighted order
    doubles the executed reduce count of the Bi-CG-STAB s=4 rows).
    Ties resolve by first occurrence (``argmax``), so the output is a
    deterministic function of the input array — jit-stable across calls.
    """
    n = vals.shape[0]
    tiny = jnp.asarray(1e-30, vals.dtype)

    def body(k, st):
        out, taken, logp = st
        i = jnp.argmax(jnp.where(taken, -jnp.inf, logp))
        t = vals[i]
        return (
            out.at[k].set(t),
            taken.at[i].set(True),
            logp + jnp.log(jnp.maximum(jnp.abs(vals - t), tiny)),
        )

    out, _, _ = jax.lax.fori_loop(
        0, n, body,
        (jnp.zeros_like(vals), jnp.zeros((n,), bool),
         jnp.log(jnp.maximum(jnp.abs(vals), tiny))),
    )
    return out
