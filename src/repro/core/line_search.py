"""Armijo backtracking line search (paper Alg. 2 line 9) as a lax.while_loop.

f(θ + α δ) ≤ f(θ) + c·α·gᵀδ,  α ∈ {1, β, β², ...}.

Each trial re-evaluates the full-batch loss — data-parallel, one all-reduce —
which is the paper's "line search inherits the scaling of the gradient" cost
model (Fig. 5). Runs fully inside the jitted HF step: no host round trips.

``paired=True`` (the overlapped-collective schedule, HFConfig.overlap):
each loop trip evaluates TWO consecutive candidates (α, βα) — two
independent forwards whose loss all-reduces pipeline back-to-back with no
scalar gate between them — then selects the first acceptable one. The
accepted α is identical to the sequential search (same β-descending
candidate sequence, first-accept semantics); the trade is one speculative
extra evaluation's compute for halving the number of BLOCKING scalar
round-trips per search from E to ⌈E/2⌉ (benchmarks/comm_model.py,
``overlap=True``).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .tree_math import tree_axpy_cast


class LineSearchResult(NamedTuple):
    alpha: jax.Array
    f_new: jax.Array
    n_evals: jax.Array
    success: jax.Array


def armijo(
    loss_fn: Callable[[Any], jax.Array],
    params,
    f0: jax.Array,
    delta,
    g_dot_delta: jax.Array,
    *,
    c: float = 1e-2,
    beta: float = 0.5,
    max_backtracks: int = 12,
    alpha0: float = 1.0,
    paired: bool = False,
) -> LineSearchResult:
    """loss_fn already closes over the batch: params ↦ scalar loss."""

    def trial(alpha):
        return loss_fn(tree_axpy_cast(alpha, delta, params))

    def cond(carry):
        alpha, f_new, k, ok = carry
        return jnp.logical_and(k < max_backtracks, jnp.logical_not(ok))

    if paired:
        def body(carry):
            alpha, _, k, _ = carry
            # Two speculative candidates per trip: f(α) and f(βα) have no
            # data dependence on each other, so their loss reductions issue
            # together — ONE blocking round-trip for two trials.
            alpha2 = alpha * beta
            f1 = trial(alpha)
            f2 = trial(alpha2)
            ok1 = f1 <= f0 + c * alpha * g_dot_delta
            ok2 = f2 <= f0 + c * alpha2 * g_dot_delta
            ok = jnp.logical_or(ok1, ok2)
            alpha_sel = jnp.where(ok1, alpha, alpha2)
            f_sel = jnp.where(ok1, f1, f2)
            alpha_next = jnp.where(ok, alpha_sel, alpha * beta * beta)
            return (alpha_next, f_sel, k + 2, ok)
    else:
        def body(carry):
            alpha, _, k, _ = carry
            f_new = trial(alpha)
            ok = f_new <= f0 + c * alpha * g_dot_delta
            alpha_next = jnp.where(ok, alpha, alpha * beta)
            return (alpha_next, f_new, k + 1, ok)

    alpha, f_new, k, ok = jax.lax.while_loop(
        cond, body, (jnp.asarray(alpha0), f0, jnp.zeros((), jnp.int32), jnp.zeros((), bool))
    )
    # On failure take a zero step (alpha=0): θ unchanged, damping will increase.
    alpha = jnp.where(ok, alpha, 0.0)
    f_new = jnp.where(ok, f_new, f0)
    return LineSearchResult(alpha, f_new, k, ok)
