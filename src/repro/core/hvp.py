"""Curvature-vector operators: exact Hessian (R-op) and Gauss-Newton.

The paper's Algorithm 2 line 5 constructs the stochastic operator
``G_k(v) = (1/N) sum_i  H_[i] v`` on a mini-batch, reduced across workers.
Under pjit/GSPMD the reduction emerges from sharding the batch over the
("pod","data") mesh axes — the jvp-of-grad below contains the same mean over
examples the loss does, so XLA inserts exactly one all-reduce per HVP, which
is the paper's one-MPI-reduce-per-CG-iteration schedule.

Operators:
  * ``make_hvp``  — exact stochastic Hessian (possibly indefinite; feeds
    Bi-CG-STAB / Hessian-CG / Hybrid-CG).
  * ``make_gnvp`` — Gauss-Newton: J^T (∇²_z ℓ) J v (PSD for convex ℓ; feeds
    Martens' GN-CG and the Hybrid fallback).

Both cost ≈ 2x a gradient, matching the paper's claim (Pearlmutter trick).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

LossFn = Callable[[Any, Any], jax.Array]  # (params, batch) -> scalar


def make_hvp(loss_fn: LossFn, params, batch) -> Callable[[Any], Any]:
    """Exact Hessian-vector product operator v ↦ ∇²f(θ) v (forward-over-reverse)."""

    def grad_fn(p):
        return jax.grad(loss_fn)(p, batch)

    def hvp(v):
        # Krylov vectors are kept in f32 (recurrence stability) while params
        # may be bf16 — cast the tangent at the operator boundary.
        vc = jax.tree_util.tree_map(lambda t, p: t.astype(p.dtype), v, params)
        return jax.jvp(grad_fn, (params,), (vc,))[1]

    return hvp


def make_gnvp(
    model_out_fn: Callable[[Any, Any], jax.Array],
    out_loss_fn: Callable[[jax.Array, Any], jax.Array],
    params,
    batch,
) -> Callable[[Any], Any]:
    """Gauss-Newton-vector product v ↦ Jᵀ (∇²_z ℓ(z)) J v.

    ``model_out_fn(params, batch) -> z`` is the network output (e.g. logits),
    ``out_loss_fn(z, batch) -> scalar`` the (convex-in-z) loss. The GN matrix
    drops the second-derivative-of-network term, guaranteeing PSD curvature —
    this is exactly what Martens' HF uses and what the paper argues loses the
    negative-curvature information.
    """

    def f(p):
        return model_out_fn(p, batch)

    def gnvp(v):
        v = jax.tree_util.tree_map(lambda t, p: t.astype(p.dtype), v, params)
        z, jv = jax.jvp(f, (params,), (v,))  # J v  (forward)
        # H_out @ jv  via jvp of the output-space gradient (z is fixed point).
        g_out = lambda zz: jax.grad(out_loss_fn)(zz, batch)
        hjv = jax.jvp(g_out, (z,), (jv,))[1]
        # Jᵀ (H_out J v)  (reverse)
        _, vjp_fn = jax.vjp(f, params)
        return vjp_fn(hjv)[0]

    return gnvp


def make_damped(op: Callable[[Any], Any], lam: jax.Array) -> Callable[[Any], Any]:
    """B(v) = G(v) + λ v  (Algorithm 1 line 4)."""

    def damped(v):
        gv = op(v)
        return jax.tree_util.tree_map(lambda g, x: g + lam * x, gv, v)

    return damped


def fd_hvp(loss_fn: LossFn, params, batch, v, eps: float = 1e-4):
    """Finite-difference HVP oracle (tests only): (∇f(θ+εv) − ∇f(θ−εv)) / 2ε."""
    gp = jax.grad(loss_fn)(
        jax.tree_util.tree_map(lambda p, t: p + eps * t, params, v), batch
    )
    gm = jax.grad(loss_fn)(
        jax.tree_util.tree_map(lambda p, t: p - eps * t, params, v), batch
    )
    return jax.tree_util.tree_map(lambda a, b: (a - b) / (2 * eps), gp, gm)
