"""Curvature-vector operators — thin compatibility wrappers over the
curvature engine (``core.curvature``).

The paper's Algorithm 2 line 5 constructs the stochastic operator
``G_k(v) = (1/N) sum_i  H_[i] v`` on a mini-batch, reduced across workers.
Under pjit/GSPMD the reduction emerges from sharding the batch over the
("pod","data") mesh axes — the operators below contain the same mean over
examples the loss does, so XLA inserts exactly one all-reduce per product,
which is the paper's one-MPI-reduce-per-CG-iteration schedule.

Operators:
  * ``make_hvp``  — exact stochastic Hessian (possibly indefinite; feeds
    Bi-CG-STAB / Hessian-CG / Hybrid-CG).
  * ``make_gnvp`` — Gauss-Newton: J^T (∇²_z ℓ) J v (PSD for convex ℓ; feeds
    Martens' GN-CG and the Hybrid fallback).

Both default to the engine's ``"linearize"`` mode: the primal
forward/backward pass runs once at operator construction and every
application executes only the cached linear map (~2 network passes per
product instead of ~4 — see core/curvature.py and EXPERIMENTS.md §Perf
pair D). Pass ``mode="naive"`` for the historical rebuild-every-call
closures, or ``mode="chunked"`` + ``chunk_size`` for flat-memory
accumulation over microbatches (large curvature batches, paper Fig. 4).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from .curvature import LossFn, make_damped, make_gnvp_op, make_hvp_op

__all__ = ["make_hvp", "make_gnvp", "make_damped", "fd_hvp"]


def make_hvp(
    loss_fn: LossFn,
    params,
    batch,
    *,
    mode: str = "linearize",
    chunk_size: int = 0,
    remat: bool = True,
    grad_reduce: Optional[Callable[[Any], Any]] = None,
) -> Callable[[Any], Any]:
    """Exact Hessian-vector product operator v ↦ ∇²f(θ) v."""
    return make_hvp_op(
        loss_fn, params, batch,
        mode=mode, chunk_size=chunk_size, remat=remat, grad_reduce=grad_reduce,
    )


def make_gnvp(
    model_out_fn: Callable[[Any, Any], jax.Array],
    out_loss_fn: Callable[[jax.Array, Any], jax.Array],
    params,
    batch,
    *,
    mode: str = "linearize",
    chunk_size: int = 0,
    remat: bool = True,
    grad_reduce: Optional[Callable[[Any], Any]] = None,
) -> Callable[[Any], Any]:
    """Gauss-Newton-vector product v ↦ Jᵀ (∇²_z ℓ(z)) J v.

    ``model_out_fn(params, batch) -> z`` is the network output (e.g. logits),
    ``out_loss_fn(z, batch) -> scalar`` the (convex-in-z) loss. The GN matrix
    drops the second-derivative-of-network term, guaranteeing PSD curvature —
    this is exactly what Martens' HF uses and what the paper argues loses the
    negative-curvature information.
    """
    return make_gnvp_op(
        model_out_fn, out_loss_fn, params, batch,
        mode=mode, chunk_size=chunk_size, remat=remat, grad_reduce=grad_reduce,
    )


def fd_hvp(loss_fn: LossFn, params, batch, v, eps: float = 1e-4):
    """Finite-difference HVP oracle (tests only): (∇f(θ+εv) − ∇f(θ−εv)) / 2ε."""
    gp = jax.grad(loss_fn)(
        jax.tree_util.tree_map(lambda p, t: p + eps * t, params, v), batch
    )
    gm = jax.grad(loss_fn)(
        jax.tree_util.tree_map(lambda p, t: p - eps * t, params, v), batch
    )
    return jax.tree_util.tree_map(lambda a, b: (a - b) / (2 * eps), gp, gm)
