"""Curvature-operator engine: linearize-once products + chunked accumulation.

The paper's per-Krylov-iteration cost model (Alg. 2 line 5: one stochastic
curvature product + one all-reduce per iteration) only holds if the product
is *cheap*. Two levers, both implemented here:

**Linearize-once** (``mode="linearize"``, the default). The naive operator
re-runs the primal forward+backward pass on every application —
``jax.jvp(grad_fn, (params,), (v,))`` computes ``grad_fn(params)`` *and* its
tangent each call, ~4 network passes per HVP. ``jax.linearize`` performs the
primal pass once per outer HF step, caches its residuals, and returns the
linear map alone: each of the ``max_cg_iters`` Krylov iterations then runs
only the tangent (~2 passes — half the FLOPs; measured 1.5–2.4× per product,
see EXPERIMENTS.md §Perf pair D). For the Gauss-Newton product the same
once-only pairing is ``jax.linearize`` on the network (J·v), its
``jax.linear_transpose`` (Jᵀ·u — reuses the *same* residuals, no second
forward pass), and a linearize of the output-space gradient (∇²_z ℓ · u).

  Note on whole-step jit: inside a single ``lax.while_loop`` body XLA's
  loop-invariant code motion can hoist the naive operator's primal out of
  the loop, recovering much of the win implicitly. The linearized form makes
  the schedule *explicit* — it survives per-call dispatch (the paper's
  MPI-root schedule, jit at the operator boundary), operators under
  ``lax.cond`` (the hybrid solver — branches are never hoisted), eager/debug
  use (no per-call retracing), and it shrinks the traced graph (faster
  compiles). Benchmarks: ``benchmarks/curvature_bench.py``.

**Chunked accumulation** (``mode="chunked"``, ``chunk_size`` knob). The
paper's Fig. 4 argues for order-of-magnitude *larger* curvature batches; the
memory wall is the linearization residuals, which scale with the curvature
batch. The chunked path rewrites the mini-batch loss as an exact
``lax.scan`` over microbatches of ``chunk_size`` examples (weighted so a
non-divisor remainder chunk is handled exactly), linearizes *that*, and —
with ``jax.checkpoint`` on the chunk body (``remat=True``) — keeps only
per-chunk boundaries resident: peak memory is flat in the curvature batch
size (the tangent re-materializes one chunk at a time inside the scan).
G·v is accumulated across chunks *inside* the operator, so ``grad_reduce``
is applied exactly once per accumulated product — Alg. 2's
one-reduce-per-Krylov-iteration schedule is preserved regardless of how
many chunks a worker sweeps.

Chunking assumes the loss/outputs decompose independently over the leading
batch axis with mean semantics (true for every model in this repo; the MoE
aux loss is per-chunk-mean approximated, same as any microbatching scheme).

Flash attention: exact-Hessian operators are forward-over-reverse
(jvp-of-grad), an order the Pallas flash kernels' first-order custom-AD
rules cannot be differentiated through. Every exact-Hessian build here is
therefore bracketed in ``kernels.flash_ad.second_order_tangents()``, under
which flash-attention models trace an AD-closed chunked-jnp attention (same
O(S·blk) memory, no (S, S) logits) — see kernels/flash_ad.py. The GN
product is first-order (linearize + linear_transpose) and runs the Pallas
JVP/backward kernels directly, no context needed — except under the s-step
block products, where hf_step brackets the GN *build* (vmap over the flash
linear map needs the AD-closed form); ``make_gnvp_op`` captures that
context state at build time and re-enters it around the lazy per-call
traces of its "naive"/"chunked" modes so the bracket holds for them too.

Sharding story:
  * **pjit/GSPMD** (implicit collectives, ``grad_reduce=None``): batch
    leaves sharded over ("pod","data"); the scan slices the *leading* axis,
    so each microbatch keeps the batch sharding and the partitioner inserts
    one all-reduce per accumulated product (the per-chunk partial products
    reduce locally — sharding propagation sees the scan carry as the only
    cross-chunk dependency).
  * **shard_map** (explicit collectives, ``grad_reduce=lax.pmean``): every
    worker scans its *local* batch shard; chunk products accumulate locally
    and the single ``grad_reduce`` at the end is the one collective —
    identical schedule to the unchunked path, so ``core.distributed`` works
    unchanged for every ``curvature_mode``.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..kernels.flash_ad import second_order_active, second_order_tangents
from ..obs import telemetry as _telemetry

LossFn = Callable[[Any, Any], jax.Array]      # (params, batch) -> scalar mean
OutFn = Callable[[Any, Any], Any]             # (params, batch) -> network output z
OutLossFn = Callable[[Any, Any], jax.Array]   # (z, batch) -> scalar mean
Op = Callable[[Any], Any]

MODES = ("naive", "linearize", "chunked")


def _cast_like(v, params):
    """Krylov vectors live in f32 (recurrence stability) while params may be
    bf16 — cast the tangent at the operator boundary."""
    return jax.tree_util.tree_map(lambda t, p: t.astype(p.dtype), v, params)


def _maybe_reduce(out, grad_reduce):
    return out if grad_reduce is None else grad_reduce(out)


def _batch_size(batch) -> int:
    sizes = {x.shape[0] for x in jax.tree_util.tree_leaves(batch)}
    if len(sizes) != 1:
        raise ValueError(f"batch leaves disagree on leading dim: {sorted(sizes)}")
    return sizes.pop()


def split_chunks(batch, chunk_size: int):
    """Split a batch along the leading axis into (main, rem, n_chunks, n_rem).

    ``main`` stacks the ⌊B/chunk⌋ full microbatches on a new leading axis
    (scan-ready); ``rem`` is the non-divisor remainder slice (None if B
    divides evenly). Static shapes throughout — two traces at most.
    """
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    B = _batch_size(batch)
    n_chunks, n_rem = divmod(B, chunk_size)
    main = None
    if n_chunks:
        main = jax.tree_util.tree_map(
            lambda x: x[: n_chunks * chunk_size].reshape(
                (n_chunks, chunk_size) + x.shape[1:]
            ),
            batch,
        )
    rem = None
    if n_rem:
        rem = jax.tree_util.tree_map(lambda x: x[B - n_rem:], batch)
    return main, rem, n_chunks, n_rem


def chunked_scalar_fn(fn: LossFn, batch, chunk_size: int, remat: bool = True
                      ) -> Callable[[Any], jax.Array]:
    """Rewrite a mean-over-batch scalar ``fn(params, batch)`` as an exact
    scan over microbatches: params ↦ (1/B) Σ_c n_c · fn(params, chunk_c).

    With ``remat`` the chunk body is ``jax.checkpoint``-ed, so a linearize
    (or grad) of the returned closure keeps only chunk boundaries resident
    and re-materializes one chunk at a time — peak memory flat in B.
    """
    B = _batch_size(batch)
    if chunk_size <= 0 or chunk_size >= B:
        return lambda p: fn(p, batch)
    main, rem, n_chunks, n_rem = split_chunks(batch, chunk_size)
    body = jax.checkpoint(fn) if remat else fn

    def chunked(p):
        def scan_body(acc, chunk):
            return acc + body(p, chunk).astype(jnp.float32), None

        total, _ = jax.lax.scan(scan_body, jnp.zeros((), jnp.float32), main)
        total = total * chunk_size
        if rem is not None:
            total = total + n_rem * body(p, rem).astype(jnp.float32)
        return total / B

    return chunked


def _check_mode(mode: str):
    if mode not in MODES:
        raise ValueError(f"curvature mode must be one of {MODES}, got {mode!r}")


# ---------------------------------------------------------------------------
# Hessian-vector product  v ↦ ∇²f(θ) v
# ---------------------------------------------------------------------------


def make_hvp_op(
    loss_fn: LossFn,
    params,
    batch,
    *,
    mode: str = "linearize",
    chunk_size: int = 0,
    remat: bool = True,
    grad_reduce: Optional[Callable[[Any], Any]] = None,
) -> Op:
    """Exact stochastic Hessian operator (Pearlmutter; forward-over-reverse).

    ``mode="naive"``     — per-call ``jvp`` of the gradient (primal re-run
                           every application; the pre-engine behavior).
    ``mode="linearize"`` — primal forward+backward once, cached linear map
                           per application.
    ``mode="chunked"``   — linearize-once over the scan-over-microbatches
                           loss; flat memory in the curvature batch size.
    """
    _check_mode(mode)
    if mode == "naive":
        def grad_fn(p):
            return jax.grad(loss_fn)(p, batch)

        def hvp(v):
            vc = _cast_like(v, params)
            # jvp-of-grad is forward-over-reverse: flash attention (if the
            # model uses it) must trace its AD-closed tangent rule here.
            with second_order_tangents():
                out = jax.jvp(grad_fn, (params,), (vc,))[1]
            return _maybe_reduce(out, grad_reduce)

        return hvp

    if mode == "chunked":
        scalar = chunked_scalar_fn(loss_fn, batch, chunk_size, remat=remat)
    else:
        scalar = lambda p: loss_fn(p, batch)
    # Forward-over-reverse: the cached linear map is the jvp of the whole
    # grad trace (forward + transposed tangent). Flash-attention models must
    # trace their AD-closed second-order tangent rule here — the Pallas
    # first-order rules cannot be forward-differentiated (kernels/flash_ad).
    with second_order_tangents():
        prim, lin = jax.linearize(jax.grad(scalar), params)
    # Telemetry phase end-marker pinned to the primal pass outputs (no-op
    # unless a sink is installed at trace time) — closes curvature_primal.
    _telemetry.marker("curvature_primal", prim)

    def hvp(v):
        return _maybe_reduce(lin(_cast_like(v, params)), grad_reduce)

    return hvp


def shared_primal_hvp(
    loss_fn: LossFn,
    params,
    batch,
    *,
    grad_reduce: Optional[Callable[[Any], Any]] = None,
):
    """One primal pass for the whole outer step: (f0, g, hvp_op).

    When the curvature mini-batch IS the gradient batch (``hvp_batch ==
    batch``, i.e. ``hvp_batch_frac >= 1``), ``hf_step`` historically paid two
    primal forward+backward sweeps over the same batch: ``value_and_grad``
    for (f0, g) and the engine's ``jax.linearize(jax.grad(...))`` for the
    cached Hessian map. Linearizing ``value_and_grad`` itself yields all
    three from a SINGLE forward+backward: the primal outputs are (f0, g) and
    the cached linear map's gradient tangent is exactly the Hessian product
    (∂g·v = H v). One fewer forward+backward per outer HF step.

    ``grad_reduce`` is applied to g once and to every H·v product (same
    schedule as ``make_hvp_op``); f0 needs no explicit reduce — under the
    shard_map wrapper the loss is already pmean'd in the forward pass.
    """
    # Forward-over-reverse (see make_hvp_op): flash-attention models trace
    # their AD-closed tangent rule; the shared-primal gradient consequently
    # uses the chunked-jnp attention backward rather than the Pallas one —
    # the price of fusing g with the Hessian map into one trace.
    with second_order_tangents():
        (f0, g), lin = jax.linearize(
            lambda p: jax.value_and_grad(loss_fn)(p, batch), params
        )
    # Fused grad+primal pass: one marker closes curvature_primal (there is
    # no separate grad_build phase on the shared path).
    _telemetry.marker("curvature_primal", f0, g)

    def hvp(v):
        return _maybe_reduce(lin(_cast_like(v, params))[1], grad_reduce)

    return f0, _maybe_reduce(g, grad_reduce), hvp


# ---------------------------------------------------------------------------
# Gauss-Newton-vector product  v ↦ Jᵀ (∇²_z ℓ) J v
# ---------------------------------------------------------------------------


def _gnvp_once(model_out_fn: OutFn, out_loss_fn: OutLossFn, params, batch) -> Op:
    """Linearize-once GN product on one batch: one primal forward pass total.

    ``jax.linearize`` on the network gives J·v *and* the residuals that
    ``jax.linear_transpose`` reuses for Jᵀ·u (no second forward, unlike
    ``jax.vjp``); the output-space Hessian ∇²_z ℓ is a linearize of the
    output-space gradient at the cached z (cheap — z-sized, not θ-sized).
    """
    z, jvp_lin = jax.linearize(lambda p: model_out_fn(p, batch), params)
    vjp_lin = jax.linear_transpose(jvp_lin, params)
    _, hout_lin = jax.linearize(
        lambda zz: jax.grad(out_loss_fn)(zz, batch), z
    )
    _telemetry.marker("curvature_primal", z)

    def gnvp(v):
        jv = jvp_lin(v)                       # J v          (tangent forward)
        hjv = hout_lin(jv)                    # ∇²_z ℓ · Jv  (output-space)
        hjv = jax.tree_util.tree_map(lambda h, zz: h.astype(zz.dtype), hjv, z)
        return vjp_lin(hjv)[0]                # Jᵀ · (…)     (tangent reverse)

    return gnvp


def _gnvp_direct(model_out_fn: OutFn, out_loss_fn: OutLossFn, params, vc, batch):
    """One GN product on one batch with the primal recomputed in-call (the
    naive per-call body and the chunked scan body — the same math, defined
    once)."""
    f = lambda p: model_out_fn(p, batch)
    z, jv = jax.jvp(f, (params,), (vc,))
    g_out = lambda zz: jax.grad(out_loss_fn)(zz, batch)
    hjv = jax.jvp(g_out, (z,), (jv,))[1]
    _, vjp_fn = jax.vjp(f, params)
    return vjp_fn(hjv)[0]


def make_gnvp_op(
    model_out_fn: OutFn,
    out_loss_fn: OutLossFn,
    params,
    batch,
    *,
    mode: str = "linearize",
    chunk_size: int = 0,
    remat: bool = True,
    grad_reduce: Optional[Callable[[Any], Any]] = None,
) -> Op:
    """Gauss-Newton operator (PSD for convex ℓ — Martens' HF and the hybrid
    fallback). Same mode semantics as ``make_hvp_op``; the chunked path
    accumulates per-microbatch GN products (J is block-diagonal over
    examples, so the per-chunk products sum exactly).

    ``remat`` is accepted for signature parity but only affects the HVP
    path: the chunked GN product recomputes each chunk's primal in-call
    already (the scan frees one chunk's intermediates before the next), so
    its memory is flat with or without checkpointing.

    The ``second_order_tangents()`` state is captured at BUILD time and
    re-entered around every lazy trace: the "naive" and "chunked" products
    re-trace the model per application, which would otherwise escape a
    context the caller held only around the builder (hf_step brackets the
    GN build when ``sstep_s > 1`` so the block products can vmap the flash
    path).
    """
    _check_mode(mode)
    ctx = (second_order_tangents if second_order_active()
           else contextlib.nullcontext)
    if mode == "naive":
        def gnvp(v):
            vc = _cast_like(v, params)
            with ctx():
                out = _gnvp_direct(model_out_fn, out_loss_fn, params, vc, batch)
            return _maybe_reduce(out, grad_reduce)

        return gnvp

    B = _batch_size(batch)
    if mode == "linearize" or chunk_size <= 0 or chunk_size >= B:
        inner = _gnvp_once(model_out_fn, out_loss_fn, params, batch)

        def gnvp(v):
            return _maybe_reduce(inner(_cast_like(v, params)), grad_reduce)

        return gnvp

    # chunked: scan over microbatches, accumulate n_c-weighted chunk products.
    main, rem, n_chunks, n_rem = split_chunks(batch, chunk_size)

    def gnvp(v):
        vc = _cast_like(v, params)
        acc0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def scan_body(acc, chunk):
            gv = _gnvp_direct(model_out_fn, out_loss_fn, params, vc, chunk)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + chunk_size * g.astype(jnp.float32), acc, gv
            )
            return acc, None

        with ctx():
            acc, _ = jax.lax.scan(scan_body, acc0, main)
            if rem is not None:
                gv = _gnvp_direct(model_out_fn, out_loss_fn, params, vc, rem)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + n_rem * g.astype(jnp.float32), acc, gv
                )
        out = jax.tree_util.tree_map(
            lambda a, p: (a / B).astype(p.dtype), acc, params
        )
        return _maybe_reduce(out, grad_reduce)

    return gnvp


def make_damped(op: Op, lam: jax.Array) -> Op:
    """B(v) = G(v) + λ v  (Algorithm 1 line 4)."""

    def damped(v):
        gv = op(v)
        return jax.tree_util.tree_map(lambda g, x: g + lam * x, gv, v)

    return damped
