"""shard_map replication rules for ``lax.while_loop`` and ``lax.cond``
(jax 0.4.x compat).

jax 0.4.37's ``jax.experimental.shard_map`` ships replication-check/rewrite
rules for ``scan`` and ``cond`` but not for ``while`` — so any shard_map
region with ``check_rep=True`` that contains a ``lax.while_loop`` (every
Krylov solve and the Armijo search in this repo) fails with
``NotImplementedError: No replication rule for while``. We keep check_rep
ON because it is what verifies, end to end, that the step's outputs really
are replicated as ``out_specs=P()`` promises — with it off, a missing
collective (e.g. forgetting the explicit ``grad_reduce`` completion pmean
that ``core.distributed`` threads into ``hf_step``) silently produces
per-worker-divergent "replicated" state instead of an error.

The shipped ``cond`` and ``scan`` CHECK rules are additionally stricter
than their REWRITE counterparts: the cond check demands the branches
produce *identical* replication types, and the scan check demands
carry-in == carry-out in a single pass — but jax's own rewrite rules (the
pass that actually runs under check_rep=True and inserts pbroadcasts)
merge with an intersection (``and_``) and fixpoint the scan carry, which
is the sound semantics: a value replicated over the axes common to every
branch (or every carry pass) is replicated over exactly that
intersection. The s-step solvers hit both strict forms — the Gram-guard
fallback's accept branch returns coordinate-recurrence state while the
fallback branch re-enters the standard solver (non-identical rep sets,
both replicated after the rewrite's pbroadcasts), and the
Newton/Chebyshev coefficient scans carry values whose replication
tightens on the first body pass. We re-register both check rules with the
same merge semantics the rewrites use (for cond, also folding in the
predicate's replication, which the strict rule ignored).

This module registers the missing rules, modeled 1:1 on the module's own
``_scan_check`` / ``_scan_rewrite``: fixpoint the carry replication through
the body jaxpr, pbroadcast inputs whose replication shrank, and rewrite
body+cond to match. Newer jax versions ship these rules natively, in which
case this is a no-op (``setdefault`` registration).

Imported for its side effect by ``core.distributed``.
"""
from __future__ import annotations

import operator as op

try:  # pragma: no cover - exercised indirectly via tests/test_distributed.py
    import jax.experimental.shard_map as _sm
    from jax._src.lax import control_flow as _cf
    from jax._src.util import split_list

    _while_p = _cf.loops.while_p

    def _and(a, b):
        # RepType None marks constants / unconstrained values.
        if a is None:
            return b
        if b is None:
            return a
        return a & b

    def _while_check(mesh, *in_rep, cond_jaxpr, body_jaxpr, cond_nconsts,
                     body_nconsts):
        cond_rep, body_rep, carry_rep_in = split_list(
            list(in_rep), [cond_nconsts, body_nconsts])
        carry_rep = list(carry_rep_in)
        for _ in range(1 + len(carry_rep)):
            out_rep = _sm._check_rep(
                mesh, body_jaxpr.jaxpr, [*body_rep, *carry_rep])
            out_rep = list(map(_and, carry_rep, out_rep))
            if out_rep == carry_rep:
                break
            carry_rep = out_rep
        else:
            raise Exception(
                "while_loop carry replication fixpoint not reached; as a "
                "workaround pass check_rep=False to shard_map")
        # cond must be checkable too (its scalar predicate drives every
        # device through the same trip count).
        _sm._check_rep(mesh, cond_jaxpr.jaxpr, [*cond_rep, *carry_rep])
        return carry_rep

    def _while_rewrite(mesh, in_rep, *args, cond_jaxpr, body_jaxpr,
                       cond_nconsts, body_nconsts):
        cond_rep, body_rep, carry_rep_in = split_list(
            list(in_rep), [cond_nconsts, body_nconsts])
        carry_rep = list(carry_rep_in)
        for _ in range(1 + len(carry_rep)):
            _, out_rep = _sm._replication_rewrite_nomatch(
                mesh, body_jaxpr, [*body_rep, *carry_rep])
            out_rep = list(map(_and, carry_rep, out_rep))
            if out_rep == carry_rep:
                break
            carry_rep = out_rep
        else:
            assert False, "while_loop carry replication fixpoint not reached"

        body_jaxpr_ = _sm._replication_rewrite_match(
            mesh, body_jaxpr, [*body_rep, *carry_rep], carry_rep)
        cond_jaxpr_, _ = _sm._replication_rewrite_nomatch(
            mesh, cond_jaxpr, [*cond_rep, *carry_rep])
        dst_rep = [*cond_rep, *body_rep, *carry_rep]
        args_ = [
            _sm.pbroadcast(x, tuple(n for n in src if n not in dst))
            if src - dst else x
            for x, src, dst in zip(args, in_rep, dst_rep)
        ]
        out_vals = _while_p.bind(
            *args_, cond_jaxpr=cond_jaxpr_, body_jaxpr=body_jaxpr_,
            cond_nconsts=cond_nconsts, body_nconsts=body_nconsts)
        return out_vals, carry_rep

    _scan_p = _cf.loops.scan_p

    def _scan_check(mesh, *in_rep, jaxpr, num_consts, num_carry, **_):
        # The shipped scan CHECK rule demands carry-in == carry-out
        # replication in a single pass, while the scan REWRITE rule
        # fixpoints the carry with an `and_` merge. Mirror the rewrite:
        # shrink the carry replication until stable, then report the
        # fixpoint (the s-step Newton/Chebyshev coefficient scans hit
        # this — their carries tighten from unconstrained to data-axis
        # replication on the first body pass).
        const_rep, carry_rep_in, xs_rep = split_list(
            list(in_rep), [num_consts, num_carry])
        carry_rep = list(carry_rep_in)
        ys_rep = []
        for _ in range(1 + num_carry):
            out_rep = _sm._check_rep(
                mesh, jaxpr.jaxpr, [*const_rep, *carry_rep, *xs_rep])
            carry_out, ys_rep = split_list(list(out_rep), [num_carry])
            carry_out = list(map(_and, carry_rep, carry_out))
            if carry_out == carry_rep:
                break
            carry_rep = carry_out
        else:
            raise Exception(
                "scan carry replication fixpoint not reached; as a "
                "workaround pass check_rep=False to shard_map")
        return [*carry_rep, *ys_rep]

    _cond_p = _cf.conditionals.cond_p

    def _cond_check(mesh, *in_rep, branches):
        pred_rep, *args_rep = in_rep
        out_rep = None
        for branch in branches:
            rep = _sm._check_rep(mesh, branch.jaxpr, args_rep)
            out_rep = (list(rep) if out_rep is None
                       else list(map(_and, out_rep, rep)))
        # Outputs can only be as replicated as the predicate that selected
        # the branch (mirrors _cond_rewrite's `and_` with pred_rep).
        return [_and(pred_rep, r) for r in out_rep]

    # register_check is setdefault — fine for while (no native rule to
    # displace), but the cond and scan rules must REPLACE the shipped
    # strict-equality ones with the rewrite-consistent intersection merge,
    # so they go into the rule table directly.
    _sm.register_check(_while_p)(_while_check)
    _sm.register_rewrite(_while_p)(_while_rewrite)
    _sm._check_rules[_cond_p] = _cond_check
    _sm._check_rules[_scan_p] = _scan_check
except (ImportError, AttributeError):  # newer jax moved/obsoleted these
    pass
