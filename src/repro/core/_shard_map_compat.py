"""shard_map replication rules for ``lax.while_loop`` (jax 0.4.x compat).

jax 0.4.37's ``jax.experimental.shard_map`` ships replication-check/rewrite
rules for ``scan`` and ``cond`` but not for ``while`` — so any shard_map
region with ``check_rep=True`` that contains a ``lax.while_loop`` (every
Krylov solve and the Armijo search in this repo) fails with
``NotImplementedError: No replication rule for while``. We keep check_rep
ON because it is what verifies, end to end, that the step's outputs really
are replicated as ``out_specs=P()`` promises — with it off, a missing
collective (e.g. forgetting the explicit ``grad_reduce`` completion pmean
that ``core.distributed`` threads into ``hf_step``) silently produces
per-worker-divergent "replicated" state instead of an error.

This module registers the missing rules, modeled 1:1 on the module's own
``_scan_check`` / ``_scan_rewrite``: fixpoint the carry replication through
the body jaxpr, pbroadcast inputs whose replication shrank, and rewrite
body+cond to match. Newer jax versions ship these rules natively, in which
case this is a no-op (``setdefault`` registration).

Imported for its side effect by ``core.distributed``.
"""
from __future__ import annotations

import operator as op

try:  # pragma: no cover - exercised indirectly via tests/test_distributed.py
    import jax.experimental.shard_map as _sm
    from jax._src.lax import control_flow as _cf
    from jax._src.util import split_list

    _while_p = _cf.loops.while_p

    def _and(a, b):
        # RepType None marks constants / unconstrained values.
        if a is None:
            return b
        if b is None:
            return a
        return a & b

    def _while_check(mesh, *in_rep, cond_jaxpr, body_jaxpr, cond_nconsts,
                     body_nconsts):
        cond_rep, body_rep, carry_rep_in = split_list(
            list(in_rep), [cond_nconsts, body_nconsts])
        carry_rep = list(carry_rep_in)
        for _ in range(1 + len(carry_rep)):
            out_rep = _sm._check_rep(
                mesh, body_jaxpr.jaxpr, [*body_rep, *carry_rep])
            out_rep = list(map(_and, carry_rep, out_rep))
            if out_rep == carry_rep:
                break
            carry_rep = out_rep
        else:
            raise Exception(
                "while_loop carry replication fixpoint not reached; as a "
                "workaround pass check_rep=False to shard_map")
        # cond must be checkable too (its scalar predicate drives every
        # device through the same trip count).
        _sm._check_rep(mesh, cond_jaxpr.jaxpr, [*cond_rep, *carry_rep])
        return carry_rep

    def _while_rewrite(mesh, in_rep, *args, cond_jaxpr, body_jaxpr,
                       cond_nconsts, body_nconsts):
        cond_rep, body_rep, carry_rep_in = split_list(
            list(in_rep), [cond_nconsts, body_nconsts])
        carry_rep = list(carry_rep_in)
        for _ in range(1 + len(carry_rep)):
            _, out_rep = _sm._replication_rewrite_nomatch(
                mesh, body_jaxpr, [*body_rep, *carry_rep])
            out_rep = list(map(_and, carry_rep, out_rep))
            if out_rep == carry_rep:
                break
            carry_rep = out_rep
        else:
            assert False, "while_loop carry replication fixpoint not reached"

        body_jaxpr_ = _sm._replication_rewrite_match(
            mesh, body_jaxpr, [*body_rep, *carry_rep], carry_rep)
        cond_jaxpr_, _ = _sm._replication_rewrite_nomatch(
            mesh, cond_jaxpr, [*cond_rep, *carry_rep])
        dst_rep = [*cond_rep, *body_rep, *carry_rep]
        args_ = [
            _sm.pbroadcast(x, tuple(n for n in src if n not in dst))
            if src - dst else x
            for x, src, dst in zip(args, in_rep, dst_rep)
        ]
        out_vals = _while_p.bind(
            *args_, cond_jaxpr=cond_jaxpr_, body_jaxpr=body_jaxpr_,
            cond_nconsts=cond_nconsts, body_nconsts=body_nconsts)
        return out_vals, carry_rep

    # setdefault semantics: a no-op on jax versions that grew native rules.
    _sm.register_check(_while_p)(_while_check)
    _sm.register_rewrite(_while_p)(_while_rewrite)
except (ImportError, AttributeError):  # newer jax moved/obsoleted these
    pass
