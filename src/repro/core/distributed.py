"""Explicit data-parallel HF step via shard_map — the paper's Algorithm 2
with its MPI schedule written out.

Under pjit/GSPMD the collectives are implicit (sharding propagation inserts
them); this module is the *explicit* form: each worker holds a batch shard,
the loss is ``pmean``-ed over the data axes, and therefore

  * ``jax.grad``   of the pmean'd loss  = local grad + ONE all-reduce
                                          (Alg. 2 line 4, "reduce to root"),
  * each HVP       (jvp of that grad)   = local HVP + ONE all-reduce per
                                          Krylov iteration (line 5),
  * each line-search trial              = ONE scalar all-reduce (line 9).

Every reduction goes through ``core.collectives.preduce`` (a tagged
``lax.pmean``), so the schedule is *auditable*: the static jaxpr walk
(``jaxpr_collective_counts``) and the executed-collective counter
(``count_executed``) both validate ``metrics["krylov_syncs"]`` /
``metrics["blocking_syncs"]`` against the program that actually ran —
see tests/test_collective_audit.py and benchmarks/fig5_scaling.py
--executed.

**Sync schedule per outer HF step** (K Krylov iterations, E line-search
evaluations; "blocking" = a round-trip whose result gates the next launch):

  schedule                      all-reduces             blocking syncs
  ----------------------------  ----------------------  ----------------------
  standard (sstep_s=1)          1 + K + E               1 + K + E
  s-step (s>1)                  1 + K + ceil(K/s) + E   1 + ceil(K/s) + E
  s-step + overlap              1 + K + ceil(K/2s) + E  ceil(K/2s) + ceil(E/2)

  * s-step keeps one matvec all-reduce per iteration (the K term) but those
    pipeline back-to-back inside a cycle's chain phase with no scalar gate;
    the Gram reduce (ceil(K/s)) is the only blocking sync of the solve.
  * overlap (HFConfig.overlap) double-buffers cycles — TWO cycles of
    coordinate recurrences per Gram reduce (ceil(K/2s)) — hides the
    gradient all-reduce behind the curvature operator's primal build
    (the leading 1 stops blocking), and pairs line-search trials so two
    loss reduces share one round-trip (ceil(E/2)). Same arithmetic, same
    accepted step; only the schedule changes.

Everything else (Krylov recurrences, damping, direction selection) operates
on replicated state, exactly like the paper's root-node logic — except no
root: every chip is the root. The resulting step is numerically identical to
the pjit path (tested) — use whichever fits the deployment; GSPMD can
overlap/schedule, shard_map makes the schedule auditable.

This very schedule runs multi-process — N real processes, gloo CPU
collectives or a TPU pod — through ``launch/multiproc.py`` +
``launch/train.py --num-processes N`` (mesh from
``launch.mesh.make_data_mesh``); tests/test_multiproc.py holds the
2-process parity and executed-sync-count checks.

Because the Krylov state is per-chip *replicated* here (pure data
parallelism), this is exactly the deployment where
``HFConfig(krylov_backend="flat")`` pays: the solve ravels the replicated
iterates into one flat buffer per chip and runs the recurrences through the
fused Pallas kernels with zero extra communication (the collectives all live
inside the loss/HVP operator applications). Under pjit with *sharded*
params, keep the default "tree" backend — the flat ravel would break
per-tensor shardings.

Every ``HFConfig.curvature_mode`` composes with this schedule unchanged:
the curvature engine receives ``grad_reduce=pmean`` and applies it once per
accumulated product, so in "chunked" mode each worker scans its *local*
batch shard chunk-by-chunk, accumulates locally, and still issues exactly
one all-reduce per Krylov iteration (see core/curvature.py, sharding story).

**s-step × backend interaction** (``HFConfig.sstep_s > 1`` — core/sstep.py):
the s-step solvers change WHAT synchronizes, and each backend realizes the
saving differently:

  * Under this shard_map schedule (replicated Krylov state), each basis
    matvec is still one ``pmean`` — but the basis phase is a pure matvec
    chain with NO scalar gates between products, so those collectives
    pipeline back-to-back instead of alternating with blocking
    dot-round-trips; the one *blocking* sync per s iterations is the Gram.
    Width-2 block products additionally halve the collective count of the
    chain phase: the vmapped ``grad_reduce`` pmean carries the stacked
    pair in ONE collective (core/blocks.py).
  * Under pjit/GSPMD with **sharded** params ("tree" backend — the right
    choice there), every standard-iteration dot is a per-shard reduction +
    a scalar all-reduce whose result gates the next step.
    ``TreeVectorBackend.gram`` keeps the sharding-preserving form (per-leaf
    ``dot_general`` contractions, no reshape — §Perf pair A) and turns s
    iterations' worth of those blocking scalar syncs into one small
    (basis × basis) matrix all-reduce per cycle.
  * With per-chip replicated state ("flat" backend, this module's regime),
    the Gram runs through the fused Pallas ``dots_block`` kernel: one pass
    over the stacked basis per cycle with zero extra communication.

The Gram-guard fallback re-enters the standard solver with the SAME
backend and ``grad_reduce``, so a breakdown never changes the collective
schedule's correctness — only its count (reported per step as
``metrics["krylov_syncs"]`` / ``metrics["sstep_fallback"]``).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import _shard_map_compat  # noqa: F401  (while/cond replication rules)
from .collectives import preduce
from .hf import HFConfig, hf_step


def data_parallel_hf_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    mesh,
    config: HFConfig,
    *,
    data_axes: Sequence[str] = ("data",),
    hvp_frac: float = 1.0,
    model_out_fn=None,
    out_loss_fn=None,
):
    """Returns step(params, state, batch) -> (params, state, metrics).

    ``batch`` leaves are sharded on their leading dim over ``data_axes``;
    params/state are replicated (pure data parallelism, the paper's setting:
    "we assume the size of the model is not huge").
    """
    axes = tuple(data_axes)

    def dloss(p, b):
        return preduce(loss_fn(p, b), axes, tag="loss")

    def dout_loss(z, b):
        return preduce(out_loss_fn(z, b), axes, tag="out_loss")

    def hvp_slice(b):
        if hvp_frac >= 1.0:
            return b
        return jax.tree_util.tree_map(
            lambda x: x[: max(int(x.shape[0] * hvp_frac), 1)], b
        )

    # NOTE: the gradient/HVP all-reduces are EXPLICIT (grad_reduce=pmean
    # below). Reverse-mode through the pmean'd loss leaves each worker with
    # its full *local* gradient contribution (no cross-worker reduction
    # appears in the transpose); pmean-ing the AD outputs — (1/N)Σ_w g_w,
    # matching the pmean'd loss — is Alg. 2's "reduce to root", one reduce
    # for g and one per Krylov iteration. Replication checking stays ON so
    # out_specs=P() is verified end-to-end (the while_loop replication rules
    # come from _shard_map_compat).
    def grad_reduce(t):
        return preduce(t, axes, tag="grad_hvp")

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(), P(axes)),
        out_specs=(P(), P(), P()),
    )
    def step(params, state, batch):
        return hf_step(
            dloss, params, state, batch, hvp_slice(batch), config,
            model_out_fn=model_out_fn,
            out_loss_fn=None if out_loss_fn is None else dout_loss,
            grad_reduce=grad_reduce,
        )

    return step
