"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across the inter-pod (DCI) links, so the
only cross-pod traffic is the gradient/HVP all-reduce (the paper's single
per-iteration MPI reduce); all param all-gathers (FSDP) and model-parallel
collectives stay on intra-pod ICI.

Defined as functions so importing this module never touches jax device
state (device count is locked at first jax init).

Multi-process note: every mesh here is built over the GLOBAL device list
(``jax.devices()``, what ``jax.make_mesh`` enumerates) — NOT
``jax.local_devices()``. Under ``jax.distributed`` each process sees only
its local slice of the hardware through ``local_devices()``, and a mesh
built from that would silently degenerate to per-process data parallelism
with no cross-process collectives. Every process must construct the SAME
global mesh (identical shape/axis order) for shard_map programs to agree;
``make_data_mesh`` is the 1-D form the multi-process harness
(launch/multiproc.py) uses.
"""
from __future__ import annotations

import jax


def make_data_mesh(axis: str = "data"):
    """1-D pure data-parallel mesh over ALL global devices.

    One axis, size = total device count across every participating process
    (1 per process under the CPU harness's XLA_FLAGS pinning). This is the
    mesh for ``core.distributed.data_parallel_hf_step`` runs launched via
    ``launch/multiproc.py``.
    """
    return jax.make_mesh((len(jax.devices()),), (axis,))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if len(jax.devices()) != _prod(shape):
        raise ValueError(
            f"production mesh {shape} needs {_prod(shape)} global devices, "
            f"found {len(jax.devices())} (jax.devices(); note "
            "jax.local_devices() is only this process's slice)"
        )
    return jax.make_mesh(shape, axes)


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= s
    return out


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def batch_axes_if_divisible(mesh, batch_size: int):
    """Largest prefix of (pod, data) whose product divides the batch."""
    axes = []
    prod = 1
    for a in data_axes(mesh):
        if batch_size % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes) if axes else None
