"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across the inter-pod (DCI) links, so the
only cross-pod traffic is the gradient/HVP all-reduce (the paper's single
per-iteration MPI reduce); all param all-gathers (FSDP) and model-parallel
collectives stay on intra-pod ICI.

Defined as functions so importing this module never touches jax device
state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def batch_axes_if_divisible(mesh, batch_size: int):
    """Largest prefix of (pod, data) whose product divides the batch."""
    axes = []
    prod = 1
    for a in data_axes(mesh):
        if batch_size % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes) if axes else None
