"""Multi-process launcher: the shard_map HF step on N real processes.

The shard_map schedule in ``core.distributed`` is process-count agnostic —
the same program runs on 8 fake CPU devices in one process (tests) or on a
TPU pod. What was missing is the harness that actually *spawns* processes
and wires ``jax.distributed`` between them, so the collectives cross a real
process boundary and the sync counts are measured, not simulated:

  PYTHONPATH=src python -m repro.launch.train --arch mlp-30-10 --smoke \\
      --num-processes 2 --sstep 2 --overlap

The parent re-executes its own command line N times with
``REPRO_MULTIPROC_*`` set; each child calls :func:`initialize_from_env`
BEFORE any jax device use, which points ``jax.distributed.initialize`` at a
local TCP coordinator and selects the gloo CPU collective backend. Each
child is pinned to ONE CPU device (``XLA_FLAGS`` below) so the global
device count equals the process count and ``launch.mesh.make_data_mesh``
builds an N-way pure data-parallel mesh.

On a TPU pod the same entry point applies: the pod runtime launches one
process per host itself, so skip :func:`spawn` and call
``jax.distributed.initialize()`` with no arguments (auto-detected
coordinator); everything downstream — mesh construction over global
devices, :func:`shard_batch` / :func:`replicate` placement, primary-only
logging — is identical.

Placement invariants (multi-process jit refuses to reshard across
processes, so inputs must arrive with their final global sharding):

  * batch leaves:   sharded on the leading dim over the data axis
                    (:func:`shard_batch` — every process builds the SAME
                    global batch from the same PRNG key and device_puts its
                    addressable shard),
  * params/state:   replicated (:func:`replicate`), bitwise identical
                    across processes by construction (same seed),
  * step outputs:   carry the out_specs shardings (all replicated here),
                    so ``float(metric)`` works on every process.
"""
from __future__ import annotations

import os
import signal
import socket
import subprocess
import sys
import tempfile
import time
from typing import Any, Callable, Optional, Sequence

ENV_NUM = "REPRO_MULTIPROC_NUM"
ENV_ID = "REPRO_MULTIPROC_ID"
ENV_COORD = "REPRO_MULTIPROC_COORD"
# Attempt counter set by the supervisor: 0 on the first launch, k after the
# k-th restart. launch/faults.py gates injected faults on it so a fault
# that killed attempt 0 does not re-fire and kill every restart too.
ENV_RESTART = "REPRO_MULTIPROC_RESTART"
# Directory where workers touch per-process heartbeat files; the
# supervisor reads mtimes to detect hangs (a worker wedged in a dead
# collective stops beating but never exits on its own).
ENV_HEARTBEAT_DIR = "REPRO_MULTIPROC_HEARTBEAT"

# Exit code of a worker whose collective watchdog fired. Kept equal to
# core.collectives.EXIT_WATCHDOG (asserted in tests/test_faults.py);
# duplicated here so the supervisor never has to import jax.
EXIT_WATCHDOG = 87

# One CPU device per process: global devices == processes, and the gloo
# cross-process collectives carry ALL communication (nothing hides on an
# intra-process fast path).
_CHILD_XLA_FLAGS = "--xla_force_host_platform_device_count=1"


def active() -> bool:
    """True in a child process spawned by :func:`spawn`."""
    return ENV_NUM in os.environ


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn(
    num_processes: int,
    module: str,
    args: Sequence[str] = (),
    *,
    env: dict | None = None,
) -> None:
    """Run ``python -m module *args`` as ``num_processes`` coordinated procs.

    Process 0 inherits stdout/stderr (it is the logging primary); the
    others are captured and replayed only on failure. Raises RuntimeError
    if any child exits non-zero.
    """
    coord = f"127.0.0.1:{_free_port()}"
    base = dict(os.environ if env is None else env)
    base["XLA_FLAGS"] = _CHILD_XLA_FLAGS
    procs = []
    for pid in range(num_processes):
        child_env = dict(base)
        child_env[ENV_NUM] = str(num_processes)
        child_env[ENV_ID] = str(pid)
        child_env[ENV_COORD] = coord
        capture = pid != 0
        procs.append(subprocess.Popen(
            [sys.executable, "-m", module, *args],
            env=child_env,
            stdout=subprocess.PIPE if capture else None,
            stderr=subprocess.STDOUT if capture else None,
            text=True,
        ))
    rcs = [p.wait() for p in procs]
    if any(rcs):
        for pid, p in enumerate(procs):
            if rcs[pid] and p.stdout is not None:
                tail = p.stdout.read().splitlines()[-30:]
                print(f"--- process {pid} (exit {rcs[pid]}) ---", file=sys.stderr)
                print("\n".join(tail), file=sys.stderr)
        raise RuntimeError(f"multiproc children failed: exit codes {rcs}")


def restart_attempt() -> int:
    """Which supervisor attempt this worker belongs to (0 = first launch)."""
    return int(os.environ.get(ENV_RESTART, "0"))


def heartbeat(step: Optional[int] = None) -> None:
    """Touch this worker's heartbeat file (no-op outside supervision).

    Called from the TRAIN LOOP itself, once per step (and once after
    compile), never from a side thread — a thread would keep beating while
    the main thread sits wedged in a dead collective, which is exactly the
    condition the heartbeat exists to expose.
    """
    d = os.environ.get(ENV_HEARTBEAT_DIR)
    if not d:
        return
    path = os.path.join(d, f"hb-p{os.environ.get(ENV_ID, '0')}")
    try:
        with open(path, "w") as f:
            f.write(f"{'' if step is None else int(step)} {time.time()}\n")
    except OSError:
        pass  # a torn-down heartbeat dir must never kill the worker


def _newest_heartbeat(directory: str) -> float:
    newest = 0.0
    try:
        for name in os.listdir(directory):
            if name.startswith("hb-p"):
                newest = max(newest,
                             os.path.getmtime(os.path.join(directory, name)))
    except OSError:
        pass
    return newest


def _terminate_all(procs, grace_s: float = 5.0) -> None:
    """SIGTERM every live child (lets telemetry signal handlers flush),
    wait up to ``grace_s``, then SIGKILL whatever is left."""
    for p in procs:
        if p.poll() is None:
            try:
                p.send_signal(signal.SIGTERM)
            except OSError:
                pass
    deadline = time.time() + grace_s
    for p in procs:
        if p.poll() is None:
            try:
                p.wait(timeout=max(0.1, deadline - time.time()))
            except subprocess.TimeoutExpired:
                pass
    for p in procs:
        if p.poll() is None:
            try:
                p.kill()
            except OSError:
                pass
            p.wait()


def _rc_desc(rc: int) -> str:
    if rc == EXIT_WATCHDOG:
        return f"exit {rc} (collective watchdog)"
    if rc < 0:
        try:
            return f"signal {signal.Signals(-rc).name}"
        except ValueError:
            return f"signal {-rc}"
    return f"exit {rc}"


def spawn_supervised(
    num_processes: int,
    module: str,
    args: Sequence[str] = (),
    *,
    max_restarts: int = 2,
    hang_timeout_s: Optional[float] = None,
    backoff_s: float = 1.0,
    poll_s: float = 0.2,
    heartbeat_dir: Optional[str] = None,
    env: dict | None = None,
    log: Callable[[str], None] = lambda m: print(m, file=sys.stderr),
) -> int:
    """:func:`spawn` under a liveness supervisor. Returns restarts used.

    Each attempt gets a fresh coordinator port (the old rendezvous is
    poisoned by the dead peer) and ``ENV_RESTART`` = attempt index. The
    supervisor polls child exits and, when ``hang_timeout_s`` is set,
    heartbeat-file mtimes; on a worker death, hang, or watchdog exit it
    tears the survivors down (SIGTERM → grace → SIGKILL: a gloo collective
    whose peer died never returns, so survivors cannot exit on their own),
    then re-launches everyone after exponential backoff — the *workers*
    resume from their last valid checkpoint (launch/train.py restore
    path); the supervisor only restarts processes, it holds no training
    state. A clean all-zero exit returns; exhausting ``max_restarts``
    raises RuntimeError with per-process exit codes and log tails.

    Hang staleness is measured from max(newest heartbeat, attempt launch
    time), so ``hang_timeout_s`` must cover worst-case first-step latency
    (gloo rendezvous + trace + compile), not just one step.
    """
    if heartbeat_dir is None:
        heartbeat_dir = tempfile.mkdtemp(prefix="repro-hb-")
    os.makedirs(heartbeat_dir, exist_ok=True)
    base = dict(os.environ if env is None else env)
    base["XLA_FLAGS"] = _CHILD_XLA_FLAGS
    base[ENV_HEARTBEAT_DIR] = heartbeat_dir

    last_failure = "never launched"
    for attempt in range(max_restarts + 1):
        coord = f"127.0.0.1:{_free_port()}"
        launched = time.time()
        procs, logs = [], []
        for pid in range(num_processes):
            child_env = dict(base)
            child_env[ENV_NUM] = str(num_processes)
            child_env[ENV_ID] = str(pid)
            child_env[ENV_COORD] = coord
            child_env[ENV_RESTART] = str(attempt)
            # Non-primaries append to files, not pipes: no drain thread
            # needed, nothing deadlocks on a full pipe buffer, and the
            # tail survives for the failure report.
            log_path = os.path.join(heartbeat_dir,
                                    f"log-p{pid}-a{attempt}.txt")
            logs.append(log_path)
            out = None if pid == 0 else open(log_path, "a")
            procs.append(subprocess.Popen(
                [sys.executable, "-m", module, *args],
                env=child_env, stdout=out,
                stderr=subprocess.STDOUT if out is not None else None,
            ))
            if out is not None:
                out.close()  # child holds its own fd

        failure = None
        while failure is None:
            time.sleep(poll_s)
            rcs = [p.poll() for p in procs]
            if all(rc == 0 for rc in rcs):
                return attempt
            dead = [(pid, rc) for pid, rc in enumerate(rcs)
                    if rc is not None and rc != 0]
            if dead:
                failure = ", ".join(f"process {pid}: {_rc_desc(rc)}"
                                    for pid, rc in dead)
            elif hang_timeout_s is not None:
                alive_since = max(_newest_heartbeat(heartbeat_dir), launched)
                if time.time() - alive_since > hang_timeout_s:
                    failure = (f"no heartbeat for {hang_timeout_s:.0f}s "
                               "(workers presumed hung)")

        log(f"[supervisor] attempt {attempt} failed: {failure}; "
            "tearing down survivors")
        _terminate_all(procs)
        last_failure = failure
        if attempt < max_restarts:
            delay = backoff_s * (2 ** attempt)
            log(f"[supervisor] restarting in {delay:.1f}s "
                f"(attempt {attempt + 1}/{max_restarts})")
            time.sleep(delay)

    for pid, log_path in enumerate(logs):
        if os.path.exists(log_path):
            with open(log_path) as f:
                tail = f.read().splitlines()[-30:]
            if tail:
                log(f"--- process {pid} (attempt {max_restarts}) ---")
                log("\n".join(tail))
    raise RuntimeError(
        f"multiproc supervision exhausted {max_restarts} restart(s); "
        f"last failure: {last_failure}")


def initialize_from_env() -> None:
    """Wire jax.distributed from the ``spawn`` env vars (no-op otherwise).

    Must run before anything touches jax devices — the CPU collective
    backend (gloo, the cross-process psum transport) is locked at backend
    init.
    """
    if not active():
        return
    import jax

    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(
        coordinator_address=os.environ[ENV_COORD],
        num_processes=int(os.environ[ENV_NUM]),
        process_id=int(os.environ[ENV_ID]),
    )


def is_primary() -> bool:
    import jax

    return jax.process_index() == 0


def shard_batch(batch: Any, mesh, axis: str = "data"):
    """Place a (replicated host) batch with leading-dim sharding over ``axis``.

    Every process passes the SAME global batch (same PRNG); each leaf lands
    as one global jax.Array of which this process holds its addressable
    shard. Works identically single-process.
    """
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    sharding = NamedSharding(mesh, P(axis))

    def put(x):
        return jax.device_put(np.asarray(x), sharding)

    return jax.tree_util.tree_map(put, batch)


def replicate(tree: Any, mesh):
    """Place a pytree fully-replicated over the whole mesh.

    Inputs must already be identical across processes (same-seed init);
    this just stamps the global replicated sharding so jit accepts them
    next to cross-process-sharded batches.
    """
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    sharding = NamedSharding(mesh, P())

    def put(x):
        return jax.device_put(np.asarray(x), sharding)

    return jax.tree_util.tree_map(put, tree)
