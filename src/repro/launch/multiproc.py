"""Multi-process launcher: the shard_map HF step on N real processes.

The shard_map schedule in ``core.distributed`` is process-count agnostic —
the same program runs on 8 fake CPU devices in one process (tests) or on a
TPU pod. What was missing is the harness that actually *spawns* processes
and wires ``jax.distributed`` between them, so the collectives cross a real
process boundary and the sync counts are measured, not simulated:

  PYTHONPATH=src python -m repro.launch.train --arch mlp-30-10 --smoke \\
      --num-processes 2 --sstep 2 --overlap

The parent re-executes its own command line N times with
``REPRO_MULTIPROC_*`` set; each child calls :func:`initialize_from_env`
BEFORE any jax device use, which points ``jax.distributed.initialize`` at a
local TCP coordinator and selects the gloo CPU collective backend. Each
child is pinned to ONE CPU device (``XLA_FLAGS`` below) so the global
device count equals the process count and ``launch.mesh.make_data_mesh``
builds an N-way pure data-parallel mesh.

On a TPU pod the same entry point applies: the pod runtime launches one
process per host itself, so skip :func:`spawn` and call
``jax.distributed.initialize()`` with no arguments (auto-detected
coordinator); everything downstream — mesh construction over global
devices, :func:`shard_batch` / :func:`replicate` placement, primary-only
logging — is identical.

Placement invariants (multi-process jit refuses to reshard across
processes, so inputs must arrive with their final global sharding):

  * batch leaves:   sharded on the leading dim over the data axis
                    (:func:`shard_batch` — every process builds the SAME
                    global batch from the same PRNG key and device_puts its
                    addressable shard),
  * params/state:   replicated (:func:`replicate`), bitwise identical
                    across processes by construction (same seed),
  * step outputs:   carry the out_specs shardings (all replicated here),
                    so ``float(metric)`` works on every process.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
from typing import Any, Sequence

ENV_NUM = "REPRO_MULTIPROC_NUM"
ENV_ID = "REPRO_MULTIPROC_ID"
ENV_COORD = "REPRO_MULTIPROC_COORD"

# One CPU device per process: global devices == processes, and the gloo
# cross-process collectives carry ALL communication (nothing hides on an
# intra-process fast path).
_CHILD_XLA_FLAGS = "--xla_force_host_platform_device_count=1"


def active() -> bool:
    """True in a child process spawned by :func:`spawn`."""
    return ENV_NUM in os.environ


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn(
    num_processes: int,
    module: str,
    args: Sequence[str] = (),
    *,
    env: dict | None = None,
) -> None:
    """Run ``python -m module *args`` as ``num_processes`` coordinated procs.

    Process 0 inherits stdout/stderr (it is the logging primary); the
    others are captured and replayed only on failure. Raises RuntimeError
    if any child exits non-zero.
    """
    coord = f"127.0.0.1:{_free_port()}"
    base = dict(os.environ if env is None else env)
    base["XLA_FLAGS"] = _CHILD_XLA_FLAGS
    procs = []
    for pid in range(num_processes):
        child_env = dict(base)
        child_env[ENV_NUM] = str(num_processes)
        child_env[ENV_ID] = str(pid)
        child_env[ENV_COORD] = coord
        capture = pid != 0
        procs.append(subprocess.Popen(
            [sys.executable, "-m", module, *args],
            env=child_env,
            stdout=subprocess.PIPE if capture else None,
            stderr=subprocess.STDOUT if capture else None,
            text=True,
        ))
    rcs = [p.wait() for p in procs]
    if any(rcs):
        for pid, p in enumerate(procs):
            if rcs[pid] and p.stdout is not None:
                tail = p.stdout.read().splitlines()[-30:]
                print(f"--- process {pid} (exit {rcs[pid]}) ---", file=sys.stderr)
                print("\n".join(tail), file=sys.stderr)
        raise RuntimeError(f"multiproc children failed: exit codes {rcs}")


def initialize_from_env() -> None:
    """Wire jax.distributed from the ``spawn`` env vars (no-op otherwise).

    Must run before anything touches jax devices — the CPU collective
    backend (gloo, the cross-process psum transport) is locked at backend
    init.
    """
    if not active():
        return
    import jax

    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(
        coordinator_address=os.environ[ENV_COORD],
        num_processes=int(os.environ[ENV_NUM]),
        process_id=int(os.environ[ENV_ID]),
    )


def is_primary() -> bool:
    import jax

    return jax.process_index() == 0


def shard_batch(batch: Any, mesh, axis: str = "data"):
    """Place a (replicated host) batch with leading-dim sharding over ``axis``.

    Every process passes the SAME global batch (same PRNG); each leaf lands
    as one global jax.Array of which this process holds its addressable
    shard. Works identically single-process.
    """
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    sharding = NamedSharding(mesh, P(axis))

    def put(x):
        return jax.device_put(np.asarray(x), sharding)

    return jax.tree_util.tree_map(put, batch)


def replicate(tree: Any, mesh):
    """Place a pytree fully-replicated over the whole mesh.

    Inputs must already be identical across processes (same-seed init);
    this just stamps the global replicated sharding so jit accepts them
    next to cross-process-sharded batches.
    """
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    sharding = NamedSharding(mesh, P())

    def put(x):
        return jax.device_put(np.asarray(x), sharding)

    return jax.tree_util.tree_map(put, tree)
