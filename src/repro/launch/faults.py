"""Deterministic fault injection for chaos-testing the training stack.

Faults are declared in the ``REPRO_FAULTS`` environment variable (flags on
``launch/train.py`` forward into it) as a ``;``-separated list of specs:

    kind@step=N[,proc=K][,secs=S][,attempt=A]

    kill          hard-kill the process (os._exit) at the top of step N —
                  models a preempted/OOM-killed worker; the survivors wedge
                  in the next collective and the supervisor restarts all.
    hang          stop making progress at the top of step N (sleep
                  ``secs``, default effectively forever) — models a wedged
                  worker; caught by heartbeat staleness or the collective
                  watchdog, never by an exit code.
    delay         sleep ``secs`` (default 1.0) at the top of step N, then
                  continue — models a straggler; must NOT trip a sanely
                  configured supervisor.
    corrupt_ckpt  after the step-N checkpoint save completes, overwrite
                  bytes in the middle of the newest checkpoint file —
                  models disk corruption / a torn write the atomic-rename
                  path cannot prevent (bit rot after the fsync); must be
                  caught by the CRC manifest at restore.
    nan_batch     poison the step-N training batch with NaN — models a
                  corrupted data shard; must surface as a rejected outer
                  step (core/hf.py divergence sentinel), not NaN params.
                  Only float leaves can carry NaN, so end-to-end this
                  needs an arch with float inputs (the vlm family's
                  vision features); integer token ids pass through.

``proc`` restricts the fault to one process index (default: every
process; kill/hang specs should set it). ``attempt`` gates on the
supervisor restart counter (``multiproc.ENV_RESTART``), default 0 — so a
kill that took down attempt 0 does not re-fire and take down every
restart, which is what makes recovery testable at all.

Everything is deterministic: same spec + same step sequence = same fault,
which is what lets ``benchmarks/chaos_check.py`` assert recovery *parity*
(the post-restart trajectory must equal the uninterrupted one) instead of
merely survival. Each fired fault is emitted as a telemetry ``fault``
event before it acts (line-buffered JSONL: the event survives the kill).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, List, Optional

ENV_FAULTS = "REPRO_FAULTS"

KINDS = ("kill", "hang", "delay", "corrupt_ckpt", "nan_batch")


@dataclasses.dataclass(frozen=True)
class Fault:
    kind: str
    step: int
    proc: Optional[int] = None   # None = every process
    secs: float = 1.0
    attempt: int = 0

    def spec(self) -> str:
        parts = [f"{self.kind}@step={self.step}"]
        if self.proc is not None:
            parts.append(f"proc={self.proc}")
        if self.secs != 1.0:
            parts.append(f"secs={self.secs:g}")
        if self.attempt != 0:
            parts.append(f"attempt={self.attempt}")
        return parts[0] + ("," + ",".join(parts[1:]) if parts[1:] else "")


def parse_faults(spec: str) -> List[Fault]:
    """Parse a ``REPRO_FAULTS`` string; raises ValueError on bad specs so a
    typo'd chaos run fails loudly instead of silently injecting nothing."""
    out = []
    for item in spec.split(";"):
        item = item.strip()
        if not item:
            continue
        if "@" not in item:
            raise ValueError(f"fault spec {item!r}: missing '@step=N'")
        kind, _, rest = item.partition("@")
        kind = kind.strip()
        if kind not in KINDS:
            raise ValueError(f"fault spec {item!r}: unknown kind {kind!r} "
                             f"(have {', '.join(KINDS)})")
        fields = {}
        for kv in rest.split(","):
            k, _, v = kv.partition("=")
            k = k.strip()
            if k not in ("step", "proc", "secs", "attempt"):
                raise ValueError(f"fault spec {item!r}: unknown field {k!r}")
            fields[k] = v.strip()
        if "step" not in fields:
            raise ValueError(f"fault spec {item!r}: missing step=")
        out.append(Fault(
            kind=kind,
            step=int(fields["step"]),
            proc=int(fields["proc"]) if "proc" in fields else None,
            secs=float(fields.get("secs", 1.0)),
            attempt=int(fields.get("attempt", 0)),
        ))
    return out


def corrupt_file(path: str, magic: bytes = b"\xde\xad\xbe\xef") -> None:
    """Overwrite bytes in the middle of ``path`` in place (no size change,
    no mtime-visible rename) — the kind of damage only a checksum finds."""
    size = os.path.getsize(path)
    blob = magic * 8
    with open(path, "r+b") as f:
        f.seek(max(0, size // 2 - len(blob) // 2))
        f.write(blob[:max(1, min(len(blob), size))])
        f.flush()
        os.fsync(f.fileno())


class FaultPlan:
    """The faults that apply to THIS process on THIS supervisor attempt.

    Hook placement (see launch/train.py): ``on_step_begin`` at the top of
    every outer step, ``poison_batch`` on the freshly built batch,
    ``corrupt_checkpoint`` right after a checkpoint save. All hooks are
    cheap no-ops when the plan is empty.
    """

    def __init__(self, faults: List[Fault], process_index: int = 0,
                 attempt: int = 0, telemetry: Any = None):
        self.process_index = int(process_index)
        self.attempt = int(attempt)
        self.telemetry = telemetry
        self.faults = [
            f for f in faults
            if (f.proc is None or f.proc == self.process_index)
            and f.attempt == self.attempt
        ]
        self._fired: set = set()

    @classmethod
    def from_env(cls, process_index: int = 0,
                 telemetry: Any = None) -> "FaultPlan":
        from . import multiproc
        spec = os.environ.get(ENV_FAULTS, "")
        return cls(parse_faults(spec), process_index,
                   multiproc.restart_attempt(), telemetry)

    def active(self) -> bool:
        return bool(self.faults)

    def _take(self, kind: str, step: int) -> Optional[Fault]:
        for f in self.faults:
            key = (f.kind, f.step, f.proc)
            if f.kind == kind and f.step == int(step) and key not in self._fired:
                self._fired.add(key)
                return f
        return None

    def _emit(self, fault: Fault, step: int, **extra) -> None:
        if self.telemetry is not None:
            self.telemetry.emit({
                "ev": "fault", "kind": fault.kind, "injected": True,
                "step": int(step), "proc": self.process_index,
                "attempt": self.attempt, "ts": time.time(), **extra})

    def on_step_begin(self, step: int) -> None:
        """Fire any kill/hang/delay scheduled for this step. ``kill`` uses
        ``os._exit`` (no atexit, no flush beyond the line-buffered
        telemetry write already issued) — a real preemption, not a polite
        shutdown."""
        f = self._take("delay", step)
        if f is not None:
            self._emit(f, step, secs=f.secs)
            time.sleep(f.secs)
        f = self._take("hang", step)
        if f is not None:
            secs = f.secs if f.secs > 1.0 else 3600.0
            self._emit(f, step, secs=secs)
            time.sleep(secs)
        f = self._take("kill", step)
        if f is not None:
            self._emit(f, step)
            os._exit(1)

    def poison_batch(self, step: int, batch: Any) -> Any:
        """Return the batch, NaN-poisoned if ``nan_batch`` fires here."""
        f = self._take("nan_batch", step)
        if f is None:
            return batch
        self._emit(f, step)
        import jax
        import jax.numpy as jnp
        return jax.tree_util.tree_map(
            lambda x: (x * jnp.nan if jnp.issubdtype(jnp.asarray(x).dtype,
                                                     jnp.floating)
                       else x), batch)

    def corrupt_checkpoint(self, step: int, directory: str) -> Optional[str]:
        """After the step-``step`` save: damage the newest checkpoint file.
        Returns the corrupted path (or None if no fault fires)."""
        f = self._take("corrupt_ckpt", step)
        if f is None:
            return None
        from ..checkpoint import latest_step
        newest = latest_step(directory)
        if newest is None:
            return None
        path = os.path.join(directory, f"ckpt_{newest:08d}.npz")
        corrupt_file(path)
        self._emit(f, step, path=path)
        return path
