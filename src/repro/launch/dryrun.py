import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes with ShapeDtypeStruct inputs (no allocation), then
record memory analysis, cost analysis and the collective schedule for the
roofline report.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init); this module is the only place it is set.
(No ``from __future__`` here for the same reason — nothing may precede the
env-var lines.)

Train shapes lower the paper's HF step (Alg. 2: grad all-reduce + Krylov
loop with per-iteration HVP all-reduce + Armijo loop) — the compiled HLO *is*
the paper's communication schedule. ``--solver sgd`` lowers the SGD baseline
instead (for the paper's collectives-per-epoch comparison). Decode shapes
lower ``serve_step`` (one token against a seq_len KV/state cache); prefill
shapes lower the full-sequence cache-building forward pass.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, INPUT_SHAPES, get_config
from ..core import HFConfig, HFState, hf_init, hf_step
from ..data.synthetic import batch_spec
from ..models import build_model
from ..roofline import (
    collective_bytes_from_hlo,
    cost_summary,
    model_flops,
    roofline_terms,
)
from .mesh import batch_axes_if_divisible, make_production_mesh
from .sharding import batch_specs, cache_specs, param_specs, to_shardings

from jax.sharding import NamedSharding, PartitionSpec as P

# long_500k needs sub-quadratic attention: dense/vlm archs run a
# sliding-window variant (window below); whisper is skipped (its decoder
# domain is capped at 448 positions — see DESIGN.md §6).
LONG_CONTEXT_WINDOW = 8192
LONG_SKIP = {"whisper-small": "enc-dec decoder capped at 448 target positions"}
# sLSTM recurs sequentially over time: a 524288-step lax.scan is lowerable
# but not a deployable prefill; xlstm long-context decode still exercises it
# (single step), which is the case that matters.


def input_specs(cfg, shape):
    """ShapeDtypeStruct stand-ins for every model input of this workload."""
    return batch_spec(cfg, shape.global_batch, shape.seq_len, shape.kind)


def adapt_config(arch_id: str, shape_name: str, ce_chunk: int = 0,
                 shard_hints: bool = False):
    cfg = get_config(arch_id)
    if shape_name == "long_500k" and cfg.family in ("dense", "vlm"):
        cfg = cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
    if ce_chunk:
        cfg = cfg.replace(ce_chunk=ce_chunk)
    if shard_hints:
        cfg = cfg.replace(shard_hints=True)
    return cfg


def make_mesh_from(spec: str):
    """"16x16" -> ("data","model") mesh; "2x16x16" -> ("pod","data","model")."""
    dims = tuple(int(x) for x in spec.split("x"))
    axes = ("pod", "data", "model")[-len(dims):]
    return jax.make_mesh(dims, axes)


def _hf_state_specs(pspecs):
    return HFState(lam=P(), prev_delta=pspecs, use_gn=P(), step=P())


def build_lowering(arch_id: str, shape_name: str, mesh, *, solver="bicgstab",
                   fsdp=True, remat=True, max_cg_iters=8, ce_chunk=0,
                   shard_cache_hd=False, shard_hints=False):
    cfg = adapt_config(arch_id, shape_name, ce_chunk, shard_hints)
    shape = INPUT_SHAPES[shape_name]
    model = build_model(cfg, remat=remat and shape.kind == "train")

    p_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(p_struct, cfg, mesh, fsdp=fsdp)
    psh = to_shardings(pspecs, mesh)

    if shape.kind == "train":
        b_struct = input_specs(cfg, shape)
        bsh = to_shardings(batch_specs(b_struct, mesh), mesh)
        if solver == "sgd":
            from ..optim import sgd

            opt = sgd(0.1)

            def step(p, b):
                return opt.step(model.loss_fn, p, (), b)[::2]

            fn = jax.jit(step, in_shardings=(psh, bsh), out_shardings=(psh, None))
            return fn, (p_struct, b_struct), cfg, shape

        hf_cfg = HFConfig(solver=solver, max_cg_iters=max_cg_iters, max_backtracks=6)
        s_struct = jax.eval_shape(lambda p: hf_init(p, hf_cfg), p_struct)
        ssh = to_shardings(_hf_state_specs(pspecs), mesh)

        def hvp_slice(b):
            return jax.tree_util.tree_map(lambda x: x[: max(x.shape[0] // 4, 1)], b)

        def step(p, s, b):
            return hf_step(model.loss_fn, p, s, b, hvp_slice(b), hf_cfg)

        fn = jax.jit(
            step, in_shardings=(psh, ssh, bsh), out_shardings=(psh, ssh, None),
            donate_argnums=(0, 1),
        )
        return fn, (p_struct, s_struct, b_struct), cfg, shape

    if shape.kind == "prefill":
        b_struct = input_specs(cfg, shape)
        bsh = to_shardings(batch_specs(b_struct, mesh), mesh)
        c_struct = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        csh = to_shardings(
            cache_specs(c_struct, cfg, mesh, shape.global_batch, shard_hd=shard_cache_hd),
            mesh,
        )

        def step(p, b):
            return model.prefill(p, b, shape.seq_len)

        fn = jax.jit(step, in_shardings=(psh, bsh), out_shardings=(None, csh))
        return fn, (p_struct, b_struct), cfg, shape

    # decode: one new token with a seq_len cache
    c_struct = jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))
    csh = to_shardings(
        cache_specs(c_struct, cfg, mesh, shape.global_batch, shard_hd=shard_cache_hd),
        mesh,
    )
    tok_struct = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_axes = batch_axes_if_divisible(mesh, shape.global_batch)
    tok_sh = NamedSharding(mesh, P(tok_axes) if tok_axes else P())
    t_struct = jax.ShapeDtypeStruct((), jnp.int32)

    def step(p, tok, t, cache):
        return model.decode_step(p, tok, t, cache)

    fn = jax.jit(
        step,
        in_shardings=(psh, tok_sh, NamedSharding(mesh, P()), csh),
        out_shardings=(None, csh),
        donate_argnums=(3,),
    )
    return fn, (p_struct, tok_struct, t_struct, c_struct), cfg, shape


def run_one(arch_id: str, shape_name: str, *, multi_pod: bool, solver="bicgstab",
            fsdp=True, remat=True, max_cg_iters=8, keep_hlo=False,
            mesh_spec=None, ce_chunk=0, shard_cache_hd=False,
            shard_hints=False) -> dict:
    rec = {
        "arch": arch_id, "shape": shape_name,
        "mesh": mesh_spec or ("2x16x16" if multi_pod else "16x16"),
        "solver": solver, "fsdp": fsdp, "remat": remat,
        "ce_chunk": ce_chunk, "shard_cache_hd": shard_cache_hd,
        "shard_hints": shard_hints,
    }
    if shape_name == "long_500k" and arch_id in LONG_SKIP:
        rec["status"] = "skipped"
        rec["reason"] = LONG_SKIP[arch_id]
        return rec
    t0 = time.time()
    try:
        mesh = (make_mesh_from(mesh_spec) if mesh_spec
                else make_production_mesh(multi_pod=multi_pod))
        n_chips = mesh.size
        fn, structs, cfg, shape = build_lowering(
            arch_id, shape_name, mesh, solver=solver, fsdp=fsdp, remat=remat,
            max_cg_iters=max_cg_iters, ce_chunk=ce_chunk,
            shard_cache_hd=shard_cache_hd, shard_hints=shard_hints,
        )
        with mesh:
            lowered = fn.lower(*structs)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes",
                )
                if hasattr(ma, k)
            }
            arg = rec["memory"].get("argument_size_in_bytes", 0)
            tmp = rec["memory"].get("temp_size_in_bytes", 0)
            rec["memory"]["per_device_total_gib"] = round((arg + tmp) / 2**30, 3)
        except Exception as e:  # CPU backend may not support it
            rec["memory"] = {"error": str(e)}
        cost = cost_summary(compiled.cost_analysis())
        hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
        rec["cost"] = cost
        rec["collectives"] = coll
        terms = roofline_terms(
            cost.get("flops", 0.0), cost.get("bytes_accessed", 0.0),
            coll["total"], n_chips,
        )
        rec["roofline"] = terms
        mf = model_flops(cfg, shape)
        rec["model_flops_global"] = mf
        hlo_flops_global = cost.get("flops", 0.0) * n_chips
        rec["useful_flops_ratio"] = (
            round(mf / hlo_flops_global, 4) if hlo_flops_global else None
        )
        rec["status"] = "ok"
        if keep_hlo:
            rec["hlo_path"] = _dump_hlo(rec, hlo)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def _dump_hlo(rec, hlo) -> str:
    os.makedirs("experiments/hlo", exist_ok=True)
    path = f"experiments/hlo/{rec['arch']}_{rec['shape']}_{rec['mesh']}_{rec['solver']}.hlo"
    with open(path, "w") as f:
        f.write(hlo)
    return path


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true", help="all arch x shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--solver", default="bicgstab",
                    choices=["bicgstab", "gn_cg", "hessian_cg", "hybrid_cg", "sgd"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--max-cg-iters", type=int, default=8)
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help='override mesh, e.g. "32x8" (data x model, 256 chips)')
    ap.add_argument("--ce-chunk", type=int, default=0,
                    help="chunked cross-entropy vocab chunk (0=off)")
    ap.add_argument("--shard-cache-hd", action="store_true",
                    help="shard decode-cache head_dim on model when kv-heads cannot shard")
    ap.add_argument("--shard-hints", action="store_true",
                    help="explicit sharding constraints on MoE dispatch intermediates")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.all or args.arch is None else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(
                    arch, shape, multi_pod=mp, solver=args.solver,
                    fsdp=not args.no_fsdp, remat=not args.no_remat,
                    max_cg_iters=args.max_cg_iters, keep_hlo=args.keep_hlo,
                    mesh_spec=args.mesh, ce_chunk=args.ce_chunk,
                    shard_cache_hd=args.shard_cache_hd,
                    shard_hints=args.shard_hints,
                )
                mesh_tag = args.mesh or ("2pod" if mp else "1pod")
                suffix = f"_{args.tag}" if args.tag else ""
                path = os.path.join(
                    args.out, f"{arch}_{shape}_{mesh_tag}_{args.solver}{suffix}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                status = rec["status"]
                extra = (
                    f"bottleneck={rec['roofline']['bottleneck']}"
                    if status == "ok" else rec.get("reason", rec.get("error", ""))[:120]
                )
                print(f"[{status:7s}] {arch:22s} {shape:12s} {mesh_tag} "
                      f"{rec.get('total_s', 0):7.1f}s  {extra}", flush=True)


if __name__ == "__main__":
    main()
