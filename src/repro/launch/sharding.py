"""Per-tensor sharding rules: param-tree paths -> PartitionSpec.

Megatron-style: attention heads / FFN / experts / vocab dims on the "model"
axis; batch on ("pod","data"). Optional FSDP shards the d_model dims of the
stacked block weights over "data" as well (ZeRO-3 style — GSPMD inserts the
per-layer all-gathers inside the scan). A dim is sharded only if the mesh
axis divides it AND the semantic unit (heads, kv-heads, experts) divides —
otherwise it falls back to replication, never to padding.

Krylov vectors / optimizer state inherit the exact param sharding, so every
tree_dot in the solvers lowers to per-shard partial reductions + one scalar
all-reduce (the paper's per-CG-iteration MPI allreduce).
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import batch_axes_if_divisible

# (path regex, logical dims for the TRAILING shape dims). Earlier rules win.
RULES = [
    (r"embed/table$", ("vocab", "d_model")),
    (r"lm_head/w$", ("d_model", "vocab")),
    (r"(wq)/w$", ("d_model", "heads_out")),
    (r"(wk|wv)/w$", ("d_model", "kv_heads_out")),
    (r"(wq)/b$", ("heads_out",)),
    (r"(wk|wv)/b$", ("kv_heads_out",)),
    (r"wo/w$", ("heads_out", "d_model")),
    (r"mlp/(wi|wg)/w$", ("d_model", "ff")),
    (r"mlp/wo/w$", ("ff", "d_model")),
    (r"router/w$", ("d_model", None)),
    (r"experts/(wi|wg)/w$", ("experts", "d_model", "ff")),
    (r"experts/wo/w$", ("experts", "ff", "d_model")),
    (r"in_proj/w$", ("d_model", "ssm_inner")),
    (r"out_proj/w$", ("ssm_inner", "d_model")),
    (r"vision_proj/w$", ("d_model", "heads_out")),
    (r"slstm/w$", ("d_model", None, None, "slstm_dh")),
    (r"slstm/r$", (None, None, None, "slstm_dh")),
    (r"(wi|wg)/w$", ("d_model", "ff")),        # bare mlp (enc-dec units)
    (r"wo?/w$", ("ff", "d_model")),
]

_MODEL_DIMS = (
    "vocab", "ff", "experts", "heads_out", "kv_heads_out", "ssm_inner", "slstm_dh"
)


def _semantic_ok(name: str, cfg, axis_size: int) -> bool:
    if name == "heads_out":
        return cfg.n_heads % axis_size == 0
    if name == "kv_heads_out":
        return cfg.n_kv_heads % axis_size == 0
    if name == "experts":
        return cfg.n_experts % axis_size == 0
    return True


def _build_spec(logical, shape, cfg, mesh, fsdp: bool) -> P:
    n_extra = len(shape) - len(logical)
    if n_extra < 0:  # tensor smaller than rule (e.g. bias matched by w-rule)
        return P()
    spec = [None] * n_extra
    used = set()
    for size, name in zip(shape[n_extra:], logical):
        ax = None
        if name in _MODEL_DIMS and "model" not in used:
            a_sz = mesh.shape["model"]
            if size % a_sz == 0 and _semantic_ok(name, cfg, a_sz):
                ax = "model"
        elif name == "d_model" and fsdp and "data" not in used:
            a_sz = mesh.shape["data"]
            if size % a_sz == 0:
                ax = "data"
        if ax:
            used.add(ax)
        spec.append(ax)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params_like, cfg, mesh, *, fsdp: bool = False):
    """PartitionSpec pytree for a param(-shaped) tree."""

    def spec_of(path, leaf):
        ps = _path_str(path)
        for pattern, logical in RULES:
            if re.search(pattern, ps):
                return _build_spec(logical, leaf.shape, cfg, mesh, fsdp)
        return P()

    return jax.tree_util.tree_map_with_path(spec_of, params_like)


def param_shardings(params_like, cfg, mesh, *, fsdp: bool = False):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params_like, cfg, mesh, fsdp=fsdp)
    )


def batch_specs(batch_like, mesh):
    """Shard every batch leaf's leading dim over ("pod","data") when divisible."""

    def spec_of(leaf):
        axes = batch_axes_if_divisible(mesh, leaf.shape[0])
        return P(axes) if axes else P()

    return jax.tree_util.tree_map(spec_of, batch_like)


def cache_specs(cache_like, cfg, mesh, batch_size: int, *, shard_hd: bool = False):
    """Decode caches: batch dim on ("pod","data"), kv-head/ssm-head dims on
    "model" when the semantic unit divides. Caches are stacked (layer-leading)
    pytrees; the batch dim is located by exact size match, integer leaves
    (slot-position buffers) stay replicated.

    ``shard_hd``: when the kv-head count does NOT divide the model axis
    (GQA with few kv heads), shard the trailing head_dim/channel dim instead —
    the QKᵀ contraction then runs as partial sums + a small logits all-reduce
    rather than all-gathering the cache (§Perf pair B)."""
    KV = cfg.n_kv_heads
    ssm_h = cfg.ssm_n_heads if cfg.ssm_state else -1
    m = mesh.shape["model"]

    def spec_of(path, leaf):
        del path
        shape = leaf.shape
        if jax.numpy.issubdtype(leaf.dtype, jax.numpy.integer):
            return P()
        spec = [None] * len(shape)
        b_dim = next((i for i, s in enumerate(shape) if s == batch_size), None)
        used_model = False
        for i, s in enumerate(shape):
            if i == b_dim:
                continue
            if not used_model and ((s == KV and KV % m == 0) or (s == ssm_h and ssm_h % m == 0)):
                spec[i] = "model"
                used_model = True
        if shard_hd and not used_model and len(shape) >= 3:
            last = len(shape) - 1
            if last != b_dim and shape[last] % m == 0:
                spec[last] = "model"
                used_model = True
        if b_dim is not None:
            spec[b_dim] = batch_axes_if_divisible(mesh, batch_size)
        while spec and spec[-1] is None:
            spec.pop()
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_of, cache_like)


def to_shardings(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
