"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --solver bicgstab --steps 20

Runs the distributed HF optimizer (or a first-order baseline) on synthetic
LM data, with checkpointing and metric logging. ``--smoke`` selects the
reduced config (CPU-runnable); without it the full config is used (TPU).

``--num-processes N`` (N > 1) re-launches this same command as N
coordinated processes (launch/multiproc.py) and runs the explicit
shard_map data-parallel HF step (core/distributed.py) over an N-way
"data" mesh — one CPU device per process locally, the pod runtime's
process set on TPU. ``--overlap`` turns on the overlapped-collective
schedule (HFConfig.overlap: double-buffered s-step cycles, hidden
gradient reduce, paired line search).
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from ..checkpoint import config_fingerprint, restore_latest_valid, save_checkpoint
from ..configs import ARCH_IDS, HFOptConfig, get_config, get_smoke_config
from ..core import collectives as collectives_mod
from ..data import lm_batch
from ..models import build_model
from ..obs import telemetry as telemetry_mod
from ..obs import trace as trace_mod
from ..optim import make_optimizer
from . import faults as faults_mod
from . import multiproc
from .mesh import make_data_mesh


def train(
    arch: str,
    *,
    smoke: bool = True,
    solver: str = "bicgstab",
    use_flash_attention: bool = False,
    steps: int = 20,
    batch_size: int = 8,
    seq_len: int = 64,
    lr: float = 0.1,
    hvp_batch_frac: float = 0.25,
    max_cg_iters: int = 8,
    precondition: bool = False,
    krylov_backend: str = "tree",
    curvature_mode: str = "linearize",
    curvature_chunk_size: int = 0,
    sstep: int = 1,
    sstep_solver: str = "auto",
    sstep_basis: str = "monomial",
    overlap: bool = False,
    nc_mode: str = "truncate",
    strict_descent: bool = False,
    distributed: bool = False,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    telemetry_dir: str | None = None,
    watchdog_s: float = 0.0,
    log_fn=print,
):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if use_flash_attention:
        cfg = cfg.replace(use_flash_attention=True)
    model = build_model(cfg)
    opt_cfg = HFOptConfig(
        name=solver, lr=lr, hvp_batch_frac=hvp_batch_frac,
        max_cg_iters=max_cg_iters, precondition=precondition,
        krylov_backend=krylov_backend,
        curvature_mode=curvature_mode,
        curvature_chunk_size=curvature_chunk_size,
        sstep_s=sstep, sstep_solver=sstep_solver, sstep_basis=sstep_basis,
        overlap=overlap, nc_mode=nc_mode, strict_descent=strict_descent,
    )
    mesh = None
    if distributed:
        # Every process builds the SAME global mesh (global device list)
        # and the same batch/params from the same PRNG; only the device_put
        # placement differs per process.
        mesh = make_data_mesh()
        n_shards = mesh.shape["data"]
        if batch_size % n_shards != 0:
            raise ValueError(
                f"batch_size {batch_size} not divisible by data-mesh size {n_shards}"
            )
        if not multiproc.is_primary():
            log_fn = lambda *a, **k: None  # noqa: E731  (primary-only logging)
    opt = make_optimizer(
        opt_cfg, model.loss_fn, model_out_fn=model.logits_fn,
        out_loss_fn=model.out_loss_fn, mesh=mesh,
    )

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    state = opt.init(params)
    # The manifest fingerprint covers everything that determines the step
    # program + batch stream; restore refuses checkpoints from any other
    # configuration instead of trusting the step number (satellite 1).
    nproc = jax.process_count()
    fingerprint = config_fingerprint(dict(
        arch=arch, smoke=smoke, opt=opt_cfg,
        batch_size=batch_size, seq_len=seq_len))
    start = 0
    if ckpt_dir:
        restored = restore_latest_valid(
            ckpt_dir, params, state,
            expect_fingerprint=fingerprint, expect_processes=nproc)
        if restored is not None:
            params, state, meta, ck_step = restored
            start = meta["step"]
            log_fn(f"restored checkpoint at step {start}")
    if mesh is not None:
        params = multiproc.replicate(params, mesh)
        state = multiproc.replicate(state, mesh)

    # Telemetry (repro.obs): per-process JSONL sink. The sink must be
    # installed while the step function is TRACED — the in-jit hooks are
    # trace-time, so a program compiled outside the install context never
    # fires a callback (zero-cost when --telemetry-dir is absent).
    sink = None
    if telemetry_dir:
        sink = telemetry_mod.Telemetry(
            telemetry_dir, process_index=jax.process_index(),
            meta=dict(kind="train", arch=arch, solver=solver, steps=steps,
                      batch_size=batch_size, seq_len=seq_len, sstep=sstep,
                      overlap=overlap, processes=jax.process_count(),
                      attempt=multiproc.restart_attempt()),
        )
        # SIGTERM (supervisor teardown) / SIGINT / normal exit all flush
        # the sink — a killed worker's partial event file stays parseable.
        telemetry_mod.register_crash_flush(sink)

    plan = faults_mod.FaultPlan.from_env(jax.process_index(), telemetry=sink)
    if plan.active():
        log_fn(f"fault plan armed: "
               f"{'; '.join(f.spec() for f in plan.faults)}")

    step_fn = jax.jit(opt.step)
    compiled = None
    history = []
    for i in range(start, steps):
        multiproc.heartbeat(i)
        plan.on_step_begin(i)
        batch = lm_batch(jax.random.fold_in(key, 1000 + i), cfg, batch_size, seq_len)
        batch = plan.poison_batch(i, batch)
        if mesh is not None:
            batch = multiproc.shard_batch(batch, mesh)
        if compiled is None:
            # AOT split: trace under the telemetry install context (hooks are
            # trace-time), then time XLA compilation separately so step 0's
            # wall_s measures the step, not the compile. The collective
            # watchdog is a trace-time install too; its monitor thread
            # outlives the context (daemon — dies with the process).
            install = (telemetry_mod.install(sink) if sink is not None
                       else contextlib.nullcontext())
            watchdog = (collectives_mod.collective_watchdog(watchdog_s)
                        if watchdog_s > 0 else contextlib.nullcontext())
            tc = time.time()
            with install, watchdog:
                lowered = step_fn.lower(params, state, batch)
            compiled = lowered.compile()
            compile_s = round(time.time() - tc, 3)
            multiproc.heartbeat(i)  # compile can dwarf hang_timeout_s steps
            if sink is not None:
                sink.emit({"ev": "span", "name": "compile", "t0": tc,
                           "t1": time.time(), "step": i})
        host_span = (sink.span("host_step", step=i) if sink is not None
                     else contextlib.nullcontext())
        with host_span:
            t0 = time.time()
            params, state, metrics = compiled(params, state, batch)
            # One sync point + one host transfer for the whole metrics dict
            # (the old per-key float() forced a device round-trip per entry).
            jax.block_until_ready((params, state, metrics))
            wall_s = round(time.time() - t0, 3)
            metrics = {k: float(v) for k, v in jax.device_get(metrics).items()}
        metrics["step"] = i
        metrics["wall_s"] = wall_s
        if i == start:
            metrics["compile_s"] = compile_s
        history.append(metrics)
        if sink is not None:
            sink.counter("loss", metrics["loss"])
        log_fn(
            f"step {i:4d} loss {metrics['loss']:.4f} |g| {metrics['grad_norm']:.3f}"
            + (f" λ {metrics['lambda']:.3g} α {metrics['alpha']:.2f} cg {metrics['cg_iters']:.0f}"
               if "lambda" in metrics else "")
        )
        if (ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0
                and (mesh is None or multiproc.is_primary())):
            save_checkpoint(ckpt_dir, i + 1, params, state,
                            fingerprint=fingerprint, processes=nproc)
            plan.corrupt_checkpoint(i + 1, ckpt_dir)
    if sink is not None:
        sink.close()
        if mesh is not None and jax.process_count() > 1:
            # Every process must have flushed its events file before the
            # primary merges; the barrier also keeps non-primaries alive
            # until the merge can read their output.
            from jax.experimental import multihost_utils
            multihost_utils.sync_global_devices("telemetry_flush")
        if mesh is None or multiproc.is_primary():
            out = trace_mod.merge_dir(telemetry_dir)
            log_fn(f"telemetry: merged trace at {out}")
    return params, state, history


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--solver", default="bicgstab",
                    choices=["sgd", "momentum", "adam", "gn_cg", "hessian_cg",
                             "hybrid_cg", "bicgstab"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--max-cg-iters", type=int, default=8)
    ap.add_argument("--flash-attention", action="store_true",
                    help="route attention through the differentiable Pallas "
                         "flash kernels (training + prefill; interpret mode "
                         "off-TPU — see EXPERIMENTS.md §Perf pair F)")
    ap.add_argument("--precondition", action="store_true",
                    help="Jacobi preconditioning (PCG / preconditioned Bi-CG-STAB)")
    ap.add_argument("--krylov-backend", default="tree", choices=["tree", "flat"],
                    help="Krylov vector backend: sharding-preserving pytrees "
                         "or flat buffers through the fused Pallas kernels")
    ap.add_argument("--curvature-mode", default="linearize",
                    choices=["naive", "linearize", "chunked"],
                    help="curvature engine: rebuild-per-call, linearize-once, "
                         "or chunked microbatch accumulation (flat memory)")
    ap.add_argument("--curvature-chunk-size", type=int, default=0,
                    help="chunked mode: examples per microbatch "
                         "(<=0 = whole curvature batch in one chunk)")
    ap.add_argument("--sstep", type=int, default=1,
                    help="s-step (communication-avoiding) Krylov solve: batch "
                         "the dots of S iterations into one Gram reduction "
                         "(<=1 = standard per-iteration recurrence)")
    ap.add_argument("--sstep-solver", default="auto",
                    choices=["auto", "cg", "bicgstab"],
                    help="s-step recurrence (auto derives it from --solver)")
    ap.add_argument("--sstep-basis", default="monomial",
                    choices=["monomial", "newton", "chebyshev"],
                    help="s-step chain polynomial: monomial power chains "
                         "(f32-safe to s~4 CG / s~2 Bi-CG-STAB) or the "
                         "Ritz-parameterized Newton/Chebyshev bases that "
                         "double usable s (free estimates from the cycle "
                         "Gram; falls back monomial -> standard on guard "
                         "failure)")
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped-collective schedule: double-buffered "
                         "s-step cycles (two cycles per Gram reduce), the "
                         "gradient all-reduce hidden behind the curvature "
                         "build, and paired speculative line-search trials "
                         "(reports metrics['blocking_syncs'])")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="spawn N coordinated processes (jax.distributed, "
                         "gloo CPU collectives, 1 device each) and run the "
                         "explicit shard_map data-parallel step over an "
                         "N-way data mesh; on a TPU pod the runtime spawns "
                         "processes itself — see launch/multiproc.py")
    ap.add_argument("--nc-mode", default="truncate",
                    choices=["truncate", "escape"],
                    help="negative-curvature policy: 'truncate' (passive "
                         "φ-best competition at the solution's norm scale) "
                         "or 'escape' (saddle-free |λ_min|-scaled escape "
                         "step along the NC direction — the λ estimate is "
                         "threaded through KrylovResult.nc_lambda, "
                         "Ritz-refined on the s-step paths)")
    ap.add_argument("--strict-descent", action="store_true",
                    help="divergence sentinel also rejects steps whose "
                         "accepted line-search loss INCREASES (non-finite "
                         "updates are always rejected); rejected steps "
                         "keep params, boost λ, and report "
                         "metrics['step_rejected']")
    ap.add_argument("--max-restarts", type=int, default=0,
                    help="supervise the multi-process run: on a worker "
                         "death/hang, tear down the survivors and relaunch "
                         "everyone (resuming from the last valid "
                         "checkpoint) up to N times with exponential "
                         "backoff; 0 = unsupervised spawn")
    ap.add_argument("--hang-timeout", type=float, default=0.0,
                    help="supervisor liveness: restart when no worker "
                         "heartbeat for this many seconds (must cover "
                         "rendezvous + compile + one step); 0 = exit-code "
                         "detection only")
    ap.add_argument("--watchdog-s", type=float, default=0.0,
                    help="per-worker collective watchdog: a collective "
                         "blocked longer than this (peer presumed dead) "
                         "hard-exits the worker with code "
                         f"{multiproc.EXIT_WATCHDOG} so the supervisor "
                         "restarts immediately instead of waiting out "
                         "--hang-timeout; 0 = off")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--history-out", default=None)
    ap.add_argument("--telemetry-dir", default=None,
                    help="write per-process telemetry (events-p{N}.jsonl: "
                         "phase spans, executed-collective begin/end times, "
                         "Krylov solve summaries) and, on the primary at "
                         "exit, the merged Chrome/Perfetto trace.json; "
                         "omit for zero-cost (no callbacks compiled in). "
                         "Inspect with python -m repro.obs.report DIR")
    args = ap.parse_args()

    if args.num_processes > 1 and not multiproc.active():
        if args.max_restarts > 0:
            restarts = multiproc.spawn_supervised(
                args.num_processes, "repro.launch.train", sys.argv[1:],
                max_restarts=args.max_restarts,
                hang_timeout_s=args.hang_timeout or None,
            )
            print(f"[supervisor] run completed after {restarts} restart(s)",
                  file=sys.stderr)
        else:
            multiproc.spawn(args.num_processes, "repro.launch.train",
                            sys.argv[1:])
        return
    multiproc.initialize_from_env()

    _, _, history = train(
        args.arch, smoke=args.smoke, solver=args.solver, steps=args.steps,
        use_flash_attention=args.flash_attention,
        batch_size=args.batch_size, seq_len=args.seq_len, lr=args.lr,
        max_cg_iters=args.max_cg_iters, precondition=args.precondition,
        krylov_backend=args.krylov_backend,
        curvature_mode=args.curvature_mode,
        curvature_chunk_size=args.curvature_chunk_size,
        sstep=args.sstep, sstep_solver=args.sstep_solver,
        sstep_basis=args.sstep_basis,
        overlap=args.overlap,
        nc_mode=args.nc_mode,
        strict_descent=args.strict_descent,
        distributed=multiproc.active(),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        telemetry_dir=args.telemetry_dir,
        watchdog_s=args.watchdog_s,
    )
    if args.history_out and (not multiproc.active() or multiproc.is_primary()):
        os.makedirs(os.path.dirname(args.history_out) or ".", exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
