"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --solver bicgstab --steps 20

Runs the distributed HF optimizer (or a first-order baseline) on synthetic
LM data, with checkpointing and metric logging. ``--smoke`` selects the
reduced config (CPU-runnable); without it the full config is used (TPU).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..configs import ARCH_IDS, HFOptConfig, get_config, get_smoke_config
from ..data import lm_batch
from ..models import build_model
from ..optim import make_optimizer


def train(
    arch: str,
    *,
    smoke: bool = True,
    solver: str = "bicgstab",
    use_flash_attention: bool = False,
    steps: int = 20,
    batch_size: int = 8,
    seq_len: int = 64,
    lr: float = 0.1,
    hvp_batch_frac: float = 0.25,
    max_cg_iters: int = 8,
    precondition: bool = False,
    krylov_backend: str = "tree",
    curvature_mode: str = "linearize",
    curvature_chunk_size: int = 0,
    sstep: int = 1,
    sstep_solver: str = "auto",
    sstep_basis: str = "monomial",
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    log_fn=print,
):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if use_flash_attention:
        cfg = cfg.replace(use_flash_attention=True)
    model = build_model(cfg)
    opt_cfg = HFOptConfig(
        name=solver, lr=lr, hvp_batch_frac=hvp_batch_frac,
        max_cg_iters=max_cg_iters, precondition=precondition,
        krylov_backend=krylov_backend,
        curvature_mode=curvature_mode,
        curvature_chunk_size=curvature_chunk_size,
        sstep_s=sstep, sstep_solver=sstep_solver, sstep_basis=sstep_basis,
    )
    opt = make_optimizer(
        opt_cfg, model.loss_fn, model_out_fn=model.logits_fn,
        out_loss_fn=model.out_loss_fn,
    )

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    state = opt.init(params)
    start = 0
    if ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            params, state, meta = restore_checkpoint(ckpt_dir, last, params, state)
            start = meta["step"]
            log_fn(f"restored checkpoint at step {start}")

    step_fn = jax.jit(opt.step)
    history = []
    for i in range(start, steps):
        batch = lm_batch(jax.random.fold_in(key, 1000 + i), cfg, batch_size, seq_len)
        t0 = time.time()
        params, state, metrics = step_fn(params, state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step"] = i
        metrics["wall_s"] = round(time.time() - t0, 3)
        history.append(metrics)
        log_fn(
            f"step {i:4d} loss {metrics['loss']:.4f} |g| {metrics['grad_norm']:.3f}"
            + (f" λ {metrics['lambda']:.3g} α {metrics['alpha']:.2f} cg {metrics['cg_iters']:.0f}"
               if "lambda" in metrics else "")
        )
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, i + 1, params, state)
    return params, state, history


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--solver", default="bicgstab",
                    choices=["sgd", "momentum", "adam", "gn_cg", "hessian_cg",
                             "hybrid_cg", "bicgstab"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--max-cg-iters", type=int, default=8)
    ap.add_argument("--flash-attention", action="store_true",
                    help="route attention through the differentiable Pallas "
                         "flash kernels (training + prefill; interpret mode "
                         "off-TPU — see EXPERIMENTS.md §Perf pair F)")
    ap.add_argument("--precondition", action="store_true",
                    help="Jacobi preconditioning (PCG / preconditioned Bi-CG-STAB)")
    ap.add_argument("--krylov-backend", default="tree", choices=["tree", "flat"],
                    help="Krylov vector backend: sharding-preserving pytrees "
                         "or flat buffers through the fused Pallas kernels")
    ap.add_argument("--curvature-mode", default="linearize",
                    choices=["naive", "linearize", "chunked"],
                    help="curvature engine: rebuild-per-call, linearize-once, "
                         "or chunked microbatch accumulation (flat memory)")
    ap.add_argument("--curvature-chunk-size", type=int, default=0,
                    help="chunked mode: examples per microbatch "
                         "(<=0 = whole curvature batch in one chunk)")
    ap.add_argument("--sstep", type=int, default=1,
                    help="s-step (communication-avoiding) Krylov solve: batch "
                         "the dots of S iterations into one Gram reduction "
                         "(<=1 = standard per-iteration recurrence)")
    ap.add_argument("--sstep-solver", default="auto",
                    choices=["auto", "cg", "bicgstab"],
                    help="s-step recurrence (auto derives it from --solver)")
    ap.add_argument("--sstep-basis", default="monomial",
                    choices=["monomial", "newton", "chebyshev"],
                    help="s-step chain polynomial: monomial power chains "
                         "(f32-safe to s~4 CG / s~2 Bi-CG-STAB) or the "
                         "Ritz-parameterized Newton/Chebyshev bases that "
                         "double usable s (free estimates from the cycle "
                         "Gram; falls back monomial -> standard on guard "
                         "failure)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    _, _, history = train(
        args.arch, smoke=args.smoke, solver=args.solver, steps=args.steps,
        use_flash_attention=args.flash_attention,
        batch_size=args.batch_size, seq_len=args.seq_len, lr=args.lr,
        max_cg_iters=args.max_cg_iters, precondition=args.precondition,
        krylov_backend=args.krylov_backend,
        curvature_mode=args.curvature_mode,
        curvature_chunk_size=args.curvature_chunk_size,
        sstep=args.sstep, sstep_solver=args.sstep_solver,
        sstep_basis=args.sstep_basis,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    if args.history_out:
        os.makedirs(os.path.dirname(args.history_out) or ".", exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(history, f, indent=1)


if __name__ == "__main__":
    main()
