"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch-size 4 --prompt-len 16 --gen-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..data import lm_batch
from ..models import build_model


def serve(arch: str, *, smoke=True, batch_size=4, prompt_len=16, gen_len=16,
          log_fn=print):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch_size, prompt_len + 1)
    prompt = dict(batch)
    prompt["tokens"] = batch["tokens"][:, :prompt_len]
    max_len = prompt_len + gen_len + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, prompt)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    offset = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    out_tokens = [tok]
    t0 = time.time()
    for i in range(gen_len - 1):
        t = jnp.asarray(prompt_len + offset + i, jnp.int32)
        logits, cache = decode(params, tok, t, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    log_fn(f"prefill {prompt_len} toks x{batch_size}: {t_prefill:.3f}s; "
           f"decode {gen_len} steps: {t_decode:.3f}s "
           f"({batch_size * (gen_len - 1) / max(t_decode, 1e-9):.1f} tok/s)")
    return gen


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    gen = serve(args.arch, smoke=args.smoke, batch_size=args.batch_size,
                prompt_len=args.prompt_len, gen_len=args.gen_len)
    print("generated token ids (first row):", gen[0].tolist())


if __name__ == "__main__":
    main()
