"""Serving drivers: batch-at-once greedy decode and continuous batching.

``serve`` prefills a batch of prompts together and greedy-decodes them in
lockstep (batch-at-once — every slot finishes before new work starts). The
decode jit donates the cache and token buffers (``donate_argnums``) so XLA
updates the KV cache in place instead of round-tripping it through HBM each
token, and generated tokens land in a preallocated (B, gen_len) host buffer.

``serve_continuous`` is the production pattern the tentpole builds: a
slot-based scheduler over the paged KV cache (models/kv_paged.py). Requests
arrive on a step clock (e.g. a Poisson trace), get admitted into freed
slots as capacity allows (``prefill_paged`` writes their pages directly),
decode advances every live slot in one fixed-shape jitted step (occupancy
mask, per-slot seq_len), and finished sequences retire via
``release_slots`` — so short requests never wait on long ones and HBM is
~live-tokens, not batch × max_len.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch-size 4 --prompt-len 16 --gen-len 16 [--continuous]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..data import lm_batch
from ..models import build_model
from ..models.kv_paged import pages_needed, release_slots


def serve(arch: str, *, smoke=True, batch_size=4, prompt_len=16, gen_len=16,
          telemetry=None, log_fn=print):
    """Batch-at-once greedy decode. Returns (tokens, stats) — stats carries
    the same timing the log line prints (prefill_s, decode_s, tok/s), and
    when a ``repro.obs.telemetry.Telemetry`` sink is passed the phases are
    ALSO emitted as telemetry spans (``log_fn`` keeps working either way —
    the sink is structured output, not a replacement for the log)."""
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = lm_batch(jax.random.PRNGKey(1), cfg, batch_size, prompt_len + 1)
    prompt = dict(batch)
    prompt["tokens"] = batch["tokens"][:, :prompt_len]
    max_len = prompt_len + gen_len + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    # donate the cache buffers: the cache updates in place instead of
    # allocating a fresh (B, W, KV, hd) per layer per token (the int32
    # token buffer has no same-shape output to alias, so it stays)
    decode = jax.jit(model.decode_step, donate_argnums=(3,))

    out = np.zeros((batch_size, gen_len), np.int32)
    t_start = time.time()
    logits, cache = prefill(params, prompt)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_mid = time.time()
    t_prefill = t_mid - t_start

    offset = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    out[:, 0] = np.asarray(tok[:, 0])
    for i in range(gen_len - 1):
        t = jnp.asarray(prompt_len + offset + i, jnp.int32)
        logits, cache = decode(params, tok, t, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out[:, i + 1] = np.asarray(tok[:, 0])
    jax.block_until_ready(tok)
    t_end = time.time()
    t_decode = t_end - t_mid
    n_tok = batch_size * gen_len            # every generated token counts
    stats = {"prefill_s": t_prefill, "decode_s": t_decode,
             "n_tok": n_tok,
             "tok_per_s": n_tok / max(t_prefill + t_decode, 1e-9),
             "tok_per_s_decode":
                 batch_size * (gen_len - 1) / max(t_decode, 1e-9)}
    if telemetry is not None:
        telemetry.emit({"ev": "span", "name": "prefill", "t0": t_start,
                        "t1": t_mid, "batch": batch_size,
                        "prompt_len": prompt_len})
        telemetry.emit({"ev": "span", "name": "decode", "t0": t_mid,
                        "t1": t_end, "batch": batch_size,
                        "gen_len": gen_len})
        telemetry.counter("tok_per_s", stats["tok_per_s"])
    log_fn(f"prefill {prompt_len} toks x{batch_size}: {t_prefill:.3f}s; "
           f"decode {gen_len - 1} steps: {t_decode:.3f}s "
           f"({stats['tok_per_s']:.1f} tok/s end-to-end, "
           f"{stats['tok_per_s_decode']:.1f} tok/s decode)")
    return out, stats


def serve_continuous(arch: str, *, smoke=True, batch_size=4, n_requests=8,
                     prompt_len=16, gen_len=16, arrival_steps=None,
                     gen_lens=None, prompts=None, page_size=8, n_pages=None,
                     gang=False, telemetry=None, log_fn=print):
    """Continuous batching over the paged cache.

    ``arrival_steps``: per-request decode-step at which it may be admitted
    (None = all at step 0 — e.g. a precomputed Poisson trace). ``prompts``:
    optional list of (1, prompt_len) token arrays (default: rows of the
    same ``lm_batch`` draw ``serve`` uses, so outputs are comparable).
    ``gen_lens``: per-request generation lengths (ragged; default
    ``gen_len`` each). ``gang=True`` degrades the scheduler to
    batch-at-once — admission waits until *every* slot is free, so short
    requests hold their slot idle while long ones finish (the baseline the
    decode bench compares against; same driver, same step clock). Returns
    (tokens: (n_requests, gen_len) host array, rows past a request's own
    ``gen_lens`` entry zero-filled, stats dict).
    """
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    if model.decode_step_paged is None:
        raise ValueError(f"{arch}: continuous batching needs a plain "
                         "decoder stack (dense/moe family)")
    params = model.init(jax.random.PRNGKey(0))
    if prompts is None:
        batch = lm_batch(jax.random.PRNGKey(1), cfg, n_requests, prompt_len + 1)
        prompts = [batch["tokens"][r:r + 1, :prompt_len]
                   for r in range(n_requests)]
    if arrival_steps is None:
        arrival_steps = [0] * n_requests
    if gen_lens is None:
        gen_lens = [gen_len] * n_requests
    assert max(gen_lens) <= gen_len, (gen_lens, gen_len)
    max_len = prompt_len + gen_len
    if n_pages is None:
        # live pages per slot + one step of slack, + the null page
        per_slot = pages_needed(max_len, page_size, cfg.sliding_window) + 1
        n_pages = 1 + batch_size * per_slot
    B = batch_size
    cache = model.init_cache_paged(B, max_len, n_pages, page_size)

    prefill_j = jax.jit(model.prefill_paged, donate_argnums=(2,))
    decode_j = jax.jit(model.decode_step_paged, donate_argnums=(2,))
    release_j = jax.jit(release_slots, donate_argnums=(0,))
    need_pages = pages_needed(prompt_len, page_size, cfg.sliding_window)

    out = np.zeros((n_requests, gen_len), np.int32)
    slot_req = [-1] * B                     # request id per slot (-1 free)
    n_gen = [0] * B
    tok = jnp.zeros((B, 1), jnp.int32)
    next_req, done, step = 0, 0, 0
    # Per-request telemetry bookkeeping: admit wall-clock + time-to-first-
    # token (prefill returns the first token, so TTFT closes with it).
    req_t0 = [None] * n_requests
    req_ttft = [None] * n_requests
    t0 = time.time()
    while done < n_requests:
        # ---- admit arrived requests into free slots (capacity permitting);
        # gang mode (batch-at-once baseline) waits for the whole batch to
        # drain before admitting the next wave
        admit = range(0) if gang and any(s >= 0 for s in slot_req) else range(B)
        for b in admit:
            if slot_req[b] >= 0 or next_req >= n_requests:
                continue
            if arrival_steps[next_req] > step:
                break                       # in-order admission
            if int(cache.n_free) < need_pages + 1:
                break                       # backpressure: wait for frees
            pbatch = {"tokens": prompts[next_req]}
            req_t0[next_req] = time.time()
            logits, cache = prefill_j(params, pbatch, cache, jnp.asarray(b))
            t0k = jnp.argmax(logits[0, -1]).astype(jnp.int32)
            tok = tok.at[b, 0].set(t0k)
            slot_req[b], n_gen[b] = next_req, 1
            out[next_req, 0] = int(t0k)     # host sync: first token is real
            req_ttft[next_req] = time.time() - req_t0[next_req]
            next_req += 1
        if telemetry is not None:
            # Scheduler-state counters, once per step clock tick: requests
            # arrived but not yet admitted, and the page-pool headroom the
            # admission backpressure tests against.
            queued = sum(1 for r in range(next_req, n_requests)
                         if arrival_steps[r] <= step)
            telemetry.counter("queue_depth", queued)
            telemetry.counter("pages_free", int(cache.n_free))
        active_h = [slot_req[b] >= 0 for b in range(B)]
        if not any(active_h):
            step += 1                       # idle: nothing arrived yet
            continue
        # ---- one fixed-shape decode step over every slot
        logits, cache = decode_j(params, tok, cache,
                                 jnp.asarray(active_h))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        retire = []
        for b in range(B):
            if slot_req[b] < 0:
                continue
            out[slot_req[b], n_gen[b]] = int(tok[b, 0])
            n_gen[b] += 1
            if n_gen[b] == gen_lens[slot_req[b]]:   # finished: free slot + pages
                rid = slot_req[b]
                if telemetry is not None:
                    telemetry.emit({
                        "ev": "span", "name": "request", "req": rid,
                        "slot": b, "t0": req_t0[rid], "t1": time.time(),
                        "ttft_s": req_ttft[rid], "n_tok": gen_lens[rid]})
                retire.append(b)
                done += 1
                slot_req[b] = -1
        if retire:
            mask = np.zeros((B,), bool)
            mask[retire] = True
            cache = release_j(cache, jnp.asarray(mask))
        step += 1
    jax.block_until_ready(tok)
    wall = time.time() - t0
    n_tok = sum(gen_lens)
    stats = {"wall_s": wall, "steps": step, "n_tok": n_tok,
             "tok_per_s": n_tok / max(wall, 1e-9),
             "tok_per_step": n_tok / max(step, 1),
             "n_pages": n_pages, "page_size": page_size}
    log_fn(f"continuous: {n_requests} reqs x {gen_len} toks on {B} slots, "
           f"{step} steps, {wall:.3f}s ({stats['tok_per_s']:.1f} tok/s)")
    return out, stats


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="slot-scheduled continuous batching (paged cache)")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--telemetry-dir", default=None,
                    help="write serving telemetry (request spans with "
                         "TTFT, queue-depth / page-pool counters) as "
                         "events-p0.jsonl + merged trace.json; inspect "
                         "with python -m repro.obs.report DIR")
    args = ap.parse_args()
    sink = None
    if args.telemetry_dir:
        from ..obs import telemetry as telemetry_mod
        sink = telemetry_mod.Telemetry(
            args.telemetry_dir,
            meta=dict(kind="serve", arch=args.arch,
                      continuous=args.continuous))
    if args.continuous:
        gen, _ = serve_continuous(
            args.arch, smoke=args.smoke, batch_size=args.batch_size,
            n_requests=args.n_requests, prompt_len=args.prompt_len,
            gen_len=args.gen_len, telemetry=sink)
    else:
        gen, _ = serve(args.arch, smoke=args.smoke,
                       batch_size=args.batch_size,
                       prompt_len=args.prompt_len, gen_len=args.gen_len,
                       telemetry=sink)
    if sink is not None:
        sink.close()
        from ..obs import trace as trace_mod
        trace_mod.merge_dir(args.telemetry_dir)
    print("generated token ids (first row):", gen[0].tolist())


if __name__ == "__main__":
    main()
