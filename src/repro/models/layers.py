"""Primitive layers: norms, MLPs, embeddings, RoPE.

Functional style: ``init_*`` builds a param dict, ``apply`` fns are pure.
All initializers take explicit PRNG keys; dtype follows the config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in, d_out, dtype, scale=None, bias=False):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d, dtype, kind="rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def mlp_init(key, d, d_ff, dtype, act="swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "wi": dense_init(k1, d, d_ff, dtype),
            "wg": dense_init(k2, d, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d, dtype),
            }
    return {"wi": dense_init(k1, d, d_ff, dtype), "wo": dense_init(k3, d_ff, d, dtype)}


def apply_mlp(p, x):
    if "wg" in p:
        h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    else:
        h = jax.nn.gelu(dense(p["wi"], x))
    return dense(p["wo"], h)


def embedding_init(key, vocab, d, dtype):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Tied head: logits in fp32 for loss stability."""
    return x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T


# ---------------------------------------------------------------- RoPE ----
def rope_freqs(head_dim, rope_fraction, theta):
    """Rotary frequencies over the first ``fraction`` of the head dim."""
    rot = int(head_dim * rope_fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, *, rope_fraction=1.0, theta=1e4):
    """x: (..., S, H, hd); positions: broadcastable to (..., S).

    ``rope_fraction < 1`` rotates only the leading slice (ChatGLM-style
    partial/2d rotary); the remainder passes through unrotated.
    """
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, rope_fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    xr = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr.astype(x.dtype), x[..., rot:]], axis=-1)
