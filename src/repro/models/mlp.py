"""The paper's own networks: fully-connected classifiers (MNIST 784-400-10,
TIMIT 360-512x3-1973, and the Fig. 4 network 784-400-150-10).

Exposes the same (loss_fn, logits_fn, out_loss_fn) split the HF optimizer
needs for its Gauss-Newton variants.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp


class MLPApi(NamedTuple):
    init: callable
    loss_fn: callable
    logits_fn: callable
    out_loss_fn: callable
    accuracy: callable


def build_mlp(layer_dims: Sequence[int], activation: str = "tanh") -> MLPApi:
    """layer_dims = (in, hidden..., n_classes). Batch: {"x": (B,D), "y": (B,) int}."""
    act = {"tanh": jnp.tanh, "relu": jax.nn.relu, "sigmoid": jax.nn.sigmoid}[activation]

    def init(key):
        params = []
        keys = jax.random.split(key, len(layer_dims) - 1)
        for k, din, dout in zip(keys, layer_dims[:-1], layer_dims[1:]):
            params.append({
                "w": jax.random.normal(k, (din, dout)) * jnp.sqrt(1.0 / din),
                "b": jnp.zeros((dout,)),
            })
        return params

    def logits_fn(params, batch):
        h = batch["x"]
        for layer in params[:-1]:
            h = act(h @ layer["w"] + layer["b"])
        return h @ params[-1]["w"] + params[-1]["b"]

    def out_loss_fn(logits, batch):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
        return jnp.mean(nll)

    def loss_fn(params, batch):
        return out_loss_fn(logits_fn(params, batch), batch)

    def accuracy(params, batch):
        pred = jnp.argmax(logits_fn(params, batch), axis=-1)
        return jnp.mean((pred == batch["y"]).astype(jnp.float32))

    return MLPApi(init, loss_fn, logits_fn, out_loss_fn, accuracy)
