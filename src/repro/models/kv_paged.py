"""Paged KV cache: a shared page pool + per-slot page tables.

The dense ``KVCache`` keeps a per-sequence ``(B, W, KV, hd)`` buffer sized
for the *longest* sequence — thousands of concurrent ragged-length requests
pay worst-case HBM each. Here K/V live in one physical page pool shared by
every slot:

  * ``k_pool`` / ``v_pool``: (L, P, page_size, KV, hd) — P physical pages
    per layer (stacked over the L decoder layers so the transformer's layer
    scan can carry one (P, page_size, KV, hd) slice per step). Page 0 is
    the reserved *null page*: writes from inactive/unmapped slots are
    routed there and reads of it are always bias-masked, so scatter
    collisions on it are harmless garbage.
  * ``page_table``: (B, max_pages) int32 — logical page j of slot b lives
    in physical page ``page_table[b, j]`` (-1 = unmapped). Logical token i
    sits at slot i % page_size of logical page i // page_size. The table is
    shared by every layer (all layers page identically).
  * ``seq_len``: (B,) tokens written so far per slot.
  * ``free_pages``/``n_free``: a functional stack of free physical page ids
    (``free_pages[:n_free]`` free) so allocation/release are jit-able
    fixed-shape scans.

The split-K decode kernel (kernels/flash_decode.py::flash_decode_paged)
scalar-prefetches the page table and gathers pages in its K/V index maps —
no dense per-sequence copy of the cache ever exists. Under a sliding window
pages that roll fully out of the live range are freed (at most one per slot
per decode step; prefill only maps pages overlapping the live range), so
steady-state HBM is ~window tokens per live slot regardless of max_len.

Allocation invariant maintained across alloc/advance/release: every
physical page > 0 is either on the free stack or mapped by exactly one
(slot, logical page); ``check_invariants`` asserts it host-side in tests.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .attention import NEG_INF, _sdpa, _split_heads
from .layers import apply_rope, dense

__all__ = [
    "PagedKVCache", "init_paged_cache", "alloc_prefill", "alloc_decode_page",
    "advance_and_free", "release_slots", "write_prefill_kv",
    "paged_decode_attend", "pages_needed", "check_invariants",
]


class PagedKVCache(NamedTuple):
    k_pool: jax.Array       # (L, P, ps, KV, hd)
    v_pool: jax.Array       # (L, P, ps, KV, hd)
    page_table: jax.Array   # (B, max_pages) int32, -1 = unmapped
    seq_len: jax.Array      # (B,) int32 tokens written per slot
    free_pages: jax.Array   # (P,) int32 stack, [:n_free] free
    n_free: jax.Array       # () int32

    @property
    def page_size(self):
        return self.k_pool.shape[2]

    @property
    def max_pages(self):
        return self.page_table.shape[1]


def init_paged_cache(cfg, n_layers, batch, max_len, n_pages, dtype,
                     page_size: int = 128) -> PagedKVCache:
    """Pool of ``n_pages`` physical pages (page 0 reserved as the null
    page), empty tables for ``batch`` slots covering ``max_len`` logical
    tokens."""
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    maxp = -(-max_len // page_size)
    return PagedKVCache(
        k_pool=jnp.zeros((n_layers, n_pages, page_size, KV, hd), dtype),
        v_pool=jnp.zeros((n_layers, n_pages, page_size, KV, hd), dtype),
        page_table=jnp.full((batch, maxp), -1, jnp.int32),
        seq_len=jnp.zeros((batch,), jnp.int32),
        # stack of free ids 1..P-1 (0 = null page, never allocated);
        # capacity P so the push index n_free never collides with a live id
        free_pages=jnp.concatenate(
            [jnp.arange(1, n_pages, dtype=jnp.int32),
             jnp.zeros((1,), jnp.int32)]),
        n_free=jnp.asarray(n_pages - 1, jnp.int32),
    )


def pages_needed(length: int, page_size: int, window: Optional[int]) -> int:
    """Pages a prefill of ``length`` maps (only those overlapping the live
    range [length - window, length) under a sliding window)."""
    hi = -(-length // page_size)
    lo = max(0, length - window) // page_size if window else 0
    return hi - lo


def _pop_scan(stack, n, take):
    """Pop one page per True in ``take`` (flat scan, fixed shape).
    Returns (stack, n, pids) with pid = -1 where take is False."""
    def body(carry, t):
        n = carry
        pid = jnp.where(t, stack[jnp.maximum(n - 1, 0)], -1)
        return jnp.where(t, n - 1, n), pid
    n, pids = jax.lax.scan(body, n, take)
    return stack, n, pids


def alloc_prefill(cache: PagedKVCache, lengths, admit,
                  window: Optional[int] = None) -> PagedKVCache:
    """(Re)build the page tables of admitted slots for a prefill of
    ``lengths`` tokens, popping pages from the free stack. ``admit``: (B,)
    bool; non-admitted rows are untouched. Under a sliding window only the
    pages overlapping the live range [lengths - window, lengths) are mapped
    (``pages_needed``). Admitted slots must have been ``release_slots``-ed
    first (their rows are assumed unmapped); the caller checks capacity
    host-side (``n_free`` vs ``pages_needed``)."""
    B, maxp = cache.page_table.shape
    ps = cache.page_size
    lengths = jnp.asarray(lengths, jnp.int32)
    j = jnp.arange(maxp, dtype=jnp.int32)[None]
    need = jnp.logical_and(j * ps < lengths[:, None],
                           admit[:, None])                   # (B, maxp)
    if window is not None:
        live_lo = jnp.maximum(lengths - window, 0)[:, None]
        need = jnp.logical_and(need, (j + 1) * ps > live_lo)
    stack, n, pids = _pop_scan(cache.free_pages, cache.n_free,
                               need.reshape(-1))
    tbl = jnp.where(need, pids.reshape(B, maxp), cache.page_table)
    seq_len = jnp.where(admit, lengths, cache.seq_len)
    return cache._replace(page_table=tbl, seq_len=seq_len,
                          free_pages=stack, n_free=n)


def alloc_decode_page(cache: PagedKVCache, active) -> PagedKVCache:
    """Map the page holding position ``seq_len`` for every active slot that
    crossed a page boundary (at most one pop per slot per step)."""
    B, maxp = cache.page_table.shape
    ps = cache.page_size
    jnew = cache.seq_len // ps                                # (B,)
    need = jnp.logical_and(
        active,
        jnp.logical_and(cache.seq_len % ps == 0, jnew < maxp))
    need = jnp.logical_and(
        need, cache.page_table[jnp.arange(B), jnp.minimum(jnew, maxp - 1)] < 0)
    stack, n, pids = _pop_scan(cache.free_pages, cache.n_free, need)
    tbl = cache.page_table.at[jnp.arange(B), jnp.minimum(jnew, maxp - 1)].set(
        jnp.where(need, pids, cache.page_table[jnp.arange(B),
                                               jnp.minimum(jnew, maxp - 1)]))
    return cache._replace(page_table=tbl, free_pages=stack, n_free=n)


def advance_and_free(cache: PagedKVCache, active,
                     window: Optional[int]) -> PagedKVCache:
    """seq_len += active; under a sliding window, free the (at most one)
    page per slot that just rolled fully out of the live range
    [seq_len - window, seq_len)."""
    sl = cache.seq_len + active.astype(jnp.int32)
    cache = cache._replace(seq_len=sl)
    if window is None:
        return cache
    B, maxp = cache.page_table.shape
    ps = cache.page_size
    fl = sl - window                                          # first live pos
    jdead = fl // ps - 1
    can = jnp.logical_and(active, jnp.logical_and(fl > 0, fl % ps == 0))
    jdead = jnp.clip(jdead, 0, maxp - 1)
    pid = cache.page_table[jnp.arange(B), jdead]
    do = jnp.logical_and(can, pid >= 0)

    def body(carry, inp):
        stack, n = carry
        d, p = inp
        stack = stack.at[jnp.where(d, n, cache.free_pages.shape[0] - 1)].set(
            jnp.where(d, p, stack[-1]))
        return (stack, jnp.where(d, n + 1, n)), 0

    (stack, n), _ = jax.lax.scan(body, (cache.free_pages, cache.n_free),
                                 (do, pid))
    tbl = cache.page_table.at[jnp.arange(B), jdead].set(
        jnp.where(do, -1, pid))
    return cache._replace(page_table=tbl, free_pages=stack, n_free=n)


def release_slots(cache: PagedKVCache, mask) -> PagedKVCache:
    """Return every page of the masked slots to the free stack and clear
    their rows (retire finished sequences / make room for admission)."""
    B, maxp = cache.page_table.shape
    rel = jnp.logical_and(mask[:, None], cache.page_table >= 0)  # (B, maxp)

    def body(carry, inp):
        stack, n = carry
        d, p = inp
        stack = stack.at[jnp.where(d, n, cache.free_pages.shape[0] - 1)].set(
            jnp.where(d, p, stack[-1]))
        return (stack, jnp.where(d, n + 1, n)), 0

    (stack, n), _ = jax.lax.scan(
        body, (cache.free_pages, cache.n_free),
        (rel.reshape(-1), cache.page_table.reshape(-1)))
    tbl = jnp.where(mask[:, None], -1, cache.page_table)
    sl = jnp.where(mask, 0, cache.seq_len)
    return cache._replace(page_table=tbl, seq_len=sl,
                          free_pages=stack, n_free=n)


# ------------------------------------------------------------- pool writes --
def _write_positions(k_pool_l, v_pool_l, page_table, pos, k, v, valid):
    """Scatter rows k/v: (B, T, KV, hd) at logical positions pos: (B, T)
    into one layer's pools. Invalid writes route to null page 0 (their
    reads are always bias-masked, so garbage there is harmless)."""
    B, T = pos.shape
    ps = k_pool_l.shape[1]
    page = jnp.where(valid,
                     page_table[jnp.arange(B)[:, None], pos // ps], 0)
    page = jnp.maximum(page, 0)                               # unmapped -> null
    page = jnp.where(valid, page, 0)
    slot = pos % ps
    flat = (page.reshape(-1), slot.reshape(-1))
    k_pool_l = k_pool_l.at[flat].set(k.reshape(B * T, *k.shape[2:]))
    v_pool_l = v_pool_l.at[flat].set(v.reshape(B * T, *v.shape[2:]))
    return k_pool_l, v_pool_l


def write_prefill_kv(k_pool_l, v_pool_l, page_table, k, v, lengths):
    """Prefill one layer: write k/v: (B, S, KV, hd) for logical positions
    [0, lengths_b) directly into the pages (positions >= lengths_b, or
    below a freed window page, hit unmapped entries and fall through to the
    null page)."""
    B, S = k.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    valid = pos < jnp.asarray(lengths, jnp.int32)[:, None]
    return _write_positions(k_pool_l, v_pool_l, page_table, pos, k, v, valid)


# ----------------------------------------------------------------- attend ---
def paged_decode_attend(p, x, cache_kv, page_table, seq_len, cfg, *,
                        active=None, interpret=None):
    """One-token decode for one layer against the paged pool.

    x: (B, 1, d); ``cache_kv``: (k_pool_l, v_pool_l) this layer's
    (P, ps, KV, hd) slices; ``seq_len``: (B,) position being written (the
    page for it must already be mapped — ``alloc_decode_page``). Returns
    (y, (k_pool_l, v_pool_l)). Under ``cfg.use_flash_attention`` the attend
    runs the scalar-prefetch paged kernel; otherwise the jnp gather oracle
    (dense copy) — parity path only.
    """
    from ..kernels import ops as kops
    from ..kernels import ref as kref

    k_pool_l, v_pool_l = cache_kv
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B = x.shape[0]
    ps = k_pool_l.shape[1]
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    pos_bt = seq_len[:, None].astype(jnp.int32)               # (B, 1)
    q = apply_rope(q, pos_bt, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, pos_bt, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    if active is None:
        active = jnp.ones((B,), bool)
    k_pool_l, v_pool_l = _write_positions(
        k_pool_l, v_pool_l, page_table, pos_bt, k, v, active[:, None])
    sl_now = seq_len + active.astype(jnp.int32)               # incl. this token
    bias = kops.paged_bias(page_table, sl_now, ps, window=cfg.sliding_window)
    bias = jnp.where(active[:, None], bias, NEG_INF)
    if cfg.use_flash_attention:
        out = kops.flash_decode_paged(q[:, 0], k_pool_l, v_pool_l,
                                      page_table, bias, interpret=interpret)
    else:
        out = kref.flash_decode_paged_ref(q[:, 0], k_pool_l, v_pool_l,
                                          page_table, bias)
    y = dense(p["wo"], out[:, None].reshape(B, 1, H * hd))
    return y, (k_pool_l, v_pool_l)


# ------------------------------------------------------------- diagnostics --
def check_invariants(cache: PagedKVCache):
    """Host-side: every page > 0 is free xor mapped exactly once."""
    import numpy as np

    tbl = np.asarray(cache.page_table)
    free = np.asarray(cache.free_pages[: int(cache.n_free)])
    P = cache.k_pool.shape[1]
    mapped = set(tbl[tbl >= 0].tolist())
    free_s = set(free.tolist())
    assert 0 not in mapped, "null page mapped"
    assert len(mapped) == int((tbl >= 0).sum()), "page double-mapped"
    assert len(free_s) == len(free), "free stack duplicate"
    assert not mapped & free_s, "page both mapped and free"
    leaked = set(range(1, P)) - mapped - free_s
    assert not leaked, f"leaked pages {leaked}"
