"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar
memory with block-diagonal recurrence).

mLSTM is a gated linear-attention recurrence — exactly the SSD form with
B=k/√dh, C=q, u=i·v and per-head scalar decay a=σ(f); we reuse
``ssm.ssd_chunked`` for the chunkwise-parallel train/prefill path and
``ssm.ssd_step`` for decode. The running normalizer n_t is carried as one
extra value channel. Exponential gating is implemented in its
sigmoid-normalized form (σ(i), σ(f)) — the max-stabilizer of the paper's
exp-gating largely cancels in h = (C q)/max(|n q|, 1); noted in DESIGN.md.

sLSTM is inherently sequential (recurrent h_{t-1} feeds the gates) and is run
as a ``lax.scan`` over time — its config appears only in xlstm-1.3b where the
sLSTM d_model is small.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import apply_norm, dense, dense_init, norm_init
from .ssm import ssd_chunked, ssd_step


# ---------------------------------------------------------------- mLSTM ----
class MLstmCache(NamedTuple):
    state: jax.Array     # (B, H, dh, dh+1) — matrix memory + normalizer col


def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wi": dense_init(ks[3], d, H, dtype, bias=True),
        "wf": dense_init(ks[4], d, H, dtype, bias=True),
        "out_norm": norm_init(d, dtype),
        "wo": dense_init(ks[5], d, d, dtype),
    }


def _mlstm_qkv(p, x, cfg):
    B, L, d = x.shape
    H = cfg.n_heads
    dh = d // H
    q = dense(p["wq"], x).reshape(B, L, H, dh)
    k = dense(p["wk"], x).reshape(B, L, H, dh) / jnp.sqrt(dh)
    v = dense(p["wv"], x).reshape(B, L, H, dh)
    i_gate = jax.nn.sigmoid(dense(p["wi"], x).astype(jnp.float32))        # (B,L,H)
    log_f = jax.nn.log_sigmoid(dense(p["wf"], x).astype(jnp.float32))     # (B,L,H)
    return q, k, v, i_gate, log_f


def _mlstm_read(y_ext, dh):
    y = y_ext[..., :dh] / jnp.maximum(jnp.abs(y_ext[..., dh:]), 1.0)
    return y


def apply_mlstm(p, x, cfg, h0=None):
    """x: (B,L,d) -> (y: (B,L,d), MLstmCache)."""
    B, L, d = x.shape
    H = cfg.n_heads
    dh = d // H
    q, k, v, i_gate, log_f = _mlstm_qkv(p, x, cfg)
    ones = jnp.ones((B, L, H, 1), v.dtype)
    u = jnp.concatenate([v, ones], axis=-1) * i_gate[..., None]            # (B,L,H,dh+1)
    chunk = cfg.ssm_chunk
    if L % chunk:
        chunk = 1 if L == 1 else next(c for c in range(min(chunk, L), 0, -1) if L % c == 0)
    y_ext, h_final = ssd_chunked(u, log_f, k, q, chunk, h0=h0)
    y = _mlstm_read(y_ext, dh).reshape(B, L, d).astype(x.dtype)
    y = apply_norm(p["out_norm"], y, cfg.norm_eps)
    return dense(p["wo"], y), MLstmCache(h_final)


def init_mlstm_cache(cfg, batch) -> MLstmCache:
    dh = cfg.d_model // cfg.n_heads
    return MLstmCache(jnp.zeros((batch, cfg.n_heads, dh, dh + 1), jnp.float32))


def mlstm_decode_step(p, x, cache: MLstmCache, cfg):
    B = x.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    q, k, v, i_gate, log_f = _mlstm_qkv(p, x, cfg)
    u = jnp.concatenate([v[:, 0], jnp.ones((B, H, 1), v.dtype)], axis=-1) * i_gate[:, 0, :, None]
    y_ext, new_state = ssd_step(u, log_f[:, 0], k[:, 0], q[:, 0], cache.state)
    y = _mlstm_read(y_ext, dh).reshape(B, 1, cfg.d_model).astype(x.dtype)
    y = apply_norm(p["out_norm"], y, cfg.norm_eps)
    return dense(p["wo"], y), MLstmCache(new_state)


# ---------------------------------------------------------------- sLSTM ----
class SLstmCache(NamedTuple):
    c: jax.Array    # (B, H, dh)
    n: jax.Array
    m: jax.Array
    h: jax.Array


def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": (jax.random.normal(k1, (d, 4, H, dh)) / jnp.sqrt(d)).astype(dtype),
        "r": (jax.random.normal(k2, (H, dh, 4, dh)) / jnp.sqrt(dh) * 0.5).astype(dtype),
        "b": jnp.zeros((4, H, dh), dtype),
        "wo": dense_init(k3, d, d, dtype),
    }


def _slstm_cell(p, pre_x, state: SLstmCache):
    """pre_x: (B,4,H,dh) input preactivations for one step."""
    c, n, m, h = state
    rec = jnp.einsum("bhd,hdge->bghe", h.astype(jnp.float32), p["r"].astype(jnp.float32))
    pre = pre_x.astype(jnp.float32) + rec                                  # (B,4,H,dh)
    i_t, f_t, z_t, o_t = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    m_new = jnp.maximum(f_t + m, i_t)                                      # stabilizer
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z_t)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return SLstmCache(c_new, n_new, m_new, h_new)


def apply_slstm(p, x, cfg, state: SLstmCache | None = None):
    """x: (B,L,d) -> (y, SLstmCache). Sequential lax.scan over time."""
    B, L, d = x.shape
    H = cfg.n_heads
    dh = d // H
    if state is None:
        state = init_slstm_cache(cfg, B)
    pre_x = jnp.einsum("bld,dghe->blghe", x, p["w"]) + p["b"]              # (B,L,4,H,dh)

    def step(carry, pre_t):
        new = _slstm_cell(p, pre_t, carry)
        return new, new.h

    final, hs = jax.lax.scan(step, state, pre_x.transpose(1, 0, 2, 3, 4))
    y = hs.transpose(1, 0, 2, 3).reshape(B, L, d).astype(x.dtype)
    return dense(p["wo"], y), final


def init_slstm_cache(cfg, batch) -> SLstmCache:
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return SLstmCache(z, z, jnp.full_like(z, -1e9), z)


def slstm_decode_step(p, x, cache: SLstmCache, cfg):
    B = x.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    pre_x = jnp.einsum("bd,dghe->bghe", x[:, 0], p["w"]) + p["b"]
    new = _slstm_cell(p, pre_x, cache)
    y = new.h.reshape(B, 1, cfg.d_model).astype(x.dtype)
    return dense(p["wo"], y), new
