"""Mamba2 / SSD blocks: chunkwise-parallel selective state space.

The SSD recurrence  S_t = a_t·S_{t-1} + B_t u_tᵀ,  y_t = C_tᵀ S_t + D·x_t
is evaluated in the chunked form (Mamba2 paper §6): intra-chunk terms as a
Q×Q masked-decay "attention" matmul (MXU-friendly), inter-chunk terms via an
associative scan over per-chunk summary states. This is the TPU-native
adaptation — time-sequential scans would serialize 4k-500k steps, while the
chunked form is O(L·Q) matmul work plus an O(L/Q) scan.

``ssd_chunked`` is shared by the Mamba2 block (zamba2) and the mLSTM block
(xlstm): mLSTM *is* this recurrence with B=k, C=q, u=i·v, a=σ(f).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import apply_norm, dense, dense_init, norm_init


def ssd_chunked(u, log_a, Bv, Cv, chunk: int, h0=None):
    """Chunked SSD.

    u:     (B, L, H, P)  decay-free inputs (dt·x or i·v), fp32 recommended
    log_a: (B, L, H)     per-step log decay (dt·A or logσ(f)), ≤ 0
    Bv:    (B, L, N) shared across heads, or (B, L, H, N) per-head
    Cv:    same convention as Bv
    h0:    (B, H, N, P) initial state or None
    Returns (y: (B, L, H, P), h_final: (B, H, N, P)).
    """
    Bb, L, H, P = u.shape
    per_head = Bv.ndim == 4
    N = Bv.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc, Q = L // chunk, chunk

    u = u.reshape(Bb, nc, Q, H, P).astype(jnp.float32)
    la = log_a.reshape(Bb, nc, Q, H).astype(jnp.float32)
    if per_head:
        Br = Bv.reshape(Bb, nc, Q, H, N).astype(jnp.float32)
        Cr = Cv.reshape(Bb, nc, Q, H, N).astype(jnp.float32)
    else:
        Br = Bv.reshape(Bb, nc, Q, N).astype(jnp.float32)
        Cr = Cv.reshape(Bb, nc, Q, N).astype(jnp.float32)

    l = jnp.cumsum(la, axis=2)                                   # inclusive (B,nc,Q,H)
    # --- intra-chunk: masked decay attention ---------------------------------
    rel = l[:, :, :, None, :] - l[:, :, None, :, :]              # l_i - l_j (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    if per_head:
        scores = jnp.einsum("bcihn,bcjhn->bcijh", Cr, Br)
    else:
        scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br)[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores * decay, u)

    # --- per-chunk summary states -------------------------------------------
    s_decay = jnp.exp(l[:, :, -1:, :] - l)                       # exp(l_Q - l_j)
    uw = u * s_decay[..., None]
    if per_head:
        S = jnp.einsum("bcjhn,bcjhp->bchnp", Br, uw)
    else:
        S = jnp.einsum("bcjn,bcjhp->bchnp", Br, uw)
    g = jnp.exp(l[:, :, -1, :])                                  # chunk decay (B,nc,H)

    # --- inter-chunk associative scan ----------------------------------------
    def combine(left, right):
        g_l, s_l = left
        g_r, s_r = right
        return g_l * g_r, g_r[..., None, None] * s_l + s_r

    g_scan, S_scan = jax.lax.associative_scan(combine, (g, S), axis=1)
    if h0 is not None:
        h0 = h0.astype(jnp.float32)
        cumg = jnp.exp(jnp.cumsum(jnp.log(jnp.maximum(g, 1e-38)), axis=1))
        S_scan = S_scan + cumg[..., None, None] * h0[:, None]
    h_final = S_scan[:, -1]
    h_prev = jnp.concatenate(
        [h0[:, None] if h0 is not None else jnp.zeros_like(S_scan[:, :1]), S_scan[:, :-1]],
        axis=1,
    )                                                            # state entering chunk c

    # --- inter-chunk contribution --------------------------------------------
    if per_head:
        y_inter = jnp.einsum("bcihn,bchnp->bcihp", Cr, h_prev)
    else:
        y_inter = jnp.einsum("bcin,bchnp->bcihp", Cr, h_prev)
    y_inter = y_inter * jnp.exp(l)[..., None]
    y = (y_intra + y_inter).reshape(Bb, L, H, P)
    return y, h_final


def ssd_step(u_t, log_a_t, B_t, C_t, state):
    """Single-token SSD recurrence (decode).

    u_t: (B,H,P), log_a_t: (B,H), B_t/C_t: (B,N) or (B,H,N), state: (B,H,N,P).
    """
    a = jnp.exp(log_a_t.astype(jnp.float32))[..., None, None]
    if B_t.ndim == 2:
        outer = jnp.einsum("bn,bhp->bhnp", B_t.astype(jnp.float32), u_t.astype(jnp.float32))
    else:
        outer = jnp.einsum("bhn,bhp->bhnp", B_t.astype(jnp.float32), u_t.astype(jnp.float32))
    new_state = a * state + outer
    if C_t.ndim == 2:
        y = jnp.einsum("bn,bhnp->bhp", C_t.astype(jnp.float32), new_state)
    else:
        y = jnp.einsum("bhn,bhnp->bhp", C_t.astype(jnp.float32), new_state)
    return y, new_state


# ------------------------------------------------------------ Mamba2 block --
class MambaCache(NamedTuple):
    conv: jax.Array      # (B, K-1, d_conv_in) rolling conv inputs
    state: jax.Array     # (B, H, N, P) SSD state


def mamba_init(key, cfg, dtype):
    d, din, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_n_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    conv_in = din + 2 * N
    return {
        "in_proj": dense_init(k1, d, 2 * din + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_in)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_in,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),                 # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),          # softplus ≈ 0.13
        "gate_norm": norm_init(din, dtype),
        "out_proj": dense_init(k3, din, d, dtype),
    }


def _split_in_proj(cfg, zxbcdt):
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads
    z, xBC, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, kernel K, over (B, L, Cin)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def apply_mamba(p, x, cfg, h0=None, conv0=None):
    """x: (B, L, d) -> (y, MambaCache). Full-sequence (train/prefill)."""
    B_, L, d = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    zxbcdt = dense(p["in_proj"], x)
    z, xBC, dt_raw = _split_in_proj(cfg, zxbcdt)
    if conv0 is not None:
        xBC_in = jnp.concatenate([conv0, xBC], axis=1)[:, -(L + cfg.ssm_conv - 1):]
        conv_out = _causal_conv(xBC_in, p["conv_w"], p["conv_b"])[:, -L:]
    else:
        conv_out = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xc, Bc, Cc = jnp.split(conv_out, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])        # (B,L,H)
    A = -jnp.exp(p["A_log"])                                               # (H,)
    xh = xc.reshape(B_, L, H, P)
    u = xh.astype(jnp.float32) * dt[..., None]
    log_a = dt * A
    chunk = cfg.ssm_chunk
    if L % chunk:
        chunk = 1 if L == 1 else next(c for c in range(min(chunk, L), 0, -1) if L % c == 0)
    y, h_final = ssd_chunked(u, log_a, Bc, Cc, chunk, h0=h0)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, L, din).astype(x.dtype)
    y = apply_norm(p["gate_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    conv_tail = (jnp.concatenate([conv0, xBC], axis=1) if conv0 is not None else xBC)[
        :, -(cfg.ssm_conv - 1):
    ]
    return dense(p["out_proj"], y), MambaCache(conv_tail, h_final)


def init_mamba_cache(cfg, batch, dtype) -> MambaCache:
    din, N = cfg.d_inner, cfg.ssm_state
    H, P = cfg.ssm_n_heads, cfg.ssm_head_dim
    return MambaCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, din + 2 * N), dtype),
        state=jnp.zeros((batch, H, N, P), jnp.float32),
    )


def mamba_decode_step(p, x, cache: MambaCache, cfg):
    """x: (B, 1, d) -> (y: (B,1,d), cache)."""
    B_ = x.shape[0]
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    zxbcdt = dense(p["in_proj"], x[:, 0])
    z, xBC, dt_raw = _split_in_proj(cfg, zxbcdt)
    window = jnp.concatenate([cache.conv, xBC[:, None]], axis=1)           # (B,K,Cin)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])
    xc, Bc, Cc = jnp.split(conv_out, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])        # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xc.reshape(B_, H, P)
    u = xh.astype(jnp.float32) * dt[..., None]
    y, new_state = ssd_step(u, dt * A, Bc, Cc, cache.state)
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B_, din).astype(x.dtype)
    y = apply_norm(p["gate_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    y = dense(p["out_proj"], y)[:, None]
    return y, MambaCache(window[:, 1:], new_state)
