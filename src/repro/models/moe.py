"""Mixture-of-Experts FFN with GShard/Switch capacity-based einsum dispatch.

Tokens are regrouped to ``(groups, group_len)`` with per-group expert capacity
C = ceil(group_len·k·cap/E), so dispatch memory is O(T·E·C) with C bounded by
the group length, not the global token count (the GShard trick). Groups are
formed *within* each batch row, so the leading dim keeps the batch's
("pod","data") sharding and the expert dimension can live on the "model" axis
— the dispatch/combine einsums then lower to all-to-all-style collectives,
which is the TPU-native form of expert parallelism.

Top-k routing: k-th choices queue behind (k-1)-th (Switch priority). Overflow
tokens are dropped; underflow slots are zeros. Aux load-balance loss follows
Switch (E · Σ_e fraction_e · mean_prob_e / k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import mlp_init, apply_mlp, dense_init

MOE_GROUP_LEN = 256


def _hint(x, *tail):
    """Best-effort sharding constraint: leading dim on the batch/data axes,
    trailing dims per ``tail``. No-op outside a mesh context."""
    from jax.sharding import PartitionSpec as P

    for data_axes in (("pod", "data"), ("data",)):
        try:
            return jax.lax.with_sharding_constraint(x, P(data_axes, *tail))
        except (ValueError, KeyError, NameError, TypeError):
            continue
    return x


def moe_init(key, cfg, dtype):
    k_r, k_e = jax.random.split(key)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    experts = jax.vmap(lambda k: mlp_init(k, d, f, dtype, act=cfg.mlp_act))(
        jax.random.split(k_e, E)
    )
    return {"router": dense_init(k_r, d, E, dtype), "experts": experts}


def group_len_for(S: int) -> int:
    gl = min(MOE_GROUP_LEN, S)
    while S % gl:
        gl -= 1
    return gl


def capacity(cfg, group_len: int) -> int:
    return max(int(cfg.capacity_factor * cfg.top_k * group_len / cfg.n_experts), 1)


def apply_moe(p, x, cfg):
    """x: (B, S, d) -> (y: (B, S, d), aux_loss: scalar)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    gl = group_len_for(S)
    C = capacity(cfg, gl)
    G = B * (S // gl)
    xg = x.reshape(G, gl, d)

    logits = (xg @ p["router"]["w"].astype(xg.dtype)).astype(jnp.float32)  # (G,gl,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                          # (G,gl,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Position-in-expert: cumsum in (k-major, token) order per group/expert.
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)                # (G,gl,K,E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, K * gl, E)              # k-priority
    pos = jnp.cumsum(flat, axis=1) * flat - flat                           # 0-based
    pos = pos.reshape(G, K, gl, E).transpose(0, 2, 1, 3)                   # (G,gl,K,E)
    keep = (pos < C) * onehot
    slot = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32) * keep[..., None]  # (G,gl,K,E,C)
    dispatch = jnp.sum(slot, axis=2)                                       # (G,gl,E,C)
    combine = jnp.sum(slot * gate_vals[..., None, None], axis=2)           # (G,gl,E,C)
    if cfg.shard_hints:
        dispatch = _hint(dispatch, None, None, None)
        combine = _hint(combine, None, None, None)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(xg.dtype), xg)
    E_, G_, C_, _ = expert_in.shape
    expert_in = expert_in.reshape(E_, G_ * C_, d)
    if cfg.shard_hints:
        # tokens stay data-sharded through the expert compute; the expert dim
        # stays whole (all-to-all emerges at the dispatch boundary instead of
        # replicating the one-hot tensors).
        from jax.sharding import PartitionSpec as P
        for data_axes in (("pod", "data"), ("data",)):
            try:
                expert_in = jax.lax.with_sharding_constraint(
                    expert_in, P(None, data_axes, None))
                break
            except (ValueError, KeyError, NameError, TypeError):
                continue
    expert_out = jax.vmap(apply_mlp)(p["experts"], expert_in)              # (E,G*C,d)
    expert_out = expert_out.reshape(E_, G_, C_, d)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(xg.dtype), expert_out)

    frac = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))                  # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob) / K
    return y.reshape(B, S, d), aux.astype(jnp.float32)
