"""Sequence-sharded decode attention (flash-decode) via shard_map.

For single-sequence long-context decode (long_500k: batch=1) neither the
batch dim nor a small kv-head count can shard the KV cache, and GSPMD's only
automatic option is to replicate/gather it. The right manual schedule shards
the cache's *sequence slots* across the model axis: every chip attends over
its local slots and the partials merge with a numerically-stable logsumexp
combine — two tiny all-reduces of (B,H)-shaped stats + one (B,H,hd) partial
sum, instead of moving the cache.

This is a beyond-paper serving optimization (the paper trains MLPs); it
composes with the rolling-buffer semantics because slot position p % W maps
each chip to an interleaved slice of positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .attention import KVCache, _split_heads
from .layers import apply_rope, dense

NEG_INF = -1e30


def sharded_decode_attend(p, x, t, cache: KVCache, cfg, mesh, *, axis="model"):
    """One-token decode with the cache's W dim sharded over ``axis``.

    x: (B,1,d); cache.k/v: (B,W,KV,hd) sharded P(None, axis, None, None);
    cache.pos: (W,) sharded P(axis). Returns (y: (B,1,d), new cache).
    """
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B = x.shape[0]
    W = cache.window
    n_shards = mesh.shape[axis]
    assert W % n_shards == 0, (W, n_shards)

    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    pos_t = jnp.full((1,), t, jnp.int32)
    q = apply_rope(q, pos_t, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, pos_t, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(None, axis, None, None), P(None, axis, None, None), P(axis)),
        out_specs=(P(), P(None, axis, None, None), P(None, axis, None, None), P(axis)),
    )
    def attend(q, k_new, v_new, k_sh, v_sh, pos_sh):
        # local slot index of the global rolling slot t % W, if it lands here
        Wl = k_sh.shape[1]
        shard_id = jax.lax.axis_index(axis)
        slot_global = jnp.mod(t, W)
        slot_local = slot_global - shard_id * Wl
        mine = jnp.logical_and(slot_local >= 0, slot_local < Wl)
        sl = jnp.clip(slot_local, 0, Wl - 1)
        k_upd = jax.lax.dynamic_update_slice_in_dim(k_sh, k_new, sl, axis=1)
        v_upd = jax.lax.dynamic_update_slice_in_dim(v_sh, v_new, sl, axis=1)
        pos_upd = jax.lax.dynamic_update_slice_in_dim(pos_sh, pos_t, sl, axis=0)
        k_sh = jnp.where(mine, k_upd, k_sh)
        v_sh = jnp.where(mine, v_upd, v_sh)
        pos_sh = jnp.where(mine, pos_upd, pos_sh)

        valid = jnp.logical_and(pos_sh >= 0, pos_sh <= t)
        if cfg.sliding_window:
            valid = jnp.logical_and(valid, pos_sh > t - cfg.sliding_window)
        G = H // KV
        qg = q.reshape(B, 1, KV, G, hd)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg, k_sh,
                            preferred_element_type=jnp.float32)
        logits = logits / jnp.sqrt(hd) + jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
        m_loc = jnp.max(logits, axis=-1)                       # (B,KV,G,1)
        m_glob = jax.lax.pmax(m_loc, axis)
        m_safe = jnp.where(m_glob <= NEG_INF / 2, 0.0, m_glob)
        e = jnp.exp(logits - m_safe[..., None])
        e = jnp.where(valid[None, None, None, None, :], e, 0.0)
        s_loc = jnp.sum(e, axis=-1)                            # (B,KV,G,1)
        o_loc = jnp.einsum("bkgst,btkh->bskgh", e.astype(v_sh.dtype), v_sh,
                           preferred_element_type=jnp.float32)
        s = jax.lax.psum(s_loc, axis)
        o = jax.lax.psum(o_loc, axis)
        out = o / jnp.maximum(s, 1e-20).transpose(0, 3, 1, 2)[..., None]
        return out.reshape(B, 1, H * hd).astype(q.dtype), k_sh, v_sh, pos_sh

    out, new_k, new_v, new_pos = attend(q, k, v, cache.k, cache.v, cache.pos)
    y = dense(p["wo"], out)
    return y, KVCache(new_k, new_v, new_pos)
