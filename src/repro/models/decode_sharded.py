"""Sequence-sharded decode attention (flash-decode) via shard_map.

For single-sequence long-context decode (long_500k: batch=1) neither the
batch dim nor a small kv-head count can shard the KV cache, and GSPMD's only
automatic option is to replicate/gather it. The right manual schedule shards
the cache's *sequence slots* across the model axis: every chip runs the
split-K flash-decode kernel (kernels/flash_decode.py) over its local slots
— emitting the per-shard (o, m, l) contract via ``return_stats`` — and the
partials merge with the same numerically-stable logsumexp combine the
kernel uses between its own splits (the combine is associative): two tiny
all-reduces of (B,H)-shaped stats + one (B,H,hd) partial sum, instead of
moving the cache.

This is a beyond-paper serving optimization (the paper trains MLPs); it
composes with the rolling-buffer semantics because slot position p % W maps
each chip to an interleaved slice of positions, and the mask rides in the
shared ``decode_bias`` row computed per shard from the local slot positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..kernels import ops as kops
from .attention import KVCache, _split_heads
from .layers import apply_rope, dense

NEG_INF = -1e30


def combine_shard_stats(o, m, l, axis):
    """Merge per-shard flash-decode partials across a mesh axis.

    o: (B, H, hd) shard-local normalized output; m/l: (B, H) shard-local
    running max / softmax mass (the ``flash_decode(return_stats=True)``
    contract). Same logsumexp algebra as kernels.flash_decode.combine_splits,
    expressed as collectives: m* = pmax(m), w = l·e^{m−m*}, then one psum
    for the mass and one for the weighted outputs.
    """
    m_glob = jax.lax.pmax(m, axis)                            # (B, H)
    m_safe = jnp.where(m_glob <= NEG_INF / 2, 0.0, m_glob)
    w = l * jnp.exp(m - m_safe)                               # 0 when masked
    l_glob = jax.lax.psum(w, axis)
    o_glob = jax.lax.psum(o.astype(jnp.float32) * w[..., None], axis)
    return o_glob / jnp.maximum(l_glob, 1e-20)[..., None]


def sharded_decode_attend(p, x, t, cache: KVCache, cfg, mesh, *, axis="model",
                          interpret=None):
    """One-token decode with the cache's W dim sharded over ``axis``.

    x: (B,1,d); cache.k/v: (B,W,KV,hd) sharded P(None, axis, None, None);
    cache.pos: (W,) sharded P(axis). Returns (y: (B,1,d), new cache).
    Each shard runs the split-K flash-decode kernel on its local slots;
    the bias row comes from ``decode_bias`` on the local slot positions, so
    sharded and unsharded decode share one mask definition.
    """
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B = x.shape[0]
    W = cache.window
    n_shards = mesh.shape[axis]
    assert W % n_shards == 0, (W, n_shards)

    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    pos_t = jnp.full((1,), t, jnp.int32)
    q = apply_rope(q, pos_t, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, pos_t, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(None, axis, None, None), P(None, axis, None, None), P(axis)),
        out_specs=(P(), P(None, axis, None, None), P(None, axis, None, None), P(axis)),
        check_rep=False,  # pallas_call has no replication rule
    )
    def attend(q, k_new, v_new, k_sh, v_sh, pos_sh):
        # local slot index of the global rolling slot t % W, if it lands here
        Wl = k_sh.shape[1]
        shard_id = jax.lax.axis_index(axis)
        slot_global = jnp.mod(t, W)
        slot_local = slot_global - shard_id * Wl
        mine = jnp.logical_and(slot_local >= 0, slot_local < Wl)
        sl = jnp.clip(slot_local, 0, Wl - 1)
        k_upd = jax.lax.dynamic_update_slice_in_dim(k_sh, k_new, sl, axis=1)
        v_upd = jax.lax.dynamic_update_slice_in_dim(v_sh, v_new, sl, axis=1)
        pos_upd = jax.lax.dynamic_update_slice_in_dim(pos_sh, pos_t, sl, axis=0)
        k_sh = jnp.where(mine, k_upd, k_sh)
        v_sh = jnp.where(mine, v_upd, v_sh)
        pos_sh = jnp.where(mine, pos_upd, pos_sh)

        bias = kops.decode_bias(pos_sh, t, window=cfg.sliding_window)
        o, m, l = kops.flash_decode(q[:, 0], k_sh, v_sh, bias,
                                    interpret=interpret, return_stats=True)
        out = combine_shard_stats(o, m, l, axis)
        return out.reshape(B, 1, H * hd).astype(q.dtype), k_sh, v_sh, pos_sh

    out, new_k, new_v, new_pos = attend(q, k, v, cache.k, cache.v, cache.pos)
    y = dense(p["wo"], out)
    return y, KVCache(new_k, new_v, new_pos)
