"""GQA attention: training/prefill (full-sequence) and single-token decode
against a rolling KV cache (bounded by the sliding window when configured).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense, dense_init

NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, H * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(k2, d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(k3, d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(k4, H * hd, d, dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _sdpa(q, k, v, mask):
    """q:(B,S,H,hd) k,v:(B,T,KV,hd) mask:(B|1,1,S,T) -> (B,S,H,hd).

    GQA: H queries share H/KV kv-heads; computed grouped to avoid
    materializing repeated K/V.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    # Keep K/V in their storage dtype (casting a 32k-deep decode cache to f32
    # would double-materialize it in HBM); accumulate the contractions in f32.
    logits = jnp.einsum(
        "bskgh,btkh->bkgst", qg, k, preferred_element_type=jnp.float32
    )
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    logits = logits + jnp.where(mask[:, :, None], 0.0, NEG_INF)  # mask:(B|1,1|KV,S,T)->(.. ,1,S,T)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgst,btkh->bskgh", w.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.reshape(B, S, H, hd).astype(q.dtype)


def causal_mask(S, T=None, *, window: Optional[int] = None, offset: int = 0):
    """(1, 1, S, T) boolean; query i attends keys j with j ≤ i+offset and
    (no window) or j > i+offset-window."""
    T = T if T is not None else S
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window is not None:
        m = jnp.logical_and(m, kj > qi - window)
    return m[None, None]


def attend_full(p, x, positions, cfg, *, mask=None, cross_kv=None):
    """Training/prefill attention. x:(B,S,d). Returns (B,S,d).

    ``cross_kv=(k_src, v_src)`` turns this into cross-attention (no mask,
    no RoPE on source side — whisper style).

    Under ``cfg.use_flash_attention`` every path runs the fully
    differentiable Pallas flash kernel (kernels.ops.flash_attention —
    forward, backward, and JVP passes, so gradients, line searches and
    every curvature product avoid the O(S²) logits): the default
    causal(/sliding-window) self-attention directly; cross-attention with
    its mismatched q/kv lengths via the kernels' pad-and-mask treatment;
    explicit (head-broadcast) masks as an additive f32 logit bias operand.
    Only per-kv-head masks (mask.shape[1] > 1, which no model config emits)
    keep the jnp ``_sdpa`` — otherwise ``_sdpa`` is the parity oracle only.
    """
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B, S, _ = x.shape
    q = _split_heads(dense(p["wq"], x), H, hd)
    if cross_kv is None:
        k = _split_heads(dense(p["wk"], x), KV, hd)
        v = _split_heads(dense(p["wv"], x), KV, hd)
        q = apply_rope(q, positions, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        k = apply_rope(k, positions, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        if cfg.use_flash_attention and mask is None:
            from ..kernels import ops as kops

            out = kops.flash_attention(q, k, v, causal=True,
                                       window=cfg.sliding_window)
            return dense(p["wo"], out.reshape(B, S, H * hd))
        if mask is None:
            mask = causal_mask(S, window=cfg.sliding_window)
    else:
        k, v = cross_kv
        if cfg.use_flash_attention and mask is None:
            from ..kernels import ops as kops

            out = kops.flash_attention(q, k, v, causal=False, window=None)
            return dense(p["wo"], out.reshape(B, S, H * hd))
        if mask is None:
            mask = jnp.ones((1, 1, S, k.shape[1]), bool)
    if cfg.use_flash_attention and mask.shape[1] == 1:
        from ..kernels import ops as kops

        bias = jnp.where(mask[:, 0], 0.0, NEG_INF).astype(jnp.float32)
        out = kops.flash_attention(q, k, v, causal=False, window=None,
                                   bias=bias)
        return dense(p["wo"], out.reshape(B, S, H * hd))
    out = _sdpa(q, k, v, mask)
    return dense(p["wo"], out.reshape(B, S, H * hd))


def encoder_attend(p, x, cfg):
    """Bidirectional self-attention (whisper encoder): no mask, no RoPE.
    Runs the non-causal flash kernel under ``cfg.use_flash_attention``."""
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B, S, _ = x.shape
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    if cfg.use_flash_attention:
        from ..kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=False, window=None)
    else:
        out = _sdpa(q, k, v, jnp.ones((1, 1, S, S), bool))
    return dense(p["wo"], out.reshape(B, S, H * hd))


# ------------------------------------------------------------- KV cache ----
class KVCache(NamedTuple):
    k: jax.Array        # (B, W, KV, hd) — rolling window buffer
    v: jax.Array        # (B, W, KV, hd)
    pos: jax.Array      # (W,) absolute position stored in each slot (-1 empty)

    @property
    def window(self):
        return self.k.shape[1]


def init_kv_cache(cfg, batch, max_len, dtype, *, ragged=False) -> KVCache:
    """Dense rolling cache. ``ragged=True`` gives per-sequence slot
    positions pos: (B, W) — the continuous-batching layout where every
    batch slot sits at its own decode position (decode_attend_ragged)."""
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    pos_shape = (batch, W) if ragged else (W,)
    return KVCache(
        k=jnp.zeros((batch, W, KV, hd), dtype),
        v=jnp.zeros((batch, W, KV, hd), dtype),
        pos=jnp.full(pos_shape, -1, jnp.int32),
    )


def attend_full_with_cache(p, x, positions, cfg, max_len, dtype=None):
    """Prefill: full-sequence causal attention that also returns the KV cache
    (rolling layout: absolute position p lives in slot p % W). Uses the
    Pallas flash-attention kernel when ``cfg.use_flash_attention``;
    non-block-aligned sequences are padded, tail-masked and sliced inside
    the kernel wrapper (kernels/flash_ad.py), so there is no alignment
    gate."""
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B, S, _ = x.shape
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    q = apply_rope(q, positions, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, positions, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    if cfg.use_flash_attention:
        from ..kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        mask = causal_mask(S, window=cfg.sliding_window)
        out = _sdpa(q, k, v, mask)
    y = dense(p["wo"], out.reshape(B, S, H * hd))

    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    keep = min(S, W)
    pos_kept = positions[S - keep:]
    slots = jnp.mod(pos_kept, W)
    cache = KVCache(
        k=jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - keep:]),
        v=jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - keep:]),
        pos=jnp.full((W,), -1, jnp.int32).at[slots].set(pos_kept),
    )
    return y, cache


def decode_attend(p, x, t, cache: KVCache, cfg):
    """One-token decode. x:(B,1,d); t: scalar absolute position of this token.

    Writes (k,v) for position t into slot t % W and attends over every valid
    slot (absolute position in (t-window, t]). Under
    ``cfg.use_flash_attention`` the attend runs the split-K flash-decode
    Pallas kernel (kernels/flash_decode.py) — rolling-slot validity and the
    sliding window enter as an additive (1, W) bias row (``decode_bias``),
    so the kernel never materializes the (B, H, W) logits.
    """
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B = x.shape[0]
    W = cache.window
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    pos_t = jnp.full((1,), t, jnp.int32)
    q = apply_rope(q, pos_t, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, pos_t, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    slot = jnp.mod(t, W)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    new_pos = jax.lax.dynamic_update_slice_in_dim(cache.pos, pos_t, slot, axis=0)
    if cfg.use_flash_attention:
        from ..kernels import ops as kops

        bias = kops.decode_bias(new_pos, t, window=cfg.sliding_window)
        out = kops.flash_decode(q[:, 0], new_k, new_v, bias)[:, None]
    else:
        valid = jnp.logical_and(new_pos >= 0, new_pos <= t)
        if cfg.sliding_window:
            valid = jnp.logical_and(valid, new_pos > t - cfg.sliding_window)
        mask = valid[None, None, None, :]                  # (1,1,1,W)
        out = _sdpa(q, new_k, new_v, mask)
    y = dense(p["wo"], out.reshape(B, 1, H * hd))
    return y, KVCache(new_k, new_v, new_pos)


def decode_attend_ragged(p, x, t, cache: KVCache, cfg, *, active=None):
    """Per-slot decode (continuous batching). x:(B,1,d); t:(B,) absolute
    position of each slot's current token; cache.pos:(B,W) (init_kv_cache
    ragged=True layout).

    Every batch slot advances independently: slot b writes its (k,v) at
    cache position t[b] % W and attends its own validity row. ``active``
    (B,) bool marks live slots — inactive slots leave their cache rows
    untouched and produce a fully-masked (zero) attend, so a freed slot can
    hold garbage while waiting for the next admitted request.
    """
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B = x.shape[0]
    W = cache.window
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    pos_bt = t[:, None].astype(jnp.int32)                  # (B, 1)
    q = apply_rope(q, pos_bt, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, pos_bt, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    if active is None:
        active = jnp.ones((B,), bool)
    slot = jnp.mod(t, W)
    ar = jnp.arange(B)
    # Scatter each slot's row; inactive slots re-write their old value.
    new_k = cache.k.at[ar, slot].set(
        jnp.where(active[:, None, None], k[:, 0], cache.k[ar, slot]))
    new_v = cache.v.at[ar, slot].set(
        jnp.where(active[:, None, None], v[:, 0], cache.v[ar, slot]))
    new_pos = cache.pos.at[ar, slot].set(
        jnp.where(active, t.astype(jnp.int32), cache.pos[ar, slot]))
    from ..kernels import ops as kops

    bias = kops.decode_bias(new_pos, t, window=cfg.sliding_window)  # (B, W)
    bias = jnp.where(active[:, None], bias, NEG_INF)
    if cfg.use_flash_attention:
        out = kops.flash_decode(q[:, 0], new_k, new_v, bias)[:, None]
    else:
        out = _sdpa(q, new_k, new_v, (bias == 0.0)[:, None, None, :])
    y = dense(p["wo"], out.reshape(B, 1, H * hd))
    return y, KVCache(new_k, new_v, new_pos)


def decode_cross_attend(p, x, cross_kv, cfg):
    """Decode-time cross attention against fixed encoder K/V. Flash-decode
    kernel under ``cfg.use_flash_attention`` (all source positions valid —
    zero bias row)."""
    hd, H = cfg.resolved_head_dim, cfg.n_heads
    B = x.shape[0]
    q = _split_heads(dense(p["wq"], x), H, hd)
    k, v = cross_kv
    if cfg.use_flash_attention:
        from ..kernels import ops as kops

        bias = jnp.zeros((1, k.shape[1]), jnp.float32)
        out = kops.flash_decode(q[:, 0], k, v, bias)[:, None]
    else:
        mask = jnp.ones((1, 1, 1, k.shape[1]), bool)
        out = _sdpa(q, k, v, mask)
    return dense(p["wo"], out.reshape(B, 1, H * hd))
