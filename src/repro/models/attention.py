"""GQA attention: training/prefill (full-sequence) and single-token decode
against a rolling KV cache (bounded by the sliding window when configured).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense, dense_init

NEG_INF = -1e30


def attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, H * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(k2, d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(k3, d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(k4, H * hd, d, dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _sdpa(q, k, v, mask):
    """q:(B,S,H,hd) k,v:(B,T,KV,hd) mask:(B|1,1,S,T) -> (B,S,H,hd).

    GQA: H queries share H/KV kv-heads; computed grouped to avoid
    materializing repeated K/V.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    # Keep K/V in their storage dtype (casting a 32k-deep decode cache to f32
    # would double-materialize it in HBM); accumulate the contractions in f32.
    logits = jnp.einsum(
        "bskgh,btkh->bkgst", qg, k, preferred_element_type=jnp.float32
    )
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    logits = logits + jnp.where(mask[:, :, None], 0.0, NEG_INF)  # mask:(B|1,1|KV,S,T)->(.. ,1,S,T)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgst,btkh->bskgh", w.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.reshape(B, S, H, hd).astype(q.dtype)


def causal_mask(S, T=None, *, window: Optional[int] = None, offset: int = 0):
    """(1, 1, S, T) boolean; query i attends keys j with j ≤ i+offset and
    (no window) or j > i+offset-window."""
    T = T if T is not None else S
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window is not None:
        m = jnp.logical_and(m, kj > qi - window)
    return m[None, None]


def attend_full(p, x, positions, cfg, *, mask=None, cross_kv=None):
    """Training/prefill attention. x:(B,S,d). Returns (B,S,d).

    ``cross_kv=(k_src, v_src)`` turns this into cross-attention (no mask,
    no RoPE on source side — whisper style).

    Under ``cfg.use_flash_attention`` the default causal(/sliding-window)
    self-attention runs the fully differentiable Pallas flash kernel
    (kernels.ops.flash_attention — forward, backward, and JVP passes, so
    gradients, line searches and every curvature product avoid the O(S²)
    logits). Explicit masks and cross-attention keep ``_sdpa`` (the kernel
    covers causal/window/valid-length masks only; cross-attention has
    mismatched q/kv lengths).
    """
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B, S, _ = x.shape
    q = _split_heads(dense(p["wq"], x), H, hd)
    if cross_kv is None:
        k = _split_heads(dense(p["wk"], x), KV, hd)
        v = _split_heads(dense(p["wv"], x), KV, hd)
        q = apply_rope(q, positions, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        k = apply_rope(k, positions, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        if cfg.use_flash_attention and mask is None:
            from ..kernels import ops as kops

            out = kops.flash_attention(q, k, v, causal=True,
                                       window=cfg.sliding_window)
            return dense(p["wo"], out.reshape(B, S, H * hd))
        if mask is None:
            mask = causal_mask(S, window=cfg.sliding_window)
    else:
        k, v = cross_kv
        if mask is None:
            mask = jnp.ones((1, 1, S, k.shape[1]), bool)
    out = _sdpa(q, k, v, mask)
    return dense(p["wo"], out.reshape(B, S, H * hd))


def encoder_attend(p, x, cfg):
    """Bidirectional self-attention (whisper encoder): no mask, no RoPE.
    Runs the non-causal flash kernel under ``cfg.use_flash_attention``."""
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B, S, _ = x.shape
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    if cfg.use_flash_attention:
        from ..kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=False, window=None)
    else:
        out = _sdpa(q, k, v, jnp.ones((1, 1, S, S), bool))
    return dense(p["wo"], out.reshape(B, S, H * hd))


# ------------------------------------------------------------- KV cache ----
class KVCache(NamedTuple):
    k: jax.Array        # (B, W, KV, hd) — rolling window buffer
    v: jax.Array        # (B, W, KV, hd)
    pos: jax.Array      # (W,) absolute position stored in each slot (-1 empty)

    @property
    def window(self):
        return self.k.shape[1]


def init_kv_cache(cfg, batch, max_len, dtype) -> KVCache:
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, W, KV, hd), dtype),
        v=jnp.zeros((batch, W, KV, hd), dtype),
        pos=jnp.full((W,), -1, jnp.int32),
    )


def attend_full_with_cache(p, x, positions, cfg, max_len, dtype=None):
    """Prefill: full-sequence causal attention that also returns the KV cache
    (rolling layout: absolute position p lives in slot p % W). Uses the
    Pallas flash-attention kernel when ``cfg.use_flash_attention``;
    non-block-aligned sequences are padded, tail-masked and sliced inside
    the kernel wrapper (kernels/flash_ad.py), so there is no alignment
    gate."""
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B, S, _ = x.shape
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    q = apply_rope(q, positions, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, positions, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    if cfg.use_flash_attention:
        from ..kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        mask = causal_mask(S, window=cfg.sliding_window)
        out = _sdpa(q, k, v, mask)
    y = dense(p["wo"], out.reshape(B, S, H * hd))

    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    keep = min(S, W)
    pos_kept = positions[S - keep:]
    slots = jnp.mod(pos_kept, W)
    cache = KVCache(
        k=jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - keep:]),
        v=jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - keep:]),
        pos=jnp.full((W,), -1, jnp.int32).at[slots].set(pos_kept),
    )
    return y, cache


def decode_attend(p, x, t, cache: KVCache, cfg):
    """One-token decode. x:(B,1,d); t: scalar absolute position of this token.

    Writes (k,v) for position t into slot t % W and attends over every valid
    slot (absolute position in (t-window, t]).
    """
    hd, H, KV = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    B = x.shape[0]
    W = cache.window
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    pos_t = jnp.full((1,), t, jnp.int32)
    q = apply_rope(q, pos_t, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, pos_t, rope_fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    slot = jnp.mod(t, W)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
    new_pos = jax.lax.dynamic_update_slice_in_dim(cache.pos, pos_t, slot, axis=0)
    valid = jnp.logical_and(new_pos >= 0, new_pos <= t)
    if cfg.sliding_window:
        valid = jnp.logical_and(valid, new_pos > t - cfg.sliding_window)
    mask = valid[None, None, None, :]                      # (1,1,1,W)
    out = _sdpa(q, new_k, new_v, mask)
    y = dense(p["wo"], out.reshape(B, 1, H * hd))
    return y, KVCache(new_k, new_v, new_pos)


def decode_cross_attend(p, x, cross_kv, cfg):
    """Decode-time cross attention against fixed encoder K/V."""
    hd, H = cfg.resolved_head_dim, cfg.n_heads
    B = x.shape[0]
    q = _split_heads(dense(p["wq"], x), H, hd)
    k, v = cross_kv
    mask = jnp.ones((1, 1, 1, k.shape[1]), bool)
    out = _sdpa(q, k, v, mask)
    return dense(p["wo"], out.reshape(B, 1, H * hd))
