"""Model substrate: scanned-block transformers for all assigned families,
plus the paper's own MLP classifiers."""
from .transformer import ModelApi, build_model, build_encdec_model
from .mlp import MLPApi, build_mlp

__all__ = ["ModelApi", "build_model", "build_encdec_model", "MLPApi", "build_mlp"]
