"""Generic scanned-block transformer covering all assigned families.

Stacks are built as *stacked pytrees* (leading dim = number of repeating
units) and executed with ``lax.scan`` — essential for compile time at 512
devices with 24-81 layers. A config's ``block_pattern`` names the repeating
unit (("attn",) dense, ("moe",) MoE, ("mamba",) SSM, ("slstm","mlstm")
xLSTM); the zamba2 hybrid (mamba backbone + one weight-*shared* attention
block every ``attn_every`` layers) and the whisper encoder-decoder get their
own stack layouts.

Three execution paths per model, all pure functions:
  * full-sequence (train loss / logits — twice differentiable for HF),
  * prefill (full sequence + returns decode caches),
  * decode_step (one token against the caches).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import xlstm as xl
from .attention import (
    KVCache,
    attend_full,
    attend_full_with_cache,
    causal_mask,
    decode_attend,
    decode_attend_ragged,
    decode_cross_attend,
    encoder_attend,
    init_kv_cache,
    _sdpa,
    _split_heads,
)
from .layers import (
    apply_mlp,
    apply_norm,
    dense,
    dense_init,
    dtype_of,
    embed,
    embedding_init,
    mlp_init,
    norm_init,
    unembed,
)
from .moe import apply_moe, moe_init
from .ssm import (
    MambaCache,
    apply_mamba,
    init_mamba_cache,
    mamba_decode_step,
    mamba_init,
)


class ModelApi(NamedTuple):
    config: Any
    init: Callable
    loss_fn: Callable            # (params, batch) -> scalar  (twice differentiable)
    logits_fn: Callable          # (params, batch) -> (B, S, V)   [GN split: network]
    out_loss_fn: Callable        # (logits, batch) -> scalar      [GN split: loss]
    prefill: Callable            # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable        # (params, token(B,1), t, cache) -> (logits, cache)
    init_cache: Callable         # (batch_size, max_len) -> cache
    # Continuous-batching / paged serving (dense-decoder stacks only; None
    # elsewhere). Ragged: every batch slot sits at its own decode position.
    decode_step_ragged: Optional[Callable] = None
    # (params, token(B,1), t(B,), cache, active(B,)) -> (logits, cache)
    init_cache_ragged: Optional[Callable] = None
    # (batch_size, max_len) -> cache with per-slot pos rows
    decode_step_paged: Optional[Callable] = None
    # (params, token(B,1), paged_cache, active(B,)) -> (logits, paged_cache)
    init_cache_paged: Optional[Callable] = None
    # (batch_size, max_len, n_pages, page_size) -> PagedKVCache
    prefill_paged: Optional[Callable] = None
    # (params, batch(1 prompt), paged_cache, slot) -> (logits, paged_cache)


# ------------------------------------------------------------------ units --
def _unit_init(key, cfg, dtype):
    parts = {}
    keys = jax.random.split(key, len(cfg.block_pattern))
    d = cfg.d_model
    for j, kind in enumerate(cfg.block_pattern):
        k = keys[j]
        name = f"b{j}_{kind}"
        if kind == "attn":
            k1, k2 = jax.random.split(k)
            from .attention import attn_init
            parts[name] = {
                "norm1": norm_init(d, dtype, cfg.norm_kind),
                "attn": attn_init(k1, cfg, dtype),
                "norm2": norm_init(d, dtype, cfg.norm_kind),
                "mlp": mlp_init(k2, d, cfg.d_ff, dtype, cfg.mlp_act),
            }
        elif kind == "moe":
            k1, k2 = jax.random.split(k)
            from .attention import attn_init
            parts[name] = {
                "norm1": norm_init(d, dtype, cfg.norm_kind),
                "attn": attn_init(k1, cfg, dtype),
                "norm2": norm_init(d, dtype, cfg.norm_kind),
                "moe": moe_init(k2, cfg, dtype),
            }
        elif kind == "mamba":
            parts[name] = {
                "norm": norm_init(d, dtype, cfg.norm_kind),
                "mamba": mamba_init(k, cfg, dtype),
            }
        elif kind == "mlstm":
            parts[name] = {
                "norm": norm_init(d, dtype, cfg.norm_kind),
                "mlstm": xl.mlstm_init(k, cfg, dtype),
            }
        elif kind == "slstm":
            parts[name] = {
                "norm": norm_init(d, dtype, cfg.norm_kind),
                "slstm": xl.slstm_init(k, cfg, dtype),
            }
    return parts


def _unit_apply(unit, x, positions, cfg, *, produce_cache=False, max_len=None):
    """Full-sequence unit. Returns (x, aux, caches-dict)."""
    aux = jnp.zeros((), jnp.float32)
    caches = {}
    for j, kind in enumerate(cfg.block_pattern):
        name = f"b{j}_{kind}"
        p = unit[name]
        if kind in ("attn", "moe"):
            h = apply_norm(p["norm1"], x, cfg.norm_eps)
            if produce_cache:
                a, kv = attend_full_with_cache(p["attn"], h, positions, cfg, max_len)
                caches[name] = kv
            else:
                a = attend_full(p["attn"], h, positions, cfg)
            x = x + a
            h = apply_norm(p["norm2"], x, cfg.norm_eps)
            if kind == "attn":
                x = x + apply_mlp(p["mlp"], h)
            else:
                mo, a_loss = apply_moe(p["moe"], h, cfg)
                x = x + mo
                aux = aux + a_loss
        elif kind == "mamba":
            h = apply_norm(p["norm"], x, cfg.norm_eps)
            y, c = apply_mamba(p["mamba"], h, cfg)
            x = x + y
            if produce_cache:
                caches[name] = c
        elif kind == "mlstm":
            h = apply_norm(p["norm"], x, cfg.norm_eps)
            y, c = xl.apply_mlstm(p["mlstm"], h, cfg)
            x = x + y
            if produce_cache:
                caches[name] = c
        elif kind == "slstm":
            h = apply_norm(p["norm"], x, cfg.norm_eps)
            y, c = xl.apply_slstm(p["slstm"], h, cfg)
            x = x + y
            if produce_cache:
                caches[name] = c
    return x, aux, caches


def _unit_decode(unit, x, t, caches, cfg):
    new_caches = {}
    for j, kind in enumerate(cfg.block_pattern):
        name = f"b{j}_{kind}"
        p = unit[name]
        c = caches[name]
        if kind in ("attn", "moe"):
            h = apply_norm(p["norm1"], x, cfg.norm_eps)
            a, new_caches[name] = decode_attend(p["attn"], h, t, c, cfg)
            x = x + a
            h = apply_norm(p["norm2"], x, cfg.norm_eps)
            if kind == "attn":
                x = x + apply_mlp(p["mlp"], h)
            else:
                mo, _ = apply_moe(p["moe"], h, cfg)
                x = x + mo
        elif kind == "mamba":
            h = apply_norm(p["norm"], x, cfg.norm_eps)
            y, new_caches[name] = mamba_decode_step(p["mamba"], h, c, cfg)
            x = x + y
        elif kind == "mlstm":
            h = apply_norm(p["norm"], x, cfg.norm_eps)
            y, new_caches[name] = xl.mlstm_decode_step(p["mlstm"], h, c, cfg)
            x = x + y
        elif kind == "slstm":
            h = apply_norm(p["norm"], x, cfg.norm_eps)
            y, new_caches[name] = xl.slstm_decode_step(p["slstm"], h, c, cfg)
            x = x + y
    return x, new_caches


def _unit_decode_ragged(unit, x, t, caches, cfg, active):
    """Per-slot decode of one unit (attn/moe blocks only — the continuous
    batching path is gated to dense-decoder stacks)."""
    new_caches = {}
    for j, kind in enumerate(cfg.block_pattern):
        name = f"b{j}_{kind}"
        p = unit[name]
        c = caches[name]
        h = apply_norm(p["norm1"], x, cfg.norm_eps)
        a, new_caches[name] = decode_attend_ragged(p["attn"], h, t, c, cfg,
                                                   active=active)
        x = x + a
        h = apply_norm(p["norm2"], x, cfg.norm_eps)
        if kind == "attn":
            x = x + apply_mlp(p["mlp"], h)
        else:
            mo, _ = apply_moe(p["moe"], h, cfg)
            x = x + mo
    return x, new_caches


def _unit_cache_zeros(cfg, batch, max_len, dtype, *, ragged=False):
    caches = {}
    for j, kind in enumerate(cfg.block_pattern):
        name = f"b{j}_{kind}"
        if kind in ("attn", "moe"):
            caches[name] = init_kv_cache(cfg, batch, max_len, dtype,
                                         ragged=ragged)
        elif kind == "mamba":
            caches[name] = init_mamba_cache(cfg, batch, dtype)
        elif kind == "mlstm":
            caches[name] = xl.init_mlstm_cache(cfg, batch)
        elif kind == "slstm":
            caches[name] = xl.init_slstm_cache(cfg, batch)
    return caches


def _stack(tree, n):
    """Replicate a cache pytree along a new leading (layer) dim."""
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), tree
    )


# ----------------------------------------------------- shared attn (zamba) --
def _shared_attn_init(key, cfg, dtype):
    from .attention import attn_init
    k1, k2 = jax.random.split(key)
    return {
        "norm1": norm_init(cfg.d_model, dtype, cfg.norm_kind),
        "attn": attn_init(k1, cfg, dtype),
        "norm2": norm_init(cfg.d_model, dtype, cfg.norm_kind),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_act),
    }


def _shared_attn_apply(p, x, positions, cfg, *, produce_cache=False, max_len=None):
    h = apply_norm(p["norm1"], x, cfg.norm_eps)
    if produce_cache:
        a, kv = attend_full_with_cache(p["attn"], h, positions, cfg, max_len)
    else:
        a, kv = attend_full(p["attn"], h, positions, cfg), None
    x = x + a
    x = x + apply_mlp(p["mlp"], apply_norm(p["norm2"], x, cfg.norm_eps))
    return x, kv


def _shared_attn_decode(p, x, t, kv, cfg):
    h = apply_norm(p["norm1"], x, cfg.norm_eps)
    a, kv = decode_attend(p["attn"], h, t, kv, cfg)
    x = x + a
    x = x + apply_mlp(p["mlp"], apply_norm(p["norm2"], x, cfg.norm_eps))
    return x, kv


def hybrid_layout(cfg):
    """(n_groups, per_group, n_tail) for the zamba stack."""
    k = cfg.attn_every
    G = cfg.n_layers // k
    return G, k, cfg.n_layers - G * k


# -------------------------------------------------------------- backbones --
def _make_remat(fn, enabled):
    return jax.checkpoint(fn) if enabled else fn


def _decoder_backbone(params, x, positions, cfg, remat):
    def body(carry, unit):
        xx, aux = carry
        xx, a, _ = _unit_apply(unit, xx, positions, cfg)
        return (xx, aux + a), None

    (x, aux), _ = jax.lax.scan(_make_remat(body, remat), (x, jnp.zeros((), jnp.float32)), params["blocks"])
    return x, aux


def _decoder_backbone_prefill(params, x, positions, cfg, max_len):
    def body(carry, unit):
        xx, aux = carry
        xx, a, c = _unit_apply(unit, xx, positions, cfg, produce_cache=True, max_len=max_len)
        return (xx, aux + a), c

    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    return x, aux, caches


def _decoder_backbone_decode(params, x, t, caches, cfg):
    def body(xx, xs):
        unit, c = xs
        xx, nc = _unit_decode(unit, xx, t, c, cfg)
        return xx, nc

    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    return x, new_caches


def _hybrid_backbone(params, x, positions, cfg, remat, *, produce_cache=False, max_len=None):
    G, k, R = hybrid_layout(cfg)
    shared = params["shared"]

    def inner(xx, unit):
        xx, _, c = _unit_apply(unit, xx, positions, cfg, produce_cache=produce_cache, max_len=max_len)
        return xx, c

    def outer(xx, group):
        xx, mc = jax.lax.scan(inner, xx, group)
        xx, kv = _shared_attn_apply(shared, xx, positions, cfg, produce_cache=produce_cache, max_len=max_len)
        return xx, (mc, kv)

    x, (mamba_caches, attn_caches) = jax.lax.scan(_make_remat(outer, remat), x, params["groups"])
    tail_caches = None
    if R:
        x, tail_caches = jax.lax.scan(inner, x, params["tail"])
    caches = {"groups_mamba": mamba_caches, "groups_attn": attn_caches, "tail": tail_caches}
    return x, (caches if produce_cache else None)


def _hybrid_decode(params, x, t, caches, cfg):
    shared = params["shared"]

    def inner(xx, xs):
        unit, c = xs
        xx, nc = _unit_decode(unit, xx, t, c, cfg)
        return xx, nc

    def outer(xx, xs):
        group, mc, kv = xs
        xx, nmc = jax.lax.scan(inner, xx, (group, mc))
        xx, nkv = _shared_attn_decode(shared, xx, t, kv, cfg)
        return xx, (nmc, nkv)

    x, (nmc, nkv) = jax.lax.scan(
        outer, x, (params["groups"], caches["groups_mamba"], caches["groups_attn"])
    )
    ntail = None
    if caches["tail"] is not None:
        x, ntail = jax.lax.scan(inner, x, (params["tail"], caches["tail"]))
    return x, {"groups_mamba": nmc, "groups_attn": nkv, "tail": ntail}


# ------------------------------------------------------------ build model --
def build_model(cfg, *, remat: bool = False) -> ModelApi:
    if cfg.is_encoder_decoder:
        return build_encdec_model(cfg, remat=remat)
    dtype = dtype_of(cfg)
    V = cfg.padded_vocab
    n_units = cfg.n_layers // len(cfg.block_pattern)
    is_hybrid = cfg.family == "hybrid" and cfg.attn_every > 0

    def init(key):
        kE, kB, kS, kH, kV = jax.random.split(key, 5)
        params = {
            "embed": embedding_init(kE, V, cfg.d_model, dtype),
            "final_norm": norm_init(cfg.d_model, dtype, cfg.norm_kind),
        }
        if is_hybrid:
            G, k, R = hybrid_layout(cfg)
            kg, kt = jax.random.split(kB)
            params["groups"] = jax.vmap(
                lambda ks: jax.vmap(lambda k2: _unit_init(k2, cfg, dtype))(ks)
            )(jax.random.split(kg, G * k).reshape(G, k, 2))
            if R:
                params["tail"] = jax.vmap(lambda k2: _unit_init(k2, cfg, dtype))(
                    jax.random.split(kt, R)
                )
            params["shared"] = _shared_attn_init(kS, cfg, dtype)
        else:
            params["blocks"] = jax.vmap(lambda k2: _unit_init(k2, cfg, dtype))(
                jax.random.split(kB, n_units)
            )
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(kH, cfg.d_model, V, dtype)
        if cfg.family == "vlm":
            params["vision_proj"] = dense_init(kV, cfg.vision_dim, cfg.d_model, dtype)
        return params

    def embed_inputs(params, batch):
        x = embed(params["embed"], batch["tokens"])
        if cfg.family == "vlm":
            vis = dense(params["vision_proj"], batch["vision_embed"].astype(dtype))
            x = jnp.concatenate([vis, x], axis=1)
        return x

    def head(params, x):
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            return unembed(params["embed"], x)
        return dense(params["lm_head"], x).astype(jnp.float32)

    def backbone(params, x, positions):
        if is_hybrid:
            x, _ = _hybrid_backbone(params, x, positions, cfg, remat)
            return x, jnp.zeros((), jnp.float32)
        return _decoder_backbone(params, x, positions, cfg, remat)

    def logits_fn(params, batch):
        x = embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        x, _ = backbone(params, x, positions)
        logits = head(params, x)
        if cfg.family == "vlm":
            logits = logits[:, batch["vision_embed"].shape[1]:]
        return logits

    def aux_fn(params, batch):
        x = embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        _, aux = backbone(params, x, positions)
        return aux

    def out_loss_fn(logits, batch):
        return _ce_loss(logits, batch)

    def loss_fn(params, batch):
        x = embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        x, aux = backbone(params, x, positions)
        if cfg.ce_chunk:
            x = apply_norm(params["final_norm"], x, cfg.norm_eps)
            if cfg.family == "vlm":
                x = x[:, batch["vision_embed"].shape[1]:]
            mask = batch.get("loss_mask")
            if mask is None:
                mask = jnp.ones(batch["targets"].shape, jnp.float32)
            w = params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]["w"]
            ce = _chunked_ce(x, w, batch["targets"], mask, cfg.ce_chunk,
                             vocab_major=cfg.tie_embeddings)
            return ce + cfg.router_aux_weight * aux
        logits = head(params, x)
        if cfg.family == "vlm":
            logits = logits[:, batch["vision_embed"].shape[1]:]
        return _ce_loss(logits, batch) + cfg.router_aux_weight * aux

    def init_cache(batch_size, max_len):
        if is_hybrid:
            G, k, R = hybrid_layout(cfg)
            unit = _unit_cache_zeros(cfg, batch_size, max_len, dtype)
            attn_unit = _unit_cache_zeros(
                cfg.replace(block_pattern=("attn",)), batch_size, max_len, dtype
            )["b0_attn"]
            return {
                "groups_mamba": _stack(_stack(unit, k), G),
                "groups_attn": _stack(attn_unit, G),
                "tail": _stack(unit, R) if R else None,
            }
        unit = _unit_cache_zeros(cfg, batch_size, max_len, dtype)
        return _stack(unit, n_units)

    def prefill(params, batch, max_len):
        x = embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        if is_hybrid:
            x, caches = _hybrid_backbone(
                params, x, positions, cfg, remat, produce_cache=True, max_len=max_len
            )
        else:
            x, _, caches = _decoder_backbone_prefill(params, x, positions, cfg, max_len)
        logits = head(params, x[:, -1:])
        return logits, caches

    def decode_step(params, token, t, caches):
        x = embed(params["embed"], token)
        if is_hybrid:
            x, new_caches = _hybrid_decode(params, x, t, caches, cfg)
        else:
            x, new_caches = _decoder_backbone_decode(params, x, t, caches, cfg)
        return head(params, x), new_caches

    # -------------------------- continuous-batching / paged serving paths --
    # Gated to plain decoder stacks (one attn/moe block per scanned unit,
    # no vision prefix): per-slot decode positions and the shared page pool
    # only make sense where every layer's cache is a KVCache.
    supports_serving = (
        not is_hybrid
        and cfg.family != "vlm"
        and len(cfg.block_pattern) == 1
        and cfg.block_pattern[0] in ("attn", "moe")
    )
    decode_step_ragged = init_cache_ragged = None
    decode_step_paged = init_cache_paged = prefill_paged = None
    if supports_serving:
        from . import kv_paged as kvp

        bname = f"b0_{cfg.block_pattern[0]}"

        def init_cache_ragged(batch_size, max_len):
            unit = _unit_cache_zeros(cfg, batch_size, max_len, dtype,
                                     ragged=True)
            return _stack(unit, n_units)

        def decode_step_ragged(params, token, t, caches, active=None):
            x = embed(params["embed"], token)

            def body(xx, xs):
                unit, c = xs
                xx, nc = _unit_decode_ragged(unit, xx, t, c, cfg, active)
                return xx, nc

            x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
            return head(params, x), new_caches

        def init_cache_paged(batch_size, max_len, n_pages, page_size=128):
            return kvp.init_paged_cache(cfg, n_units, batch_size, max_len,
                                        n_pages, dtype, page_size)

        def prefill_paged(params, batch, cache, slot):
            """Admit one prompt (batch["tokens"]: (1, S)) into ``slot``:
            run the dense prefill, map pages for the slot, and scatter the
            per-layer K/V into the pool in logical order. The slot's table
            row must be unmapped (released). Returns (last-token logits,
            cache)."""
            S = batch["tokens"].shape[1]
            logits, dcaches = prefill(params, batch, S)
            kv = dcaches[bname]                  # k: (L, 1, W, KV, hd)
            W = kv.k.shape[2]
            B = cache.page_table.shape[0]
            admit = jnp.arange(B) == slot
            lengths = jnp.where(admit, S, 0)
            cache = kvp.alloc_prefill(cache, lengths, admit,
                                      window=cfg.sliding_window)
            row = cache.page_table[slot][None]   # (1, max_pages)
            # rolling slot of logical position i is i % W; positions below
            # the live window alias newer ones but land on unmapped logical
            # pages (routed to the null page), so the gather is safe
            idx = jnp.arange(S) % W
            kl, vl = kv.k[:, :, idx], kv.v[:, :, idx]     # (L, 1, S, KV, hd)
            ln = jnp.full((1,), S, jnp.int32)
            kps, vps = jax.vmap(
                lambda kp, vp, k1, v1: kvp.write_prefill_kv(
                    kp, vp, row, k1, v1, ln)
            )(cache.k_pool, cache.v_pool, kl, vl)
            return logits, cache._replace(k_pool=kps, v_pool=vps)

        def decode_step_paged(params, token, cache, active=None):
            if active is None:
                active = jnp.ones((token.shape[0],), bool)
            cache = kvp.alloc_decode_page(cache, active)
            x = embed(params["embed"], token)

            def body(xx, xs):
                unit, kp, vp = xs
                p = unit[bname]
                h = apply_norm(p["norm1"], xx, cfg.norm_eps)
                a, (kp, vp) = kvp.paged_decode_attend(
                    p["attn"], h, (kp, vp), cache.page_table, cache.seq_len,
                    cfg, active=active)
                xx = xx + a
                h = apply_norm(p["norm2"], xx, cfg.norm_eps)
                if cfg.block_pattern[0] == "attn":
                    xx = xx + apply_mlp(p["mlp"], h)
                else:
                    mo, _ = apply_moe(p["moe"], h, cfg)
                    xx = xx + mo
                return xx, (kp, vp)

            x, (kps, vps) = jax.lax.scan(
                body, x, (params["blocks"], cache.k_pool, cache.v_pool))
            cache = cache._replace(k_pool=kps, v_pool=vps)
            cache = kvp.advance_and_free(cache, active,
                                         window=cfg.sliding_window)
            return head(params, x), cache

    return ModelApi(cfg, init, loss_fn, logits_fn, out_loss_fn, prefill,
                    decode_step, init_cache,
                    decode_step_ragged=decode_step_ragged,
                    init_cache_ragged=init_cache_ragged,
                    decode_step_paged=decode_step_paged,
                    init_cache_paged=init_cache_paged,
                    prefill_paged=prefill_paged)


def _ce_loss(logits, batch):
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _chunked_ce(x, w, targets, mask, chunk, *, vocab_major: bool):
    """Cross-entropy without materializing the (B,S,V) logits: scan over
    vocab chunks with an online logsumexp (+ target-logit pick). The chunk
    body is rematerialized, so neither forward nor backward ever holds more
    than (B,S,chunk) activation — the §Perf pair-C optimization for 100k+
    vocabs (full-logit CE dominates HBM traffic in the HF step, where the
    loss is evaluated in the gradient, every HVP and every line-search trial).

    x: (B,S,d) hidden states; w: (V,d) if vocab_major (tied embedding) else
    (d,V) (lm head).
    """
    V = w.shape[0] if vocab_major else w.shape[1]
    assert V % chunk == 0, (V, chunk)
    nc = V // chunk
    xf = x.astype(jnp.float32)
    B, S, _ = x.shape

    @jax.checkpoint
    def body(carry, c):
        m, s, tl = carry
        if vocab_major:
            wc = jax.lax.dynamic_slice_in_dim(w, c * chunk, chunk, axis=0)
            logits = jnp.einsum("bsd,vd->bsv", xf, wc.astype(jnp.float32))
        else:
            wc = jax.lax.dynamic_slice_in_dim(w, c * chunk, chunk, axis=1)
            logits = jnp.einsum("bsd,dv->bsv", xf, wc.astype(jnp.float32))
        mc = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, mc)
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1
        )
        loc = targets - c * chunk
        in_c = jnp.logical_and(loc >= 0, loc < chunk)
        tl_c = jnp.take_along_axis(
            logits, jnp.clip(loc, 0, chunk - 1)[..., None], axis=-1
        )[..., 0]
        tl = jnp.where(in_c, tl_c, tl)
        return (m_new, s, tl), None

    init = (
        jnp.full((B, S), -1e30, jnp.float32),
        jnp.zeros((B, S), jnp.float32),
        jnp.zeros((B, S), jnp.float32),
    )
    (m, s, tl), _ = jax.lax.scan(body, init, jnp.arange(nc))
    nll = jnp.log(s) + m - tl
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ------------------------------------------------------- encoder-decoder ---
def _enc_unit_init(key, cfg, dtype):
    from .attention import attn_init
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "norm1": norm_init(d, dtype, cfg.norm_kind),
        "attn": attn_init(k1, cfg, dtype),
        "norm2": norm_init(d, dtype, cfg.norm_kind),
        "mlp": mlp_init(k2, d, cfg.d_ff, dtype, cfg.mlp_act),
    }


def _dec_unit_init(key, cfg, dtype):
    from .attention import attn_init
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "norm1": norm_init(d, dtype, cfg.norm_kind),
        "self_attn": attn_init(k1, cfg, dtype),
        "norm2": norm_init(d, dtype, cfg.norm_kind),
        "cross_attn": attn_init(k2, cfg, dtype),
        "norm3": norm_init(d, dtype, cfg.norm_kind),
        "mlp": mlp_init(k3, d, cfg.d_ff, dtype, cfg.mlp_act),
    }


def sinusoidal_positions(n, d):
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe


def build_encdec_model(cfg, *, remat: bool = False) -> ModelApi:
    """Whisper-style: bidirectional encoder over (stub) audio-frame embeddings,
    causal decoder with per-layer cross attention. Sinusoidal positions on
    both sides (whisper uses learned decoder positions capped at 448; we use
    sinusoidal so arbitrary dry-run lengths are well-formed — see DESIGN.md)."""
    dtype = dtype_of(cfg)
    V = cfg.padded_vocab

    def init(key):
        kE, kEnc, kDec, kH = jax.random.split(key, 4)
        return {
            "embed": embedding_init(kE, V, cfg.d_model, dtype),
            "enc_blocks": jax.vmap(lambda k: _enc_unit_init(k, cfg, dtype))(
                jax.random.split(kEnc, cfg.n_encoder_layers)
            ),
            "enc_norm": norm_init(cfg.d_model, dtype, cfg.norm_kind),
            "dec_blocks": jax.vmap(lambda k: _dec_unit_init(k, cfg, dtype))(
                jax.random.split(kDec, cfg.n_layers)
            ),
            "final_norm": norm_init(cfg.d_model, dtype, cfg.norm_kind),
            "lm_head": dense_init(kH, cfg.d_model, V, dtype),
        }

    def encode(params, audio_embed):
        x = audio_embed.astype(dtype)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dtype)[None]

        def body(xx, unit):
            h = apply_norm(unit["norm1"], xx, cfg.norm_eps)
            xx = xx + encoder_attend(unit["attn"], h, cfg)
            xx = xx + apply_mlp(unit["mlp"], apply_norm(unit["norm2"], xx, cfg.norm_eps))
            return xx, None

        x, _ = jax.lax.scan(_make_remat(body, remat), x, params["enc_blocks"])
        return apply_norm(params["enc_norm"], x, cfg.norm_eps)

    def _cross_kv(unit, enc_out):
        KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        k = _split_heads(dense(unit["cross_attn"]["wk"], enc_out), KV, hd)
        v = _split_heads(dense(unit["cross_attn"]["wv"], enc_out), KV, hd)
        return k, v

    def decode_seq(params, tokens, enc_out, *, produce_cache=False, max_len=None):
        x = embed(params["embed"], tokens)
        S = x.shape[1]
        x = x + sinusoidal_positions(S, cfg.d_model).astype(dtype)[None]
        positions = jnp.arange(S)

        def body(xx, unit):
            h = apply_norm(unit["norm1"], xx, cfg.norm_eps)
            if produce_cache:
                a, kv = attend_full_with_cache(unit["self_attn"], h, positions, cfg, max_len)
            else:
                a, kv = attend_full(unit["self_attn"], h, positions, cfg), None
            xx = xx + a
            ck, cv = _cross_kv(unit, enc_out)
            h = apply_norm(unit["norm2"], xx, cfg.norm_eps)
            xx = xx + attend_full(unit["cross_attn"], h, positions, cfg, cross_kv=(ck, cv))
            xx = xx + apply_mlp(unit["mlp"], apply_norm(unit["norm3"], xx, cfg.norm_eps))
            return xx, ((kv, ck, cv) if produce_cache else None)

        x, caches = jax.lax.scan(_make_remat(body, remat), x, params["dec_blocks"])
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        return dense(params["lm_head"], x).astype(jnp.float32), caches

    def logits_fn(params, batch):
        enc_out = encode(params, batch["audio_embed"])
        logits, _ = decode_seq(params, batch["tokens"], enc_out)
        return logits

    def loss_fn(params, batch):
        return _ce_loss(logits_fn(params, batch), batch)

    def init_cache(batch_size, max_len):
        KV, hd, F = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_audio_frames
        L = cfg.n_layers
        W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        kv = KVCache(
            k=jnp.zeros((L, batch_size, W, KV, hd), dtype),
            v=jnp.zeros((L, batch_size, W, KV, hd), dtype),
            pos=jnp.full((L, W), -1, jnp.int32),
        )
        cross = (
            jnp.zeros((L, batch_size, F, KV, hd), dtype),
            jnp.zeros((L, batch_size, F, KV, hd), dtype),
        )
        return {"self": kv, "cross_k": cross[0], "cross_v": cross[1]}

    def prefill(params, batch, max_len):
        enc_out = encode(params, batch["audio_embed"])
        logits, caches = decode_seq(
            params, batch["tokens"], enc_out, produce_cache=True, max_len=max_len
        )
        kv, ck, cv = caches
        return logits[:, -1:], {"self": kv, "cross_k": ck, "cross_v": cv}

    def decode_step(params, token, t, caches):
        x = embed(params["embed"], token)
        x = x + _sin_pos_at(t, cfg.d_model).astype(dtype)

        def body(xx, xs):
            unit, kv, ck, cv = xs
            h = apply_norm(unit["norm1"], xx, cfg.norm_eps)
            a, nkv = decode_attend(unit["self_attn"], h, t, kv, cfg)
            xx = xx + a
            h = apply_norm(unit["norm2"], xx, cfg.norm_eps)
            xx = xx + decode_cross_attend(unit["cross_attn"], h, (ck, cv), cfg)
            xx = xx + apply_mlp(unit["mlp"], apply_norm(unit["norm3"], xx, cfg.norm_eps))
            return xx, nkv

        x, nkv = jax.lax.scan(
            body, x, (params["dec_blocks"], caches["self"], caches["cross_k"], caches["cross_v"])
        )
        x = apply_norm(params["final_norm"], x, cfg.norm_eps)
        logits = dense(params["lm_head"], x).astype(jnp.float32)
        return logits, {"self": nkv, "cross_k": caches["cross_k"], "cross_v": caches["cross_v"]}

    return ModelApi(
        cfg, init, loss_fn, logits_fn, _ce_loss, prefill, decode_step, init_cache
    )


def _sin_pos_at(t, d):
    dim = jnp.arange(0, d, 2).astype(jnp.float32)
    ang = t.astype(jnp.float32) / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((d,), jnp.float32)
    return pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))[None, None]
