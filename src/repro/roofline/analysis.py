"""Roofline terms from compiled dry-run artifacts (no jax import needed).

  compute    = HLO_FLOPs / (chips x peak_FLOPs)
  memory     = HLO_bytes / (chips x HBM_bw)
  collective = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are parsed out of the HLO text: we sum the *result* shape bytes of
every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute (async `-start` forms counted once, `-done` ignored).
Caveat (documented in EXPERIMENTS.md): XLA's cost analysis counts a
while-loop body once, so for the HF step the terms are per-Krylov-iteration
program cost; the per-outer-iteration cost multiplies the solver trip count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e hardware constants (per chip) — from the task brief.
@dataclasses.dataclass(frozen=True)
class _HW:
    peak_flops: float = 197e12      # bf16 FLOP/s
    hbm_bw: float = 819e9           # B/s
    ici_bw: float = 50e9            # B/s per link
    hbm_bytes: float = 16e9         # HBM capacity


HW = _HW()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%x = bf16[8,128]{1,0} all-reduce(...)` (scalar result) and
# `%x = (f32[8]{0}, f32[8]{0}) all-reduce-start(...)` (tuple result)
_OP_SCALAR_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\]\S*\s+("
    + "|".join(_COLLECTIVES)
    + r")(-start)?\("
)
_OP_TUPLE_RE = re.compile(
    r"=\s*\((.*?)\)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str, top_k: int = 5) -> Dict[str, int]:
    """Sum result bytes per collective kind (plus 'total' and the ``top_k``
    largest individual ops for diagnosis)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    tops = []
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _OP_SCALAR_RE.search(s)
        if m:
            dtype, dims, kind, _start = m.groups()
            size = _shape_bytes(dtype, dims)
            desc = f"{kind} {dtype}[{dims}]"
        else:
            m = _OP_TUPLE_RE.search(s)
            if not m:
                continue
            shapes, kind, _start = m.groups()
            found = _SHAPE_RE.findall(shapes)
            size = sum(_shape_bytes(d, i) for d, i in found)
            desc = f"{kind} tuple({len(found)})" + (
                f" {found[0][0]}[{found[0][1]}]" if found else ""
            )
        out[kind] += size
        count[kind] += 1
        tops.append((size, desc))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    tops.sort(reverse=True)
    out["top_ops"] = [f"{sz/2**30:.2f}GiB {desc}" for sz, desc in tops[:top_k]]
    return out


def cost_summary(cost_analysis) -> Dict[str, float]:
    """Normalize compiled.cost_analysis() output (dict or list-of-dicts)."""
    if cost_analysis is None:
        return {}
    props = cost_analysis[0] if isinstance(cost_analysis, (list, tuple)) else cost_analysis
    return {
        "flops": float(props.get("flops", 0.0)),
        "bytes_accessed": float(props.get("bytes accessed", 0.0)),
    }


def model_flops(cfg, shape) -> float:
    """Useful-model FLOPs for the workload: 6·N·D train (N = active params for
    MoE), 2·N·tokens decode/prefill-forward-only."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def active_param_count(cfg) -> int:
    if cfg.n_experts and cfg.top_k:
        full = cfg.param_count()
        dense_like = cfg.replace(n_experts=cfg.top_k)  # only k experts active
        return dense_like.param_count()
    return cfg.param_count()


def roofline_terms(
    flops: float, bytes_accessed: float, collective_bytes: float, n_chips: int
) -> Dict[str, float]:
    """All inputs are PER-DEVICE quantities (XLA analyses the partitioned,
    per-device module). flops·chips / (chips·peak) == flops/peak, so the
    per-device form below is identical to the brief's global formula."""
    compute = flops / HW.peak_flops
    memory = bytes_accessed / HW.hbm_bw
    collective = collective_bytes / HW.ici_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    return terms
