"""granite-moe-1b-a400m — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        n_experts=32,
        top_k=8,
        block_pattern=("moe",),
        tie_embeddings=True,
        dtype="bfloat16",
        source="[hf:ibm-granite/granite-3.0-1b-a400m-base]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=512, n_experts=4, top_k=2, dtype="float32",
    )
