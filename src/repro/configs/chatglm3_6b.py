"""chatglm3-6b — 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024,
RoPE 2d (partial rotary, fraction 0.5), QKV bias. [arXiv:2406.12793]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="chatglm3-6b",
        family="dense",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab_size=65024,
        qkv_bias=True,
        rope_fraction=0.5,
        block_pattern=("attn",),
        dtype="bfloat16",
        source="[arXiv:2406.12793]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32",
    )
