"""granite-3-8b — 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-8b-base]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12800,
        vocab_size=49155,
        block_pattern=("attn",),
        dtype="bfloat16",
        source="[hf:ibm-granite/granite-3.0-2b-base]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32",
    )
