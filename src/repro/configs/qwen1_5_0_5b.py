"""qwen1.5-0.5b — 24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab_size=151936,
        qkv_bias=True,
        tie_embeddings=True,
        block_pattern=("attn",),
        dtype="bfloat16",
        source="[hf:Qwen/Qwen1.5-0.5B]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512, dtype="float32",
    )
