"""Config registry: assigned architectures (by dashed id) + input shapes.

Filenames use underscores (python modules); ids keep the assigned dashes.
"""
from .base import (
    INPUT_SHAPES,
    HFOptConfig,
    InputShape,
    ModelConfig,
    RunConfig,
    pad_vocab,
)
from . import (
    chatglm3_6b,
    granite_3_8b,
    granite_moe_1b_a400m,
    mixtral_8x22b,
    phi_3_vision_4_2b,
    qwen1_5_0_5b,
    qwen2_1_5b,
    whisper_small,
    xlstm_1_3b,
    zamba2_7b,
)

_MODULES = {
    "mixtral-8x22b": mixtral_8x22b,
    "xlstm-1.3b": xlstm_1_3b,
    "zamba2-7b": zamba2_7b,
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "whisper-small": whisper_small,
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
    "qwen1.5-0.5b": qwen1_5_0_5b,
    "chatglm3-6b": chatglm3_6b,
    "granite-3-8b": granite_3_8b,
    "qwen2-1.5b": qwen2_1_5b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].smoke_config()


__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "HFOptConfig", "InputShape", "ModelConfig",
    "RunConfig", "get_config", "get_smoke_config", "pad_vocab",
]
