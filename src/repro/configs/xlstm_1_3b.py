"""xlstm-1.3b — 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304; alternating
sLSTM + mLSTM blocks (d_ff=0: no separate FFN sub-block). [arXiv:2405.04517]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block_pattern=("slstm", "mlstm"),
        dtype="bfloat16",
        source="[arXiv:2405.04517]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, vocab_size=512,
        ssm_chunk=16, dtype="float32",
    )
