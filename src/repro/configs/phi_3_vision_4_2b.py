"""phi-3-vision-4.2b — 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064;
phi3-mini decoder + CLIP vision frontend STUBBED: input_specs feeds
(B, 576, 1024) patch embeddings + linear projector.
[hf:microsoft/Phi-3-vision-128k-instruct]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="phi-3-vision-4.2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        n_vision_tokens=576,
        vision_dim=1024,
        block_pattern=("attn",),
        dtype="bfloat16",
        source="[hf:microsoft/Phi-3-vision-128k-instruct]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512, n_vision_tokens=8, vision_dim=32, dtype="float32",
    )
