"""zamba2-7b — 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000,
ssm_state=64; Mamba2 backbone + one weight-shared full-attention block every
6 mamba blocks (the Zamba trick). [arXiv:2411.15242]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        head_dim=112,
        ssm_state=64,
        ssm_head_dim=64,
        attn_every=6,
        block_pattern=("mamba",),
        dtype="bfloat16",
        source="[arXiv:2411.15242]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=5, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512, ssm_state=16, ssm_head_dim=16,
        attn_every=2, ssm_chunk=16, dtype="float32",
    )
