"""The paper's own experimental networks (He et al. 2017, §5).

MNIST:  784-400-10 (Fig. 3) and 784-400-150-10 (Fig. 4), tanh.
TIMIT:  360 features, 3 hidden layers x 512 units, 1973 classes (Fig. 5).
"""

MNIST_FIG3 = (784, 400, 10)
MNIST_FIG4 = (784, 400, 150, 10)
TIMIT_FIG5 = (360, 512, 512, 512, 1973)
