"""whisper-small — enc-dec, 12+12L d_model=768 12H d_ff=3072 vocab=51865;
conv/mel frontend STUBBED: input_specs feeds (B, 1500, 768) frame embeddings.
[arXiv:2212.04356]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-small",
        family="audio",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        is_encoder_decoder=True,
        n_encoder_layers=12,
        n_audio_frames=1500,
        max_target_positions=448,
        mlp_act="gelu",
        norm_kind="layernorm",
        dtype="bfloat16",
        source="[arXiv:2212.04356]",
        notes="decoder positions sinusoidal (paper: learned, cap 448) — see DESIGN.md",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_encoder_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512, n_audio_frames=32, dtype="float32",
    )
