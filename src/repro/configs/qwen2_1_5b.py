"""qwen2-1.5b — 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
QKV bias. [arXiv:2407.10671]"""
from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2-1.5b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        qkv_bias=True,
        tie_embeddings=True,
        block_pattern=("attn",),
        dtype="bfloat16",
        source="[arXiv:2407.10671]",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32",
    )
