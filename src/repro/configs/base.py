"""Configuration schema for models, input shapes and runs."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm", "mlp")
BLOCK_KINDS = ("attn", "moe", "mamba", "slstm", "mlstm")


def pad_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None            # default d_model // n_heads
    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0                # chatglm3: 0.5 (2d/partial RoPE)
    sliding_window: Optional[int] = None      # mixtral SWA; dense long_500k variant
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # --- hybrid (zamba2): one weight-shared attn block every k mamba blocks
    attn_every: int = 0
    # --- heterogeneous stacks: repeating unit of BLOCK_KINDS ---
    block_pattern: Tuple[str, ...] = ("attn",)
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500                # stub frontend output length
    max_target_positions: Optional[int] = None
    # --- vlm (phi-3-vision) ---
    n_vision_tokens: int = 0                  # stub patch embeddings
    vision_dim: int = 1024                    # stub frontend embedding width
    # --- numerics / misc ---
    # Chunked cross-entropy: compute the LM head + CE over vocab chunks of
    # this size (0 = off, materialize full logits). Cuts HBM traffic for
    # 100k+ vocabs several-fold (see EXPERIMENTS.md §Perf pair C).
    ce_chunk: int = 0
    # Explicit with_sharding_constraint hints on the MoE dispatch/combine
    # intermediates (keeps the one-hot dispatch tensors token-sharded instead
    # of letting GSPMD replicate them — §Perf pair A). No-op without a mesh.
    shard_hints: bool = False
    # Use the Pallas flash-attention kernels on BOTH the serving and the
    # training path (attend_full / encoder_attend / attend_full_with_cache).
    # Fully differentiable: forward emits the logsumexp residual, reverse
    # mode runs the Pallas dQ and dK/dV kernels, forward mode (the curvature
    # engine's J·v) runs the Pallas JVP pass, and exact-Hessian
    # forward-over-reverse traces use an AD-closed chunked-jnp form (see
    # kernels/flash_ad.py + EXPERIMENTS.md §Perf pair F). Non-block-aligned
    # seq_len is padded to the 128 tile, tail-masked and sliced. Explicit
    # masks and cross-attention keep the jnp `_sdpa` fallback/oracle.
    use_flash_attention: bool = False
    dtype: str = "float32"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mlp_act: str = "swiglu"                   # swiglu | gelu
    norm_kind: str = "rmsnorm"                # rmsnorm | layernorm
    source: str = ""                          # citation for the config
    notes: str = ""

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        for b in self.block_pattern:
            assert b in BLOCK_KINDS, b

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def d_inner(self) -> int:                 # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, V = self.d_model, self.d_ff, self.padded_vocab
        hd, H, KV = self.resolved_head_dim, self.n_heads, self.n_kv_heads
        attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        if self.qkv_bias:
            attn += (H + 2 * KV) * hd
        mlp = 3 * d * f if self.mlp_act == "swiglu" else 2 * d * f
        moe_mlp = self.n_experts * mlp + d * self.n_experts
        din, N = self.d_inner, self.ssm_state
        nh = self.ssm_n_heads if self.ssm_state else 0
        mamba = (
            d * (2 * din + 2 * N + nh)        # in_proj: x, z, B, C, dt
            + self.ssm_conv * din             # depthwise conv
            + din * d                          # out_proj
            + 3 * nh                           # A, D, dt_bias
        ) if self.ssm_state else 0
        mlstm = 4 * d * d + d * d + 2 * d + d * d  # q,k,v,o (+gates, skip proj)
        slstm = 4 * d * d + 4 * (d // max(self.n_heads, 1)) * d + 4 * d

        def block_cost(kind: str) -> int:
            return {
                "attn": attn + mlp + 2 * d,
                "moe": attn + moe_mlp + 2 * d,
                "mamba": mamba + d,
                "mlstm": mlstm + 2 * d,
                "slstm": slstm + 2 * d,
            }[kind]

        n_units = self.n_layers // len(self.block_pattern)
        blocks = n_units * sum(block_cost(k) for k in self.block_pattern)
        if self.family == "hybrid" and self.attn_every:
            blocks += attn + mlp + 2 * d      # ONE shared attention block
        if self.is_encoder_decoder:
            blocks += self.n_encoder_layers * (attn + mlp + 2 * d)
            blocks += self.n_layers // len(self.block_pattern) * (attn + 2 * d)  # cross-attn
        emb = V * d
        head = 0 if self.tie_embeddings else V * d
        return emb + blocks + head + d


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                                  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class HFOptConfig:
    """Optimizer selection + paper hyper-parameters (see core.hf.HFConfig)."""
    name: str = "bicgstab"                    # sgd | momentum | adam | gn_cg | hessian_cg | hybrid_cg | bicgstab
    lr: float = 0.1                            # first-order only
    momentum: float = 0.9
    max_cg_iters: int = 16
    cg_tol: float = 5e-3
    init_damping: float = 1.0
    cg_decay: float = 0.95
    hvp_batch_frac: float = 0.25               # curvature mini-batch fraction
    precondition: bool = False                 # Jacobi preconditioning (all Krylov solvers)
    krylov_backend: str = "tree"               # "tree" (sharded pytrees) | "flat" (fused Pallas)
    # Curvature engine (core.curvature): "naive" | "linearize" | "chunked".
    # "linearize" caches the primal linearization once per outer step;
    # "chunked" adds lax.scan microbatch accumulation of G·v at flat memory
    # (curvature_chunk_size examples per chunk) for Fig. 4-scale hvp batches.
    curvature_mode: str = "linearize"
    curvature_chunk_size: int = 0              # chunked mode: examples per microbatch
    # s-step (communication-avoiding) Krylov solve (core.sstep): sstep_s > 1
    # batches the dot products of s Krylov iterations into one Gram-matrix
    # reduction (1 + ceil(K/s) + E reduces per outer step vs 1 + K + E),
    # with a conditioning guard that falls back to the standard solver.
    # sstep_solver: "auto" (derive from `name`) | "cg" | "bicgstab".
    # sstep_basis picks the chain polynomial: "monomial" (f32 depth budget
    # s≤4 CG / s≤2 Bi-CG-STAB) | "newton" | "chebyshev" (Ritz-parameterized
    # conditioned bases that double usable s — EXPERIMENTS.md §Perf pair G).
    sstep_s: int = 1
    sstep_solver: str = "auto"
    sstep_basis: str = "monomial"
    # Overlapped collective schedule (core.hf HFConfig.overlap):
    # double-buffered s-step cycles (two cycles per Gram reduction), the
    # gradient all-reduce hidden behind the curvature primal build, and
    # paired speculative line-search trials — blocking syncs per outer step
    # drop from 1 + ceil(K/s) + E to ceil(K/2s) + ceil(E/2)
    # (benchmarks/comm_model.py overlap=True, measured by
    # benchmarks/fig5_scaling.py --executed).
    overlap: bool = False
    # Negative-curvature policy (core.hf NC_MODES): "truncate" (passive
    # φ-best competition at the solution's norm scale) | "escape"
    # (saddle-free |λ_min|-scaled escape step along the NC direction,
    # Arjovsky arXiv:1506.00059 — λ from KrylovResult.nc_lambda).
    nc_mode: str = "truncate"
    # Divergence sentinel (core.hf): reject_nonfinite rolls back any outer
    # step whose accepted loss or update is non-finite (NaN curvature
    # batch, overflow) and boosts λ instead of poisoning the params;
    # strict_descent additionally rejects finite steps whose loss rises by
    # more than descent_guard·max(1, |f0|). reject_boost scales λ on a
    # rejection (<=0 → damping_inc²).
    reject_nonfinite: bool = True
    strict_descent: bool = False
    descent_guard: float = 0.0
    reject_boost: float = 0.0


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: InputShape
    opt: HFOptConfig = HFOptConfig()
    seed: int = 0
    steps: int = 100
    fsdp: bool = False                         # shard stacked params over data axis too
    remat: bool = False                        # activation checkpointing on blocks
    use_flash_attention: bool = False          # Pallas kernel path (TPU)
