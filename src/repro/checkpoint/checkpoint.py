"""Durable flat-npz pytree checkpointing: atomic writes, per-array
checksums, a validated manifest, and newest-*valid* fallback restore.

Leaves are addressed by their tree path ("blocks/b0_attn/attn/wq/w"), so a
restore can rebuild into any pytree with the same structure — including the
full HF optimizer state (damping λ, Krylov warm start δ_{k-1}, hybrid flag,
step counter), which is what makes a resumed run *step-deterministic*: the
continuation executes the same program on the same state and the same
step-indexed batches as the uninterrupted run (asserted bitwise on params
in tests/test_checkpoint.py).

Durability contract (what a ``kill -9`` mid-write can and cannot leave):

  * writes go to a temp file in the SAME directory, are flushed + fsync'd,
    and land under the final name via ``os.replace`` (atomic on POSIX) —
    the final name is never observable half-written; the directory entry
    itself is fsync'd so the rename survives a crash of the whole host;
  * every array carries a CRC32 in the ``__manifest__`` JSON record, so a
    torn or bit-flipped file is *detected* at restore, not silently loaded
    (``verify_checkpoint`` / ``CheckpointCorruptError``);
  * ``restore_latest_valid`` scans steps newest-first and restores the
    first checkpoint that verifies — a corrupted latest falls back to the
    previous valid one instead of poisoning the resume;
  * the manifest records a config fingerprint and the writing process
    count; ``restore_checkpoint`` refuses (``CheckpointMismatchError``) to
    restore state into an incompatible run instead of trusting the step
    number alone.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import zlib
from typing import Any, Optional

import jax
import numpy as np

FORMAT_VERSION = 2


class CheckpointError(Exception):
    """Base class for checkpoint failures."""


class CheckpointCorruptError(CheckpointError):
    """The checkpoint file is torn, unreadable, or fails its checksums."""


class CheckpointMismatchError(CheckpointError):
    """The checkpoint is valid but belongs to an incompatible run
    (config fingerprint or process count differ from the restorer's)."""


def config_fingerprint(obj: Any) -> str:
    """Stable short fingerprint of a run configuration.

    Accepts dataclasses / dicts / tuples / primitives; the JSON-canonical
    form (sorted keys) is hashed so field order never matters. Used by the
    manifest so a resume into a different arch/solver/batch shape is
    refused instead of silently restoring incompatible optimizer state.
    """

    def canon(x):
        if dataclasses.is_dataclass(x) and not isinstance(x, type):
            return {"__dc__": type(x).__name__,
                    **{f.name: canon(getattr(x, f.name))
                       for f in dataclasses.fields(x)}}
        if isinstance(x, dict):
            return {str(k): canon(v) for k, v in sorted(x.items())}
        if isinstance(x, (list, tuple)):
            return [canon(v) for v in x]
        if isinstance(x, (str, int, float, bool)) or x is None:
            return x
        return repr(x)

    blob = json.dumps(canon(obj), sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_checkpoint(
    directory: str,
    step: int,
    params: Any,
    opt_state: Any = None,
    extra: dict | None = None,
    *,
    fingerprint: Optional[str] = None,
    processes: int = 1,
) -> str:
    """Atomically write ``ckpt_{step}.npz`` with checksums + manifest.

    ``fingerprint`` (see :func:`config_fingerprint`) and ``processes`` are
    recorded in the manifest and validated on restore. ``extra`` rides in
    both the manifest and the legacy ``__meta__`` record.
    """
    os.makedirs(directory, exist_ok=True)
    payload = {f"params/{k}": v for k, v in _flatten_with_paths(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v
                        for k, v in _flatten_with_paths(opt_state).items()})
    meta = {"step": int(step), **(extra or {})}
    manifest = {
        "format": FORMAT_VERSION,
        "step": int(step),
        "fingerprint": fingerprint,
        "processes": int(processes),
        "checksums": {k: _crc(v) for k, v in payload.items()},
        "extra": dict(extra or {}),
    }
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta),
                     __manifest__=json.dumps(manifest), **payload)
            # Durability before visibility: the bytes must be on disk
            # BEFORE the rename makes the final name observable, or a
            # crash can leave a fully-named, half-written checkpoint.
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    # fsync the directory entry so the rename itself survives a host crash.
    dfd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
    return final


def _step_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.npz")


def all_steps(directory: str) -> list:
    """Every checkpoint step present on disk (no validity check), sorted."""
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(f[len("ckpt_"):-len(".npz")])
        for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
    )


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def verify_checkpoint(path: str) -> dict:
    """Integrity-check one checkpoint file; return its manifest.

    Raises :class:`CheckpointCorruptError` on a torn/unreadable file, a
    missing manifest, a key set that disagrees with the manifest, or any
    per-array CRC32 mismatch.
    """
    try:
        with np.load(path, allow_pickle=False) as z:
            if "__manifest__" not in z.files:
                raise CheckpointCorruptError(
                    f"{path}: no __manifest__ record (pre-durability format "
                    "or torn write)")
            manifest = json.loads(str(z["__manifest__"]))
            checksums = manifest.get("checksums", {})
            keys = {k for k in z.files if k not in ("__meta__", "__manifest__")}
            if keys != set(checksums):
                raise CheckpointCorruptError(
                    f"{path}: manifest/key mismatch "
                    f"(missing={sorted(set(checksums) - keys)[:3]} "
                    f"extra={sorted(keys - set(checksums))[:3]})")
            for k, want in checksums.items():
                got = _crc(z[k])
                if got != int(want):
                    raise CheckpointCorruptError(
                        f"{path}: checksum mismatch on {k!r} "
                        f"(stored {want}, computed {got})")
    except CheckpointCorruptError:
        raise
    except Exception as e:  # zipfile/json/np errors: torn or garbled file
        raise CheckpointCorruptError(f"{path}: unreadable ({e})") from e
    return manifest


def valid_steps(directory: str) -> list:
    """Steps whose checkpoint files pass :func:`verify_checkpoint`."""
    out = []
    for step in all_steps(directory):
        try:
            verify_checkpoint(_step_path(directory, step))
        except CheckpointCorruptError:
            continue
        out.append(step)
    return out


def latest_valid_step(directory: str) -> int | None:
    """Newest step that verifies clean (newest-first scan, torn files
    skipped). None when no valid checkpoint exists."""
    for step in reversed(all_steps(directory)):
        try:
            verify_checkpoint(_step_path(directory, step))
        except CheckpointCorruptError:
            continue
        return step
    return None


def _check_manifest(manifest: dict, path: str,
                    expect_fingerprint: Optional[str],
                    expect_processes: Optional[int]) -> None:
    if (expect_fingerprint is not None
            and manifest.get("fingerprint") is not None
            and manifest["fingerprint"] != expect_fingerprint):
        raise CheckpointMismatchError(
            f"{path}: config fingerprint {manifest['fingerprint']!r} does "
            f"not match this run's {expect_fingerprint!r} — the checkpoint "
            "was written by a different model/optimizer configuration; "
            "refusing to restore incompatible state (point --ckpt-dir at a "
            "fresh directory, or rerun with the original config)")
    if (expect_processes is not None
            and manifest.get("processes") is not None
            and int(manifest["processes"]) != int(expect_processes)):
        raise CheckpointMismatchError(
            f"{path}: written by {manifest['processes']} process(es), "
            f"restoring into {expect_processes} — replicated optimizer "
            "state is only step-deterministic at the writing process "
            "count; refusing (restart with --num-processes "
            f"{manifest['processes']})")


def restore_checkpoint(
    directory: str,
    step: int,
    params_like: Any,
    opt_state_like: Any = None,
    *,
    expect_fingerprint: Optional[str] = None,
    expect_processes: Optional[int] = None,
    verify: bool = True,
):
    """Restore into templates (shape/structure donors). Returns
    (params, opt_state, meta).

    ``verify=True`` (default) checksums every array and validates the
    manifest against ``expect_fingerprint`` / ``expect_processes`` BEFORE
    any state is rebuilt — the step number alone is never trusted
    (:class:`CheckpointCorruptError` / :class:`CheckpointMismatchError`).
    """
    path = _step_path(directory, step)
    if verify:
        manifest = verify_checkpoint(path)
        _check_manifest(manifest, path, expect_fingerprint, expect_processes)
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        data = {k: z[k] for k in z.files
                if k not in ("__meta__", "__manifest__")}

    def rebuild(template, prefix):
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            key = prefix + "/".join(_path_str(x) for x in p)
            if key not in data:
                raise CheckpointMismatchError(
                    f"{path}: missing leaf {key!r} — the restore template's "
                    "tree structure differs from the saved one")
            arr = data[key]
            if arr.shape != leaf.shape:
                raise CheckpointMismatchError(
                    f"{path}: shape mismatch on {key!r} "
                    f"(saved {arr.shape}, template {leaf.shape})")
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = rebuild(params_like, "params/")
    opt_state = rebuild(opt_state_like, "opt/") if opt_state_like is not None else None
    return params, opt_state, meta


def restore_latest_valid(
    directory: str,
    params_like: Any,
    opt_state_like: Any = None,
    *,
    expect_fingerprint: Optional[str] = None,
    expect_processes: Optional[int] = None,
):
    """Restore the newest checkpoint that passes integrity checks.

    Corrupt/torn files are skipped (with a fallback to older steps);
    manifest *mismatches* are NOT skipped — a valid checkpoint from an
    incompatible run raises :class:`CheckpointMismatchError`, because
    silently resuming older compatible state would hide the operator
    error. Returns (params, opt_state, meta, step) or None when the
    directory holds no valid checkpoint.
    """
    for step in reversed(all_steps(directory)):
        path = _step_path(directory, step)
        try:
            manifest = verify_checkpoint(path)
        except CheckpointCorruptError:
            continue
        _check_manifest(manifest, path, expect_fingerprint, expect_processes)
        params, opt_state, meta = restore_checkpoint(
            directory, step, params_like, opt_state_like, verify=False)
        return params, opt_state, meta, step
    return None
