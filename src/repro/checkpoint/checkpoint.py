"""Flat-npz pytree checkpointing with step metadata.

Leaves are addressed by their tree path ("blocks/b0_attn/attn/wq/w"), so a
restore can rebuild into any pytree with the same structure — including the
optimizer state. Atomic rename guards against torn writes.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, params: Any, opt_state: Any = None, extra: dict | None = None):
    os.makedirs(directory, exist_ok=True)
    payload = {f"params/{k}": v for k, v in _flatten_with_paths(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v for k, v in _flatten_with_paths(opt_state).items()})
    meta = {"step": int(step), **(extra or {})}
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, __meta__=json.dumps(meta), **payload)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[len("ckpt_"):-len(".npz")])
        for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, params_like: Any, opt_state_like: Any = None):
    """Restore into templates (shape/structure donors). Returns
    (params, opt_state, meta)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        data = {k: z[k] for k in z.files if k != "__meta__"}

    def rebuild(template, prefix):
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            key = prefix + "/".join(_path_str(x) for x in p)
            arr = data[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = rebuild(params_like, "params/")
    opt_state = rebuild(opt_state_like, "opt/") if opt_state_like is not None else None
    return params, opt_state, meta
