from .checkpoint import (
    CheckpointCorruptError,
    CheckpointError,
    CheckpointMismatchError,
    all_steps,
    config_fingerprint,
    latest_step,
    latest_valid_step,
    restore_checkpoint,
    restore_latest_valid,
    save_checkpoint,
    valid_steps,
    verify_checkpoint,
)

__all__ = [
    "CheckpointCorruptError", "CheckpointError", "CheckpointMismatchError",
    "all_steps", "config_fingerprint", "latest_step", "latest_valid_step",
    "restore_checkpoint", "restore_latest_valid", "save_checkpoint",
    "valid_steps", "verify_checkpoint",
]
