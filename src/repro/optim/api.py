"""Unified optimizer interface: first-order baselines and the paper's HF
variants behind one (init, step) surface, selected by HFOptConfig.name.

HF steps take the full batch for gradient/line-search and slice a curvature
mini-batch of ``hvp_batch_frac`` (paper Alg. 2: full gradient, mini-batch
Hessian; Fig. 4 sweeps this size).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import HFOptConfig
from ..core import HFConfig, hf_init, hf_step
from .first_order import adam, momentum_sgd, sgd

FIRST_ORDER = ("sgd", "momentum", "adam")


class Optimizer(NamedTuple):
    name: str
    init: Callable[[Any], Any]
    step: Callable[..., tuple]  # (params, state, batch) -> (params, state, metrics)


def _slice_batch(batch, frac: float):
    """Leading-dim slice for the curvature mini-batch (static fraction)."""
    if frac >= 1.0:
        return batch

    def cut(x):
        n = max(int(x.shape[0] * frac), 1)
        return x[:n]

    return jax.tree_util.tree_map(cut, batch)


def make_optimizer(
    opt: HFOptConfig,
    loss_fn,
    model_out_fn=None,
    out_loss_fn=None,
    mesh=None,
    data_axes=("data",),
) -> Optimizer:
    """``mesh`` selects the explicit data-parallel step: the HF step is
    wrapped in shard_map over ``data_axes`` (core.distributed — batch leaves
    sharded on their leading dim, params/state replicated, the paper's MPI
    schedule written out). Works for single- AND multi-process meshes
    (launch/multiproc.py); first-order optimizers don't take a mesh here.
    """
    if opt.name in FIRST_ORDER:
        if mesh is not None:
            raise ValueError(
                "mesh= is only supported for the HF optimizers "
                f"(got first-order {opt.name!r})"
            )
        fo = {
            "sgd": lambda: sgd(opt.lr),
            "momentum": lambda: momentum_sgd(opt.lr, opt.momentum),
            "adam": lambda: adam(opt.lr),
        }[opt.name]()

        def step(params, state, batch):
            return fo.step(loss_fn, params, state, batch)

        return Optimizer(opt.name, fo.init, step)

    hf_cfg = HFConfig(
        solver=opt.name,
        max_cg_iters=opt.max_cg_iters,
        cg_tol=opt.cg_tol,
        init_damping=opt.init_damping,
        cg_decay=opt.cg_decay,
        precondition=opt.precondition,
        krylov_backend=opt.krylov_backend,
        curvature_mode=opt.curvature_mode,
        curvature_chunk_size=opt.curvature_chunk_size,
        sstep_s=opt.sstep_s,
        sstep_solver=opt.sstep_solver,
        sstep_basis=opt.sstep_basis,
        overlap=opt.overlap,
        nc_mode=opt.nc_mode,
        reject_nonfinite=opt.reject_nonfinite,
        strict_descent=opt.strict_descent,
        descent_guard=opt.descent_guard,
        reject_boost=opt.reject_boost,
    )

    def init(params):
        return hf_init(params, hf_cfg)

    if mesh is not None:
        from ..core.distributed import data_parallel_hf_step

        step = data_parallel_hf_step(
            loss_fn, mesh, hf_cfg, data_axes=tuple(data_axes),
            hvp_frac=opt.hvp_batch_frac,
            model_out_fn=model_out_fn, out_loss_fn=out_loss_fn,
        )
        return Optimizer(opt.name, init, step)

    def step(params, state, batch):
        hvp_batch = _slice_batch(batch, opt.hvp_batch_frac)
        return hf_step(
            loss_fn, params, state, batch, hvp_batch, hf_cfg,
            model_out_fn=model_out_fn, out_loss_fn=out_loss_fn,
        )

    return Optimizer(opt.name, init, step)
