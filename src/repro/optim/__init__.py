from .first_order import adam, momentum_sgd, sgd, FirstOrderOptimizer
from .api import make_optimizer, Optimizer

__all__ = ["adam", "momentum_sgd", "sgd", "FirstOrderOptimizer", "make_optimizer", "Optimizer"]
