"""First-order baselines the paper compares against: SGD, Momentum-SGD
(Sutskever et al.), Adam. Pure (init, step) pairs over pytrees.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class FirstOrderOptimizer(NamedTuple):
    init: Callable[[Any], Any]
    step: Callable[..., tuple]   # (loss_fn, params, state, batch) -> (params, state, metrics)


def _metrics(loss, g):
    sq = sum(jnp.vdot(x.astype(jnp.float32), x.astype(jnp.float32))
             for x in jax.tree_util.tree_leaves(g))
    return {"loss": loss, "grad_norm": jnp.sqrt(sq)}


def sgd(lr: float) -> FirstOrderOptimizer:
    def init(params):
        return ()

    def step(loss_fn, params, state, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        new = jax.tree_util.tree_map(lambda p, gg: p - lr * gg.astype(p.dtype), params, g)
        return new, state, _metrics(loss, g)

    return FirstOrderOptimizer(init, step)


def momentum_sgd(lr: float, beta: float = 0.9) -> FirstOrderOptimizer:
    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def step(loss_fn, params, state, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        vel = jax.tree_util.tree_map(lambda v, gg: beta * v + gg.astype(v.dtype), state, g)
        new = jax.tree_util.tree_map(lambda p, v: p - lr * v, params, vel)
        return new, vel, _metrics(loss, g)

    return FirstOrderOptimizer(init, step)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> FirstOrderOptimizer:
    class AdamState(NamedTuple):
        m: Any
        v: Any
        t: jax.Array

    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(z, z, jnp.zeros((), jnp.int32))

    def step(loss_fn, params, state, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        t = state.t + 1
        m = jax.tree_util.tree_map(lambda mm, gg: b1 * mm + (1 - b1) * gg.astype(jnp.float32), state.m, g)
        v = jax.tree_util.tree_map(lambda vv, gg: b2 * vv + (1 - b2) * jnp.square(gg.astype(jnp.float32)), state.v, g)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, mm, vv: p - (lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)).astype(p.dtype),
            params, m, v,
        )
        return new, AdamState(m, v, t), _metrics(loss, g)

    return FirstOrderOptimizer(init, step)
