"""Merge per-process events.jsonl files into a Chrome/Perfetto trace.json
and reconstruct phase / collective spans for programmatic checks.

Span reconstruction
-------------------
The in-jit side emits *end-markers* only (``{"ev": "phase"}``), each
data-dependent on its phase's outputs; a phase span is the interval
between consecutive markers of one (process, step), named after the
closing marker. ``step_begin`` opens the chain and is not itself a phase.
Collectives arrive as ready-made ``{"ev": "coll", t0, t1}`` spans whose
begin fires at reduce-input-ready and end at reduce-output-ready — so in
overlap mode the hidden grad-reduce span brackets the curvature primal
build, and :func:`grad_reduce_overlap` turns the PR 7 schedule claim into
a measured number.

Trace layout: pid = process index; tids — 0 phases, 1 collectives,
2 host spans, 3 counters/instants. Chrome "X" complete events, ts/dur in
microseconds relative to the earliest event in the directory.
"""
from __future__ import annotations

import glob
import json
import os
import re
from typing import Optional

__all__ = [
    "load_events", "phase_spans", "collective_spans", "overlap_seconds",
    "grad_reduce_overlap", "fault_events", "build_trace", "merge_dir",
]

_LANES = {"phase": 0, "coll": 1, "span": 2, "counter": 3, "instant": 3,
          "fault": 3}


def fault_events(events):
    """``[{pid, kind, ts, ...}]`` for every fault/rejection event: injected
    faults (launch/faults.py), divergence-sentinel step rejections
    (core/hf.py via telemetry.reject_event), signal deaths. Sorted by
    time; used by chaos checks to assert faults landed where planned."""
    return sorted((dict(e) for e in events if e.get("ev") == "fault"),
                  key=lambda e: (e.get("ts", 0.0), e["pid"]))


def load_events(events_dir: str):
    """All events from every ``events-p*.jsonl`` in ``events_dir``, each
    annotated with its process index under ``"pid"``. Unparseable lines
    (torn writes from a killed process) are skipped."""
    events = []
    for path in sorted(glob.glob(os.path.join(events_dir, "events-p*.jsonl"))):
        m = re.search(r"events-p(\d+)\.jsonl$", path)
        pid = int(m.group(1)) if m else 0
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError:
                    continue
                ev["pid"] = pid
                events.append(ev)
    return events


def phase_spans(events):
    """Reconstruct ``[{pid, step, name, t0, t1}]`` from phase end-markers.

    Markers are grouped by (pid, step) and sorted by timestamp; each
    marker closes the span opened by its predecessor. Consecutive markers
    with the same name (e.g. the hybrid solver building two curvature
    operators) collapse into one span ending at the last marker.
    """
    groups: dict = {}
    for ev in events:
        if ev.get("ev") == "phase":
            groups.setdefault((ev["pid"], ev.get("step", -1)), []).append(ev)
    spans = []
    for (pid, step), marks in groups.items():
        marks.sort(key=lambda e: e["ts"])
        out = []
        for mk in marks:
            if mk["name"] == "step_begin":
                out.append(dict(pid=pid, step=step, name=mk["name"],
                                t0=mk["ts"], t1=mk["ts"]))
            elif out and out[-1]["name"] == mk["name"]:
                out[-1]["t1"] = mk["ts"]
            elif out:
                out.append(dict(pid=pid, step=step, name=mk["name"],
                                t0=out[-1]["t1"], t1=mk["ts"]))
            else:
                out.append(dict(pid=pid, step=step, name=mk["name"],
                                t0=mk["ts"], t1=mk["ts"]))
        spans.extend(s for s in out if s["name"] != "step_begin")
    spans.sort(key=lambda s: (s["pid"], s["t0"]))
    return spans


def collective_spans(events):
    """``[{pid, tag, label, t0, t1}]`` for every executed collective."""
    return sorted((dict(pid=e["pid"], tag=e["tag"], label=e["label"],
                        t0=e["t0"], t1=e["t1"])
                   for e in events if e.get("ev") == "coll"),
                  key=lambda s: (s["pid"], s["t0"]))


def overlap_seconds(a, b) -> float:
    """Temporal intersection of two spans (dicts with t0/t1), >= 0."""
    return max(0.0, min(a["t1"], b["t1"]) - max(a["t0"], b["t0"]))


def grad_reduce_overlap(events, *, phase: str = "curvature_primal",
                        label: str = "grad_reduce"):
    """Per (pid, step): how much of the grad-reduce collective span hides
    inside the curvature-primal phase span.

    Returns ``[{pid, step, overlap_s, phase_s, coll_s, frac}]`` where
    ``frac`` = overlap / phase duration — ~0 under the blocking schedule
    (the reduce completes before the primal build starts), substantial
    under ``HFConfig.overlap`` (the reduce span brackets the build).
    """
    phases = [s for s in phase_spans(events) if s["name"] == phase]
    colls = [c for c in collective_spans(events) if c["label"] == label]
    rows = []
    for p in phases:
        # the step's grad-reduce: same process, begin at/before the
        # primal phase ends (the hidden reduce issues before the build)
        cands = [c for c in colls
                 if c["pid"] == p["pid"] and c["t0"] <= p["t1"]
                 and c["t1"] >= p["t0"] - 1.0]
        if not cands:
            continue
        c = max(cands, key=lambda c: overlap_seconds(c, p))
        ov = overlap_seconds(c, p)
        dur = max(p["t1"] - p["t0"], 1e-12)
        rows.append(dict(pid=p["pid"], step=p["step"], overlap_s=ov,
                         phase_s=p["t1"] - p["t0"], coll_s=c["t1"] - c["t0"],
                         frac=ov / dur))
    return rows


def _us(t: float, t_base: float) -> float:
    return (t - t_base) * 1e6


def build_trace(events) -> dict:
    """Chrome/Perfetto trace dict (``traceEvents`` JSON) from raw events."""
    times = [v for e in events for k, v in e.items()
             if k in ("ts", "t0") and isinstance(v, (int, float))]
    t_base = min(times) if times else 0.0
    out = []
    pids = sorted({e["pid"] for e in events})
    for pid in pids:
        out.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": f"process {pid}"}})
        for tid, lane in ((0, "phases"), (1, "collectives"),
                          (2, "host"), (3, "events")):
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": lane}})

    for s in phase_spans(events):
        out.append({"ph": "X", "pid": s["pid"], "tid": _LANES["phase"],
                    "name": s["name"], "ts": _us(s["t0"], t_base),
                    "dur": max(_us(s["t1"], t_base) - _us(s["t0"], t_base), 1),
                    "args": {"step": s["step"]}})
    for c in collective_spans(events):
        out.append({"ph": "X", "pid": c["pid"], "tid": _LANES["coll"],
                    "name": c["label"], "ts": _us(c["t0"], t_base),
                    "dur": max(_us(c["t1"], t_base) - _us(c["t0"], t_base), 1),
                    "args": {"tag": c["tag"]}})
    for e in events:
        kind = e.get("ev")
        if kind == "span":
            args = {k: v for k, v in e.items()
                    if k not in ("ev", "name", "t0", "t1", "pid")}
            out.append({"ph": "X", "pid": e["pid"], "tid": _LANES["span"],
                        "name": e["name"], "ts": _us(e["t0"], t_base),
                        "dur": max(_us(e["t1"], t_base)
                                   - _us(e["t0"], t_base), 1),
                        "args": args})
        elif kind == "counter":
            out.append({"ph": "C", "pid": e["pid"], "tid": _LANES["counter"],
                        "name": e["name"], "ts": _us(e["ts"], t_base),
                        "args": {e["name"]: e["value"]}})
        elif kind == "instant":
            args = {k: v for k, v in e.items()
                    if k not in ("ev", "name", "ts", "pid")}
            out.append({"ph": "i", "pid": e["pid"], "tid": _LANES["instant"],
                        "name": e["name"], "ts": _us(e["ts"], t_base),
                        "s": "p", "args": args})
        elif kind == "fault":
            # Process-scoped instant ("s": "p") named fault:<kind> so
            # injected faults, step rejections, and signal deaths stand
            # out on the events lane next to the spans they interrupt.
            args = {k: v for k, v in e.items()
                    if k not in ("ev", "kind", "ts", "pid")}
            out.append({"ph": "i", "pid": e["pid"], "tid": _LANES["fault"],
                        "name": f"fault:{e.get('kind', '?')}",
                        "ts": _us(e.get("ts", t_base), t_base),
                        "s": "p", "args": args})
    out.sort(key=lambda e: e.get("ts", 0.0))
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def merge_dir(events_dir: str, out_path: Optional[str] = None) -> str:
    """Merge every events-p*.jsonl under ``events_dir`` into one
    ``trace.json`` (written into the same dir by default)."""
    events = load_events(events_dir)
    trace = build_trace(events)
    if out_path is None:
        out_path = os.path.join(events_dir, "trace.json")
    with open(out_path, "w") as f:
        json.dump(trace, f)
    return out_path
