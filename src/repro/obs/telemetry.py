"""Per-process structured telemetry sink + trace-time instrumentation hooks.

Two halves:

  * **Host side** — :class:`Telemetry` appends JSON events to
    ``events-p{N}.jsonl`` (one object per line) and offers a wall-clock
    ``span`` context manager plus instant/counter emitters for host code
    (train loop, serve scheduler).

  * **In-jit side** — module-level trace-time state, following the
    ``core.collectives.count_executed`` pattern: while a sink is installed
    via :func:`install`, tracing the optimizer step bakes in
    ``jax.debug.callback`` timestamps — phase end-markers, collective
    begin/end pairs (see ``core.collectives.preduce``), Krylov solve
    summaries, per-cycle Ritz snapshots. With no sink installed **nothing
    is traced in**: every hook checks ``_active`` at trace time and
    returns before touching jax, so the disabled jaxpr is identical to the
    un-instrumented program (zero-cost-off; asserted in
    tests/test_telemetry.py).

Timing semantics on XLA:CPU: custom calls run synchronously in the compute
thread, so a callback's ``time.time()`` is the executor's actual schedule
position. A collective's begin callback depends only on the reduce *input*
(fires at input-ready = earliest possible issue time) and its end callback
on the reduce *output* (fires at completion) — under ``HFConfig.overlap``
the hidden grad-reduce span therefore visibly brackets the curvature
primal build, while the blocking schedule closes it before the primal
starts. That schedule difference is the PR's headline measurement.

Every callback operand is multiplied by ``0 * sum(dep)`` so it stays
data-dependent (can't be constant-folded or hoisted past the value it
brackets) while adding no numerics.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from collections import deque
from typing import Any, Optional

__all__ = [
    "Telemetry", "install", "active", "collective_label",
    "current_collective_label", "step_scope", "marker", "solve_event",
    "ritz_event", "reject_event", "register_crash_flush",
]


class Telemetry:
    """Append-only JSONL event sink for one process.

    Thread-safe: jax debug callbacks may land on a runtime thread while the
    host loop emits spans. Events are flushed line-by-line so a crashed or
    killed process still leaves a parseable file.
    """

    def __init__(self, out_dir: str, process_index: int = 0,
                 meta: Optional[dict] = None):
        os.makedirs(out_dir, exist_ok=True)
        self.out_dir = out_dir
        self.process_index = process_index
        self.path = os.path.join(out_dir, f"events-p{process_index}.jsonl")
        self._lock = threading.Lock()
        self._f = open(self.path, "a", buffering=1)
        # Pending collective begins, FIFO per (tag, label). On CPU same-tag
        # reduces are serialized by data dependence, so FIFO pairing is
        # faithful; a leftover begin (e.g. process killed mid-step) is
        # dropped at close().
        self._pending: dict = {}
        self.emit({"ev": "meta", "process": process_index,
                   "ts": time.time(), **(meta or {})})

    # -- raw emission ----------------------------------------------------
    def emit(self, event: dict) -> None:
        line = json.dumps(event, separators=(",", ":"), default=float)
        with self._lock:
            self._f.write(line + "\n")

    # -- host-side API ---------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **fields):
        t0 = time.time()
        try:
            yield
        finally:
            t1 = time.time()
            self.emit({"ev": "span", "name": name, "t0": t0, "t1": t1,
                       **fields})

    def instant(self, name: str, **fields) -> None:
        self.emit({"ev": "instant", "name": name, "ts": time.time(),
                   **fields})

    def counter(self, name: str, value, ts: Optional[float] = None) -> None:
        self.emit({"ev": "counter", "name": name, "value": float(value),
                   "ts": time.time() if ts is None else ts})

    def log(self, msg: str) -> None:
        self.emit({"ev": "log", "msg": str(msg), "ts": time.time()})

    # -- in-jit callback receivers --------------------------------------
    def phase_event(self, name: str, step: int) -> None:
        self.emit({"ev": "phase", "name": name, "step": int(step),
                   "ts": time.time()})

    def collective_begin(self, tag: str, label: str) -> None:
        key = (tag, label)
        with self._lock:
            self._pending.setdefault(key, deque()).append(time.time())

    def collective_end(self, tag: str, label: str) -> None:
        t1 = time.time()
        key = (tag, label)
        with self._lock:
            q = self._pending.get(key)
            t0 = q.popleft() if q else t1
        self.emit({"ev": "coll", "tag": tag, "label": label,
                   "t0": t0, "t1": t1})

    def solve_event(self, step: int, **fields) -> None:
        self.emit({"ev": "solve", "step": int(step), "ts": time.time(),
                   **fields})

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# -- trace-time state (checked when the step function is TRACED) ---------
_active: Optional[Telemetry] = None
_labels: list = []        # collective_label stack (trace-time)
_steps: list = []         # step_scope stack of traced step arrays


def active() -> Optional[Telemetry]:
    """The installed sink, or None. Checked at trace time by every hook."""
    return _active


@contextlib.contextmanager
def install(sink: Telemetry):
    """Trace optimizer steps inside this context to bake telemetry
    callbacks into the jitted program. The callbacks close over ``sink``
    and keep writing to it on every execution of the compiled step, even
    after the context exits (same lifetime rule as ``count_executed``)."""
    global _active
    prev = _active
    _active = sink
    try:
        yield sink
    finally:
        _active = prev


@contextlib.contextmanager
def collective_label(label: str):
    """Relabel telemetry events for preduce calls traced inside this
    context (e.g. the gradient all-reduce, whose count tag stays
    ``grad_hvp`` so PR 7 executed-count audits are untouched)."""
    _labels.append(label)
    try:
        yield
    finally:
        _labels.pop()


def current_collective_label() -> Optional[str]:
    return _labels[-1] if _labels else None


@contextlib.contextmanager
def step_scope(step):
    """Provide the traced outer-step index to markers emitted from code
    (e.g. the curvature engine) that has no access to ``HFState``."""
    _steps.append(step)
    try:
        yield
    finally:
        _steps.pop()


def _dep_scalar(deps):
    """A zero f32 scalar data-dependent on every leaf of ``deps`` — the
    callback operand that pins a marker to its phase's outputs."""
    import jax
    import jax.numpy as jnp
    total = jnp.zeros((), jnp.float32)
    for d in deps:
        for leaf in jax.tree_util.tree_leaves(d):
            total = total + jnp.sum(leaf).astype(jnp.float32)
    return jnp.zeros((), jnp.float32) * total


def marker(name: str, *deps, step=None) -> None:
    """Emit a phase end-marker callback, data-dependent on ``deps``.

    No-op (nothing traced) when no sink is installed. The marker closes
    the phase named ``name``; trace.py reconstructs phase spans as the
    interval between consecutive markers of one (process, step).
    """
    sink = _active
    if sink is None:
        return
    import jax
    import jax.numpy as jnp
    if step is None:
        step = _steps[-1] if _steps else jnp.int32(-1)

    def _cb(s, _unused, _sink=sink, _name=name):
        _sink.phase_event(_name, int(s))

    jax.debug.callback(_cb, step, _dep_scalar(deps))


def solve_event(step, *, iters, residual, syncs, residual_history,
                nc_found, breakdown) -> None:
    """Emit the per-step Krylov solve summary (iteration count, final
    residual, per-iteration residual curve). No-op when no sink."""
    sink = _active
    if sink is None:
        return
    import jax
    import numpy as np

    def _cb(s, it, res, sy, hist, nc, brk, _sink=sink):
        h = np.asarray(hist, dtype=np.float64)
        h = h[np.isfinite(h)]
        _sink.solve_event(
            int(s), iters=int(it), residual=float(res), syncs=int(sy),
            residual_history=[round(float(v), 8) for v in h],
            nc_found=bool(nc), breakdown=bool(brk))

    jax.debug.callback(_cb, step, iters, residual, syncs,
                       residual_history, nc_found, breakdown)


def reject_event(step, rejected, lam, f_new) -> None:
    """Divergence-sentinel hook: traced into every step, but the host-side
    callback emits a ``fault`` event only when the step was actually
    rejected (non-finite or non-descending update, see core/hf.py).
    No-op (nothing traced) when no sink is installed."""
    sink = _active
    if sink is None:
        return
    import jax

    def _cb(s, rej, l, f, _sink=sink):
        if bool(rej):
            _sink.emit({"ev": "fault", "kind": "step_reject",
                        "step": int(s), "lam": float(l),
                        "loss_new": float(f), "ts": time.time()})

    jax.debug.callback(_cb, step, rejected, lam, f_new)


def register_crash_flush(sink: Telemetry):
    """Close ``sink`` on abnormal exit so a SIGTERM'd / interrupted worker
    still leaves a flushed, parseable event file.

    Installs an ``atexit`` hook plus SIGTERM/SIGINT handlers that flush the
    sink, emit a final ``fault`` event recording the signal, then re-raise
    the default disposition (so the supervisor still sees a signal death).
    Handlers chain to any previously-installed callable handler. Safe to
    call from non-main threads: signal installation failures are ignored
    (the atexit hook alone still covers normal interpreter shutdown).
    """
    import atexit
    import signal

    atexit.register(sink.close)

    def _make(signum, prev):
        def _handler(num, frame):
            try:
                sink.emit({"ev": "fault", "kind": "signal",
                           "signal": int(num), "ts": time.time()})
                sink.close()
            except Exception:
                pass
            if callable(prev):
                prev(num, frame)
            else:
                signal.signal(num, signal.SIG_DFL)
                os.kill(os.getpid(), num)
        return _handler

    for signum in (signal.SIGTERM, signal.SIGINT):
        try:
            prev = signal.getsignal(signum)
            signal.signal(signum, _make(signum, prev))
        except ValueError:
            # signal only works in the main thread; atexit still covers us.
            pass


def ritz_event(ritz, ok, *, basis: str) -> None:
    """Per-cycle Ritz-value snapshot from the adaptive s-step Gram
    (free: the eigenvalues are already computed to refresh the basis).
    No-op when no sink; otherwise fires once per executed cycle."""
    sink = _active
    if sink is None:
        return
    import jax
    import numpy as np
    step = _steps[-1] if _steps else None

    def _cb(s, vals, okv, _sink=sink, _basis=basis):
        v = np.asarray(vals, dtype=np.float64)
        _sink.emit({"ev": "ritz", "step": int(s), "basis": _basis,
                    "ok": bool(okv), "ts": time.time(),
                    "values": [round(float(x), 8) for x in v.ravel()]})

    import jax.numpy as jnp
    if step is None:
        step = jnp.int32(-1)
    jax.debug.callback(_cb, step, ritz, ok)
