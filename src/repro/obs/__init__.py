"""Observability: structured telemetry sink, Perfetto trace merge, report CLI.

The in-jit side (phase markers, collective begin/end timestamps, solve
events) lives in :mod:`repro.obs.telemetry` and is wired into the core
modules behind a trace-time ``install`` context — when no sink is installed
nothing is traced in and the optimizer jaxpr is byte-identical to the
un-instrumented program (tests/test_telemetry.py asserts this).

Host-side artifacts:

  * ``events-p{N}.jsonl`` — one JSON object per line, per process.
  * ``trace.json`` — Chrome/Perfetto trace merged across processes
    (:mod:`repro.obs.trace`), pid = process index, tid = event lane.
  * ``python -m repro.obs.report <dir>`` — phase breakdown, collective
    timeline, solve-convergence summary.
"""
from . import telemetry, trace  # noqa: F401
