"""Render a telemetry events dir: phase breakdown, collective timeline,
Krylov solve convergence, serve latency summary.

    python -m repro.obs.report <events_dir> [--check]

``--check`` (CI smoke) exits non-zero unless both the phase and the
collective sections are non-empty — the merged artifact from the
2-process train smoke must actually contain the measured schedule.
"""
from __future__ import annotations

import argparse
import sys

from . import trace as _trace


def _fmt_ms(s: float) -> str:
    return f"{s * 1e3:9.3f}"


def _table(rows, header):
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    lines = ["  ".join(str(c).ljust(w) for c, w in zip(header, widths))]
    lines.append("  ".join("-" * w for w in widths))
    lines.extend("  ".join(str(c).ljust(w) for c, w in zip(r, widths))
                 for r in rows)
    return "\n".join(lines)


def phase_breakdown(events):
    agg: dict = {}
    for s in _trace.phase_spans(events):
        n, tot = agg.get(s["name"], (0, 0.0))
        agg[s["name"]] = (n + 1, tot + (s["t1"] - s["t0"]))
    total = sum(t for _, t in agg.values()) or 1.0
    rows = [(name, n, _fmt_ms(t), _fmt_ms(t / n), f"{100 * t / total:5.1f}%")
            for name, (n, t) in sorted(agg.items(),
                                       key=lambda kv: -kv[1][1])]
    return rows


def collective_breakdown(events):
    agg: dict = {}
    for c in _trace.collective_spans(events):
        key = (c["label"], c["tag"])
        n, tot = agg.get(key, (0, 0.0))
        agg[key] = (n + 1, tot + (c["t1"] - c["t0"]))
    rows = [(label, tag, n, _fmt_ms(t), _fmt_ms(t / n))
            for (label, tag), (n, t) in sorted(agg.items(),
                                               key=lambda kv: -kv[1][1])]
    return rows


def solve_summary(events):
    rows = []
    for e in sorted((e for e in events if e.get("ev") == "solve"),
                    key=lambda e: (e["pid"], e.get("step", -1))):
        hist = [h for h in e.get("residual_history", [])
                if isinstance(h, (int, float))]
        first = hist[0] if hist else float("nan")
        last = hist[-1] if hist else e.get("residual", float("nan"))
        red = first / last if hist and last else float("nan")
        rows.append((e["pid"], e.get("step", -1), e.get("iters", 0),
                     e.get("syncs", 0), f"{first:.3e}", f"{last:.3e}",
                     f"{red:9.2f}", e.get("nc_found", False),
                     e.get("breakdown", False)))
    return rows


def serve_summary(events):
    reqs = [e for e in events if e.get("ev") == "span"
            and e.get("name") == "request"]
    if not reqs:
        return None
    lat = sorted(e["t1"] - e["t0"] for e in reqs)
    ttft = sorted(e["ttft_s"] for e in reqs if "ttft_s" in e)

    def pct(xs, p):
        return xs[min(int(p * len(xs)), len(xs) - 1)] if xs else float("nan")

    free = [e["value"] for e in events
            if e.get("ev") == "counter" and e.get("name") == "pages_free"]
    depth = [e["value"] for e in events
             if e.get("ev") == "counter" and e.get("name") == "queue_depth"]
    return dict(n_requests=len(reqs),
                latency_p50_ms=pct(lat, 0.5) * 1e3,
                latency_p95_ms=pct(lat, 0.95) * 1e3,
                ttft_p50_ms=pct(ttft, 0.5) * 1e3,
                min_pages_free=min(free) if free else None,
                mean_queue_depth=(sum(depth) / len(depth)) if depth else None)


def render(events_dir: str, out=None) -> dict:
    out = out if out is not None else sys.stdout
    events = _trace.load_events(events_dir)
    phases = phase_breakdown(events)
    colls = collective_breakdown(events)
    solves = solve_summary(events)
    print(f"telemetry report: {events_dir} "
          f"({len(events)} events, "
          f"{len({e['pid'] for e in events})} process(es))\n", file=out)

    print("== phase breakdown ==", file=out)
    print(_table(phases, ("phase", "count", "total_ms", "mean_ms", "share"))
          if phases else "(no phase events)", file=out)

    print("\n== collective timeline ==", file=out)
    print(_table(colls, ("label", "tag", "count", "total_ms", "mean_ms"))
          if colls else "(no collective events)", file=out)

    ov = _trace.grad_reduce_overlap(events)
    if ov:
        mean_frac = sum(r["frac"] for r in ov) / len(ov)
        print(f"\ngrad-reduce ∩ curvature-primal: mean overlap "
              f"{mean_frac * 100:.1f}% of primal build "
              f"({len(ov)} step(s))", file=out)

    print("\n== solve convergence ==", file=out)
    print(_table(solves, ("pid", "step", "iters", "syncs", "r_first",
                          "r_last", "reduction", "nc", "breakdown"))
          if solves else "(no solve events)", file=out)

    ritz = [e for e in events if e.get("ev") == "ritz"]
    if ritz:
        lo = min(min(e["values"]) for e in ritz if e["values"])
        hi = max(max(e["values"]) for e in ritz if e["values"])
        print(f"\nritz snapshots: {len(ritz)} cycle(s), "
              f"eigenvalue range [{lo:.3e}, {hi:.3e}]", file=out)

    srv = serve_summary(events)
    if srv:
        print("\n== serve ==", file=out)
        for k, v in srv.items():
            print(f"  {k}: {v:.3f}" if isinstance(v, float)
                  else f"  {k}: {v}", file=out)

    return dict(n_phases=len(phases), n_collectives=len(colls),
                n_solves=len(solves), overlap_rows=len(ov))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a telemetry events directory.")
    ap.add_argument("events_dir")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless phase AND collective sections "
                         "are non-empty (CI artifact smoke)")
    args = ap.parse_args(argv)
    stats = render(args.events_dir)
    if args.check and (stats["n_phases"] == 0 or stats["n_collectives"] == 0):
        print("report --check FAILED: empty phase or collective section",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
