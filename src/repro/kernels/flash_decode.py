"""Split-K flash-decode as Pallas TPU kernels (the serving hot path).

Decode-time attention is one query row per sequence against a long KV
window: the arithmetic is a (1, hd) @ (hd, W) matvec pair, so the kernel is
bandwidth-bound and the parallelism has to come from the *KV* axis, not the
query axis the training kernels tile. Both kernels here therefore
parallelize the grid over KV blocks ("split-K"): every grid cell runs an
online softmax over its slice of the window and emits a *partial*
(o, m, l) triple — o normalized within the slice, m the running row max,
l the softmax mass — and ``combine_splits`` merges the partials with the
same logsumexp algebra the PR 4 training kernels and
``models/decode_sharded.py`` already use (m* = max mᵢ, weights lᵢ·e^{mᵢ−m*}).
The combine is associative, so the same (o, m, l) contract also merges
*across shards* (the sequence-sharded decode schedule) and across page
splits.

Mask semantics ride in a precomputed f32 additive **bias** row per sequence
(``decode_bias`` / ``paged_bias``): rolling-slot validity (absolute position
stored per slot, -1 empty), per-sequence ragged ``t`` (continuous batching —
each slot in the batch may sit at a different decode position), sliding
windows, and missing pages all become 0/-1e30 entries of an O(B·W) vector.
That keeps the kernels free of positional bookkeeping — one mask definition
in jnp, shared with the oracle — and costs H× less HBM than the (B, H, W)
logits ``_sdpa`` materializes (the O(S²) problem does not exist at decode;
the O(H·W) logits + two-pass softmax traffic is what this kernel removes).

Kernels:

  * ``_fd_kernel``       — dense rolling cache. Grid (B, KV, n_splits,
                           blocks_per_split): the innermost axis reduces
                           sequentially into VMEM scratch (the PR 4
                           m/l/acc recurrence), the n_splits axis is
                           embarrassingly parallel and each split writes its
                           own (o, m, l). GQA is handled by shaping q as
                           (B, KV, G, hd) — all G query heads of one kv head
                           share the K/V tiles of a grid cell.
  * ``_fd_paged_kernel`` — paged cache. Grid (B, KV, max_pages) with the
                           page table as a *scalar-prefetch* operand: the
                           K/V BlockSpec index maps dereference
                           ``page_table[b, j]`` to pick the physical pool
                           page to DMA, so the kernel gathers pages without
                           ever materializing a dense per-sequence copy.
                           Each page is one split (page_size is aligned to
                           the KV block); unmapped pages (-1) clamp to page
                           0 and are masked out by the bias.

Off-TPU both kernels run in interpret mode (how this repo validates them);
the wall-clock caveat of EXPERIMENTS.md §Perf pair F applies — the honest
CPU signal is the XLA peak-memory column of ``benchmarks/decode_bench.py``.
TPU layout note: the per-split stats outputs are (..., n_splits, G) with G
in the lane dimension; for small G this under-fills the 128-lane tile, but
the stats are O(B·H·n_splits) — noise next to the K/V traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ------------------------------------------------------------ mask -> bias --
def decode_bias(pos, t, *, window=None):
    """Additive f32 bias row(s) for rolling-slot decode attention.

    ``pos``: (W,) or (B, W) absolute position stored in each cache slot
    (-1 = empty); ``t``: scalar or (B,) current decode position per
    sequence. A slot is attendable iff 0 <= pos <= t and (when a sliding
    window is set) pos > t - window. Returns (B, W) (or (1, W) for shared
    scalar inputs) with 0.0 on attendable slots and NEG_INF elsewhere —
    the ONE definition of decode-mask semantics, shared by the Pallas
    kernels, the jnp oracle, and the `_sdpa` fallback path.
    """
    pos = jnp.asarray(pos)
    t = jnp.asarray(t)
    if pos.ndim == 1:
        pos = pos[None]
    tb = t[:, None] if t.ndim == 1 else t[None, None]
    valid = jnp.logical_and(pos >= 0, pos <= tb)
    if window is not None:
        valid = jnp.logical_and(valid, pos > tb - window)
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def paged_bias(page_table, seq_len, page_size, *, window=None):
    """Additive f32 bias for paged decode attention.

    Logical token i of sequence b lives at slot i % page_size of page
    i // page_size; ``page_table``: (B, max_pages) physical page ids
    (-1 = unmapped); ``seq_len``: (B,) tokens written so far (the query
    attends positions < seq_len, i.e. t = seq_len - 1 inclusive of the
    just-written token). Returns (B, max_pages * page_size).
    """
    B, maxp = page_table.shape
    pos = jnp.arange(maxp * page_size, dtype=jnp.int32)[None]        # (1, L)
    sl = seq_len[:, None]
    valid = pos < sl
    if window is not None:
        valid = jnp.logical_and(valid, pos > sl - 1 - window)
    mapped = (page_table >= 0)[:, :, None]                            # (B, maxp, 1)
    valid = jnp.logical_and(
        valid, jnp.broadcast_to(mapped, (B, maxp, page_size)).reshape(B, -1))
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


# ------------------------------------------------------------------ kernels --
def _fd_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref,
               m_scr, l_scr, acc_scr, *, scale, n_inner):
    i = pl.program_id(3)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                          # (G, hd)
    k = k_ref[0, :, 0, :]                                    # (blk_k, hd)
    v = v_ref[0, :, 0, :]
    bias = bias_ref[0]                                       # (blk_k,)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale + bias[None, :]                                # (G, blk_k)

    m_prev = m_scr[...]                                      # (G, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    # masked entries carry bias <= NEG_INF, so exp underflows to exact 0
    p = jnp.exp(logits - m_safe)
    alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(i == n_inner - 1)
    def _finish():
        norm = jnp.where(l_new <= 0.0, 1.0, l_new)
        o_ref[0, 0, 0] = (acc / norm).astype(o_ref.dtype)
        m_ref[0, 0, 0] = m_new[:, 0]
        l_ref[0, 0, 0] = l_new[:, 0]


def _fd_paged_kernel(tbl_ref, q_ref, k_ref, v_ref, bias_ref,
                     o_ref, m_ref, l_ref, *, scale):
    # one page == one split: single-shot softmax, no scratch recurrence
    q = q_ref[0, 0]                                          # (G, hd)
    k = k_ref[0, :, 0, :]                                    # (ps, hd)
    v = v_ref[0, :, 0, :]
    bias = bias_ref[0]                                       # (ps,)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale + bias[None, :]
    m = jnp.max(logits, axis=1, keepdims=True)               # (G, 1)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    p = jnp.exp(logits - m_safe)
    l = jnp.sum(p, axis=1, keepdims=True)
    o = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) / jnp.where(l <= 0.0, 1.0, l)
    o_ref[0, 0, 0] = o.astype(o_ref.dtype)
    m_ref[0, 0, 0] = m[:, 0]
    l_ref[0, 0, 0] = l[:, 0]


# ------------------------------------------------------------ split combine --
def combine_splits(o, m, l):
    """Merge per-split partials with logsumexp algebra.

    o: (B, KV, S, G, hd) per-split normalized outputs, m/l: (B, KV, S, G)
    running max / softmax mass per split (axis 2 = splits). Returns
    (o: (B, H, hd), m: (B, H), l: (B, H)) with H = KV*G (head h = kv*G + g,
    the repo's GQA grouping) — global stats so the result can be merged
    AGAIN across shards with the same algebra (decode_sharded.py).
    Fully-masked splits carry (m, l) = (NEG_INF, 0) and contribute nothing.
    """
    B, KV, S, G, hd = o.shape
    m_glob = jnp.max(m, axis=2)                              # (B, KV, G)
    m_safe = jnp.where(m_glob <= NEG_INF / 2, 0.0, m_glob)
    w = l * jnp.exp(m - m_safe[:, :, None])                  # (B, KV, S, G)
    l_glob = jnp.sum(w, axis=2)
    o_glob = jnp.sum(o * w[..., None], axis=2) / jnp.maximum(
        l_glob, 1e-20)[..., None]
    return (o_glob.reshape(B, KV * G, hd),
            jnp.where(m_glob <= NEG_INF / 2, NEG_INF, m_glob).reshape(B, KV * G),
            l_glob.reshape(B, KV * G))


def _pick_splits(n_blocks, n_splits):
    """Largest divisor of n_blocks that is <= n_splits (static)."""
    s = max(1, min(n_splits, n_blocks))
    while n_blocks % s:
        s -= 1
    return s


# ----------------------------------------------------------------- wrappers --
def flash_decode(q, k, v, bias, *, scale=None, blk_k=128, n_splits=8,
                 interpret=False, return_stats=False):
    """Dense split-K flash decode.

    q: (B, H, hd) one query row per sequence; k/v: (B, W, KV, hd) rolling
    cache; bias: (B, W) or (1, W) additive mask row (``decode_bias``).
    W is padded to the KV block with NEG_INF bias; the block count is split
    into the largest divisor <= ``n_splits`` parallel grid cells. Returns
    (B, H, hd), or (o, m, l) with (B, H) global stats when
    ``return_stats`` (the cross-shard merge contract).
    """
    B, H, hd = q.shape
    W, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = float(scale if scale is not None else 1.0 / (hd ** 0.5))
    blk_k = min(blk_k, max(W, 8))
    Wp = -(-W // blk_k) * blk_k
    if Wp != W:
        pad = ((0, 0), (0, Wp - W), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        bias = jnp.pad(bias, ((0, 0), (0, Wp - W)), constant_values=NEG_INF)
    if bias.shape[0] != B:
        bias = jnp.broadcast_to(bias, (B, Wp))
    nk = Wp // blk_k
    ns = _pick_splits(nk, n_splits)
    n_inner = nk // ns
    qg = q.reshape(B, KV, G, hd)

    kernel = functools.partial(_fd_kernel, scale=scale, n_inner=n_inner)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(B, KV, ns, n_inner),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s, i: (b, h, 0, 0)),
            pl.BlockSpec((1, blk_k, 1, hd),
                         lambda b, h, s, i: (b, s * n_inner + i, h, 0)),
            pl.BlockSpec((1, blk_k, 1, hd),
                         lambda b, h, s, i: (b, s * n_inner + i, h, 0)),
            pl.BlockSpec((1, blk_k), lambda b, h, s, i: (b, s * n_inner + i)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, 1, G, hd), lambda b, h, s, i: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, h, s, i: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, h, s, i: (b, h, s, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, KV, ns, G, hd), q.dtype),
            jax.ShapeDtypeStruct((B, KV, ns, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, ns, G), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, bias)
    og, mg, lg = combine_splits(o.astype(jnp.float32), m, l)
    og = og.astype(q.dtype)
    return (og, mg, lg) if return_stats else og


def flash_decode_paged(q, k_pool, v_pool, page_table, bias, *, scale=None,
                       interpret=False, return_stats=False):
    """Paged split-K flash decode (one page = one split).

    q: (B, H, hd); k_pool/v_pool: (P, page_size, KV, hd) — the *shared* page
    pool; page_table: (B, max_pages) int32 physical page per logical page
    (-1 unmapped); bias: (B, max_pages * page_size) (``paged_bias``). The
    page table is a scalar-prefetch operand: the K/V index maps dereference
    it to choose the pool page each grid cell DMAs, so unmapped logical
    pages cost a clamped re-read of page 0 (fully bias-masked) and no dense
    gather ever exists.
    """
    B, H, hd = q.shape
    P, ps, KV, _ = k_pool.shape
    maxp = page_table.shape[1]
    G = H // KV
    scale = float(scale if scale is not None else 1.0 / (hd ** 0.5))
    qg = q.reshape(B, KV, G, hd)

    kernel = functools.partial(_fd_paged_kernel, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, tbl: (jnp.maximum(tbl[b, j], 0), 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, h, j, tbl: (jnp.maximum(tbl[b, j], 0), 0, h, 0)),
            pl.BlockSpec((1, ps), lambda b, h, j, tbl: (b, j)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, 1, G, hd), lambda b, h, j, tbl: (b, h, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, h, j, tbl: (b, h, j, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, h, j, tbl: (b, h, j, 0)),
        ),
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((B, KV, maxp, G, hd), q.dtype),
            jax.ShapeDtypeStruct((B, KV, maxp, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, maxp, G), jnp.float32),
        ),
        interpret=interpret,
    )(page_table, qg, k_pool, v_pool, bias)
    og, mg, lg = combine_splits(o.astype(jnp.float32), m, l)
    og = og.astype(q.dtype)
    return (og, mg, lg) if return_stats else og
