"""SSD (Mamba2) intra-chunk kernel: the quadratic hot-spot of the chunked
state-space scan, as a Pallas TPU kernel.

Per (batch, chunk, head) grid step, entirely in VMEM:
    l        = cumsum(log_a)                       (Q,)
    scores   = C Bᵀ                                (Q,Q)   [MXU]
    decay    = exp(l_i − l_j) · causal_mask        (Q,Q)
    y_intra  = (scores ⊙ decay) u                  (Q,P)   [MXU]
    S_chunk  = Bᵀ (u ⊙ exp(l_Q − l))               (N,P)   [MXU]
    g        = exp(l_Q)                            scalar

The O(L/Q) inter-chunk combination (associative scan over (g, S) + the
rank-1 correction C·h_prev·exp(l)) stays in jnp — it is tiny and latency
bound, not compute bound. Forward-only (deployment path), validated against
the pure-jnp ``ssm.ssd_chunked`` oracle in interpret mode.

Block shapes: Q (chunk) and P (head_dim) are the MXU dims — keep them at
128/64; N (state) ≤ 256 rides along in VMEM. VMEM footprint per step ≈
Q·(2N + 2P + Q) · 4B ≈ 0.3 MB at Q=128, N=P=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(u_ref, la_ref, b_ref, c_ref, y_ref, s_ref, g_ref, l_ref, *, Q):
    u = u_ref[0, 0, 0].astype(jnp.float32)            # (Q, P)
    la = la_ref[0, 0, 0].astype(jnp.float32)          # (Q,)
    B = b_ref[0, 0].astype(jnp.float32)               # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)               # (Q, N)

    l = jnp.cumsum(la)                                 # (Q,)
    rel = l[:, None] - l[None, :]                      # l_i - l_j
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )
    decay = jnp.where(causal, jnp.exp(rel), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)   # (Q,Q)
    y = jax.lax.dot_general(scores * decay, u, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)        # (Q,P)
    s_dec = jnp.exp(l[-1] - l)                         # (Q,)
    S = jax.lax.dot_general(B, u * s_dec[:, None], (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)        # (N,P)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    s_ref[0, 0, 0] = S.astype(s_ref.dtype)
    g_ref[0, 0, 0] = jnp.exp(l[-1])
    l_ref[0, 0, 0] = l.astype(l_ref.dtype)


def ssd_intra(u, log_a, Bv, Cv, *, interpret=False):
    """u: (B,nc,H,Q,P); log_a: (B,nc,H,Q); Bv/Cv: (B,nc,Q,N) (shared heads).

    Returns (y_intra: (B,nc,H,Q,P), S: (B,nc,H,N,P), g: (B,nc,H),
             l: (B,nc,H,Q))."""
    Bb, nc, H, Q, P = u.shape
    N = Bv.shape[-1]
    kernel = functools.partial(_ssd_intra_kernel, Q=Q)
    y, S, g, l = pl.pallas_call(
        kernel,
        grid=(Bb, nc, H),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c, h: (b, c, h, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, c, h: (b, c, h)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c, h: (b, c, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, nc, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, nc, H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, nc, H), jnp.float32),
            jax.ShapeDtypeStruct((Bb, nc, H, Q), jnp.float32),
        ],
        interpret=interpret,
    )(u, log_a, Bv, Cv)
    return y, S, g, l


def ssd_chunked_pallas(u, log_a, Bv, Cv, chunk: int, h0=None, *, interpret=False):
    """Drop-in for ``ssm.ssd_chunked`` (shared-heads B/C) with the intra-chunk
    work in the Pallas kernel and the inter-chunk scan in jnp."""
    Bb, L, H, P = u.shape
    assert L % chunk == 0
    nc, Q = L // chunk, chunk
    N = Bv.shape[-1]
    u_r = u.reshape(Bb, nc, Q, H, P).transpose(0, 1, 3, 2, 4)
    la_r = log_a.reshape(Bb, nc, Q, H).transpose(0, 1, 3, 2)
    Bv_r = Bv.reshape(Bb, nc, Q, N)
    Cv_r = Cv.reshape(Bb, nc, Q, N)
    y_intra, S, g, l = ssd_intra(u_r, la_r, Bv_r, Cv_r, interpret=interpret)

    def combine(left, right):
        g_l, s_l = left
        g_r, s_r = right
        return g_l * g_r, g_r[..., None, None] * s_l + s_r

    g_scan, S_scan = jax.lax.associative_scan(combine, (g, S), axis=1)
    if h0 is not None:
        h0 = h0.astype(jnp.float32)
        cumg = jnp.exp(jnp.cumsum(jnp.log(jnp.maximum(g, 1e-38)), axis=1))
        S_scan = S_scan + cumg[..., None, None] * h0[:, None]
    h_final = S_scan[:, -1]
    h_prev = jnp.concatenate(
        [h0[:, None] if h0 is not None else jnp.zeros_like(S_scan[:, :1]), S_scan[:, :-1]],
        axis=1,
    )
    y_inter = jnp.einsum("bcin,bchnp->bchip", Cv_r, h_prev) * jnp.exp(l)[..., None]
    y = (y_intra + y_inter).transpose(0, 1, 3, 2, 4).reshape(Bb, L, H, P)
    return y, h_final
