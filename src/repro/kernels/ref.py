"""Pure-jnp oracles for every Pallas kernel (correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _ref_mask(S, T=None, *, causal, window, valid_len):
    T = S if T is None else T
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = kj <= qi
    if window is not None:
        mask = jnp.logical_and(mask, kj > qi - window)
    if valid_len is not None:
        mask = jnp.logical_and(mask, kj < valid_len)
    return mask


def _ref_logits(q, k, scale, *, causal, window, valid_len, bias=None):
    """Masked (B,KV,G,Sq,Sk) logits + mask from grouped heads. ``bias``:
    optional (B|1, Sq, Sk) additive logit bias (explicit masks)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    qg = q.reshape(B, S, KV, H // KV, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if bias is not None:
        logits = logits + bias[:, None, None]
    mask = _ref_mask(S, T, causal=causal, window=window, valid_len=valid_len)
    return jnp.where(mask[None, None, None], logits, NEG_INF), mask


def flash_attention_fwd_ref(q, k, v, *, causal=True, window=None,
                            valid_len=None, scale=None, bias=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) -> (o: (B,Sq,H,hd), lse: (B,H,Sq)).
    GQA via head grouping; lse is the per-row logsumexp residual (0 for
    fully-masked rows, matching the kernel's guard)."""
    B, S, H, hd = q.shape
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    logits, _ = _ref_logits(q, k, scale, causal=causal, window=window,
                            valid_len=valid_len, bias=bias)
    m = jnp.max(logits, axis=-1)
    m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    l = jnp.sum(jnp.exp(logits - m_safe[..., None]), axis=-1)
    lse = m_safe + jnp.log(jnp.where(l <= 0.0, 1.0, l))
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return (
        out.reshape(B, S, H, hd).astype(q.dtype),
        lse.reshape(B, H, S),
    )


def flash_attention_ref(q, k, v, *, causal=True, window=None, valid_len=None,
                        scale=None, bias=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd). GQA via grouping."""
    return flash_attention_fwd_ref(q, k, v, causal=causal, window=window,
                                   valid_len=valid_len, scale=scale,
                                   bias=bias)[0]


def _ref_p(q, k, lse, scale, *, causal, window, valid_len, bias=None):
    """(B,KV,G,Sq,Sk) attention weights recomputed from the stored lse."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    logits, mask = _ref_logits(q, k, scale, causal=causal, window=window,
                               valid_len=valid_len, bias=bias)
    lseg = lse.reshape(B, KV, H // KV, S)
    return jnp.where(mask[None, None, None],
                     jnp.exp(logits - lseg[..., None]), 0.0)


def flash_attention_bwd_ref(q, k, v, o, lse, do, *, causal=True, window=None,
                            valid_len=None, scale=None, bias=None):
    """Dense-jnp backward from the stored lse: returns (dq, dk, dv).

    dP = dO Vᵀ, Δ = rowsum(dO ∘ O), dS = P ∘ (dP − Δ);
    dQ = scale·dS K, dK = scale·dSᵀ Q, dV = Pᵀ dO (GQA group-summed).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    p = _ref_p(q, k, lse, scale, causal=causal, window=window,
               valid_len=valid_len, bias=bias)
    qg = q.reshape(B, S, KV, G, hd)
    dog = do.reshape(B, S, KV, G, hd).astype(jnp.float32)
    delta = jnp.einsum("bshd,bshd->bsh", o.astype(jnp.float32),
                       do.astype(jnp.float32)).reshape(B, S, KV, G)
    dp = jnp.einsum("bskgh,btkh->bkgst", dog, v,
                    preferred_element_type=jnp.float32)
    ds = p * (dp - delta.transpose(0, 2, 3, 1)[..., None])
    dq = scale * jnp.einsum("bkgst,btkh->bskgh", ds, k,
                            preferred_element_type=jnp.float32)
    dk = scale * jnp.einsum("bkgst,bskgh->btkh", ds, qg,
                            preferred_element_type=jnp.float32)
    dv = jnp.einsum("bkgst,bskgh->btkh", p, dog,
                    preferred_element_type=jnp.float32)
    return (dq.reshape(B, S, H, hd).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


def flash_attention_jvp_ref(q, k, v, o, lse, qt, kt, vt, *, causal=True,
                            window=None, valid_len=None, scale=None,
                            bias=None):
    """Dense-jnp tangent from the stored lse: returns (ȯ, l̇se).

    Ṡ = scale·(Q̇Kᵀ + QK̇ᵀ), t = rowsum(P ∘ Ṡ);
    ȯ = Σ_j P_ij (Ṡ_ij v_j + v̇_j) − t ∘ o, l̇se = t.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    p = _ref_p(q, k, lse, scale, causal=causal, window=window,
               valid_len=valid_len, bias=bias)
    qg = q.reshape(B, S, KV, G, hd)
    qtg = qt.reshape(B, S, KV, G, hd)
    st = scale * (
        jnp.einsum("bskgh,btkh->bkgst", qtg, k,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bskgh,btkh->bkgst", qg, kt,
                     preferred_element_type=jnp.float32)
    )
    r = p * st
    g = (jnp.einsum("bkgst,btkh->bskgh", r, v,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bkgst,btkh->bskgh", p, vt,
                      preferred_element_type=jnp.float32))
    t = jnp.sum(r, axis=-1)                                   # (B,KV,G,S)
    t_bsh = t.transpose(0, 3, 1, 2).reshape(B, S, H)
    ot = g.reshape(B, S, H, hd) - t_bsh[..., None] * o.astype(jnp.float32)
    return ot.astype(o.dtype), t.reshape(B, H, S)


def flash_decode_ref(q, k, v, bias, *, scale=None):
    """Dense decode oracle. q: (B,H,hd), k/v: (B,W,KV,hd), bias: (B|1,W)
    additive mask row (0 attendable / NEG_INF masked) -> (B,H,hd).
    Independent dense softmax — ground truth for the split-K kernel."""
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    logits = jnp.einsum("bkgh,btkh->bkgt", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = logits + bias[:, None, None, :]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)


def flash_decode_paged_ref(q, k_pool, v_pool, page_table, bias, *, scale=None):
    """Paged decode oracle: gather the logical KV in jnp (dense copy — the
    thing the kernel avoids) then run the dense oracle."""
    B = q.shape[0]
    ps = k_pool.shape[1]
    pages = jnp.maximum(page_table, 0)                       # (B, maxp)
    k = k_pool[pages].reshape(B, -1, *k_pool.shape[2:])      # (B, maxp*ps, KV, hd)
    v = v_pool[pages].reshape(B, -1, *v_pool.shape[2:])
    return flash_decode_ref(q, k, v, bias, scale=scale)


def bicgstab_x_update_ref(x, p, s, alpha, gamma):
    """x + alpha*p + gamma*s in f32."""
    return (x.astype(jnp.float32) + alpha * p.astype(jnp.float32)
            + gamma * s.astype(jnp.float32))


def bicgstab_residual_dots_ref(s, As, r0s, gamma):
    """r = s - gamma*As; returns (r, <r,r0s>, <r,r>)."""
    r = s.astype(jnp.float32) - gamma * As.astype(jnp.float32)
    return r, jnp.vdot(r, r0s.astype(jnp.float32)), jnp.vdot(r, r)


def dot2_ref(u, v):
    """(<u,v>, <v,v>) in f32."""
    uf, vf = u.astype(jnp.float32), v.astype(jnp.float32)
    return jnp.vdot(uf, vf), jnp.vdot(vf, vf)
