"""Pure-jnp oracles for every Pallas kernel (correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B,S,H,hd), k/v: (B,S,KV,hd) -> (B,S,H,hd). GQA via head grouping."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = kj <= qi
    if window is not None:
        mask = jnp.logical_and(mask, kj > qi - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def bicgstab_x_update_ref(x, p, s, alpha, gamma):
    """x + alpha*p + gamma*s in f32."""
    return (x.astype(jnp.float32) + alpha * p.astype(jnp.float32)
            + gamma * s.astype(jnp.float32))


def bicgstab_residual_dots_ref(s, As, r0s, gamma):
    """r = s - gamma*As; returns (r, <r,r0s>, <r,r>)."""
    r = s.astype(jnp.float32) - gamma * As.astype(jnp.float32)
    return r, jnp.vdot(r, r0s.astype(jnp.float32)), jnp.vdot(r, r)


def dot2_ref(u, v):
    """(<u,v>, <v,v>) in f32."""
    uf, vf = u.astype(jnp.float32), v.astype(jnp.float32)
    return jnp.vdot(uf, vf), jnp.vdot(vf, vf)
