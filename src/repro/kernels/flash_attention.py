"""Flash attention (forward + backward + JVP) as Pallas TPU kernels.

Online-softmax blockwise attention: every kernel runs on a 4-D grid whose
innermost dimension is the reduction axis and keeps its accumulators in VMEM
scratch across that axis. Causal and sliding-window masks are applied inside
the block; fully-masked key blocks contribute nothing (the m/l recurrence is
a no-op for -inf rows). GQA is handled in the index maps (kv head =
q head // group). ``valid_len`` masks a zero-padded key tail so
non-block-aligned sequences can be padded to the 128 lane tile and sliced
(see kernels.flash_ad.flash_mha). Query and key lengths may differ
(cross-attention), and every kernel takes an optional (B|1, Sq, Sk) f32
additive logit ``bias`` operand — the pad-and-mask route for explicit
attention masks (0 attendable / -1e30 dropped; batch-1 biases broadcast in
the index map without a materialized copy).

Kernels (S = q length, hd = head dim):

  * ``_fa_kernel``      — forward; emits O and the per-row logsumexp
                          LSE_i = m_i + log l_i, the residual every other
                          kernel uses to recompute P = exp(S·scale − LSE)
                          blockwise instead of storing the (S, S) weights.
  * ``_fa_dq_kernel``   — backward dQ pass: grid (B, H, q_blocks, k_blocks),
                          dQ_i = scale · Σ_j P_ij (dP_ij − Δ_i) K_j with
                          dP = dO Vᵀ and Δ = rowsum(dO ∘ O) precomputed.
  * ``_fa_dkv_kernel``  — backward dK/dV pass: grid (B, H, k_blocks,
                          q_blocks) (reduction over q blocks), emitting
                          per-q-head dK/dV; the GQA group-sum happens in the
                          caller (kernels.ops.flash_attention_bwd).
  * ``_fa_jvp_kernel``  — forward-mode tangent pass: with Ṡ = scale·(Q̇Kᵀ +
                          QK̇ᵀ), accumulates G_i = Σ_j P_ij (Ṡ_ij V_j + V̇_j)
                          and t_i = Σ_j P_ij Ṡ_ij; the caller finishes
                          Ȯ = G − t ∘ O (and L̇SE = t). This is the extra
                          flash pass that makes the kernel usable under
                          ``jax.linearize`` (the curvature engine's J·v).

BlockSpecs stage (blk_q x hd) query tiles and (blk_k x hd) key/value tiles
into VMEM; the MXU sees (blk_q x hd) @ (hd x blk_k) matmuls with
hardware-aligned tiles (blk_* multiples of 128 for f32/bf16). LSE/Δ ride in
(B, H, S) layout with (1, 1, blk_q) blocks, the same layout the stock JAX
flash kernels use for their l/m residuals.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def position_mask(q_pos, k_pos, *, causal, window, valid_len):
    """Broadcasted attention mask from query/key position arrays — the ONE
    definition of the causal/sliding-window/valid-length semantics, shared
    by every Pallas kernel here and by the chunked-jnp second-order route
    (kernels/flash_ad.py), so the two routes cannot drift. The pure-jnp
    oracle (kernels/ref.py) keeps an independent copy on purpose: it is the
    ground truth these semantics are tested against."""
    mask = jnp.ones(jnp.broadcast_shapes(q_pos.shape, k_pos.shape), bool)
    if causal:
        mask = jnp.logical_and(mask, k_pos <= q_pos)
    if window is not None:
        mask = jnp.logical_and(mask, k_pos > q_pos - window)
    if valid_len is not None:
        mask = jnp.logical_and(mask, k_pos < valid_len)
    return mask


def _block_mask(qi, ki, blk_q, blk_k, *, causal, window, valid_len):
    """(blk_q, blk_k) boolean mask for the (qi, ki) grid cell."""
    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    return position_mask(q_pos, k_pos, causal=causal, window=window,
                         valid_len=valid_len)


def _fa_kernel(q_ref, k_ref, v_ref, *refs,
               scale, causal, window, valid_len, blk_q, blk_k, n_k_blocks,
               has_bias=False):
    if has_bias:
        bias_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr = refs
    else:
        o_ref, lse_ref, m_scr, l_scr, acc_scr = refs
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                                   # (blk_q, hd)
    k = k_ref[0, :, 0, :]                                   # (blk_k, hd)
    v = v_ref[0, :, 0, :]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                               # (blk_q, blk_k)
    if has_bias:
        # additive f32 bias tile (explicit masks: 0 attend / NEG_INF drop);
        # masked entries underflow exp() to exact 0 below
        logits = logits + bias_ref[0]

    mask = _block_mask(qi, ki, blk_q, blk_k, causal=causal, window=window,
                       valid_len=valid_len)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                                     # (blk_q, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(jnp.where(mask, logits - m_safe, NEG_INF))  # (blk_q, blk_k)
    alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        norm = jnp.where(l_new <= 0.0, 1.0, l_new)
        o_ref[0, :, 0, :] = (acc / norm).astype(o_ref.dtype)
        # per-row logsumexp residual; fully-masked rows get lse = 0 and the
        # downstream kernels mask their P entries explicitly anyway.
        m_fin = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        lse_ref[0, 0, :] = (m_fin + jnp.log(norm))[:, 0]


def _recompute_p(q, k, lse, qi, ki, *, scale, causal, window, valid_len,
                 blk_q, blk_k, bias=None):
    """P block from the stored LSE: P_ij = exp(scale·q_i·k_j + bias − lse_i)."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    if bias is not None:
        s = s + bias
    mask = _block_mask(qi, ki, blk_q, blk_k, causal=causal, window=window,
                       valid_len=valid_len)
    return jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0), mask


def _fa_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, *refs,
                  scale, causal, window, valid_len, blk_q, blk_k,
                  n_k_blocks, has_bias=False):
    if has_bias:
        bias_ref, dq_ref, acc_scr = refs
    else:
        dq_ref, acc_scr = refs
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]
    k = k_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :]
    do = do_ref[0, :, 0, :]
    lse = lse_ref[0, 0, :]
    delta = delta_ref[0, 0, :]

    p, _ = _recompute_p(q, k, lse, qi, ki, scale=scale, causal=causal,
                        window=window, valid_len=valid_len,
                        blk_q=blk_q, blk_k=blk_k,
                        bias=bias_ref[0] if has_bias else None)
    dp = jax.lax.dot_general(                               # dO @ Vᵀ
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta[:, None])                          # (blk_q, blk_k)
    acc_scr[...] += jax.lax.dot_general(                    # dS @ K
        ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        dq_ref[0, :, 0, :] = (acc_scr[...] * scale).astype(dq_ref.dtype)


def _fa_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, *refs,
                   scale, causal, window, valid_len, blk_q, blk_k,
                   n_q_blocks, has_bias=False):
    if has_bias:
        bias_ref, dk_ref, dv_ref, dk_scr, dv_scr = refs
    else:
        dk_ref, dv_ref, dk_scr, dv_scr = refs
    # grid (B, H, k_blocks, q_blocks): reduction over q blocks (innermost)
    ki = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, :, 0, :]
    k = k_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :]
    do = do_ref[0, :, 0, :]
    lse = lse_ref[0, 0, :]
    delta = delta_ref[0, 0, :]

    p, _ = _recompute_p(q, k, lse, qi, ki, scale=scale, causal=causal,
                        window=window, valid_len=valid_len,
                        blk_q=blk_q, blk_k=blk_k,
                        bias=bias_ref[0] if has_bias else None)
    dv_scr[...] += jax.lax.dot_general(                     # Pᵀ @ dO
        p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta[:, None])
    dk_scr[...] += jax.lax.dot_general(                     # dSᵀ @ Q
        ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(qi == n_q_blocks - 1)
    def _finish():
        dk_ref[0, :, 0, :] = (dk_scr[...] * scale).astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


def _fa_jvp_kernel(q_ref, k_ref, v_ref, qt_ref, kt_ref, vt_ref, lse_ref,
                   *refs, scale, causal, window, valid_len, blk_q, blk_k,
                   n_k_blocks, has_bias=False):
    if has_bias:
        bias_ref, g_ref, t_ref, g_scr, t_scr = refs
    else:
        g_ref, t_ref, g_scr, t_scr = refs
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        g_scr[...] = jnp.zeros_like(g_scr)
        t_scr[...] = jnp.zeros_like(t_scr)

    q = q_ref[0, :, 0, :]
    k = k_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :]
    qt = qt_ref[0, :, 0, :]
    kt = kt_ref[0, :, 0, :]
    vt = vt_ref[0, :, 0, :]
    lse = lse_ref[0, 0, :]

    p, mask = _recompute_p(q, k, lse, qi, ki, scale=scale, causal=causal,
                           window=window, valid_len=valid_len,
                           blk_q=blk_q, blk_k=blk_k,
                           bias=bias_ref[0] if has_bias else None)
    st = (jax.lax.dot_general(                              # Q̇ Kᵀ + Q K̇ᵀ
        qt, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) + jax.lax.dot_general(
        q, kt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )) * scale
    r = p * jnp.where(mask, st, 0.0)                        # P ∘ Ṡ
    g_scr[...] += jax.lax.dot_general(
        r.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) + jax.lax.dot_general(
        p.astype(vt.dtype), vt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    t_scr[...] += jnp.sum(r, axis=1, keepdims=True)

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        g_ref[0, :, 0, :] = g_scr[...].astype(g_ref.dtype)
        t_ref[0, 0, :] = t_scr[:, 0]


# --------------------------------------------------------------- wrappers --
def _shapes(q, k, blk_q, blk_k):
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, Sk, blk_q, blk_k)
    return B, Sq, Sk, H, hd, KV, G, blk_q, blk_k, Sq // blk_q, Sk // blk_k


def _resolve_scale(scale, hd):
    return float(scale if scale is not None else 1.0 / (hd ** 0.5))


def _bias_spec(bias, blk_q, blk_k, transposed_grid=False):
    """BlockSpec for the optional (Bb, Sq, Sk) f32 additive-bias operand.
    Bb == 1 broadcasts over the batch in the index map (no materialized
    copy). ``transposed_grid``: the dK/dV grid is (B, H, k, q)."""
    bb = bias.shape[0]
    if transposed_grid:
        return pl.BlockSpec((1, blk_q, blk_k),
                            lambda b, h, j, i: (b if bb > 1 else 0, i, j))
    return pl.BlockSpec((1, blk_q, blk_k),
                        lambda b, h, i, j: (b if bb > 1 else 0, i, j))


def flash_attention_fwd(q, k, v, *, causal=True, window=None, valid_len=None,
                        scale=None, blk_q=128, blk_k=128, interpret=False,
                        bias=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) -> (o: (B,Sq,H,hd), lse: (B,H,Sq)).
    ``bias``: optional (B|1, Sq, Sk) f32 additive logit bias (explicit
    masks: 0 attend / NEG_INF drop)."""
    B, Sq, Sk, H, hd, KV, G, blk_q, blk_k, nq, nk = _shapes(q, k, blk_q, blk_k)
    scale = _resolve_scale(scale, hd)
    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        valid_len=valid_len, blk_q=blk_q, blk_k=blk_k, n_k_blocks=nk,
        has_bias=bias is not None,
    )
    in_specs = [
        pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
    ]
    args = (q, k, v)
    if bias is not None:
        in_specs.append(_bias_spec(bias, blk_q, blk_k))
        args = args + (bias,)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, 1, blk_q), lambda b, h, i, j: (b, h, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


def flash_attention(q, k, v, *, causal=True, window=None, valid_len=None,
                    scale=None, blk_q=128, blk_k=128, interpret=False,
                    bias=None):
    """Forward only (serving path): q (B,Sq,H,hd), k/v (B,Sk,KV,hd) -> o."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, valid_len=valid_len,
        scale=scale, blk_q=blk_q, blk_k=blk_k, interpret=interpret, bias=bias,
    )[0]


def flash_attention_dq(q, k, v, do, lse, delta, *, causal=True, window=None,
                       valid_len=None, scale=None, blk_q=128, blk_k=128,
                       interpret=False, bias=None):
    """Backward dQ pass. lse/delta: (B,H,Sq). Returns dq (B,Sq,H,hd)."""
    B, Sq, Sk, H, hd, KV, G, blk_q, blk_k, nq, nk = _shapes(q, k, blk_q, blk_k)
    scale = _resolve_scale(scale, hd)
    kernel = functools.partial(
        _fa_dq_kernel, scale=scale, causal=causal, window=window,
        valid_len=valid_len, blk_q=blk_q, blk_k=blk_k, n_k_blocks=nk,
        has_bias=bias is not None,
    )
    in_specs = [
        pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        pl.BlockSpec((1, 1, blk_q), lambda b, h, i, j: (b, h, i)),
        pl.BlockSpec((1, 1, blk_q), lambda b, h, i, j: (b, h, i)),
    ]
    args = (q, k, v, do, lse, delta)
    if bias is not None:
        in_specs.append(_bias_spec(bias, blk_q, blk_k))
        args = args + (bias,)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((blk_q, hd), jnp.float32)],
        interpret=interpret,
    )(*args)


def flash_attention_dkv(q, k, v, do, lse, delta, *, causal=True, window=None,
                        valid_len=None, scale=None, blk_q=128, blk_k=128,
                        interpret=False, bias=None):
    """Backward dK/dV pass, per *query* head (the caller sums each GQA
    group). Returns (dk_h, dv_h): (B,Sk,H,hd)."""
    B, Sq, Sk, H, hd, KV, G, blk_q, blk_k, nq, nk = _shapes(q, k, blk_q, blk_k)
    scale = _resolve_scale(scale, hd)
    kernel = functools.partial(
        _fa_dkv_kernel, scale=scale, causal=causal, window=window,
        valid_len=valid_len, blk_q=blk_q, blk_k=blk_k, n_q_blocks=nq,
        has_bias=bias is not None,
    )
    in_specs = [
        pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, j, i: (b, i, h, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, j, i: (b, j, h // G, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, j, i: (b, j, h // G, 0)),
        pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, j, i: (b, i, h, 0)),
        pl.BlockSpec((1, 1, blk_q), lambda b, h, j, i: (b, h, i)),
        pl.BlockSpec((1, 1, blk_q), lambda b, h, j, i: (b, h, i)),
    ]
    args = (q, k, v, do, lse, delta)
    if bias is not None:
        in_specs.append(_bias_spec(bias, blk_q, blk_k, transposed_grid=True))
        args = args + (bias,)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nk, nq),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, j, i: (b, j, h, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, j, i: (b, j, h, 0)),
        ),
        out_shape=(
            # per-q-head partials stay f32 so the GQA group-sum outside the
            # kernel accumulates at full precision even for bf16 models
            jax.ShapeDtypeStruct((B, Sk, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, Sk, H, hd), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((blk_k, hd), jnp.float32),
            pltpu.VMEM((blk_k, hd), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


def flash_attention_jvp(q, k, v, qt, kt, vt, lse, *, causal=True, window=None,
                        valid_len=None, scale=None, blk_q=128, blk_k=128,
                        interpret=False, bias=None):
    """Tangent pass: returns (g: (B,Sq,H,hd), t: (B,H,Sq)) with
    g_i = Σ_j P_ij (Ṡ_ij v_j + v̇_j) and t_i = Σ_j P_ij Ṡ_ij; the caller
    forms ȯ = g − t ∘ o (and l̇se = t)."""
    B, Sq, Sk, H, hd, KV, G, blk_q, blk_k, nq, nk = _shapes(q, k, blk_q, blk_k)
    scale = _resolve_scale(scale, hd)
    kernel = functools.partial(
        _fa_jvp_kernel, scale=scale, causal=causal, window=window,
        valid_len=valid_len, blk_q=blk_q, blk_k=blk_k, n_k_blocks=nk,
        has_bias=bias is not None,
    )
    in_specs = [
        pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        pl.BlockSpec((1, 1, blk_q), lambda b, h, i, j: (b, h, i)),
    ]
    args = (q, k, v, qt, kt, vt, lse)
    if bias is not None:
        in_specs.append(_bias_spec(bias, blk_q, blk_k))
        args = args + (bias,)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, 1, blk_q), lambda b, h, i, j: (b, h, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, Sq, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((blk_q, hd), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
