"""Flash attention (prefill/training forward) as a Pallas TPU kernel.

Online-softmax blockwise attention: grid (batch, q_heads, q_blocks,
k_blocks); running max/sum and the output accumulator live in VMEM scratch
and persist across the innermost (k_blocks) grid dimension. Causal and
sliding-window masks are applied inside the block; fully-masked key blocks
contribute nothing (the m/l recurrence is a no-op for -inf rows).

BlockSpecs stage (blk_q x hd) query tiles and (blk_k x hd) key/value tiles
into VMEM; the MXU sees (blk_q x hd) @ (hd x blk_k) matmuls with
hardware-aligned tiles (blk_* multiples of 128 for f32/bf16). GQA is handled
in the index maps (kv head = q head // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale, causal, window, blk_q, blk_k, n_k_blocks):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                                   # (blk_q, hd)
    k = k_ref[0, :, 0, :]                                   # (blk_k, hd)
    v = v_ref[0, :, 0, :]
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                               # (blk_q, blk_k)

    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = jnp.ones((blk_q, blk_k), bool)
    if causal:
        mask = jnp.logical_and(mask, k_pos <= q_pos)
    if window is not None:
        mask = jnp.logical_and(mask, k_pos > q_pos - window)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                                     # (blk_q, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(jnp.where(mask, logits - m_safe, NEG_INF))  # (blk_q, blk_k)
    alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == n_k_blocks - 1)
    def _finish():
        norm = jnp.where(l_new <= 0.0, 1.0, l_new)
        o_ref[0, :, 0, :] = (acc / norm).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    blk_q=128, blk_k=128, interpret=False):
    """q: (B,S,H,hd), k/v: (B,S,KV,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = float(scale if scale is not None else 1.0 / (hd ** 0.5))
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, S)
    assert S % blk_q == 0 and S % blk_k == 0, (S, blk_q, blk_k)
    nq, nk = S // blk_q, S // blk_k
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
