"""Pallas TPU kernels for the perf-critical hot spots, with pure-jnp oracles.

  flash_attention — blockwise online-softmax attention (prefill/train fwd)
  cg_fused        — fused Bi-CG-STAB vector recurrences (the paper's
                    HBM-bound Krylov inner loop)
  ssd_scan        — Mamba2/SSD intra-chunk kernel (zamba2/xLSTM hot-spot)

Validated in interpret mode on CPU against the pure-jnp oracles; compiled
path targets TPU.
"""
from . import ops, ref, ssd_scan
from .ops import bicgstab_residual_dots, bicgstab_x_update, dot2, flash_attention
from .ssd_scan import ssd_chunked_pallas, ssd_intra

__all__ = ["ops", "ref", "ssd_scan", "bicgstab_residual_dots",
           "bicgstab_x_update", "dot2", "flash_attention",
           "ssd_chunked_pallas", "ssd_intra"]
