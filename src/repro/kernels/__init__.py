"""Pallas TPU kernels for the perf-critical hot spots, with pure-jnp oracles.

  flash_attention — blockwise online-softmax attention: forward (+logsumexp
                    residual), backward dQ / dK+dV passes, and a JVP pass
  flash_ad        — the AD closure over those kernels (custom_jvp +
                    linear_call; ``second_order_tangents`` for the
                    exact-Hessian forward-over-reverse traces)
  cg_fused        — fused Bi-CG-STAB vector recurrences (the paper's
                    HBM-bound Krylov inner loop)
  ssd_scan        — Mamba2/SSD intra-chunk kernel (zamba2/xLSTM hot-spot)

Validated in interpret mode on CPU against the pure-jnp oracles; compiled
path targets TPU.
"""
from . import flash_ad, ops, ref, ssd_scan
from .ops import (
    bicgstab_residual_dots,
    bicgstab_x_update,
    dot2,
    flash_attention,
    flash_attention_bwd,
    flash_attention_fwd,
    flash_attention_jvp,
    second_order_tangents,
)
from .ssd_scan import ssd_chunked_pallas, ssd_intra

__all__ = ["flash_ad", "ops", "ref", "ssd_scan", "bicgstab_residual_dots",
           "bicgstab_x_update", "dot2", "flash_attention",
           "flash_attention_bwd", "flash_attention_fwd", "flash_attention_jvp",
           "second_order_tangents", "ssd_chunked_pallas", "ssd_intra"]
