"""Differentiable flash attention: the AD closure over the Pallas kernels.

``flash_mha`` is the training-path entry point (models/attention.py routes
``attend_full`` / ``encoder_attend`` here under ``cfg.use_flash_attention``).
It must compose with every transform the HF optimizer applies to the loss:

  * ``jax.value_and_grad``         — the outer-step gradient (Alg. 2 line 3),
  * ``jax.linearize`` + ``jax.linear_transpose`` — the curvature engine's
    Gauss-Newton product (J·v / Jᵀ·u, core/curvature.py::_gnvp_once),
  * ``jax.linearize(jax.grad(f))`` — the exact-Hessian product
    (forward-over-reverse, every ``curvature_mode``),
  * plain evaluation — the Armijo line search and serving prefill.

**First-order structure.** ``flash_mha`` is a ``jax.custom_jvp`` function
whose tangent rule is an extra flash pass with the saved logsumexp: the
Pallas JVP kernel computes ȯ = Σ_j P_ij(Ṡ_ij v_j + v̇_j) − t ∘ o blockwise,
and it is wired through ``jax.custom_derivatives.linear_call`` so that
*transposing* the tangent (what ``jax.grad`` and ``jax.linear_transpose``
do) lands on the Pallas backward kernels (dQ pass + dK/dV pass). Reverse
mode therefore saves only (q, k, v, o, lse) — O(S) residuals instead of the
O(S²) logits ``_sdpa`` materializes — and the gradient, the line search and
the whole Gauss-Newton Krylov loop run on Pallas kernels.

**Second-order structure.** Exact-Hessian products are forward-over-reverse:
``jax.linearize(jax.grad(loss))`` must forward-differentiate the *transposed*
tangent computation. No custom-transpose mechanism survives that —
``linear_call`` has no JVP rule, ``custom_vjp`` forbids forward mode
outright, and a scan emitted from inside a custom_jvp rule never acquires
the linearity annotations ``lax.scan``'s transpose rule requires (scan
transposition only works on scans that went through scan's *own* jvp rule).
Pallas closure at second order would mean flash double-backward kernels.
Instead, the curvature engine brackets its exact-Hessian operator builds in
``second_order_tangents()``; under that context the entry point swaps the
kernel for ``_chunked_attention`` — a plain-jnp attention chunked over
*query blocks* (a ``jax.checkpoint``-ed ``lax.scan``; K/V are broadcast
consts, per-block outputs are stacked ys, there is no sequence-sized carry).
Being ordinary jnp, JAX derives its gradient, its JVP, and the JVP of its
gradient by standard rules, and remat keeps every direction at O(S·blk)
memory — the (S, S) logits are never materialized, which is exactly what
the Krylov inner loop pays K times per outer step. The routing cannot be
inferred from trace state (``lax.scan``'s jvp rule re-traces bodies with
fresh tracers, hiding any outer transform), so it is explicit and
trace-time: the flag is read when the loss is *traced*, which is when the
engine builds its operators. Misrouting fails loudly: the first-order
entry's nested-forward rule raises with a pointer to the context manager.

Non-block-aligned sequences are padded to the 128-lane tile with the key
tail masked via ``valid_len`` and the output sliced back — the pad/slice is
ordinary jnp, so it is transparent to all of the above; padded query rows
are discarded by the slice and their tangents/cotangents are exact zeros.

One more routing consequence: ``jax.vmap`` over a cached linear map
containing the first-order tangent (core/blocks.py's s-step block
products) has no batching rule for ``linear_call``, so ``hf_step`` builds
the Gauss-Newton operator under ``second_order_tangents()`` whenever
``sstep_s > 1`` — the AD-closed form is plain jnp and vmaps fine (a no-op
for non-flash models). Exact-Hessian s-step operators are already built
under the context by the curvature engine.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from . import flash_attention as fa

NEG_INF = -1e30

_SECOND_ORDER_DEPTH = 0


@contextlib.contextmanager
def second_order_tangents():
    """Trace-time context: flash attention swaps its Pallas custom-AD rules
    for the AD-closed chunked-jnp form, so the traced computation supports
    forward-over-reverse (exact-Hessian products). Wrap the *trace* that
    builds the operator — core/curvature.py does this for every
    exact-Hessian mode."""
    global _SECOND_ORDER_DEPTH
    _SECOND_ORDER_DEPTH += 1
    try:
        yield
    finally:
        _SECOND_ORDER_DEPTH -= 1


def second_order_active() -> bool:
    return _SECOND_ORDER_DEPTH > 0


# --------------------------------------------------- shared AD-pass impls --
def flash_bwd_passes(q, k, v, o, lse, do, **kkw):
    """The attention VJP from the stored lse: Δ precompute, the Pallas dQ
    pass, the Pallas dK/dV pass, and the GQA group-sum (f32 partials).
    The single implementation behind both the linear_call transpose (what
    jax.grad executes) and the public ops.flash_attention_bwd wrapper the
    kernel tests pin — one copy, no drift."""
    delta = jnp.einsum("bshd,bshd->bsh", o.astype(jnp.float32),
                       do.astype(jnp.float32)).transpose(0, 2, 1)
    dq = fa.flash_attention_dq(q, k, v, do, lse, delta, **kkw)
    dkh, dvh = fa.flash_attention_dkv(q, k, v, do, lse, delta, **kkw)
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    dk = dkh.reshape(B, S, KV, G, hd).sum(3)
    dv = dvh.reshape(B, S, KV, G, hd).sum(3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def flash_jvp_pass(q, k, v, o, lse, qt, kt, vt, **kkw):
    """The attention JVP from the stored lse: the Pallas tangent pass plus
    the ȯ = g − t ∘ o finish (and l̇se = t). Single implementation behind
    the linear_call tangent and ops.flash_attention_jvp."""
    g, t = fa.flash_attention_jvp(q, k, v, qt, kt, vt, lse, **kkw)
    ot = g - t.transpose(0, 2, 1)[..., None] * o.astype(jnp.float32)
    return ot.astype(o.dtype), t


# ----------------------------------------------- second-order (jnp) entry --
def _chunked_attention(q, k, v, *, causal, window, scale, valid_len, blk):
    """Attention as a checkpointed scan over query blocks — the AD-closed
    form the exact-Hessian engine traces through.

    Each step computes softmax(q_blk Kᵀ)V for one (blk, S) tile: peak
    memory O(S·blk), never the (S, S) logits. K/V enter as (nonlinear)
    scan consts and the per-block outputs are stacked ys, so ``lax.scan``'s
    jvp rule gives the tangent scan correct linearity annotations — the
    structure every further transform (transpose, jvp-of-transpose)
    composes with by construction. ``jax.checkpoint`` on the body keeps the
    same O(S·blk) bound for all of them (P tiles are recomputed, not
    stored).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    blk = min(blk, S)
    nb = S // blk
    f32 = jnp.float32
    qs = q.reshape(B, nb, blk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def body(_, x):
        qb, i0 = x                                  # qb: (B, blk, KV, G, hd)
        s = jnp.einsum("bskgh,btkh->bkgst", qb, k,
                       preferred_element_type=f32) * scale
        mask = fa.position_mask(i0 + jnp.arange(blk)[:, None],
                                jnp.arange(S)[None, :], causal=causal,
                                window=window, valid_len=valid_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_safe), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        ob = jnp.einsum("bkgst,btkh->bskgh", p / jnp.where(l <= 0.0, 1.0, l),
                        v, preferred_element_type=f32)
        return None, ob.reshape(B, blk, H, hd).astype(q.dtype)

    _, ys = jax.lax.scan(jax.checkpoint(body), None,
                         (qs, jnp.arange(nb) * blk))
    return ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


# -------------------------------------------------------- per-config entry --
@functools.lru_cache(maxsize=None)
def _fa_entry(causal, window, scale, blk_q, blk_k, interpret, valid_len,
              second_order):
    """Build (and cache) the differentiable attention callable for one
    static configuration. ``second_order`` is part of the cache key on
    purpose: the two rule sets must be distinct function objects so no
    jit/trace cache can alias them across contexts."""
    kkw = dict(causal=causal, window=window, valid_len=valid_len,
               scale=scale, blk_q=blk_q, blk_k=blk_k, interpret=interpret)

    if second_order:
        return functools.partial(
            _chunked_attention, causal=causal, window=window, scale=scale,
            valid_len=valid_len, blk=blk_k)

    @jax.custom_jvp
    def fwd_res(q, k, v):
        return fa.flash_attention_fwd(q, k, v, **kkw)

    @fwd_res.defjvp
    def fwd_res_jvp(primals, tangents):
        # Fires only when the primal forward is itself forward-differentiated
        # — i.e. forward-over-reverse reached the first-order entry. The
        # Pallas kernels cannot close that order; fail with the remedy.
        raise NotImplementedError(
            "flash attention: exact-Hessian (forward-over-reverse) traces "
            "must be built under kernels.ops.second_order_tangents() — the "
            "curvature engine does this; wrap any hand-rolled "
            "jvp-of-grad the same way.")

    def _tan(res, lin):
        # JVP flash pass (Pallas): linear in (q̇, k̇, v̇) given residuals.
        return flash_jvp_pass(*res, *lin, **kkw)[0]

    def _tan_transpose(res, ct):
        # Transpose of _tan == the attention VJP: Pallas dQ + dK/dV passes
        # (this is what jax.grad / jax.linear_transpose execute).
        return flash_bwd_passes(*res, ct, **kkw)

    @jax.custom_jvp
    def fa_o(q, k, v):
        return fwd_res(q, k, v)[0]

    @fa_o.defjvp
    def fa_o_jvp(primals, tangents):
        q, k, v = primals
        o, lse = fwd_res(q, k, v)
        ot = jax.custom_derivatives.linear_call(
            _tan, _tan_transpose, (q, k, v, o, lse), tuple(tangents))
        return o, ot

    return jax.jit(fa_o)


# ------------------------------------------------------------ public entry --
def flash_mha(q, k, v, *, causal=True, window=None, scale=None,
              blk_q=128, blk_k=128, interpret=False):
    """Differentiable flash attention with pad-and-mask block alignment.

    q: (B,S,H,hd), k/v: (B,S,KV,hd) -> (B,S,H,hd). When S is not a multiple
    of the kernel block, inputs are zero-padded to the next 128 multiple,
    the padded key tail is masked inside the kernels (``valid_len``) and the
    output is sliced back. The rule set (Pallas first-order vs AD-closed
    chunked-jnp) is picked by ``second_order_tangents()`` at trace time; see
    module docstring.
    """
    B, S, H, hd = q.shape
    if k.shape[1] != S:
        raise ValueError(
            f"flash_mha requires matching q/kv lengths, got {S} vs "
            f"{k.shape[1]} (cross-attention stays on the jnp path)")
    scale = float(scale if scale is not None else 1.0 / (hd ** 0.5))
    # Strict 128-tile contract: any S that is not a 128 multiple is padded
    # (including S < 128) — sub-128 blocks would hand the TPU lane dimension
    # non-aligned logits/LSE tiles. 128-multiple S runs unpadded with the
    # caller's block sizes.
    if S % 128 == 0:
        Sp, valid_len = S, None
    else:
        Sp, valid_len = -(-S // 128) * 128, S
    entry = _fa_entry(causal, window, scale, blk_q, blk_k, bool(interpret),
                      valid_len, second_order_active())
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    o = entry(q, k, v)
    return o[:, :S] if Sp != S else o
