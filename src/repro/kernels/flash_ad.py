"""Differentiable flash attention: the AD closure over the Pallas kernels.

``flash_mha`` is the training-path entry point (models/attention.py routes
``attend_full`` / ``encoder_attend`` here under ``cfg.use_flash_attention``).
It must compose with every transform the HF optimizer applies to the loss:

  * ``jax.value_and_grad``         — the outer-step gradient (Alg. 2 line 3),
  * ``jax.linearize`` + ``jax.linear_transpose`` — the curvature engine's
    Gauss-Newton product (J·v / Jᵀ·u, core/curvature.py::_gnvp_once),
  * ``jax.linearize(jax.grad(f))`` — the exact-Hessian product
    (forward-over-reverse, every ``curvature_mode``),
  * plain evaluation — the Armijo line search and serving prefill.

**First-order structure.** ``flash_mha`` is a ``jax.custom_jvp`` function
whose tangent rule is an extra flash pass with the saved logsumexp: the
Pallas JVP kernel computes ȯ = Σ_j P_ij(Ṡ_ij v_j + v̇_j) − t ∘ o blockwise,
and it is wired through ``jax.custom_derivatives.linear_call`` so that
*transposing* the tangent (what ``jax.grad`` and ``jax.linear_transpose``
do) lands on the Pallas backward kernels (dQ pass + dK/dV pass). Reverse
mode therefore saves only (q, k, v, o, lse) — O(S) residuals instead of the
O(S²) logits ``_sdpa`` materializes — and the gradient, the line search and
the whole Gauss-Newton Krylov loop run on Pallas kernels.

**Second-order structure.** Exact-Hessian products are forward-over-reverse:
``jax.linearize(jax.grad(loss))`` must forward-differentiate the *transposed*
tangent computation. No custom-transpose mechanism survives that —
``linear_call`` has no JVP rule, ``custom_vjp`` forbids forward mode
outright, and a scan emitted from inside a custom_jvp rule never acquires
the linearity annotations ``lax.scan``'s transpose rule requires (scan
transposition only works on scans that went through scan's *own* jvp rule).
Pallas closure at second order would mean flash double-backward kernels.
Instead, the curvature engine brackets its exact-Hessian operator builds in
``second_order_tangents()``; under that context the entry point swaps the
kernel for ``_chunked_attention`` — a plain-jnp attention chunked over
*query blocks* (a ``jax.checkpoint``-ed ``lax.scan``; K/V are broadcast
consts, per-block outputs are stacked ys, there is no sequence-sized carry).
Being ordinary jnp, JAX derives its gradient, its JVP, and the JVP of its
gradient by standard rules, and remat keeps every direction at O(S·blk)
memory — the (S, S) logits are never materialized, which is exactly what
the Krylov inner loop pays K times per outer step. The routing cannot be
inferred from trace state (``lax.scan``'s jvp rule re-traces bodies with
fresh tracers, hiding any outer transform), so it is explicit and
trace-time: the flag is read when the loss is *traced*, which is when the
engine builds its operators. Misrouting fails loudly: the first-order
entry's nested-forward rule raises with a pointer to the context manager.

Non-block-aligned sequences are padded to the 128-lane tile with the key
tail masked via ``valid_len`` and the output sliced back — the pad/slice is
ordinary jnp, so it is transparent to all of the above; padded query rows
are discarded by the slice and their tangents/cotangents are exact zeros.

One more routing consequence: ``jax.vmap`` over a cached linear map
containing the first-order tangent (core/blocks.py's s-step block
products) has no batching rule for ``linear_call``, so ``hf_step`` builds
the Gauss-Newton operator under ``second_order_tangents()`` whenever
``sstep_s > 1`` — the AD-closed form is plain jnp and vmaps fine (a no-op
for non-flash models). Exact-Hessian s-step operators are already built
under the context by the curvature engine.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from . import flash_attention as fa

NEG_INF = -1e30

_SECOND_ORDER_DEPTH = 0


@contextlib.contextmanager
def second_order_tangents():
    """Trace-time context: flash attention swaps its Pallas custom-AD rules
    for the AD-closed chunked-jnp form, so the traced computation supports
    forward-over-reverse (exact-Hessian products). Wrap the *trace* that
    builds the operator — core/curvature.py does this for every
    exact-Hessian mode."""
    global _SECOND_ORDER_DEPTH
    _SECOND_ORDER_DEPTH += 1
    try:
        yield
    finally:
        _SECOND_ORDER_DEPTH -= 1


def second_order_active() -> bool:
    return _SECOND_ORDER_DEPTH > 0


# --------------------------------------------------- shared AD-pass impls --
def flash_bwd_passes(q, k, v, o, lse, do, **kkw):
    """The attention VJP from the stored lse: Δ precompute, the Pallas dQ
    pass, the Pallas dK/dV pass, and the GQA group-sum (f32 partials).
    The single implementation behind both the linear_call transpose (what
    jax.grad executes) and the public ops.flash_attention_bwd wrapper the
    kernel tests pin — one copy, no drift."""
    delta = jnp.einsum("bshd,bshd->bsh", o.astype(jnp.float32),
                       do.astype(jnp.float32)).transpose(0, 2, 1)
    dq = fa.flash_attention_dq(q, k, v, do, lse, delta, **kkw)
    dkh, dvh = fa.flash_attention_dkv(q, k, v, do, lse, delta, **kkw)
    B, _, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    dk = dkh.reshape(B, Sk, KV, G, hd).sum(3)
    dv = dvh.reshape(B, Sk, KV, G, hd).sum(3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def flash_jvp_pass(q, k, v, o, lse, qt, kt, vt, **kkw):
    """The attention JVP from the stored lse: the Pallas tangent pass plus
    the ȯ = g − t ∘ o finish (and l̇se = t). Single implementation behind
    the linear_call tangent and ops.flash_attention_jvp."""
    g, t = fa.flash_attention_jvp(q, k, v, qt, kt, vt, lse, **kkw)
    ot = g - t.transpose(0, 2, 1)[..., None] * o.astype(jnp.float32)
    return ot.astype(o.dtype), t


# ----------------------------------------------- second-order (jnp) entry --
def _chunked_attention(q, k, v, bias=None, *, causal, window, scale,
                       valid_len, blk):
    """Attention as a checkpointed scan over query blocks — the AD-closed
    form the exact-Hessian engine traces through.

    Each step computes softmax(q_blk Kᵀ)V for one (blk, Sk) tile: peak
    memory O(Sk·blk), never the (Sq, Sk) logits. K/V enter as (nonlinear)
    scan consts and the per-block outputs are stacked ys, so ``lax.scan``'s
    jvp rule gives the tangent scan correct linearity annotations — the
    structure every further transform (transpose, jvp-of-transpose)
    composes with by construction. ``jax.checkpoint`` on the body keeps the
    same O(Sk·blk) bound for all of them (P tiles are recomputed, not
    stored). ``bias``: optional (B|1, Sq, Sk) additive logit bias, sliced
    per query block (constant — differentiation passes it through as a
    zero-tangent const).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    blk = min(blk, S)
    nb = S // blk
    f32 = jnp.float32
    qs = q.reshape(B, nb, blk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    if bias is not None:
        bias = jnp.broadcast_to(bias, (B, S, T))
        bias = bias.reshape(B, nb, blk, T).transpose(1, 0, 2, 3)
    else:
        bias = jnp.zeros((nb, 1, 1, 1), f32)

    def body(_, x):
        qb, bb, i0 = x                              # qb: (B, blk, KV, G, hd)
        s = jnp.einsum("bskgh,btkh->bkgst", qb, k,
                       preferred_element_type=f32) * scale
        s = s + bb[:, None, None]
        mask = fa.position_mask(i0 + jnp.arange(blk)[:, None],
                                jnp.arange(T)[None, :], causal=causal,
                                window=window, valid_len=valid_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        m_safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_safe), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        ob = jnp.einsum("bkgst,btkh->bskgh", p / jnp.where(l <= 0.0, 1.0, l),
                        v, preferred_element_type=f32)
        return None, ob.reshape(B, blk, H, hd).astype(q.dtype)

    _, ys = jax.lax.scan(jax.checkpoint(body), None,
                         (qs, bias, jnp.arange(nb) * blk))
    return ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


# -------------------------------------------------------- per-config entry --
@functools.lru_cache(maxsize=None)
def _fa_entry(causal, window, scale, blk_q, blk_k, interpret, valid_len,
              second_order, has_bias=False):
    """Build (and cache) the differentiable attention callable for one
    static configuration. ``second_order`` is part of the cache key on
    purpose: the two rule sets must be distinct function objects so no
    jit/trace cache can alias them across contexts. ``has_bias`` entries
    take a fourth (B|1, Sq, Sk) f32 additive-bias operand — a constant
    w.r.t. differentiation (its tangent is discarded; masks carry no
    gradient), but a traced residual of every AD pass."""
    kkw = dict(causal=causal, window=window, valid_len=valid_len,
               scale=scale, blk_q=blk_q, blk_k=blk_k, interpret=interpret)

    if second_order:
        chunked = functools.partial(
            _chunked_attention, causal=causal, window=window, scale=scale,
            valid_len=valid_len, blk=blk_k)
        if has_bias:
            return lambda q, k, v, bias: chunked(q, k, v, bias)
        return chunked

    @jax.custom_jvp
    def fwd_res(q, k, v, bias=None):
        return fa.flash_attention_fwd(q, k, v, bias=bias, **kkw)

    @fwd_res.defjvp
    def fwd_res_jvp(primals, tangents):
        # Fires only when the primal forward is itself forward-differentiated
        # — i.e. forward-over-reverse reached the first-order entry. The
        # Pallas kernels cannot close that order; fail with the remedy.
        raise NotImplementedError(
            "flash attention: exact-Hessian (forward-over-reverse) traces "
            "must be built under kernels.ops.second_order_tangents() — the "
            "curvature engine does this; wrap any hand-rolled "
            "jvp-of-grad the same way.")

    def _tan(res, lin):
        # JVP flash pass (Pallas): linear in (q̇, k̇, v̇) given residuals.
        q, k, v, o, lse, bias = res
        return flash_jvp_pass(q, k, v, o, lse, *lin, bias=bias, **kkw)[0]

    def _tan_transpose(res, ct):
        # Transpose of _tan == the attention VJP: Pallas dQ + dK/dV passes
        # (this is what jax.grad / jax.linear_transpose execute).
        q, k, v, o, lse, bias = res
        return flash_bwd_passes(q, k, v, o, lse, ct, bias=bias, **kkw)

    if has_bias:
        @jax.custom_jvp
        def fa_o(q, k, v, bias):
            return fwd_res(q, k, v, bias)[0]

        @fa_o.defjvp
        def fa_o_jvp(primals, tangents):
            q, k, v, bias = primals
            o, lse = fwd_res(q, k, v, bias)
            # the bias tangent is dropped: masks are constants of the model
            ot = jax.custom_derivatives.linear_call(
                _tan, _tan_transpose, (q, k, v, o, lse, bias),
                tuple(tangents[:3]))
            return o, ot
    else:
        @jax.custom_jvp
        def fa_o(q, k, v):
            return fwd_res(q, k, v)[0]

        @fa_o.defjvp
        def fa_o_jvp(primals, tangents):
            q, k, v = primals
            o, lse = fwd_res(q, k, v)
            ot = jax.custom_derivatives.linear_call(
                _tan, _tan_transpose, (q, k, v, o, lse, None),
                tuple(tangents))
            return o, ot

    return jax.jit(fa_o)


# ------------------------------------------------------------ public entry --
def flash_mha(q, k, v, *, causal=True, window=None, scale=None,
              blk_q=128, blk_k=128, interpret=False, bias=None):
    """Differentiable flash attention with pad-and-mask block alignment.

    q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd) -> (B,Sq,H,hd). Query and key lengths
    may differ (cross-attention). When a length is not a multiple of the
    kernel block, that side is zero-padded to the next 128 multiple, the
    padded key tail is masked inside the kernels (``valid_len``), padded
    query rows are sliced back off (their tangents/cotangents are exact
    zeros). ``bias``: optional (B|1, Sq, Sk) f32 additive logit bias — the
    explicit-mask route (0 attendable / -1e30 dropped); it is treated as a
    constant under differentiation. The rule set (Pallas first-order vs
    AD-closed chunked-jnp) is picked by ``second_order_tangents()`` at trace
    time; see module docstring.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = float(scale if scale is not None else 1.0 / (hd ** 0.5))
    # Strict 128-tile contract: any length that is not a 128 multiple is
    # padded (including < 128) — sub-128 blocks would hand the TPU lane
    # dimension non-aligned logits/LSE tiles. 128-multiple lengths run
    # unpadded with the caller's block sizes.
    Sqp = -(-Sq // 128) * 128
    Skp = -(-Sk // 128) * 128
    valid_len = Sk if Skp != Sk else None
    entry = _fa_entry(causal, window, scale, blk_q, blk_k, bool(interpret),
                      valid_len, second_order_active(), bias is not None)
    if Sqp != Sq:
        qpad = ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0))
        q = jnp.pad(q, qpad)
    if Skp != Sk:
        kpad = ((0, 0), (0, Skp - Sk), (0, 0), (0, 0))
        k, v = jnp.pad(k, kpad), jnp.pad(v, kpad)
    if bias is not None:
        bias = jnp.pad(bias.astype(jnp.float32),
                       ((0, 0), (0, Sqp - Sq), (0, Skp - Sk)),
                       constant_values=NEG_INF)
        o = entry(q, k, v, bias)
    else:
        o = entry(q, k, v)
    return o[:, :Sq] if Sqp != Sq else o
