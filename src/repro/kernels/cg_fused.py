"""Fused Bi-CG-STAB vector recurrences as Pallas TPU kernels.

The paper's inner loop streams ~N-element (model-sized) vectors through HBM;
on TPU these recurrences are pure bandwidth. Fusing the axpy chains with the
dot products they feed removes whole HBM passes:

  * ``x_update``:       x + α·p + γ·s                (3 reads 1 write, vs 4r/2w)
  * ``residual_dots``:  r = s − γ·As; ⟨r,r0*⟩; ⟨r,r⟩ (3 reads 1 write + scalars,
                        vs 2r/1w + 2×2r for the separate dots)
  * ``dot2``:           ⟨u,v⟩, ⟨v,v⟩                 (2 reads, vs 4)
  * ``dots_block``:     the (s_u × s_v) Gram block UVᵀ of two stacked vector
                        blocks in ONE pass over the data (s_u + s_v reads
                        total, vs 2·s_u·s_v reads for pairwise dot2 calls) —
                        the s-step solvers' all-dots-for-s-iterations reduce
                        (core/sstep.py).

1-D grid over VMEM-sized chunks; per-block partial sums land in a
(n_blocks,)-shaped output reduced by the (tiny) jnp.sum in ops.py. All
accumulation in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64 * 1024  # 64k f32 elements = 256 KiB per operand tile in VMEM


def _x_update_kernel(alpha_ref, gamma_ref, x_ref, p_ref, s_ref, o_ref):
    a = alpha_ref[0]
    g = gamma_ref[0]
    o_ref[...] = (
        x_ref[...].astype(jnp.float32)
        + a * p_ref[...].astype(jnp.float32)
        + g * s_ref[...].astype(jnp.float32)
    ).astype(o_ref.dtype)


def x_update(x, p, s, alpha, gamma, *, block=BLOCK, interpret=False):
    """x + alpha*p + gamma*s over flat f32 vectors (padded to block)."""
    n = x.shape[0]
    nb = pl.cdiv(n, block)
    scal = lambda v: jnp.asarray([v], jnp.float32) if jnp.ndim(v) == 0 else v.reshape(1)
    return pl.pallas_call(
        _x_update_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(scal(alpha), scal(gamma), x, p, s)


def _residual_dots_kernel(gamma_ref, s_ref, As_ref, r0s_ref, r_ref, d1_ref, d2_ref):
    g = gamma_ref[0]
    r = s_ref[...].astype(jnp.float32) - g * As_ref[...].astype(jnp.float32)
    r_ref[...] = r
    d1_ref[0] = jnp.sum(r * r0s_ref[...].astype(jnp.float32))
    d2_ref[0] = jnp.sum(r * r)


def residual_dots(s, As, r0s, gamma, *, block=BLOCK, interpret=False):
    """r = s - gamma*As; returns (r, per-block <r,r0s>, per-block <r,r>)."""
    n = s.shape[0]
    nb = pl.cdiv(n, block)
    scal = lambda v: jnp.asarray([v], jnp.float32) if jnp.ndim(v) == 0 else v.reshape(1)
    r, d1, d2 = pl.pallas_call(
        _residual_dots_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(scal(gamma), s, As, r0s)
    return r, d1, d2


def _dots_block_kernel(u_ref, v_ref, o_ref):
    u = u_ref[...].astype(jnp.float32)      # (s_u, block)
    v = v_ref[...].astype(jnp.float32)      # (s_v, block)
    o_ref[0] = jax.lax.dot_general(
        u, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


# The Gram kernel streams s_u + s_v row vectors per grid step, so its column
# tile is narrower than the single-vector fusions' (s rows of 16k f32 =
# 64 KiB/row in VMEM; at s ≤ 16 this stays well inside the ~16 MB budget).
BLOCK_GRAM = 16 * 1024


def dots_block(U, V, *, block=BLOCK_GRAM, interpret=False):
    """Per-column-block partials of the Gram matrix U @ Vᵀ.

    ``U``: (s_u, n), ``V``: (s_v, n) stacked flat f32 vectors (n padded to a
    block multiple, rows padded to the sublane tile by ops.py). Returns
    (n_blocks, s_u, s_v) partials; the (tiny) reduction over blocks — the
    s-step solvers' ONE communication point per s Krylov iterations — happens
    in ops.py.
    """
    su, n = U.shape
    sv = V.shape[0]
    nb = pl.cdiv(n, block)
    return pl.pallas_call(
        _dots_block_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((su, block), lambda i: (0, i)),
            pl.BlockSpec((sv, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, su, sv), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, su, sv), jnp.float32),
        interpret=interpret,
    )(U, V)


def _dot2_kernel(u_ref, v_ref, d1_ref, d2_ref):
    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    d1_ref[0] = jnp.sum(u * v)
    d2_ref[0] = jnp.sum(v * v)


def dot2(u, v, *, block=BLOCK, interpret=False):
    """Per-block partials of (<u,v>, <v,v>)."""
    n = u.shape[0]
    nb = pl.cdiv(n, block)
    return pl.pallas_call(
        _dot2_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(u, v)
