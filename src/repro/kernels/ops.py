"""Jit'd public wrappers for the Pallas kernels.

Attention (the training/serving hot path — see EXPERIMENTS.md §Perf pair F):

  * ``flash_attention``     — fully differentiable flash attention
                              (kernels/flash_ad.py: custom_jvp + linear_call
                              over the forward/backward/JVP kernels; padding
                              for non-block-aligned S),
  * ``flash_attention_fwd`` / ``flash_attention_bwd`` /
    ``flash_attention_jvp`` — the raw (non-differentiable) kernel passes,
  * ``second_order_tangents`` — trace-time context for exact-Hessian
                              (forward-over-reverse) traces, re-exported
                              from flash_ad for the curvature engine.

Decode (the serving hot path — see EXPERIMENTS.md §Perf pair H):

  * ``flash_decode``        — split-K single-query decode over a dense
                              rolling KV cache (kernels/flash_decode.py);
                              ``return_stats`` exposes the (o, m, l)
                              partials contract models/decode_sharded.py
                              merges across shards,
  * ``flash_decode_paged``  — the same kernel over the shared page pool
                              (models/kv_paged.py) with a scalar-prefetched
                              page table — no dense per-sequence gather,
  * ``decode_bias`` / ``paged_bias`` — the ONE definition of decode-mask
                              semantics (rolling-slot validity, ragged t,
                              sliding window, unmapped pages), shared by
                              the kernels, the jnp oracles, and `_sdpa`.

The remainder are the execution layer of the *flat* Krylov vector backend
(``core.krylov.FlatVectorBackend``): the solvers in ``core/solvers.py``
ravel their iterates into flat f32 buffers once per solve and run every
axpy/dot recurrence through these fusions —

  * ``bicgstab_x_update``     — y + α·u + γ·v  (Bi-CG-STAB x and p updates),
  * ``bicgstab_residual_dots``— r = s − γ·t fused with ⟨r,r0*⟩ and ⟨r,r⟩
                                (also the CG residual update + ‖r‖²),
  * ``dot2``                  — ⟨u,v⟩, ⟨v,v⟩ in one pass (curvature probes,
                                Bi-CG-STAB ω, CG α denominators),
  * ``gram_block``            — the (s_u × s_v) Gram matrix UVᵀ of two stacked
                                vector blocks in one pass (the s-step solvers'
                                all-dots-for-s-iterations reduction —
                                core/sstep.py via the Krylov block backend).

Each fusion removes whole HBM passes over model-sized vectors relative to
the per-leaf pytree path (see cg_fused.py for the traffic accounting) — the
flat backend wins when Krylov state is per-chip replicated (pure data
parallelism) and the inner loop is bandwidth-bound. The pytree ("tree")
backend keeps per-tensor shardings instead and wins when params are sharded
under pjit. ``benchmarks/kernels_bench.py`` compares both end-to-end.

``interpret=True`` runs the kernel bodies in Python on CPU (how this repo
validates them); on a real TPU pass interpret=False (default resolves from
the backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import cg_fused, flash_ad, flash_attention as fa, flash_decode as fd
from .flash_ad import second_order_tangents  # re-export (curvature engine)
from .flash_decode import decode_bias, paged_bias  # re-export (mask->bias)


def _default_interpret():
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, blk_q=128, blk_k=128,
                    interpret=None, bias=None):
    """Fully differentiable flash attention (training + serving path).

    Forward runs the Pallas online-softmax kernel (with the logsumexp
    residual); reverse mode transposes onto the Pallas dQ / dK+dV kernels;
    forward mode (``jax.linearize`` — the curvature engine's J·v) runs the
    Pallas JVP pass. Exact-Hessian (forward-over-reverse) traces must be
    bracketed in ``second_order_tangents()`` — see kernels/flash_ad.py.
    Non-block-aligned lengths are padded to the 128 tile, tail-masked and
    sliced; q and kv lengths may differ (cross-attention). ``bias``:
    optional (B|1, Sq, Sk) f32 additive logit bias — the explicit-mask
    route (constant under differentiation).
    """
    interpret = _default_interpret() if interpret is None else interpret
    return flash_ad.flash_mha(
        q, k, v, causal=causal, window=window, blk_q=blk_q, blk_k=blk_k,
        interpret=interpret, bias=bias,
    )


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "valid_len", "blk_q", "blk_k", "interpret"))
def flash_attention_fwd(q, k, v, *, causal=True, window=None, valid_len=None,
                        blk_q=128, blk_k=128, interpret=None, bias=None):
    """Raw forward kernel: (o, lse) with lse: (B,H,S) the per-row logsumexp
    residual the backward/JVP kernels consume (non-differentiable wrapper)."""
    interpret = _default_interpret() if interpret is None else interpret
    return fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, valid_len=valid_len,
        blk_q=blk_q, blk_k=blk_k, interpret=interpret, bias=bias,
    )


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "valid_len", "blk_q", "blk_k", "interpret"))
def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=None,
                        valid_len=None, blk_q=128, blk_k=128, interpret=None,
                        bias=None):
    """Raw backward: (dq, dk, dv) from the stored lse — Δ precompute, the
    Pallas dQ pass, the Pallas dK/dV pass, and the GQA group-sum. Same
    implementation jax.grad executes (flash_ad.flash_bwd_passes)."""
    interpret = _default_interpret() if interpret is None else interpret
    return flash_ad.flash_bwd_passes(
        q, k, v, o, lse, do, causal=causal, window=window, bias=bias,
        valid_len=valid_len, blk_q=blk_q, blk_k=blk_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "valid_len", "blk_q", "blk_k", "interpret"))
def flash_attention_jvp(q, k, v, o, lse, qt, kt, vt, *, causal=True,
                        window=None, valid_len=None, blk_q=128, blk_k=128,
                        interpret=None, bias=None):
    """Raw forward-mode tangent: (ȯ, l̇se) via the Pallas JVP pass (two extra
    block matmuls per tile: Q̇Kᵀ + QK̇ᵀ against the recomputed P). Same
    implementation jax.linearize executes (flash_ad.flash_jvp_pass)."""
    interpret = _default_interpret() if interpret is None else interpret
    return flash_ad.flash_jvp_pass(
        q, k, v, o, lse, qt, kt, vt, causal=causal, window=window, bias=bias,
        valid_len=valid_len, blk_q=blk_q, blk_k=blk_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "scale", "blk_k", "n_splits", "interpret", "return_stats"))
def flash_decode(q, k, v, bias, *, scale=None, blk_k=128, n_splits=8,
                 interpret=None, return_stats=False):
    """Split-K flash decode over a dense rolling cache (serving hot path).

    q: (B,H,hd) one query row per sequence; k/v: (B,W,KV,hd); bias: (B|1,W)
    additive mask row from ``decode_bias`` (rolling-slot validity, ragged
    per-sequence t, sliding window). The grid parallelizes over KV blocks;
    partials merge with the logsumexp combine (kernels/flash_decode.py).
    ``return_stats`` additionally returns global (m, l): (B,H) — the
    contract models/decode_sharded.py uses to merge across shards.
    """
    interpret = _default_interpret() if interpret is None else interpret
    return fd.flash_decode(
        q, k, v, bias, scale=scale, blk_k=blk_k, n_splits=n_splits,
        interpret=interpret, return_stats=return_stats)


@functools.partial(jax.jit, static_argnames=(
    "scale", "interpret", "return_stats"))
def flash_decode_paged(q, k_pool, v_pool, page_table, bias, *, scale=None,
                       interpret=None, return_stats=False):
    """Split-K flash decode over the shared page pool (models/kv_paged.py).

    The page table is scalar-prefetched so the kernel's K/V index maps
    gather physical pages directly — no dense per-sequence copy. bias from
    ``paged_bias`` masks the beyond-length tail, sliding window, and
    unmapped pages.
    """
    interpret = _default_interpret() if interpret is None else interpret
    return fd.flash_decode_paged(
        q, k_pool, v_pool, page_table, bias, scale=scale,
        interpret=interpret, return_stats=return_stats)


def _pad_flat(x, block):
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, n


@functools.partial(jax.jit, static_argnames=("interpret",))
def bicgstab_x_update(x, p, s, alpha, gamma, *, interpret=None):
    """x + alpha*p + gamma*s  (flat f32 vectors)."""
    interpret = _default_interpret() if interpret is None else interpret
    xp, n = _pad_flat(x, cg_fused.BLOCK)
    pp, _ = _pad_flat(p, cg_fused.BLOCK)
    sp, _ = _pad_flat(s, cg_fused.BLOCK)
    return cg_fused.x_update(xp, pp, sp, alpha, gamma, interpret=interpret)[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bicgstab_residual_dots(s, As, r0s, gamma, *, interpret=None):
    """r = s - gamma*As; returns (r, <r,r0s>, <r,r>)."""
    interpret = _default_interpret() if interpret is None else interpret
    sp, n = _pad_flat(s, cg_fused.BLOCK)
    Ap, _ = _pad_flat(As, cg_fused.BLOCK)
    rp, _ = _pad_flat(r0s, cg_fused.BLOCK)
    r, d1, d2 = cg_fused.residual_dots(sp, Ap, rp, gamma, interpret=interpret)
    return r[:n], jnp.sum(d1), jnp.sum(d2)


def _pad_block_rows(M, block, row_tile=8):
    """Pad a (s, n) stack to (s_pad, n_pad): columns to a kernel-block
    multiple, rows to the f32 sublane tile (zero rows/columns contribute
    zero to every Gram entry)."""
    s, n = M.shape
    pad_c = (-n) % block
    pad_r = (-s) % row_tile
    if pad_c or pad_r:
        M = jnp.pad(M, ((0, pad_r), (0, pad_c)))
    return M, s


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram_block(U, V, *, interpret=None):
    """Gram matrix U @ Vᵀ of two stacked flat f32 vector blocks.

    ``U``: (s_u, n), ``V``: (s_v, n) → (s_u, s_v) with every entry ⟨u_i, v_j⟩
    accumulated in one pass over the data (per-column-block partials from the
    Pallas kernel, reduced here). This is the flat backend's ``gram`` — the
    single reduction an s-step cycle issues in place of per-iteration dots.
    """
    interpret = _default_interpret() if interpret is None else interpret
    Up, su = _pad_block_rows(U, cg_fused.BLOCK_GRAM)
    Vp, sv = _pad_block_rows(V, cg_fused.BLOCK_GRAM)
    parts = cg_fused.dots_block(Up, Vp, interpret=interpret)
    return jnp.sum(parts, axis=0)[:su, :sv]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dot2(u, v, *, interpret=None):
    """(<u,v>, <v,v>)."""
    interpret = _default_interpret() if interpret is None else interpret
    up, _ = _pad_flat(u, cg_fused.BLOCK)
    vp, _ = _pad_flat(v, cg_fused.BLOCK)
    d1, d2 = cg_fused.dot2(up, vp, interpret=interpret)
    return jnp.sum(d1), jnp.sum(d2)
