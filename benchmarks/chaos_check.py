"""Chaos harness: inject real faults into real training runs and assert
*recovery parity* — not merely that the stack survives a fault, but that
what it computes afterwards is the SAME trajectory the uninterrupted run
produces (checkpoint restore is bitwise on params and the batch stream is
step-indexed, so any divergence is a durability bug, not noise).

Three scenarios, each driving the actual ``repro.launch.train`` CLI (the
product path — arg parsing, supervisor, restore, fault hooks — not a
test double):

  kill_restart   2-process run, worker 1 hard-killed (``os._exit``) at the
                 top of step 2 via ``REPRO_FAULTS``. The survivor's gloo
                 collective dies or wedges; the supervisor
                 (``multiproc.spawn_supervised``) tears down, relaunches,
                 and the workers resume from the last valid checkpoint.
                 Asserts: >= 1 restart consumed, the resumed steps' losses
                 match an uninterrupted 2-process baseline, and the
                 injected kill is visible in telemetry (the line-buffered
                 event survives the kill).

  corrupt_ckpt   single-process run whose newest checkpoint is bit-flipped
                 in place after its (atomic, fsync'd) save — damage only a
                 checksum can find. Asserts: ``verify_checkpoint`` raises,
                 ``latest_valid_step`` < ``latest_step``, the resume run
                 restores the previous valid step and replays it to the
                 identical loss.

  nan_batch      single-process run on the vlm arch (float vision inputs
                 can carry NaN; token ids cannot) with the step-1 batch
                 poisoned. Asserts: the divergence sentinel (core/hf.py)
                 reports ``step_rejected`` exactly at step 1, boosts λ,
                 keeps params finite (later steps train normally), and
                 both the injected fault and the rejection land in
                 telemetry.

Writes ``BENCH_chaos.json``; ``check(result)`` holds the acceptance
assertions (schema documented in EXPERIMENTS.md §Robustness) and runs in
CI via ``benchmarks/run.py --check``.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys
import tempfile

from repro.checkpoint import (CheckpointCorruptError, latest_step,
                              latest_valid_step, verify_checkpoint)
from repro.launch import multiproc
from repro.obs import trace as trace_mod

JSON_OUT = "BENCH_chaos.json"

ARCH = "qwen1.5-0.5b"
VLM_ARCH = "phi-3-vision-4.2b"
STEPS = 4
KILL_STEP = 2
BASE_ARGS = ["--smoke", "--batch-size", "4", "--seq-len", "16",
             "--max-cg-iters", "4"]
# Must cover gloo rendezvous + trace + compile on a loaded CI box, not
# just a step — staleness is measured from attempt launch time.
HANG_TIMEOUT_S = 300.0


def _env(faults: str | None = None) -> dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env.pop("REPRO_FAULTS", None)
    if faults:
        env["REPRO_FAULTS"] = faults
    return env


def _train_cli(args: list, *, faults: str | None = None) -> None:
    """One single-process train run through the real CLI."""
    subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *BASE_ARGS, *args],
        env=_env(faults), check=True, timeout=900,
    )


def _losses(history_path: str) -> dict:
    with open(history_path) as f:
        return {int(m["step"]): m for m in json.load(f)}


# ---------------------------------------------------------------- scenarios

def scenario_kill_restart(workdir: str, log=print) -> dict:
    """Worker death mid-training → supervised restart → parity."""
    fault = f"kill@step={KILL_STEP},proc=1"
    base_hist = os.path.join(workdir, "kill_base.json")
    chaos_hist = os.path.join(workdir, "kill_chaos.json")
    ckpt_dir = os.path.join(workdir, "kill_ckpt")
    tel_dir = os.path.join(workdir, "kill_telemetry")

    log(f"  [kill_restart] baseline: 2-process, {STEPS} steps")
    _train_cli(["--arch", ARCH, "--steps", str(STEPS), "--num-processes", "2",
                "--history-out", base_hist])

    log(f"  [kill_restart] chaos: {fault}, supervised")
    # spawn_supervised called directly (not via the train CLI's
    # --max-restarts path) so the restart count comes back as a value;
    # the children run the same CLI the flag would launch.
    restarts = multiproc.spawn_supervised(
        2, "repro.launch.train",
        [*BASE_ARGS, "--arch", ARCH, "--steps", str(STEPS),
         "--num-processes", "2", "--ckpt-dir", ckpt_dir,
         "--ckpt-every", "1", "--history-out", chaos_hist,
         "--telemetry-dir", tel_dir],
        max_restarts=2, hang_timeout_s=HANG_TIMEOUT_S,
        env=_env(fault), log=log,
    )

    base = _losses(base_hist)
    resumed = _losses(chaos_hist)  # the successful attempt's segment
    deltas = {s: abs(base[s]["loss"] - m["loss"]) for s, m in resumed.items()}
    faults_seen = trace_mod.fault_events(trace_mod.load_events(tel_dir))
    log(f"  [kill_restart] restarts={restarts} resumed_steps="
        f"{sorted(resumed)} max_delta={max(deltas.values()):.3e}")
    return {
        "fault": fault,
        "restarts": restarts,
        "baseline_loss": {str(s): m["loss"] for s, m in base.items()},
        "resumed_loss": {str(s): m["loss"] for s, m in resumed.items()},
        "resumed_steps": sorted(resumed),
        "max_loss_delta": max(deltas.values()),
        "fault_events": faults_seen,
    }


def scenario_corrupt_ckpt(workdir: str, log=print) -> dict:
    """Checksum-detected checkpoint corruption → fallback restore."""
    fault = f"corrupt_ckpt@step={STEPS - 1}"
    ckpt_dir = os.path.join(workdir, "corrupt_ckpt")
    hist1 = os.path.join(workdir, "corrupt_run1.json")
    hist2 = os.path.join(workdir, "corrupt_run2.json")

    log(f"  [corrupt_ckpt] run 1: {fault}")
    _train_cli(["--arch", ARCH, "--steps", str(STEPS - 1),
                "--ckpt-dir", ckpt_dir, "--ckpt-every", "1",
                "--history-out", hist1], faults=fault)
    newest, newest_valid = latest_step(ckpt_dir), latest_valid_step(ckpt_dir)
    corrupt_path = os.path.join(ckpt_dir, f"ckpt_{newest:08d}.npz")
    try:
        verify_checkpoint(corrupt_path)
        detected = False
    except CheckpointCorruptError:
        detected = True

    log(f"  [corrupt_ckpt] run 2: resume (latest={newest} "
        f"valid={newest_valid} detected={detected})")
    _train_cli(["--arch", ARCH, "--steps", str(STEPS),
                "--ckpt-dir", ckpt_dir, "--ckpt-every", "1",
                "--history-out", hist2])

    run1, run2 = _losses(hist1), _losses(hist2)
    # run 2 restored at newest_valid and replayed step newest_valid
    # onwards; the overlapping replayed step must reproduce run 1 exactly.
    replay = {s: abs(run1[s]["loss"] - m["loss"])
              for s, m in run2.items() if s in run1}
    log(f"  [corrupt_ckpt] replay_steps={sorted(replay)} "
        f"max_delta={max(replay.values()):.3e}")
    return {
        "fault": fault,
        "latest_step": newest,
        "latest_valid_step": newest_valid,
        "corruption_detected": detected,
        "resume_start": min(run2),
        "replay_steps": sorted(replay),
        "max_loss_delta": max(replay.values()),
    }


def scenario_nan_batch(workdir: str, log=print) -> dict:
    """NaN curvature/gradient batch → rejected step, boosted λ."""
    fault = "nan_batch@step=1"
    hist = os.path.join(workdir, "nan_hist.json")
    tel_dir = os.path.join(workdir, "nan_telemetry")
    log(f"  [nan_batch] {VLM_ARCH}: {fault}")
    _train_cli(["--arch", VLM_ARCH, "--steps", str(STEPS),
                "--history-out", hist, "--telemetry-dir", tel_dir],
               faults=fault)
    rows = [{"step": s, "loss": m["loss"], "lambda": m["lambda"],
             "rejected": m["step_rejected"]}
            for s, m in sorted(_losses(hist).items())]
    faults_seen = trace_mod.fault_events(trace_mod.load_events(tel_dir))
    log(f"  [nan_batch] rejected={[r['step'] for r in rows if r['rejected']]}"
        f" lambdas={[r['lambda'] for r in rows]}")
    return {"fault": fault, "steps": rows, "fault_events": faults_seen}


# ------------------------------------------------------------------- harness

def run_bench(tiny: bool = False, out_path: str = JSON_OUT, log=print) -> dict:
    with tempfile.TemporaryDirectory(prefix="chaos-") as workdir:
        result = {
            "schema": 1,
            "meta": {"arch": ARCH, "vlm_arch": VLM_ARCH, "steps": STEPS,
                     "kill_step": KILL_STEP, "tiny": tiny},
            "kill_restart": scenario_kill_restart(workdir, log),
            "corrupt_ckpt": scenario_corrupt_ckpt(workdir, log),
            "nan_batch": scenario_nan_batch(workdir, log),
        }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    log(f"wrote {out_path}")
    return result


def check(result):
    """Acceptance assertions for BENCH_chaos.json (owned by this bench —
    benchmarks/run.py --check calls it next to the writer)."""
    assert result["schema"] == 1

    kr = result["kill_restart"]
    # The kill consumed at least one supervised restart (and the budget
    # was not exhausted — the run completed, or we would not be here).
    assert kr["restarts"] >= 1, kr["restarts"]
    # The resumed segment re-ran the killed step onward...
    assert kr["resumed_steps"], kr
    assert min(kr["resumed_steps"]) <= KILL_STEP, kr["resumed_steps"]
    assert max(kr["resumed_steps"]) == STEPS - 1, kr["resumed_steps"]
    # ...to the SAME losses as the uninterrupted baseline: recovery
    # parity, the claim that separates "restarted" from "recovered".
    assert kr["max_loss_delta"] <= 1e-6, kr["max_loss_delta"]
    # The kill itself is in the telemetry (flushed before os._exit).
    kills = [e for e in kr["fault_events"]
             if e["kind"] == "kill" and e.get("injected")]
    assert kills and kills[0]["step"] == KILL_STEP, kr["fault_events"]

    cc = result["corrupt_ckpt"]
    assert cc["corruption_detected"], cc
    assert cc["latest_valid_step"] is not None
    assert cc["latest_valid_step"] < cc["latest_step"], cc
    # Resume started from the newest VALID checkpoint, not the torn one.
    assert cc["resume_start"] == cc["latest_valid_step"], cc
    assert cc["replay_steps"], cc
    assert cc["max_loss_delta"] <= 1e-6, cc["max_loss_delta"]

    nb = result["nan_batch"]
    rows = {r["step"]: r for r in nb["steps"]}
    # Exactly the poisoned step was rejected...
    assert rows[1]["rejected"] == 1.0, rows
    assert all(r["rejected"] == 0.0 for s, r in rows.items() if s != 1), rows
    # ...λ was boosted through the LM machinery...
    assert rows[2]["lambda"] > rows[1]["lambda"] > rows[0]["lambda"], rows
    # ...and params stayed finite: training continues normally after.
    for s in (2, 3):
        assert math.isfinite(rows[s]["loss"]), rows
    kinds = {e["kind"] for e in nb["fault_events"]}
    assert "nan_batch" in kinds and "step_reject" in kinds, nb["fault_events"]
    rejects = [e for e in nb["fault_events"] if e["kind"] == "step_reject"]
    assert [e["step"] for e in rejects] == [1], rejects


def summary(result):
    """One-line headline for the --summary markdown table."""
    kr = result["kill_restart"]
    return (f"kill/restart recovered in {kr['restarts']} restart(s), "
            f"max loss delta {kr['max_loss_delta']:.1e}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--out", default=JSON_OUT)
    args = ap.parse_args()
    result = run_bench(tiny=args.tiny, out_path=args.out)
    check(result)
    print("chaos checks ok")


if __name__ == "__main__":
    main()
