"""Paper Fig. 4: batch-size scaling of second-order methods on the
784-400-150-10 network — progress per outer iteration as a function of the
curvature mini-batch size b (larger b ⇒ better stochastic Hessian ⇒ more
aggressive valid steps), vs mini-batch SGD whose returns stop past b̃.

Reported: objective after a fixed budget of outer iterations for each b, and
the iteration count to an error threshold where reached.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_mlp import MNIST_FIG4
from repro.core import HFConfig, hf_init, hf_step
from repro.data import classification_dataset
from repro.models import build_mlp

N_TRAIN = 4096
NOISE = 3.5          # hard enough that the Hessian estimate quality matters
OUTER_ITERS = 6


def _train_err(model, params, data):
    return 1.0 - float(model.accuracy(params, data))


def run(log=print):
    model = build_mlp(MNIST_FIG4)
    data = classification_dataset(jax.random.PRNGKey(0), N_TRAIN, 784, 10,
                                  noise=NOISE)
    rows = []
    for b in (64, 256, 1024, 4096):
        cfg = HFConfig(solver="bicgstab", max_cg_iters=10)
        params = model.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        hvp_batch = {k: v[:b] for k, v in data.items()}
        step = jax.jit(lambda p, s, hb: hf_step(
            model.loss_fn, p, s, data, hb, cfg,
            model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
        params, state, _ = step(params, state, hvp_batch)  # compile
        t0 = time.time()
        loss = None
        for i in range(OUTER_ITERS):
            params, state, m = step(params, state, hvp_batch)
            loss = float(m["loss_new"])
        dt = (time.time() - t0) * 1e6 / OUTER_ITERS
        err = _train_err(model, params, data)
        rows.append((f"fig4/bicgstab_b{b}", dt,
                     f"loss_after_{OUTER_ITERS}it={loss:.4f} err={err:.4f}"))

    # SGD reference at two mini-batch sizes (paper: increasing b does NOT
    # help SGD) — same number of gradient evaluations as HF's data passes.
    from repro.data.synthetic import minibatches
    from repro.optim.first_order import momentum_sgd
    for b in (64, 1024):
        opt = momentum_sgd(0.1)
        p2 = model.init(jax.random.PRNGKey(1))
        st = opt.init(p2)
        stepf = jax.jit(lambda p, s, bb: opt.step(model.loss_fn, p, s, bb))
        t0 = time.time()
        n_steps = OUTER_ITERS * (1 + 2 * 10 // 4)  # HF's effective passes
        done = 0
        for ep in range(1000):
            for bb in minibatches(data, b, seed=ep):
                if done >= n_steps * (N_TRAIN // b):
                    break
                p2, st, _ = stepf(p2, st, bb)
                done += 1
            if done >= n_steps * (N_TRAIN // b):
                break
        dt = (time.time() - t0) * 1e6 / max(done, 1)
        loss = float(model.loss_fn(p2, data))
        rows.append((f"fig4/msgd_b{b}", dt,
                     f"loss_after_{n_steps}ep={loss:.4f} err={_train_err(model, p2, data):.4f}"))
    return rows
