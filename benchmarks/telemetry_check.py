"""Telemetry cross-check: three independent collective counters, one truth.

  PYTHONPATH=src python benchmarks/telemetry_check.py [--tiny] [--out PATH]

One jitted data-parallel HF step is traced with BOTH instrumentation paths
armed — the telemetry sink (``repro.obs.telemetry``: begin/end debug
callbacks per executed ``preduce``) and the executed-collective counter
(``core.collectives.count_executed``: an independent tally callback at the
same sites) — and then executed once. The check asserts that the two
runtime observers and the in-jit accounting agree:

  1. per tag, telemetry ``coll`` span-pair count == ``count_executed``
     per-device tally (two independent callback paths, same schedule);
  2. the solve event's ``syncs`` == ``metrics["krylov_syncs"]`` (the
     callback-reported and the returned-metric view of the same scalar);
  3. ``metrics["blocking_syncs"]`` == the comm-model formula recomputed
     from those pieces (non-overlap: ``1 + krylov_syncs + ls_evals``).

If a future change makes the telemetry trace show collectives that the
audited counter doesn't (or vice versa), this is the bench that fails.
Results go to ``BENCH_telemetry.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

if __package__ in (None, ""):
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (_ROOT, os.path.join(_ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

import jax

from repro.core import HFConfig, hf_init
from repro.core.collectives import count_executed
from repro.core.distributed import data_parallel_hf_step
from repro.data import classification_dataset
from repro.models import build_mlp
from repro.obs import telemetry as telemetry_mod
from repro.obs import trace as trace_mod

JSON_OUT = "BENCH_telemetry.json"


def run_bench(tiny: bool = False, out_path: str = JSON_OUT, log=print):
    # One representative non-overlap combo (s-step CG): the blocking-sync
    # formula is the additive one, so every executed reduce is visible to
    # all three counters. Shapes are CI-smoke either way — this bench
    # checks counts, not wall clock.
    dims, B, iters = ((16, 32, 4), 16, 6) if tiny else ((64, 32, 10), 64, 8)
    model = build_mlp(dims)
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(jax.random.PRNGKey(0), B, dims[0], dims[-1])
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    cfg = HFConfig(solver="hessian_cg", max_cg_iters=iters, cg_tol=0.0,
                   sstep_s=2, overlap=False)

    tmp = tempfile.mkdtemp(prefix="telemetry_check_")
    sink = telemetry_mod.Telemetry(tmp, meta=dict(kind="telemetry_check"))
    with telemetry_mod.install(sink), count_executed() as counts:
        step = data_parallel_hf_step(model.loss_fn, mesh, cfg)
        p, s, m = jax.jit(step)(params, hf_init(params, cfg), data)
        jax.block_until_ready(p)
    sink.close()
    executed = counts.per_device(len(jax.local_devices()))
    metrics = {k: float(v) for k, v in jax.device_get(m).items()}

    events = trace_mod.load_events(tmp)
    colls = trace_mod.collective_spans(events)
    telemetry_counts: dict = {}
    for c in colls:
        telemetry_counts[c["tag"]] = telemetry_counts.get(c["tag"], 0) + 1
    solves = [e for e in events if e["ev"] == "solve"]

    result = {
        "config": {"mlp": list(dims), "batch": B, "max_cg_iters": iters,
                   "solver": cfg.solver, "sstep_s": cfg.sstep_s,
                   "overlap": cfg.overlap, "tiny": tiny,
                   "devices": len(jax.devices())},
        "tags": {t: {"telemetry": telemetry_counts.get(t, 0),
                     "executed": int(executed.get(t, 0))}
                 for t in sorted(set(telemetry_counts) | set(executed))},
        "solve_event": solves[0] if solves else None,
        "metrics": {k: metrics[k] for k in
                    ("krylov_syncs", "blocking_syncs", "ls_evals",
                     "cg_iters", "sstep_fallback")},
    }
    log(f"telemetry check: tags={result['tags']} "
        f"blocking={metrics['blocking_syncs']:.0f}")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    log(f"wrote {out_path}")
    return result


def check(result):
    """Acceptance: the two runtime observers and the in-jit accounting all
    describe the same executed collective schedule."""
    tags = result["tags"]
    assert tags, "no collectives observed at all"
    for tag, row in tags.items():
        assert row["telemetry"] == row["executed"], (tag, tags)
    m = result["metrics"]
    sol = result["solve_event"]
    assert sol is not None, "no solve event emitted"
    assert sol["iters"] == int(m["cg_iters"]), (sol, m)
    assert sol["syncs"] == int(m["krylov_syncs"]), (sol, m)
    # Non-overlap formula: grad reduce + per-cycle Gram syncs + line search.
    assert int(m["blocking_syncs"]) == \
        1 + int(m["krylov_syncs"]) + int(m["ls_evals"]), m
    # The residual curve is real data: one finite entry per iteration.
    hist = sol["residual_history"]
    assert len(hist) == sol["iters"] and all(v == v for v in hist), sol


def summary(result):
    """One-line headline for the --summary markdown table."""
    m = result["metrics"]
    return (f"{len(result['tags'])} collective tags agree; "
            f"blocking_syncs {int(m['blocking_syncs'])}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--out", default=JSON_OUT)
    args = ap.parse_args()
    result = run_bench(tiny=args.tiny, out_path=args.out)
    check(result)
    print("telemetry check ok")


if __name__ == "__main__":
    main()
