"""Render the §Perf optimized-vs-baseline comparison table from the dry-run
records in experiments/dryrun (baseline) and experiments/perf (optimized)."""
from __future__ import annotations

import glob
import json
import os


def rows(perf_dir="experiments/perf", base_dir="experiments/dryrun", suffix="_opt.json"):
    out = []
    for f in sorted(glob.glob(os.path.join(perf_dir, f"*{suffix}"))):
        r = json.load(open(f))
        arch, shape, mesh = r["arch"], r["shape"], r["mesh"]
        tag = "1pod" if mesh == "16x16" else "2pod"
        base_path = os.path.join(base_dir, f"{arch}_{shape}_{tag}_bicgstab.json")
        if not os.path.exists(base_path):
            continue
        b = json.load(open(base_path))
        bt, ot = b["roofline"], r["roofline"]
        dom_b, dom_o = bt[bt["bottleneck"]], ot[ot["bottleneck"]]
        out.append({
            "arch": arch, "shape": shape, "mesh": mesh,
            "base": f"{bt['bottleneck'].replace('_s','')} {dom_b:.3g}s",
            "opt": f"{ot['bottleneck'].replace('_s','')} {dom_o:.3g}s",
            "gain": f"{dom_b/dom_o:.1f}x",
            "hbm": f"{b['memory'].get('per_device_total_gib')} → {r['memory'].get('per_device_total_gib')}",
            "useful": f"{b.get('useful_flops_ratio')} → {r.get('useful_flops_ratio')}",
        })
    return out


def markdown():
    cols = ("arch", "shape", "mesh", "base", "opt", "gain", "hbm", "useful")
    lines = ["| " + " | ".join(cols) + " |", "|" + "---|" * len(cols)]
    for row in rows():
        lines.append("| " + " | ".join(str(row[c]) for c in cols) + " |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown())
