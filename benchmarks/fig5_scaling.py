"""Paper Fig. 5: multi-node scaling of distributed HF on the TIMIT network
(360-512x3-1973).

The paper measures wall-clock on 1-32 Xeon nodes (2.65 TFLOP/s each) over
Omni-Path; this repo has one CPU whose wall-clock is ~10³ slower than a
cluster node, which would hide the communication term entirely. So the
*compute* term is the analytic FLOP count of each component (gradient = 6·m·B,
one CG iteration = 2 HVPs = 12·m·B, line-search eval = 2·m·B) at the paper's
per-node throughput × 50% efficiency, and the *communication* term is the §3
ring-allreduce model. Reported: projected speedup per (node count × batch
size) — reproducing the paper's observations that scaling is near-linear
only for B ≥ 4096, that small batches are the primary scaling bottleneck,
and that the CG solve is the non-scaling component (its per-iteration
compute is batch-independent-per-node while its reduces are not).

The CPU-measured per-component times are also reported (sanity anchor for
the FLOP model), via one small-B run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_mlp import TIMIT_FIG5
from repro.core import make_hvp
from repro.data import classification_dataset
from repro.models import build_mlp

from .comm_model import (hf_sstep_syncs_per_iteration, model_size,
                         speedup_model, sstep_bootstrap)

NODE_FLOPS = 2.65e12 * 0.5   # paper's Xeon node at 50% efficiency
K_CG, N_LS = 10, 2
SSTEP_S = 4                  # s-step series: one Gram sync per 4 CG iterations
SSTEP_BASIS_S = 8            # Newton-basis series: the depth the adaptive
                             # bases unlock past the monomial f32 budget


def _time_it(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def run(log=print):
    rows = []
    msize = model_size(TIMIT_FIG5)
    msize_bytes = msize * 4

    # CPU sanity anchor (small batch): measured per-component wall time
    model = build_mlp(TIMIT_FIG5)
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(jax.random.PRNGKey(0), 1024, 360, 1973)
    v = jax.tree_util.tree_map(jnp.ones_like, params)
    t_grad = _time_it(jax.jit(lambda p, b: jax.grad(model.loss_fn)(p, b)), params, data)
    t_hvp = _time_it(jax.jit(lambda p, b, vv: make_hvp(model.loss_fn, p, b)(vv)),
                     params, data, v)
    rows.append(("fig5/cpu_anchor_B1024", t_grad * 1e6,
                 f"grad={t_grad*1e3:.1f}ms hvp={t_hvp*1e3:.1f}ms "
                 f"hvp/grad={t_hvp/t_grad:.2f} (paper: ~2x gradient cost)"))

    for B in (256, 1024, 4096, 16384):
        # analytic per-node compute of one outer iteration at paper hardware
        t_grad_n = 6.0 * msize * B / NODE_FLOPS
        t_hvp_n = 12.0 * msize * (B // 4) / NODE_FLOPS   # curvature batch B/4
        t_ls_n = 2.0 * msize * B / NODE_FLOPS
        t_compute = t_compute_std = t_grad_n + K_CG * t_hvp_n + N_LS * t_ls_n
        syncs = 1 + K_CG + N_LS
        for N in (1, 2, 4, 8, 16, 32):
            sp = speedup_model(
                N, compute_s_per_node_unit=t_compute,
                bytes_per_sync=msize_bytes, syncs=syncs,
            )
            rows.append((f"fig5/B{B}_N{N}", t_compute * 1e6 / N,
                         f"speedup={sp:.2f} compute={t_compute*1e3:.1f}ms"))
        # s-step series (core/sstep.py): the CG-iteration syncs — the paper's
        # non-scaling component — collapse to one Gram per s iterations; the
        # basis needs (2s−1)/s products per iteration instead of 1 (the
        # p- and r-power chains), so per-node compute rises by that factor.
        # This is the communication-avoiding trade: it pays exactly in the
        # small-batch / many-node regime the paper identifies as the scaling
        # bottleneck.
        s = SSTEP_S
        t_compute_ss = (
            t_grad_n + K_CG * ((2 * s - 1) / s) * t_hvp_n + N_LS * t_ls_n
        )
        syncs_ss = hf_sstep_syncs_per_iteration(K_CG, N_LS, s)
        for N in (1, 2, 4, 8, 16, 32):
            sp = speedup_model(
                N, compute_s_per_node_unit=t_compute_ss,
                bytes_per_sync=msize_bytes, syncs=syncs_ss,
            )
            # speedup vs the STANDARD single-node time (apples-to-apples)
            sp_vs_std = sp * t_compute_std / t_compute_ss
            rows.append((f"fig5/sstep{s}_B{B}_N{N}", t_compute_ss * 1e6 / N,
                         f"speedup={sp_vs_std:.2f} syncs={syncs_ss}v{syncs}"))
        # Newton-basis s-step series (core/sstep.py, §Perf pair G): the
        # adaptive basis doubles usable s past the monomial f32 budget,
        # which pays in the DEEP-solve regime — at K=10, s=8's bootstrap
        # cycles eat the saving (2 boots + 1 cycle == monomial s=4's 3
        # cycles), so this series models a K=32 solve against its own
        # K=32 standard baseline (speedups are self-relative;
        # apples-to-apples within the series). Per-node compute prices
        # the bootstrap cycles' shallow chains and the full-depth cycles'
        # 2s−1 products explicitly; the sync count includes one Gram per
        # bootstrap cycle.
        sn, K_deep = SSTEP_BASIS_S, 32
        t_std_deep = t_grad_n + K_deep * t_hvp_n + N_LS * t_ls_n
        n_boot, covered = sstep_bootstrap(sn, "cg", "newton")
        s_boot = covered // max(n_boot, 1)
        cycles = -(-max(K_deep - covered, 0) // sn)
        products = n_boot * (2 * s_boot - 1) + cycles * (2 * sn - 1)
        t_compute_nb = (
            t_grad_n + products * t_hvp_n + N_LS * t_ls_n
        )
        syncs_deep = 1 + K_deep + N_LS
        syncs_nb = hf_sstep_syncs_per_iteration(K_deep, N_LS, sn,
                                                basis="newton")
        syncs_mono4 = hf_sstep_syncs_per_iteration(K_deep, N_LS, SSTEP_S)
        for N in (1, 2, 4, 8, 16, 32):
            sp = speedup_model(
                N, compute_s_per_node_unit=t_compute_nb,
                bytes_per_sync=msize_bytes, syncs=syncs_nb,
            )
            sp_vs_std = sp * t_std_deep / t_compute_nb
            rows.append((f"fig5/sstep{sn}_newton_K{K_deep}_B{B}_N{N}",
                         t_compute_nb * 1e6 / N,
                         f"speedup={sp_vs_std:.2f} "
                         f"syncs={syncs_nb}v{syncs_mono4}(mono4)v"
                         f"{syncs_deep}(std)"))
    return rows
