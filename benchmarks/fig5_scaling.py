"""Paper Fig. 5: multi-node scaling of distributed HF on the TIMIT network
(360-512x3-1973) — analytic projection PLUS an executed multi-process series.

**Projection** (CSV mode / ``projection`` key of the JSON): the paper
measures wall-clock on 1-32 Xeon nodes (2.65 TFLOP/s each) over Omni-Path;
this repo has one CPU whose wall-clock is ~10³ slower than a cluster node,
which would hide the communication term entirely. So the *compute* term is
the analytic FLOP count of each component (gradient = 6·m·B, one CG
iteration = 2 HVPs = 12·m·B, line-search eval = 2·m·B) at the paper's
per-node throughput × 50% efficiency, and the *communication* term is the
§3 ring-allreduce model. Series: standard HF, s-step (one Gram sync per s
CG iterations), Newton-basis deep solves, and the overlapped schedule
(HFConfig.overlap — double-buffered cycles, hidden gradient reduce, paired
line search; only BLOCKING syncs priced, comm_model ``overlap=True``).

**Executed** (``--executed`` / ``executed`` key, the part the projection
used to hand-wave): every combo in ``EXEC_COMBOS`` — {cg, bicgstab} ×
{s=1, s>1 newton} plus the overlap pair — actually RUNS
``core.distributed.data_parallel_hf_step`` twice: once as a single
process and once as 2 coordinated processes (launch/multiproc.py:
jax.distributed + gloo CPU collectives, one device per process), with
``cg_tol=0`` pinning the Krylov iteration count. Each run records the
per-step metrics AND the executed collective counts from
``core.collectives.count_executed`` (a debug-callback tally that fires
per execution, while_loop trips included). ``check()`` then asserts, on
the artifact CI publishes (``BENCH_scaling.json`` via
``benchmarks/run.py --check``):

  * 2-process loss trajectory == single-process (numerical parity),
  * executed collective counts identical across process counts,
  * ``metrics["blocking_syncs"]`` == comm_model
    ``hf_sstep_syncs_per_iteration(K_exec, E_exec, s, solver, basis,
    overlap)`` for every combo — the claim, the formula, and the executed
    program agree,
  * the overlap pair: strictly fewer blocking syncs, loss parity.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs.paper_mlp import TIMIT_FIG5
from repro.core import HFConfig, hf_init, make_hvp
from repro.core.collectives import count_executed
from repro.core.distributed import data_parallel_hf_step
from repro.data import classification_dataset
from repro.launch import multiproc
from repro.models import build_mlp

from .comm_model import (hf_sstep_syncs_per_iteration, model_size,
                         speedup_model, sstep_bootstrap)

NODE_FLOPS = 2.65e12 * 0.5   # paper's Xeon node at 50% efficiency
K_CG, N_LS = 10, 2
SSTEP_S = 4                  # s-step series: one Gram sync per 4 CG iterations
SSTEP_BASIS_S = 8            # Newton-basis series: the depth the adaptive
                             # bases unlock past the monomial f32 budget
NODES = (1, 2, 4, 8, 16, 32)
BATCHES = (256, 1024, 4096, 16384)

JSON_OUT = "BENCH_scaling.json"


def _time_it(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


# ---------------------------------------------------------------- projection

def projection_records(B: int) -> list:
    """Analytic speedup records for one batch size, all series."""
    msize = model_size(TIMIT_FIG5)
    msize_bytes = msize * 4
    t_grad_n = 6.0 * msize * B / NODE_FLOPS
    t_hvp_n = 12.0 * msize * (B // 4) / NODE_FLOPS   # curvature batch B/4
    t_ls_n = 2.0 * msize * B / NODE_FLOPS
    recs = []

    def series(name, t_compute, syncs, t_base, note=""):
        for N in NODES:
            sp = speedup_model(
                N, compute_s_per_node_unit=t_compute,
                bytes_per_sync=msize_bytes, syncs=syncs,
            )
            # speedup vs the series' STANDARD single-node time
            recs.append({
                "series": name, "B": B, "N": N,
                "speedup": round(sp * t_base / t_compute, 4),
                "syncs": syncs,
                "t_compute_ms": round(t_compute * 1e3, 4),
                "note": note,
            })

    t_std = t_grad_n + K_CG * t_hvp_n + N_LS * t_ls_n
    series("standard", t_std, 1 + K_CG + N_LS, t_std)

    # s-step: the CG-iteration syncs — the paper's non-scaling component —
    # collapse to one Gram per s iterations; the basis needs (2s−1)/s
    # products per iteration instead of 1 (the p- and r-power chains), so
    # per-node compute rises by that factor. The communication-avoiding
    # trade pays exactly in the small-batch / many-node regime the paper
    # identifies as the scaling bottleneck.
    s = SSTEP_S
    t_ss = t_grad_n + K_CG * ((2 * s - 1) / s) * t_hvp_n + N_LS * t_ls_n
    series(f"sstep{s}", t_ss, hf_sstep_syncs_per_iteration(K_CG, N_LS, s),
           t_std)

    # Overlapped schedule on the same solve: double-buffered cycles run at
    # effective stride 2s ((4s−1)/2s products per iteration), the paired
    # line search speculates one extra eval per shared round-trip, the
    # gradient reduce hides behind the curvature build. Only BLOCKING
    # syncs enter the latency term — the hidden reduces' bytes still flow,
    # priced into nothing here because the §3 model charges latency per
    # *blocking* sync (comm_model overlap formulas carry the byte side).
    t_ov = (t_grad_n + K_CG * ((4 * s - 1) / (2 * s)) * t_hvp_n
            + 2 * math.ceil(N_LS / 2) * t_ls_n)
    series(f"sstep{s}_overlap", t_ov,
           hf_sstep_syncs_per_iteration(K_CG, N_LS, s, overlap=True),
           t_std, note="blocking syncs only")

    # Newton-basis deep solve (§Perf pair G): adaptive bases double usable
    # s past the monomial f32 budget; pays in the DEEP-solve regime — at
    # K=10, s=8's bootstrap cycles eat the saving, so this series models a
    # K=32 solve against its own K=32 standard baseline.
    sn, K_deep = SSTEP_BASIS_S, 32
    t_std_deep = t_grad_n + K_deep * t_hvp_n + N_LS * t_ls_n
    n_boot, covered = sstep_bootstrap(sn, "cg", "newton")
    s_boot = covered // max(n_boot, 1)
    cycles = -(-max(K_deep - covered, 0) // sn)
    products = n_boot * (2 * s_boot - 1) + cycles * (2 * sn - 1)
    t_nb = t_grad_n + products * t_hvp_n + N_LS * t_ls_n
    series(f"sstep{sn}_newton_K{K_deep}", t_nb,
           hf_sstep_syncs_per_iteration(K_deep, N_LS, sn, basis="newton"),
           t_std_deep, note=f"vs K={K_deep} standard")
    return recs


def run(log=print):
    """CSV rows: CPU anchor + the projection series."""
    rows = []
    # CPU sanity anchor (small batch): measured per-component wall time
    model = build_mlp(TIMIT_FIG5)
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(jax.random.PRNGKey(0), 1024, 360, 1973)
    v = jax.tree_util.tree_map(jnp.ones_like, params)
    t_grad = _time_it(jax.jit(lambda p, b: jax.grad(model.loss_fn)(p, b)), params, data)
    t_hvp = _time_it(jax.jit(lambda p, b, vv: make_hvp(model.loss_fn, p, b)(vv)),
                     params, data, v)
    rows.append(("fig5/cpu_anchor_B1024", t_grad * 1e6,
                 f"grad={t_grad*1e3:.1f}ms hvp={t_hvp*1e3:.1f}ms "
                 f"hvp/grad={t_hvp/t_grad:.2f} (paper: ~2x gradient cost)"))
    for B in BATCHES:
        for r in projection_records(B):
            rows.append((f"fig5/{r['series']}_B{B}_N{r['N']}",
                         r["t_compute_ms"] * 1e3 / r["N"],
                         f"speedup={r['speedup']:.2f} syncs={r['syncs']}"))
    return rows


# ------------------------------------------------------------------ executed

EXEC_DIMS = (16, 32, 4)
EXEC_BATCH = 16
EXEC_K = 8                   # cg_tol=0 pins the solve to exactly K iterations

# {cg, bicgstab} × {s=1, s>1 newton} + the monomial overlap pair. Shapes
# stay tiny — what's measured is the collective schedule, not throughput.
EXEC_COMBOS = {
    "cg_s1": dict(solver="gn_cg", s=1, basis="monomial", overlap=False),
    "cg_s4_newton": dict(solver="gn_cg", s=4, basis="newton", overlap=False),
    "bicgstab_s1": dict(solver="bicgstab", s=1, basis="monomial", overlap=False),
    # One outer step: Bi-CG-STAB's non-normal recurrence amplifies the
    # pmean summation-order delta between process counts once the step-2
    # solve is ill-converged (residual ~0.07), so later-step losses are
    # chaos, not schedule. Step 1 carries the parity + sync-count claim;
    # the schedule itself is step-independent.
    "bicgstab_s2_newton": dict(
        solver="bicgstab", s=2, basis="newton", overlap=False, n_steps=1),
    "cg_s2": dict(solver="gn_cg", s=2, basis="monomial", overlap=False),
    "cg_s2_overlap": dict(solver="gn_cg", s=2, basis="monomial", overlap=True),
}


def run_combo(name: str, steps: int = 2) -> dict:
    """Execute one combo on the CURRENT process set (1 or N processes) and
    tally its collectives. Deterministic: same seeds, same data, every
    process computes the identical global batch."""
    spec = EXEC_COMBOS[name]
    model = build_mlp(EXEC_DIMS)
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(
        jax.random.PRNGKey(0), EXEC_BATCH, EXEC_DIMS[0], EXEC_DIMS[-1])
    cfg = HFConfig(
        solver=spec["solver"], max_cg_iters=EXEC_K, cg_tol=0.0,
        init_damping=spec.get("damping", 1.0),
        sstep_s=spec["s"], sstep_basis=spec["basis"], overlap=spec["overlap"],
    )
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    step = data_parallel_hf_step(
        model.loss_fn, mesh, cfg,
        model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn,
    )
    p = multiproc.replicate(params, mesh)
    s = multiproc.replicate(hf_init(params, cfg), mesh)
    batch = multiproc.shard_batch(data, mesh)
    step_rows = []
    with count_executed() as counts:
        jitted = jax.jit(step)
        for _ in range(steps):
            p, s, m = jitted(p, s, batch)
            jax.block_until_ready(p)
            step_rows.append({k: float(v) for k, v in m.items()})
    return {
        "combo": name, **spec,
        "n_processes": jax.process_count(),
        "final_loss": step_rows[-1]["loss_new"],
        "steps": step_rows,
        "executed": counts.per_device(len(jax.local_devices())),
    }


def _spawn_combo(name: str, n_processes: int, steps: int) -> dict:
    """Run a combo as n_processes fresh coordinated processes (1 device
    each) and collect the primary's record."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "record.json")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src"), root]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        multiproc.spawn(
            n_processes, "benchmarks.fig5_scaling",
            ["--worker", "--combo", name, "--worker-out", out,
             "--steps", str(steps)],
            env=env,
        )
        with open(out) as f:
            return json.load(f)


def run_executed(steps: int = 2, log=print) -> list:
    records = []
    for name in EXEC_COMBOS:
        combo_steps = EXEC_COMBOS[name].get("n_steps", steps)
        for nproc in (1, 2):
            rec = _spawn_combo(name, nproc, combo_steps)
            records.append(rec)
            blocking = [int(r["blocking_syncs"]) for r in rec["steps"]]
            log(f"  [{name}] nproc={nproc} loss={rec['final_loss']:.6f} "
                f"blocking/step={blocking} executed={rec['executed']}")
    return records


def run_bench(tiny: bool = False, out_path: str = JSON_OUT, log=print) -> dict:
    # 2 outer steps in both modes: step counts don't change the schedule
    # (what this bench measures), and later steps on tol=0 tiny solves
    # drift into roundoff-order chaos that would flake the parity check.
    steps = 2
    log(f"fig5 executed series: mlp{EXEC_DIMS} batch={EXEC_BATCH} "
        f"K={EXEC_K} steps={steps} combos={list(EXEC_COMBOS)}")
    result = {
        "schema": 1,
        "meta": {
            "timit_dims": list(TIMIT_FIG5),
            "exec_dims": list(EXEC_DIMS), "exec_batch": EXEC_BATCH,
            "exec_K": EXEC_K, "exec_steps": steps, "tiny": tiny,
            "backend": jax.default_backend(),
        },
        "projection": [r for B in BATCHES for r in projection_records(B)],
        "executed": run_executed(steps, log),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    log(f"wrote {out_path}")
    return result


def check(result):
    """Acceptance assertions for BENCH_scaling.json (owned by this bench —
    benchmarks/run.py --check calls it next to the writer)."""
    assert result["schema"] == 1
    proj = result["projection"]
    # Overlap projection: strictly fewer blocking syncs than the same-s
    # non-overlapped series, at every batch size.
    for B in BATCHES:
        ss = next(r for r in proj
                  if r["series"] == f"sstep{SSTEP_S}" and r["B"] == B)
        ov = next(r for r in proj
                  if r["series"] == f"sstep{SSTEP_S}_overlap" and r["B"] == B)
        assert ov["syncs"] < ss["syncs"] < 1 + K_CG + N_LS, (ss, ov)

    by = {(r["combo"], r["n_processes"]): r for r in result["executed"]}
    for name, spec in EXEC_COMBOS.items():
        r1, r2 = by[(name, 1)], by[(name, 2)]
        # Multi-process parity: same math, different process count.
        assert abs(r1["final_loss"] - r2["final_loss"]) <= 1e-4 * max(
            1.0, abs(r1["final_loss"])), (name, r1["final_loss"], r2["final_loss"])
        # The executed collective schedule must not depend on process count.
        assert r1["executed"] == r2["executed"], (name, r1["executed"],
                                                  r2["executed"])
        family = "bicgstab" if spec["solver"] == "bicgstab" else "cg"
        for st in r2["steps"]:
            # No guard fallbacks: the combos are chosen inside the
            # conditioning envelope, so the schedule is the clean one.
            assert st["sstep_fallback"] == 0.0, (name, st)
            # The tentpole cross-check: reported blocking syncs == comm
            # model formula at the EXECUTED iteration/eval counts.
            expect = hf_sstep_syncs_per_iteration(
                int(st["cg_iters"]), int(st["ls_evals"]), spec["s"],
                solver=family, basis=spec["basis"], overlap=spec["overlap"])
            assert int(st["blocking_syncs"]) == expect, (
                name, st["blocking_syncs"], expect, st)
        # Executed loss-reduce count: one f0 + one per line-search eval,
        # per step (validates the counter against the executed program).
        n_loss = r2["executed"].get("loss", 0)
        assert n_loss == sum(1 + int(st["ls_evals"]) for st in r2["steps"]), (
            name, n_loss, r2["steps"])
    # The overlap pair: fewer executed blocking syncs at loss parity.
    base, ov = by[("cg_s2", 2)], by[("cg_s2_overlap", 2)]
    b_base = sum(int(st["blocking_syncs"]) for st in base["steps"])
    b_ov = sum(int(st["blocking_syncs"]) for st in ov["steps"])
    assert b_ov < b_base, (b_ov, b_base)
    assert abs(base["final_loss"] - ov["final_loss"]) <= 5e-3 * max(
        1.0, abs(base["final_loss"])), (base["final_loss"], ov["final_loss"])


def summary(result):
    """One-line headline for the --summary markdown table."""
    best = max(result["projection"], key=lambda r: r["speedup"])
    return (f"projected {best['speedup']:.2f}x (series {best['series']}, "
            f"N={best['N']}); executed {len(result['executed'])} runs")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--out", default=JSON_OUT)
    ap.add_argument("--executed", action="store_true",
                    help="run the executed multi-process series and write "
                         "the JSON artifact (default: print projection CSV)")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--combo", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--worker-out", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--steps", type=int, default=2, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.worker:
        multiproc.initialize_from_env()
        rec = run_combo(args.combo, steps=args.steps)
        if multiproc.is_primary() and args.worker_out:
            with open(args.worker_out, "w") as f:
                json.dump(rec, f, indent=1)
        return
    if args.executed:
        result = run_bench(tiny=args.tiny, out_path=args.out)
        check(result)
        print("executed-series checks ok")
        return
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
