"""Aggregate the dry-run JSON records into the EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import glob
import json
import os

COLS = ("arch", "shape", "mesh", "status", "compute_s", "memory_s",
        "collective_s", "bottleneck", "useful", "hbm_gib")


def load_records(dryrun_dir="experiments/dryrun", solver="bicgstab"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*_{solver}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def row_of(r):
    if r["status"] != "ok":
        return {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": r["status"] + (f" ({r.get('reason','')})" if r.get("reason") else ""),
            "compute_s": "", "memory_s": "", "collective_s": "",
            "bottleneck": "", "useful": "", "hbm_gib": "",
        }
    t = r["roofline"]
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"], "status": "ok",
        "compute_s": f"{t['compute_s']:.2e}", "memory_s": f"{t['memory_s']:.2e}",
        "collective_s": f"{t['collective_s']:.2e}",
        "bottleneck": t["bottleneck"].replace("_s", ""),
        "useful": r.get("useful_flops_ratio", ""),
        "hbm_gib": r.get("memory", {}).get("per_device_total_gib", ""),
    }


def markdown_table(recs):
    rows = [row_of(r) for r in recs]
    head = "| " + " | ".join(COLS) + " |"
    sep = "|" + "---|" * len(COLS)
    body = ["| " + " | ".join(str(row[c]) for c in COLS) + " |" for row in rows]
    return "\n".join([head, sep] + body)


def run(log=print):
    recs = load_records()
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    rows = [("roofline/records_ok", 0.0, f"count={len(ok)}"),
            ("roofline/records_skipped", 0.0, f"count={len(skipped)}"),
            ("roofline/records_error", 0.0, f"count={len(err)}")]
    return rows


if __name__ == "__main__":
    print(markdown_table(load_records()))
