"""Decode-path benchmarks: split-K flash decode, paged KV, continuous batching.

  PYTHONPATH=src python benchmarks/decode_bench.py [--tiny] [--out PATH]

Three sections, one JSON (``BENCH_decode.json``):

  * **kernel** — one-token decode attention over a full rolling cache of
    W slots, flash (``ops.flash_decode``) vs `_sdpa` (the jnp fallback):
    wall time (median-of-reps, jitted) and XLA compiled peak temp memory
    (``memory_analysis().temp_size_in_bytes``). `_sdpa` materializes the
    (B, KV, G, 1, W) logits plus softmax temps; the kernel streams W in
    blocks and keeps (o, m, l) partials. On TPU the acceptance is direct:
    flash peak temp <= `_sdpa` at W=8192. Off-TPU the interpreter carries
    full K/V copies through its grid loop (~3x the cache, measured: same
    temp whether H=2 or H=48), which swamps an O(W)-vs-O(W) comparison
    that flash wins on real hardware — so the acceptance there is the
    slope of peak temp in the query-head count at fixed (W, KV): `_sdpa`
    pays ~W*4 B/head for the logits it materializes, flash only the
    (B, KV, ns, G) partial stats. The slope isolates exactly the term the
    kernel exists to eliminate and is immune to the constant carry.
  * **paged** — KV-cache HBM for a ragged batch: dense allocates
    B x max_len slots regardless of occupancy, the page pool allocates
    ceil(len/page_size) pages per live sequence (+ the null page). Both
    sides also run one decode step over identical logical contents and the
    max|flash - paged| parity is recorded.
  * **continuous** — ``launch.serve.serve_continuous`` against its own
    ``gang=True`` degradation (batch-at-once: admission waits for the whole
    batch to drain) on the same step clock, same Poisson arrival trace,
    same ragged generation lengths. The deterministic signal is
    tokens/step — gang mode holds freed slots idle while the longest
    request in the wave finishes.

Off-TPU the Pallas kernel runs in **interpret mode**: wall-clock numbers
time the interpreter's per-block HLO and are recorded for completeness
only — the honest CPU signals are the memory columns and tokens/step
(EXPERIMENTS.md §Perf pair H; TPU re-measure is a ROADMAP item).
``--tiny`` is the CI smoke mode (smaller shapes, 1 rep, same code paths,
same JSON, same acceptance at W=8192).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.flash_decode import decode_bias, paged_bias
from repro.models.attention import _sdpa

JSON_OUT = "BENCH_decode.json"


def _time_it(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        ts.append(time.time() - t0)
    return sorted(ts)[len(ts) // 2]


def _temp_bytes(jitted, *args):
    ma = jitted.lower(*args).compile().memory_analysis()
    return None if ma is None else int(ma.temp_size_in_bytes)


_IMPLS = {
    "flash": lambda q, k, v, b: ops.flash_decode(q, k, v, b),
    "sdpa": lambda q, k, v, b: _sdpa(
        q[:, None], k, v, (b == 0.0)[:, None, None, :])[:, 0],
}


def _kernel_rows(seqs, B, H, KV, hd, reps, log):
    """flash_decode vs _sdpa single-token decode at each cache depth W."""
    rows = []
    for W in seqs:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, W, KV, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, W, KV, hd), jnp.float32)
        pos = jnp.arange(W, dtype=jnp.int32)
        t = jnp.asarray(W - 1, jnp.int32)
        bias = decode_bias(pos, t)                       # (1, W), all valid

        for impl, raw in _IMPLS.items():
            fn = jax.jit(raw)
            t_w = _time_it(fn, q, k, v, bias, reps=reps)
            mem = _temp_bytes(fn, q, k, v, bias)
            rows.append({"W": W, "impl": impl, "wall_s": round(t_w, 5),
                         "temp_bytes": mem,
                         "tok_per_s": round(B / max(t_w, 1e-9), 1)})
            log(f"  W={W:6d} {impl:5s} {t_w * 1e3:9.2f} ms  "
                f"temp={mem if mem is not None else '?'} B")
    return rows


def _head_slopes(W, B, H, KV, hd):
    """d(peak temp)/d(query head) at fixed (W, KV): the (B, H, W) logits
    term `_sdpa` materializes and flash streams away (the off-TPU form of
    the memory acceptance — see the module docstring)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    k = jax.random.normal(ks[1], (B, W, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, W, KV, hd), jnp.float32)
    bias = decode_bias(jnp.arange(W, dtype=jnp.int32),
                       jnp.asarray(W - 1, jnp.int32))
    slopes = {}
    for impl, raw in _IMPLS.items():
        temps = []
        for h in (H, 4 * H):
            q = jax.random.normal(ks[0], (B, h, hd), jnp.float32)
            temps.append(_temp_bytes(jax.jit(raw), q, k, v, bias))
        if None in temps:
            return None
        slopes[impl] = round((temps[1] - temps[0]) / (3 * H), 1)
    return slopes


def _paged_section(lengths, max_len, ps, KV, hd, H, log):
    """HBM bytes + one-step parity: page pool vs dense ragged cache."""
    B = len(lengths)
    maxp = -(-max_len // ps)
    n_pages = 1 + sum(-(-l // ps) for l in lengths)      # + null page 0

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kd = jnp.zeros((B, max_len, KV, hd), jnp.float32)
    vd = jnp.zeros((B, max_len, KV, hd), jnp.float32)
    pos = jnp.full((B, max_len), -1, jnp.int32)
    k_pool = jnp.zeros((n_pages, ps, KV, hd), jnp.float32)
    v_pool = jnp.zeros((n_pages, ps, KV, hd), jnp.float32)
    table = np.full((B, maxp), -1, np.int32)
    nxt = 1
    for b, ln in enumerate(lengths):
        kb = jax.random.normal(jax.random.fold_in(ks[1], b), (ln, KV, hd))
        vb = jax.random.normal(jax.random.fold_in(ks[2], b), (ln, KV, hd))
        kd = kd.at[b, :ln].set(kb)
        vd = vd.at[b, :ln].set(vb)
        pos = pos.at[b, :ln].set(jnp.arange(ln))
        pad = -(-ln // ps) * ps
        kp = jnp.zeros((pad, KV, hd)).at[:ln].set(kb).reshape(-1, ps, KV, hd)
        vp = jnp.zeros((pad, KV, hd)).at[:ln].set(vb).reshape(-1, ps, KV, hd)
        npg = pad // ps
        k_pool = k_pool.at[nxt:nxt + npg].set(kp)
        v_pool = v_pool.at[nxt:nxt + npg].set(vp)
        table[b, :npg] = np.arange(nxt, nxt + npg)
        nxt += npg
    table = jnp.asarray(table)
    seq_len = jnp.asarray(lengths, jnp.int32)

    bias_d = decode_bias(pos, seq_len - 1)
    bias_p = paged_bias(table, seq_len, ps)
    dense_fn = jax.jit(lambda q, k, v, b: ops.flash_decode(q, k, v, b))
    paged_fn = jax.jit(lambda q, kp, vp, tb, b: ops.flash_decode_paged(
        q, kp, vp, tb, b))
    o_d = dense_fn(q, kd, vd, bias_d)
    o_p = paged_fn(q, k_pool, v_pool, table, bias_p)
    parity = float(jnp.max(jnp.abs(o_d - o_p)))

    kv_item = KV * hd * 4 * 2                            # k+v, f32 bytes
    dense_bytes = B * max_len * kv_item
    paged_bytes = (n_pages * ps * kv_item                # pool (incl. null)
                   + table.size * 4 + B * 4 + n_pages * 4)  # table + lens + free stack
    out = {"lengths": list(lengths), "max_len": max_len, "page_size": ps,
           "n_pages": n_pages, "dense_bytes": dense_bytes,
           "paged_bytes": paged_bytes,
           "hbm_ratio": round(dense_bytes / paged_bytes, 2),
           "parity_maxdiff": parity,
           "wall_s_dense": round(_time_it(dense_fn, q, kd, vd, bias_d,
                                          reps=1), 5),
           "wall_s_paged": round(_time_it(paged_fn, q, k_pool, v_pool,
                                          table, bias_p, reps=1), 5)}
    log(f"  paged: lengths={list(lengths)} dense={dense_bytes} B "
        f"paged={paged_bytes} B (x{out['hbm_ratio']}) parity={parity:.2e}")
    return out


def _continuous_section(n_req, slots, prompt_len, gen_len, log):
    """Continuous batching vs gang (batch-at-once) on one Poisson trace."""
    from repro.launch.serve import serve_continuous

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.poisson(1.0, n_req)).tolist()
    gen_lens = rng.integers(2, gen_len + 1, n_req).tolist()
    out = {"n_requests": n_req, "slots": slots, "prompt_len": prompt_len,
           "arrival_steps": arrivals, "gen_lens": gen_lens}
    toks = {}
    for mode, gang in (("continuous", False), ("batch_at_once", True)):
        t, stats = serve_continuous(
            "qwen2-1.5b", smoke=True, batch_size=slots, n_requests=n_req,
            prompt_len=prompt_len, gen_len=gen_len, arrival_steps=arrivals,
            gen_lens=gen_lens, gang=gang, log_fn=lambda *a: None)
        toks[mode] = t
        out[mode] = {"steps": stats["steps"],
                     "tok_per_step": round(stats["tok_per_step"], 3),
                     "wall_s": round(stats["wall_s"], 3),
                     "tok_per_s": round(stats["tok_per_s"], 1)}
        log(f"  {mode}: {stats['steps']} steps, "
            f"{stats['tok_per_step']:.2f} tok/step, {stats['wall_s']:.2f}s")
    # both schedulers must emit identical tokens per request
    out["tokens_equal"] = bool(
        np.array_equal(toks["continuous"], toks["batch_at_once"]))
    return out


def run_bench(tiny: bool = False, out_path: str = JSON_OUT, log=print):
    if tiny:
        seqs, B, H, KV, hd, reps = [1024, 8192], 1, 2, 1, 64, 1
        lengths, max_len, ps = [8, 16, 48, 64], 64, 8
        n_req, slots, prompt_len, gen_len = 5, 2, 8, 6
    else:
        seqs, B, H, KV, hd, reps = [1024, 8192, 32768], 4, 8, 2, 128, 3
        lengths, max_len, ps = [512, 1024, 4096, 8192], 8192, 128
        n_req, slots, prompt_len, gen_len = 16, 4, 32, 24

    log(f"decode bench: B={B} H={H} KV={KV} hd={hd} W={seqs}"
        f"{' [tiny]' if tiny else ''}")
    rows = _kernel_rows(seqs, B, H, KV, hd, reps, log)
    paged = _paged_section(lengths, max_len, ps, KV, hd, H, log)
    cont = _continuous_section(n_req, slots, prompt_len, gen_len, log)

    def temp(W, impl):
        for r in rows:
            if (r["W"], r["impl"]) == (W, impl):
                return r["temp_bytes"]
        return None

    W_acc = 8192 if 8192 in seqs else max(seqs)
    tf, ts = temp(W_acc, "flash"), temp(W_acc, "sdpa")
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu:
        # direct: flash peak temp <= the logits-materializing fallback
        mem_ok = None if tf is None or ts is None else bool(tf <= ts)
        slopes = None
    else:
        # interpret mode: per-query-head temp slope isolates the
        # (B, H, W) logits term from the interpreter's constant K/V carry
        slopes = _head_slopes(W_acc, B, H, KV, hd)
        mem_ok = None if slopes is None else bool(
            slopes["flash"] <= slopes["sdpa"])
    summary = {
        "W_acc": W_acc,
        "mem_ok": mem_ok,
        "mem_metric": "temp_bytes" if on_tpu else "temp_bytes_per_head",
        "head_slopes": slopes,
        "mem_ratio": None if tf is None or ts is None
        else round(ts / max(tf, 1), 2),
        "paged_hbm_ok": bool(paged["paged_bytes"] < paged["dense_bytes"]),
        "paged_parity_ok": bool(paged["parity_maxdiff"] < 1e-4),
        # acceptance: continuous throughput (deterministic tok/step) >=
        # batch-at-once on the same trace, with identical tokens
        "cont_ok": bool(
            cont["continuous"]["tok_per_step"]
            >= cont["batch_at_once"]["tok_per_step"]
            and cont["tokens_equal"]),
    }
    log(f"  summary: {summary}")

    result = {
        "config": {"B": B, "H": H, "KV": KV, "hd": hd, "seqs": seqs,
                   "reps": reps, "tiny": tiny,
                   "backend": jax.default_backend(),
                   "interpret": jax.default_backend() != "tpu"},
        "rows": rows,
        "paged": paged,
        "continuous": cont,
        "summary": summary,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    log(f"wrote {out_path}")
    return result


def check(result):
    """Schema/acceptance assertions for BENCH_decode.json (owned by this
    bench — benchmarks/run.py --check calls it next to the writer)."""
    s = result["summary"]
    assert s["mem_ok"], s
    assert s["paged_hbm_ok"] and s["paged_parity_ok"], s
    assert s["cont_ok"], s
    pairs = {(r["W"], r["impl"]) for r in result["rows"]}
    assert len(pairs) == 2 * len(result["config"]["seqs"]), pairs
    assert result["continuous"]["tokens_equal"]


def run(log=print):
    """benchmarks.run integration: CSV rows from a tiny pass (no JSON)."""
    res = run_bench(tiny=True, out_path=os.devnull, log=lambda *a: None)
    rows = []
    for r in res["rows"]:
        rows.append((f"decode/{r['impl']}_W{r['W']}", r["wall_s"] * 1e6,
                     f"temp_bytes={r['temp_bytes']}"))
    p, c, s = res["paged"], res["continuous"], res["summary"]
    rows.append(("decode/paged_hbm", 0.0,
                 f"ratio={p['hbm_ratio']} parity={p['parity_maxdiff']:.1e}"))
    rows.append(("decode/continuous_vs_gang", 0.0,
                 f"tok_per_step={c['continuous']['tok_per_step']}"
                 f"/{c['batch_at_once']['tok_per_step']} ok={s['cont_ok']}"))
    return rows


def summary(result):
    """One-line headline for the --summary markdown table."""
    s = result["summary"]
    return (f"mem_ok={s['mem_ok']} paged_parity={s['paged_parity_ok']} "
            f"continuous={s['cont_ok']}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: smaller shapes, 1 rep, same code paths")
    ap.add_argument("--out", default=JSON_OUT)
    args = ap.parse_args()
    run_bench(tiny=args.tiny, out_path=args.out)


if __name__ == "__main__":
    main()
