"""s-step (communication-avoiding) Krylov benchmarks: reduce counts,
block-HVP amortization, and training parity.

  PYTHONPATH=src python benchmarks/sstep_bench.py [--tiny] [--out PATH]

Measures, on the paper's Fig. 4 MLP (784-400-150-10):

  1. **block amortization** — one stacked (s, n) multi-tangent curvature
     product (core/blocks.py: jax.vmap over the cached linear map, residuals
     read once) vs s independent single-tangent products in the per-call
     dispatch regime the Krylov solvers use, for both the Hessian and the
     Gauss-Newton operator. The acceptance row: measurable per-product
     speedup for s ≥ 4 (on CPU the two-sided GN product — J·v and Jᵀ·u
     share one residual set — is where the amortization shows; the
     single-sided HVP's vmap lands on CPU BLAS's slow batched-matmul path
     at small s, see EXPERIMENTS.md §Perf pair E).
  2. **reduce counts** — hf_step with the standard vs s-step solvers in
     both families (Bi-CG-STAB at s=2, CG/Gauss-Newton at s ∈ {2, 4}); the
     executed blocking-reduction count per outer iteration (1 gradient +
     ``KrylovResult.syncs`` Krylov + E line-search, from the step metrics)
     must satisfy the comm model's s-step bound
     ``1 + ceil(K/s) + E`` (vs ``1 + K + E`` standard) —
     benchmarks/comm_model.py. Bi-CG-STAB at s=4 would build depth-8
     monomial chains — beyond f32, the guard falls back every step — so the
     benchmarked grid is the configuration space where s-step is *useful*,
     and fallback_frac documents the guard's firing rate in each row.
  3. **training parity + wall clock** — short deterministic training runs,
     standard vs s-step per family: the final training loss must match the
     family's standard solver within tolerance (2% of the initial loss —
     the s-step recurrence is the same math, re-associated), and per-step
     wall clock is reported (on one CPU the blocking-sync latency the
     s-step form removes does not exist, so wall parity is the expectation
     here — the win is the sync count, priced by the Fig. 5 model).
  4. **basis × s sweep** (§Perf pair G) — the Newton/Chebyshev bases at
     double the monomial f32 depth budget (CG s=8, Bi-CG-STAB s=4), at a
     deep-solve configuration (tight tol, parity-meaningful damping):
     reduces/outer vs the family's monomial-best rows, the Gram-guard
     fallback + degrade rates, and loss parity. Acceptance: the newton
     target rows run with ZERO guard fallbacks and strictly fewer
     reduces/outer than every monomial row of their family.

Results go to ``BENCH_sstep.json`` (schema 2: EXPERIMENTS.md §Perf pairs
E/G). ``--tiny`` is the CI smoke mode: smallest shapes, 1 rep, same code
paths, same JSON. ``check()`` owns the JSON's acceptance assertions
(called by ``benchmarks/run.py --check`` in CI).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.core import HFConfig, hf_init, hf_step
from repro.core.blocks import make_block_gnvp_op, make_block_hvp_op, stack_tangents
from repro.core.curvature import make_gnvp_op, make_hvp_op
from repro.core.tree_math import tree_pseudo_noise
from repro.data import classification_dataset
from repro.models import build_mlp

try:
    from .comm_model import (hf_sstep_syncs_per_iteration,
                             hf_syncs_per_iteration, sstep_bootstrap)
except ImportError:  # executed directly: python benchmarks/sstep_bench.py
    from comm_model import (hf_sstep_syncs_per_iteration,
                            hf_syncs_per_iteration, sstep_bootstrap)

# Final-loss parity band, standard vs s-step trajectories, as a fraction of
# the INITIAL loss: both runs land within this much of each other on the
# problem's loss scale (near zero training loss a relative band is noise).
LOSS_TOL_FRAC = 0.02


def _time_it(fn, *args, reps=3):
    """Median-of-reps after one warmup (load-spike-robust, same policy as
    curvature_bench)."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        ts.append(time.time() - t0)
    return sorted(ts)[len(ts) // 2]


def bench_block_products(model, params, batch, s_list, reps, log):
    """(s, n) block product vs s single products, per-call dispatch, for
    both curvature operators."""
    ops = {
        "hvp": (
            jax.jit(make_hvp_op(model.loss_fn, params, batch,
                                mode="linearize")),
            jax.jit(make_block_hvp_op(model.loss_fn, params, batch,
                                      mode="linearize")),
        ),
        "gnvp": (
            jax.jit(make_gnvp_op(model.logits_fn, model.out_loss_fn, params,
                                 batch, mode="linearize")),
            jax.jit(make_block_gnvp_op(model.logits_fn, model.out_loss_fn,
                                       params, batch, mode="linearize")),
        ),
    }
    rows = []
    for op_name, (single, blk) in ops.items():
        for s in s_list:
            tangents = [tree_pseudo_noise(params, i) for i in range(s)]
            V = stack_tangents(tangents)

            def singles(ts=tuple(tangents), single=single):
                return [single(v) for v in ts]

            t_single = _time_it(singles, reps=reps)
            t_block = _time_it(blk, V, reps=reps)
            rows.append({
                "op": op_name,
                "s": s,
                "singles_us": t_single * 1e6,
                "block_us": t_block * 1e6,
                "per_product_us": t_block * 1e6 / s,
                "speedup": round(t_single / t_block, 3),
            })
            log(f"  block-{op_name:4s} s={s}: {s}x single "
                f"{t_single*1e6:9.0f} us   block {t_block*1e6:9.0f} us   "
                f"speedup {t_single/t_block:.2f}x")
    return rows


def _train(model, params, data, cfg, steps):
    state = hf_init(params, cfg)
    step = jax.jit(lambda p, s, b, cfg=cfg: hf_step(
        model.loss_fn, p, s, b, b, cfg,
        model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
    p = params
    walls, syncs, iters, ls_evals, losses = [], [], [], [], []
    fallbacks, basis_fallbacks, degraded = [], [], []
    for i in range(steps):
        t0 = time.time()
        p, state, m = step(p, state, data)
        jax.block_until_ready(p)
        if i > 0:                      # step 0 pays compile
            walls.append(time.time() - t0)
        syncs.append(int(m["krylov_syncs"]))
        iters.append(int(m["cg_iters"]))
        ls_evals.append(int(m["ls_evals"]))
        fallbacks.append(bool(m["sstep_fallback"]))
        basis_fallbacks.append(bool(m["sstep_basis_fallback"]))
        degraded.append(bool(m["sstep_basis_degraded"]))
        losses.append(float(m["loss_new"]))
    return {
        "final_loss": losses[-1],
        "mean_wall_s": round(sum(walls) / max(len(walls), 1), 5),
        "syncs_mean": sum(syncs) / len(syncs),
        "iters_mean": sum(iters) / len(iters),
        "ls_evals_mean": sum(ls_evals) / len(ls_evals),
        "fallback_frac": sum(fallbacks) / len(fallbacks),
        # Gram-guard (basis-caused) subset of fallback_frac — Bi-CG-STAB
        # ρ/ω recurrence collapse (a standard-solver behavior) excluded.
        "basis_fallback_frac": sum(basis_fallbacks) / len(basis_fallbacks),
        # adaptive basis degraded to monomial mid-solve (fallback chain)
        "degraded_frac": sum(degraded) / len(degraded),
    }


def bench_solvers(model, params, data, K, families, steps, log):
    """Reduce counts + training parity, standard vs s-step, per solver
    family: {"bicgstab": (2,), "gn_cg": (2, 4)} — s-step Bi-CG-STAB needs
    2s-deep chains so s=2 is its f32 depth budget; the CG recurrence (depth
    s) carries s=4."""
    loss0 = float(model.loss_fn(params, data))
    rows = []
    ok = True
    loss_ok = True
    for solver, s_list in families.items():
        std = _train(model, params, data,
                     HFConfig(solver=solver, max_cg_iters=K), steps)
        E = std["ls_evals_mean"]
        rows.append({
            "solver": solver, "s": 1, **std,
            "reduces_per_outer": 1 + std["syncs_mean"] + E,
            "bound": hf_syncs_per_iteration(K, math.ceil(E)),
        })
        log(f"  standard {solver}: loss {std['final_loss']:.4f}  "
            f"wall {std['mean_wall_s']*1e3:.1f} ms  "
            f"reduces/outer {rows[-1]['reduces_per_outer']:.1f}")
        for s in s_list:
            cfg = HFConfig(solver=solver, max_cg_iters=K, sstep_s=s)
            r = _train(model, params, data, cfg, steps)
            E_s = r["ls_evals_mean"]
            reduces = 1 + r["syncs_mean"] + E_s
            bound = hf_sstep_syncs_per_iteration(K, math.ceil(E_s), s)
            row_ok = reduces <= bound + 1e-9
            row_loss_ok = (
                abs(r["final_loss"] - std["final_loss"])
                <= LOSS_TOL_FRAC * loss0
            )
            rows.append({
                "solver": f"sstep_{solver}", "s": s, **r,
                "reduces_per_outer": reduces, "bound": bound,
                "ok": row_ok, "loss_ok": row_loss_ok,
            })
            ok = ok and row_ok
            loss_ok = loss_ok and row_loss_ok
            log(f"  sstep_{solver} s={s}: loss {r['final_loss']:.4f}  "
                f"wall {r['mean_wall_s']*1e3:.1f} ms  "
                f"reduces/outer {reduces:.1f} <= bound {bound} : {row_ok}  "
                f"fallback {r['fallback_frac']:.0%}")
    return {"K": K, "steps": steps, "initial_loss": loss0, "rows": rows,
            "ok": ok, "loss_ok": loss_ok}


# §Perf pair G configuration: tight tolerance forces the Krylov solves to
# actually run K deep (the regime where communication-avoidance pays — at
# the default 5e-3 the solves terminate in a handful of iterations and
# there is nothing to batch), and the heavier damping keeps the Bi-CG-STAB
# comparison out of the NC-branch-chaotic regime where final-loss parity
# between two equally-correct solvers is meaningless (the repo's own
# tree-vs-flat standard runs differ there; see tests/test_flash_path.py's
# in-test note and tests/test_sstep.py's parity configs).
BASES_TOL = 1e-6
BASES_DAMPING = 5.0


def bench_bases(model, params, data, K, steps, tiny, log):
    """Basis × s sweep (§Perf pair G): reduces/outer + guard-fallback rate
    + loss parity, per solver family. The acceptance rows are the NEWTON
    basis at double the family's monomial f32 depth budget (CG s=8,
    Bi-CG-STAB s=4): zero Gram-guard fallbacks and reduces/outer strictly
    below every monomial row of the family; Chebyshev rows ride along
    (same zero-guard-fallback bar, reduce win not required — its widened
    interval trades a little effective depth for robustness)."""
    grids = {
        "bicgstab": [("monomial", 2), ("newton", 4), ("chebyshev", 4)],
    }
    if not tiny:
        grids["gn_cg"] = [("monomial", 2), ("monomial", 4),
                          ("newton", 8), ("chebyshev", 8)]
    target = {"bicgstab": 4, "gn_cg": 8}
    loss0 = float(model.loss_fn(params, data))
    rows = []
    ok = True
    loss_ok = True
    win_ok = True
    for family, grid in grids.items():
        kind = "bicgstab" if family == "bicgstab" else "cg"
        std = _train(model, params, data,
                     HFConfig(solver=family, max_cg_iters=K,
                              cg_tol=BASES_TOL, init_damping=BASES_DAMPING),
                     steps)
        rows.append({"solver": family, "basis": "standard", "s": 1, **std,
                     "reduces_per_outer": 1 + std["syncs_mean"]
                     + std["ls_evals_mean"]})
        log(f"  [{family}] standard: loss {std['final_loss']:.4f}  "
            f"reduces/outer {rows[-1]['reduces_per_outer']:.1f}")
        mono_best = rows[-1]["reduces_per_outer"]
        adaptive_rows = []
        for basis, s in grid:
            cfg = HFConfig(solver=family, max_cg_iters=K,
                           cg_tol=BASES_TOL, init_damping=BASES_DAMPING,
                           sstep_s=s, sstep_basis=basis)
            r = _train(model, params, data, cfg, steps)
            E = r["ls_evals_mean"]
            reduces = 1 + r["syncs_mean"] + E
            bound = hf_sstep_syncs_per_iteration(
                K, math.ceil(E), s, solver=kind, basis=basis)
            # `bound` prices the full-depth schedule. The depth-resolved
            # prefix guard may legitimately run SHORTER cycles (each still
            # ≥ 1 iteration), so the hard executed-count invariant is
            # "never more than one Gram per executed iteration, plus the
            # bootstraps and at most one degrade": row_ok checks
            # reduces ≤ max(schedule bound, per-iteration bound). When the
            # guard fell back, the merged standard-solver iterations add
            # their own syncs and the check is undefined for the row (the
            # row then documents the failure rate, which IS its point for
            # the over-budget monomial depths).
            n_boot, covered = sstep_bootstrap(s, kind, basis)
            hard = (1 + n_boot + max(r["iters_mean"] - covered, 0.0)
                    + r["degraded_frac"] + E)
            row_ok = (reduces <= max(bound, hard) + 1e-9
                      if r["fallback_frac"] == 0.0 else None)
            row_loss_ok = (
                abs(r["final_loss"] - std["final_loss"])
                <= LOSS_TOL_FRAC * loss0
            )
            row = {"solver": family, "basis": basis, "s": s, **r,
                   "reduces_per_outer": reduces, "bound": bound,
                   "ok": row_ok, "loss_ok": row_loss_ok}
            rows.append(row)
            ok = ok and (row_ok is None or row_ok)
            loss_ok = loss_ok and row_loss_ok
            if basis == "monomial":
                mono_best = min(mono_best, reduces)
            else:
                adaptive_rows.append(row)
            log(f"  [{family}] {basis} s={s}: loss {r['final_loss']:.4f}  "
                f"reduces/outer {reduces:.1f} <= bound {bound} : {row_ok}  "
                f"guard_fb {r['basis_fallback_frac']:.0%}  "
                f"degraded {r['degraded_frac']:.0%}")
        for row in adaptive_rows:
            # "Guard-quiet" = the Gram guard never forced a STANDARD-solver
            # fallback. A mid-solve degrade to the monomial basis is the
            # internal fallback-chain link — it costs one wasted reduction
            # (priced into reduces_per_outer) but keeps the s-step sync
            # schedule; its rate is reported per row (degraded_frac), not
            # counted against the acceptance.
            zero_fb = row["basis_fallback_frac"] == 0.0
            win = row["reduces_per_outer"] < mono_best - 1e-9
            row["guard_quiet"] = zero_fb
            row["beats_monomial"] = win
            win_ok = win_ok and zero_fb
            if row["basis"] == "newton" and row["s"] == target[row["solver"]]:
                win_ok = win_ok and win
        log(f"  [{family}] monomial-best reduces/outer: {mono_best:.1f}")
    # Tiny shapes are convergence-dominated (solves terminate in a handful
    # of iterations, so the bootstrap cycles eat the budget and the loss
    # trajectories diverge at band level) — like block_amortization_ok,
    # the acceptance verdicts are only meaningful from full runs.
    return {"K": K, "steps": steps, "tol": BASES_TOL,
            "init_damping": BASES_DAMPING, "initial_loss": loss0,
            "rows": rows, "ok": ok,
            "loss_ok": None if tiny else loss_ok,
            "win_ok": None if tiny else win_ok}


def run_bench(tiny: bool = False, out_path: str = "BENCH_sstep.json",
              log=print):
    if tiny:
        dims, B, K, reps, steps = (64, 32, 10), 64, 4, 1, 4
        families, block_s = {"bicgstab": (2,)}, (1, 2, 4)
        bases_K, bases_steps = 16, 4
    else:
        dims, B, K, reps, steps = (784, 400, 150, 10), 512, 16, 3, 10
        families, block_s = {"bicgstab": (2,), "gn_cg": (2, 4)}, (1, 2, 4, 8)
        bases_K, bases_steps = 16, 8
    model = build_mlp(dims)
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(jax.random.PRNGKey(0), B, dims[0], dims[-1])

    log(f"sstep bench: mlp{dims} batch={B} K={K}{' [tiny]' if tiny else ''}")
    result = {
        "schema": 2,
        "config": {"mlp": list(dims), "batch": B, "max_cg_iters": K,
                   "reps": reps, "steps": steps, "tiny": tiny,
                   "backend": jax.default_backend()},
        "block_products": bench_block_products(
            model, params, data, block_s, reps, log),
        "solvers": bench_solvers(model, params, data, K, families, steps, log),
        "bases": bench_bases(model, params, data, bases_K, bases_steps,
                             tiny, log),
    }
    # The amortization acceptance: s ≥ 4 block products beat s singles. On
    # CPU the GN product is where the residual-read amortization shows
    # (two-sided residual reuse); the HVP rows are reported alongside —
    # see EXPERIMENTS.md §Perf pair E for the CPU-vs-TPU discussion.
    amort = [r for r in result["block_products"]
             if r["s"] >= 4 and r["op"] == "gnvp"]
    result["block_amortization_ok"] = (
        bool(amort) and all(r["speedup"] > 1.0 for r in amort)
        if not tiny else None   # tiny shapes are dispatch-noise-dominated
    )
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    log(f"wrote {out_path}")
    return result


JSON_OUT = "BENCH_sstep.json"


def check(result):
    """Schema/acceptance assertions for BENCH_sstep.json (owned by this
    bench — benchmarks/run.py --check calls it next to the writer)."""
    sol = result["solvers"]
    assert sol["ok"], sol
    assert sol["loss_ok"], sol
    bases = result["bases"]
    assert bases["ok"], [r for r in bases["rows"] if not r.get("ok", True)]
    assert len(bases["rows"]) >= 4, bases["rows"]
    if bases["loss_ok"] is not None:
        assert bases["loss_ok"], [
            r for r in bases["rows"] if not r.get("loss_ok", True)]
    # §Perf pair G acceptance: newton target rows (CG s=8 / Bi-CG-STAB s=4)
    # run guard-quiet and strictly under the family's monomial-best
    # reduces/outer; chebyshev rows must be guard-quiet too. (None on
    # --tiny: convergence-dominated shapes, verdicts meaningless.)
    if bases["win_ok"] is not None:
        assert bases["win_ok"], [
            {k: r[k] for k in ("solver", "basis", "s", "reduces_per_outer",
                               "basis_fallback_frac", "degraded_frac")}
            for r in bases["rows"] if r["basis"] not in ("standard",)]
    if result.get("block_amortization_ok") is not None:
        assert result["block_amortization_ok"], result["block_products"]


def run(log=print):
    """benchmarks.run integration: CSV rows from a tiny pass (no JSON)."""
    res = run_bench(tiny=True, out_path=os.devnull, log=lambda *a: None)
    rows = []
    for r in res["block_products"]:
        rows.append((f"sstep/block_{r['op']}_s{r['s']}", r["per_product_us"],
                     f"speedup={r['speedup']}"))
    for r in res["solvers"]["rows"]:
        rows.append((f"sstep/{r['solver']}_s{r['s']}",
                     r["mean_wall_s"] * 1e6,
                     f"reduces={r['reduces_per_outer']:.1f} "
                     f"loss={r['final_loss']:.4f}"))
    for r in res["bases"]["rows"]:
        rows.append((f"sstep/bases_{r['solver']}_{r['basis']}_s{r['s']}",
                     r["mean_wall_s"] * 1e6,
                     f"reduces={r['reduces_per_outer']:.1f} "
                     f"guard_fb={r.get('basis_fallback_frac', 0.0):.2f} "
                     f"loss={r['final_loss']:.4f}"))
    return rows


def summary(result):
    """One-line headline for the --summary markdown table."""
    rows = result["solvers"]["rows"]
    std = min(r["reduces_per_outer"] for r in rows if r["s"] == 1)
    best = min(r["reduces_per_outer"] for r in rows if r["s"] > 1)
    return f"reduces/outer: sstep {best:.1f} vs standard {std:.1f}"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: smallest shapes, 1 rep, same code paths")
    ap.add_argument("--out", default="BENCH_sstep.json")
    args = ap.parse_args()
    run_bench(tiny=args.tiny, out_path=args.out)


if __name__ == "__main__":
    main()
