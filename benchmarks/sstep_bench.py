"""s-step (communication-avoiding) Krylov benchmarks: reduce counts,
block-HVP amortization, and training parity.

  PYTHONPATH=src python benchmarks/sstep_bench.py [--tiny] [--out PATH]

Measures, on the paper's Fig. 4 MLP (784-400-150-10):

  1. **block amortization** — one stacked (s, n) multi-tangent curvature
     product (core/blocks.py: jax.vmap over the cached linear map, residuals
     read once) vs s independent single-tangent products in the per-call
     dispatch regime the Krylov solvers use, for both the Hessian and the
     Gauss-Newton operator. The acceptance row: measurable per-product
     speedup for s ≥ 4 (on CPU the two-sided GN product — J·v and Jᵀ·u
     share one residual set — is where the amortization shows; the
     single-sided HVP's vmap lands on CPU BLAS's slow batched-matmul path
     at small s, see EXPERIMENTS.md §Perf pair E).
  2. **reduce counts** — hf_step with the standard vs s-step solvers in
     both families (Bi-CG-STAB at s=2, CG/Gauss-Newton at s ∈ {2, 4}); the
     executed blocking-reduction count per outer iteration (1 gradient +
     ``KrylovResult.syncs`` Krylov + E line-search, from the step metrics)
     must satisfy the comm model's s-step bound
     ``1 + ceil(K/s) + E`` (vs ``1 + K + E`` standard) —
     benchmarks/comm_model.py. Bi-CG-STAB at s=4 would build depth-8
     monomial chains — beyond f32, the guard falls back every step — so the
     benchmarked grid is the configuration space where s-step is *useful*,
     and fallback_frac documents the guard's firing rate in each row.
  3. **training parity + wall clock** — short deterministic training runs,
     standard vs s-step per family: the final training loss must match the
     family's standard solver within tolerance (2% of the initial loss —
     the s-step recurrence is the same math, re-associated), and per-step
     wall clock is reported (on one CPU the blocking-sync latency the
     s-step form removes does not exist, so wall parity is the expectation
     here — the win is the sync count, priced by the Fig. 5 model).

Results go to ``BENCH_sstep.json`` (schema: EXPERIMENTS.md §Perf pair E).
``--tiny`` is the CI smoke mode: smallest shapes, 1 rep, same code paths,
same JSON.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import jax.numpy as jnp

from repro.core import HFConfig, hf_init, hf_step
from repro.core.blocks import make_block_gnvp_op, make_block_hvp_op, stack_tangents
from repro.core.curvature import make_gnvp_op, make_hvp_op
from repro.core.tree_math import tree_pseudo_noise
from repro.data import classification_dataset
from repro.models import build_mlp

try:
    from .comm_model import hf_sstep_syncs_per_iteration, hf_syncs_per_iteration
except ImportError:  # executed directly: python benchmarks/sstep_bench.py
    from comm_model import hf_sstep_syncs_per_iteration, hf_syncs_per_iteration

# Final-loss parity band, standard vs s-step trajectories, as a fraction of
# the INITIAL loss: both runs land within this much of each other on the
# problem's loss scale (near zero training loss a relative band is noise).
LOSS_TOL_FRAC = 0.02


def _time_it(fn, *args, reps=3):
    """Median-of-reps after one warmup (load-spike-robust, same policy as
    curvature_bench)."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        ts.append(time.time() - t0)
    return sorted(ts)[len(ts) // 2]


def bench_block_products(model, params, batch, s_list, reps, log):
    """(s, n) block product vs s single products, per-call dispatch, for
    both curvature operators."""
    ops = {
        "hvp": (
            jax.jit(make_hvp_op(model.loss_fn, params, batch,
                                mode="linearize")),
            jax.jit(make_block_hvp_op(model.loss_fn, params, batch,
                                      mode="linearize")),
        ),
        "gnvp": (
            jax.jit(make_gnvp_op(model.logits_fn, model.out_loss_fn, params,
                                 batch, mode="linearize")),
            jax.jit(make_block_gnvp_op(model.logits_fn, model.out_loss_fn,
                                       params, batch, mode="linearize")),
        ),
    }
    rows = []
    for op_name, (single, blk) in ops.items():
        for s in s_list:
            tangents = [tree_pseudo_noise(params, i) for i in range(s)]
            V = stack_tangents(tangents)

            def singles(ts=tuple(tangents), single=single):
                return [single(v) for v in ts]

            t_single = _time_it(singles, reps=reps)
            t_block = _time_it(blk, V, reps=reps)
            rows.append({
                "op": op_name,
                "s": s,
                "singles_us": t_single * 1e6,
                "block_us": t_block * 1e6,
                "per_product_us": t_block * 1e6 / s,
                "speedup": round(t_single / t_block, 3),
            })
            log(f"  block-{op_name:4s} s={s}: {s}x single "
                f"{t_single*1e6:9.0f} us   block {t_block*1e6:9.0f} us   "
                f"speedup {t_single/t_block:.2f}x")
    return rows


def _train(model, params, data, cfg, steps):
    state = hf_init(params, cfg)
    step = jax.jit(lambda p, s, b, cfg=cfg: hf_step(
        model.loss_fn, p, s, b, b, cfg,
        model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
    p = params
    walls, syncs, iters, ls_evals, fallbacks, losses = [], [], [], [], [], []
    for i in range(steps):
        t0 = time.time()
        p, state, m = step(p, state, data)
        jax.block_until_ready(p)
        if i > 0:                      # step 0 pays compile
            walls.append(time.time() - t0)
        syncs.append(int(m["krylov_syncs"]))
        iters.append(int(m["cg_iters"]))
        ls_evals.append(int(m["ls_evals"]))
        fallbacks.append(bool(m["sstep_fallback"]))
        losses.append(float(m["loss_new"]))
    return {
        "final_loss": losses[-1],
        "mean_wall_s": round(sum(walls) / max(len(walls), 1), 5),
        "syncs_mean": sum(syncs) / len(syncs),
        "iters_mean": sum(iters) / len(iters),
        "ls_evals_mean": sum(ls_evals) / len(ls_evals),
        "fallback_frac": sum(fallbacks) / len(fallbacks),
    }


def bench_solvers(model, params, data, K, families, steps, log):
    """Reduce counts + training parity, standard vs s-step, per solver
    family: {"bicgstab": (2,), "gn_cg": (2, 4)} — s-step Bi-CG-STAB needs
    2s-deep chains so s=2 is its f32 depth budget; the CG recurrence (depth
    s) carries s=4."""
    loss0 = float(model.loss_fn(params, data))
    rows = []
    ok = True
    loss_ok = True
    for solver, s_list in families.items():
        std = _train(model, params, data,
                     HFConfig(solver=solver, max_cg_iters=K), steps)
        E = std["ls_evals_mean"]
        rows.append({
            "solver": solver, "s": 1, **std,
            "reduces_per_outer": 1 + std["syncs_mean"] + E,
            "bound": hf_syncs_per_iteration(K, math.ceil(E)),
        })
        log(f"  standard {solver}: loss {std['final_loss']:.4f}  "
            f"wall {std['mean_wall_s']*1e3:.1f} ms  "
            f"reduces/outer {rows[-1]['reduces_per_outer']:.1f}")
        for s in s_list:
            cfg = HFConfig(solver=solver, max_cg_iters=K, sstep_s=s)
            r = _train(model, params, data, cfg, steps)
            E_s = r["ls_evals_mean"]
            reduces = 1 + r["syncs_mean"] + E_s
            bound = hf_sstep_syncs_per_iteration(K, math.ceil(E_s), s)
            row_ok = reduces <= bound + 1e-9
            row_loss_ok = (
                abs(r["final_loss"] - std["final_loss"])
                <= LOSS_TOL_FRAC * loss0
            )
            rows.append({
                "solver": f"sstep_{solver}", "s": s, **r,
                "reduces_per_outer": reduces, "bound": bound,
                "ok": row_ok, "loss_ok": row_loss_ok,
            })
            ok = ok and row_ok
            loss_ok = loss_ok and row_loss_ok
            log(f"  sstep_{solver} s={s}: loss {r['final_loss']:.4f}  "
                f"wall {r['mean_wall_s']*1e3:.1f} ms  "
                f"reduces/outer {reduces:.1f} <= bound {bound} : {row_ok}  "
                f"fallback {r['fallback_frac']:.0%}")
    return {"K": K, "steps": steps, "initial_loss": loss0, "rows": rows,
            "ok": ok, "loss_ok": loss_ok}


def run_bench(tiny: bool = False, out_path: str = "BENCH_sstep.json",
              log=print):
    if tiny:
        dims, B, K, reps, steps = (64, 32, 10), 64, 4, 1, 4
        families, block_s = {"bicgstab": (2,)}, (1, 2, 4)
    else:
        dims, B, K, reps, steps = (784, 400, 150, 10), 512, 16, 3, 10
        families, block_s = {"bicgstab": (2,), "gn_cg": (2, 4)}, (1, 2, 4, 8)
    model = build_mlp(dims)
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(jax.random.PRNGKey(0), B, dims[0], dims[-1])

    log(f"sstep bench: mlp{dims} batch={B} K={K}{' [tiny]' if tiny else ''}")
    result = {
        "config": {"mlp": list(dims), "batch": B, "max_cg_iters": K,
                   "reps": reps, "steps": steps, "tiny": tiny,
                   "backend": jax.default_backend()},
        "block_products": bench_block_products(
            model, params, data, block_s, reps, log),
        "solvers": bench_solvers(model, params, data, K, families, steps, log),
    }
    # The amortization acceptance: s ≥ 4 block products beat s singles. On
    # CPU the GN product is where the residual-read amortization shows
    # (two-sided residual reuse); the HVP rows are reported alongside —
    # see EXPERIMENTS.md §Perf pair E for the CPU-vs-TPU discussion.
    amort = [r for r in result["block_products"]
             if r["s"] >= 4 and r["op"] == "gnvp"]
    result["block_amortization_ok"] = (
        bool(amort) and all(r["speedup"] > 1.0 for r in amort)
        if not tiny else None   # tiny shapes are dispatch-noise-dominated
    )
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    log(f"wrote {out_path}")
    return result


def run(log=print):
    """benchmarks.run integration: CSV rows from a tiny pass (no JSON)."""
    res = run_bench(tiny=True, out_path=os.devnull, log=lambda *a: None)
    rows = []
    for r in res["block_products"]:
        rows.append((f"sstep/block_{r['op']}_s{r['s']}", r["per_product_us"],
                     f"speedup={r['speedup']}"))
    for r in res["solvers"]["rows"]:
        rows.append((f"sstep/{r['solver']}_s{r['s']}",
                     r["mean_wall_s"] * 1e6,
                     f"reduces={r['reduces_per_outer']:.1f} "
                     f"loss={r['final_loss']:.4f}"))
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: smallest shapes, 1 rep, same code paths")
    ap.add_argument("--out", default="BENCH_sstep.json")
    args = ap.parse_args()
    run_bench(tiny=args.tiny, out_path=args.out)


if __name__ == "__main__":
    main()
