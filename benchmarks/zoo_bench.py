"""Model-zoo scenario sweep: HF (both NC modes) vs a first-order baseline.

  PYTHONPATH=src python benchmarks/zoo_bench.py [--tiny] [--out PATH]

Every measured number before this bench was a 4-layer MLP; the configs/
registry has promised a zoo all along. This bench runs real training on the
four in-tree architecture families that stress *different curvature
structures* (Zhang et al., arXiv:1712.07296):

  * granite-moe-1b-a400m — MoE routing (sparse expert gradients)
  * zamba2-7b            — hybrid mamba/ssd_scan SSM (long-recurrence
                           Jacobians)
  * xlstm-1.3b           — matrix-memory xLSTM recurrence
  * whisper-small        — encoder-decoder cross-attention (audio)

per optimizer mode:

  * ``hf-truncate`` — Bi-CG-STAB HF, passive NC policy (φ-best truncation)
  * ``hf-escape``   — Bi-CG-STAB HF with saddle-free |λ_min|-scaled escape
                      steps (``HFConfig.nc_mode="escape"``, the λ estimate
                      threaded through ``KrylovResult.nc_lambda``)
  * ``adam``        — first-order baseline

recording the loss trajectory, nc_found/nc_used rates and blocking
reduces/outer for each (arch, mode) cell. A separate ``saddle`` section runs
the nc_mode A/B on the paper's Fig. 2 landscape and a stiffer quartic
(λ_min = −2), counting outer steps until the iterate exits the saddle
region — the acceptance is escape ≥ truncate (never more steps) with both
reaching a minimum. Results go to ``BENCH_zoo.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (_ROOT, os.path.join(_ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)

import jax
import jax.numpy as jnp

from repro.configs import HFOptConfig, get_smoke_config
from repro.core import HFConfig, hf_init, hf_step
from repro.data import lm_batch
from repro.models import build_model
from repro.optim.api import make_optimizer

JSON_OUT = "BENCH_zoo.json"

# One family per curvature structure. The full ARCH_IDS sweep is dryrun
# territory (launch/dryrun.py); the bench trains the four the ROADMAP names.
ZOO = ("granite-moe-1b-a400m", "zamba2-7b", "xlstm-1.3b", "whisper-small")
MODES = ("hf-truncate", "hf-escape", "adam")


# ---------------------------------------------------------------- zoo sweep
def _zoo_cfg(arch: str, tiny: bool):
    """Smoke config, shrunk further in tiny mode: the HF step compiles a
    forward-over-reverse Hessian trace through the whole model, and CI pays
    that compile 2× (both nc_modes) per arch — width and depth go to the
    floor that still exercises each family's structure (the MoE router, the
    hybrid's attn-every-k interleave, the ssd_scan recurrence, the
    encoder-decoder cross-attention)."""
    cfg = get_smoke_config(arch)
    if not tiny:
        return cfg
    kw = dict(d_model=32, n_heads=2, vocab_size=128,
              d_ff=min(cfg.d_ff, 64) if cfg.d_ff else cfg.d_ff)
    if cfg.n_kv_heads:
        kw["n_kv_heads"] = min(cfg.n_kv_heads, 2)
    # hybrid needs >= 2 layers to keep one attn block in the interleave
    kw["n_layers"] = 2 if cfg.family == "hybrid" else 1
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = 1
        kw["n_audio_frames"] = 8
    return cfg.replace(**kw)


def _train_cell(arch: str, mode: str, *, steps: int, batch_size: int,
                seq_len: int, max_cg_iters: int, tiny: bool = False) -> dict:
    """Train one (arch, optimizer-mode) cell at smoke shapes; returns the
    loss trajectory plus NC/communication rates from the step metrics."""
    cfg = _zoo_cfg(arch, tiny)
    model = build_model(cfg)
    if mode == "adam":
        opt_cfg = HFOptConfig(name="adam", lr=1e-3)
    else:
        opt_cfg = HFOptConfig(
            name="bicgstab", max_cg_iters=max_cg_iters,
            nc_mode=("escape" if mode == "hf-escape" else "truncate"),
        )
    opt = make_optimizer(opt_cfg, model.loss_fn,
                         model_out_fn=model.logits_fn,
                         out_loss_fn=model.out_loss_fn)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    state = opt.init(params)
    step = jax.jit(opt.step)
    losses, nc_found, nc_used, blocking = [], 0, 0, []
    for i in range(steps):
        batch = lm_batch(jax.random.fold_in(key, 1000 + i), cfg,
                         batch_size, seq_len)
        params, state, metrics = step(params, state, batch)
        metrics = {k: float(v) for k, v in jax.device_get(metrics).items()}
        losses.append(metrics["loss"])
        nc_found += int(metrics.get("nc_found", 0.0) > 0)
        nc_used += int(metrics.get("nc_used", 0.0) > 0)
        blocking.append(metrics.get("blocking_syncs", 0.0))
    final = float(model.loss_fn(params, lm_batch(
        jax.random.fold_in(key, 999), cfg, batch_size, seq_len)))
    return {
        "loss": [round(v, 5) for v in losses],
        "final_loss": round(final, 5),
        "nc_found_rate": round(nc_found / steps, 3),
        "nc_used_rate": round(nc_used / steps, 3),
        "reduces_per_outer": round(sum(blocking) / steps, 2),
    }


# ------------------------------------------------------------ saddle A/B --
# Paper Fig. 2 (λ_min = −1 at the saddle) and a stiffer quartic (λ_min = −2):
# the escape scale |λ| doubles with the landscape's curvature while the
# truncate scale max(sol_norm, nc_min_step) does not — the A/B gap is the
# point of the saddle-free step.
_LANDSCAPES = {
    "fig2": (lambda x, y: 0.5 * x**2 + 0.25 * y**4 - 0.5 * y**2, 0.5),
    "stiff": (lambda x, y: 0.5 * x**2 + 0.25 * y**4 - 1.0 * y**2, 0.7),
}


def _saddle_ab(name: str, *, steps: int = 30) -> dict:
    f, thresh = _LANDSCAPES[name]

    def loss_fn(params, batch):
        return f(params["x"], params["y"]) + 0.0 * jnp.sum(batch)

    batch = jnp.zeros((1,))
    start = {"x": jnp.asarray(0.9, jnp.float32),
             "y": jnp.asarray(0.0, jnp.float32)}
    out = {}
    for nc_mode in ("truncate", "escape"):
        cfg = HFConfig(solver="bicgstab", max_cg_iters=10,
                       init_damping=1e-3, krylov_jitter=1e-3,
                       nc_mode=nc_mode)
        params, state = start, hf_init(start, cfg)
        step = jax.jit(
            lambda p, s, _cfg=cfg: hf_step(loss_fn, p, s, batch, batch, _cfg))
        exit_step = steps + 1
        for i in range(steps):
            params, state, _ = step(params, state)
            if exit_step > steps and abs(float(params["y"])) > thresh:
                exit_step = i + 1
        out[nc_mode] = {
            "exit_steps": exit_step,
            "final_loss": round(float(loss_fn(params, batch)), 5),
            "final_y": round(float(params["y"]), 5),
        }
    return out


def run_bench(tiny: bool = False, out_path: str = JSON_OUT, log=print):
    if tiny:
        steps, B, S, iters = 3, 4, 16, 4
    else:
        steps, B, S, iters = 8, 8, 32, 8

    archs: dict = {}
    for arch in ZOO:
        archs[arch] = {}
        for mode in MODES:
            cell = _train_cell(arch, mode, steps=steps, batch_size=B,
                               seq_len=S, max_cg_iters=iters, tiny=tiny)
            archs[arch][mode] = cell
            log(f"zoo {arch:22s} {mode:12s} "
                f"loss {cell['loss'][0]:.3f}->{cell['final_loss']:.3f} "
                f"nc_found {cell['nc_found_rate']:.2f} "
                f"reduces/outer {cell['reduces_per_outer']:.1f}")

    saddle = {name: _saddle_ab(name) for name in _LANDSCAPES}
    for name, ab in saddle.items():
        log(f"saddle {name}: escape {ab['escape']['exit_steps']} steps "
            f"vs truncate {ab['truncate']['exit_steps']}")

    result = {
        "config": {"steps": steps, "batch": B, "seq_len": S,
                   "max_cg_iters": iters, "tiny": tiny,
                   "archs": list(ZOO), "modes": list(MODES)},
        "archs": archs,
        "saddle": saddle,
    }
    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=1)
    log(f"wrote {out_path}")
    return result


def check(result):
    """Acceptance: finite training on every zoo arch under every mode, and
    escape ≥ truncate (never MORE outer steps to leave the saddle region,
    both reaching a minimum) on every saddle landscape."""
    for arch, modes in result["archs"].items():
        for mode, cell in modes.items():
            traj = cell["loss"] + [cell["final_loss"]]
            assert all(v == v and abs(v) != float("inf") for v in traj), \
                (arch, mode, traj)
        # the HF rows actually exercised the Krylov machinery
        for mode in ("hf-truncate", "hf-escape"):
            assert modes[mode]["reduces_per_outer"] > 0, (arch, modes[mode])
    for name, ab in result["saddle"].items():
        esc, tru = ab["escape"], ab["truncate"]
        assert esc["exit_steps"] <= tru["exit_steps"], (name, ab)
        # both policies end at a real minimum, not the saddle
        for row in (esc, tru):
            assert row["final_loss"] < -1e-3, (name, ab)


def summary(result):
    """One-line headline for the --summary markdown table."""
    n = len(result["archs"])
    sad = result["saddle"].get("fig2", {})
    esc = sad.get("escape", {}).get("exit_steps", "?")
    tru = sad.get("truncate", {}).get("exit_steps", "?")
    return f"{n} archs finite; fig2 exit: escape {esc} vs truncate {tru}"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--out", default=JSON_OUT)
    args = ap.parse_args()
    result = run_bench(tiny=args.tiny, out_path=args.out)
    check(result)
    print("zoo check ok")


if __name__ == "__main__":
    main()
