"""Benchmark harness — one benchmark family per paper table/figure.

CSV mode (default): print ``name,us_per_call,derived`` rows for every
registered suite.

  PYTHONPATH=src python -m benchmarks.run [--only fig3,fig4,...]

Check mode (the CI entry point): run every JSON-writing bench, write its
``BENCH_*.json`` artifact, and execute the bench's OWN ``check(result)``
assertions — each bench owns the acceptance criteria for the schema it
writes (the assertions live next to the writer, not copy-pasted into the
workflow), and the JSONs are uploaded as workflow artifacts so the perf
trajectory is inspectable per-commit.

  PYTHONPATH=src python benchmarks/run.py --tiny --check [--only sstep]

``--summary`` appends a markdown table (per bench: artifact, headline
metric, pass/fail) to ``$GITHUB_STEP_SUMMARY`` (stdout when unset) so the
per-commit perf trajectory is readable in the Actions UI without
downloading artifacts. ``--verify-artifacts`` asserts that EVERY registered
bench has written its ``BENCH_*.json`` — a bench that silently fails to
write can no longer pass green (CI runs it after the check step).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):
    # Executed as a script (python benchmarks/run.py): make the repo root
    # and src/ importable so `benchmarks.*` and `repro.*` resolve.
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (_ROOT, os.path.join(_ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)


def checked_registry() -> dict:
    """name -> module for every JSON-writing bench with its own check().

    The single source of truth for check mode, ``--verify-artifacts`` and
    the CI completeness gate: registering a bench here is what makes its
    artifact mandatory.
    """
    from benchmarks import (attention_bench, chaos_check, curvature_bench,
                            decode_bench, fig5_scaling, sstep_bench,
                            telemetry_check, zoo_bench)
    return {
        "curvature": curvature_bench,
        "sstep": sstep_bench,
        "attention": attention_bench,
        "decode": decode_bench,
        "scaling": fig5_scaling,
        "telemetry": telemetry_check,
        "chaos": chaos_check,
        "zoo": zoo_bench,
    }


def write_summary(rows: list) -> None:
    """Render the per-bench headline table as markdown, appended to
    ``$GITHUB_STEP_SUMMARY`` when set (the Actions UI), stdout otherwise."""
    lines = ["## Bench summary", "",
             "| bench | artifact | headline | status |",
             "|---|---|---|---|"]
    for name, artifact, headline, ok in rows:
        lines.append(f"| {name} | `{artifact}` | {headline} | "
                     f"{'✅ pass' if ok else '❌ FAIL'} |")
    text = "\n".join(lines) + "\n"
    out = os.environ.get("GITHUB_STEP_SUMMARY")
    if out:
        with open(out, "a") as f:
            f.write(text)
    else:
        print(text)


def verify_artifacts(only=None) -> list:
    """Every registered bench must have written its JSON artifact (and it
    must parse). Returns the missing/broken names."""
    bad = []
    for name, mod in checked_registry().items():
        if only and name not in only:
            continue
        try:
            with open(mod.JSON_OUT) as f:
                json.load(f)
        except (OSError, ValueError) as e:
            print(f"artifact missing/unreadable for bench {name!r}: "
                  f"{mod.JSON_OUT}: {e}")
            bad.append(name)
    return bad


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig3,fig4,fig5,kernels,"
                         "attention,curvature,sstep,decode,scaling,roofline,"
                         "telemetry,chaos,zoo (check mode only)")
    ap.add_argument("--tiny", action="store_true",
                    help="check mode: run the JSON benches at CI-smoke "
                         "shapes (same code paths, same schema)")
    ap.add_argument("--check", action="store_true",
                    help="run the JSON-writing benches, write BENCH_*.json "
                         "and execute each bench's own check(result) "
                         "assertions (the CI bench-smoke entry point)")
    ap.add_argument("--summary", action="store_true",
                    help="check mode: append a markdown table of per-bench "
                         "headline numbers + pass/fail to "
                         "$GITHUB_STEP_SUMMARY (stdout when unset)")
    ap.add_argument("--verify-artifacts", action="store_true",
                    help="assert every registered bench has written its "
                         "BENCH_*.json (the CI completeness gate); can run "
                         "standalone after a --check pass")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    if args.verify_artifacts and not args.check:
        missing = verify_artifacts(only)
        if missing:
            sys.exit(f"missing bench artifacts: {', '.join(missing)}")
        print(f"all registered bench artifacts present "
              f"({len(checked_registry())} registered)")
        return

    from benchmarks import (fig3_variants, fig4_batchsize, fig5_scaling,
                            kernels_bench, attention_bench,
                            curvature_bench, decode_bench, roofline_table,
                            sstep_bench)

    if args.check:
        checked = checked_registry()
        failures = []
        summary_rows = []
        for name, mod in checked.items():
            if only and name not in only:
                continue
            print(f"== {name} ({mod.JSON_OUT}) ==")
            ok, headline = True, ""
            try:
                result = mod.run_bench(tiny=args.tiny, out_path=mod.JSON_OUT)
                mod.check(result)
                print(f"== {name}: check ok ==")
            except AssertionError as e:
                ok = False
                failures.append(name)
                print(f"== {name}: CHECK FAILED: {e} ==")
            if ok and hasattr(mod, "summary"):
                try:
                    headline = mod.summary(result)
                except Exception as e:  # a summary bug must not fail CI
                    headline = f"(summary error: {type(e).__name__})"
            summary_rows.append((name, mod.JSON_OUT, headline, ok))
        # Re-read what was actually written: the artifact the workflow
        # uploads must itself satisfy the schema the check ran against.
        for name, mod in checked.items():
            if (only and name not in only) or name in failures:
                continue
            with open(mod.JSON_OUT) as f:
                json.load(f)
        if args.summary:
            write_summary(summary_rows)
        if args.verify_artifacts:
            missing = [n for n in verify_artifacts(only) if n not in failures]
            if missing:
                sys.exit(f"missing bench artifacts: {', '.join(missing)}")
        if failures:
            sys.exit(f"bench checks failed: {', '.join(failures)}")
        return

    suites = {
        "fig3": fig3_variants.run,
        "fig4": fig4_batchsize.run,
        "fig5": fig5_scaling.run,
        "kernels": kernels_bench.run,
        "attention": attention_bench.run,
        "curvature": curvature_bench.run,
        "sstep": sstep_bench.run,
        "decode": decode_bench.run,
        "roofline": roofline_table.run,
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            for row_name, us, derived in fn(log=lambda *a: None):
                print(f"{row_name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
