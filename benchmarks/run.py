"""Benchmark harness — one benchmark family per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig3,fig4,...]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig3,fig4,fig5,kernels,"
                         "attention,curvature,sstep,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (fig3_variants, fig4_batchsize, fig5_scaling, kernels_bench,
                   attention_bench, curvature_bench, roofline_table,
                   sstep_bench)
    suites = {
        "fig3": fig3_variants.run,
        "fig4": fig4_batchsize.run,
        "fig5": fig5_scaling.run,
        "kernels": kernels_bench.run,
        "attention": attention_bench.run,
        "curvature": curvature_bench.run,
        "sstep": sstep_bench.run,
        "roofline": roofline_table.run,
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            for row_name, us, derived in fn(log=lambda *a: None):
                print(f"{row_name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
