"""Benchmark harness — one benchmark family per paper table/figure.

CSV mode (default): print ``name,us_per_call,derived`` rows for every
registered suite.

  PYTHONPATH=src python -m benchmarks.run [--only fig3,fig4,...]

Check mode (the CI entry point): run every JSON-writing bench, write its
``BENCH_*.json`` artifact, and execute the bench's OWN ``check(result)``
assertions — each bench owns the acceptance criteria for the schema it
writes (the assertions live next to the writer, not copy-pasted into the
workflow), and the JSONs are uploaded as workflow artifacts so the perf
trajectory is inspectable per-commit.

  PYTHONPATH=src python benchmarks/run.py --tiny --check [--only sstep]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):
    # Executed as a script (python benchmarks/run.py): make the repo root
    # and src/ importable so `benchmarks.*` and `repro.*` resolve.
    _ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for p in (_ROOT, os.path.join(_ROOT, "src")):
        if p not in sys.path:
            sys.path.insert(0, p)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig3,fig4,fig5,kernels,"
                         "attention,curvature,sstep,decode,scaling,roofline,"
                         "telemetry,chaos (check mode only)")
    ap.add_argument("--tiny", action="store_true",
                    help="check mode: run the JSON benches at CI-smoke "
                         "shapes (same code paths, same schema)")
    ap.add_argument("--check", action="store_true",
                    help="run the JSON-writing benches, write BENCH_*.json "
                         "and execute each bench's own check(result) "
                         "assertions (the CI bench-smoke entry point)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig3_variants, fig4_batchsize, fig5_scaling,
                            kernels_bench, attention_bench, chaos_check,
                            curvature_bench, decode_bench, roofline_table,
                            sstep_bench, telemetry_check)

    if args.check:
        checked = {
            "curvature": curvature_bench,
            "sstep": sstep_bench,
            "attention": attention_bench,
            "decode": decode_bench,
            "scaling": fig5_scaling,
            "telemetry": telemetry_check,
            "chaos": chaos_check,
        }
        failures = []
        for name, mod in checked.items():
            if only and name not in only:
                continue
            print(f"== {name} ({mod.JSON_OUT}) ==")
            result = mod.run_bench(tiny=args.tiny, out_path=mod.JSON_OUT)
            try:
                mod.check(result)
                print(f"== {name}: check ok ==")
            except AssertionError as e:
                failures.append(name)
                print(f"== {name}: CHECK FAILED: {e} ==")
        # Re-read what was actually written: the artifact the workflow
        # uploads must itself satisfy the schema the check ran against.
        for name, mod in checked.items():
            if (only and name not in only) or name in failures:
                continue
            with open(mod.JSON_OUT) as f:
                json.load(f)
        if failures:
            sys.exit(f"bench checks failed: {', '.join(failures)}")
        return

    suites = {
        "fig3": fig3_variants.run,
        "fig4": fig4_batchsize.run,
        "fig5": fig5_scaling.run,
        "kernels": kernels_bench.run,
        "attention": attention_bench.run,
        "curvature": curvature_bench.run,
        "sstep": sstep_bench.run,
        "decode": decode_bench.run,
        "roofline": roofline_table.run,
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            for row_name, us, derived in fn(log=lambda *a: None):
                print(f"{row_name},{us:.1f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
