"""Curvature-engine benchmarks: naive vs linearize-once vs chunked.

  PYTHONPATH=src python benchmarks/curvature_bench.py [--tiny] [--out PATH]

Measures, on the paper's Fig. 4 MLP (784-400-150-10):

  1. **per-product** — one curvature product per application, in both the
     per-call regime (operator applied as built: naive re-traces and
     re-runs the primal forward+backward every call, linearize replays the
     cached linear map) and a jitted-handle regime (params/batch as runtime
     arguments; XLA overlaps much of the naive primal there, so the delta
     is smaller — see module notes in core/curvature.py).
  2. **solve** — a full fixed-length CG solve driving the operator once per
     iteration (per-call dispatch, the paper's MPI-root schedule where each
     CG iteration issues one product + one reduce). This is the acceptance
     row: linearized vs naive speed-up.
  3. **hf_step** — whole-step wall clock + compile time, curvature modes ×
     both Krylov backends (tree / flat-Pallas-interpret). Inside one jitted
     while_loop XLA's loop-invariant code motion can hoist the naive
     primal, so in-jit mode deltas are small on straight solvers — the
     hybrid solver's ``lax.cond`` (never hoisted) and compile times show
     the structural win; the per-call rows show the schedule win.
  4. **memory** — XLA compiled-memory analysis (temp bytes) of an hf_step
     at 1× and 10× curvature batch, unchunked vs chunked: the chunked 10×
     batch must stay ~flat (paper Fig. 4's large-batch regime at fixed
     memory).

Results go to ``BENCH_curvature.json`` (schema: EXPERIMENTS.md §Perf
pair D). ``--tiny`` is the CI smoke mode: smallest shapes, 1 rep, same
code paths, same JSON.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import HFConfig, hf_init, hf_step
from repro.core.curvature import make_gnvp_op, make_hvp_op
from repro.data import classification_dataset
from repro.models import build_mlp


def _time_it(fn, *args, reps=3):
    """Median-of-reps after one warmup (this box has load spikes; the
    median is the stable statistic)."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        ts.append(time.time() - t0)
    return sorted(ts)[len(ts) // 2]


def _ops(model, params, batch, mode, chunk):
    kw = dict(mode=mode, chunk_size=chunk)
    hvp = make_hvp_op(model.loss_fn, params, batch, **kw)
    gnvp = make_gnvp_op(model.logits_fn, model.out_loss_fn, params, batch, **kw)
    return hvp, gnvp


def bench_per_product(model, params, batch, chunk, reps, log):
    """One product per operator application, two dispatch regimes.

    * ``percall`` — the operator exactly as ``make_hvp``/``make_gnvp``
      return it, applied eagerly per Krylov iteration: naive re-traces and
      re-runs the primal forward+backward every call; linearize replays the
      once-built linear map. This is the cost the ISSUE's "per-call
      retracing" names and the regime the solve row below uses.
    * ``jit`` — a jitted handle with params/batch as *runtime arguments*
      (they change every outer step — baking them in would let XLA
      constant-fold the naive primal away at compile time). Inside one jit,
      XLA can still overlap/hoist much of the naive primal, so this delta
      is smaller and cache-noise-sensitive; reported for completeness.
    """
    v = jax.tree_util.tree_map(lambda p: jnp.ones_like(p, jnp.float32), params)
    rows = []
    for mode in ("naive", "linearize", "chunked"):
        t_build = time.time()
        hvp, gnvp = _ops(model, params, batch, mode, chunk)
        build_s = time.time() - t_build  # linearize/chunked: eager primal pass
        jitted = {
            "hvp": (jax.jit(lambda p, b, u: make_hvp_op(
                model.loss_fn, p, b, mode="naive")(u))
                    if mode == "naive" else jax.jit(hvp)),
            "gnvp": (jax.jit(lambda p, b, u: make_gnvp_op(
                model.logits_fn, model.out_loss_fn, p, b, mode="naive")(u))
                     if mode == "naive" else jax.jit(gnvp)),
        }
        for op_name, op in (("hvp", hvp), ("gnvp", gnvp)):
            t_pc = _time_it(op, v, reps=reps)
            if mode == "naive":
                t_jit = _time_it(jitted[op_name], params, batch, v, reps=reps)
            else:
                t_jit = _time_it(jitted[op_name], v, reps=reps)
            rows.append({"op": op_name, "mode": mode,
                         "chunk": chunk if mode == "chunked" else None,
                         "percall_us": t_pc * 1e6, "jit_us": t_jit * 1e6,
                         "build_s": round(build_s, 4)})
            log(f"  per-product {op_name:4s} {mode:9s} "
                f"percall {t_pc*1e6:9.0f} us   jit {t_jit*1e6:9.0f} us")
    return rows


@jax.jit
def _bicgstab_update(x, r, p, r0s, rho, v, t_vec, s, alpha):
    """Tail of one Bi-CG-STAB iteration given the two operator products
    (v = A p̂, t = A ŝ). Mode-independent flat-f32 recurrence, jitted once,
    so the solve comparison isolates the operator cost (same ravel-once
    representation the flat Krylov backend uses)."""
    omega = (t_vec @ s) / jnp.maximum(t_vec @ t_vec, 1e-20)
    x = x + alpha * p + omega * s
    r = s - omega * t_vec
    rho_new = r @ r0s
    beta = (rho_new / jnp.where(jnp.abs(rho) < 1e-20, 1.0, rho)) * (
        alpha / jnp.where(jnp.abs(omega) < 1e-20, 1.0, omega)
    )
    p = r + beta * (p - omega * v)
    return x, r, p, rho_new


def _percall_bicgstab(damped_flat_op, b_flat, iters):
    """Python-driven Bi-CG-STAB (paper Algorithm 3), fixed iteration count,
    one operator dispatch per product — the paper's MPI-root schedule (two
    products + two reduces per iteration). The operator is applied exactly
    as ``make_hvp(mode=...)`` returns it: naive re-traces and re-runs the
    primal every call, linearize replays the cached linear map."""
    x = jnp.zeros_like(b_flat)
    r = b_flat
    r0s = b_flat
    p = b_flat
    rho = r @ r0s
    for _ in range(iters):
        v = damped_flat_op(p)                        # A p̂_j
        alpha = rho / (v @ r0s)
        s = r - alpha * v
        t_vec = damped_flat_op(s)                    # A ŝ_j
        x, r, p, rho = _bicgstab_update(x, r, p, r0s, rho, v, t_vec, s, alpha)
    return x


def bench_solve(model, params, batch, iters, chunk, reps, log):
    """Acceptance row: 16-iteration Krylov solve (the paper's Bi-CG-STAB),
    per-call dispatch."""
    from jax.flatten_util import ravel_pytree

    g = jax.grad(model.loss_fn)(params, batch)
    b = jax.tree_util.tree_map(lambda x: -x.astype(jnp.float32), g)
    b_flat, unravel = ravel_pytree(b)
    lam = jnp.asarray(1.0, jnp.float32)
    out = {"solver": "bicgstab_percall", "iters": iters}
    for mode in ("naive", "linearize", "chunked"):
        hvp, _ = _ops(model, params, batch, mode, chunk)

        def flat_op(vf, hvp=hvp):
            # pytree boundary + damping charged to the operator side
            # (identical for every mode)
            return ravel_pytree(hvp(unravel(vf)))[0] + lam * vf

        t = _time_it(lambda bb: _percall_bicgstab(flat_op, bb, iters),
                     b_flat, reps=reps)
        out[f"{mode}_s"] = round(t, 5)
        log(f"  solve[{iters} it] {mode:9s} {t:8.4f} s")
    out["speedup_linearize"] = round(out["naive_s"] / out["linearize_s"], 3)
    out["speedup_chunked"] = round(out["naive_s"] / out["chunked_s"], 3)
    log(f"  solve speedup linearize/naive = {out['speedup_linearize']:.2f}x")
    return out


def bench_hf_step(model, params, data, iters, chunk, reps, backends, log):
    """Whole-jit hf_step across curvature modes × Krylov backends."""
    rows = []
    for backend in backends:
        for mode in ("naive", "linearize", "chunked"):
            cfg = HFConfig(solver="bicgstab", max_cg_iters=iters,
                           krylov_backend=backend, curvature_mode=mode,
                           curvature_chunk_size=chunk if mode == "chunked" else 0)
            state = hf_init(params, cfg)
            step = jax.jit(lambda p, s, b, cfg=cfg: hf_step(
                model.loss_fn, p, s, b, b, cfg))
            t0 = time.time()
            jax.block_until_ready(step(params, state, data)[0])
            compile_s = time.time() - t0
            t = _time_it(lambda p, s, b: step(p, s, b)[0],
                         params, state, data, reps=reps)
            rows.append({"backend": backend, "mode": mode, "wall_s": round(t, 5),
                         "compile_s": round(compile_s, 3)})
            log(f"  hf_step {backend:4s}/{mode:9s} {t:8.4f} s"
                f"  (compile {compile_s:5.2f} s)")
    return rows


def bench_memory(model, params, data_small, data_big, iters, chunk, log):
    """Compiled-memory analysis: temp bytes of hf_step vs curvature batch.

    ``batch`` (gradient + line search) is held at 1× throughout; only
    ``hvp_batch`` grows — isolating the curvature-side residual memory the
    chunked mode is built to flatten.
    """
    def temp_bytes(hvp_batch, mode, chunk_size):
        cfg = HFConfig(solver="bicgstab", max_cg_iters=iters,
                       curvature_mode=mode, curvature_chunk_size=chunk_size)
        state = hf_init(params, cfg)
        comp = jax.jit(lambda p, s, b, hb, cfg=cfg: hf_step(
            model.loss_fn, p, s, b, hb, cfg)).lower(
            params, state, data_small, hvp_batch).compile()
        ma = comp.memory_analysis()
        return None if ma is None else int(ma.temp_size_in_bytes)

    B = next(iter(jax.tree_util.tree_leaves(data_small))).shape[0]
    B10 = next(iter(jax.tree_util.tree_leaves(data_big))).shape[0]
    rows = [
        {"label": "1x_unchunked", "hvp_batch": B, "mode": "linearize",
         "chunk": 0, "temp_bytes": temp_bytes(data_small, "linearize", 0)},
        {"label": "10x_unchunked", "hvp_batch": B10, "mode": "linearize",
         "chunk": 0, "temp_bytes": temp_bytes(data_big, "linearize", 0)},
        {"label": "10x_chunked", "hvp_batch": B10, "mode": "chunked",
         "chunk": chunk, "temp_bytes": temp_bytes(data_big, "chunked", chunk)},
    ]
    out = {"rows": rows, "flat_memory_ok": None}
    if all(r["temp_bytes"] is not None for r in rows):
        base, big, flat = (r["temp_bytes"] for r in rows)
        # chunked 10× batch must cost ~the 1× footprint, not the 10× one
        out["flat_memory_ok"] = bool(flat <= 1.3 * base)
        out["unchunked_growth"] = round(big / base, 2)
        out["chunked_growth"] = round(flat / base, 2)
    for r in rows:
        log(f"  memory {r['label']:14s} hvp_batch={r['hvp_batch']:5d} "
            f"temp={r['temp_bytes'] if r['temp_bytes'] is not None else '?'} B")
    if out["flat_memory_ok"] is not None:
        log(f"  memory growth 10x unchunked={out['unchunked_growth']}x "
            f"chunked={out['chunked_growth']}x flat_ok={out['flat_memory_ok']}")
    return out


def run_bench(tiny: bool = False, out_path: str = "BENCH_curvature.json",
              log=print):
    if tiny:
        dims, B, iters, reps = (64, 32, 10), 64, 4, 1
    else:
        dims, B, iters, reps = (784, 400, 150, 10), 512, 16, 3
    chunk = B // 4
    model = build_mlp(dims)
    params = model.init(jax.random.PRNGKey(1))
    data = classification_dataset(jax.random.PRNGKey(0), B, dims[0], dims[-1])
    data_big = classification_dataset(jax.random.PRNGKey(2), 10 * B, dims[0], dims[-1])

    log(f"curvature bench: mlp{dims} batch={B} iters={iters} chunk={chunk}"
        f"{' [tiny]' if tiny else ''}")
    result = {
        "config": {"mlp": list(dims), "batch": B, "hvp_iters": iters,
                   "chunk": chunk, "reps": reps, "tiny": tiny,
                   "backend": jax.default_backend()},
        "per_product": bench_per_product(model, params, data, chunk, reps, log),
        "solve": bench_solve(model, params, data, iters, chunk, reps, log),
        # flat backend = Pallas interpret mode off-TPU: on the full-size
        # config that times the Python interpreter, so the backend matrix
        # runs flat rows at tiny scale only (kernels_bench.py, same policy).
        "hf_step": bench_hf_step(
            model, params, data, iters, chunk, reps,
            backends=("tree", "flat") if tiny else ("tree",), log=log),
        "memory": bench_memory(model, params, data, data_big, iters, chunk, log),
    }
    if not tiny:
        tiny_model = build_mlp((64, 32, 10))
        tiny_params = tiny_model.init(jax.random.PRNGKey(1))
        tiny_data = classification_dataset(jax.random.PRNGKey(0), 64, 64, 10)
        result["hf_step_flat_small"] = bench_hf_step(
            tiny_model, tiny_params, tiny_data, iters, 16, reps,
            backends=("flat",), log=log)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    log(f"wrote {out_path}")
    return result


JSON_OUT = "BENCH_curvature.json"


def check(result):
    """Schema/acceptance assertions for BENCH_curvature.json (owned by
    this bench — benchmarks/run.py --check calls it next to the writer;
    these used to live as a heredoc in the CI workflow)."""
    mem = result["memory"]
    assert mem["flat_memory_ok"], mem
    s = result["solve"]
    assert s["naive_s"] > 0 and s["linearize_s"] > 0, s
    modes = {(r["op"], r["mode"]) for r in result["per_product"]}
    assert len(modes) == 6, modes          # hvp/gnvp x naive/linearize/chunked


def run(log=print):
    """benchmarks.run integration: CSV rows from a tiny pass (no JSON)."""
    res = run_bench(tiny=True, out_path=os.devnull, log=lambda *a: None)
    rows = []
    for r in res["per_product"]:
        rows.append((f"curvature/{r['op']}_{r['mode']}", r["percall_us"],
                     f"jit_us={r['jit_us']:.0f} build_s={r['build_s']}"))
    s = res["solve"]
    rows.append((f"curvature/{s['solver']}_it{s['iters']}_naive",
                 s["naive_s"] * 1e6,
                 f"speedup_linearize={s['speedup_linearize']}"))
    for r in res["hf_step"]:
        rows.append((f"curvature/hf_step_{r['backend']}_{r['mode']}",
                     r["wall_s"] * 1e6, f"compile_s={r['compile_s']}"))
    m = res["memory"]
    if m["flat_memory_ok"] is not None:
        rows.append(("curvature/memory_10x_chunked_growth",
                     0.0, f"growth={m['chunked_growth']}x flat_ok={m['flat_memory_ok']}"))
    return rows


def summary(result):
    """One-line headline for the --summary markdown table."""
    s = result["solve"]
    m = result["memory"]
    return (f"solve speedup linearize/naive {s['speedup_linearize']}x; "
            f"chunked growth {m['chunked_growth']}x")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: smallest shapes, 1 rep, same code paths")
    ap.add_argument("--out", default="BENCH_curvature.json")
    args = ap.parse_args()
    run_bench(tiny=args.tiny, out_path=args.out)


if __name__ == "__main__":
    main()
