"""Paper Fig. 3: SGD vs HF variants on the MNIST network (784-400-10).

Reports objective vs (outer) iterations, vs epochs (effective data passes),
and vs #communications — the paper's three x-axes. One SGD "iteration" is one
epoch (paper convention). Communications are counted with the §3 model:
SGD data-parallel = 2 reduces per mini-batch; HF = 1 (grad) + K (HVP) + E
(line-search) reduces per outer iteration.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_mlp import MNIST_FIG3
from repro.core import HFConfig, hf_init, hf_step
from repro.data import classification_dataset
from repro.data.synthetic import minibatches
from repro.models import build_mlp

from .comm_model import hf_syncs_per_iteration, sgd_syncs_per_epoch

N_TRAIN = 4096
N_NODES = 16
ITERS = 15


def run(log=print):
    model = build_mlp(MNIST_FIG3)
    data = classification_dataset(jax.random.PRNGKey(0), N_TRAIN, 784, 10)
    rows = []

    for solver in ("gn_cg", "hessian_cg", "hybrid_cg", "bicgstab"):
        cfg = HFConfig(solver=solver, max_cg_iters=10)
        params = model.init(jax.random.PRNGKey(1))
        state = hf_init(params, cfg)
        step = jax.jit(lambda p, s: hf_step(
            model.loss_fn, p, s, data, data, cfg,
            model_out_fn=model.logits_fn, out_loss_fn=model.out_loss_fn))
        params, state, m = step(params, state)  # warmup/compile
        t0 = time.time()
        comms = epochs = 0.0
        for i in range(ITERS):
            params, state, m = step(params, state)
            comms += hf_syncs_per_iteration(int(m["cg_iters"]) * 2, int(m["ls_evals"]))
            epochs += 1 + 0.25 * 2 * int(m["cg_iters"]) + 0.5 * int(m["ls_evals"])
        dt = (time.time() - t0) / ITERS
        loss = float(model.loss_fn(params, data))
        rows.append((f"fig3/{solver}", dt * 1e6,
                     f"loss={loss:.4f} epochs={epochs:.0f} comms={comms:.0f}"))

    # SGD / momentum-SGD baselines, batch 64
    from repro.optim.first_order import momentum_sgd, sgd as sgd_opt
    for name, opt in (("sgd", sgd_opt(0.1)), ("msgd", momentum_sgd(0.1))):
        params = model.init(jax.random.PRNGKey(1))
        st = opt.init(params)
        stepf = jax.jit(lambda p, s, b: opt.step(model.loss_fn, p, s, b))
        b0 = next(minibatches(data, 64, seed=0))
        params, st, _ = stepf(params, st, b0)
        t0 = time.time()
        comms = 0.0
        for ep in range(ITERS):
            for b in minibatches(data, 64, seed=ep):
                params, st, _ = stepf(params, st, b)
            comms += sgd_syncs_per_epoch(N_TRAIN, 64, N_NODES)
        dt = (time.time() - t0) / ITERS
        loss = float(model.loss_fn(params, data))
        rows.append((f"fig3/{name}", dt * 1e6,
                     f"loss={loss:.4f} epochs={ITERS} comms={comms:.0f}"))
    return rows
