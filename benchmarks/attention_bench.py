"""Attention-path benchmarks: flash (Pallas) vs `_sdpa` (jnp oracle).

  PYTHONPATH=src python benchmarks/attention_bench.py [--tiny] [--out PATH]

Measures, per sequence length S ∈ {128, 512, 2048} (tiny: {128}):

  * **fwd**      — one causal attention forward,
  * **fwd_bwd**  — value-and-grad of a scalarized attention (the training
                   hot loop's per-layer cost: forward + dQ + dK/dV),
  * **jvp**      — ``jax.jvp`` through attention (the curvature engine's
                   J·v tangent pass, one application of the cached linear
                   map per Krylov iteration),

each as wall time (median-of-reps, jitted) and XLA compiled peak temp
memory (``memory_analysis().temp_size_in_bytes``, same method as
``curvature_bench.py``). The acceptance row is **fwd_bwd peak memory at the
largest S**: `_sdpa` materializes the (B, KV, G, S, S) logits in both
passes (O(S²)); the flash path stores only (o, lse) residuals and
recomputes P blockwise (O(S·blk)).

Off-TPU the Pallas kernels run in **interpret mode**: wall-clock numbers
time the interpreter's unrolled per-block HLO and systematically flatter
the jnp path — they are recorded for completeness, but the honest CPU
signal is the memory column (EXPERIMENTS.md §Perf pair F; TPU re-measure is
a ROADMAP item). Results go to ``BENCH_attention.json``; ``--tiny`` is the
CI smoke mode (smallest shapes, 1 rep, same code paths, same JSON).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.attention import _sdpa, causal_mask


def _time_it(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        ts.append(time.time() - t0)
    return sorted(ts)[len(ts) // 2]


def _temp_bytes(jitted, *args):
    ma = jitted.lower(*args).compile().memory_analysis()
    return None if ma is None else int(ma.temp_size_in_bytes)


def _paths(S, w):
    """(name -> (flash_fn, sdpa_fn)) for one sequence length."""
    flash = lambda q, k, v: ops.flash_attention(q, k, v, causal=True)
    sdpa = lambda q, k, v: _sdpa(q, k, v, causal_mask(S))

    def scalarize(f):
        return lambda q, k, v: jnp.sum(f(q, k, v) * w)

    def paths_for(f):
        return {
            "fwd": lambda q, k, v: f(q, k, v),
            "fwd_bwd": jax.grad(scalarize(f), argnums=(0, 1, 2)),
            "jvp": lambda q, k, v, qt, kt, vt: jax.jvp(
                f, (q, k, v), (qt, kt, vt))[1],
        }

    return paths_for(flash), paths_for(sdpa)


def run_bench(tiny: bool = False, out_path: str = "BENCH_attention.json",
              log=print):
    if tiny:
        seqs, B, H, KV, hd, reps = [128], 1, 2, 1, 32, 1
    else:
        seqs, B, H, KV, hd, reps = [128, 512, 2048], 1, 2, 1, 64, 3

    log(f"attention bench: B={B} H={H} KV={KV} hd={hd} S={seqs}"
        f"{' [tiny]' if tiny else ''}")
    rows = []
    for S in seqs:
        ks = jax.random.split(jax.random.PRNGKey(0), 7)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
        w = jax.random.normal(ks[3], (B, S, H, hd), jnp.float32)
        qt = jax.random.normal(ks[4], (B, S, H, hd), jnp.float32)
        kt = jax.random.normal(ks[5], (B, S, KV, hd), jnp.float32)
        vt = jax.random.normal(ks[6], (B, S, KV, hd), jnp.float32)
        flash_paths, sdpa_paths = _paths(S, w)
        for impl, paths in (("flash", flash_paths), ("sdpa", sdpa_paths)):
            for name, fn in paths.items():
                args = (q, k, v, qt, kt, vt) if name == "jvp" else (q, k, v)
                jitted = jax.jit(fn)
                t = _time_it(jitted, *args, reps=reps)
                mem = _temp_bytes(jitted, *args)
                rows.append({"S": S, "path": name, "impl": impl,
                             "wall_s": round(t, 5), "temp_bytes": mem})
                log(f"  S={S:5d} {name:7s} {impl:5s} {t * 1e3:9.2f} ms  "
                    f"temp={mem if mem is not None else '?'} B")

    def temp(S, path, impl):
        for r in rows:
            if (r["S"], r["path"], r["impl"]) == (S, path, impl):
                return r["temp_bytes"]
        return None

    S_max = max(seqs)
    summary = {"S_max": S_max, "mem_ok": None, "mem_ratio": {}}
    for name in ("fwd", "fwd_bwd", "jvp"):
        tf, ts = temp(S_max, name, "flash"), temp(S_max, name, "sdpa")
        if tf is not None and ts is not None:
            summary["mem_ratio"][name] = round(ts / max(tf, 1), 2)
    if summary["mem_ratio"].get("fwd_bwd") is not None:
        # acceptance: flash fwd+bwd beats _sdpa peak temp at the largest S
        summary["mem_ok"] = bool(summary["mem_ratio"]["fwd_bwd"] > 1.0)
    log(f"  mem ratios (sdpa/flash) at S={S_max}: {summary['mem_ratio']} "
        f"ok={summary['mem_ok']}")

    result = {
        "config": {"B": B, "H": H, "KV": KV, "hd": hd, "seqs": seqs,
                   "reps": reps, "tiny": tiny,
                   "backend": jax.default_backend(),
                   "interpret": jax.default_backend() != "tpu"},
        "rows": rows,
        "summary": summary,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    log(f"wrote {out_path}")
    return result


JSON_OUT = "BENCH_attention.json"


def check(result):
    """Schema/acceptance assertions for BENCH_attention.json (owned by
    this bench — benchmarks/run.py --check calls it next to the writer;
    these used to live as a heredoc in the CI workflow)."""
    assert result["summary"]["mem_ok"], result["summary"]
    paths = {(r["S"], r["path"], r["impl"]) for r in result["rows"]}
    n_seqs = len(result["config"]["seqs"])
    # fwd/fwd_bwd/jvp x flash/sdpa per sequence length
    assert len(paths) == 6 * n_seqs, paths


def run(log=print):
    """benchmarks.run integration: CSV rows from a tiny pass (no JSON)."""
    res = run_bench(tiny=True, out_path=os.devnull, log=lambda *a: None)
    rows = []
    for r in res["rows"]:
        rows.append((f"attention/{r['path']}_{r['impl']}_S{r['S']}",
                     r["wall_s"] * 1e6,
                     f"temp_bytes={r['temp_bytes']}"))
    s = res["summary"]
    rows.append(("attention/mem_ratio_fwd_bwd", 0.0,
                 f"ratio={s['mem_ratio'].get('fwd_bwd')} ok={s['mem_ok']}"))
    return rows


def summary(result):
    """One-line headline for the --summary markdown table."""
    s = result["summary"]
    return (f"sdpa/flash peak-temp ratio {s['mem_ratio'].get('fwd_bwd')}x "
            f"at S={s['S_max']}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: smallest shapes, 1 rep, same code paths")
    ap.add_argument("--out", default="BENCH_attention.json")
    args = ap.parse_args()
    run_bench(tiny=args.tiny, out_path=args.out)


if __name__ == "__main__":
    main()
