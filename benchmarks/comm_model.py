"""Paper §3: analytic communication model for model- vs data-parallel SGD
and for distributed HF. These are the exact formulas from the paper, used by
fig5_scaling and validated in tests/test_comm_model.py.

Model parallelism (weights split over N nodes, layer dims d_1..d_l):
  floats exchanged / epoch ≈ 2 · (n/b) · b · Σ_i d_i
  synchronizations / epoch = 2 · l · n/b

Data parallelism (weights replicated, data split):
  floats exchanged / epoch ≈ (n/b) · log(N) · Σ_i d_{i-1}·d_i
  synchronizations / epoch = 2 · n/b

Distributed HF (this paper): per OUTER iteration —
  1 gradient reduce + K Krylov-iteration HVP reduces + E line-search loss
  reduces, each of model size (gradient/HVP) or scalar (loss);
  outer iterations per epoch ≈ 1 (full-batch gradient).
"""
from __future__ import annotations

import math
from typing import Sequence


def mp_floats_per_epoch(n: int, b: int, dims: Sequence[int]) -> float:
    return 2.0 * (n / b) * b * sum(dims[1:-1] if len(dims) > 2 else dims)


def mp_syncs_per_epoch(n: int, b: int, n_layers: int) -> float:
    return 2.0 * n_layers * n / b


def dp_floats_per_epoch(n: int, b: int, dims: Sequence[int], N: int) -> float:
    weights = sum(d0 * d1 for d0, d1 in zip(dims[:-1], dims[1:]))
    return (n / b) * max(math.log2(max(N, 2)), 1.0) * weights


def dp_syncs_per_epoch(n: int, b: int) -> float:
    return 2.0 * n / b


def model_size(dims: Sequence[int]) -> int:
    return sum(d0 * d1 + d1 for d0, d1 in zip(dims[:-1], dims[1:]))


def hf_floats_per_iteration(dims: Sequence[int], cg_iters: int, ls_evals: int) -> float:
    m = model_size(dims)
    return (1 + cg_iters) * m + ls_evals  # grad + HVPs (model-sized) + scalars


def hf_syncs_per_iteration(cg_iters: int, ls_evals: int) -> int:
    return 1 + cg_iters + ls_evals


def sgd_syncs_per_epoch(n: int, b: int, N: int) -> float:
    """Data-parallel SGD: one reduce+broadcast per mini-batch step."""
    return 2.0 * n / b


def speedup_model(
    n_nodes: int, *, compute_s_per_node_unit: float, bytes_per_sync: float,
    syncs: float, bw_bytes_s: float = 12.5e9, latency_s: float = 5e-6,
) -> float:
    """T(N) = compute/N + syncs·(latency·log2(N) + bytes/bw·(N-1)/N).
    Ring-allreduce cost model; returns T(1)/T(N)."""
    t1 = compute_s_per_node_unit + 0.0
    comm = syncs * (
        latency_s * max(math.log2(max(n_nodes, 2)), 1.0)
        + (bytes_per_sync / bw_bytes_s) * (n_nodes - 1) / max(n_nodes, 1)
    )
    tn = compute_s_per_node_unit / n_nodes + comm
    return t1 / tn
