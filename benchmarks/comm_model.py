"""Paper §3: analytic communication model for model- vs data-parallel SGD
and for distributed HF. These are the exact formulas from the paper, used by
fig5_scaling and validated in tests/test_comm_model.py.

Model parallelism (weights split over N nodes, layer dims d_1..d_l):
  floats exchanged / epoch ≈ 2 · (n/b) · b · Σ_i d_i
  synchronizations / epoch = 2 · l · n/b

Data parallelism (weights replicated, data split):
  floats exchanged / epoch ≈ (n/b) · log(N) · Σ_i d_{i-1}·d_i
  synchronizations / epoch = 2 · n/b

Distributed HF (this paper): per OUTER iteration —
  1 gradient reduce + K Krylov-iteration HVP reduces + E line-search loss
  reduces, each of model size (gradient/HVP) or scalar (loss);
  outer iterations per epoch ≈ 1 (full-batch gradient).

s-step (communication-avoiding) HF (core/sstep.py): the K per-iteration
Krylov synchronizations collapse into one Gram-matrix reduction per cycle of
s iterations —
  syncs/outer iteration:  1 + ceil(K/s) + E       (vs 1 + K + E standard)
  floats/outer iteration: MORE than standard — each cycle grows BOTH the p-
  and r-power chains (2d−1 products of model size per cycle, chain depth
  d = s for CG / 2s for Bi-CG-STAB, vs s products for s standard CG
  iterations: asymptotically ~2× the reduce traffic, though those reduces
  are dependency-free within a cycle and pipeline — no scalar gate between
  them), plus one (2d+1)²-float Gram per cycle (Bi-CG-STAB's is
  (4s+1)·(4s+4) with the r0*/b/x probe columns). Trading bytes for blocking
  syncs is the communication-avoiding deal; it pays when latency dominates
  (the paper's small-batch / many-node regime).

Newton/Chebyshev s-step bases (``basis="newton"|"chebyshev"``): the f32
monomial depth budget caps s at ~4 (CG) / 2 (Bi-CG-STAB); the adaptive
bases double it (CG s=8, Bi-CG-STAB s=4 — core/sstep.py, EXPERIMENTS.md
§Perf pair G) at the cost of ``sstep_bootstrap`` shallow monomial cycles
up front (one Gram reduction each; the Ritz estimates themselves are free,
extracted from Grams the solver already reduces).

Overlapped schedule (``overlap=True`` — HFConfig.overlap, core/sstep.py
double-buffered cycles): only BLOCKING syncs count. Two s-iteration cycles
share one Gram reduction (effective stride 2s), the gradient reduce hides
behind the curvature primal build (0 blocking), and paired line-search
trials share round-trips —
  blocking syncs/outer iteration:  n_boot(2s) + ceil((K − covered)/2s)
                                   + ceil(E/2)
  (vs 1 + n_boot(s) + ceil((K − covered)/s) + E non-overlapped; at s=1
  the standard solver still runs, so the Krylov term stays K).
The *total* all-reduce count barely moves (the hidden reduces still
happen; the paired search adds one speculative loss reduce per shared
round-trip) — the float formulas with ``overlap=True`` price that.
Cross-checked against executed collective counts by
benchmarks/fig5_scaling.py --executed and tests/test_comm_model.py.
"""
from __future__ import annotations

import math
from typing import Sequence


def mp_floats_per_epoch(n: int, b: int, dims: Sequence[int]) -> float:
    return 2.0 * (n / b) * b * sum(dims[1:-1] if len(dims) > 2 else dims)


def mp_syncs_per_epoch(n: int, b: int, n_layers: int) -> float:
    return 2.0 * n_layers * n / b


def dp_floats_per_epoch(n: int, b: int, dims: Sequence[int], N: int) -> float:
    weights = sum(d0 * d1 for d0, d1 in zip(dims[:-1], dims[1:]))
    return (n / b) * max(math.log2(max(N, 2)), 1.0) * weights


def dp_syncs_per_epoch(n: int, b: int) -> float:
    return 2.0 * n / b


def model_size(dims: Sequence[int]) -> int:
    return sum(d0 * d1 + d1 for d0, d1 in zip(dims[:-1], dims[1:]))


def hf_floats_per_iteration(dims: Sequence[int], cg_iters: int, ls_evals: int) -> float:
    m = model_size(dims)
    return (1 + cg_iters) * m + ls_evals  # grad + HVPs (model-sized) + scalars


def hf_syncs_per_iteration(cg_iters: int, ls_evals: int) -> int:
    return 1 + cg_iters + ls_evals


def sstep_basis_len(s: int, solver: str = "cg") -> int:
    """Basis length per s-step cycle: [p-chain (d+1) | r-chain (d)] with
    chain depth d = s (CG) or 2s (Bi-CG-STAB: two products/iteration) —
    independent of the basis polynomial (monomial/Newton/Chebyshev chains
    have identical shape, core/sstep.py)."""
    d = 2 * s if solver == "bicgstab" else s
    return 2 * d + 1


# Mirrors core/sstep.py: f32-safe monomial power applications for the
# adaptive bases' bootstrap cycles.
SSTEP_BOOT_APPLICATIONS = 4


def sstep_bootstrap(s: int, solver: str = "cg", basis: str = "monomial"):
    """(bootstrap cycles, iterations they cover) for an s-step solve.

    The monomial basis has no bootstrap. The adaptive (newton/chebyshev)
    bases open with monomial cycles at the f32-safe depth until k ≥ s
    iterations have run (the structural rank floor — core/sstep.py), plus
    one extra margin cycle for Bi-CG-STAB's 2-products-per-iteration
    chains."""
    if basis == "monomial":
        return 0, 0
    if solver == "bicgstab":
        s_boot = max(1, min(s, SSTEP_BOOT_APPLICATIONS // 2))
        n_boot = -(-s // s_boot) + 1
    else:
        s_boot = max(1, min(s, SSTEP_BOOT_APPLICATIONS))
        n_boot = -(-s // s_boot)
    return n_boot, n_boot * s_boot


def hf_sstep_floats_per_iteration(
    dims: Sequence[int], cg_iters: int, ls_evals: int, s: int,
    solver: str = "cg", basis: str = "monomial", overlap: bool = False,
) -> float:
    """Floats exchanged per outer iteration with the s-step solve: gradient
    + the cycle product traffic + one small Gram per cycle + line-search
    scalars. Each cycle advances BOTH polynomial chains — 2d−1 model-sized
    products per cycle (chain depth d = s for CG, 2s for Bi-CG-STAB) vs s
    products for s standard CG iterations — so the model-sized traffic is
    asymptotically ~2× standard (s=1 CG reduces exactly to the standard
    count plus its 3×3 Gram). The adaptive bases (``basis=`` "newton" /
    "chebyshev") open with shallow bootstrap cycles whose chains cost
    proportionally less per cycle; the basis recurrence itself adds zero
    communication (axpys are node-local, the Ritz estimates ride the Gram
    the cycle already reduces). MORE bytes for s× fewer blocking syncs:
    the communication-avoiding trade, priced against latency by
    fig5_scaling.py's sstep series.

    ``overlap=True``: double-buffered cycles run chains at effective
    stride 2s (the deep half's products still cross the wire — hidden,
    not removed), and the paired line search sends one speculative extra
    loss scalar per shared round-trip."""
    m = model_size(dims)
    s_eff = 2 * s if (overlap and s > 1) else s
    n_boot, covered = sstep_bootstrap(s_eff, solver, basis)
    s_boot = 0 if n_boot == 0 else covered // n_boot
    cycles = math.ceil(max(cg_iters - covered, 0) / max(s_eff, 1))
    d = 2 * s_eff if solver == "bicgstab" else s_eff
    d_boot = 2 * s_boot if solver == "bicgstab" else s_boot
    bl = sstep_basis_len(s_eff, solver)        # == 2d + 1
    bl_boot = sstep_basis_len(s_boot, solver) if n_boot else 0
    gram_cols = bl + (3 if solver == "bicgstab" else 0)  # r0*/b/x probe cols
    gram_cols_boot = bl_boot + (3 if solver == "bicgstab" else 0)
    products = cycles * (2 * d - 1) + n_boot * max(2 * d_boot - 1, 0)
    grams = cycles * bl * gram_cols + n_boot * bl_boot * gram_cols_boot
    ls_floats = 2 * math.ceil(ls_evals / 2) if overlap else ls_evals
    return (1 + products) * m + grams + ls_floats


def hf_sstep_syncs_per_iteration(cg_iters: int, ls_evals: int, s: int,
                                 solver: str = "cg",
                                 basis: str = "monomial",
                                 overlap: bool = False) -> int:
    """Blocking synchronizations per outer iteration: the K per-Krylov-
    iteration scalar round-trips collapse to one Gram reduction per cycle
    of s iterations (1 + ceil(K/s) + E vs 1 + K + E). The adaptive bases
    prepend their bootstrap cycles (one Gram each, covering
    ``sstep_bootstrap`` iterations) — the price of the free Ritz
    estimates that let s double past the monomial f32 budget. Validated
    against the executed counts (KrylovResult.syncs) by
    benchmarks/sstep_bench.py.

    ``overlap=True`` counts only the syncs that still BLOCK under the
    overlapped schedule (HFConfig.overlap): the gradient reduce hides
    behind the curvature primal build (the leading 1 drops), cycles run
    double-buffered at effective stride 2s, and paired line-search trials
    share round-trips (E → ceil(E/2)). At s=1 the standard solver still
    runs (no cycles to double-buffer — core/hf.py engages s-step only for
    sstep_s>1), so overlap keeps the K per-iteration round-trips and saves
    only the gradient + line-search terms. Matches
    ``metrics["blocking_syncs"]``, measured end to end by
    benchmarks/fig5_scaling.py --executed."""
    s_eff = 2 * s if (overlap and s > 1) else s
    n_boot, covered = sstep_bootstrap(s_eff, solver, basis)
    cycles = math.ceil(max(cg_iters - covered, 0) / max(s_eff, 1))
    if overlap:
        krylov = (n_boot + cycles) if s > 1 else cg_iters
        return krylov + math.ceil(ls_evals / 2)
    return 1 + n_boot + cycles + ls_evals


def sgd_syncs_per_epoch(n: int, b: int, N: int) -> float:
    """Data-parallel SGD: one reduce+broadcast per mini-batch step."""
    return 2.0 * n / b


def speedup_model(
    n_nodes: int, *, compute_s_per_node_unit: float, bytes_per_sync: float,
    syncs: float, bw_bytes_s: float = 12.5e9, latency_s: float = 5e-6,
) -> float:
    """T(N) = compute/N + syncs·(latency·log2(N) + bytes/bw·(N-1)/N).
    Ring-allreduce cost model; returns T(1)/T(N)."""
    t1 = compute_s_per_node_unit + 0.0
    comm = syncs * (
        latency_s * max(math.log2(max(n_nodes, 2)), 1.0)
        + (bytes_per_sync / bw_bytes_s) * (n_nodes - 1) / max(n_nodes, 1)
    )
    tn = compute_s_per_node_unit / n_nodes + comm
    return t1 / tn
