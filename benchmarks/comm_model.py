"""Paper §3: analytic communication model for model- vs data-parallel SGD
and for distributed HF. These are the exact formulas from the paper, used by
fig5_scaling and validated in tests/test_comm_model.py.

Model parallelism (weights split over N nodes, layer dims d_1..d_l):
  floats exchanged / epoch ≈ 2 · (n/b) · b · Σ_i d_i
  synchronizations / epoch = 2 · l · n/b

Data parallelism (weights replicated, data split):
  floats exchanged / epoch ≈ (n/b) · log(N) · Σ_i d_{i-1}·d_i
  synchronizations / epoch = 2 · n/b

Distributed HF (this paper): per OUTER iteration —
  1 gradient reduce + K Krylov-iteration HVP reduces + E line-search loss
  reduces, each of model size (gradient/HVP) or scalar (loss);
  outer iterations per epoch ≈ 1 (full-batch gradient).

s-step (communication-avoiding) HF (core/sstep.py): the K per-iteration
Krylov synchronizations collapse into one Gram-matrix reduction per cycle of
s iterations —
  syncs/outer iteration:  1 + ceil(K/s) + E       (vs 1 + K + E standard)
  floats/outer iteration: MORE than standard — each cycle grows BOTH the p-
  and r-power chains (2d−1 products of model size per cycle, chain depth
  d = s for CG / 2s for Bi-CG-STAB, vs s products for s standard CG
  iterations: asymptotically ~2× the reduce traffic, though those reduces
  are dependency-free within a cycle and pipeline — no scalar gate between
  them), plus one (2d+1)²-float Gram per cycle (Bi-CG-STAB's is
  (4s+1)·(4s+4) with the r0*/b/x probe columns). Trading bytes for blocking
  syncs is the communication-avoiding deal; it pays when latency dominates
  (the paper's small-batch / many-node regime).
"""
from __future__ import annotations

import math
from typing import Sequence


def mp_floats_per_epoch(n: int, b: int, dims: Sequence[int]) -> float:
    return 2.0 * (n / b) * b * sum(dims[1:-1] if len(dims) > 2 else dims)


def mp_syncs_per_epoch(n: int, b: int, n_layers: int) -> float:
    return 2.0 * n_layers * n / b


def dp_floats_per_epoch(n: int, b: int, dims: Sequence[int], N: int) -> float:
    weights = sum(d0 * d1 for d0, d1 in zip(dims[:-1], dims[1:]))
    return (n / b) * max(math.log2(max(N, 2)), 1.0) * weights


def dp_syncs_per_epoch(n: int, b: int) -> float:
    return 2.0 * n / b


def model_size(dims: Sequence[int]) -> int:
    return sum(d0 * d1 + d1 for d0, d1 in zip(dims[:-1], dims[1:]))


def hf_floats_per_iteration(dims: Sequence[int], cg_iters: int, ls_evals: int) -> float:
    m = model_size(dims)
    return (1 + cg_iters) * m + ls_evals  # grad + HVPs (model-sized) + scalars


def hf_syncs_per_iteration(cg_iters: int, ls_evals: int) -> int:
    return 1 + cg_iters + ls_evals


def sstep_basis_len(s: int, solver: str = "cg") -> int:
    """Monomial-basis length per s-step cycle: [p, Ap, …, Aᵈp, r, …, A^{d−1}r]
    with chain depth d = s (CG) or 2s (Bi-CG-STAB: two products/iteration)."""
    d = 2 * s if solver == "bicgstab" else s
    return 2 * d + 1


def hf_sstep_floats_per_iteration(
    dims: Sequence[int], cg_iters: int, ls_evals: int, s: int,
    solver: str = "cg",
) -> float:
    """Floats exchanged per outer iteration with the s-step solve: gradient
    + the cycle product traffic + one small Gram per cycle + line-search
    scalars. Each cycle advances BOTH monomial chains — 2d−1 model-sized
    products per cycle (chain depth d = s for CG, 2s for Bi-CG-STAB) vs s
    products for s standard CG iterations — so the model-sized traffic is
    asymptotically ~2× standard (s=1 CG reduces exactly to the standard
    count plus its 3×3 Gram). MORE bytes for s× fewer blocking syncs: the
    communication-avoiding trade, priced against latency by
    fig5_scaling.py's sstep series."""
    m = model_size(dims)
    cycles = math.ceil(cg_iters / max(s, 1))
    d = 2 * s if solver == "bicgstab" else s
    bl = sstep_basis_len(s, solver)            # == 2d + 1
    gram_cols = bl + (3 if solver == "bicgstab" else 0)  # r0*/b/x probe cols
    return (1 + cycles * (2 * d - 1)) * m + cycles * bl * gram_cols + ls_evals


def hf_sstep_syncs_per_iteration(cg_iters: int, ls_evals: int, s: int) -> int:
    """Blocking synchronizations per outer iteration: the K per-Krylov-
    iteration scalar round-trips collapse to one Gram reduction per cycle
    of s iterations (1 + ceil(K/s) + E vs 1 + K + E). Validated against the
    executed counts (KrylovResult.syncs) by benchmarks/sstep_bench.py."""
    return 1 + math.ceil(cg_iters / max(s, 1)) + ls_evals


def sgd_syncs_per_epoch(n: int, b: int, N: int) -> float:
    """Data-parallel SGD: one reduce+broadcast per mini-batch step."""
    return 2.0 * n / b


def speedup_model(
    n_nodes: int, *, compute_s_per_node_unit: float, bytes_per_sync: float,
    syncs: float, bw_bytes_s: float = 12.5e9, latency_s: float = 5e-6,
) -> float:
    """T(N) = compute/N + syncs·(latency·log2(N) + bytes/bw·(N-1)/N).
    Ring-allreduce cost model; returns T(1)/T(N)."""
    t1 = compute_s_per_node_unit + 0.0
    comm = syncs * (
        latency_s * max(math.log2(max(n_nodes, 2)), 1.0)
        + (bytes_per_sync / bw_bytes_s) * (n_nodes - 1) / max(n_nodes, 1)
    )
    tn = compute_s_per_node_unit / n_nodes + comm
    return t1 / tn
