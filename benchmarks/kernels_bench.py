"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness-path
timing only) vs the jnp reference path (XLA-compiled, the meaningful CPU
number). On TPU the Pallas path compiles natively; derived column reports
the HBM-traffic model (bytes moved) which is hardware-independent.

Also benchmarks the *end-to-end solver paths*: one full Bi-CG-STAB / CG
solve through the tree (pytree leaf-ops) backend vs the flat (fused-kernel)
backend, plus the flat backend with the fusions replaced by plain jnp ops —
which isolates representation (ravel once vs per-leaf dispatch) from fusion.
On CPU the honest fused number is the jnp-substituted flat path (Pallas
interpret mode times the Python interpreter, not the kernel); on TPU the
fused path compiles natively and the traffic model predicts the win.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.krylov import FlatVectorBackend, get_backend
from repro.core.solvers import bicgstab, cg
from repro.kernels import ref


def _time_it(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


class _JnpFlatBackend(FlatVectorBackend):
    """Flat representation with the fused Pallas kernels swapped for plain
    jnp ops: isolates ravel-once representation from kernel fusion, and is
    the honest flat-path number on CPU (interpret mode times the Python
    interpreter, not the kernel)."""

    name = "flat_jnp"

    def dot(self, u, v):
        return jnp.vdot(u, v)

    def dot2(self, u, v):
        return jnp.vdot(u, v), jnp.vdot(v, v)

    def norm(self, v):
        return jnp.sqrt(jnp.vdot(v, v))

    def fused_update(self, y, u, v, a, g):
        return y + a * u + g * v

    def update_residual(self, s, As, gamma, r0s=None):
        r = s - gamma * As
        return r, (None if r0s is None else jnp.vdot(r, r0s)), jnp.vdot(r, r)


def _solver_rows(log):
    """End-to-end Krylov solve: tree backend vs flat backends.

    Operator = damped diagonal (cheap on purpose: isolates the recurrence
    cost, which is what the backends change). tol=0 forces the full
    iteration budget so both paths do identical work.
    """
    rows = []
    iters = 8
    n = 1 << 20  # ~1M params over 3 pytree leaves
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 4)
    shapes = {"w1": (1024, 512), "w2": (512, 512), "b": (n - 1024 * 512 - 512 * 512,)}
    d = {k: 1.0 + jax.random.uniform(kk, s) for (k, s), kk in zip(shapes.items(), ks)}
    b = {k: jax.random.normal(kk, s) for (k, s), kk in zip(shapes.items(), ks[1:])}
    x0 = jax.tree_util.tree_map(jnp.zeros_like, b)
    A = lambda v: jax.tree_util.tree_map(lambda dd, vv: dd * vv + 0.1 * vv, d, v)

    flat_ops = 10 * n * 4  # fused per-iteration bytes: 2×fused_update(4v) + residual_dots(2v)
    tree_ops = 16 * n * 4  # unfused: same updates as separate axpys + dots re-reading operands

    def bench(name, make_be, solver, solver_name):
        be = make_be()
        fn = jax.jit(lambda b, x0: solver(
            A, b, x0, lam=0.1, max_iters=iters, tol=0.0, backend=be).x)
        t = _time_it(fn, b, x0, reps=3)
        rows.append((f"kernels/{solver_name}_{name}_n1M_it{iters}", t * 1e6,
                     f"per_iter_us={t/iters*1e6:.0f} fused_traffic_ratio={flat_ops/tree_ops:.2f}"))

    for solver, sname in ((bicgstab, "bicgstab"), (cg, "cg")):
        bench("tree", lambda: get_backend("tree"), solver, sname)
        bench("flat_jnp", lambda: _JnpFlatBackend(b), solver, sname)
    # Pallas interpret mode: correctness-path timing only (Python executes the
    # kernel body block-by-block) — smaller size to keep the suite fast. On
    # TPU this path compiles natively and the traffic model above applies.
    bs = {k: v[:64] if v.ndim == 1 else v[:64, :64] for k, v in b.items()}
    ds = {k: v[:64] if v.ndim == 1 else v[:64, :64] for k, v in d.items()}
    x0s = jax.tree_util.tree_map(jnp.zeros_like, bs)
    As = lambda v: jax.tree_util.tree_map(lambda dd, vv: dd * vv + 0.1 * vv, ds, v)
    fn = jax.jit(lambda b, x0: bicgstab(
        As, b, x0, lam=0.1, max_iters=iters, tol=0.0,
        backend=FlatVectorBackend(bs, interpret=True)).x)
    t = _time_it(fn, bs, x0s, reps=1)
    rows.append((f"kernels/bicgstab_flat_pallas_interpret_small_it{iters}", t * 1e6,
                 "correctness_path_only=1"))
    return rows


def run(log=print):
    rows = []
    # flash attention reference path
    B, S, H, KV, hd = 2, 1024, 8, 2, 128
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, KV, hd), jnp.float32)
    fa_ref = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    t = _time_it(fa_ref, q, k, v)
    flops = 4 * B * H * S * S * hd
    rows.append(("kernels/attention_ref_jnp", t * 1e6, f"gflops={flops/t/1e9:.1f}"))

    # CG fused ops: bytes-moved model vs naive
    n = 4_000_000
    x, p, s = (jax.random.normal(kk, (n,), jnp.float32) for kk in jax.random.split(k1, 3))
    naive = jax.jit(lambda x, p, s: ref.bicgstab_x_update_ref(x, p, s, 0.5, 0.25))
    t = _time_it(naive, x, p, s)
    naive_bytes = 6 * n * 4      # unfused: 4 reads + 2 writes
    fused_bytes = 4 * n * 4      # fused kernel: 3 reads + 1 write
    rows.append(("kernels/x_update_ref_jnp", t * 1e6,
                 f"GBps={naive_bytes/t/1e9:.1f} fused_traffic_ratio={fused_bytes/naive_bytes:.2f}"))

    d = jax.jit(lambda s, As, r0s: ref.bicgstab_residual_dots_ref(s, As, r0s, 0.3))
    t = _time_it(d, x, p, s)
    rows.append(("kernels/residual_dots_ref_jnp", t * 1e6,
                 f"fused_traffic_ratio={(4*n*4)/(8*n*4):.2f}"))
    rows.extend(_solver_rows(log))
    return rows
