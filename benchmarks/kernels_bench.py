"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness-path
timing only) vs the jnp reference path (XLA-compiled, the meaningful CPU
number). On TPU the Pallas path compiles natively; derived column reports
the HBM-traffic model (bytes moved) which is hardware-independent.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time_it(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def run(log=print):
    rows = []
    # flash attention reference path
    B, S, H, KV, hd = 2, 1024, 8, 2, 128
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(k2, (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(k3, (B, S, KV, hd), jnp.float32)
    fa_ref = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    t = _time_it(fa_ref, q, k, v)
    flops = 4 * B * H * S * S * hd
    rows.append(("kernels/attention_ref_jnp", t * 1e6, f"gflops={flops/t/1e9:.1f}"))

    # CG fused ops: bytes-moved model vs naive
    n = 4_000_000
    x, p, s = (jax.random.normal(kk, (n,), jnp.float32) for kk in jax.random.split(k1, 3))
    naive = jax.jit(lambda x, p, s: ref.bicgstab_x_update_ref(x, p, s, 0.5, 0.25))
    t = _time_it(naive, x, p, s)
    naive_bytes = 6 * n * 4      # unfused: 4 reads + 2 writes
    fused_bytes = 4 * n * 4      # fused kernel: 3 reads + 1 write
    rows.append(("kernels/x_update_ref_jnp", t * 1e6,
                 f"GBps={naive_bytes/t/1e9:.1f} fused_traffic_ratio={fused_bytes/naive_bytes:.2f}"))

    d = jax.jit(lambda s, As, r0s: ref.bicgstab_residual_dots_ref(s, As, r0s, 0.3))
    t = _time_it(d, x, p, s)
    rows.append(("kernels/residual_dots_ref_jnp", t * 1e6,
                 f"fused_traffic_ratio={(4*n*4)/(8*n*4):.2f}"))
    return rows
