"""Pytest root conftest: make `repro` (src layout) and `benchmarks`
importable regardless of PYTHONPATH. Deliberately does NOT touch XLA flags —
smoke tests and benches must see the real (1-device) CPU; only
launch/dryrun.py sets the 512-device flag, in its own process.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
