"""Pytest root conftest: make `repro` (src layout) and `benchmarks`
importable regardless of PYTHONPATH. Deliberately does NOT touch XLA flags —
smoke tests and benches must see the real (1-device) CPU; only
launch/dryrun.py sets the 512-device flag, in its own process.

Registers the ``slow`` marker for long-running system/benchmark-shaped
tests. Tier-1 (`pytest -x -q`) deselects them by default via pytest.ini's
``addopts = -m "not slow"``; run everything with ``pytest -m ""`` or just
the slow set with ``pytest -m slow --override-ini addopts=``.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running system/benchmark-shaped test "
        "(deselected by default; run with -m '' or -m slow)",
    )
